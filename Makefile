# Distributed Lion — top-level convenience targets.
#
# `make verify` mirrors the CI tier-1 gate exactly; run it before
# pushing. Everything cargo-related runs from rust/.

CARGO_DIR := rust

.PHONY: verify build test docs fmt fmt-check bench-quick clean

## tier-1 verify: what CI runs (ROADMAP.md)
verify:
	cd $(CARGO_DIR) && cargo build --release && cargo test -q

## rustdoc with warnings denied (CI gates this alongside tier-1)
docs:
	cd $(CARGO_DIR) && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

fmt:
	cd $(CARGO_DIR) && cargo fmt

fmt-check:
	cd $(CARGO_DIR) && cargo fmt --check

## CI-speed smoke pass over the paper-table benches
bench-quick:
	cd $(CARGO_DIR) && DLION_BENCH_QUICK=1 cargo bench --bench table1_bandwidth -- --quick
	cd $(CARGO_DIR) && DLION_BENCH_QUICK=1 cargo bench --bench hotpath -- --quick

clean:
	cd $(CARGO_DIR) && cargo clean
