# Distributed Lion — top-level convenience targets.
#
# `make verify` mirrors the CI tier-1 gate exactly; run it before
# pushing. Everything cargo-related runs from rust/.

CARGO_DIR := rust

.PHONY: verify build test docs fmt fmt-check clippy artifacts-native lm-suite bench-quick bench-json bench-diff bench-check pgo topology mixed chaos clean

## tier-1 verify: what CI runs (ROADMAP.md)
verify:
	cd $(CARGO_DIR) && cargo build --release && cargo test -q

## rustdoc with warnings denied (CI gates this alongside tier-1)
docs:
	cd $(CARGO_DIR) && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

fmt:
	cd $(CARGO_DIR) && cargo fmt

fmt-check:
	cd $(CARGO_DIR) && cargo fmt --check

## lint gate CI runs alongside tier-1 (all targets, warnings are errors)
clippy:
	cd $(CARGO_DIR) && cargo clippy --all-targets -- -D warnings

## write a native artifact set (manifest.json + checksummed
## params_init.bin) under artifacts/ — no Python/JAX needed. Re-running
## is a no-op while the source_hash is unchanged. MODEL=tiny|small|
## lm10m|lm25m|lm100m, SEED, VOTE_WORKERS override the defaults.
MODEL ?= tiny
SEED ?= 0
VOTE_WORKERS ?= 4
artifacts-native:
	cd $(CARGO_DIR) && cargo run --release -q -- gen-artifacts \
		--model $(MODEL) --out ../artifacts --seed $(SEED) --vote-workers $(VOTE_WORKERS)

## the formerly artifacts-gated LM + runtime integration suites, run
## live on the native backend (zero skips) — CI runs this explicitly
lm-suite:
	cd $(CARGO_DIR) && cargo test -q --test integration_runtime --test native_backend

## CI-speed smoke pass over the paper-table benches (hotpath's JSON is
## routed to target/ so a smoke run never touches the committed baseline)
bench-quick:
	cd $(CARGO_DIR) && DLION_BENCH_QUICK=1 cargo bench --bench table1_bandwidth -- --quick
	cd $(CARGO_DIR) && DLION_BENCH_QUICK=1 DLION_BENCH_JSON=target/BENCH_fresh.json \
		cargo bench --bench hotpath -- --quick

## perf trajectory snapshot: runs the hotpath bench and refreshes
## BENCH_hotpath.json at the repo root (SWAR kernel micro-rows, vector
## codec rows at d=1M, monolithic-vs-chunked rounds at d=1M and d=4M)
## so speedups are comparable across PRs. Run WITHOUT quick mode when
## committing a new baseline so the numbers are stable.
bench-json:
	cd $(CARGO_DIR) && cargo bench --bench hotpath
	@echo "--- BENCH_hotpath.json ---" && cat BENCH_hotpath.json

## perf delta vs the committed baseline: re-measure the hotpath rows
## into target/BENCH_fresh.json (quick mode) and print the per-row
## delta table. Structural regressions (a baseline row missing from the
## fresh run) always exit nonzero; once the committed baseline is
## measured ("provisional": false), timing regressions past the
## tolerance gate too. The 0.5 tolerance (vs the CLI's 0.25 default)
## damps quick-mode noise on shared runners.
bench-diff:
	cd $(CARGO_DIR) && DLION_BENCH_QUICK=1 DLION_BENCH_JSON=target/BENCH_fresh.json \
		cargo bench --bench hotpath -- --quick
	cd $(CARGO_DIR) && cargo run --release -q -- bench-diff \
		--baseline ../BENCH_hotpath.json --fresh target/BENCH_fresh.json --tolerance 0.5

## assert the committed perf baseline is measured ("provisional": false,
## no null timings) — the CI step that keeps a provisional baseline from
## silently returning
bench-check:
	cd $(CARGO_DIR) && cargo run --release -q -- bench-check --baseline ../BENCH_hotpath.json

## profile-guided-optimization lane: (1) measure a warmup reference with
## the plain release build, (2) replay the hotpath bench on an
## instrumented build to collect profiles, (3) merge them with
## llvm-profdata, (4) rebuild with the profile and re-measure, then
## print the warmup-vs-PGO delta table (the PGO bench JSON also embeds a
## geomean summary under "pgo"). Everything lands under target/ — the
## committed BENCH_hotpath.json baseline is never touched.
PGO_DIR := $(CURDIR)/$(CARGO_DIR)/target/pgo
pgo:
	@LLVM_PROFDATA=$$(command -v llvm-profdata || \
		find "$$(rustc --print sysroot)" -name llvm-profdata -type f 2>/dev/null | head -n1); \
	if [ -z "$$LLVM_PROFDATA" ]; then \
		echo "pgo: llvm-profdata not found (install LLVM tools or rustup component add llvm-tools)"; \
		exit 1; \
	fi; \
	set -e; \
	cd $(CARGO_DIR); \
	echo "== PGO 1/4: warmup reference (plain release) =="; \
	DLION_PGO_PHASE=warmup DLION_BENCH_JSON=target/BENCH_pgo_warmup.json \
		cargo bench --bench hotpath -- --quick; \
	echo "== PGO 2/4: instrumented profile collection =="; \
	rm -rf "$(PGO_DIR)" && mkdir -p "$(PGO_DIR)"; \
	RUSTFLAGS="-Cprofile-generate=$(PGO_DIR)" \
		DLION_BENCH_JSON=target/BENCH_pgo_instr.json \
		cargo bench --bench hotpath -- --quick; \
	echo "== PGO 3/4: merging profiles =="; \
	"$$LLVM_PROFDATA" merge -o "$(PGO_DIR)/merged.profdata" "$(PGO_DIR)"; \
	echo "== PGO 4/4: profile-guided rebuild + re-measure =="; \
	RUSTFLAGS="-Cprofile-use=$(PGO_DIR)/merged.profdata" \
		DLION_PGO_PHASE=pgo DLION_PGO_WARMUP_JSON=target/BENCH_pgo_warmup.json \
		DLION_BENCH_JSON=target/BENCH_pgo.json \
		cargo bench --bench hotpath -- --quick; \
	cargo run --release -q -- bench-diff \
		--baseline target/BENCH_pgo_warmup.json --fresh target/BENCH_pgo.json --tolerance 10

## quick pass over the topology × local-steps extension bench
topology:
	cd $(CARGO_DIR) && DLION_BENCH_QUICK=1 cargo bench --bench ext_topology -- --quick

## quick pass over the mixed-wires extension bench (assignment ratios ×
## chunk sizes × topologies + the per-link @cheap/@rich selector)
mixed:
	cd $(CARGO_DIR) && DLION_BENCH_QUICK=1 cargo bench --bench ext_mixed -- --quick

## elastic-round chaos suite: the fixed-seed kill/delay/corrupt matrix
## (strategies × topologies × transports) + the TCP fault/reconnect
## tests. Deterministic — every fault plan is seeded in the tests.
chaos:
	cd $(CARGO_DIR) && cargo test -q --test chaos_rounds --test tcp_faults

clean:
	cd $(CARGO_DIR) && cargo clean
