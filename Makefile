# Distributed Lion — top-level convenience targets.
#
# `make verify` mirrors the CI tier-1 gate exactly; run it before
# pushing. Everything cargo-related runs from rust/.

CARGO_DIR := rust

.PHONY: verify build test docs fmt fmt-check clippy bench-quick bench-json bench-diff topology mixed chaos clean

## tier-1 verify: what CI runs (ROADMAP.md)
verify:
	cd $(CARGO_DIR) && cargo build --release && cargo test -q

## rustdoc with warnings denied (CI gates this alongside tier-1)
docs:
	cd $(CARGO_DIR) && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

fmt:
	cd $(CARGO_DIR) && cargo fmt

fmt-check:
	cd $(CARGO_DIR) && cargo fmt --check

## lint gate CI runs alongside tier-1 (all targets, warnings are errors)
clippy:
	cd $(CARGO_DIR) && cargo clippy --all-targets -- -D warnings

## CI-speed smoke pass over the paper-table benches (hotpath's JSON is
## routed to target/ so a smoke run never touches the committed baseline)
bench-quick:
	cd $(CARGO_DIR) && DLION_BENCH_QUICK=1 cargo bench --bench table1_bandwidth -- --quick
	cd $(CARGO_DIR) && DLION_BENCH_QUICK=1 DLION_BENCH_JSON=target/BENCH_fresh.json \
		cargo bench --bench hotpath -- --quick

## perf trajectory snapshot: runs the hotpath bench and refreshes
## BENCH_hotpath.json at the repo root (SWAR kernel micro-rows +
## monolithic-vs-chunked rounds at d=1M and d=4M) so speedups are
## comparable across PRs. Run WITHOUT quick mode when committing a new
## baseline so the numbers are stable.
bench-json:
	cd $(CARGO_DIR) && cargo bench --bench hotpath
	@echo "--- BENCH_hotpath.json ---" && cat BENCH_hotpath.json

## perf delta vs the committed baseline: re-measure the hotpath rows
## into target/BENCH_fresh.json (quick mode) and print the per-row
## delta table. Exits nonzero only on structural regressions (a
## baseline row missing from the fresh run); timing noise is soft.
bench-diff:
	cd $(CARGO_DIR) && DLION_BENCH_QUICK=1 DLION_BENCH_JSON=target/BENCH_fresh.json \
		cargo bench --bench hotpath -- --quick
	cd $(CARGO_DIR) && cargo run --release -q -- bench-diff \
		--baseline ../BENCH_hotpath.json --fresh target/BENCH_fresh.json

## quick pass over the topology × local-steps extension bench
topology:
	cd $(CARGO_DIR) && DLION_BENCH_QUICK=1 cargo bench --bench ext_topology -- --quick

## quick pass over the mixed-wires extension bench (assignment ratios ×
## chunk sizes × topologies + the per-link @cheap/@rich selector)
mixed:
	cd $(CARGO_DIR) && DLION_BENCH_QUICK=1 cargo bench --bench ext_mixed -- --quick

## elastic-round chaos suite: the fixed-seed kill/delay/corrupt matrix
## (strategies × topologies × transports) + the TCP fault/reconnect
## tests. Deterministic — every fault plan is seeded in the tests.
chaos:
	cd $(CARGO_DIR) && cargo test -q --test chaos_rounds --test tcp_faults

clean:
	cd $(CARGO_DIR) && cargo clean
