//! Bandwidth probe: runs every strategy one synchronous round over the
//! real threaded fabric (and optionally loopback TCP) and verifies the
//! transport-counted bytes equal the analytic Table-1 prediction.
//!
//! Run: `cargo run --release --example bandwidth_probe [--tcp]`

use dlion::bench_utils::Table;
use dlion::cluster::{run_threaded, TrainConfig};
use dlion::comm::{tcp, CommStats, ServerTransport, WorkerTransport};
use dlion::optim::dist::{by_name, StrategyHyper, ALL_STRATEGIES};
use dlion::tasks::quadratic::Quadratic;
use dlion::tasks::GradTask;
use std::sync::Arc;

fn main() {
    let d = 100_000;
    let n = 4;
    let steps = 5;
    let hp = StrategyHyper::default();
    let mut table = Table::new(
        &format!("Measured vs analytic bandwidth (d={d}, n={n}, {steps} steps)"),
        &["strategy", "uplink B/step", "analytic", "downlink B/step", "analytic"],
    );
    for name in ALL_STRATEGIES {
        let strategy = by_name(name, &hp).unwrap();
        let task: Arc<dyn GradTask + Send + Sync> = Arc::new(Quadratic::new(d, 5.0, 0.5, 1));
        let cfg = TrainConfig {
            steps,
            batch_per_worker: 4,
            base_lr: 1e-3,
            eval_every: 0,
            seed: 3,
            ..Default::default()
        };
        let (_, stats) = run_threaded(task, strategy.as_ref(), n, &cfg);
        let up_per_step = stats.uplink() as f64 / steps as f64;
        let down_per_step = stats.downlink() as f64 / steps as f64;
        let up_pred = strategy.uplink_bits_per_param(n) * d as f64 * n as f64 / 8.0;
        let down_pred = strategy.downlink_bits_per_param(n) * d as f64 * n as f64 / 8.0;
        table.row(vec![
            name.to_string(),
            format!("{up_per_step:.0}"),
            format!("{up_pred:.0}"),
            format!("{down_per_step:.0}"),
            format!("{down_pred:.0}"),
        ]);
    }
    table.print();

    if std::env::args().any(|a| a == "--tcp") {
        println!("TCP loopback round (d=10_000, n=3, d-lion-mavo):");
        let stats = CommStats::new();
        let (port, listener) = tcp::bind_loopback().unwrap();
        let d = 10_000;
        let n = 3;
        let hp = StrategyHyper::default();
        let strategy = by_name("d-lion-mavo", &hp).unwrap();
        let handles: Vec<_> = (0..n)
            .map(|id| {
                let stats = stats.clone();
                let mut logic = strategy.make_worker(id, n, d);
                std::thread::spawn(move || {
                    let mut w = tcp::TcpWorker::connect(port, id, stats).unwrap();
                    let mut rng = dlion::util::Rng::new(id as u64);
                    let mut grad = vec![0.0f32; d];
                    rng.fill_normal(&mut grad, 1.0);
                    let mut params = vec![0.0f32; d];
                    let up = logic.encode(&grad, 1e-3, 0);
                    w.send(up).unwrap();
                    let down = w.recv().unwrap();
                    logic.apply(&mut params, &down, 1e-3, 0);
                    params
                })
            })
            .collect();
        let mut server_t = tcp::TcpServer::accept(&listener, n, stats.clone()).unwrap();
        let mut server = strategy.make_server(n, d);
        let uplinks = server_t.gather().unwrap();
        let downlink = server.aggregate(&uplinks, 1e-3, 0);
        server_t.broadcast(&downlink).unwrap();
        let params: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(params.windows(2).all(|w| w[0] == w[1]), "replicas diverged over TCP");
        println!(
            "  ok: uplink {} B, downlink {} B, replicas identical",
            stats.uplink(),
            stats.downlink()
        );
    }
}
