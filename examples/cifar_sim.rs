//! Figure 2/3 scenario, single-shot: all seven Section-5.1 methods on
//! the synthetic vision task with k ∈ {4, 8} workers. The full sweep
//! (4 worker counts × 3 seeds, Figure 2/3/4 CSVs) lives in
//! `cargo bench --bench fig2_cifar_sim`; this example is the readable
//! version a user runs first.
//!
//! Run: `cargo run --release --example cifar_sim`

use dlion::bench_utils::Table;
use dlion::cluster::{run_sequential, TrainConfig};
use dlion::optim::dist::{by_name, StrategyHyper};
use dlion::tasks::data::VisionData;
use dlion::tasks::mlp::MlpVision;
use dlion::tasks::GradTask;
use std::sync::Arc;

const METHODS: &[&str] = &[
    "g-adamw", "g-lion", "d-lion-avg", "d-lion-mavo", "terngrad", "graddrop", "dgc",
];

fn main() {
    let data = Arc::new(VisionData::generate(4096, 1024, 1.6, 42));
    let task = MlpVision::new(data, 64);
    let d = task.dim();
    println!("synthetic-CIFAR stand-in: {} params, 10 classes", d);

    let mut table = Table::new(
        "Distributed Lion vs established methods (paper Fig. 2 regime)",
        &["method", "k=4 acc", "k=8 acc", "bits/param/iter (k=4)"],
    );
    for &name in METHODS {
        // Table 2 hyper-parameters: Lion-family lr lower than the rest.
        let (lr, wd) = match name {
            "g-adamw" => (1e-3, 0.0005),
            "g-lion" | "d-lion-avg" | "d-lion-mavo" => (5e-4, 0.005),
            _ => (5e-3, 0.0005),
        };
        let hp = StrategyHyper { weight_decay: wd as f32, ..Default::default() };
        let strategy = by_name(name, &hp).expect("strategy");
        let mut accs = Vec::new();
        let mut bits = 0.0;
        for &k in &[4usize, 8] {
            let cfg = TrainConfig {
                steps: 800,
                batch_per_worker: 32,
                base_lr: lr,
                eval_every: 0,
                seed: 42,
                ..Default::default()
            };
            let res = run_sequential(&task, strategy.as_ref(), k, &cfg);
            accs.push(res.final_eval.unwrap().accuracy.unwrap());
            if k == 4 {
                bits = res.bits_per_param_per_iter(d);
            }
        }
        table.row(vec![
            name.to_string(),
            format!("{:.3}", accs[0]),
            format!("{:.3}", accs[1]),
            format!("{bits:.2}"),
        ]);
    }
    table.print();
    println!("Expected shape (paper Fig. 2): D-Lion ≈ G-Lion ≈ G-AdamW accuracy;");
    println!("TernGrad/GradDrop/DGC trail at matched (low) bandwidth.");
}
