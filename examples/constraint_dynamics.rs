//! Section-4 theory, empirically: Phase I exponential constraint
//! enforcement (Theorem 4.4) and Phase II KKT-score decay with the
//! √N majority-vote advantage (Theorems 4.6 vs 4.8).
//!
//! Run: `cargo run --release --example constraint_dynamics`

use dlion::cluster::{run_sequential, TrainConfig};
use dlion::optim::dist::{by_name, StrategyHyper};
use dlion::optim::lion::Lion;
use dlion::optim::{LionParams, Optimizer};
use dlion::tasks::quadratic::Quadratic;
use dlion::tasks::GradTask;
use dlion::theory;
use dlion::util::Rng;

fn phase1() {
    println!("== Phase I (Thm 4.4): dist(x_t, F) <= (1-ελ)^t dist(x_0, F) ==\n");
    let d = 64;
    let lambda = 0.5f32;
    let eps = 0.05f32;
    let q = Quadratic::new(d, 5.0, 0.2, 1);
    let mut lion = Lion::new(d, LionParams { beta1: 0.9, beta2: 0.99, weight_decay: lambda });
    let mut x = vec![30.0f32; d];
    let mut g = vec![0.0f32; d];
    let mut rng = Rng::new(2);
    println!("{:>5} {:>14} {:>14} {:>8}", "t", "dist(x,F)", "(1-ελ)^t·d0", "phase");
    let d0 = theory::dist_to_feasible(&x, lambda);
    let mut dists = Vec::new();
    for t in 0..120 {
        let dist = theory::dist_to_feasible(&x, lambda);
        dists.push(dist);
        if t % 10 == 0 {
            let bound = (1.0 - (eps * lambda) as f64).powi(t as i32) * d0;
            println!(
                "{t:>5} {dist:>14.6} {bound:>14.6} {:>8}",
                match theory::phase(&x, lambda) {
                    theory::Phase::ConstraintEnforcing => "I",
                    theory::Phase::Optimizing => "II",
                }
            );
        }
        q.minibatch_grad(&x, &mut rng, 8, &mut g);
        lion.step(&mut x, &g, eps);
    }
    theory::check_phase1_contraction(&dists, (eps * lambda) as f64, 1.05)
        .expect("Theorem 4.4 contraction");
    println!("\ncontraction bound verified for all (s, t) pairs ✓\n");
}

fn phase2() {
    println!("== Phase II (Thm 4.6/4.8): KKT score S̄ vs worker count N ==\n");
    let d = 256;
    let lambda = 0.1f32;
    println!(
        "{:>14} {:>10} {:>10} {:>10} {:>10}",
        "strategy", "N=1", "N=4", "N=16", "N=64"
    );
    for name in ["d-lion-mavo", "d-lion-avg"] {
        let hp = StrategyHyper { weight_decay: lambda, ..Default::default() };
        let strategy = by_name(name, &hp).unwrap();
        let mut row = format!("{name:>14}");
        for n in [1usize, 4, 16, 64] {
            // average the KKT score along the trajectory tail
            let q = Quadratic::new(d, 5.0, 4.0, 7);
            let cfg = TrainConfig {
                steps: 400,
                batch_per_worker: 1,
                base_lr: 0.004,
                min_lr_frac: 1.0, // constant lr: matches the theorem setting
                eval_every: 0,
                seed: 11,
                ..Default::default()
            };
            let res = run_sequential(&q, strategy.as_ref(), n, &cfg);
            let x = res.final_params.as_ref().unwrap();
            let mut g = vec![0.0f32; d];
            q.true_grad(x, &mut g);
            let s = theory::kkt_score(&g, x, lambda) / d as f64;
            row.push_str(&format!(" {s:>10.5}"));
        }
        println!("{row}");
    }
    println!("\nExpected shape: MaVo's score falls with N (Thm 4.6's 1/√N term);");
    println!("Avg's floor does not improve with N (Thm 4.8's N-independent σ term).");
}

fn main() {
    phase1();
    phase2();
}
