//! Quickstart: train a small classifier with Distributed Lion (MaVo) on
//! 4 workers and compare its communication volume against Global AdamW.
//!
//! Run: `cargo run --release --example quickstart`

use dlion::cluster::{run_sequential, TrainConfig};
use dlion::optim::dist::{by_name, StrategyHyper};
use dlion::tasks::data::VisionData;
use dlion::tasks::mlp::MlpVision;
use dlion::tasks::GradTask;
use std::sync::Arc;

fn main() {
    // 1. A task: synthetic 10-class vision problem, 2-layer MLP.
    let data = Arc::new(VisionData::generate(4096, 1024, 1.6, 42));
    let task = MlpVision::new(data, 64);
    println!("task: {} ({} parameters)", task.name(), task.dim());

    // 2. A training configuration (paper defaults: batch 32/worker,
    //    cosine schedule, 3 seeds — one seed here for speed).
    let cfg = TrainConfig {
        steps: 600,
        batch_per_worker: 32,
        base_lr: 1e-3,
        eval_every: 200,
        seed: 42,
        ..Default::default()
    };
    let hp = StrategyHyper { weight_decay: 0.005, ..Default::default() };
    let nworkers = 4;

    // 3. Train with two strategies and compare accuracy + bandwidth.
    for name in ["d-lion-mavo", "g-adamw"] {
        let strategy = by_name(name, &hp).expect("registered strategy");
        let result = run_sequential(&task, strategy.as_ref(), nworkers, &cfg);
        let eval = result.final_eval.as_ref().unwrap();
        println!(
            "{name:>12}: acc {:.3}  loss {:.3}  comm {:>12} bytes ({:.1} bits/param/iter)",
            eval.accuracy.unwrap_or(f64::NAN),
            eval.loss,
            result.total_uplink() + result.total_downlink(),
            result.bits_per_param_per_iter(task.dim()),
        );
    }
    println!("\nD-Lion should match G-AdamW accuracy at ~30x less communication.");
}
