//! End-to-end driver (EXPERIMENTS.md §E2E): train the GPT2++-style
//! transformer with Distributed Lion through the full three-layer
//! stack —
//!
//!   L3 rust coordinator (this binary: workers, majority-vote server,
//!      1-bit codecs, byte accounting)
//!   L2 transformer fwd/bwd artifact (`train_step`: the pure-Rust
//!      native backend by default; PJRT when `--artifacts` points at
//!      an AOT set from `make artifacts`)
//!   L1 fused Lion kernel artifact (`lion_update`, equivalence-checked
//!      against the coordinator's native update)
//!
//! Works on a fresh checkout with no artifacts directory. Flags:
//! --steps N --workers N --strategy NAME --corpus-bytes N
//! --out csv_path --save ckpt.bin --resume ckpt.bin

use dlion::cluster::{run_sequential, TrainConfig};
use dlion::lm::corpus::Grammar;
use dlion::lm::LmTask;
use dlion::optim::dist::{by_name, StrategyHyper};
use dlion::runtime::LionUpdateExec;
use dlion::tasks::GradTask;
use dlion::util::Rng;

fn arg(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() {
    let artifacts = arg("--artifacts").unwrap_or_else(|| "artifacts".into());
    let steps: usize = arg("--steps").and_then(|s| s.parse().ok()).unwrap_or(300);
    let workers: usize = arg("--workers").and_then(|s| s.parse().ok()).unwrap_or(4);
    let strategy_name = arg("--strategy").unwrap_or_else(|| "d-lion-mavo".into());
    let corpus_bytes: usize =
        arg("--corpus-bytes").and_then(|s| s.parse().ok()).unwrap_or(400_000);

    let mut task = LmTask::new(&artifacts, corpus_bytes, Grammar::default(), 42)
        .expect("LM task (falls back to the native backend when no artifacts exist)");
    if let Some(path) = arg("--resume") {
        let ck = dlion::lm::checkpoint::Checkpoint::load(
            &path,
            &task.rt.manifest.model_name,
            task.rt.manifest.flat_dim,
        )
        .expect("load checkpoint");
        println!("resumed from {path} (step {})", ck.step);
        task.set_init(ck.params);
    }
    let d = task.dim();
    println!(
        "model={} backend={} d={} batch/worker={} seq={} workers={workers} strategy={strategy_name}",
        task.rt.manifest.model_name,
        task.rt.backend_name(),
        d,
        task.batch,
        task.seq_plus1 - 1
    );

    // Cross-layer equivalence check: the L1 Pallas lion kernel must agree
    // bit-exactly with the coordinator's native update on real data.
    {
        let lu = LionUpdateExec::new(&task.rt).expect("lion_update artifact");
        let mut rng = Rng::new(7);
        let mut m = vec![0.0f32; d];
        let mut g = vec![0.0f32; d];
        rng.fill_normal(&mut m, 0.01);
        rng.fill_normal(&mut g, 1.0);
        let (delta, m_new) = lu.run(&m, &g).unwrap();
        let mut lion = dlion::optim::lion::Lion::new(d, Default::default());
        lion.momentum.copy_from_slice(&m);
        let mut native = vec![0.0f32; d];
        lion.peek_update(&g, &mut native);
        lion.advance_momentum(&g);
        assert!(
            delta.iter().zip(&native).all(|(&k, &n)| k as f32 == n),
            "lion_update artifact and native update disagree"
        );
        let max_m_err = m_new
            .iter()
            .zip(&lion.momentum)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_m_err < 1e-5, "momentum mismatch {max_m_err}");
        println!("L1 kernel ≡ L3 native update: OK (d={d})");
    }

    let hp = StrategyHyper { weight_decay: 0.1, ..Default::default() };
    let strategy = by_name(&strategy_name, &hp).expect("registered strategy");
    let cfg = TrainConfig {
        steps,
        base_lr: 1e-3,
        warmup_steps: steps / 20,
        eval_every: (steps / 10).max(1),
        seed: 42,
        batch_per_worker: 0, // batch baked into the artifact
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let result = run_sequential(&task, strategy.as_ref(), workers, &cfg);
    println!("\nstep   train_loss  eval_loss  ppl");
    for r in &result.history {
        if let Some(e) = &r.eval {
            println!("{:>5}  {:>9.4}  {:>9.4}  {:>6.2}", r.step, r.train_loss, e.loss, e.loss.exp());
        }
    }
    let fin = result.final_eval.unwrap();
    let first = result.history.first().map(|r| r.train_loss).unwrap_or(f64::NAN);
    println!(
        "\nfinal: eval_loss={:.4} ppl={:.3} (train loss {first:.3} → {:.3})",
        fin.loss,
        fin.loss.exp(),
        result.tail_loss(10),
    );
    println!(
        "comm: uplink={} B downlink={} B  ({:.2} bits/param/iter; 32-bit dense would be {:.0})",
        result.total_uplink(),
        result.total_downlink(),
        result.bits_per_param_per_iter(d),
        64.0 * workers as f64,
    );
    println!("wall: {:.1}s ({:.2} s/step)", t0.elapsed().as_secs_f64(), t0.elapsed().as_secs_f64() / steps as f64);
    if let Some(out) = arg("--out") {
        result.write_csv(&out).unwrap();
        println!("history written to {out}");
    }
    if let Some(path) = arg("--save") {
        let ck = dlion::lm::checkpoint::Checkpoint::new(
            steps as u64,
            task.rt.manifest.model_name.clone(),
            result.final_params.clone().unwrap(),
        );
        ck.save(&path).unwrap();
        println!("checkpoint saved to {path}");
    }
    assert!(
        fin.loss < first,
        "training must reduce loss: final {} vs initial {first}",
        fin.loss
    );
}
