"""AOT pipeline: lower the L2 model + L1 kernels to HLO text artifacts.

Usage (from python/):
    python -m compile.aot --config tiny --out ../artifacts

Emits into the output directory:
    train_step.hlo.txt     fused fwd+bwd: (tokens, *params) -> (loss, *grads)
    eval_step.hlo.txt      loss only
    lion_update.hlo.txt    L1 Pallas fused Lion worker update over flat d
    majority_vote.hlo.txt  L1 Pallas vote aggregation (N x d -> d)
    apply_update.hlo.txt   x - lr*(delta + wd*x) elementwise
    params_init.bin        flat f32 LE initial parameters
    manifest.json          layout + artifact contract for the rust runtime

Interchange is HLO *text*, not `.serialize()`: jax >= 0.5 emits protos
with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md and aot_recipe).
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import lion_step, majority_vote

MANIFEST_VERSION = 1
# Workers per majority_vote artifact (server-side aggregation width).
DEFAULT_VOTE_WORKERS = 4


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_train_step(cfg):
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32)
    params = [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in M.param_specs(cfg)
    ]
    return jax.jit(M.make_train_step(cfg)).lower(tok, *params)


def lower_eval_step(cfg):
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32)
    params = [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in M.param_specs(cfg)
    ]
    return jax.jit(M.make_eval_step(cfg)).lower(tok, *params)


def lower_lion_update(flat_dim, beta1, beta2):
    spec = jax.ShapeDtypeStruct((flat_dim,), jnp.float32)

    def fn(m, g):
        return lion_step.lion_update(m, g, beta1=beta1, beta2=beta2)

    return jax.jit(fn).lower(spec, spec)


def lower_majority_vote(nworkers, flat_dim):
    spec = jax.ShapeDtypeStruct((nworkers, flat_dim), jnp.int8)

    def fn(deltas):
        return (majority_vote.majority_vote(deltas),)

    return jax.jit(fn).lower(spec)


def lower_apply_update(flat_dim):
    x = jax.ShapeDtypeStruct((flat_dim,), jnp.float32)
    delta = jax.ShapeDtypeStruct((flat_dim,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    def fn(x, delta, lr, wd):
        return (x - lr * (delta + wd * x),)

    return jax.jit(fn).lower(x, delta, scalar, scalar)


def tensor_json(name, shape, dtype="f32", offset=None):
    d = {"name": name, "shape": list(int(s) for s in shape), "dtype": dtype}
    if offset is not None:
        d["offset"] = int(offset)
    return d


def build(cfg_name: str, out_dir: str, seed: int = 0, vote_workers: int = DEFAULT_VOTE_WORKERS,
          force: bool = False) -> dict:
    cfg = M.CONFIGS[cfg_name]
    os.makedirs(out_dir, exist_ok=True)

    # Input-hash for no-op rebuilds: config + source files.
    srcs = []
    here = os.path.dirname(__file__)
    for root, _, files in os.walk(here):
        for f in sorted(files):
            if f.endswith(".py"):
                srcs.append(os.path.join(root, f))
    h = hashlib.sha256()
    h.update(repr(cfg).encode())
    h.update(str(seed).encode())
    h.update(str(vote_workers).encode())
    for s in srcs:
        with open(s, "rb") as fh:
            h.update(fh.read())
    input_hash = h.hexdigest()[:16]
    stamp_path = os.path.join(out_dir, ".stamp")
    if not force and os.path.exists(stamp_path):
        with open(stamp_path) as fh:
            if fh.read().strip() == input_hash:
                print(f"artifacts up to date (hash {input_hash}); skipping")
                with open(os.path.join(out_dir, "manifest.json")) as mf:
                    return json.load(mf)

    specs = M.param_specs(cfg)
    flat_dim = 0
    params_json = []
    for name, shape in specs:
        n = int(np.prod(shape))
        params_json.append(tensor_json(name, shape, "f32", offset=flat_dim))
        flat_dim += n
    print(f"model {cfg.name}: {flat_dim:,} params, {len(specs)} tensors")

    artifacts = {}

    def emit(name, lowered, inputs, outputs):
        fname = f"{name}.hlo.txt"
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        artifacts[name] = {"file": fname, "inputs": inputs, "outputs": outputs}
        print(f"  wrote {fname} ({len(text) / 1e6:.2f} MB)")

    tok_spec = tensor_json("tokens", (cfg.batch, cfg.seq_len + 1), "i32")
    param_specs_json = [tensor_json(n, s) for n, s in specs]
    grad_specs_json = [tensor_json("d_" + n, s) for n, s in specs]

    emit(
        "train_step",
        lower_train_step(cfg),
        [tok_spec] + param_specs_json,
        [tensor_json("loss", ())] + grad_specs_json,
    )
    emit(
        "eval_step",
        lower_eval_step(cfg),
        [tok_spec] + param_specs_json,
        [tensor_json("loss", ())],
    )
    emit(
        "lion_update",
        lower_lion_update(flat_dim, beta1=0.9, beta2=0.99),
        [tensor_json("m", (flat_dim,)), tensor_json("g", (flat_dim,))],
        [tensor_json("delta", (flat_dim,), "i8"), tensor_json("m_new", (flat_dim,))],
    )
    emit(
        "majority_vote",
        lower_majority_vote(vote_workers, flat_dim),
        [tensor_json("deltas", (vote_workers, flat_dim), "i8")],
        [tensor_json("agg", (flat_dim,), "i8")],
    )
    emit(
        "apply_update",
        lower_apply_update(flat_dim),
        [
            tensor_json("x", (flat_dim,)),
            tensor_json("delta", (flat_dim,)),
            tensor_json("lr", ()),
            tensor_json("wd", ()),
        ],
        [tensor_json("x_new", (flat_dim,))],
    )

    # Initial parameters (flat f32 LE).
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    flat = np.concatenate([np.asarray(p, dtype=np.float32).ravel() for p in params])
    assert flat.size == flat_dim, (flat.size, flat_dim)
    flat.astype("<f4").tofile(os.path.join(out_dir, "params_init.bin"))
    print(f"  wrote params_init.bin ({flat.nbytes / 1e6:.1f} MB)")

    manifest = {
        "version": MANIFEST_VERSION,
        "model": cfg.name,
        "input_hash": input_hash,
        "config": {
            "vocab": cfg.vocab,
            "dim": cfg.dim,
            "layers": cfg.layers,
            "heads": cfg.heads,
            "seq_len": cfg.seq_len,
            "batch": cfg.batch,
            "vote_workers": vote_workers,
        },
        "flat_dim": flat_dim,
        "params": params_json,
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(stamp_path, "w") as f:
        f.write(input_hash)
    print(f"  wrote manifest.json (hash {input_hash})")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", default="tiny", choices=sorted(M.CONFIGS))
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--vote-workers", type=int, default=DEFAULT_VOTE_WORKERS)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    build(args.config, args.out, seed=args.seed, vote_workers=args.vote_workers,
          force=args.force)


if __name__ == "__main__":
    main()
