"""L1 Pallas kernel: fused Distributed-Lion worker update.

One HBM->VMEM pass over (m, g) tiles computes BOTH outputs of the
worker step (paper eq. 4):

    delta = bsign(beta1 * m + (1 - beta1) * g)   (int8, 4x smaller store)
    m_new = beta2 * m + (1 - beta2) * g          (f32)

Unfused, this is three elementwise passes (blend, sign, momentum) and a
f32 update store; fused it is one pass and an int8 update store — the
kernel is purely bandwidth-bound (arithmetic intensity ~5 flops / 9
bytes), so the fusion IS the optimization. See DESIGN.md
§Hardware-Adaptation for the TPU (VMEM/BlockSpec) sizing rationale.

MUST run with interpret=True on this image: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT client cannot execute; interpret mode
lowers to plain HLO that XLA-CPU compiles natively (the *runtime*
artifact is still fused compiled code).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 64k f32 per tile = 256 KiB; 3 live tiles (m, g, m_new) + int8 delta
# ≈ 832 KiB, far under the ~16 MiB VMEM of a TPU core. On CPU interpret
# mode this is simply the loop-block size.
DEFAULT_BLOCK = 65536


def _kernel(m_ref, g_ref, delta_ref, mnew_ref, *, beta1, beta2):
    m = m_ref[...]
    g = g_ref[...]
    blend = beta1 * m + (1.0 - beta1) * g
    # binarized sign: >= 0 -> +1 (never 0, required by the 1-bit codec)
    delta_ref[...] = jnp.where(blend >= 0, 1, -1).astype(jnp.int8)
    mnew_ref[...] = beta2 * m + (1.0 - beta2) * g


def lion_update(m, g, beta1=0.9, beta2=0.99, block=DEFAULT_BLOCK, interpret=True):
    """Fused Lion worker update via Pallas.

    m, g: f32[d] (d need not divide block; inputs are padded internally).
    Returns (delta int8[d], m_new f32[d]).
    """
    d = m.shape[0]
    assert m.shape == g.shape, (m.shape, g.shape)
    block = min(block, max(d, 1))
    pad = (-d) % block
    if pad:
        m = jnp.pad(m, (0, pad))
        g = jnp.pad(g, (0, pad))
    dp = d + pad
    grid = dp // block
    kernel = functools.partial(_kernel, beta1=float(beta1), beta2=float(beta2))
    delta, m_new = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dp,), jnp.int8),
            jax.ShapeDtypeStruct((dp,), jnp.float32),
        ],
        interpret=interpret,
    )(m, g)
    if pad:
        delta = delta[:d]
        m_new = m_new[:d]
    return delta, m_new
