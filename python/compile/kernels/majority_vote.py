"""L1 Pallas kernel: majority-vote aggregation (paper eq. 5).

Server-side: given the N workers' binary updates stacked as
int8[N, d], compute sign(sum_i delta_i) per coordinate. Tiled along d:
each grid step loads an (N, block) int8 tile (the whole worker column
fits VMEM for N <= 64 with block = 32k: 2 MiB in, 32 KiB out), reduces
along the worker axis in int32, and stores the int8 ternary result.

interpret=True for the same CPU-PJRT reason as lion_step.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 32768


def _kernel(deltas_ref, out_ref):
    votes = jnp.sum(deltas_ref[...].astype(jnp.int32), axis=0)
    out_ref[...] = jnp.sign(votes).astype(jnp.int8)


def majority_vote(deltas, block=DEFAULT_BLOCK, interpret=True):
    """sign(sum over workers) of an int8[N, d] stack -> int8[d]."""
    n, d = deltas.shape
    block = min(block, max(d, 1))
    pad = (-d) % block
    if pad:
        # zero-pad: padded coords produce sign(0)=0, sliced off below
        deltas = jnp.pad(deltas, ((0, 0), (0, pad)))
    dp = d + pad
    grid = dp // block
    out = pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((n, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.int8),
        interpret=interpret,
    )(deltas)
    return out[:d] if pad else out
