"""Pure-jnp oracles for the Pallas kernels.

These are the correctness contracts: `lion_step.lion_update` and
`majority_vote.majority_vote` must match these bit-for-bit (integer
outputs) / to float tolerance (momentum) under pytest + hypothesis.

Sign convention: the *binarized* sign ``bsign(x) = +1 if x >= 0 else -1``
(zero maps to +1), matching the rust `optim::lion::bsign` so the 1-bit
codec never sees a zero. ``jnp.sign`` is NOT used on the worker update path.
"""

import jax.numpy as jnp

# Default Lion betas (Chen et al. 2023b; paper Algorithm 1).
BETA1 = 0.9
BETA2 = 0.99


def bsign(x):
    """Binarized sign: x >= 0 -> +1 else -1 (int8)."""
    return jnp.where(x >= 0, 1, -1).astype(jnp.int8)


def lion_update_ref(m, g, beta1=BETA1, beta2=BETA2):
    """Reference fused Lion worker update (paper eq. 4).

    Returns (delta int8 in {-1,+1}, m_new f32):
      delta = bsign(beta1 * m + (1 - beta1) * g)
      m_new = beta2 * m + (1 - beta2) * g
    """
    m = m.astype(jnp.float32)
    g = g.astype(jnp.float32)
    delta = bsign(beta1 * m + (1.0 - beta1) * g)
    m_new = beta2 * m + (1.0 - beta2) * g
    return delta, m_new


def majority_vote_ref(deltas):
    """Reference server aggregation (paper eq. 5, Majority Vote).

    deltas: int8[N, d] of worker sign updates in {-1, +1}.
    Returns int8[d] = sign(sum_i deltas[i]) in {-1, 0, +1}
    (0 only possible for even-N ties).
    """
    s = jnp.sum(deltas.astype(jnp.int32), axis=0)
    return jnp.sign(s).astype(jnp.int8)


def avg_vote_ref(deltas):
    """Reference Averaging aggregation: (1/N) * sum_i deltas[i], f32[d]."""
    n = deltas.shape[0]
    return jnp.sum(deltas.astype(jnp.float32), axis=0) / n


def apply_update_ref(x, delta, lr, wd):
    """Worker-side apply (paper eq. 6): x - lr * (delta + wd * x)."""
    return x - lr * (delta.astype(jnp.float32) + wd * x)
