"""L2: GPT2++-style byte-level transformer LM (fwd/bwd), build-time only.

"GPT2++" per the paper's Section 5.2: the GPT-2 block with modern
LLaMA-style training techniques — RMSNorm instead of LayerNorm and a
gated (SwiGLU) MLP. Causal self-attention, learned positional
embeddings, byte vocab (256).

Parameters are an *ordered list* of (name, array); the order defines the
flat-buffer layout shared with the rust coordinator (manifest.json).
`train_step` returns (loss, *grads) in the same order — one fused
forward+backward executable.

The L1 Pallas kernel (`kernels.lion_step`) is exported alongside from
aot.py; at train time the rust coordinator owns the optimizer loop, so
the kernel is a separate artifact rather than being fused into
train_step (the paper's workers also separate grad computation from the
Lion update).
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = 256
    dim: int = 64
    layers: int = 2
    heads: int = 2
    seq_len: int = 64
    batch: int = 4
    # SwiGLU hidden multiple (LLaMA uses ~8/3 * dim rounded)
    mlp_mult: float = 8 / 3

    @property
    def head_dim(self):
        assert self.dim % self.heads == 0
        return self.dim // self.heads

    @property
    def mlp_hidden(self):
        h = int(self.dim * self.mlp_mult)
        return ((h + 31) // 32) * 32  # round to 32


# Registry of model sizes. `tiny` is the pytest/integration config;
# `lm100m` is the EXPERIMENTS.md end-to-end driver target.
CONFIGS = {
    "tiny": ModelConfig("tiny", dim=64, layers=2, heads=2, seq_len=64, batch=4),
    "small": ModelConfig("small", dim=256, layers=4, heads=4, seq_len=128, batch=8),
    "lm10m": ModelConfig("lm10m", dim=320, layers=8, heads=8, seq_len=256, batch=8),
    "lm25m": ModelConfig("lm25m", dim=512, layers=8, heads=8, seq_len=256, batch=8),
    "lm100m": ModelConfig("lm100m", dim=768, layers=14, heads=12, seq_len=256, batch=8),
}


def param_specs(cfg: ModelConfig):
    """Ordered (name, shape) list — the flat layout contract."""
    specs = [
        ("embed", (cfg.vocab, cfg.dim)),
        ("pos", (cfg.seq_len, cfg.dim)),
    ]
    for i in range(cfg.layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1", (cfg.dim,)),
            (p + "wq", (cfg.dim, cfg.dim)),
            (p + "wk", (cfg.dim, cfg.dim)),
            (p + "wv", (cfg.dim, cfg.dim)),
            (p + "wo", (cfg.dim, cfg.dim)),
            (p + "ln2", (cfg.dim,)),
            (p + "w_gate", (cfg.dim, cfg.mlp_hidden)),
            (p + "w_up", (cfg.dim, cfg.mlp_hidden)),
            (p + "w_down", (cfg.mlp_hidden, cfg.dim)),
        ]
    specs += [
        ("ln_f", (cfg.dim,)),
        ("head", (cfg.dim, cfg.vocab)),
    ]
    return specs


def init_params(cfg: ModelConfig, key):
    """Initialize parameters (GPT-2-style scaled normal; norms at 1)."""
    params = []
    specs = param_specs(cfg)
    keys = jax.random.split(key, len(specs))
    for (name, shape), k in zip(specs, keys):
        if name.endswith(("ln1", "ln2", "ln_f")):
            arr = jnp.ones(shape, jnp.float32)
        elif name == "pos":
            arr = 0.01 * jax.random.normal(k, shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            scale = 0.02 if name in ("embed",) else 1.0 / jnp.sqrt(fan_in)
            # residual-branch down-scaling (GPT-2 trick)
            if name.endswith(("wo", "w_down")):
                scale = scale / jnp.sqrt(2.0 * cfg.layers)
            arr = scale * jax.random.normal(k, shape, jnp.float32)
        params.append(arr.astype(jnp.float32))
    return params


def rms_norm(x, scale, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def attention(x, wq, wk, wv, wo, cfg: ModelConfig):
    b, t, d = x.shape
    h, hd = cfg.heads, cfg.head_dim
    q = (x @ wq).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    scores = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(causal[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = (probs @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ wo


def swiglu(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def forward(params, tokens_in, cfg: ModelConfig):
    """tokens_in: i32[b, t] -> logits f32[b, t, vocab]."""
    it = iter(params)

    def take():
        return next(it)

    embed, pos = take(), take()
    x = embed[tokens_in] + pos[None, : tokens_in.shape[1]]
    for _ in range(cfg.layers):
        ln1, wq, wk, wv, wo = take(), take(), take(), take(), take()
        ln2, w_gate, w_up, w_down = take(), take(), take(), take()
        x = x + attention(rms_norm(x, ln1), wq, wk, wv, wo, cfg)
        x = x + swiglu(rms_norm(x, ln2), w_gate, w_up, w_down)
    ln_f, head = take(), take()
    return rms_norm(x, ln_f) @ head


def loss_fn(params, tokens, cfg: ModelConfig):
    """Next-byte cross entropy. tokens: i32[b, t+1]."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = forward(params, inputs, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def make_train_step(cfg: ModelConfig):
    """(tokens, *params) -> (loss, *grads): the fused fwd+bwd artifact."""

    @functools.partial(jax.jit, static_argnums=())
    def train_step(tokens, *params):
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(ps, tokens, cfg)
        )(list(params))
        return (loss, *grads)

    return train_step


def make_eval_step(cfg: ModelConfig):
    """(tokens, *params) -> (loss,): loss-only artifact."""

    @functools.partial(jax.jit, static_argnums=())
    def eval_step(tokens, *params):
        return (loss_fn(list(params), tokens, cfg),)

    return eval_step


def num_params(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_specs(cfg))
