"""AOT pipeline: manifest consistency, HLO text validity, no-op rebuilds."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build("tiny", out, seed=0, vote_workers=4)
    return out, manifest


def test_manifest_layout_is_contiguous(built):
    _, m = built
    offset = 0
    for p in m["params"]:
        assert p["offset"] == offset
        offset += int(np.prod(p["shape"]))
    assert m["flat_dim"] == offset


def test_manifest_matches_model_specs(built):
    _, m = built
    specs = M.param_specs(M.CONFIGS["tiny"])
    assert len(m["params"]) == len(specs)
    for p, (name, shape) in zip(m["params"], specs):
        assert p["name"] == name
        assert tuple(p["shape"]) == tuple(shape)


def test_all_artifacts_exist_and_are_hlo_text(built):
    out, m = built
    assert set(m["artifacts"]) == {
        "train_step",
        "eval_step",
        "lion_update",
        "majority_vote",
        "apply_update",
    }
    for name, a in m["artifacts"].items():
        path = os.path.join(out, a["file"])
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"{name} is not HLO text"


def test_params_init_matches_flat_dim(built):
    out, m = built
    data = np.fromfile(os.path.join(out, "params_init.bin"), dtype="<f4")
    assert data.size == m["flat_dim"]
    assert np.isfinite(data).all()
    # norm layers initialized to exactly 1.0 somewhere in the buffer
    assert (data == 1.0).sum() >= M.CONFIGS["tiny"].dim


def test_train_step_io_shapes(built):
    _, m = built
    ts = m["artifacts"]["train_step"]
    cfg = M.CONFIGS["tiny"]
    assert ts["inputs"][0]["shape"] == [cfg.batch, cfg.seq_len + 1]
    assert ts["inputs"][0]["dtype"] == "i32"
    assert len(ts["inputs"]) == 1 + len(m["params"])
    assert len(ts["outputs"]) == 1 + len(m["params"])
    assert ts["outputs"][0]["shape"] == []


def test_lion_update_io(built):
    _, m = built
    lu = m["artifacts"]["lion_update"]
    d = m["flat_dim"]
    assert lu["inputs"][0]["shape"] == [d]
    assert lu["outputs"][0]["dtype"] == "i8"
    assert lu["outputs"][1]["shape"] == [d]


def test_noop_rebuild_is_skipped(built, capsys):
    out, m = built
    m2 = aot.build("tiny", out, seed=0, vote_workers=4)
    assert "up to date" in capsys.readouterr().out
    assert m2["input_hash"] == m["input_hash"]


def test_force_rebuild(built):
    out, m = built
    m2 = aot.build("tiny", out, seed=0, vote_workers=4, force=True)
    assert m2["flat_dim"] == m["flat_dim"]


def test_manifest_json_parses(built):
    out, _ = built
    with open(os.path.join(out, "manifest.json")) as f:
        j = json.load(f)
    assert j["version"] == aot.MANIFEST_VERSION
    assert j["model"] == "tiny"
