"""Python-side simulation of Algorithm 1 built ONLY from the L1 kernels +
refs — cross-checks the paper's semantics independently of the rust
implementation (which tests the same invariants in rust/src/optim/dist).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import lion_step, majority_vote, ref

settings.register_profile("repo2", max_examples=25, deadline=None)
settings.load_profile("repo2")


def lion_sequential(x0, grads_per_step, lr, wd, beta1=0.9, beta2=0.99):
    """Single-node Lion (paper eq. 1), binarized sign."""
    x, m = x0.copy(), np.zeros_like(x0)
    for g in grads_per_step:
        blend = beta1 * m + (1 - beta1) * g
        delta = np.where(blend >= 0, 1.0, -1.0)
        x = x - lr * (delta + wd * x)
        m = beta2 * m + (1 - beta2) * g
    return x


def dlion_mavo(x0, grads_per_step_per_worker, lr, wd):
    """Distributed Lion MaVo via the Pallas kernels (paper Algorithm 1)."""
    nworkers = len(grads_per_step_per_worker[0])
    d = x0.size
    x = jnp.asarray(x0)
    ms = [jnp.zeros(d, jnp.float32) for _ in range(nworkers)]
    for grads in grads_per_step_per_worker:
        deltas, new_ms = [], []
        for m, g in zip(ms, grads):
            delta, m_new = lion_step.lion_update(m, jnp.asarray(g), block=256)
            deltas.append(delta)
            new_ms.append(m_new)
        ms = new_ms
        agg = majority_vote.majority_vote(jnp.stack(deltas), block=256)
        x = ref.apply_update_ref(x, agg, lr, wd)
    return np.asarray(x)


@given(
    d=st.integers(min_value=4, max_value=200),
    steps=st.integers(min_value=1, max_value=20),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_n1_mavo_equals_sequential_lion(d, steps, seed):
    # Invariant 3 (DESIGN.md), python side: one worker == plain Lion.
    rng = np.random.default_rng(seed)
    x0 = rng.standard_normal(d).astype(np.float32)
    grads = [rng.standard_normal(d).astype(np.float32) for _ in range(steps)]
    lr, wd = 0.01, 0.1
    seq = lion_sequential(x0, grads, lr, wd)
    dist = dlion_mavo(x0, [[g] for g in grads], lr, wd)
    np.testing.assert_allclose(dist, seq, rtol=1e-5, atol=1e-6)


@given(
    n=st.sampled_from([3, 5, 9]),
    d=st.integers(min_value=4, max_value=100),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_mavo_follows_majority_gradient_sign(n, d, seed):
    # At step 0 (zero momentum) the aggregated update must be the majority
    # of the workers' gradient signs.
    rng = np.random.default_rng(seed)
    grads = [rng.standard_normal(d).astype(np.float32) for _ in range(n)]
    deltas = []
    for g in grads:
        delta, _ = lion_step.lion_update(jnp.zeros(d, jnp.float32), jnp.asarray(g), block=64)
        deltas.append(delta)
    agg = np.asarray(majority_vote.majority_vote(jnp.stack(deltas), block=64))
    votes = sum(np.where(g >= 0, 1, -1) for g in grads)
    np.testing.assert_array_equal(agg, np.sign(votes).astype(np.int8))


def test_mavo_noise_suppression_improves_with_workers():
    # The √N story behind Theorem 4.6: with a fixed true gradient plus
    # worker noise, more workers make the majority vote agree more often
    # with the true gradient's sign.
    rng = np.random.default_rng(0)
    d = 2000
    true_g = rng.standard_normal(d).astype(np.float32)

    def agreement(n):
        grads = [true_g + 2.0 * rng.standard_normal(d).astype(np.float32) for _ in range(n)]
        deltas = [
            lion_step.lion_update(jnp.zeros(d, jnp.float32), jnp.asarray(g))[0]
            for g in grads
        ]
        agg = np.asarray(majority_vote.majority_vote(jnp.stack(deltas)))
        return float((agg == np.where(true_g >= 0, 1, -1)).mean())

    a1, a9, a33 = agreement(1), agreement(9), agreement(33)
    assert a9 > a1 + 0.05, (a1, a9)
    assert a33 > a9, (a9, a33)


def test_avg_downlink_values_are_low_precision():
    # Averaging sends S/N where S is an integer in {-N..N}: exactly the
    # log(N)-bit alphabet of Table 1.
    rng = np.random.default_rng(1)
    n, d = 8, 500
    deltas = jnp.asarray(rng.choice([-1, 1], size=(n, d)).astype(np.int8))
    avg = np.asarray(ref.avg_vote_ref(deltas))
    alphabet = {(2 * k - n) / n for k in range(n + 1)}
    assert set(np.unique(avg).tolist()) <= alphabet
