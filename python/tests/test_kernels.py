"""L1 Pallas kernels vs pure-jnp oracles (the core correctness signal).

hypothesis sweeps shapes, dtypes, block sizes, and value regimes;
integer outputs must match bit-for-bit, momentum to float tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lion_step, majority_vote, ref

settings.register_profile("repo", max_examples=40, deadline=None)
settings.load_profile("repo")


def rand_f32(rng, n, scale=1.0, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(n).astype(dtype) * scale)


@given(
    d=st.integers(min_value=1, max_value=5000),
    block=st.sampled_from([64, 256, 1024, 65536]),
    scale=st.sampled_from([1e-6, 1.0, 1e6]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_lion_update_matches_ref(d, block, scale, seed):
    rng = np.random.default_rng(seed)
    m = rand_f32(rng, d, scale)
    g = rand_f32(rng, d, scale)
    delta, m_new = lion_step.lion_update(m, g, block=block)
    delta_ref, m_new_ref = ref.lion_update_ref(m, g)
    assert delta.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(delta), np.asarray(delta_ref))
    # Kernel and ref may fuse multiply-adds in different order; when the
    # blend cancels (|m_new| << |inputs|) the error is relative to the
    # INPUT magnitude, so scale atol by the value scale.
    np.testing.assert_allclose(
        np.asarray(m_new), np.asarray(m_new_ref), rtol=1e-5, atol=1e-5 * scale
    )


@given(
    d=st.integers(min_value=1, max_value=2000),
    beta1=st.floats(min_value=0.0, max_value=1.0),
    beta2=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_lion_update_beta_sweep(d, beta1, beta2, seed):
    rng = np.random.default_rng(seed)
    m, g = rand_f32(rng, d), rand_f32(rng, d)
    delta, m_new = lion_step.lion_update(m, g, beta1=beta1, beta2=beta2, block=256)
    delta_ref, m_new_ref = ref.lion_update_ref(m, g, beta1=beta1, beta2=beta2)
    np.testing.assert_array_equal(np.asarray(delta), np.asarray(delta_ref))
    np.testing.assert_allclose(np.asarray(m_new), np.asarray(m_new_ref), rtol=1e-5, atol=1e-7)


def test_lion_update_binarized_zero_convention():
    # blend == 0 must produce +1 (the 1-bit codec has no zero symbol).
    m = jnp.zeros(8, jnp.float32)
    g = jnp.zeros(8, jnp.float32)
    delta, _ = lion_step.lion_update(m, g, block=8)
    assert (np.asarray(delta) == 1).all()


def test_lion_update_is_strictly_binary():
    rng = np.random.default_rng(7)
    m, g = rand_f32(rng, 4096), rand_f32(rng, 4096)
    delta, _ = lion_step.lion_update(m, g)
    vals = set(np.unique(np.asarray(delta)).tolist())
    assert vals <= {-1, 1}


@given(
    n=st.integers(min_value=1, max_value=33),
    d=st.integers(min_value=1, max_value=3000),
    block=st.sampled_from([32, 128, 32768]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_majority_vote_matches_ref(n, d, block, seed):
    rng = np.random.default_rng(seed)
    deltas = jnp.asarray(rng.choice([-1, 1], size=(n, d)).astype(np.int8))
    out = majority_vote.majority_vote(deltas, block=block)
    out_ref = ref.majority_vote_ref(deltas)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_ref))


def test_majority_vote_odd_n_never_ties():
    rng = np.random.default_rng(3)
    deltas = jnp.asarray(rng.choice([-1, 1], size=(5, 1000)).astype(np.int8))
    out = np.asarray(majority_vote.majority_vote(deltas))
    assert (out != 0).all()


def test_majority_vote_is_odd_function():
    rng = np.random.default_rng(4)
    deltas = jnp.asarray(rng.choice([-1, 1], size=(4, 500)).astype(np.int8))
    a = np.asarray(majority_vote.majority_vote(deltas))
    b = np.asarray(majority_vote.majority_vote(-deltas))
    np.testing.assert_array_equal(a, -b)


def test_majority_vote_unanimous():
    ones = jnp.ones((7, 64), jnp.int8)
    np.testing.assert_array_equal(np.asarray(majority_vote.majority_vote(ones)), 1)
    np.testing.assert_array_equal(np.asarray(majority_vote.majority_vote(-ones)), -1)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_apply_update_ref_contract(seed):
    # mirror of the rust-side apply: x - lr*(delta + wd*x)
    rng = np.random.default_rng(seed)
    x = rand_f32(rng, 100)
    delta = jnp.asarray(rng.choice([-1, 1], size=100).astype(np.int8))
    out = ref.apply_update_ref(x, delta, 0.1, 0.01)
    expect = np.asarray(x) - 0.1 * (np.asarray(delta, np.float32) + 0.01 * np.asarray(x))
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-6)


@pytest.mark.parametrize("d", [1, 5, 63, 64, 65, 100_000])
def test_lion_update_edge_sizes(d):
    rng = np.random.default_rng(d)
    m, g = rand_f32(rng, d), rand_f32(rng, d)
    delta, m_new = lion_step.lion_update(m, g)
    delta_ref, m_new_ref = ref.lion_update_ref(m, g)
    np.testing.assert_array_equal(np.asarray(delta), np.asarray(delta_ref))
    # FMA ordering differs between the tiled kernel and the fused ref
    np.testing.assert_allclose(np.asarray(m_new), np.asarray(m_new_ref), rtol=1e-5, atol=1e-6)
