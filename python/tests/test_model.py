"""L2 model correctness: shapes, loss sanity, gradient check, causality."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.CONFIGS["tiny"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return jnp.asarray(
        rng.integers(0, CFG.vocab, size=(CFG.batch, CFG.seq_len + 1)), jnp.int32
    )


def test_param_specs_cover_all_layers():
    specs = M.param_specs(CFG)
    names = [n for n, _ in specs]
    assert names[0] == "embed" and names[-1] == "head"
    assert sum(1 for n in names if n.startswith("layer1.")) == 9
    assert len(names) == 4 + 9 * CFG.layers


def test_init_matches_specs(params):
    for (name, shape), p in zip(M.param_specs(CFG), params):
        assert p.shape == shape, name
        assert p.dtype == jnp.float32


def test_forward_shapes(params, tokens):
    logits = M.forward(params, tokens[:, :-1], CFG)
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_initial_loss_near_uniform(params, tokens):
    loss = M.loss_fn(params, tokens, CFG)
    uniform = np.log(CFG.vocab)
    assert abs(float(loss) - uniform) < 1.0, (float(loss), uniform)


def test_causality(params, tokens):
    # Changing a future token must not affect earlier logits.
    inp = tokens[:, :-1]
    logits_a = M.forward(params, inp, CFG)
    perturbed = inp.at[:, -1].set((inp[:, -1] + 1) % CFG.vocab)
    logits_b = M.forward(params, perturbed, CFG)
    np.testing.assert_allclose(
        np.asarray(logits_a[:, :-1]), np.asarray(logits_b[:, :-1]), rtol=1e-5, atol=1e-6
    )
    assert not np.allclose(np.asarray(logits_a[:, -1]), np.asarray(logits_b[:, -1]))


def test_train_step_outputs(params, tokens):
    step = M.make_train_step(CFG)
    out = step(tokens, *params)
    assert len(out) == 1 + len(params)
    loss, grads = out[0], out[1:]
    assert loss.shape == ()
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert bool(jnp.isfinite(g).all())


def test_gradients_match_finite_difference(params, tokens):
    step = M.make_train_step(CFG)
    out = step(tokens, *params)
    grads = out[1:]
    # probe a few coordinates of the head matrix (last param)
    idx = len(params) - 1
    eps = 1e-3
    rng = np.random.default_rng(1)
    for _ in range(3):
        i = rng.integers(0, params[idx].shape[0])
        j = rng.integers(0, params[idx].shape[1])
        pp = [p.copy() for p in params]
        pp[idx] = pp[idx].at[i, j].add(eps)
        lp = float(M.loss_fn(pp, tokens, CFG))
        pp[idx] = pp[idx].at[i, j].add(-2 * eps)
        lm = float(M.loss_fn(pp, tokens, CFG))
        fd = (lp - lm) / (2 * eps)
        an = float(grads[idx][i, j])
        assert abs(fd - an) < 5e-2 * (1 + abs(fd)), (fd, an)


def test_eval_step_matches_loss(params, tokens):
    ev = M.make_eval_step(CFG)
    (loss_e,) = ev(tokens, *params)
    loss_d = M.loss_fn(params, tokens, CFG)
    np.testing.assert_allclose(float(loss_e), float(loss_d), rtol=1e-6)


def test_one_sgd_step_reduces_loss(params, tokens):
    step = M.make_train_step(CFG)
    out = step(tokens, *params)
    loss0, grads = out[0], out[1:]
    lr = 0.1
    new_params = [p - lr * g for p, g in zip(params, grads)]
    loss1 = M.loss_fn(new_params, tokens, CFG)
    assert float(loss1) < float(loss0)


def test_num_params_counts():
    n = M.num_params(CFG)
    assert n == 143_680  # pinned: the tiny config's manifest flat_dim


@pytest.mark.parametrize("name", sorted(M.CONFIGS))
def test_all_configs_are_consistent(name):
    cfg = M.CONFIGS[name]
    assert cfg.dim % cfg.heads == 0
    assert M.num_params(cfg) > 0


def test_config_scale_ladder():
    # lm100m must actually be ~100M params (the EXPERIMENTS.md target).
    n100 = M.num_params(M.CONFIGS["lm100m"])
    assert 80e6 < n100 < 130e6, n100
    n25 = M.num_params(M.CONFIGS["lm25m"])
    assert 18e6 < n25 < 35e6, n25
