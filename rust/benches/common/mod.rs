//! Shared setup for the paper-table benches.
#![allow(dead_code)] // each bench binary uses a different subset

use dlion::cluster::TrainConfig;
use dlion::optim::dist::StrategyHyper;
use dlion::tasks::data::VisionData;
use dlion::tasks::mlp::MlpVision;
use std::sync::Arc;

/// The Figure 2–4 substrate: synthetic-vision MLP (CIFAR-10 stand-in).
pub fn vision_task(seed: u64) -> MlpVision {
    let data = Arc::new(VisionData::generate(4096, 1024, 1.6, seed));
    MlpVision::new(data, 64)
}

/// Per-method (lr, wd) from Table 2, scaled to this substrate (the
/// paper's raw lr values are ViT-specific; ratios preserved).
pub fn table2_hparams(method: &str) -> (f64, StrategyHyper) {
    let mut hp = StrategyHyper::default();
    let lr = match method {
        "g-adamw" => {
            hp.weight_decay = 0.0005;
            1e-3
        }
        "g-lion" | "d-lion-avg" | "d-lion-mavo" | "d-lion-ef" | "d-lion-msync" => {
            hp.weight_decay = 0.005;
            5e-4
        }
        name if name.starts_with("bandwidth-aware")
            || name.starts_with("d-lion-local")
            || name.starts_with("mixed") =>
        {
            hp.weight_decay = 0.005;
            5e-4
        }
        "d-signum-avg" | "d-signum-mavo" => {
            hp.weight_decay = 0.005;
            hp.signum_beta = 0.99;
            5e-4
        }
        "dgc" | "graddrop" | "terngrad" => {
            hp.weight_decay = 0.0005;
            hp.keep_frac = 0.04;
            5e-3
        }
        _ => 1e-3,
    };
    (lr, hp)
}

/// Bench-wide train config; `quick` (via `cargo bench -- --quick` or
/// DLION_BENCH_QUICK=1) shrinks everything for CI.
pub fn train_cfg(steps: usize, seed: u64) -> TrainConfig {
    let quick = dlion::bench_utils::quick_mode();
    TrainConfig {
        steps: if quick { steps / 8 } else { steps },
        batch_per_worker: 32,
        base_lr: 0.0, // set per method
        eval_every: 0,
        seed,
        ..Default::default()
    }
}

pub fn seeds() -> Vec<u64> {
    if dlion::bench_utils::quick_mode() {
        vec![42]
    } else {
        vec![42, 52, 62] // the paper's seeds
    }
}

pub fn out_dir() -> std::path::PathBuf {
    let p = std::path::PathBuf::from("results");
    std::fs::create_dir_all(&p).ok();
    p
}
