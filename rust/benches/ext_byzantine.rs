//! Extension experiment: Byzantine-worker robustness.
//!
//! The paper inherits SignSGD-with-majority-vote's fault-tolerance story
//! (Bernstein et al. 2018c, cited in footnote 4): a 1-bit vote bounds a
//! corrupt worker's per-coordinate influence to one vote, while f32
//! gradient averaging is unbounded. This bench trains the vision task
//! with b ∈ {0, 1, 3} workers replaced by random-byte adversaries
//! (k = 8 total) and reports final accuracy per strategy.
//!
//! Run: `cargo bench --bench ext_byzantine [-- --quick]`

mod common;

use dlion::bench_utils::Table;
use dlion::optim::dist::faulty::{Fault, FaultyWorker};
use dlion::optim::dist::{by_name, run_round, WorkerLogic};
use dlion::tasks::GradTask;
use dlion::util::math::cosine_lr;
use dlion::util::Rng;

const METHODS: &[&str] = &["g-lion", "d-lion-avg", "d-lion-mavo"];
const K: usize = 8;

fn main() {
    let quick = dlion::bench_utils::quick_mode();
    let steps = if quick { 120 } else { 800 };
    let byz_counts = [0usize, 1, 3];
    let mut header: Vec<String> = vec!["method".into()];
    header.extend(byz_counts.iter().map(|b| format!("acc @ {b} byz")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!("Extension — Byzantine robustness (k={K}, random-byte adversaries)"),
        &header_refs,
    );
    for &method in METHODS {
        let (lr, hp) = common::table2_hparams(method);
        let strategy = by_name(method, &hp).unwrap();
        let mut row = vec![method.to_string()];
        for &nbyz in &byz_counts {
            let task = common::vision_task(42);
            let d = task.dim();
            let mut root = Rng::new(42);
            let params0 = task.init_params(&mut root);
            let mut params = vec![params0; K];
            let mut rngs: Vec<Rng> = (0..K).map(|i| root.fork(i as u64)).collect();
            let mut workers: Vec<Box<dyn WorkerLogic>> =
                (0..K).map(|i| strategy.make_worker(i, K, d)).collect();
            for b in 0..nbyz {
                let honest = std::mem::replace(&mut workers[b], strategy.make_worker(b, K, d));
                workers[b] =
                    Box::new(FaultyWorker::new(honest, Fault::RandomBytes, 100 + b as u64));
            }
            let mut server = strategy.make_server(K, d);
            let mut grads = vec![vec![0.0f32; d]; K];
            for step in 0..steps {
                let lr_t = cosine_lr(step, steps, 0, lr, 0.0) as f32;
                for ((g, p), r) in grads.iter_mut().zip(&params).zip(rngs.iter_mut()) {
                    task.minibatch_grad(p, r, 32, g);
                }
                run_round(&mut workers, server.as_mut(), &mut params, &grads, lr_t, step);
            }
            // evaluate an honest replica (index nbyz is always honest)
            let acc = task.evaluate(&params[nbyz.min(K - 1)]).accuracy.unwrap();
            row.push(format!("{acc:.3}"));
            eprintln!("byzantine: {method} b={nbyz} -> {acc:.3}");
        }
        t.row(row);
    }
    t.print();
    t.write_csv(common::out_dir().join("ext_byzantine.csv")).unwrap();
    println!("Expected shape (Bernstein 2018c, inherited by D-Lion): the vote");
    println!("degrades gracefully with minority corruption; averaging-based");
    println!("downlinks admit more damage per corrupt worker.");
}
