//! Extension — mixed wires: per-chunk arm-assignment ratios × chunk
//! sizes × topologies, next to the per-link budget-driven selector.
//!
//! The paper's trade-off, made per parameter range: each chunk of the
//! tag-15 envelope rides its own arm's native frames, so a
//! `mixed(d-lion-mavo*r,g-lion)` round ships r/(r+1) of the model as
//! 1-bit majority votes and the rest dense — on every hop (the
//! agg→root link carries intavg vote partials next to tag-14 dense
//! sums in the same round). The `@cheap/@rich` row lets the per-hop
//! token bucket spend `hyper.link_budget` instead of a fixed ratio.
//!
//! Worker-edge columns are bits/param/step per worker (Table-1
//! normalization); `agg up` is per group on the root link; `model` is
//! the strategy's own weighted analytic rate (up + down), which the
//! measured columns must track within frame-header slack whenever the
//! cycle divides the chunk count; `pipe ms` projects one round of a
//! 100M-param model over a 10 Gbit/s link with chunk-level up/down
//! pipelining ([`dlion::comm::simnet::estimate_pipelined_costs`]).
//!
//! Run: `cargo bench --bench ext_mixed [-- --quick]`

mod common;

use dlion::bench_utils::Table;
use dlion::cluster::run_sequential;
use dlion::cluster::topology::Topology;
use dlion::comm::simnet::{estimate_pipelined_costs, Link};
use dlion::optim::dist::{by_name, MixedStrategy, StrategyHyper};
use dlion::tasks::GradTask;

fn mixed_ratio(r: usize) -> String {
    if r == 1 {
        "mixed(d-lion-mavo,g-lion)".to_string()
    } else {
        format!("mixed(d-lion-mavo*{r},g-lion)")
    }
}

/// Pipelined one-round projection for a static ratio at scale: 100M
/// params, 10 Gbit/s server NIC, chunked to the bench's chunk count.
fn pipelined_ms(hp: &StrategyHyper, ratio: usize, nchunks: usize, n: usize) -> f64 {
    let d = 100_000_000usize;
    let arms = vec![
        by_name("d-lion-mavo", hp).unwrap(),
        by_name("g-lion", hp).unwrap(),
    ];
    let mixed = MixedStrategy::per_chunk(arms, vec![ratio, 1]).unwrap();
    let costs = mixed.chunk_costs(d, d / nchunks, n);
    estimate_pipelined_costs(&costs, n, Link::gbit(10.0)) * 1e3
}

fn main() {
    let quick = dlion::bench_utils::quick_mode();
    let k = 8; // workers
    let steps = if quick { 120 } else { 800 };
    // (strategy, chunk_size, topology): assignment ratios × chunk sizes
    // × topologies, plus the plain arms as anchors and one per-link row
    let mut cases: Vec<(String, usize, Topology)> = vec![
        ("d-lion-mavo".into(), 200, Topology::Star),
        ("g-lion".into(), 200, Topology::Star),
    ];
    let ratios: &[usize] = if quick { &[1, 7] } else { &[1, 3, 7] };
    let chunk_sizes: &[usize] = if quick { &[200] } else { &[40, 200] };
    for &r in ratios {
        for &cs in chunk_sizes {
            for topo in [Topology::Star, Topology::Hierarchical { group_size: 4 }] {
                cases.push((mixed_ratio(r), cs, topo));
            }
        }
    }
    cases.push((
        "mixed(d-lion-mavo@cheap,g-lion@rich)".into(),
        200,
        Topology::Hierarchical { group_size: 4 },
    ));
    let mut t = Table::new(
        &format!("Extension — mixed wires (k={k} workers, {steps} steps)"),
        &[
            "method",
            "chunk",
            "topology",
            "final acc",
            "up b/p/step",
            "down b/p/step",
            "agg up b/p/step",
            "model up+down",
            "pipe ms@100M",
        ],
    );
    for (method, chunk_size, topo) in &cases {
        let (lr, mut hp) = common::table2_hparams(method);
        hp.link_budget = 8.0; // the @cheap/@rich row's per-hop budget
        let strategy = by_name(method, &hp).unwrap();
        let task = common::vision_task(42);
        let mut cfg = common::train_cfg(steps, 42);
        cfg.base_lr = lr;
        cfg.topology = *topo;
        cfg.chunk_size = *chunk_size;
        let d = task.dim();
        let res = run_sequential(&task, strategy.as_ref(), k, &cfg);
        let ngroups = match topo {
            Topology::Star => 1,
            Topology::Hierarchical { group_size } => k.div_ceil(*group_size),
        };
        let denom_worker = (d * k * res.history.len()) as f64;
        let denom_group = (d * ngroups * res.history.len()) as f64;
        let acc = res.final_eval.as_ref().unwrap().accuracy.unwrap_or(0.0);
        let model =
            strategy.uplink_bits_per_param(k) + strategy.downlink_bits_per_param(k);
        // static ratio rows get a 64-chunk pipelined projection at
        // 100M params; the anchors and the per-link row print '-'
        let pipe = if *method == mixed_ratio(1) {
            Some(pipelined_ms(&hp, 1, 64, k))
        } else if let Some(rest) = method.strip_prefix("mixed(d-lion-mavo*") {
            rest.split(',')
                .next()
                .and_then(|s| s.parse::<usize>().ok())
                .map(|r| pipelined_ms(&hp, r, 64, k))
        } else {
            None
        };
        t.row(vec![
            method.clone(),
            chunk_size.to_string(),
            topo.to_string(),
            format!("{acc:.3}"),
            format!("{:.3}", res.total_uplink() as f64 * 8.0 / denom_worker),
            format!("{:.3}", res.total_downlink() as f64 * 8.0 / denom_worker),
            format!("{:.3}", res.total_agg_uplink() as f64 * 8.0 / denom_group),
            format!("{model:.3}"),
            pipe.map_or("-".into(), |p| format!("{p:.2}")),
        ]);
        eprintln!("mixed: {method} cs={chunk_size} @ {topo} -> acc {acc:.3}");
    }
    t.print();
    t.write_csv(common::out_dir().join("ext_mixed.csv")).unwrap();
    println!("Checks: measured up/down track the weighted model (heads aside) when");
    println!("the cycle divides the chunk count; hier rows pay vote partials + dense");
    println!("sums on the agg link; the @cheap/@rich row's spend stays under");
    println!("hyper.link_budget on both hops (pinned in tests/property_invariants.rs)");
}
