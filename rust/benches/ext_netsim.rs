//! Extension experiment: projected per-step wall-clock on real links.
//!
//! Combines the analytic Table-1 byte counts with a parameter-server
//! network model (comm::simnet) and the measured PJRT compute time to
//! project where each method's step time lands for 1 Gbit and 10 Gbit
//! server NICs at LLM scale — quantifying the paper's "particularly
//! advantageous for training large models" claim.
//!
//! Run: `cargo bench --bench ext_netsim`

mod common;

use dlion::bench_utils::Table;
use dlion::comm::simnet::{estimate, estimate_pipelined, Link};
use dlion::optim::dist::{by_name, StrategyHyper};

const METHODS: &[&str] = &[
    "g-adamw",
    "g-lion",
    "d-lion-avg",
    "d-lion-mavo",
    "d-lion-ef",
    "d-lion-msync",
    "bandwidth-aware(d-lion-mavo,g-lion)",
    "terngrad",
    "dgc",
    "qsgd",
    "ef-signsgd",
];

fn main() {
    let hp = StrategyHyper::default();
    for (d_label, d) in [("350M (GPT2++ medium)", 350_000_000usize), ("7B (LLaMA)", 7_000_000_000)]
    {
        for n in [4usize, 32] {
            let mut t = Table::new(
                &format!("Projected comm time/step — {d_label}, n={n} workers"),
                &["method", "1 Gbit/s", "10 Gbit/s", "vs g-adamw @10G"],
            );
            let base =
                estimate(by_name("g-adamw", &hp).unwrap().as_ref(), d, n, Link::gbit(10.0))
                    .total();
            for &m in METHODS {
                let s = by_name(m, &hp).unwrap();
                let t1 = estimate(s.as_ref(), d, n, Link::gbit(1.0)).total();
                let t10 = estimate(s.as_ref(), d, n, Link::gbit(10.0)).total();
                t.row(vec![
                    m.to_string(),
                    format!("{:.2}s", t1),
                    format!("{:.3}s", t10),
                    format!("{:.1}x faster", base / t10),
                ]);
            }
            t.print();
            t.write_csv(common::out_dir().join(format!("ext_netsim_{d}_{n}.csv"))).unwrap();
        }
    }
    chunk_pipelining();
    println!("Shape check: D-Lion MaVo ≈ 32x faster on the wire than G-AdamW;");
    println!("Avg pays only the log(N)-bit downlink premium.");
}

/// Chunk-pipelining projection at 1B-param scale: splitting the round
/// into chunk messages lets the downlink of chunk i overlap the uplink
/// of chunk i+1 (and, with compute overlap, hides comm under the step's
/// compute). Columns are chunk_size ∈ {d, d/8, d/64} — the latency-
/// hiding win the chunked wire format unlocks.
fn chunk_pipelining() {
    let hp = StrategyHyper::default();
    let d = 1_000_000_000usize;
    let n = 32usize;
    let compute_s = 0.25; // nominal fwd+bwd time per step at this scale
    for link_g in [1.0f64, 10.0] {
        let link = Link::gbit(link_g);
        let mut t = Table::new(
            &format!(
                "Chunk-pipelined comm/step — 1B params, n={n}, {link_g} Gbit/s, \
                 compute {compute_s}s (overlap)"
            ),
            &["method", "chunk=d (serial)", "chunk=d/8", "chunk=d/64", "step time @d/64"],
        );
        for m in ["g-adamw", "d-lion-avg", "d-lion-mavo", "dgc"] {
            let s = by_name(m, &hp).unwrap();
            let t1 = estimate_pipelined(s.as_ref(), d, n, link, 1);
            let t8 = estimate_pipelined(s.as_ref(), d, n, link, 8);
            let t64 = estimate_pipelined(s.as_ref(), d, n, link, 64);
            t.row(vec![
                m.to_string(),
                format!("{t1:.3}s"),
                format!("{t8:.3}s"),
                format!("{t64:.3}s"),
                format!("{:.3}s", compute_s.max(t64)),
            ]);
        }
        t.print();
        t.write_csv(common::out_dir().join(format!("ext_netsim_pipeline_{link_g}g.csv")))
            .unwrap();
    }
}
