//! Extension experiment: projected per-step wall-clock on real links.
//!
//! Combines the analytic Table-1 byte counts with a parameter-server
//! network model (comm::simnet) and the measured PJRT compute time to
//! project where each method's step time lands for 1 Gbit and 10 Gbit
//! server NICs at LLM scale — quantifying the paper's "particularly
//! advantageous for training large models" claim.
//!
//! Run: `cargo bench --bench ext_netsim`

mod common;

use dlion::bench_utils::Table;
use dlion::comm::simnet::{estimate, Link};
use dlion::optim::dist::{by_name, StrategyHyper};

const METHODS: &[&str] = &[
    "g-adamw",
    "g-lion",
    "d-lion-avg",
    "d-lion-mavo",
    "d-lion-ef",
    "d-lion-msync",
    "bandwidth-aware(d-lion-mavo,g-lion)",
    "terngrad",
    "dgc",
    "qsgd",
    "ef-signsgd",
];

fn main() {
    let hp = StrategyHyper::default();
    for (d_label, d) in [("350M (GPT2++ medium)", 350_000_000usize), ("7B (LLaMA)", 7_000_000_000)]
    {
        for n in [4usize, 32] {
            let mut t = Table::new(
                &format!("Projected comm time/step — {d_label}, n={n} workers"),
                &["method", "1 Gbit/s", "10 Gbit/s", "vs g-adamw @10G"],
            );
            let base =
                estimate(by_name("g-adamw", &hp).unwrap().as_ref(), d, n, Link::gbit(10.0))
                    .total();
            for &m in METHODS {
                let s = by_name(m, &hp).unwrap();
                let t1 = estimate(s.as_ref(), d, n, Link::gbit(1.0)).total();
                let t10 = estimate(s.as_ref(), d, n, Link::gbit(10.0)).total();
                t.row(vec![
                    m.to_string(),
                    format!("{:.2}s", t1),
                    format!("{:.3}s", t10),
                    format!("{:.1}x faster", base / t10),
                ]);
            }
            t.print();
            t.write_csv(common::out_dir().join(format!("ext_netsim_{d}_{n}.csv"))).unwrap();
        }
    }
    println!("Shape check: D-Lion MaVo ≈ 32x faster on the wire than G-AdamW;");
    println!("Avg pays only the log(N)-bit downlink premium.");
}
