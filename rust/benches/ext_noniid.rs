//! Extension experiment (paper footnote 3): Distributed Lion under
//! non-i.i.d. data. Each worker's batches are class-skewed with
//! parameter α ∈ {0, 0.5, 0.9}; α=0 is the paper's i.i.d. setting.
//!
//! Two questions:
//! * does the majority vote stay robust when workers' gradient signs
//!   systematically disagree (label skew), compared with gradient
//!   averaging (G-Lion) and update averaging (D-Lion Avg)?
//! * how far do the private Lion momenta drift apart between syncs —
//!   the failure mode `d-lion-msync` periodically repairs and
//!   `d-lion-ef` compensates for — as a function of the skew?
//!
//! The drift column is the run-mean RMS per-parameter deviation of the
//! worker momenta from their across-worker mean,
//! `√(Σ_w ‖m_w − m̄‖² / (n·d))`, probed through
//! `WorkerLogic::momentum()` after every round ("-" for strategies
//! whose workers keep no probe-able momentum; G-Lion's replicated
//! momenta are identical by construction).
//!
//! Run: `cargo bench --bench ext_noniid [-- --quick]`

mod common;

use dlion::bench_utils::Table;
use dlion::cluster::TrainConfig;
use dlion::optim::dist::{by_name, run_round, Strategy};
use dlion::tasks::data::VisionData;
use dlion::tasks::mlp::{MlpVision, Sharding};
use dlion::tasks::GradTask;
use dlion::util::math::cosine_lr;
use dlion::util::Rng;
use std::sync::Arc;

const METHODS: &[&str] = &["g-lion", "d-lion-avg", "d-lion-mavo", "d-lion-ef", "d-lion-msync"];

/// RMS per-parameter deviation of the worker momenta from their mean.
fn momentum_drift(momenta: &[&[f32]]) -> f64 {
    let n = momenta.len();
    let d = momenta[0].len();
    let mut sq = 0.0f64;
    for i in 0..d {
        let mean: f64 = momenta.iter().map(|m| m[i] as f64).sum::<f64>() / n as f64;
        sq += momenta.iter().map(|m| (m[i] as f64 - mean).powi(2)).sum::<f64>();
    }
    (sq / (n * d) as f64).sqrt()
}

/// The sequential training loop, replicated by hand so the worker
/// momenta stay probe-able between rounds. Returns (final accuracy,
/// run-mean momentum drift if the strategy exposes momenta).
fn run_with_drift(
    task: &dyn GradTask,
    strategy: &dyn Strategy,
    nworkers: usize,
    cfg: &TrainConfig,
) -> (f64, Option<f64>) {
    // This loop mirrors run_sequential's flat every-step round only —
    // it exists so the momenta stay probe-able between rounds. Refuse
    // strategies whose cadence the cluster engine would handle
    // differently rather than silently training them at H = 1.
    assert_eq!(
        strategy.local_steps(),
        1,
        "run_with_drift drives flat every-step rounds; {} needs the cluster engine",
        strategy.name()
    );
    let d = task.dim();
    let mut root = Rng::new(cfg.seed);
    let params0 = task.init_params(&mut root);
    let mut params: Vec<Vec<f32>> = vec![params0; nworkers];
    let mut rngs: Vec<Rng> = (0..nworkers).map(|i| root.fork(i as u64)).collect();
    let mut workers: Vec<_> = (0..nworkers).map(|i| strategy.make_worker(i, nworkers, d)).collect();
    let mut server = strategy.make_server(nworkers, d);
    let mut grads: Vec<Vec<f32>> = vec![vec![0.0; d]; nworkers];
    let mut drift_sum = 0.0f64;
    let mut drift_rounds = 0usize;
    for step in 0..cfg.steps {
        let lr = cosine_lr(step, cfg.steps, cfg.warmup_steps, cfg.base_lr, cfg.min_lr_frac) as f32;
        for (w, ((g, p), r)) in grads.iter_mut().zip(&params).zip(rngs.iter_mut()).enumerate() {
            let _ = task.minibatch_grad_worker(p, r, cfg.batch_per_worker, g, w, nworkers);
        }
        run_round(&mut workers, server.as_mut(), &mut params, &grads, lr, step);
        let momenta: Option<Vec<&[f32]>> = workers.iter().map(|w| w.momentum()).collect();
        if let Some(moms) = momenta {
            drift_sum += momentum_drift(&moms);
            drift_rounds += 1;
        }
    }
    let acc = task.evaluate(&params[0]).accuracy.unwrap_or(0.0);
    let drift = (drift_rounds > 0).then(|| drift_sum / drift_rounds as f64);
    (acc, drift)
}

fn main() {
    let quick = dlion::bench_utils::quick_mode();
    let alphas = [0.0f64, 0.5, 0.9];
    let k = 8; // label skew needs several workers to matter
    let mut header: Vec<String> = vec!["method".into()];
    for a in &alphas {
        header.push(format!("acc @ α={a}"));
        header.push(format!("drift @ α={a}"));
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!("Extension — non-i.i.d. class skew (k={k} workers)"),
        &header_refs,
    );
    for &method in METHODS {
        let (lr, mut hp) = common::table2_hparams(method);
        // resync often enough for the drift repair to show inside the
        // bench horizon
        hp.msync_every = 16;
        let strategy = by_name(method, &hp).unwrap();
        let mut row = vec![method.to_string()];
        for &alpha in &alphas {
            let data = Arc::new(VisionData::generate(4096, 1024, 1.6, 42));
            let sharding =
                if alpha == 0.0 { Sharding::Iid } else { Sharding::ByClass { alpha } };
            let task = MlpVision::with_sharding(data, 64, sharding);
            let mut cfg = common::train_cfg(if quick { 120 } else { 800 }, 42);
            cfg.base_lr = lr;
            let (acc, drift) = run_with_drift(&task, strategy.as_ref(), k, &cfg);
            row.push(format!("{acc:.3}"));
            row.push(drift.map_or("-".into(), |x| format!("{x:.5}")));
            eprintln!(
                "noniid: {method} α={alpha} -> acc {acc:.3} drift {}",
                drift.map_or("-".into(), |x| format!("{x:.5}"))
            );
        }
        t.row(row);
    }
    t.print();
    t.write_csv(common::out_dir().join("ext_noniid.csv")).unwrap();
    println!("Footnote-3 check: accuracy should degrade gracefully with α for all");
    println!("methods, with MaVo staying within a few points of G-Lion; momentum");
    println!("drift should grow with α and sit lower for d-lion-msync (periodic");
    println!("bf16 resync) than for plain d-lion-mavo.");
}
