//! Extension experiment (paper footnote 3): Distributed Lion under
//! non-i.i.d. data. Each worker's batches are class-skewed with
//! parameter α ∈ {0, 0.5, 0.9}; α=0 is the paper's i.i.d. setting.
//!
//! Question: does the majority vote stay robust when workers' gradient
//! signs systematically disagree (label skew), compared with gradient
//! averaging (G-Lion) and update averaging (D-Lion Avg)?
//!
//! Run: `cargo bench --bench ext_noniid [-- --quick]`

mod common;

use dlion::bench_utils::Table;
use dlion::cluster::run_sequential;
use dlion::optim::dist::by_name;
use dlion::tasks::data::VisionData;
use dlion::tasks::mlp::{MlpVision, Sharding};
use std::sync::Arc;

const METHODS: &[&str] = &["g-lion", "d-lion-avg", "d-lion-mavo"];

fn main() {
    let quick = dlion::bench_utils::quick_mode();
    let alphas = [0.0f64, 0.5, 0.9];
    let k = 8; // label skew needs several workers to matter
    let mut header: Vec<String> = vec!["method".into()];
    header.extend(alphas.iter().map(|a| format!("acc @ α={a}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!("Extension — non-i.i.d. class skew (k={k} workers)"),
        &header_refs,
    );
    for &method in METHODS {
        let (lr, hp) = common::table2_hparams(method);
        let strategy = by_name(method, &hp).unwrap();
        let mut row = vec![method.to_string()];
        for &alpha in &alphas {
            let data = Arc::new(VisionData::generate(4096, 1024, 1.6, 42));
            let sharding =
                if alpha == 0.0 { Sharding::Iid } else { Sharding::ByClass { alpha } };
            let task = MlpVision::with_sharding(data, 64, sharding);
            let mut cfg = common::train_cfg(if quick { 120 } else { 800 }, 42);
            cfg.base_lr = lr;
            let res = run_sequential(&task, strategy.as_ref(), k, &cfg);
            let acc = res.final_eval.unwrap().accuracy.unwrap();
            row.push(format!("{acc:.3}"));
            eprintln!("noniid: {method} α={alpha} -> {acc:.3}");
        }
        t.row(row);
    }
    t.print();
    t.write_csv(common::out_dir().join("ext_noniid.csv")).unwrap();
    println!("Footnote-3 check: accuracy should degrade gracefully with α for all");
    println!("methods, with MaVo staying within a few points of G-Lion.");
}
