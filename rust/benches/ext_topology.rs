//! Extension — topology-aware rounds: group sizes × local-step windows
//! against flat every-step d-lion-mavo.
//!
//! Two orthogonal levers over the same 1-bit frames:
//! * **Hierarchical majority vote** (`hier:<g>`): workers uplink to a
//!   group aggregator that ships exact `intavg` vote partials to the
//!   root — the trajectory is bit-identical to the flat star, but the
//!   root's inbound link carries ⌈log₂(g+1)⌉ bits/param per *group*
//!   instead of 1 bit/param per *worker* (the `agg up` column).
//! * **Local steps** (`d-lion-local(H)`): one wire round every H
//!   optimizer steps, amortizing the worker edge to 1/H bits/param/step
//!   at some accuracy cost from the staler aggregation.
//!
//! Worker-edge columns are per worker per optimizer step (Table-1
//! normalization); the `agg` columns are the root link's total
//! bits/param per step (all groups combined).
//!
//! Run: `cargo bench --bench ext_topology [-- --quick]`

mod common;

use dlion::bench_utils::Table;
use dlion::cluster::run_sequential;
use dlion::cluster::topology::Topology;
use dlion::optim::dist::by_name;
use dlion::tasks::GradTask;

fn main() {
    let quick = dlion::bench_utils::quick_mode();
    let k = 8;
    let steps = if quick { 120 } else { 800 };
    let cases: &[(&str, Topology)] = &[
        ("d-lion-mavo", Topology::Star),
        ("d-lion-mavo", Topology::Hierarchical { group_size: 2 }),
        ("d-lion-mavo", Topology::Hierarchical { group_size: 4 }),
        ("d-lion-local(2)", Topology::Star),
        ("d-lion-local(4)", Topology::Star),
        ("d-lion-local(8)", Topology::Star),
        ("d-lion-local(4)", Topology::Hierarchical { group_size: 4 }),
    ];
    let mut t = Table::new(
        &format!("Extension — topology × local steps (k={k} workers, {steps} steps)"),
        &[
            "method",
            "topology",
            "final acc",
            "up b/p/step",
            "down b/p/step",
            "agg up b/p/step",
            "agg down b/p/step",
        ],
    );
    for &(method, topo) in cases {
        let (lr, hp) = common::table2_hparams(method);
        let strategy = by_name(method, &hp).unwrap();
        let task = common::vision_task(42);
        let mut cfg = common::train_cfg(steps, 42);
        cfg.base_lr = lr;
        cfg.topology = topo;
        let d = task.dim();
        let res = run_sequential(&task, strategy.as_ref(), k, &cfg);
        let denom_worker = (d * k * res.history.len()) as f64;
        let denom_link = (d * res.history.len()) as f64;
        let acc = res.final_eval.as_ref().unwrap().accuracy.unwrap_or(0.0);
        t.row(vec![
            method.to_string(),
            topo.to_string(),
            format!("{acc:.3}"),
            format!("{:.3}", res.total_uplink() as f64 * 8.0 / denom_worker),
            format!("{:.3}", res.total_downlink() as f64 * 8.0 / denom_worker),
            format!("{:.3}", res.total_agg_uplink() as f64 * 8.0 / denom_link),
            format!("{:.3}", res.total_agg_downlink() as f64 * 8.0 / denom_link),
        ]);
        eprintln!("topology: {method} @ {topo} -> acc {acc:.3}");
    }
    t.print();
    t.write_csv(common::out_dir().join("ext_topology.csv")).unwrap();
    println!("Checks: every hier row's accuracy equals the flat d-lion-mavo row");
    println!("(vote partials are exact); d-lion-local(H) divides the worker-edge");
    println!("bits by H; the root link (agg up) pays ceil(log2(g+1)) bits/param");
    println!("per group — cheaper than relaying g sign frames once g > 2.");
}
