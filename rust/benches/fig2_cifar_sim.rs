//! Figure 2 reproduction: test accuracy over training for the seven
//! Section-5.1 methods × k ∈ {4, 8, 16, 32} workers × 3 seeds on the
//! synthetic-vision substrate. Emits per-run accuracy curves
//! (results/fig2_curves.csv) and the final-accuracy matrix.
//!
//! Paper shape to check: D-Lion (MaVo) ≈ G-Lion; D-Lion (Avg) ≈ G-AdamW;
//! all four clearly above TernGrad/GradDrop/DGC; accuracy drifts down
//! slowly as k grows.
//!
//! Run: `cargo bench --bench fig2_cifar_sim [-- --quick]`

mod common;

use dlion::bench_utils::Table;
use dlion::cluster::run_sequential;
use dlion::optim::dist::by_name;
use dlion::tasks::GradTask;
use dlion::util::csv::CsvWriter;
use dlion::util::math::mean;

const METHODS: &[&str] = &[
    "g-adamw", "g-lion", "d-lion-avg", "d-lion-mavo", "terngrad", "graddrop", "dgc",
];

fn main() {
    let quick = dlion::bench_utils::quick_mode();
    let workers: &[usize] = if quick { &[4] } else { &[4, 8, 16, 32] };
    let seeds = common::seeds();
    let mut curves = CsvWriter::create(
        common::out_dir().join("fig2_curves.csv"),
        &["method", "k", "seed", "step", "eval_acc"],
    )
    .unwrap();
    let mut header: Vec<String> = vec!["method".into()];
    header.extend(workers.iter().map(|k| format!("k={k}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Figure 2 — final test accuracy (mean over seeds)", &header_refs);
    for &method in METHODS {
        let (lr, hp) = common::table2_hparams(method);
        let strategy = by_name(method, &hp).unwrap();
        let mut row = vec![method.to_string()];
        for &k in workers {
            let mut finals = Vec::new();
            for &seed in &seeds {
                let task = common::vision_task(seed);
                let mut cfg = common::train_cfg(800, seed);
                cfg.base_lr = lr;
                cfg.eval_every = cfg.steps / 8;
                let res = run_sequential(&task, strategy.as_ref(), k, &cfg);
                for r in &res.history {
                    if let Some(e) = &r.eval {
                        curves
                            .row(&[
                                method.to_string(),
                                k.to_string(),
                                seed.to_string(),
                                r.step.to_string(),
                                format!("{:.5}", e.accuracy.unwrap_or(f64::NAN)),
                            ])
                            .unwrap();
                    }
                }
                finals.push(res.final_eval.unwrap().accuracy.unwrap());
            }
            row.push(format!("{:.3}", mean(&finals)));
            eprintln!("fig2: {method} k={k} -> {:.3}", mean(&finals));
        }
        t.row(row);
    }
    curves.flush().unwrap();
    t.print();
    t.write_csv(common::out_dir().join("fig2_final_acc.csv")).unwrap();
    let _ = &common::vision_task(42).dim();
}
