//! Figure 3 reproduction: best test accuracy vs worker count k, for the
//! global and Distributed-Lion methods. The paper's observation to
//! check: performance degrades slowly with k (larger effective batch ⇒
//! less stochasticity), and D-Lion (MaVo) tracks or slightly beats
//! G-Lion at small scale.
//!
//! Run: `cargo bench --bench fig3_workers [-- --quick]`

mod common;

use dlion::bench_utils::Table;
use dlion::cluster::run_sequential;
use dlion::optim::dist::by_name;
use dlion::util::math::{mean, std_dev};

const METHODS: &[&str] = &["g-adamw", "g-lion", "d-lion-avg", "d-lion-mavo"];

fn main() {
    let quick = dlion::bench_utils::quick_mode();
    let workers: &[usize] = if quick { &[4, 8] } else { &[4, 8, 16, 32] };
    let seeds = common::seeds();
    let mut header: Vec<String> = vec!["method".into()];
    header.extend(workers.iter().map(|k| format!("k={k}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Figure 3 — best test accuracy vs worker count (mean ± std over seeds)",
        &header_refs,
    );
    for &method in METHODS {
        let (lr, hp) = common::table2_hparams(method);
        let strategy = by_name(method, &hp).unwrap();
        let mut row = vec![method.to_string()];
        for &k in workers {
            let mut bests = Vec::new();
            for &seed in &seeds {
                let task = common::vision_task(seed);
                let mut cfg = common::train_cfg(800, seed);
                cfg.base_lr = lr;
                cfg.eval_every = cfg.steps / 8;
                let res = run_sequential(&task, strategy.as_ref(), k, &cfg);
                bests.push(res.best_accuracy().unwrap());
            }
            row.push(format!("{:.3}±{:.3}", mean(&bests), std_dev(&bests)));
            eprintln!("fig3: {method} k={k} -> {:.3}", mean(&bests));
        }
        t.row(row);
    }
    t.print();
    t.write_csv(common::out_dir().join("fig3_best_acc.csv")).unwrap();
}
