//! Figure 4 reproduction: test *error* vs communication bits per
//! iteration (per parameter, per worker, up+down) at k = 4 — including
//! the D-SIGNUM (Avg/MaVo) ablations. Closer to the lower-left is
//! better.
//!
//! Paper shape to check: D-Lion variants sit in the lower-left corner
//! (≈2–4 bits, lowest error); the SIGNUM ablations sit at the same
//! bandwidth but higher error; G-Lion/G-AdamW reach similar error only
//! at 64 bits; TernGrad/GradDrop/DGC are dominated.
//!
//! Run: `cargo bench --bench fig4_tradeoff [-- --quick]`

mod common;

use dlion::bench_utils::Table;
use dlion::cluster::run_sequential;
use dlion::optim::dist::by_name;
use dlion::tasks::GradTask;
use dlion::util::math::mean;

const METHODS: &[&str] = &[
    "g-adamw",
    "g-lion",
    "d-lion-avg",
    "d-lion-mavo",
    "d-lion-ef",
    "d-lion-msync",
    "bandwidth-aware(d-lion-mavo,g-lion)",
    "d-signum-avg",
    "d-signum-mavo",
    "terngrad",
    "graddrop",
    "dgc",
];

fn main() {
    let k = 4;
    let seeds = common::seeds();
    let mut t = Table::new(
        "Figure 4 — test error vs communication bits/iter (k=4)",
        &["method", "bits/param/iter", "test error", "paper position"],
    );
    let expectation: &[(&str, &str)] = &[
        ("d-lion-mavo", "lower-left (best)"),
        ("d-lion-avg", "lower-left"),
        ("d-lion-ef", "lower-left (EF extension)"),
        ("d-lion-msync", "near lower-left + sync premium"),
        ("bandwidth-aware(d-lion-mavo,g-lion)", "tracks the link budget"),
        ("d-signum-mavo", "same bits, worse error"),
        ("d-signum-avg", "same bits, worse error"),
        ("g-lion", "64 bits, low error"),
        ("g-adamw", "64 bits, low error"),
        ("terngrad", "dominated"),
        ("graddrop", "dominated"),
        ("dgc", "dominated"),
    ];
    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    for &method in METHODS {
        let (lr, hp) = common::table2_hparams(method);
        let strategy = by_name(method, &hp).unwrap();
        let mut errs = Vec::new();
        let mut bits = 0.0;
        for &seed in &seeds {
            let task = common::vision_task(seed);
            let mut cfg = common::train_cfg(800, seed);
            cfg.base_lr = lr;
            let res = run_sequential(&task, strategy.as_ref(), k, &cfg);
            errs.push(1.0 - res.final_eval.unwrap().accuracy.unwrap());
            bits = res.bits_per_param_per_iter(task.dim());
        }
        rows.push((method.to_string(), bits, mean(&errs)));
        eprintln!("fig4: {method} bits={bits:.2} err={:.3}", mean(&errs));
    }
    for (method, bits, err) in &rows {
        let note = expectation
            .iter()
            .find(|(m, _)| m == method)
            .map(|(_, e)| *e)
            .unwrap_or("—");
        t.row(vec![
            method.clone(),
            format!("{bits:.2}"),
            format!("{err:.3}"),
            note.to_string(),
        ]);
    }
    t.print();
    t.write_csv(common::out_dir().join("fig4_tradeoff.csv")).unwrap();

    // Pareto check: at least one D-Lion variant must not be dominated by
    // any compression baseline (the paper's headline trade-off claim).
    let dlion_best = rows
        .iter()
        .filter(|(m, _, _)| m.starts_with("d-lion"))
        .map(|&(_, b, e)| (b, e))
        .fold((f64::MAX, f64::MAX), |acc, x| (acc.0.min(x.0), acc.1.min(x.1)));
    for (m, b, e) in &rows {
        if ["terngrad", "graddrop", "dgc"].contains(&m.as_str()) {
            assert!(
                *e > dlion_best.1 || *b > dlion_best.0,
                "{m} dominates D-Lion: bits {b} err {e} vs {dlion_best:?}"
            );
        }
    }
    println!("Pareto check: no compression baseline dominates D-Lion ✓");
}
