//! §Perf hot-path microbenches: every operation on the per-step critical
//! path of the coordinator, at LM scale (d = 4M, "small"-model size ×
//! headroom), plus the PJRT train_step/lion_update artifact latencies
//! when artifacts exist. Feeds EXPERIMENTS.md §Perf before/after.
//!
//! The SWAR kernel micro-rows and the monolithic-vs-chunked round rows
//! are collected into one machine-readable trajectory file written once
//! at the end of the run — `BENCH_hotpath.json` at the repo root (path
//! override: `DLION_BENCH_JSON`) — which `dlion bench-diff` compares
//! against the committed baseline (`make bench-diff`).
//!
//! Run: `cargo bench --bench hotpath [-- --quick]`

mod common;

use dlion::bench_utils::{bench_auto, black_box, fmt_secs, Table};
use dlion::optim::dist::{by_name, StrategyHyper};
use dlion::optim::lion::Lion;
use dlion::optim::{LionParams, Optimizer};
use dlion::util::Rng;

/// `d1M`-style dimension tag for trajectory row names.
fn dim_tag(d: usize) -> String {
    if d % 1_000_000 == 0 {
        format!("d{}M", d / 1_000_000)
    } else {
        format!("d{d}")
    }
}

/// Collected §Perf trajectory rows (name, baseline_s, optimized_s),
/// written once at the end of `main` as the `BENCH_hotpath.json`
/// trajectory file consumed by `dlion bench-diff`.
struct PerfRows {
    rows: Vec<(String, f64, f64)>,
}

impl PerfRows {
    fn new() -> Self {
        PerfRows { rows: Vec::new() }
    }

    fn push(&mut self, name: &str, baseline_s: f64, optimized_s: f64) {
        self.rows.push((name.to_string(), baseline_s, optimized_s));
    }

    fn write_json(&self, quick: bool) {
        use dlion::util::json::{emit, parse, Json};
        use std::collections::BTreeMap;
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|(name, b, o)| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(name.clone()));
                m.insert("baseline_s".to_string(), Json::Num(*b));
                m.insert("optimized_s".to_string(), Json::Num(*o));
                m.insert("speedup".to_string(), Json::Num(*b / *o));
                Json::Obj(m)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("bench".to_string(), Json::Str("hotpath".into()));
        let threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
        top.insert("threads".to_string(), Json::Num(threads as f64));
        top.insert("quick".to_string(), Json::Bool(quick));
        // A freshly measured file is never provisional; the committed
        // baseline may carry `"provisional": true` + null timings when
        // it was authored on a machine that could not run the bench.
        top.insert("provisional".to_string(), Json::Bool(false));
        top.insert("simd".to_string(), Json::Str(dlion::comm::simd::active().name().to_string()));
        // `make pgo` runs the bench twice: once as the warmup/reference
        // build (DLION_PGO_PHASE=warmup) and once on the profile-guided
        // rebuild (DLION_PGO_PHASE=pgo). The PGO run loads the warmup
        // trajectory and embeds the warmup-vs-PGO delta in its JSON.
        if let Ok(phase) = std::env::var("DLION_PGO_PHASE") {
            top.insert("pgo_phase".to_string(), Json::Str(phase.clone()));
            if phase == "pgo" {
                let wpath = std::env::var("DLION_PGO_WARMUP_JSON")
                    .unwrap_or_else(|_| "target/BENCH_pgo_warmup.json".into());
                match std::fs::read_to_string(&wpath).ok().and_then(|s| parse(&s).ok()) {
                    Some(w) => {
                        let mut logsum = 0.0f64;
                        let mut k = 0usize;
                        if let Some(arr) = w.get("rows").and_then(|r| r.as_arr()) {
                            for row in arr {
                                let name = row.get("name").and_then(|x| x.as_str());
                                let wopt = row.get("optimized_s").and_then(|x| x.as_f64());
                                let (Some(name), Some(wopt)) = (name, wopt) else { continue };
                                let here = self
                                    .rows
                                    .iter()
                                    .find(|(n, _, _)| n.as_str() == name)
                                    .map(|(_, _, o)| *o);
                                if let Some(o) = here {
                                    if o > 0.0 && wopt > 0.0 {
                                        logsum += (wopt / o).ln();
                                        k += 1;
                                    }
                                }
                            }
                        }
                        let geomean = (k > 0).then(|| (logsum / k as f64).exp());
                        let mut pgo = BTreeMap::new();
                        pgo.insert("warmup_json".to_string(), Json::Str(wpath.clone()));
                        pgo.insert("rows_compared".to_string(), Json::Num(k as f64));
                        pgo.insert(
                            "geomean_speedup".to_string(),
                            geomean.map(Json::Num).unwrap_or(Json::Null),
                        );
                        top.insert("pgo".to_string(), Json::Obj(pgo));
                        if let Some(g) = geomean {
                            println!("PGO vs warmup: {g:.3}x geomean over {k} shared rows");
                        }
                    }
                    None => eprintln!("hotpath: PGO warmup trajectory {wpath} unreadable, delta skipped"),
                }
            }
        }
        top.insert("rows".to_string(), Json::Arr(rows));
        let path = std::env::var("DLION_BENCH_JSON")
            .unwrap_or_else(|_| "../BENCH_hotpath.json".into());
        std::fs::write(&path, emit(&Json::Obj(top)) + "\n").unwrap();
        println!("wrote {} ({} rows)", path, self.rows.len());
    }
}

/// §Perf kernel micro-rows: the SWAR hot kernels vs the scalar paths
/// they replaced, at d = 1M. Each optimized path is asserted bit-exact
/// against its baseline before timing, then both land as a trajectory
/// row so `make bench-diff` tracks them across PRs.
fn kernel_micro(d: usize, tgt: f64, rows: &mut PerfRows) {
    use dlion::comm::{sign, swar};
    use dlion::optim::lion::fused_encode_slice;
    let mut t = Table::new(
        &format!("SWAR kernels vs scalar baselines, d={d}"),
        &["kernel", "baseline", "optimized", "speedup"],
    );
    let mut rng = Rng::new(11);
    let mut blend = vec![0.0f32; d];
    rng.fill_normal(&mut blend, 1.0);

    // 1. sign pack: per-lane bit loop -> 8-lane SWAR sign gather
    assert_eq!(sign::pack_f32_scalar(&blend), sign::pack_f32(&blend));
    let base = bench_auto(tgt, || {
        black_box(sign::pack_f32_scalar(black_box(&blend)));
    });
    let opt = bench_auto(tgt, || {
        black_box(sign::pack_f32(black_box(&blend)));
    });
    t.row(vec![
        "pack_f32 (SWAR gather)".into(),
        fmt_secs(base.median),
        fmt_secs(opt.median),
        format!("{:.2}x", base.median / opt.median),
    ]);
    rows.push(&format!("kernel/pack_f32/{}", dim_tag(d)), base.median, opt.median);

    // 2. server vote: N × i32-LUT accumulate + sign emit -> bit-sliced
    //    carry-save planes + threshold carry-out (the pure-MaVo downlink)
    for n in [8usize, 32] {
        let payloads: Vec<Vec<u8>> = (0..n)
            .map(|_| {
                let mut w = vec![0.0f32; d];
                rng.fill_normal(&mut w, 1.0);
                sign::pack_f32(&w)
            })
            .collect();
        let mut votes = vec![0i32; d];
        let plen = sign::packed_len(d);
        let mut out_base = vec![0u8; plen];
        let mut out_opt = vec![0u8; plen];
        let mut planes = swar::VotePlanes::new(d, n);
        // strict majority: count(+1) >= n/2 + 1, i.e. vote sum > 0 for
        // odd AND even n (the sum has n's parity, so > 0 <=> >= 2 - n%2)
        let threshold = n / 2 + 1;
        let base = bench_auto(tgt, || {
            votes.fill(0);
            for p in &payloads {
                sign::accumulate_votes(black_box(p), &mut votes);
            }
            for (ci, chunk) in votes.chunks(8).enumerate() {
                let mut byte = 0u8;
                for (j, &v) in chunk.iter().enumerate() {
                    byte |= u8::from(v > 0) << j;
                }
                out_base[ci] = byte;
            }
            black_box(&out_base);
        });
        let opt = bench_auto(tgt, || {
            planes.reset();
            for p in &payloads {
                planes.add(black_box(p));
            }
            planes.threshold_into(threshold, &mut out_opt);
            black_box(&out_opt);
        });
        assert_eq!(out_base, out_opt, "SWAR vote plane != i32 LUT majority (n={n})");
        t.row(vec![
            format!("vote_accumulate n={n} (bit-planes)"),
            fmt_secs(base.median),
            fmt_secs(opt.median),
            format!("{:.2}x", base.median / opt.median),
        ]);
        rows.push(
            &format!("kernel/vote_accumulate/{}/n{n}", dim_tag(d)),
            base.median,
            opt.median,
        );
    }

    // 3. D-Lion worker encode: 3-pass decomposed (blend store, scalar
    //    pack, momentum pass) -> single fused pass with SWAR sign gather
    let mut g = vec![0.0f32; d];
    rng.fill_normal(&mut g, 1.0);
    let mut lion = Lion::new(d, LionParams::default());
    let mut scratch = vec![0.0f32; d];
    let base = bench_auto(tgt, || {
        let b1 = lion.hp.beta1;
        for ((s, &m), &gg) in scratch.iter_mut().zip(&lion.momentum).zip(&g) {
            *s = b1 * m + (1.0 - b1) * gg;
        }
        black_box(sign::pack_f32_scalar(&scratch));
        lion.advance_momentum(black_box(&g));
    });
    let hp = LionParams::default();
    let mut momentum = vec![0.0f32; d];
    let mut out = vec![0u8; sign::packed_len(d)];
    let opt = bench_auto(tgt, || {
        fused_encode_slice(hp.beta1, hp.beta2, &mut momentum, black_box(&g), &mut out);
        black_box(&out);
    });
    t.row(vec![
        "fused_encode_slice (SWAR)".into(),
        fmt_secs(base.median),
        fmt_secs(opt.median),
        format!("{:.2}x", base.median / opt.median),
    ]);
    rows.push(&format!("kernel/fused_encode/{}", dim_tag(d)), base.median, opt.median);

    t.print();
    t.write_csv(common::out_dir().join(format!("hotpath_kernels_d{d}.csv"))).unwrap();
}

/// §Perf vector-codec rows: the `comm::simd` dispatched kernels vs the
/// scalar oracles they replaced, at d = 1M — dense f32 pack/accumulate,
/// the intavg log(N)-bit downlink (8 ranks per u64 register), bf16
/// round-to-nearest-even, and the base-3 ternary codec. Every pair is
/// asserted bit-exact before timing, then lands as a trajectory row so
/// `make bench-diff` gates the kernels once the baseline is measured.
fn codec_micro(d: usize, tgt: f64, rows: &mut PerfRows) {
    use dlion::comm::{dense, half, intavg, simd, tern};
    let mut t = Table::new(
        &format!("Vector codecs vs scalar oracles (tier: {}), d={d}", simd::active().name()),
        &["kernel", "scalar", "vector", "speedup"],
    );
    let tag = dim_tag(d);
    let push = |t: &mut Table, rows: &mut PerfRows, label: &str, row: &str, b: f64, o: f64| {
        t.row(vec![
            label.to_string(),
            fmt_secs(b),
            fmt_secs(o),
            format!("{:.2}x", b / o),
        ]);
        rows.push(row, b, o);
    };
    let mut rng = Rng::new(13);
    let mut v = vec![0.0f32; d];
    rng.fill_normal(&mut v, 1.0);

    // 1. dense pack: per-element extend_from_slice -> LE memcpy
    assert_eq!(dense::pack(&v), dense::pack_scalar(&v));
    let base = bench_auto(tgt, || {
        black_box(dense::pack_scalar(black_box(&v)));
    });
    let opt = bench_auto(tgt, || {
        black_box(dense::pack(black_box(&v)));
    });
    push(&mut t, rows, "dense::pack (LE memcpy)", &format!("dense/pack/{tag}"), base.median, opt.median);

    // 2. dense accumulate: per-element from_le_bytes add -> 8-lane adds
    let payload = dense::pack(&v);
    {
        let mut a = vec![0.25f32; d];
        let mut b = vec![0.25f32; d];
        dense::accumulate(&payload, &mut a);
        dense::accumulate_scalar(&payload, &mut b);
        assert_eq!(a, b, "dense accumulate parity");
    }
    let mut acc = vec![0.0f32; d];
    let base = bench_auto(tgt, || {
        dense::accumulate_scalar(black_box(&payload), black_box(&mut acc));
    });
    let opt = bench_auto(tgt, || {
        dense::accumulate(black_box(&payload), black_box(&mut acc));
    });
    push(
        &mut t,
        rows,
        "dense::accumulate (vector adds)",
        &format!("dense/accumulate/{tag}"),
        base.median,
        opt.median,
    );

    // 3. intavg pack/unpack at n=8 (b=4): one bounds-checked flush per
    //    element -> 8 ranks per u64 register
    let n = 8usize;
    let sums: Vec<i32> = (0..d).map(|_| 2 * rng.below(n + 1) as i32 - n as i32).collect();
    assert_eq!(intavg::pack(&sums, n), intavg::pack_scalar(&sums, n));
    let base = bench_auto(tgt, || {
        black_box(intavg::pack_scalar(black_box(&sums), n));
    });
    let opt = bench_auto(tgt, || {
        black_box(intavg::pack(black_box(&sums), n));
    });
    push(&mut t, rows, "intavg::pack n=8 (8/u64)", &format!("intavg/pack/{tag}"), base.median, opt.median);

    let ipacked = intavg::pack(&sums, n);
    let mut iout = vec![0i32; d];
    {
        let mut islow = vec![0i32; d];
        intavg::unpack_into(&ipacked, n, &mut iout);
        intavg::unpack_into_scalar(&ipacked, n, &mut islow);
        assert_eq!(iout, islow, "intavg unpack parity");
    }
    let base = bench_auto(tgt, || {
        intavg::unpack_into_scalar(black_box(&ipacked), n, black_box(&mut iout));
    });
    let opt = bench_auto(tgt, || {
        intavg::unpack_into(black_box(&ipacked), n, black_box(&mut iout));
    });
    push(
        &mut t,
        rows,
        "intavg::unpack n=8 (8/u64)",
        &format!("intavg/unpack/{tag}"),
        base.median,
        opt.median,
    );

    // 4. bf16 pack/unpack: branchy per-element RNE -> branchless lanes
    assert_eq!(half::pack(&v), half::pack_scalar(&v));
    let base = bench_auto(tgt, || {
        black_box(half::pack_scalar(black_box(&v)));
    });
    let opt = bench_auto(tgt, || {
        black_box(half::pack(black_box(&v)));
    });
    push(&mut t, rows, "half::pack (branchless RNE)", &format!("half/pack/{tag}"), base.median, opt.median);

    let hpacked = half::pack(&v);
    let mut hout = vec![0.0f32; d];
    {
        let mut hslow = vec![0.0f32; d];
        half::unpack_into(&hpacked, &mut hout);
        half::unpack_into_scalar(&hpacked, &mut hslow);
        assert_eq!(
            hout.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            hslow.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "bf16 unpack parity"
        );
    }
    let base = bench_auto(tgt, || {
        half::unpack_into_scalar(black_box(&hpacked), black_box(&mut hout));
    });
    let opt = bench_auto(tgt, || {
        half::unpack_into(black_box(&hpacked), black_box(&mut hout));
    });
    push(&mut t, rows, "half::unpack (widen lanes)", &format!("half/unpack/{tag}"), base.median, opt.median);

    // 5. tern pack/unpack: serial Horner %3 chain -> base-3 dot + LUT
    let trits: Vec<i8> = (0..d).map(|_| rng.below(3) as i8 - 1).collect();
    assert_eq!(tern::pack(&trits), tern::pack_scalar(&trits));
    let base = bench_auto(tgt, || {
        black_box(tern::pack_scalar(black_box(&trits)));
    });
    let opt = bench_auto(tgt, || {
        black_box(tern::pack(black_box(&trits)));
    });
    push(&mut t, rows, "tern::pack (base-3 dot)", &format!("tern/pack/{tag}"), base.median, opt.median);

    let tpacked = tern::pack(&trits);
    let mut tout = vec![0i8; d];
    {
        let mut tslow = vec![0i8; d];
        tern::unpack_into(&tpacked, &mut tout);
        tern::unpack_into_scalar(&tpacked, &mut tslow);
        assert_eq!(tout, tslow, "tern unpack parity");
    }
    let base = bench_auto(tgt, || {
        tern::unpack_into_scalar(black_box(&tpacked), black_box(&mut tout));
    });
    let opt = bench_auto(tgt, || {
        tern::unpack_into(black_box(&tpacked), black_box(&mut tout));
    });
    push(&mut t, rows, "tern::unpack (256×5 LUT)", &format!("tern/unpack/{tag}"), base.median, opt.median);

    t.print();
    t.write_csv(common::out_dir().join(format!("hotpath_codecs_d{d}.csv"))).unwrap();
}

fn strategy_round(d: usize, n: usize) {
    let mut t = Table::new(
        &format!("Full strategy round (encode+aggregate+apply), d={d}, n={n}"),
        &["strategy", "median/round", "params GB/s", "× dense f32 copy"],
    );
    let hp = StrategyHyper::default();
    let mut rng = Rng::new(5);
    let grads: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut g = vec![0.0f32; d];
            rng.fill_normal(&mut g, 1.0);
            g
        })
        .collect();
    // baseline: one dense f32 memcpy of the params
    let src = grads[0].clone();
    let mut dst = vec![0.0f32; d];
    let base = bench_auto(0.4, || {
        dst.copy_from_slice(black_box(&src));
        black_box(&dst);
    });
    for name in ["d-lion-mavo", "d-lion-avg", "d-signum-mavo", "terngrad", "dgc", "g-lion", "g-adamw"] {
        let strat = by_name(name, &hp).unwrap();
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut server = strat.make_server(n, d);
        let mut params: Vec<Vec<f32>> = vec![vec![0.1f32; d]; n];
        let mut step = 0usize;
        let timing = bench_auto(0.8, || {
            let ups: Vec<_> = workers
                .iter_mut()
                .zip(&grads)
                .map(|(w, g)| w.encode(black_box(g), 1e-3, step))
                .collect();
            let down = server.aggregate(&ups, 1e-3, step);
            for (w, p) in workers.iter_mut().zip(params.iter_mut()) {
                w.apply(p, &down, 1e-3, step);
            }
            step += 1;
        });
        t.row(vec![
            name.to_string(),
            fmt_secs(timing.median),
            format!("{:.2}", (4.0 * d as f64 * n as f64) / timing.median / 1e9),
            format!("{:.1}x", timing.median / base.median),
        ]);
    }
    t.print();
    t.write_csv(common::out_dir().join(format!("hotpath_round_d{d}_n{n}.csv"))).unwrap();
}

/// The chunked-redesign headline: encode+aggregate throughput of the
/// pre-redesign monolithic round (sequential worker loop + one
/// whole-model aggregate — exactly what `run_round` does) vs the
/// chunked round engine (split-borrow worker-/chunk-parallel encode
/// into recycled zero-copy frames, SWAR bit-plane vote aggregate).
/// Emits `round/chunked/*` and `round/mixed/*` trajectory rows.
fn chunked_round(d: usize, n: usize, tgt: f64, rows: &mut PerfRows) {
    use dlion::cluster::topology::{RoundEngine, Topology};
    let mut t = Table::new(
        &format!("Chunked round engine vs monolithic (d-lion-mavo), d={d}, n={n}"),
        &["path", "median encode+aggregate", "params GB/s", "speedup"],
    );
    let hp = StrategyHyper::default();
    let mut rng = Rng::new(7);
    let grads: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut g = vec![0.0f32; d];
            rng.fill_normal(&mut g, 1.0);
            g
        })
        .collect();
    // pre-redesign baseline: sequential encode loop + monolithic aggregate
    let strat = by_name("d-lion-mavo", &hp).unwrap();
    let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
    let mut server = strat.make_server(n, d);
    let mut step = 0usize;
    let base = bench_auto(tgt, || {
        let ups: Vec<_> = workers
            .iter_mut()
            .zip(&grads)
            .map(|(w, g)| w.encode(black_box(g), 1e-3, step))
            .collect();
        black_box(server.aggregate(&ups, 1e-3, step));
        step += 1;
    });
    // chunked path: 256 KiB chunks, worker-/chunk-parallel via the
    // engine; uplink buffers are recycled round-to-round as in training
    let chunk_size = 1 << 16;
    let mut workers2: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
    let mut engine = RoundEngine::new(strat.as_ref(), n, d, Topology::Star, chunk_size);
    let mut step2 = 0usize;
    let chunked = bench_auto(tgt, || {
        let ups = engine.encode_all(&mut workers2, &grads, 1e-3, step2);
        black_box(engine.aggregate(black_box(&ups), 1e-3, step2));
        engine.recycle_uplinks(ups);
        step2 += 1;
    });
    let speedup = base.median / chunked.median;
    // mixed-wire path: the same engine round with a per-chunk arm
    // assignment (7/8 sign-vote + 1/8 dense) — tracks the heterogeneous
    // envelope's encode+aggregate throughput across PRs
    let mstrat = by_name("mixed(d-lion-mavo*7,g-lion)", &hp).unwrap();
    let mut workers3: Vec<_> = (0..n).map(|i| mstrat.make_worker(i, n, d)).collect();
    let mut mengine = RoundEngine::new(mstrat.as_ref(), n, d, Topology::Star, chunk_size);
    let mut step3 = 0usize;
    let mixed = bench_auto(tgt, || {
        let ups = mengine.encode_all(&mut workers3, &grads, 1e-3, step3);
        black_box(mengine.aggregate(black_box(&ups), 1e-3, step3));
        mengine.recycle_uplinks(ups);
        step3 += 1;
    });
    let gbs = |m: f64| (4.0 * d as f64 * n as f64) / m / 1e9;
    t.row(vec![
        "monolithic (pre-redesign)".into(),
        fmt_secs(base.median),
        format!("{:.2}", gbs(base.median)),
        "1.0x".into(),
    ]);
    t.row(vec![
        format!("chunked engine (chunk_size={chunk_size})"),
        fmt_secs(chunked.median),
        format!("{:.2}", gbs(chunked.median)),
        format!("{speedup:.2}x"),
    ]);
    t.row(vec![
        "mixed(d-lion-mavo*7,g-lion) engine round".into(),
        fmt_secs(mixed.median),
        format!("{:.2}", gbs(mixed.median)),
        format!("{:.2}x", base.median / mixed.median),
    ]);
    t.print();
    t.write_csv(common::out_dir().join(format!("hotpath_chunked_d{d}_n{n}.csv"))).unwrap();
    rows.push(&format!("round/chunked/{}/n{n}", dim_tag(d)), base.median, chunked.median);
    rows.push(&format!("round/mixed/{}/n{n}", dim_tag(d)), base.median, mixed.median);
    println!("chunked round speedup at d={d}: {speedup:.2}x");
}

fn lion_kernels(d: usize) {
    let mut t = Table::new(
        &format!("Lion update micro-ops, d={d}"),
        &["op", "median", "GB/s"],
    );
    let mut rng = Rng::new(6);
    let mut g = vec![0.0f32; d];
    rng.fill_normal(&mut g, 1.0);
    let mut lion = Lion::new(d, LionParams::default());
    let mut params = vec![0.1f32; d];
    let timing = bench_auto(0.5, || {
        lion.step(black_box(&mut params), black_box(&g), 1e-3);
    });
    t.row(vec![
        "Lion::step (fused native)".into(),
        fmt_secs(timing.median),
        format!("{:.2}", 12.0 * d as f64 / timing.median / 1e9), // r:m,g,p w:m,p
    ]);
    let mut delta = vec![0.0f32; d];
    let timing = bench_auto(0.5, || {
        lion.peek_update(black_box(&g), black_box(&mut delta));
    });
    t.row(vec![
        "Lion::peek_update".into(),
        fmt_secs(timing.median),
        format!("{:.2}", 8.0 * d as f64 / timing.median / 1e9),
    ]);
    let timing = bench_auto(0.5, || {
        lion.advance_momentum(black_box(&g));
    });
    t.row(vec![
        "Lion::advance_momentum".into(),
        fmt_secs(timing.median),
        format!("{:.2}", 8.0 * d as f64 / timing.median / 1e9),
    ]);
    t.print();
    t.write_csv(common::out_dir().join("hotpath_lion_micro.csv")).unwrap();
}

fn pjrt_path() {
    let artifacts = std::env::var("DLION_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        eprintln!("hotpath: no artifacts, skipping PJRT latencies");
        return;
    }
    let rt = dlion::runtime::Runtime::load(&artifacts).unwrap();
    let d = rt.manifest.flat_dim;
    let ts = dlion::runtime::TrainStepExec::new(&rt).unwrap();
    let lu = dlion::runtime::LionUpdateExec::new(&rt).unwrap();
    let init = std::fs::read(std::path::Path::new(&artifacts).join("params_init.bin")).unwrap();
    let params: Vec<f32> = init
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let tokens: Vec<i32> = (0..ts.batch * ts.seq_plus1).map(|i| (i % 251) as i32).collect();
    let mut grad = vec![0.0f32; d];
    let mut t = Table::new(
        &format!("PJRT artifact latencies (model={}, d={d})", rt.manifest.model_name),
        &["artifact", "median", "note"],
    );
    let timing = bench_auto(1.0, || {
        black_box(ts.run(black_box(&params), black_box(&tokens), black_box(&mut grad)).unwrap());
    });
    t.row(vec![
        "train_step (fwd+bwd)".into(),
        fmt_secs(timing.median),
        format!("{} tok/s", (ts.batch * (ts.seq_plus1 - 1)) as f64 / timing.median),
    ]);
    let m = vec![0.01f32; d];
    let timing = bench_auto(1.0, || {
        black_box(lu.run(black_box(&m), black_box(&grad)).unwrap());
    });
    t.row(vec![
        "lion_update (Pallas artifact)".into(),
        fmt_secs(timing.median),
        format!("{:.2} GB/s", 8.0 * d as f64 / timing.median / 1e9),
    ]);
    t.print();
    t.write_csv(common::out_dir().join("hotpath_pjrt.csv")).unwrap();
}

fn perf_ablation(d: usize) {
    // §Perf before/after: naive implementations vs the optimized hot
    // paths that replaced them (EXPERIMENTS.md §Perf iteration log).
    use dlion::comm::{intavg, sign};
    let mut t = Table::new(
        &format!("§Perf ablation — before (naive) vs after (optimized), d={d}"),
        &["op", "before", "after", "speedup"],
    );
    let mut rng = Rng::new(9);
    let mut blend = vec![0.0f32; d];
    rng.fill_normal(&mut blend, 1.0);
    let packed = sign::pack_f32(&blend);

    // 1. server vote accumulation: per-bit loop -> byte LUT
    let mut votes = vec![0i32; d];
    let before = bench_auto(0.5, || {
        sign::accumulate_votes_naive(black_box(&packed), black_box(&mut votes));
    });
    let after = bench_auto(0.5, || {
        sign::accumulate_votes(black_box(&packed), black_box(&mut votes));
    });
    t.row(vec![
        "accumulate_votes (LUT)".into(),
        fmt_secs(before.median),
        fmt_secs(after.median),
        format!("{:.2}x", before.median / after.median),
    ]);

    // 2. avg-downlink pack: per-bit writes -> u64 shift register
    let sums: Vec<i32> = blend.iter().map(|&x| ((x * 2.0) as i32).clamp(-2, 2) * 2).collect();
    let before = bench_auto(0.5, || {
        black_box(intavg::pack_naive(black_box(&sums), 4));
    });
    let after = bench_auto(0.5, || {
        black_box(intavg::pack(black_box(&sums), 4));
    });
    t.row(vec![
        "intavg::pack (u64 register)".into(),
        fmt_secs(before.median),
        fmt_secs(after.median),
        format!("{:.2}x", before.median / after.median),
    ]);

    // 3. D-Lion worker encode: 3-pass (blend store, pack, momentum) ->
    //    single fused pass
    let mut lion_a = Lion::new(d, LionParams::default());
    let mut scratch = vec![0.0f32; d];
    let mut g = vec![0.0f32; d];
    rng.fill_normal(&mut g, 1.0);
    let before = bench_auto(0.5, || {
        // the pre-optimization worker path
        let b1 = lion_a.hp.beta1;
        for ((s, &m), &gg) in scratch.iter_mut().zip(&lion_a.momentum).zip(&g) {
            *s = b1 * m + (1.0 - b1) * gg;
        }
        black_box(sign::pack_f32(&scratch));
        lion_a.advance_momentum(black_box(&g));
    });
    let mut lion_b = Lion::new(d, LionParams::default());
    let after = bench_auto(0.5, || {
        black_box(lion_b.encode_fused(black_box(&g)));
    });
    t.row(vec![
        "D-Lion worker encode (fused)".into(),
        fmt_secs(before.median),
        fmt_secs(after.median),
        format!("{:.2}x", before.median / after.median),
    ]);
    t.print();
    t.write_csv(common::out_dir().join("hotpath_perf_ablation.csv")).unwrap();
}

fn main() {
    let quick = dlion::bench_utils::quick_mode();
    let d = if quick { 1_000_000 } else { 4_000_000 };
    // quick mode keeps the full row schema (bench-diff hard-fails on
    // missing rows) but shrinks per-row measurement time for CI
    let tgt = if quick { 0.12 } else { 0.8 };
    let mut rows = PerfRows::new();
    kernel_micro(1_000_000, tgt, &mut rows);
    codec_micro(1_000_000, tgt, &mut rows); // acceptance point: d = 1M
    strategy_round(d, 4);
    chunked_round(1_000_000, 4, tgt, &mut rows); // acceptance point: d = 1M
    chunked_round(4_000_000, 4, tgt, &mut rows); // second model size
    lion_kernels(d);
    perf_ablation(d);
    pjrt_path();
    rows.write_json(quick);
}
