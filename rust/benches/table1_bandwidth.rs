//! Table 1 reproduction: minimum bandwidth per method, both analytic
//! (bits/param formulas) and *measured* (actual encoded bytes through
//! the codecs) across model sizes and worker counts — plus codec
//! throughput (the L3 hot-path numbers for EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench table1_bandwidth [-- --quick]`

mod common;

use dlion::bench_utils::{bench_auto, black_box, fmt_secs, Table};
use dlion::comm::{intavg, sign, tern};
use dlion::optim::dist::{by_name, StrategyHyper, ALL_STRATEGIES};
use dlion::util::Rng;

fn analytic_table(n: usize) {
    let hp = StrategyHyper::default();
    let mut t = Table::new(
        &format!("Table 1 — minimum bandwidth (bits/param), n={n} workers"),
        &["Method", "Worker→Server", "Server→Worker", "paper says"],
    );
    let paper: &[(&str, &str)] = &[
        ("g-lion", "32d / 32d"),
        ("g-adamw", "32d / 32d"),
        ("terngrad", "1.5d / log(2n+1)d"),
        ("dgc", "(1−η)32d / 32d"),
        ("d-lion-avg", "d / log(n)d"),
        ("d-lion-mavo", "d / d"),
    ];
    for name in ALL_STRATEGIES {
        let s = by_name(name, &hp).unwrap();
        let note = paper
            .iter()
            .find(|(m, _)| m == name)
            .map(|(_, p)| *p)
            .unwrap_or("—");
        t.row(vec![
            name.to_string(),
            format!("{:.2}", s.uplink_bits_per_param(n)),
            format!("{:.2}", s.downlink_bits_per_param(n)),
            note.to_string(),
        ]);
    }
    t.print();
    t.write_csv(common::out_dir().join(format!("table1_analytic_n{n}.csv"))).unwrap();
}

fn measured_table() {
    // Measured bytes through one full encode->aggregate round per method.
    let mut t = Table::new(
        "Table 1 — measured encoded bytes (one round, per worker)",
        &["Method", "d", "n", "uplink B", "downlink B", "uplink bits/param"],
    );
    let quick = dlion::bench_utils::quick_mode();
    let dims: &[usize] = if quick { &[100_000] } else { &[100_000, 1_000_000] };
    let hp = StrategyHyper::default();
    for &d in dims {
        for &n in &[4usize, 32] {
            for name in ["d-lion-mavo", "d-lion-avg", "terngrad", "dgc", "g-adamw"] {
                let strat = by_name(name, &hp).unwrap();
                let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
                let mut server = strat.make_server(n, d);
                let mut rng = Rng::new(7);
                let grads: Vec<Vec<f32>> = (0..n)
                    .map(|_| {
                        let mut g = vec![0.0f32; d];
                        rng.fill_normal(&mut g, 1.0);
                        g
                    })
                    .collect();
                let ups: Vec<_> = workers
                    .iter_mut()
                    .zip(&grads)
                    .map(|(w, g)| w.encode(g, 1e-3, 0))
                    .collect();
                let up_bytes = ups[0].len();
                let down = server.aggregate(&ups, 1e-3, 0);
                t.row(vec![
                    name.to_string(),
                    d.to_string(),
                    n.to_string(),
                    up_bytes.to_string(),
                    down.len().to_string(),
                    format!("{:.3}", up_bytes as f64 * 8.0 / d as f64),
                ]);
            }
        }
    }
    t.print();
    t.write_csv(common::out_dir().join("table1_measured.csv")).unwrap();
}

fn codec_throughput() {
    // §Perf L3 numbers: GB/s through the hot-path codecs on this core.
    let d = 4_000_000;
    let mut rng = Rng::new(3);
    let mut blend = vec![0.0f32; d];
    rng.fill_normal(&mut blend, 1.0);
    let mut t = Table::new(
        "L3 hot-path codec throughput (1 core)",
        &["op", "median", "GB/s (f32 in)"],
    );
    let timing = bench_auto(0.6, || {
        black_box(sign::pack_f32(black_box(&blend)));
    });
    t.row(vec![
        "sign::pack_f32 (worker uplink)".into(),
        fmt_secs(timing.median),
        format!("{:.2}", 4.0 * d as f64 / timing.median / 1e9),
    ]);
    let packed = sign::pack_f32(&blend);
    let mut votes = vec![0i32; d];
    let timing = bench_auto(0.6, || {
        sign::accumulate_votes(black_box(&packed), black_box(&mut votes));
    });
    t.row(vec![
        "sign::accumulate_votes (server)".into(),
        fmt_secs(timing.median),
        format!("{:.2}", 4.0 * d as f64 / timing.median / 1e9),
    ]);
    // valid vote sums for n=4 (parity: S+4 even)
    let sums: Vec<i32> = blend.iter().map(|&x| ((x * 2.0) as i32).clamp(-2, 2) * 2).collect();
    let timing = bench_auto(0.6, || {
        black_box(intavg::pack(black_box(&sums), 4));
    });
    t.row(vec![
        "intavg::pack n=4 (avg downlink)".into(),
        fmt_secs(timing.median),
        format!("{:.2}", 4.0 * d as f64 / timing.median / 1e9),
    ]);
    let trits: Vec<i8> = blend
        .iter()
        .map(|&x| if x > 0.5 { 1 } else if x < -0.5 { -1 } else { 0 })
        .collect();
    let timing = bench_auto(0.6, || {
        black_box(tern::pack(black_box(&trits)));
    });
    t.row(vec![
        "tern::pack (terngrad uplink)".into(),
        fmt_secs(timing.median),
        format!("{:.2}", d as f64 / timing.median / 1e9),
    ]);
    t.print();
    t.write_csv(common::out_dir().join("table1_codec_throughput.csv")).unwrap();
}

fn main() {
    analytic_table(4);
    analytic_table(32);
    measured_table();
    codec_throughput();
}
