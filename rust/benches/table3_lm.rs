//! Table 3 analogue: language-model pretraining perplexity for
//! AdamW vs G-Lion vs D-Lion (MaVo) vs D-Lion (Avg) — the paper's
//! GPT2++/OpenWebText study, substituted with the transformer on the
//! synthetic corpus (DESIGN.md substitutions; identical code path,
//! smaller scale). Runs on the native backend out of the box; point
//! `DLION_ARTIFACTS` at an AOT set to drive PJRT instead.
//!
//! Paper shape to check: all four land within a narrow perplexity band;
//! the D-Lion variants are not meaningfully worse than the globals.
//!
//! Run: `cargo bench --bench table3_lm [-- --quick]`

mod common;

use dlion::bench_utils::Table;
use dlion::cluster::{run_sequential, TrainConfig};
use dlion::lm::corpus::Grammar;
use dlion::lm::LmTask;
use dlion::optim::dist::{by_name, StrategyHyper};
use dlion::tasks::GradTask;

const METHODS: &[&str] = &["g-adamw", "g-lion", "d-lion-mavo", "d-lion-avg"];

fn main() {
    let artifacts = std::env::var("DLION_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let quick = dlion::bench_utils::quick_mode();
    let steps = if quick { 40 } else { 200 };
    let workers = 4;
    let mut t = Table::new(
        &format!("Table 3 analogue — synthetic-corpus LM ({steps} steps, k={workers})"),
        &["method", "val loss", "perplexity", "uplink bits/param/iter"],
    );
    let mut ppls: Vec<(String, f64)> = Vec::new();
    for &method in METHODS {
        // Table-3 hyper-parameters: AdamW lr 3e-4 wd 0.1; Lion family
        // lr ~1/3 of AdamW's, wd 1.0 (paper's ratio, scaled).
        let (lr, wd) = if method == "g-adamw" { (1e-3, 0.1f32) } else { (3e-4, 1.0f32) };
        let hp = StrategyHyper { weight_decay: wd, ..Default::default() };
        let strategy = by_name(method, &hp).unwrap();
        let task = LmTask::new(&artifacts, 300_000, Grammar::default(), 42).unwrap();
        let cfg = TrainConfig {
            steps,
            base_lr: lr,
            warmup_steps: steps / 20,
            eval_every: 0,
            seed: 42,
            batch_per_worker: 0,
            ..Default::default()
        };
        let res = run_sequential(&task, strategy.as_ref(), workers, &cfg);
        let loss = res.final_eval.unwrap().loss;
        let up_bits = res.total_uplink() as f64 * 8.0
            / (task.dim() as f64 * steps as f64 * workers as f64);
        t.row(vec![
            method.to_string(),
            format!("{loss:.4}"),
            format!("{:.3}", loss.exp()),
            format!("{up_bits:.2}"),
        ]);
        ppls.push((method.to_string(), loss.exp()));
        eprintln!("table3: {method} ppl={:.3}", loss.exp());
    }
    t.print();
    t.write_csv(common::out_dir().join("table3_lm.csv")).unwrap();

    // Shape check (the paper's Table-3 claim): D-Lion matches *its global
    // counterpart* G-Lion — the same optimizer fed aggregated gradients —
    // within a narrow perplexity band. (AdamW-vs-Lion is a different
    // comparison and horizon-sensitive; see EXPERIMENTS.md.)
    let g_lion = ppls.iter().find(|(m, _)| m == "g-lion").unwrap().1;
    for (m, p) in &ppls {
        if m.starts_with("d-lion") {
            assert!(
                *p < g_lion * 1.15,
                "{m} ppl {p:.3} too far above g-lion {g_lion:.3}"
            );
        }
    }
    println!("shape check: D-Lion within 15% of G-Lion perplexity ✓");
}
