//! Table 4 analogue: pretrain on the base corpus, finetune with each
//! method on a shifted domain, evaluate on 7 held-out "downstream"
//! domains (the paper's LLaMA-7B 3-shot instruction-finetuning study,
//! substituted per DESIGN.md). Runs on the native backend out of the
//! box; point `DLION_ARTIFACTS` at an AOT set to drive PJRT instead.
//!
//! Paper shape to check: G-AdamW, G-Lion and D-Lion (MaVo) land within a
//! narrow band per domain; finetuning beats the 0-shot (pretrained-only)
//! row on the finetuning-adjacent domains.
//!
//! Run: `cargo bench --bench table4_finetune [-- --quick]`

mod common;

use dlion::bench_utils::Table;
use dlion::cluster::{run_sequential, TrainConfig};
use dlion::lm::corpus::Grammar;
use dlion::lm::LmTask;
use dlion::optim::dist::{by_name, StrategyHyper};

const METHODS: &[&str] = &["g-adamw", "g-lion", "d-lion-mavo", "d-lion-avg"];
const NUM_DOMAINS: usize = 7;

fn main() {
    let artifacts = std::env::var("DLION_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let quick = dlion::bench_utils::quick_mode();
    let pretrain_steps = if quick { 30 } else { 150 };
    let finetune_steps = if quick { 15 } else { 60 };
    let workers = 4; // paper: 4 workers per finetuning experiment

    // Pretrain once with G-Lion (the checkpoint all methods start from).
    let base = LmTask::new(&artifacts, 300_000, Grammar::default(), 42).unwrap();
    let hp = StrategyHyper { weight_decay: 1.0, ..Default::default() };
    let pre_strat = by_name("g-lion", &hp).unwrap();
    let pre_cfg = TrainConfig {
        steps: pretrain_steps,
        base_lr: 3e-4,
        warmup_steps: pretrain_steps / 10,
        eval_every: 0,
        seed: 42,
        batch_per_worker: 0,
        ..Default::default()
    };
    eprintln!("table4: pretraining {pretrain_steps} steps…");
    let pre = run_sequential(&base, pre_strat.as_ref(), workers, &pre_cfg);
    let pretrained = pre.final_params.unwrap();

    // Evaluation: loss on each downstream domain's corpus.
    let eval_domains: Vec<LmTask> = (0..NUM_DOMAINS)
        .map(|i| base.with_corpus(80_000, Grammar::domain(i), 1000 + i as u64))
        .collect();
    let eval_row = |params: &[f32]| -> Vec<f64> {
        eval_domains.iter().map(|t| t.eval_loss(params).unwrap()).collect()
    };

    let mut header: Vec<String> = vec!["method".into()];
    header.extend((0..NUM_DOMAINS).map(|i| format!("dom{i}")));
    header.push("mean".into());
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Table 4 analogue — downstream eval loss after finetuning (lower is better)",
        &header_refs,
    );

    // 0-shot row: the pretrained checkpoint without finetuning.
    let zero = eval_row(&pretrained);
    let zero_mean = zero.iter().sum::<f64>() / NUM_DOMAINS as f64;
    let mut row = vec!["0-shot".to_string()];
    row.extend(zero.iter().map(|l| format!("{l:.3}")));
    row.push(format!("{zero_mean:.3}"));
    t.row(row);

    // Finetune on the middle domain with each method.
    let ft_grammar = Grammar::domain(3);
    let mut means: Vec<(String, f64)> = Vec::new();
    for &method in METHODS {
        // Table-4 hyper-parameters (scaled): AdamW lr 2e-5-ish, wd 0;
        // Lion variants lr ~1/3, wd 0.01.
        let (lr, wd) = if method == "g-adamw" { (3e-4, 0.0f32) } else { (1e-4, 0.01f32) };
        let hp = StrategyHyper { weight_decay: wd, ..Default::default() };
        let strategy = by_name(method, &hp).unwrap();
        let mut ft_task = base.with_corpus(150_000, ft_grammar, 77);
        ft_task.set_init(pretrained.clone());
        let cfg = TrainConfig {
            steps: finetune_steps,
            base_lr: lr,
            eval_every: 0,
            seed: 7,
            batch_per_worker: 0,
            ..Default::default()
        };
        let res = run_sequential(&ft_task, strategy.as_ref(), workers, &cfg);
        let params = res.final_params.unwrap();
        let losses = eval_row(&params);
        let mean = losses.iter().sum::<f64>() / NUM_DOMAINS as f64;
        let mut row = vec![method.to_string()];
        row.extend(losses.iter().map(|l| format!("{l:.3}")));
        row.push(format!("{mean:.3}"));
        t.row(row);
        means.push((method.to_string(), mean));
        eprintln!("table4: {method} mean downstream loss {mean:.3}");
    }
    t.print();
    t.write_csv(common::out_dir().join("table4_finetune.csv")).unwrap();

    // Shape checks: finetuning helps on the finetuned domain's
    // neighborhood, and D-Lion MaVo is within a narrow band of G-Lion.
    let g_lion = means.iter().find(|(m, _)| m == "g-lion").unwrap().1;
    let d_mavo = means.iter().find(|(m, _)| m == "d-lion-mavo").unwrap().1;
    assert!(
        (d_mavo - g_lion).abs() < 0.25 * g_lion,
        "d-lion-mavo {d_mavo:.3} vs g-lion {g_lion:.3}"
    );
    println!("shape check: D-Lion(MaVo) within band of G-Lion after finetuning ✓");
}
