//! Hand-rolled bench harness (the offline crate set has no criterion).
//!
//! Provides warmup + timed iterations with median / p10 / p90 / MAD
//! statistics, a markdown/CSV table emitter for the paper-table benches,
//! and a `black_box` shim. All `cargo bench` targets use
//! `harness = false` and drive this module.

use std::hint::black_box as std_black_box;
use std::time::Instant;

/// Prevent the optimizer from eliding a computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Timing statistics over bench iterations (seconds).
#[derive(Clone, Debug)]
pub struct Timing {
    pub iters: usize,
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
    pub mean: f64,
}

impl Timing {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median
    }
}

impl std::fmt::Display for Timing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "median {:>10} (p10 {}, p90 {}, n={})",
            fmt_secs(self.median),
            fmt_secs(self.p10),
            fmt_secs(self.p90),
            self.iters
        )
    }
}

/// Human-readable seconds.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Time `f` with `warmup` discarded runs then `iters` measured runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(&samples)
}

/// Auto-calibrating variant: picks an iteration count targeting
/// `target_secs` total measurement time (min 5 iters).
pub fn bench_auto<F: FnMut()>(target_secs: f64, mut f: F) -> Timing {
    let t0 = Instant::now();
    f(); // warmup + calibration probe
    let probe = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_secs / probe) as usize).clamp(5, 10_000);
    bench(1, iters, f)
}

fn summarize(samples: &[f64]) -> Timing {
    use crate::util::math::{mean, median, percentile};
    Timing {
        iters: samples.len(),
        median: median(samples),
        p10: percentile(samples, 10.0),
        p90: percentile(samples, 90.0),
        mean: mean(samples),
    }
}

/// Markdown table emitter for paper-table reproduction benches.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table row width mismatch");
        self.rows.push(cells);
    }

    /// Print as aligned markdown.
    pub fn print(&self) {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n## {}\n", self.title);
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncols {
                s.push_str(&format!(" {:<width$} |", cells[i], width = widths[i]));
            }
            s
        };
        println!("{}", fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<width$}|", "", width = w + 2));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        println!();
    }

    /// Also write as CSV next to stdout output.
    pub fn write_csv(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        let mut w = crate::util::csv::CsvWriter::create(
            path,
            &self.header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        )?;
        for row in &self.rows {
            w.row(row)?;
        }
        w.flush()
    }
}

/// Parse `--quick` / env DLION_BENCH_QUICK for CI-speed benches.
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick")
        || std::env::var("DLION_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let t = bench(2, 20, || {
            black_box((0..1000).sum::<u64>());
        });
        assert_eq!(t.iters, 20);
        assert!(t.median > 0.0);
        assert!(t.p10 <= t.median && t.median <= t.p90);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }

    #[test]
    fn table_rejects_bad_width() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn table_panics_on_width_mismatch() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
