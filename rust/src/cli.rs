//! Hand-rolled CLI (no clap offline): `dlion <command> [flags] [k=v ...]`.
//!
//! Commands:
//! * `train`      — run one experiment config (`--config path` + overrides)
//! * `sweep`      — strategies × workers × seeds sweep, CSV out
//! * `bandwidth`  — print the Table-1 bandwidth matrix
//! * `strategies` — list registered strategies
//! * `lm`         — train the transformer LM; runs on the native backend
//!   out of the box (no artifacts needed), or on PJRT given an AOT
//!   artifact set from `make artifacts`
//! * `gen-artifacts` — write a native artifact set (manifest +
//!   checksummed init params); no-ops when `source_hash` is unchanged
//! * `bench-diff` — compare a fresh BENCH_hotpath.json against the
//!   committed baseline (structural regressions always exit nonzero;
//!   timing regressions past the tolerance exit nonzero once the
//!   baseline is measured, i.e. not `"provisional": true`)
//! * `bench-check` — assert the committed baseline is measured
//!   (`"provisional": false`, no null timings)

use crate::cluster::{run_sequential, run_threaded, TrainConfig};
use crate::config::Experiment;
use crate::error::{DlionError, Result};
use crate::optim::dist::{by_name, StrategyHyper, ALL_STRATEGIES, EXTENSION_STRATEGIES};
use crate::tasks::GradTask;
use std::sync::Arc;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: std::collections::BTreeMap<String, String>,
    pub overrides: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`: first token = command, `--k v` / `--k=v` flags,
    /// bare `a.b=c` tokens become config overrides.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        args.command = it.next().cloned().unwrap_or_else(|| "help".into());
        while let Some(tok) = it.next() {
            if let Some(flag) = tok.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--") && !n.contains('=')) == Some(true)
                {
                    args.flags.insert(flag.to_string(), it.next().unwrap().clone());
                } else {
                    args.flags.insert(flag.to_string(), "true".into());
                }
            } else if tok.contains('=') {
                args.overrides.push(tok.clone());
            } else {
                return Err(DlionError::Config(format!("unexpected argument '{tok}'")));
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1"))
    }
}

pub const HELP: &str = "\
dlion — Distributed Lion training coordinator

USAGE: dlion <command> [--flags] [key=value overrides]

COMMANDS:
  train       run one experiment   (--config configs/fig2.toml, --threaded)
  sweep       strategies × workers × seeds sweep, CSV to --out dir
  bandwidth   print the Table-1 bandwidth matrix (--dim, --workers)
  strategies  list registered distributed strategies (core + extensions:
              d-lion-ef, d-lion-msync, d-lion-local(<H>),
              bandwidth-aware(<cheap>,<rich>),
              mixed(<arm>[*<weight>], ...) / mixed(<a>@cheap,<b>@rich))
  lm          train the transformer LM (--artifacts artifacts/,
              --strategy d-lion-mavo, --workers 4, --steps 200). With
              no artifacts directory it runs the pure-Rust native
              backend on the registry model (--model, default tiny) —
              `dlion lm` works on a fresh checkout.
  gen-artifacts
              write a native artifact set: manifest.json + checksummed
              params_init.bin (--model tiny, --out artifacts/,
              --seed 0, --vote-workers 4, --force). Unchanged
              source_hash + intact checksums = cached no-op.
  bench-diff  print the perf delta table: a fresh hotpath trajectory
              (--fresh target/BENCH_fresh.json) vs the committed
              baseline (--baseline BENCH_hotpath.json). A baseline row
              missing from the fresh run exits nonzero; slowdowns past
              --tolerance (default 0.25) also exit nonzero when the
              baseline is measured (soft while \"provisional\": true).
  bench-check assert the committed baseline (--baseline
              BENCH_hotpath.json) is measured: \"provisional\": false
              and no null timings, else exit nonzero.
  help        this text

Overrides use dotted keys, e.g.: train.steps=500 hyper.weight_decay=0.01
topology=hier:4 routes rounds worker→group-aggregator→root (default
star); hyper.local_steps=<H> sets the window for the bare d-lion-local
alias; hyper.chunk_size=<elems> splits every wire message into
per-chunk frames for the native-chunked families (sign-vote, dense,
sparse) — bit-exact and byte-identical to the whole-model path, with
chunk-parallel encode/aggregate/apply on large models (0 = monolithic,
the default). mixed(...) assigns a different arm per chunk (weighted
cycle) or per link (@cheap/@rich under hyper.link_budget, one token
bucket per hop); weighted names carry commas, so pass them via a TOML
strategies list (see configs/mixed.toml).
";

/// Entry point used by main.rs (kept here so it is unit-testable).
pub fn run(argv: &[String]) -> Result<i32> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "help" | "-h" | "--help" => {
            println!("{HELP}");
            Ok(0)
        }
        "strategies" => {
            for s in ALL_STRATEGIES {
                println!("{s}");
            }
            for s in EXTENSION_STRATEGIES {
                println!("{s}  (extension)");
            }
            Ok(0)
        }
        "bandwidth" => cmd_bandwidth(&args),
        "train" => cmd_train(&args),
        "sweep" => cmd_sweep(&args),
        "lm" => cmd_lm(&args),
        "gen-artifacts" => cmd_gen_artifacts(&args),
        "bench-diff" => cmd_bench_diff(&args),
        "bench-check" => cmd_bench_check(&args),
        other => Err(DlionError::Config(format!("unknown command '{other}' (try help)"))),
    }
}

fn load_experiment(args: &Args) -> Result<Experiment> {
    let mut exp = match args.flag("config") {
        Some(path) => Experiment::load(path)?,
        None => Experiment::default(),
    };
    for ov in &args.overrides {
        exp.apply_override(ov)?;
    }
    Ok(exp)
}

fn cmd_bandwidth(args: &Args) -> Result<i32> {
    let dim: usize = args.flag("dim").and_then(|s| s.parse().ok()).unwrap_or(1_000_000);
    let workers: usize = args.flag("workers").and_then(|s| s.parse().ok()).unwrap_or(32);
    let hp = StrategyHyper::default();
    println!("Table 1 — bits/param for d={dim}, n={workers}:");
    println!("{:<38} {:>14} {:>14}", "method", "worker→server", "server→worker");
    for &name in ALL_STRATEGIES.iter().chain(EXTENSION_STRATEGIES.iter()) {
        let s = by_name(name, &hp).unwrap();
        println!(
            "{:<38} {:>14.2} {:>14.2}",
            name,
            s.uplink_bits_per_param(workers),
            s.downlink_bits_per_param(workers)
        );
    }
    Ok(0)
}

fn cmd_train(args: &Args) -> Result<i32> {
    let exp = load_experiment(args)?;
    let hp = exp.hyper;
    for strat_name in &exp.strategies {
        // by_name's error message names the exact parse failure; let it
        // surface verbatim (malformed composite names included)
        let strategy = by_name(strat_name, &hp)?;
        for &n in &exp.workers {
            for &seed in &exp.seeds {
                let task = exp.build_task(seed as u64)?;
                let cfg = TrainConfig { seed: seed as u64, ..exp.train.clone() };
                let result = if args.flag_bool("threaded") {
                    let task_arc: Arc<dyn crate::tasks::GradTask + Send + Sync> =
                        Arc::from(exp.build_task(seed as u64)?);
                    run_threaded(task_arc, strategy.as_ref(), n, &cfg).0
                } else {
                    run_sequential(task.as_ref(), strategy.as_ref(), n, &cfg)
                };
                let fin = result.final_eval.unwrap();
                println!(
                    "{strat_name} n={n} seed={seed}: loss={:.4} acc={} up={}B down={}B ({:.1}s)",
                    fin.loss,
                    fin.accuracy.map_or("-".into(), |a| format!("{a:.4}")),
                    result.total_uplink(),
                    result.total_downlink(),
                    result.wall_secs
                );
                if let Some(dir) = args.flag("out") {
                    let path = format!("{dir}/{}_{strat_name}_n{n}_s{seed}.csv", exp.name);
                    result.write_csv(&path)?;
                }
            }
        }
    }
    Ok(0)
}

fn cmd_sweep(args: &Args) -> Result<i32> {
    let exp = load_experiment(args)?;
    let out_dir = args.flag("out").unwrap_or("results").to_string();
    std::fs::create_dir_all(&out_dir)?;
    let mut summary = crate::util::csv::CsvWriter::create(
        format!("{out_dir}/{}_summary.csv", exp.name),
        &[
            "strategy",
            "workers",
            "seed",
            "final_loss",
            "final_acc",
            "best_acc",
            "uplink_bytes",
            "downlink_bytes",
            "agg_uplink_bytes",
            "agg_downlink_bytes",
            "agg_uplink_msgs",
            "agg_downlink_msgs",
            "bits_per_param_iter",
            "wall_secs",
        ],
    )?;
    for strat_name in &exp.strategies {
        let strategy = by_name(strat_name, &exp.hyper)?;
        for &n in &exp.workers {
            for &seed in &exp.seeds {
                let task = exp.build_task(seed as u64)?;
                let cfg = TrainConfig { seed: seed as u64, ..exp.train.clone() };
                let result = run_sequential(task.as_ref(), strategy.as_ref(), n, &cfg);
                let fin = result.final_eval.unwrap();
                summary.row(&[
                    strat_name.clone(),
                    n.to_string(),
                    seed.to_string(),
                    format!("{:.6}", fin.loss),
                    fin.accuracy.map_or(String::new(), |a| format!("{a:.6}")),
                    result.best_accuracy().map_or(String::new(), |a| format!("{a:.6}")),
                    result.total_uplink().to_string(),
                    result.total_downlink().to_string(),
                    result.total_agg_uplink().to_string(),
                    result.total_agg_downlink().to_string(),
                    result.total_agg_uplink_msgs().to_string(),
                    result.total_agg_downlink_msgs().to_string(),
                    format!("{:.3}", result.bits_per_param_per_iter(task.dim())),
                    format!("{:.2}", result.wall_secs),
                ])?;
                println!(
                    "done: {strat_name} n={n} seed={seed} loss={:.4}",
                    fin.loss
                );
            }
        }
    }
    summary.flush()?;
    println!("summary written to {out_dir}/{}_summary.csv", exp.name);
    Ok(0)
}

fn cmd_lm(args: &Args) -> Result<i32> {
    let artifacts = args.flag("artifacts").unwrap_or("artifacts").to_string();
    let strat_name = args.flag("strategy").unwrap_or("d-lion-mavo").to_string();
    let workers: usize = args.flag("workers").and_then(|s| s.parse().ok()).unwrap_or(4);
    let steps: usize = args.flag("steps").and_then(|s| s.parse().ok()).unwrap_or(200);
    let lr: f64 = args.flag("lr").and_then(|s| s.parse().ok()).unwrap_or(1e-3);
    let wd: f32 = args.flag("wd").and_then(|s| s.parse().ok()).unwrap_or(0.1);
    let corpus_bytes: usize =
        args.flag("corpus-bytes").and_then(|s| s.parse().ok()).unwrap_or(200_000);
    let model = args.flag("model").unwrap_or("tiny").to_string();
    let hp = StrategyHyper { weight_decay: wd, ..Default::default() };
    let strategy = by_name(&strat_name, &hp)?;
    let rt = Arc::new(crate::runtime::Runtime::open_model(&artifacts, &model)?);
    let task = crate::lm::LmTask::with_runtime(
        rt,
        corpus_bytes,
        crate::lm::corpus::Grammar::default(),
        42,
    )?;
    println!(
        "lm: model={} backend={} d={} batch={} seq={} strategy={strat_name} workers={workers}",
        task.rt.manifest.model_name,
        task.rt.backend_name(),
        task.dim(),
        task.batch,
        task.seq_plus1 - 1
    );
    let cfg = TrainConfig {
        steps,
        base_lr: lr,
        warmup_steps: steps / 20,
        eval_every: (steps / 10).max(1),
        seed: 42,
        ..Default::default()
    };
    let result = run_sequential(&task, strategy.as_ref(), workers, &cfg);
    let (mut up, mut down) = (0u64, 0u64);
    for r in &result.history {
        up += r.uplink_bytes;
        down += r.downlink_bytes;
        if let Some(e) = &r.eval {
            println!(
                "step {:>5} loss {:.4} eval_loss {:.4} ppl {:.2} up {}B down {}B",
                r.step,
                r.train_loss,
                e.loss,
                e.loss.exp(),
                up,
                down
            );
        }
    }
    let fin = result.final_eval.unwrap();
    println!(
        "final: eval_loss={:.4} ppl={:.3} uplink={}B downlink={}B wall={:.1}s",
        fin.loss,
        fin.loss.exp(),
        result.total_uplink(),
        result.total_downlink(),
        result.wall_secs
    );
    if let Some(out) = args.flag("out") {
        result.write_csv(out)?;
    }
    Ok(0)
}

/// Write (or revalidate) a native artifact set. The `source_hash`
/// recompilation cache makes repeated invocations no-ops until the
/// model config, seed, vote width, or format version changes.
fn cmd_gen_artifacts(args: &Args) -> Result<i32> {
    let model = args.flag("model").unwrap_or("tiny").to_string();
    let out = args.flag("out").unwrap_or("artifacts").to_string();
    let seed: u64 = args.flag("seed").and_then(|s| s.parse().ok()).unwrap_or(0);
    let vote_workers: usize = args
        .flag("vote-workers")
        .and_then(|s| s.parse().ok())
        .unwrap_or(crate::runtime::native::DEFAULT_VOTE_WORKERS);
    let force = args.flag_bool("force");
    let report = crate::runtime::native::generate(&model, &out, seed, vote_workers, force)?;
    println!(
        "gen-artifacts: model={} dir={} source_hash={} — {}",
        report.manifest.model_name,
        report.dir.display(),
        report.source_hash,
        if report.fresh { "written" } else { "up to date (cached no-op)" }
    );
    println!(
        "  flat_dim={} params={} artifacts={} backend={}",
        report.manifest.flat_dim,
        report.manifest.params.len(),
        report.manifest.artifacts.len(),
        report.manifest.backend
    );
    Ok(0)
}

/// One trajectory row's timings; any value may be absent (null timings
/// in a provisional baseline).
#[derive(Clone, Copy)]
struct BenchRow {
    baseline_s: Option<f64>,
    optimized_s: Option<f64>,
    speedup: Option<f64>,
}

/// Row name → timings.
type BenchRows = std::collections::BTreeMap<String, BenchRow>;

/// A parsed trajectory file: the provisional marker decides whether
/// timing regressions gate (`bench-diff`) and whether the baseline is
/// acceptable at all (`bench-check`).
struct BenchFile {
    provisional: bool,
    rows: BenchRows,
}

fn load_bench_file(path: &str) -> Result<BenchFile> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| DlionError::Config(format!("bench: cannot read {path}: {e}")))?;
    let doc = crate::util::json::parse(&text)
        .map_err(|e| DlionError::Config(format!("bench: {path}: {e}")))?;
    let rows = doc
        .get("rows")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| DlionError::Config(format!("bench: {path}: no \"rows\" array")))?;
    let mut map = BenchRows::new();
    for row in rows {
        let name = row
            .get("name")
            .and_then(|n| n.as_str())
            .ok_or_else(|| DlionError::Config(format!("bench: {path}: row without name")))?;
        map.insert(
            name.to_string(),
            BenchRow {
                baseline_s: row.get("baseline_s").and_then(|v| v.as_f64()),
                optimized_s: row.get("optimized_s").and_then(|v| v.as_f64()),
                speedup: row.get("speedup").and_then(|v| v.as_f64()),
            },
        );
    }
    let provisional = doc.get("provisional").and_then(|v| v.as_bool()).unwrap_or(false);
    Ok(BenchFile { provisional, rows: map })
}

/// Compare a fresh hotpath trajectory file against the committed
/// baseline. Always prints the full per-row delta table. STRUCTURAL
/// regressions — a baseline row missing from the fresh run, or an
/// unreadable/malformed file — exit nonzero unconditionally. Timing
/// slowdowns past `--tolerance` also exit nonzero once the baseline is
/// **measured** (`"provisional": false`); against a provisional
/// baseline (null timings authored where the bench could not run) they
/// are reported but soft, until measured numbers land.
fn cmd_bench_diff(args: &Args) -> Result<i32> {
    let base_path = args.flag("baseline").unwrap_or("BENCH_hotpath.json");
    let fresh_path = args.flag("fresh").unwrap_or("target/BENCH_fresh.json");
    let tol: f64 = args.flag("tolerance").and_then(|s| s.parse().ok()).unwrap_or(0.25);
    let base = load_bench_file(base_path)?;
    let fresh = load_bench_file(fresh_path)?;
    let gating = !base.provisional;
    let fmt = crate::bench_utils::fmt_secs;
    println!(
        "perf delta: {fresh_path} vs {base_path} ({} tolerance +{:.0}%)",
        if gating { "gating" } else { "soft/provisional" },
        tol * 100.0
    );
    println!("{:<42} {:>10} {:>10} {:>8} {:>8}", "row", "baseline", "fresh", "delta", "speedup");
    let mut missing: Vec<&String> = Vec::new();
    let mut slower = 0usize;
    for (name, brow) in &base.rows {
        let Some(frow) = fresh.rows.get(name) else {
            missing.push(name);
            continue;
        };
        let spd = frow.speedup.map_or_else(|| "-".to_string(), |s| format!("{s:.2}x"));
        match (&brow.optimized_s, &frow.optimized_s) {
            (Some(b), Some(f)) => {
                let delta = (f - b) / b;
                let mark = if delta > tol {
                    slower += 1;
                    "  <-- slower"
                } else {
                    ""
                };
                println!(
                    "{name:<42} {:>10} {:>10} {:>+7.1}% {spd:>8}{mark}",
                    fmt(*b),
                    fmt(*f),
                    delta * 100.0
                );
            }
            (None, Some(f)) => {
                println!(
                    "{name:<42} {:>10} {:>10} {:>8} {spd:>8}  (no committed timing)",
                    "-",
                    fmt(*f),
                    "-"
                );
            }
            (_, None) => {
                let b = brow.optimized_s.map_or_else(|| "-".to_string(), fmt);
                println!("{name:<42} {b:>10} {:>10} {:>8} {:>8}  (fresh timing null)", "-", "-", "-");
            }
        }
    }
    for name in fresh.rows.keys() {
        if !base.rows.contains_key(name) {
            println!("{name:<42} (new row — not in baseline)");
        }
    }
    if !missing.is_empty() {
        for name in &missing {
            println!("MISSING row in fresh run: {name}");
        }
        println!("bench-diff: structural regression — {} baseline row(s) missing", missing.len());
        return Ok(1);
    }
    if slower > 0 {
        if gating {
            println!(
                "bench-diff: timing regression — {slower} row(s) slower than the measured baseline beyond +{:.0}%",
                tol * 100.0
            );
            return Ok(1);
        }
        println!(
            "note: {slower} row(s) slower than baseline beyond +{:.0}% (soft: baseline is provisional)",
            tol * 100.0
        );
    }
    println!("bench-diff: ok ({} rows compared)", base.rows.len());
    Ok(0)
}

/// Assert the committed baseline is actually measured: `"provisional"`
/// must be false and every row must carry non-null timings. CI runs
/// this against `BENCH_hotpath.json` so a provisional baseline can
/// never silently return once measured numbers have landed.
fn cmd_bench_check(args: &Args) -> Result<i32> {
    let path = args.flag("baseline").unwrap_or("BENCH_hotpath.json");
    let file = load_bench_file(path)?;
    let mut bad = 0usize;
    if file.provisional {
        println!("bench-check: {path} is marked \"provisional\": true");
        bad += 1;
    }
    if file.rows.is_empty() {
        println!("bench-check: {path} has no rows");
        bad += 1;
    }
    for (name, r) in &file.rows {
        for (field, v) in [
            ("baseline_s", r.baseline_s),
            ("optimized_s", r.optimized_s),
            ("speedup", r.speedup),
        ] {
            if v.is_none() {
                println!("bench-check: {path}: row {name} has null {field}");
                bad += 1;
            }
        }
    }
    if bad > 0 {
        println!(
            "bench-check: FAIL — {path} is not a measured baseline ({bad} problem(s)); \
             run `make bench-json` on a machine with the Rust toolchain and commit the result"
        );
        return Ok(1);
    }
    println!("bench-check: ok — {path} measured, {} rows, all timings present", file.rows.len());
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_flags_and_overrides() {
        let a = Args::parse(&argv("train --config x.toml --threaded train.steps=5")).unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.flag("config"), Some("x.toml"));
        assert!(a.flag_bool("threaded"));
        assert_eq!(a.overrides, vec!["train.steps=5"]);
        let a = Args::parse(&argv("sweep --out=dir")).unwrap();
        assert_eq!(a.flag("out"), Some("dir"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Args::parse(&argv("train bogus")).is_err());
        assert!(run(&argv("frobnicate")).is_err());
    }

    #[test]
    fn help_and_listing_run() {
        assert_eq!(run(&argv("help")).unwrap(), 0);
        assert_eq!(run(&argv("strategies")).unwrap(), 0);
        assert_eq!(run(&argv("bandwidth --dim 1000 --workers 8")).unwrap(), 0);
    }

    #[test]
    fn quick_train_runs() {
        let code = run(&argv(
            "train task=quadratic strategies=d-lion-mavo workers=2 seeds=1 \
             train.steps=20 train.eval_every=0 task.dim=16",
        ))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn quick_train_runs_extension_strategies() {
        // d-lion-ef, d-lion-msync, d-lion-local, and the bare
        // bandwidth-aware alias are trainable end-to-end from the CLI
        // (the composite bandwidth-aware(a,b) form contains a comma and
        // must come from a TOML config's strategies list instead of a
        // CLI override).
        let code = run(&argv(
            "train task=quadratic strategies=d-lion-ef,d-lion-msync,bandwidth-aware,d-lion-local \
             workers=2 seeds=1 train.steps=12 train.eval_every=0 task.dim=16 \
             hyper.msync_every=4 hyper.link_budget=8 hyper.local_steps=3",
        ))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn quick_train_runs_chunked_wire_format() {
        // hyper.chunk_size drives the chunked wire path end-to-end for
        // a native family (d-lion-mavo, g-lion) and is silently a
        // single-chunk plan for monolithic strategies (terngrad).
        let code = run(&argv(
            "train task=quadratic strategies=d-lion-mavo,g-lion,terngrad workers=2 seeds=1 \
             train.steps=10 train.eval_every=0 task.dim=64 hyper.chunk_size=16",
        ))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn quick_train_runs_hierarchical_topology() {
        let code = run(&argv(
            "train task=quadratic strategies=d-lion-mavo topology=hier:2 \
             workers=4 seeds=1 train.steps=10 train.eval_every=0 task.dim=16",
        ))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn quick_train_runs_mixed_wires_from_a_config() {
        // The mixed composite names carry commas, so they ship via a
        // TOML strategies list; this drives the per-chunk and per-link
        // forms end-to-end from the CLI surface (config + overrides),
        // hierarchical + chunked.
        let dir = std::env::temp_dir().join("dlion_mixed_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mixed.toml");
        std::fs::write(
            &path,
            "task = \"quadratic\"\n\
             strategies = [\"mixed(d-lion-mavo*3,g-lion)\", \"mixed(d-lion-mavo@cheap,g-lion@rich)\"]\n\
             topology = \"hier:2\"\n\
             [train]\nsteps = 8\neval_every = 0\n\
             [hyper]\nchunk_size = 40\nlink_budget = 8.0\n\
             [task]\ndim = 200\n",
        )
        .unwrap();
        let code = run(&[
            "train".into(),
            "--config".into(),
            path.to_str().unwrap().into(),
            "workers=4".into(),
            "seeds=1".into(),
        ])
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn malformed_mixed_name_surfaces_the_parse_error() {
        // mixed() has no comma, so it survives the CLI strategies split
        // and must reach the user as the parser's named failure.
        let err = run(&argv(
            "train task=quadratic strategies=mixed() workers=1 seeds=1 train.steps=2",
        ))
        .err()
        .expect("empty mixed arm list must fail");
        assert!(
            err.to_string().contains("empty arm list"),
            "error should name the empty arm list: {err}"
        );
    }

    #[test]
    fn malformed_strategy_name_surfaces_the_parse_error() {
        // Satellite contract: the by_name parse failure reaches the CLI
        // error verbatim — no silent "unknown strategy" collapse.
        let err = run(&argv(
            "train task=quadratic strategies=d-lion-local(x) workers=1 seeds=1 train.steps=2",
        ))
        .err()
        .expect("malformed name must fail");
        assert!(
            err.to_string().contains("d-lion-local(<H>)"),
            "error should explain the expected form: {err}"
        );
    }

    #[test]
    fn gen_artifacts_writes_and_then_noops() {
        let dir = std::env::temp_dir().join("dlion_cli_gen_artifacts");
        let _ = std::fs::remove_dir_all(&dir);
        let gen = |extra: &str| {
            run(&argv(&format!(
                "gen-artifacts --model tiny --out {} --seed 7 {extra}",
                dir.display()
            )))
            .unwrap()
        };
        assert_eq!(gen(""), 0);
        assert!(dir.join("manifest.json").is_file());
        assert!(dir.join("params_init.bin").is_file());
        // second run must be the cached no-op: manifest bytes unchanged
        let before = std::fs::read(dir.join("manifest.json")).unwrap();
        assert_eq!(gen(""), 0);
        assert_eq!(before, std::fs::read(dir.join("manifest.json")).unwrap());
        // --force rewrites (same content for same inputs)
        assert_eq!(gen("--force"), 0);
        assert_eq!(before, std::fs::read(dir.join("manifest.json")).unwrap());
        assert!(run(&argv("gen-artifacts --model warp-drive")).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lm_trains_natively_without_artifacts() {
        // the acceptance path: `dlion lm` on a checkout with no
        // artifacts/ directory trains on the native backend
        let missing = std::env::temp_dir().join("dlion_cli_lm_no_artifacts");
        let _ = std::fs::remove_dir_all(&missing);
        let code = run(&argv(&format!(
            "lm --artifacts {} --workers 2 --steps 3 --corpus-bytes 20000",
            missing.display()
        )))
        .unwrap();
        assert_eq!(code, 0);
    }

    fn write_bench_json(path: &std::path::Path, provisional: bool, rows: &[(&str, Option<f64>)]) {
        let rows_json: Vec<String> = rows
            .iter()
            .map(|(name, opt)| {
                let (o, s) = match opt {
                    Some(v) => (format!("{v}"), "2.0".to_string()),
                    None => ("null".into(), "null".into()),
                };
                format!(
                    "{{\"name\": \"{name}\", \"baseline_s\": {o}, \"optimized_s\": {o}, \"speedup\": {s}}}"
                )
            })
            .collect();
        std::fs::write(
            path,
            format!(
                "{{\"bench\": \"hotpath\", \"threads\": 4, \"quick\": true, \
                 \"provisional\": {provisional}, \"rows\": [{}]}}\n",
                rows_json.join(", ")
            ),
        )
        .unwrap();
    }

    #[test]
    fn bench_diff_is_soft_against_a_provisional_baseline() {
        let dir = std::env::temp_dir().join("dlion_bench_diff_ok");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("base.json");
        let fresh = dir.join("fresh.json");
        // provisional baseline: a 10x slowdown and a null row both
        // soft-pass — nothing measured to gate against yet
        write_bench_json(&base, true, &[("kernel/a", Some(0.5)), ("kernel/b", None)]);
        write_bench_json(&fresh, false, &[("kernel/a", Some(5.0)), ("kernel/b", Some(1.0))]);
        let code = run(&[
            "bench-diff".into(),
            format!("--baseline={}", base.display()),
            format!("--fresh={}", fresh.display()),
        ])
        .unwrap();
        assert_eq!(code, 0, "a provisional baseline must not gate on timings");
    }

    #[test]
    fn bench_diff_gates_timing_regressions_on_a_measured_baseline() {
        let dir = std::env::temp_dir().join("dlion_bench_diff_gate");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("base.json");
        let fresh = dir.join("fresh.json");
        write_bench_json(&base, false, &[("kernel/a", Some(0.5)), ("kernel/b", Some(1.0))]);
        // kernel/a regresses 10x past any sane tolerance
        write_bench_json(&fresh, false, &[("kernel/a", Some(5.0)), ("kernel/b", Some(1.0))]);
        let diff = |tol: &str| {
            run(&[
                "bench-diff".into(),
                format!("--baseline={}", base.display()),
                format!("--fresh={}", fresh.display()),
                format!("--tolerance={tol}"),
            ])
            .unwrap()
        };
        assert_eq!(diff("0.25"), 1, "measured baseline + slowdown must exit nonzero");
        assert_eq!(diff("20.0"), 0, "within tolerance passes");
        // matching timings pass at the default tolerance
        write_bench_json(&fresh, false, &[("kernel/a", Some(0.5)), ("kernel/b", Some(1.0))]);
        assert_eq!(diff("0.25"), 0);
    }

    #[test]
    fn bench_diff_fails_on_missing_baseline_row() {
        let dir = std::env::temp_dir().join("dlion_bench_diff_missing");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("base.json");
        let fresh = dir.join("fresh.json");
        write_bench_json(&base, false, &[("kernel/a", Some(0.5)), ("kernel/gone", Some(0.5))]);
        write_bench_json(&fresh, false, &[("kernel/a", Some(0.5)), ("kernel/new", Some(0.1))]);
        let code = run(&[
            "bench-diff".into(),
            format!("--baseline={}", base.display()),
            format!("--fresh={}", fresh.display()),
        ])
        .unwrap();
        assert_eq!(code, 1, "a dropped row is a structural regression");
        // malformed fresh file is an error, not a soft pass
        std::fs::write(&fresh, "{not json").unwrap();
        assert!(run(&[
            "bench-diff".into(),
            format!("--baseline={}", base.display()),
            format!("--fresh={}", fresh.display()),
        ])
        .is_err());
    }

    #[test]
    fn bench_check_accepts_only_a_fully_measured_baseline() {
        let dir = std::env::temp_dir().join("dlion_bench_check");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("base.json");
        let check = |p: &std::path::Path| {
            run(&["bench-check".into(), format!("--baseline={}", p.display())]).unwrap()
        };
        write_bench_json(&base, false, &[("kernel/a", Some(0.5)), ("kernel/b", Some(1.0))]);
        assert_eq!(check(&base), 0, "measured baseline passes");
        write_bench_json(&base, true, &[("kernel/a", Some(0.5))]);
        assert_eq!(check(&base), 1, "provisional marker fails");
        write_bench_json(&base, false, &[("kernel/a", Some(0.5)), ("kernel/b", None)]);
        assert_eq!(check(&base), 1, "null timings fail");
        write_bench_json(&base, false, &[]);
        assert_eq!(check(&base), 1, "empty rows fail");
        assert!(
            run(&["bench-check".into(), "--baseline=/nonexistent/x.json".into()]).is_err(),
            "unreadable baseline is an error"
        );
    }
}
