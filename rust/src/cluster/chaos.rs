//! Deterministic fault-injection harness: elastic quorum rounds under a
//! seeded [`FaultPlan`].
//!
//! [`run_chaos`] is the third cluster driver, next to
//! [`crate::cluster::run_sequential`] and
//! [`crate::cluster::run_threaded`]: one OS thread per worker over a
//! real transport (in-process channels or loopback TCP), but the server
//! closes each round with [`super::topology::RoundEngine::aggregate_quorum`]
//! under the config's [`super::topology::QuorumPolicy`] instead of
//! blocking for the full cluster. Faults are *planned*, not random at
//! run time:
//!
//! * **Kill** — the worker exits before round `r`; its socket/channel
//!   drops, the server marks it dead and every later round closes
//!   without it.
//! * **Delay** — the worker skips its uplink for rounds `[r, r+d)`,
//!   EF-folding the skipped gradients into a [`StragglerFold`] residual
//!   that rides on its next real uplink (nothing is dropped — the
//!   sign-of-sum of the folded window is what gets voted). It still
//!   receives and applies every broadcast, so its replica never forks.
//! * **Corrupt** — the worker's uplink payloads are corrupted from
//!   round `r` on via [`FaultyWorker`] (tag and length preserved), the
//!   same Byzantine model as the `ext_byzantine` bench.
//!
//! Because delayed workers deterministically *skip the send* (rather
//! than send late), frame↔round alignment is exact and the achieved
//! quorum of every round is a pure function of the plan — which is what
//! the chaos tests assert. An honest plan (no events) makes every round
//! a full-arrival round, which [`RoundEngine::aggregate_quorum`] routes
//! through the lockstep `aggregate` path — bit-exact with
//! [`crate::cluster::run_sequential`].
//!
//! [`RoundEngine::aggregate_quorum`]: super::topology::RoundEngine::aggregate_quorum
//! [`FaultyWorker`]: crate::optim::dist::faulty::FaultyWorker

use super::metrics::{RunResult, StepRecord};
use super::topology::{HopBytes, RoundEngine};
use super::TrainConfig;
use crate::comm::tcp::{bind_loopback, TcpServer, TcpWorker};
use crate::comm::transport::{inproc_fabric, CommStats, ServerTransport, WorkerTransport};
use crate::error::{DlionError, Result};
use crate::optim::dist::faulty::{Fault, FaultyWorker};
use crate::optim::dist::{ChunkPlan, Strategy, WorkerLogic};
use crate::tasks::GradTask;
use crate::util::math::cosine_lr;
use crate::util::Rng;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

/// What happens to one worker at one round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The worker process dies before this round: no more uplinks, its
    /// connection drops, it never comes back.
    Kill,
    /// The worker misses its uplink for `rounds` consecutive rounds
    /// (EF-folded, not lost), then resumes.
    Delay {
        /// Consecutive rounds the worker stays silent (≥ 1).
        rounds: usize,
    },
    /// The worker turns Byzantine from this round on: every uplink
    /// payload is corrupted per the [`Fault`] model.
    Corrupt(Fault),
}

/// One planned fault: `worker` suffers `kind` starting at `round`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub worker: usize,
    pub round: usize,
    pub kind: FaultKind,
}

/// A seeded, fully deterministic fault schedule. The seed feeds the
/// corrupt workers' payload rngs; kills and delays need no randomness
/// at all, so two runs of the same plan see byte-identical faults.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub seed: u64,
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// An honest plan (no faults): every round is a full-quorum round.
    pub fn honest() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, events: Vec::new() }
    }

    /// Kill `worker` right before round `round`.
    pub fn kill(mut self, worker: usize, round: usize) -> Self {
        self.events.push(FaultEvent { worker, round, kind: FaultKind::Kill });
        self
    }

    /// Delay `worker` for `rounds` rounds starting at `round`.
    pub fn delay(mut self, worker: usize, round: usize, rounds: usize) -> Self {
        self.events.push(FaultEvent { worker, round, kind: FaultKind::Delay { rounds } });
        self
    }

    /// Turn `worker` Byzantine (per `fault`) from round `round` on.
    pub fn corrupt(mut self, worker: usize, round: usize, fault: Fault) -> Self {
        self.events.push(FaultEvent { worker, round, kind: FaultKind::Corrupt(fault) });
        self
    }

    /// Is `worker` dead at (or before) `round`?
    pub fn dead_at(&self, worker: usize, round: usize) -> bool {
        self.events.iter().any(|e| {
            e.worker == worker && e.round <= round && matches!(e.kind, FaultKind::Kill)
        })
    }

    /// Is `worker` planned to skip its uplink at `round` (alive but
    /// inside a delay window)?
    pub fn delayed_at(&self, worker: usize, round: usize) -> bool {
        self.events.iter().any(|e| {
            e.worker == worker
                && matches!(e.kind, FaultKind::Delay { rounds }
                    if e.round <= round && round < e.round + rounds)
        })
    }

    /// Does `worker`'s uplink arrive at `round`? (Corrupt workers
    /// arrive — with garbage.)
    pub fn arrives(&self, worker: usize, round: usize) -> bool {
        !self.dead_at(worker, round) && !self.delayed_at(worker, round)
    }

    /// The corruption applied to `worker`, if any: `(from_round, fault)`.
    pub fn corrupt_from(&self, worker: usize) -> Option<(usize, Fault)> {
        self.events.iter().find_map(|e| match e.kind {
            FaultKind::Corrupt(f) if e.worker == worker => Some((e.round, f)),
            _ => None,
        })
    }

    /// Is `worker` ever killed by this plan?
    pub fn killed(&self, worker: usize) -> bool {
        self.events.iter().any(|e| e.worker == worker && matches!(e.kind, FaultKind::Kill))
    }

    /// Workers that survive the whole run (never killed).
    pub fn survivors(&self, nworkers: usize) -> Vec<usize> {
        (0..nworkers).filter(|&w| !self.killed(w)).collect()
    }

    /// Any delay events in the plan? (These require a round deadline —
    /// a silent-but-alive worker would otherwise block gather forever.)
    pub fn has_delays(&self) -> bool {
        self.events.iter().any(|e| matches!(e.kind, FaultKind::Delay { .. }))
    }

    /// The quorum round `round` must close with under this plan: the
    /// count of workers whose uplink arrives. This is what the chaos
    /// tests check the recorded [`StepRecord::quorum`] against.
    pub fn expected_quorum(&self, nworkers: usize, round: usize) -> usize {
        (0..nworkers).filter(|&w| self.arrives(w, round)).count()
    }

    fn validate(&self, nworkers: usize) -> Result<()> {
        for e in &self.events {
            if e.worker >= nworkers {
                return Err(DlionError::Config(format!(
                    "fault plan names worker {} in a {nworkers}-worker cluster",
                    e.worker
                )));
            }
            if let FaultKind::Delay { rounds } = e.kind {
                if rounds == 0 {
                    return Err(DlionError::Config(
                        "delay fault needs rounds >= 1".into(),
                    ));
                }
            }
        }
        if self.survivors(nworkers).is_empty() {
            return Err(DlionError::Config(
                "fault plan kills every worker — nothing left to train".into(),
            ));
        }
        Ok(())
    }
}

/// Error-feedback residual for a straggler: gradients of skipped rounds
/// accumulate here and ride on the next real uplink, so a delayed
/// worker's gradient mass is conserved, merely late — the sign-momentum
/// analogue of error feedback across *rounds* instead of across the
/// compressor.
pub struct StragglerFold {
    residual: Vec<f32>,
    scratch: Vec<f32>,
    pending: bool,
}

impl StragglerFold {
    pub fn new(dim: usize) -> StragglerFold {
        StragglerFold { residual: vec![0.0; dim], scratch: Vec::new(), pending: false }
    }

    /// Fold a skipped round's gradient into the residual.
    pub fn miss(&mut self, grads: &[f32]) {
        assert_eq!(grads.len(), self.residual.len(), "gradient dim mismatch");
        for (r, g) in self.residual.iter_mut().zip(grads) {
            *r += *g;
        }
        self.pending = true;
    }

    /// The gradient to actually uplink this round: `grads` plus any
    /// pending residual (which this call clears). With nothing pending
    /// it returns `grads` itself, bit-for-bit — the honest path never
    /// touches f32 arithmetic.
    pub fn take<'a>(&'a mut self, grads: &'a [f32]) -> &'a [f32] {
        if !self.pending {
            return grads;
        }
        assert_eq!(grads.len(), self.residual.len(), "gradient dim mismatch");
        self.scratch.clear();
        self.scratch.extend(self.residual.iter().zip(grads).map(|(r, g)| r + g));
        self.residual.fill(0.0);
        self.pending = false;
        &self.scratch
    }

    /// Is there un-shipped gradient mass in the residual?
    pub fn pending(&self) -> bool {
        self.pending
    }

    /// L1 mass of the residual (the conserved quantity the property
    /// test tracks across a missed round).
    pub fn residual_mass(&self) -> f64 {
        self.residual.iter().map(|r| r.abs() as f64).sum()
    }
}

/// Which fabric the chaos run moves bytes over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosTransport {
    /// In-process mpsc channels ([`inproc_fabric`]).
    InProc,
    /// Loopback TCP sockets ([`crate::comm::tcp`]), with per-connection
    /// read deadlines doing the straggler detection.
    Tcp,
}

/// What a chaos run reports beyond the ordinary [`RunResult`].
pub struct ChaosReport {
    pub result: RunResult,
    /// Achieved quorum per round (index = step).
    pub quorums: Vec<usize>,
    /// Workers that were never killed (their final replicas are the
    /// bit-identical ones; `result.final_params` comes from the first).
    pub survivors: Vec<usize>,
    /// Transport byte counters for the run.
    pub stats: Arc<CommStats>,
}

#[allow(clippy::too_many_arguments)]
fn spawn_worker<T: WorkerTransport + Send + 'static>(
    mut wt: T,
    nworkers: usize,
    task: Arc<dyn GradTask + Send + Sync>,
    mut logic: Box<dyn WorkerLogic>,
    mut rng: Rng,
    params0: Vec<f32>,
    cfg: TrainConfig,
    chunk_plan: ChunkPlan,
    fplan: FaultPlan,
    loss_tx: mpsc::Sender<(usize, f64)>,
) -> JoinHandle<std::io::Result<Vec<f32>>> {
    std::thread::spawn(move || -> std::io::Result<Vec<f32>> {
        let d = params0.len();
        let wid = wt.worker_id();
        let mut params = params0;
        let mut grad = vec![0.0f32; d];
        let mut fold = StragglerFold::new(d);
        for step in 0..cfg.steps {
            if fplan.dead_at(wid, step) {
                // the process "dies": transport drops on return, the
                // server reads EOF / a closed channel
                return Ok(params);
            }
            let lr =
                cosine_lr(step, cfg.steps, cfg.warmup_steps, cfg.base_lr, cfg.min_lr_frac) as f32;
            let loss = task.minibatch_grad_worker(
                &params,
                &mut rng,
                cfg.batch_per_worker,
                &mut grad,
                wid,
                nworkers,
            );
            let _ = loss_tx.send((step, loss as f64));
            if fplan.delayed_at(wid, step) {
                // straggler: skip the send (deterministic abstention),
                // EF-fold the gradient for the comeback round
                fold.miss(&grad);
            } else {
                let g = fold.take(&grad);
                let uplink = logic.encode_planned(g, &chunk_plan, lr, step);
                wt.send(uplink)?;
            }
            // everyone alive — including stragglers — applies the
            // broadcast, so replicas never fork
            let downlink = wt.recv()?;
            logic.apply_planned(&mut params, &downlink, &chunk_plan, lr, step);
        }
        Ok(params)
    })
}

/// Run the elastic round loop under a [`FaultPlan`]. The config's
/// quorum policy ([`TrainConfig::quorum_policy`]) governs when rounds
/// close: each round aggregates whatever uplinks arrived by the
/// deadline, errors (named) if fewer than `cfg.quorum` arrive, and
/// records the achieved quorum in [`StepRecord::quorum`] and on the
/// transport's [`CommStats`].
///
/// Restrictions (all named [`DlionError::Config`] errors, no panics):
/// the strategy must sync every step (`local_steps == 1` — elastic
/// rounds and local-step schedules don't compose yet), a plan with
/// delay events needs `cfg.round_deadline_ms > 0`, and at least one
/// worker must survive. Periodic eval is skipped (`eval_every` is
/// ignored); the final eval runs on the first survivor's replica.
pub fn run_chaos(
    task: Arc<dyn GradTask + Send + Sync>,
    strategy: &dyn Strategy,
    nworkers: usize,
    cfg: &TrainConfig,
    fplan: &FaultPlan,
    transport: ChaosTransport,
) -> Result<ChaosReport> {
    if strategy.local_steps().max(1) != 1 {
        return Err(DlionError::Config(format!(
            "chaos driver requires a per-step strategy (local_steps == 1), {} has {}",
            strategy.name(),
            strategy.local_steps()
        )));
    }
    fplan.validate(nworkers)?;
    let policy = cfg.quorum_policy();
    if fplan.has_delays() && policy.deadline().is_none() {
        return Err(DlionError::Config(
            "fault plan has delay events but hyper.round_deadline_ms is 0: \
             a silent-but-alive worker would block gather forever"
                .into(),
        ));
    }

    let d = task.dim();
    let chunk_plan = strategy.plan(d, cfg.chunk_size);
    let stats = CommStats::new();
    let mut root = Rng::new(cfg.seed);
    let params0 = task.init_params(&mut root);
    let (loss_tx, loss_rx) = mpsc::channel::<(usize, f64)>();

    // Per-worker logic, wrapped Byzantine where the plan says so. Same
    // rng forks as the lockstep drivers — honest plans replay their
    // batches exactly.
    let mut logics: Vec<Box<dyn WorkerLogic>> = Vec::with_capacity(nworkers);
    for w in 0..nworkers {
        let mut logic = strategy.make_worker(w, nworkers, d);
        if let Some((round, fault)) = fplan.corrupt_from(w) {
            let seed = fplan.seed ^ (w as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            logic = Box::new(FaultyWorker::from_step(logic, fault, seed, round));
        }
        logics.push(logic);
    }

    let mut handles: Vec<JoinHandle<std::io::Result<Vec<f32>>>> = Vec::with_capacity(nworkers);
    let mut server: Box<dyn ServerTransport> = match transport {
        ChaosTransport::InProc => {
            let (st, wts) = inproc_fabric(nworkers, stats.clone());
            for (wt, (w, logic)) in wts.into_iter().zip(logics.into_iter().enumerate()) {
                handles.push(spawn_worker(
                    wt,
                    nworkers,
                    task.clone(),
                    logic,
                    root.fork(w as u64),
                    params0.clone(),
                    cfg.clone(),
                    chunk_plan,
                    fplan.clone(),
                    loss_tx.clone(),
                ));
            }
            Box::new(st)
        }
        ChaosTransport::Tcp => {
            let (port, listener) = bind_loopback()?;
            for (w, logic) in logics.into_iter().enumerate() {
                let wt = TcpWorker::connect(port, w, stats.clone())?;
                handles.push(spawn_worker(
                    wt,
                    nworkers,
                    task.clone(),
                    logic,
                    root.fork(w as u64),
                    params0.clone(),
                    cfg.clone(),
                    chunk_plan,
                    fplan.clone(),
                    loss_tx.clone(),
                ));
            }
            Box::new(TcpServer::accept(&listener, nworkers, stats.clone())?)
        }
    };
    drop(loss_tx);

    // Server loop: deadline gather, quorum-checked aggregate, broadcast.
    // Byte deltas around the round are race-free for the same reason as
    // run_threaded: an arriving worker blocks on the downlink, so no
    // step-(s+1) uplink exists before the step-s broadcast.
    let mut engine = RoundEngine::new(strategy, nworkers, d, cfg.topology, cfg.chunk_size);
    let required = policy.required(nworkers).max(1);
    let mut quorums: Vec<usize> = Vec::with_capacity(cfg.steps);
    let mut step_bytes: Vec<(u64, u64, HopBytes)> = Vec::with_capacity(cfg.steps);
    let (mut prev_up, mut prev_down) = (0u64, 0u64);
    let t0 = std::time::Instant::now();
    for step in 0..cfg.steps {
        let lr = cosine_lr(step, cfg.steps, cfg.warmup_steps, cfg.base_lr, cfg.min_lr_frac) as f32;
        let uplinks = server.gather_quorum(policy.deadline())?;
        let up_now = stats.uplink();
        let arrived = uplinks.iter().filter(|u| u.is_some()).count();
        if arrived < required {
            return Err(DlionError::Cluster(format!(
                "round {step}: quorum not met — {arrived}/{nworkers} uplinks arrived, \
                 policy floor is {required}"
            )));
        }
        let (downlink, hops, quorum) = engine.aggregate_quorum(uplinks, lr, step)?;
        stats.record_round_quorum(quorum, nworkers);
        stats.record_agg_uplink(hops.agg_uplink, hops.agg_uplink_msgs);
        stats.record_agg_downlink(hops.agg_downlink, hops.agg_downlink_msgs);
        server.broadcast(&downlink)?;
        let down_now = stats.downlink();
        quorums.push(quorum);
        step_bytes.push((up_now - prev_up, down_now - prev_down, hops));
        prev_up = up_now;
        prev_down = down_now;
    }

    let mut result = RunResult::new(task.name(), strategy.name(), nworkers);
    let mut per_step = vec![(0.0f64, 0usize); cfg.steps];
    for (step, loss) in loss_rx.iter() {
        per_step[step].0 += loss;
        per_step[step].1 += 1;
    }
    for (step, (sum, count)) in per_step.into_iter().enumerate() {
        let (uplink_bytes, downlink_bytes, hops) = step_bytes[step];
        let lr = cosine_lr(step, cfg.steps, cfg.warmup_steps, cfg.base_lr, cfg.min_lr_frac) as f32;
        result.push(StepRecord {
            step,
            lr: lr as f64,
            train_loss: sum / count.max(1) as f64,
            eval: None,
            uplink_bytes,
            downlink_bytes,
            agg_uplink_bytes: hops.agg_uplink as u64,
            agg_downlink_bytes: hops.agg_downlink as u64,
            agg_uplink_msgs: hops.agg_uplink_msgs as u64,
            agg_downlink_msgs: hops.agg_downlink_msgs as u64,
            quorum: quorums[step] as u64,
        });
    }

    let mut final_params: Vec<Vec<f32>> = Vec::with_capacity(nworkers);
    for h in handles {
        final_params.push(h.join().expect("chaos worker panicked")?);
    }
    let survivors = fplan.survivors(nworkers);
    if cfg.check_replicas {
        let first = survivors[0];
        for &w in &survivors[1..] {
            assert_eq!(
                final_params[first], final_params[w],
                "surviving replicas diverged (workers {first} and {w})"
            );
        }
    }
    result.final_eval = Some(task.evaluate(&final_params[survivors[0]]));
    result.wall_secs = t0.elapsed().as_secs_f64();
    result.final_params = Some(final_params.swap_remove(survivors[0]));
    Ok(ChaosReport { result, quorums, survivors, stats })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_plan_queries_are_consistent() {
        let plan = FaultPlan::new(0xC0)
            .kill(2, 3)
            .delay(1, 2, 2)
            .corrupt(0, 1, Fault::BitFlip);
        assert!(!plan.dead_at(2, 2));
        assert!(plan.dead_at(2, 3));
        assert!(plan.dead_at(2, 99), "kills are permanent");
        assert!(!plan.delayed_at(1, 1));
        assert!(plan.delayed_at(1, 2));
        assert!(plan.delayed_at(1, 3));
        assert!(!plan.delayed_at(1, 4), "delay window is half-open");
        assert!(plan.arrives(0, 5), "corrupt workers still arrive");
        assert_eq!(plan.corrupt_from(0), Some((1, Fault::BitFlip)));
        assert_eq!(plan.corrupt_from(1), None);
        assert_eq!(plan.survivors(4), vec![0, 1, 3]);
        // round 0: all 4; round 2: worker 1 delayed; round 3: 1 delayed + 2 dead
        assert_eq!(plan.expected_quorum(4, 0), 4);
        assert_eq!(plan.expected_quorum(4, 2), 3);
        assert_eq!(plan.expected_quorum(4, 3), 2);
        assert_eq!(plan.expected_quorum(4, 4), 3, "delay over, kill persists");
        assert!(plan.has_delays());
        assert!(!FaultPlan::honest().has_delays());
    }

    #[test]
    fn fault_plan_validation_rejects_bad_plans() {
        assert!(FaultPlan::new(1).kill(5, 0).validate(4).is_err(), "worker oob");
        assert!(FaultPlan::new(1).delay(0, 0, 0).validate(4).is_err(), "zero delay");
        let all_dead = FaultPlan::new(1).kill(0, 0).kill(1, 0);
        assert!(all_dead.validate(2).is_err(), "no survivors");
        assert!(all_dead.validate(3).is_ok());
    }

    #[test]
    fn straggler_fold_conserves_mass_and_is_identity_when_empty() {
        let mut fold = StragglerFold::new(3);
        let g0 = [1.0f32, -2.0, 0.5];
        // honest path: take returns the very same slice (no f32 math)
        assert!(!fold.pending());
        assert_eq!(fold.take(&g0), &g0[..]);
        // miss a round, then the next take carries the sum
        fold.miss(&g0);
        assert!(fold.pending());
        assert!((fold.residual_mass() - 3.5).abs() < 1e-12);
        let g1 = [0.5f32, 1.0, -0.5];
        let combined: Vec<f32> = fold.take(&g1).to_vec();
        assert_eq!(combined, vec![1.5, -1.0, 0.0]);
        assert!(!fold.pending());
        assert!(fold.residual_mass() < 1e-12, "residual cleared after take");
        // two consecutive misses accumulate
        fold.miss(&g0);
        fold.miss(&g1);
        let out: Vec<f32> = fold.take(&[0.0, 0.0, 0.0]).to_vec();
        assert_eq!(out, vec![1.5, -1.0, 0.0]);
    }
}
