//! Deterministic fault-injection harness: elastic quorum rounds under a
//! seeded [`FaultPlan`].
//!
//! [`run_chaos`] is the third cluster driver, next to
//! [`crate::cluster::run_sequential`] and
//! [`crate::cluster::run_threaded`]: one OS thread per worker over a
//! real transport (in-process channels or loopback TCP), but the server
//! closes each round with [`super::topology::RoundEngine::aggregate_quorum`]
//! under the config's [`super::topology::QuorumPolicy`] instead of
//! blocking for the full cluster. Faults are *planned*, not random at
//! run time:
//!
//! * **Kill** — the worker exits before round `r`; its socket/channel
//!   drops, the server marks it dead and every later round closes
//!   without it.
//! * **Delay** — the worker skips its uplink for rounds `[r, r+d)`,
//!   EF-folding the skipped gradients into a [`StragglerFold`] residual
//!   that rides on its next real uplink (nothing is dropped — the
//!   sign-of-sum of the folded window is what gets voted). It still
//!   receives and applies every broadcast, so its replica never forks.
//! * **Corrupt** — the worker's uplink payloads are corrupted from
//!   round `r` on via [`FaultyWorker`] (tag and length preserved), the
//!   same Byzantine model as the `ext_byzantine` bench.
//! * **Rejoin** — the worker dies before round `r` like a kill, but
//!   comes back before round `r'`: the driver reconnects it through
//!   [`TcpServer::accept_reconnect`], catches its replica up — from the
//!   server's broadcast replay ring when the gap fits
//!   ([`CatchUpPath::Ring`]), from a periodic server-side
//!   [`Checkpoint`] plus the ring tail when it doesn't
//!   ([`CatchUpPath::Checkpoint`]) — and the worker votes again from
//!   round `r'` on. Because `apply` is replica-pure and the learning
//!   rate is a pure function of the step, the caught-up replica is
//!   bit-identical to one that never died, which the end-of-run
//!   replica check pins.
//!
//! Because delayed workers deterministically *skip the send* (rather
//! than send late), frame↔round alignment is exact and the achieved
//! quorum of every round is a pure function of the plan — which is what
//! the chaos tests assert. An honest plan (no events) makes every round
//! a full-arrival round, which [`RoundEngine::aggregate_quorum`] routes
//! through the lockstep `aggregate` path — bit-exact with
//! [`crate::cluster::run_sequential`].
//!
//! Local-steps strategies (`d-lion-local(H)`) run the same harness on
//! the wire-round cadence: workers take `H` local steps per sync round,
//! and a worker inside a delay window at a sync step *abstains* the
//! whole window via [`WorkerLogic::abstain_sync`] — its `H` steps of
//! sign votes carry into the next uplink it does ship (the vote-level
//! analogue of [`StragglerFold`]), so abstention stays exact for the
//! sign-vote family. [`FaultPlan::silent_window`] and
//! [`FaultPlan::expected_quorum_windowed`] are the plan queries on that
//! cadence.
//!
//! [`RoundEngine::aggregate_quorum`]: super::topology::RoundEngine::aggregate_quorum
//! [`FaultyWorker`]: crate::optim::dist::faulty::FaultyWorker
//! [`TcpServer::accept_reconnect`]: crate::comm::tcp::TcpServer::accept_reconnect
//! [`WorkerLogic::abstain_sync`]: crate::optim::dist::WorkerLogic::abstain_sync
//! [`Checkpoint`]: crate::lm::checkpoint::Checkpoint

use super::metrics::{RunResult, StepRecord};
use super::topology::{HopBytes, RoundEngine};
use super::TrainConfig;
use crate::comm::tcp::{bind_loopback, TcpServer, TcpWorker};
use crate::comm::transport::{
    inproc_fabric, CommStats, InProcServer, Message, ServerTransport, WorkerTransport,
};
use crate::error::{DlionError, Result};
use crate::lm::checkpoint::Checkpoint;
use crate::optim::dist::faulty::{Fault, FaultyWorker};
use crate::optim::dist::{ChunkPlan, Strategy, WorkerLogic};
use crate::tasks::GradTask;
use crate::util::math::cosine_lr;
use crate::util::Rng;
use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// What happens to one worker at one round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The worker process dies before this round: no more uplinks, its
    /// connection drops, it never comes back.
    Kill,
    /// The worker misses its uplink for `rounds` consecutive rounds
    /// (EF-folded, not lost), then resumes.
    Delay {
        /// Consecutive rounds the worker stays silent (≥ 1).
        rounds: usize,
    },
    /// The worker turns Byzantine from this round on: every uplink
    /// payload is corrupted per the [`Fault`] model.
    Corrupt(Fault),
    /// The worker dies before this round (like [`FaultKind::Kill`]) but
    /// reconnects and catches up before round `rejoin_round`, voting
    /// again from there on. TCP transport only — the catch-up rides the
    /// reconnect handshake.
    Rejoin {
        /// First round the worker participates in again (> the kill
        /// round).
        rejoin_round: usize,
    },
}

/// One planned fault: `worker` suffers `kind` starting at `round`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub worker: usize,
    pub round: usize,
    pub kind: FaultKind,
}

/// A seeded, fully deterministic fault schedule. The seed feeds the
/// corrupt workers' payload rngs; kills, delays and rejoins need no
/// randomness at all, so two runs of the same plan see byte-identical
/// faults.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub seed: u64,
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// An honest plan (no faults): every round is a full-quorum round.
    pub fn honest() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, events: Vec::new() }
    }

    /// Kill `worker` right before round `round`.
    pub fn kill(mut self, worker: usize, round: usize) -> Self {
        self.events.push(FaultEvent { worker, round, kind: FaultKind::Kill });
        self
    }

    /// Delay `worker` for `rounds` rounds starting at `round`.
    pub fn delay(mut self, worker: usize, round: usize, rounds: usize) -> Self {
        self.events.push(FaultEvent { worker, round, kind: FaultKind::Delay { rounds } });
        self
    }

    /// Turn `worker` Byzantine (per `fault`) from round `round` on.
    pub fn corrupt(mut self, worker: usize, round: usize, fault: Fault) -> Self {
        self.events.push(FaultEvent { worker, round, kind: FaultKind::Corrupt(fault) });
        self
    }

    /// Kill `worker` right before round `round` and bring it back right
    /// before round `rejoin_round` via TCP reconnect + catch-up.
    pub fn rejoin(mut self, worker: usize, round: usize, rejoin_round: usize) -> Self {
        self.events.push(FaultEvent { worker, round, kind: FaultKind::Rejoin { rejoin_round } });
        self
    }

    /// Is `worker` dead at `round`? (A rejoining worker is dead only
    /// inside its `[kill, rejoin)` window.)
    pub fn dead_at(&self, worker: usize, round: usize) -> bool {
        self.events.iter().any(|e| {
            e.worker == worker
                && match e.kind {
                    FaultKind::Kill => e.round <= round,
                    FaultKind::Rejoin { rejoin_round } => e.round <= round && round < rejoin_round,
                    _ => false,
                }
        })
    }

    /// Is `worker` planned to skip its uplink at `round` (alive but
    /// inside a delay window)?
    pub fn delayed_at(&self, worker: usize, round: usize) -> bool {
        self.events.iter().any(|e| {
            e.worker == worker
                && matches!(e.kind, FaultKind::Delay { rounds }
                    if e.round <= round && round < e.round + rounds)
        })
    }

    /// Does `worker`'s uplink arrive at `round`? (Corrupt workers
    /// arrive — with garbage.)
    pub fn arrives(&self, worker: usize, round: usize) -> bool {
        !self.dead_at(worker, round) && !self.delayed_at(worker, round)
    }

    /// The corruption applied to `worker`, if any: `(from_round, fault)`.
    pub fn corrupt_from(&self, worker: usize) -> Option<(usize, Fault)> {
        self.events.iter().find_map(|e| match e.kind {
            FaultKind::Corrupt(f) if e.worker == worker => Some((e.round, f)),
            _ => None,
        })
    }

    /// Is `worker` ever killed for good by this plan? (Rejoins don't
    /// count: the worker ends the run alive.)
    pub fn killed(&self, worker: usize) -> bool {
        self.events.iter().any(|e| e.worker == worker && matches!(e.kind, FaultKind::Kill))
    }

    /// Workers alive at the end of the run (never permanently killed —
    /// rejoined workers are survivors, and their final replicas must be
    /// bit-identical to everyone else's).
    pub fn survivors(&self, nworkers: usize) -> Vec<usize> {
        (0..nworkers).filter(|&w| !self.killed(w)).collect()
    }

    /// Any delay events in the plan? (These require a round deadline —
    /// a silent-but-alive worker would otherwise block gather forever.)
    pub fn has_delays(&self) -> bool {
        self.events.iter().any(|e| matches!(e.kind, FaultKind::Delay { .. }))
    }

    /// The worker's rejoin window, if any: `(kill_round, rejoin_round)`.
    pub fn rejoin_of(&self, worker: usize) -> Option<(usize, usize)> {
        self.events.iter().find_map(|e| match e.kind {
            FaultKind::Rejoin { rejoin_round } if e.worker == worker => {
                Some((e.round, rejoin_round))
            }
            _ => None,
        })
    }

    /// Every rejoin in the plan as `(worker, kill_round, rejoin_round)`,
    /// sorted by rejoin round — the order the driver performs them in.
    pub fn rejoins(&self) -> Vec<(usize, usize, usize)> {
        let mut v: Vec<(usize, usize, usize)> = self
            .events
            .iter()
            .filter_map(|e| match e.kind {
                FaultKind::Rejoin { rejoin_round } => Some((e.worker, e.round, rejoin_round)),
                _ => None,
            })
            .collect();
        v.sort_by_key(|&(_, _, at)| at);
        v
    }

    /// The quorum round `round` must close with under this plan: the
    /// count of workers whose uplink arrives. This is what the chaos
    /// tests check the recorded [`StepRecord::quorum`] against.
    pub fn expected_quorum(&self, nworkers: usize, round: usize) -> usize {
        (0..nworkers).filter(|&w| self.arrives(w, round)).count()
    }

    /// Window analogue of [`FaultPlan::delayed_at`] for local-steps
    /// strategies: is `worker` delayed anywhere inside the `h`-step
    /// window ending at sync step `sync_step`? A hit silences the whole
    /// window — the worker abstains the sync and carries its votes.
    /// With `h == 1` this is exactly `delayed_at`.
    pub fn silent_window(&self, worker: usize, sync_step: usize, h: usize) -> bool {
        let start = (sync_step + 1).saturating_sub(h);
        (start..=sync_step).any(|s| self.delayed_at(worker, s))
    }

    /// The quorum the sync round at `sync_step` must close with on the
    /// local-steps cadence: workers neither dead at the sync step nor
    /// silenced anywhere in its `h`-step window. Reduces to
    /// [`FaultPlan::expected_quorum`] at `h == 1`.
    pub fn expected_quorum_windowed(&self, nworkers: usize, sync_step: usize, h: usize) -> usize {
        (0..nworkers)
            .filter(|&w| !self.dead_at(w, sync_step) && !self.silent_window(w, sync_step, h))
            .count()
    }

    fn validate(&self, nworkers: usize) -> Result<()> {
        for e in &self.events {
            if e.worker >= nworkers {
                return Err(DlionError::Config(format!(
                    "fault plan names worker {} in a {nworkers}-worker cluster",
                    e.worker
                )));
            }
            match e.kind {
                FaultKind::Delay { rounds } if rounds == 0 => {
                    return Err(DlionError::Config("delay fault needs rounds >= 1".into()));
                }
                FaultKind::Rejoin { rejoin_round } if rejoin_round <= e.round => {
                    return Err(DlionError::Config(format!(
                        "worker {} rejoin round {rejoin_round} must come after its kill \
                         at round {}",
                        e.worker, e.round
                    )));
                }
                _ => {}
            }
        }
        for w in 0..nworkers {
            let deaths = self
                .events
                .iter()
                .filter(|e| {
                    e.worker == w
                        && matches!(e.kind, FaultKind::Kill | FaultKind::Rejoin { .. })
                })
                .count();
            if deaths > 1 {
                return Err(DlionError::Config(format!(
                    "worker {w} has {deaths} kill/rejoin events — at most one death per \
                     worker per run"
                )));
            }
        }
        if self.survivors(nworkers).is_empty() {
            return Err(DlionError::Config(
                "fault plan kills every worker — nothing left to train".into(),
            ));
        }
        Ok(())
    }
}

/// Error-feedback residual for a straggler: gradients of skipped rounds
/// accumulate here and ride on the next real uplink, so a delayed
/// worker's gradient mass is conserved, merely late — the sign-momentum
/// analogue of error feedback across *rounds* instead of across the
/// compressor.
pub struct StragglerFold {
    residual: Vec<f32>,
    scratch: Vec<f32>,
    pending: bool,
}

impl StragglerFold {
    pub fn new(dim: usize) -> StragglerFold {
        StragglerFold { residual: vec![0.0; dim], scratch: Vec::new(), pending: false }
    }

    /// Fold a skipped round's gradient into the residual.
    pub fn miss(&mut self, grads: &[f32]) {
        assert_eq!(grads.len(), self.residual.len(), "gradient dim mismatch");
        for (r, g) in self.residual.iter_mut().zip(grads) {
            *r += *g;
        }
        self.pending = true;
    }

    /// The gradient to actually uplink this round: `grads` plus any
    /// pending residual (which this call clears). With nothing pending
    /// it returns `grads` itself, bit-for-bit — the honest path never
    /// touches f32 arithmetic.
    pub fn take<'a>(&'a mut self, grads: &'a [f32]) -> &'a [f32] {
        if !self.pending {
            return grads;
        }
        assert_eq!(grads.len(), self.residual.len(), "gradient dim mismatch");
        self.scratch.clear();
        self.scratch.extend(self.residual.iter().zip(grads).map(|(r, g)| r + g));
        self.residual.fill(0.0);
        self.pending = false;
        &self.scratch
    }

    /// Is there un-shipped gradient mass in the residual?
    pub fn pending(&self) -> bool {
        self.pending
    }

    /// L1 mass of the residual (the conserved quantity the property
    /// test tracks across a missed round).
    pub fn residual_mass(&self) -> f64 {
        self.residual.iter().map(|r| r.abs() as f64).sum()
    }
}

/// Which fabric the chaos run moves bytes over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosTransport {
    /// In-process mpsc channels ([`inproc_fabric`]).
    InProc,
    /// Loopback TCP sockets ([`crate::comm::tcp`]), with per-connection
    /// read deadlines doing the straggler detection.
    Tcp,
}

/// How a rejoined worker caught its replica up.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CatchUpPath {
    /// Every missed broadcast still sat in the server's replay ring:
    /// the reconnect handshake replayed them all.
    Ring,
    /// The gap exceeded the ring: the replica restored from the
    /// periodic server-side checkpoint at `from` applied rounds, then
    /// replayed the ring tail.
    Checkpoint {
        /// Applied-round count of the checkpoint the replica restarted
        /// from (a multiple of the replay ring depth).
        from: usize,
    },
}

/// One mid-run rejoin the driver performed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RejoinRecord {
    pub worker: usize,
    /// The round the worker rejoined before: it votes again from here.
    pub round: usize,
    /// Broadcast rounds replayed over the wire during catch-up.
    pub replayed: usize,
    pub path: CatchUpPath,
}

/// What a chaos run reports beyond the ordinary [`RunResult`].
pub struct ChaosReport {
    pub result: RunResult,
    /// Achieved quorum per round (index = step; 0 on the local phases
    /// of a local-steps run, matching [`StepRecord::quorum`]).
    pub quorums: Vec<usize>,
    /// Workers alive at the end (rejoined workers included; their final
    /// replicas are the bit-identical ones — `result.final_params`
    /// comes from the first).
    pub survivors: Vec<usize>,
    /// Every mid-run rejoin, in the order performed.
    pub rejoins: Vec<RejoinRecord>,
    /// Transport byte counters for the run.
    pub stats: Arc<CommStats>,
}

/// A chaos worker thread: yields how it left the loop, or the
/// transport error that took it down.
type WorkerHandle = JoinHandle<std::io::Result<WorkerExit>>;

/// How a worker thread left the round loop.
enum WorkerExit {
    /// Ran through the final round.
    Finished(Vec<f32>),
    /// The plan killed it mid-run: hand back the replica *and* the
    /// optimizer state so a rejoin models a dropped connection, not a
    /// wiped machine (momentum survives the outage).
    Dead { params: Vec<f32>, logic: Box<dyn WorkerLogic>, rng: Rng },
}

/// The per-worker round loop, shared by fresh workers (from step 0) and
/// rejoined workers (from their rejoin round, after catch-up). Returns
/// `Ok(true)` if it ran through the final round, `Ok(false)` if the
/// plan killed the worker.
#[allow(clippy::too_many_arguments)]
fn worker_loop<T: WorkerTransport>(
    wt: &mut T,
    start_step: usize,
    h: usize,
    nworkers: usize,
    task: &(dyn GradTask + Send + Sync),
    logic: &mut Box<dyn WorkerLogic>,
    rng: &mut Rng,
    params: &mut Vec<f32>,
    cfg: &TrainConfig,
    chunk_plan: &ChunkPlan,
    fplan: &FaultPlan,
    loss_tx: &mpsc::Sender<(usize, f64)>,
) -> std::io::Result<bool> {
    let d = params.len();
    let wid = wt.worker_id();
    let mut grad = vec![0.0f32; d];
    let mut fold = StragglerFold::new(d);
    for step in start_step..cfg.steps {
        if fplan.dead_at(wid, step) {
            // the process "dies": transport drops on return, the
            // server reads EOF / a closed channel
            return Ok(false);
        }
        let lr =
            cosine_lr(step, cfg.steps, cfg.warmup_steps, cfg.base_lr, cfg.min_lr_frac) as f32;
        let loss = task.minibatch_grad_worker(
            params,
            rng,
            cfg.batch_per_worker,
            &mut grad,
            wid,
            nworkers,
        );
        let _ = loss_tx.send((step, loss as f64));
        if h > 1 && (step + 1) % h != 0 {
            // local phase: every alive worker — delayed or not — keeps
            // exploring locally, so the window's Λ = Σ lr stays
            // identical across replicas and the reconciling apply
            // cannot fork them
            logic.local_step(params, &grad, lr, step);
            continue;
        }
        if h > 1 {
            // sync step of a local-steps window
            if fplan.silent_window(wid, step, h) {
                // abstain the whole window: its votes carry into the
                // next shipped uplink (vote-level straggler fold)
                logic.abstain_sync(&grad, lr, step);
            } else {
                let uplink = logic.encode_planned(&grad, chunk_plan, lr, step);
                wt.send(uplink)?;
            }
        } else if fplan.delayed_at(wid, step) {
            // straggler: skip the send (deterministic abstention),
            // EF-fold the gradient for the comeback round
            fold.miss(&grad);
        } else {
            let g = fold.take(&grad);
            let uplink = logic.encode_planned(g, chunk_plan, lr, step);
            wt.send(uplink)?;
        }
        // everyone alive — including stragglers — applies the
        // broadcast, so replicas never fork
        let downlink = wt.recv()?;
        logic.apply_planned(params, &downlink, chunk_plan, lr, step);
    }
    Ok(true)
}

#[allow(clippy::too_many_arguments)]
fn spawn_worker<T: WorkerTransport + Send + 'static>(
    mut wt: T,
    h: usize,
    nworkers: usize,
    task: Arc<dyn GradTask + Send + Sync>,
    mut logic: Box<dyn WorkerLogic>,
    mut rng: Rng,
    params0: Vec<f32>,
    cfg: TrainConfig,
    chunk_plan: ChunkPlan,
    fplan: FaultPlan,
    loss_tx: mpsc::Sender<(usize, f64)>,
) -> WorkerHandle {
    std::thread::spawn(move || -> std::io::Result<WorkerExit> {
        let mut params = params0;
        let finished = worker_loop(
            &mut wt,
            0,
            h,
            nworkers,
            task.as_ref(),
            &mut logic,
            &mut rng,
            &mut params,
            &cfg,
            &chunk_plan,
            &fplan,
            &loss_tx,
        )?;
        drop(wt);
        Ok(if finished {
            WorkerExit::Finished(params)
        } else {
            WorkerExit::Dead { params, logic, rng }
        })
    })
}

/// Reconnect a previously-dead worker, replay the missed broadcasts
/// onto its replica (`applied` = rounds it has already applied), and
/// run the shared round loop from `rejoin_round`. Catch-up is bit-exact
/// because `apply` is replica-pure and `cosine_lr` is a pure function
/// of the step.
#[allow(clippy::too_many_arguments)]
fn spawn_rejoined_worker(
    port: u16,
    worker: usize,
    applied: usize,
    rejoin_round: usize,
    nworkers: usize,
    task: Arc<dyn GradTask + Send + Sync>,
    mut logic: Box<dyn WorkerLogic>,
    mut rng: Rng,
    params0: Vec<f32>,
    cfg: TrainConfig,
    chunk_plan: ChunkPlan,
    fplan: FaultPlan,
    loss_tx: mpsc::Sender<(usize, f64)>,
    stats: Arc<CommStats>,
) -> WorkerHandle {
    std::thread::spawn(move || -> std::io::Result<WorkerExit> {
        let (mut wt, replayed) =
            TcpWorker::reconnect(port, worker, applied as u32, stats, cfg.replay_ring)?;
        let mut params = params0;
        for (k, frame) in replayed.iter().enumerate() {
            let round = applied + k;
            let lr =
                cosine_lr(round, cfg.steps, cfg.warmup_steps, cfg.base_lr, cfg.min_lr_frac) as f32;
            logic.apply_planned(&mut params, frame, &chunk_plan, lr, round);
        }
        debug_assert_eq!(
            applied + replayed.len(),
            rejoin_round,
            "catch-up must land exactly on the rejoin round"
        );
        let finished = worker_loop(
            &mut wt,
            rejoin_round,
            1,
            nworkers,
            task.as_ref(),
            &mut logic,
            &mut rng,
            &mut params,
            &cfg,
            &chunk_plan,
            &fplan,
            &loss_tx,
        )?;
        debug_assert!(finished, "a rejoined worker has no second death (plan validated)");
        drop(wt);
        Ok(WorkerExit::Finished(params))
    })
}

/// The chaos server: a concrete enum instead of `Box<dyn
/// ServerTransport>` because the rejoin path needs the TCP-only
/// [`TcpServer::accept_reconnect`] and the listener it accepts on.
enum ChaosServer {
    InProc(InProcServer),
    Tcp { server: TcpServer, listener: TcpListener, port: u16 },
}

impl ChaosServer {
    fn gather_quorum(
        &mut self,
        deadline: Option<Duration>,
    ) -> std::io::Result<Vec<Option<Message>>> {
        match self {
            ChaosServer::InProc(s) => s.gather_quorum(deadline),
            ChaosServer::Tcp { server, .. } => server.gather_quorum(deadline),
        }
    }

    fn broadcast(&mut self, msg: &[u8]) -> std::io::Result<()> {
        match self {
            ChaosServer::InProc(s) => s.broadcast(msg),
            ChaosServer::Tcp { server, .. } => server.broadcast(msg),
        }
    }
}

/// Sequence number for per-run checkpoint directories, so parallel
/// tests in one process never collide.
static CK_DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// Run the elastic round loop under a [`FaultPlan`]. The config's
/// quorum policy ([`TrainConfig::quorum_policy`]) governs when rounds
/// close: each round aggregates whatever uplinks arrived by the
/// deadline, errors (named) if fewer than `cfg.quorum` arrive, and
/// records the achieved quorum in [`StepRecord::quorum`] and on the
/// transport's [`CommStats`].
///
/// Local-steps strategies run on the wire-round cadence: the server
/// gathers only every `local_steps()`-th step, and a worker delayed
/// anywhere inside a window abstains the whole window (vote carry, see
/// the module docs).
///
/// Rejoin plans additionally drive [`TcpServer::accept_reconnect`]
/// mid-run: at each rejoin round the driver reconnects the dead worker
/// and catches it up from the broadcast replay ring
/// (`cfg.replay_ring` rounds deep) or, when the gap is larger, from a
/// server-side [`Checkpoint`] it saves every `replay_ring` rounds
/// against a shadow replica. Each rejoin is reported in
/// [`ChaosReport::rejoins`].
///
/// Restrictions (all named [`DlionError::Config`] errors, no panics): a
/// plan with delay events needs `cfg.round_deadline_ms > 0`; at least
/// one worker must survive; rejoin plans need the TCP transport, a
/// per-step strategy (`local_steps == 1`), a nonzero `cfg.replay_ring`,
/// and rejoin rounds inside the run. Periodic eval is skipped
/// (`eval_every` is ignored); the final eval runs on the first
/// survivor's replica.
pub fn run_chaos(
    task: Arc<dyn GradTask + Send + Sync>,
    strategy: &dyn Strategy,
    nworkers: usize,
    cfg: &TrainConfig,
    fplan: &FaultPlan,
    transport: ChaosTransport,
) -> Result<ChaosReport> {
    let h = strategy.local_steps().max(1);
    fplan.validate(nworkers)?;
    let policy = cfg.quorum_policy();
    if fplan.has_delays() && policy.deadline().is_none() {
        return Err(DlionError::Config(
            "fault plan has delay events but hyper.round_deadline_ms is 0: \
             a silent-but-alive worker would block gather forever"
                .into(),
        ));
    }
    let rejoins = fplan.rejoins();
    if !rejoins.is_empty() {
        if transport != ChaosTransport::Tcp {
            return Err(DlionError::Config(
                "rejoin plans need the TCP transport: mid-run catch-up rides the \
                 reconnect handshake (comm::tcp), which the in-proc fabric does not have"
                    .into(),
            ));
        }
        if h != 1 {
            return Err(DlionError::Config(format!(
                "rejoin plans need a per-step strategy (local_steps == 1): catch-up \
                 replays whole wire rounds, but {} takes {h} local steps per round",
                strategy.name()
            )));
        }
        if cfg.replay_ring == 0 {
            return Err(DlionError::Config(
                "rejoin plans need hyper.replay_ring >= 1 — with an empty ring there \
                 is nothing to catch up from"
                    .into(),
            ));
        }
        for &(w, kill, at) in &rejoins {
            if at >= cfg.steps {
                return Err(DlionError::Config(format!(
                    "worker {w} rejoins at round {at} but the run is only {} rounds \
                     (killed at {kill})",
                    cfg.steps
                )));
            }
        }
    }

    let d = task.dim();
    let chunk_plan = strategy.plan(d, cfg.chunk_size);
    let stats = CommStats::new();
    let mut root = Rng::new(cfg.seed);
    let params0 = task.init_params(&mut root);
    let (loss_tx, loss_rx) = mpsc::channel::<(usize, f64)>();

    // Shadow replica + checkpoint dir, only when some rejoin gap can
    // outrun the replay ring. The shadow applies every broadcast to a
    // fresh replica — valid as a checkpoint source because apply is
    // replica-pure — and saves every `replay_ring` rounds, so a
    // beyond-ring rejoin restores from the newest multiple-of-ring
    // checkpoint and replays only the ring tail.
    let needs_ck = rejoins.iter().any(|&(_, kill, at)| at - kill > cfg.replay_ring);
    let ck_dir: Option<PathBuf> = if needs_ck {
        let dir = std::env::temp_dir().join(format!(
            "dlion-chaos-ck-{}-{}",
            std::process::id(),
            CK_DIR_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir)?;
        Some(dir)
    } else {
        None
    };
    let mut shadow_logic = if needs_ck { Some(strategy.make_worker(0, nworkers, d)) } else { None };
    let mut shadow_params = if needs_ck { Some(params0.clone()) } else { None };

    // Per-worker logic, wrapped Byzantine where the plan says so. Same
    // rng forks as the lockstep drivers — honest plans replay their
    // batches exactly.
    let mut logics: Vec<Box<dyn WorkerLogic>> = Vec::with_capacity(nworkers);
    for w in 0..nworkers {
        let mut logic = strategy.make_worker(w, nworkers, d);
        if let Some((round, fault)) = fplan.corrupt_from(w) {
            let seed = fplan.seed ^ (w as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            logic = Box::new(FaultyWorker::from_step(logic, fault, seed, round));
        }
        logics.push(logic);
    }

    let mut handles: Vec<Option<WorkerHandle>> = Vec::with_capacity(nworkers);
    let mut server = match transport {
        ChaosTransport::InProc => {
            let (st, wts) = inproc_fabric(nworkers, stats.clone());
            for (wt, (w, logic)) in wts.into_iter().zip(logics.into_iter().enumerate()) {
                handles.push(Some(spawn_worker(
                    wt,
                    h,
                    nworkers,
                    task.clone(),
                    logic,
                    root.fork(w as u64),
                    params0.clone(),
                    cfg.clone(),
                    chunk_plan,
                    fplan.clone(),
                    loss_tx.clone(),
                )));
            }
            ChaosServer::InProc(st)
        }
        ChaosTransport::Tcp => {
            let (port, listener) = bind_loopback()?;
            for (w, logic) in logics.into_iter().enumerate() {
                let wt = TcpWorker::connect(port, w, stats.clone())?;
                handles.push(Some(spawn_worker(
                    wt,
                    h,
                    nworkers,
                    task.clone(),
                    logic,
                    root.fork(w as u64),
                    params0.clone(),
                    cfg.clone(),
                    chunk_plan,
                    fplan.clone(),
                    loss_tx.clone(),
                )));
            }
            let server = TcpServer::accept(&listener, nworkers, stats.clone(), cfg.replay_ring)?;
            ChaosServer::Tcp { server, listener, port }
        }
    };
    // NOTE: loss_tx stays alive until after the server loop — rejoined
    // workers spawned mid-loop need clones of it.

    // Server loop: deadline gather, quorum-checked aggregate, broadcast.
    // Byte deltas around the round are race-free for the same reason as
    // run_threaded: an arriving worker blocks on the downlink, so no
    // step-(s+1) uplink exists before the step-s broadcast.
    let mut engine = RoundEngine::new(strategy, nworkers, d, cfg.topology, cfg.chunk_size);
    let required = policy.required(nworkers).max(1);
    let mut quorums: Vec<usize> = Vec::with_capacity(cfg.steps);
    let mut step_bytes: Vec<(u64, u64, HopBytes)> = Vec::with_capacity(cfg.steps);
    let mut rejoin_records: Vec<RejoinRecord> = Vec::new();
    let mut rejoin_idx = 0usize;
    let (mut prev_up, mut prev_down) = (0u64, 0u64);
    let t0 = std::time::Instant::now();
    for step in 0..cfg.steps {
        // Rejoins scheduled before this round: join the dead thread,
        // pick the catch-up source, spawn the reconnecting worker and
        // accept it — all before this round's gather, so the worker
        // votes in round `step` itself.
        while rejoin_idx < rejoins.len() && rejoins[rejoin_idx].2 == step {
            let (w, kill_round, at) = rejoins[rejoin_idx];
            rejoin_idx += 1;
            let exit = handles[w]
                .take()
                .expect("rejoining worker already has no handle")
                .join()
                .expect("chaos worker panicked")?;
            let WorkerExit::Dead { params, logic, rng } = exit else {
                unreachable!("worker {w} was planned dead at {kill_round} but finished");
            };
            let gap = at - kill_round;
            let (applied, start_params, path) = if gap <= cfg.replay_ring {
                // every missed broadcast is still in the ring: resume
                // from the replica exactly as it died
                (kill_round, params, CatchUpPath::Ring)
            } else {
                // ring too short: restore from the newest checkpoint at
                // a multiple of the ring depth (strictly after the kill,
                // at most `replay_ring - 1` rounds behind `at`)
                let from = (at / cfg.replay_ring) * cfg.replay_ring;
                let dir = ck_dir.as_ref().expect("beyond-ring rejoin without checkpoint dir");
                let ck =
                    Checkpoint::load(dir.join(format!("round_{from}.ck")), &task.name(), d)?;
                (from, ck.params, CatchUpPath::Checkpoint { from })
            };
            let port = match &server {
                ChaosServer::Tcp { port, .. } => *port,
                ChaosServer::InProc(_) => unreachable!("rejoin validated TCP-only"),
            };
            handles[w] = Some(spawn_rejoined_worker(
                port,
                w,
                applied,
                at,
                nworkers,
                task.clone(),
                logic,
                rng,
                start_params,
                cfg.clone(),
                chunk_plan,
                fplan.clone(),
                loss_tx.clone(),
                stats.clone(),
            ));
            let ChaosServer::Tcp { server: tcp, listener, .. } = &mut server else {
                unreachable!("rejoin validated TCP-only");
            };
            let got = tcp.accept_reconnect(listener)?;
            if got != w {
                return Err(DlionError::Cluster(format!(
                    "round {step}: expected worker {w} on the reconnect path, got {got}"
                )));
            }
            rejoin_records.push(RejoinRecord {
                worker: w,
                round: at,
                replayed: at - applied,
                path,
            });
        }

        if h > 1 && (step + 1) % h != 0 {
            // local phase: no wire round (matches run_threaded's record
            // convention — zero bytes, zero quorum)
            quorums.push(0);
            step_bytes.push((0, 0, HopBytes::default()));
            continue;
        }
        let lr = cosine_lr(step, cfg.steps, cfg.warmup_steps, cfg.base_lr, cfg.min_lr_frac) as f32;
        let uplinks = server.gather_quorum(policy.deadline())?;
        let up_now = stats.uplink();
        let arrived = uplinks.iter().filter(|u| u.is_some()).count();
        if arrived < required {
            return Err(DlionError::Cluster(format!(
                "round {step}: quorum not met — {arrived}/{nworkers} uplinks arrived, \
                 policy floor is {required}"
            )));
        }
        let (downlink, hops, quorum) = engine.aggregate_quorum(uplinks, lr, step)?;
        stats.record_round_quorum(quorum, nworkers);
        stats.record_agg_uplink(hops.agg_uplink, hops.agg_uplink_msgs);
        stats.record_agg_downlink(hops.agg_downlink, hops.agg_downlink_msgs);
        server.broadcast(&downlink)?;
        if let (Some(sl), Some(sp)) = (shadow_logic.as_mut(), shadow_params.as_mut()) {
            sl.apply_planned(sp, &downlink, &chunk_plan, lr, step);
            if (step + 1) % cfg.replay_ring == 0 {
                let dir = ck_dir.as_ref().expect("shadow replica without checkpoint dir");
                Checkpoint::new((step + 1) as u64, task.name(), sp.clone())
                    .save(dir.join(format!("round_{}.ck", step + 1)))?;
            }
        }
        let down_now = stats.downlink();
        quorums.push(quorum);
        step_bytes.push((up_now - prev_up, down_now - prev_down, hops));
        prev_up = up_now;
        prev_down = down_now;
    }
    drop(loss_tx);

    let mut result = RunResult::new(task.name(), strategy.name(), nworkers);
    let mut per_step = vec![(0.0f64, 0usize); cfg.steps];
    for (step, loss) in loss_rx.iter() {
        per_step[step].0 += loss;
        per_step[step].1 += 1;
    }
    for (step, (sum, count)) in per_step.into_iter().enumerate() {
        let (uplink_bytes, downlink_bytes, hops) = step_bytes[step];
        let lr = cosine_lr(step, cfg.steps, cfg.warmup_steps, cfg.base_lr, cfg.min_lr_frac) as f32;
        result.push(StepRecord {
            step,
            lr: lr as f64,
            train_loss: sum / count.max(1) as f64,
            eval: None,
            uplink_bytes,
            downlink_bytes,
            agg_uplink_bytes: hops.agg_uplink as u64,
            agg_downlink_bytes: hops.agg_downlink as u64,
            agg_uplink_msgs: hops.agg_uplink_msgs as u64,
            agg_downlink_msgs: hops.agg_downlink_msgs as u64,
            quorum: quorums[step] as u64,
        });
    }

    let mut final_params: Vec<Vec<f32>> = Vec::with_capacity(nworkers);
    for handle in handles {
        let exit = handle
            .expect("worker handle missing at join")
            .join()
            .expect("chaos worker panicked")?;
        final_params.push(match exit {
            WorkerExit::Finished(p) | WorkerExit::Dead { params: p, .. } => p,
        });
    }
    if let Some(dir) = &ck_dir {
        let _ = std::fs::remove_dir_all(dir);
    }
    let survivors = fplan.survivors(nworkers);
    // A local-steps run that ends mid-window has un-reconciled local
    // state; replicas only provably agree on sync boundaries.
    if cfg.check_replicas && cfg.steps % h == 0 {
        let first = survivors[0];
        for &w in &survivors[1..] {
            assert_eq!(
                final_params[first], final_params[w],
                "surviving replicas diverged (workers {first} and {w})"
            );
        }
    }
    result.final_eval = Some(task.evaluate(&final_params[survivors[0]]));
    result.wall_secs = t0.elapsed().as_secs_f64();
    result.final_params = Some(final_params.swap_remove(survivors[0]));
    Ok(ChaosReport { result, quorums, survivors, rejoins: rejoin_records, stats })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_plan_queries_are_consistent() {
        let plan = FaultPlan::new(0xC0)
            .kill(2, 3)
            .delay(1, 2, 2)
            .corrupt(0, 1, Fault::BitFlip);
        assert!(!plan.dead_at(2, 2));
        assert!(plan.dead_at(2, 3));
        assert!(plan.dead_at(2, 99), "kills are permanent");
        assert!(!plan.delayed_at(1, 1));
        assert!(plan.delayed_at(1, 2));
        assert!(plan.delayed_at(1, 3));
        assert!(!plan.delayed_at(1, 4), "delay window is half-open");
        assert!(plan.arrives(0, 5), "corrupt workers still arrive");
        assert_eq!(plan.corrupt_from(0), Some((1, Fault::BitFlip)));
        assert_eq!(plan.corrupt_from(1), None);
        assert_eq!(plan.survivors(4), vec![0, 1, 3]);
        // round 0: all 4; round 2: worker 1 delayed; round 3: 1 delayed + 2 dead
        assert_eq!(plan.expected_quorum(4, 0), 4);
        assert_eq!(plan.expected_quorum(4, 2), 3);
        assert_eq!(plan.expected_quorum(4, 3), 2);
        assert_eq!(plan.expected_quorum(4, 4), 3, "delay over, kill persists");
        assert!(plan.has_delays());
        assert!(!FaultPlan::honest().has_delays());
    }

    #[test]
    fn rejoin_plan_queries_bound_the_dead_window() {
        let plan = FaultPlan::new(1).rejoin(1, 2, 5);
        assert!(!plan.dead_at(1, 1));
        assert!(plan.dead_at(1, 2));
        assert!(plan.dead_at(1, 4));
        assert!(!plan.dead_at(1, 5), "alive again at the rejoin round");
        assert!(!plan.dead_at(1, 99));
        assert_eq!(plan.rejoin_of(1), Some((2, 5)));
        assert_eq!(plan.rejoin_of(0), None);
        assert_eq!(plan.rejoins(), vec![(1, 2, 5)]);
        assert!(!plan.killed(1), "a rejoined worker is not killed");
        assert_eq!(plan.survivors(3), vec![0, 1, 2]);
        // quorum dips only inside the dead window
        assert_eq!(plan.expected_quorum(3, 1), 3);
        assert_eq!(plan.expected_quorum(3, 3), 2);
        assert_eq!(plan.expected_quorum(3, 5), 3);
        // rejoins() sorts by rejoin round
        let two = FaultPlan::new(2).rejoin(0, 4, 9).rejoin(2, 1, 3);
        assert_eq!(two.rejoins(), vec![(2, 1, 3), (0, 4, 9)]);
    }

    #[test]
    fn windowed_plan_queries_cover_the_whole_sync_window() {
        // delay worker 1 at steps [4, 6): with h = 3, the window ending
        // at sync step 5 contains steps 3..=5, so it is silenced; the
        // window ending at 8 (steps 6..=8) is clean again.
        let plan = FaultPlan::new(3).delay(1, 4, 2);
        assert!(plan.silent_window(1, 5, 3));
        assert!(!plan.silent_window(1, 2, 3));
        assert!(!plan.silent_window(1, 8, 3));
        assert!(!plan.silent_window(0, 5, 3));
        assert_eq!(plan.expected_quorum_windowed(4, 5, 3), 3);
        assert_eq!(plan.expected_quorum_windowed(4, 8, 3), 4);
        // h == 1 reduces to the per-step queries
        for step in 0..10 {
            assert_eq!(
                plan.expected_quorum_windowed(4, step, 1),
                plan.expected_quorum(4, step),
                "step {step}"
            );
            assert_eq!(plan.silent_window(1, step, 1), plan.delayed_at(1, step), "step {step}");
        }
        // dead workers are excluded on the windowed cadence too
        let dead = FaultPlan::new(4).kill(0, 2);
        assert_eq!(dead.expected_quorum_windowed(4, 5, 3), 3);
    }

    #[test]
    fn fault_plan_validation_rejects_bad_plans() {
        assert!(FaultPlan::new(1).kill(5, 0).validate(4).is_err(), "worker oob");
        assert!(FaultPlan::new(1).delay(0, 0, 0).validate(4).is_err(), "zero delay");
        let all_dead = FaultPlan::new(1).kill(0, 0).kill(1, 0);
        assert!(all_dead.validate(2).is_err(), "no survivors");
        assert!(all_dead.validate(3).is_ok());
        // rejoin must come strictly after the kill
        assert!(FaultPlan::new(1).rejoin(0, 3, 3).validate(2).is_err(), "empty window");
        assert!(FaultPlan::new(1).rejoin(0, 3, 2).validate(2).is_err(), "backwards window");
        assert!(FaultPlan::new(1).rejoin(0, 3, 4).validate(2).is_ok());
        // one death per worker per run
        assert!(
            FaultPlan::new(1).rejoin(0, 1, 3).kill(0, 5).validate(2).is_err(),
            "rejoin then kill"
        );
        assert!(
            FaultPlan::new(1).rejoin(0, 1, 3).rejoin(0, 5, 7).validate(2).is_err(),
            "double rejoin"
        );
    }

    #[test]
    fn straggler_fold_conserves_mass_and_is_identity_when_empty() {
        let mut fold = StragglerFold::new(3);
        let g0 = [1.0f32, -2.0, 0.5];
        // honest path: take returns the very same slice (no f32 math)
        assert!(!fold.pending());
        assert_eq!(fold.take(&g0), &g0[..]);
        // miss a round, then the next take carries the sum
        fold.miss(&g0);
        assert!(fold.pending());
        assert!((fold.residual_mass() - 3.5).abs() < 1e-12);
        let g1 = [0.5f32, 1.0, -0.5];
        let combined: Vec<f32> = fold.take(&g1).to_vec();
        assert_eq!(combined, vec![1.5, -1.0, 0.0]);
        assert!(!fold.pending());
        assert!(fold.residual_mass() < 1e-12, "residual cleared after take");
        // two consecutive misses accumulate
        fold.miss(&g0);
        fold.miss(&g1);
        let out: Vec<f32> = fold.take(&[0.0, 0.0, 0.0]).to_vec();
        assert_eq!(out, vec![1.5, -1.0, 0.0]);
    }
}
