//! Run metrics: per-step records, aggregates, CSV export.

use crate::tasks::Eval;
use crate::util::csv::CsvWriter;
use std::path::Path;

/// One training step's record. The four byte counters are per-hop: the
/// worker-edge pair is Table 1's accounting; the aggregator pair covers
/// the group↔root links of a hierarchical topology (0 on the flat star
/// and on the local steps of a local-steps strategy).
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub lr: f64,
    pub train_loss: f64,
    pub eval: Option<Eval>,
    /// worker → aggregator (star: worker → server)
    pub uplink_bytes: u64,
    /// aggregator → worker (star: server → worker)
    pub downlink_bytes: u64,
    /// aggregator → root (hierarchical only)
    pub agg_uplink_bytes: u64,
    /// root → aggregator (hierarchical only)
    pub agg_downlink_bytes: u64,
    /// aggregator → root messages this step (hierarchical only)
    pub agg_uplink_msgs: u64,
    /// root → aggregator messages this step (hierarchical only)
    pub agg_downlink_msgs: u64,
    /// Achieved quorum: uplinks aggregated this step (= nworkers on a
    /// lockstep sync step, fewer when an elastic round closed early, 0
    /// on the local steps of a local-steps strategy — no wire round).
    pub quorum: u64,
}

/// Full run result.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub task: String,
    pub strategy: String,
    pub nworkers: usize,
    pub history: Vec<StepRecord>,
    pub final_eval: Option<Eval>,
    pub final_params: Option<Vec<f32>>,
    pub wall_secs: f64,
}

impl RunResult {
    pub fn new(task: String, strategy: String, nworkers: usize) -> Self {
        RunResult {
            task,
            strategy,
            nworkers,
            history: Vec::new(),
            final_eval: None,
            final_params: None,
            wall_secs: 0.0,
        }
    }

    pub fn push(&mut self, rec: StepRecord) {
        self.history.push(rec);
    }

    /// Total bytes moved worker→server across the run.
    pub fn total_uplink(&self) -> u64 {
        self.history.iter().map(|r| r.uplink_bytes).sum()
    }

    /// Total bytes moved server→worker across the run.
    pub fn total_downlink(&self) -> u64 {
        self.history.iter().map(|r| r.downlink_bytes).sum()
    }

    /// Total aggregator→root bytes (hierarchical topologies; 0 on the
    /// flat star).
    pub fn total_agg_uplink(&self) -> u64 {
        self.history.iter().map(|r| r.agg_uplink_bytes).sum()
    }

    /// Total root→aggregator bytes (hierarchical topologies; 0 on the
    /// flat star).
    pub fn total_agg_downlink(&self) -> u64 {
        self.history.iter().map(|r| r.agg_downlink_bytes).sum()
    }

    /// Total aggregator→root messages across the run (hierarchical
    /// topologies; 0 on the flat star).
    pub fn total_agg_uplink_msgs(&self) -> u64 {
        self.history.iter().map(|r| r.agg_uplink_msgs).sum()
    }

    /// Total root→aggregator messages across the run.
    pub fn total_agg_downlink_msgs(&self) -> u64 {
        self.history.iter().map(|r| r.agg_downlink_msgs).sum()
    }

    /// Smallest achieved quorum over the run's wire rounds (steps with
    /// `quorum > 0`); `None` if no wire round happened.
    pub fn min_quorum(&self) -> Option<u64> {
        self.history.iter().map(|r| r.quorum).filter(|&q| q > 0).min()
    }

    /// Number of wire rounds that closed with fewer than `nworkers`
    /// uplinks (elastic rounds that actually dropped someone).
    pub fn partial_rounds(&self) -> usize {
        let n = self.nworkers as u64;
        self.history.iter().filter(|r| r.quorum > 0 && r.quorum < n).count()
    }

    /// Best held-out accuracy observed (periodic evals + final).
    pub fn best_accuracy(&self) -> Option<f64> {
        let peri = self
            .history
            .iter()
            .filter_map(|r| r.eval.as_ref().and_then(|e| e.accuracy));
        let fin = self.final_eval.as_ref().and_then(|e| e.accuracy);
        peri.chain(fin).fold(None, |acc, a| Some(acc.map_or(a, |m: f64| m.max(a))))
    }

    /// Mean train loss over the last `k` steps (plateau estimate).
    pub fn tail_loss(&self, k: usize) -> f64 {
        let n = self.history.len();
        let take = k.min(n).max(1);
        let s: f64 = self.history[n - take..].iter().map(|r| r.train_loss).sum();
        s / take as f64
    }

    /// Per-iteration communication bits per parameter *per worker* (both
    /// directions) — the x-axis of Figure 4. The paper normalizes this
    /// way: G-Lion/G-AdamW sit at 64 (= 32 up + 32 down). Worker-edge
    /// hops only: the aggregator↔root links have their own totals
    /// ([`Self::total_agg_uplink`]) because they are per *group*, not
    /// per worker.
    pub fn bits_per_param_per_iter(&self, dim: usize) -> f64 {
        if self.history.is_empty() {
            return 0.0;
        }
        let per_iter =
            (self.total_uplink() + self.total_downlink()) as f64 / self.history.len() as f64;
        per_iter * 8.0 / dim as f64 / self.nworkers.max(1) as f64
    }

    /// Dump the history as CSV.
    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut w = CsvWriter::create(
            path,
            &[
                "step",
                "lr",
                "train_loss",
                "eval_loss",
                "eval_acc",
                "uplink_bytes",
                "downlink_bytes",
                "agg_uplink_bytes",
                "agg_downlink_bytes",
                "agg_uplink_msgs",
                "agg_downlink_msgs",
                "quorum",
            ],
        )?;
        for r in &self.history {
            let (el, ea) = match &r.eval {
                Some(e) => (
                    format!("{:.6}", e.loss),
                    e.accuracy.map_or(String::new(), |a| format!("{a:.6}")),
                ),
                None => (String::new(), String::new()),
            };
            w.row(&[
                r.step.to_string(),
                format!("{:.8}", r.lr),
                format!("{:.6}", r.train_loss),
                el,
                ea,
                r.uplink_bytes.to_string(),
                r.downlink_bytes.to_string(),
                r.agg_uplink_bytes.to_string(),
                r.agg_downlink_bytes.to_string(),
                r.agg_uplink_msgs.to_string(),
                r.agg_downlink_msgs.to_string(),
                r.quorum.to_string(),
            ])?;
        }
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize) -> RunResult {
        let mut r = RunResult::new("t".into(), "s".into(), 4);
        for step in 0..n {
            r.push(StepRecord {
                step,
                lr: 0.1,
                train_loss: 1.0 / (step + 1) as f64,
                eval: if step % 2 == 0 {
                    Some(Eval { loss: 0.5, accuracy: Some(0.1 * step as f64) })
                } else {
                    None
                },
                uplink_bytes: 100,
                downlink_bytes: 50,
                agg_uplink_bytes: 25,
                agg_downlink_bytes: 10,
                agg_uplink_msgs: 2,
                agg_downlink_msgs: 2,
                quorum: if step == 1 { 3 } else { 4 },
            });
        }
        r
    }

    #[test]
    fn aggregates() {
        let r = mk(10);
        assert_eq!(r.total_uplink(), 1000);
        assert_eq!(r.total_downlink(), 500);
        assert_eq!(r.total_agg_uplink(), 250);
        assert_eq!(r.total_agg_downlink(), 100);
        assert_eq!(r.total_agg_uplink_msgs(), 20);
        assert_eq!(r.total_agg_downlink_msgs(), 20);
        assert_eq!(r.min_quorum(), Some(3));
        assert_eq!(r.partial_rounds(), 1);
        assert!((r.best_accuracy().unwrap() - 0.8).abs() < 1e-12);
        assert!(r.tail_loss(3) < r.tail_loss(10));
        // 150 bytes/iter over dim 100, 4 workers -> 3 bits/param/worker
        assert!((r.bits_per_param_per_iter(100) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn csv_roundtrip_lines() {
        let r = mk(4);
        let path = std::env::temp_dir().join(format!("dlion_hist_{}.csv", std::process::id()));
        r.write_csv(&path).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content.lines().count(), 5); // header + 4
        std::fs::remove_file(&path).ok();
    }
}
