//! Cluster runtime: the leader/worker training loop (paper Algorithm 1's
//! outer `while not convergent` loop, for any [`Strategy`]).
//!
//! Two execution modes with identical semantics:
//! * [`run_sequential`] — single-thread round loop; fastest on this
//!   1-core box, used by the sweep benches (thousands of runs).
//! * [`run_threaded`] — one OS thread per worker plus a server loop over
//!   a byte-counted [`crate::comm`] fabric (in-proc channels); proves the
//!   message protocol end-to-end and feeds the transport byte counters.
//!
//! Neither driver owns the round choreography: both hand the gathered
//! uplinks to one shared [`topology::RoundEngine`], which routes them
//! through the configured [`topology::Topology`] (flat star, or a
//! two-level worker → group-aggregator → root tree) at the strategy's
//! communication cadence ([`Strategy::local_steps`]) and returns per-hop
//! byte accounting. That is what keeps the two modes bit-exact in
//! parameters *and* in the full per-hop byte history.
//!
//! Both assert the replicated-parameter invariant at every **sync
//! point**: every worker holds bit-identical parameters after every
//! communication round (the downlink broadcast is the only global
//! mutation). Local-steps strategies explore independently between sync
//! points and reconcile at the next round.

pub mod chaos;
pub mod metrics;
pub mod topology;

use crate::comm::{inproc_fabric, CommStats, ServerTransport, WorkerTransport};
use crate::optim::dist::Strategy;
use crate::tasks::{Eval, GradTask};
use crate::util::math::cosine_lr;
use crate::util::Rng;
use metrics::{RunResult, StepRecord};
use std::sync::Arc;
use topology::{HopBytes, RoundEngine, Topology};

/// Training-loop configuration (defaults mirror the paper's CIFAR setup:
/// batch 32/worker, cosine schedule, flat star).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub batch_per_worker: usize,
    pub base_lr: f64,
    pub warmup_steps: usize,
    pub min_lr_frac: f64,
    /// evaluate every `eval_every` steps (0 = only at the end)
    pub eval_every: usize,
    pub seed: u64,
    /// verify the replicated-parameter invariant at every sync point
    /// (costly for big d; always on in tests)
    pub check_replicas: bool,
    /// communication layout (config syntax: `star` / `hier:<group_size>`)
    pub topology: Topology,
    /// wire chunk size in parameters (TOML `hyper.chunk_size`; 0 =
    /// whole-model frames). Strategies with a native chunked codec
    /// split every message into `ceil(dim / chunk_size)` per-chunk
    /// frames — bit-exact and byte-identical to the monolithic path —
    /// and the round engine processes the chunks in parallel on large
    /// models; monolithic strategies ignore it.
    pub chunk_size: usize,
    /// Elastic-round quorum floor (TOML `hyper.quorum`; 0 = all
    /// workers). Only the chaos/elastic driver ([`chaos::run_chaos`])
    /// closes rounds early; the lockstep drivers ignore it.
    pub quorum: usize,
    /// Elastic-round gather deadline in milliseconds (TOML
    /// `hyper.round_deadline_ms`; 0 = block forever).
    pub round_deadline_ms: u64,
    /// Broadcast rounds the TCP server retains for reconnect replay
    /// (TOML `hyper.replay_ring`). The single source of truth for both
    /// ends of the reconnect handshake: the server's ring length and
    /// the worker's hostile-count clamp are handed this same value. A
    /// rejoin gap beyond the ring must restore from a checkpoint first
    /// ([`chaos::CatchUpPath::Checkpoint`]), and the chaos driver saves
    /// server-side checkpoints every `replay_ring` rounds when a plan
    /// needs them.
    pub replay_ring: usize,
}

impl TrainConfig {
    /// The [`topology::QuorumPolicy`] this config describes.
    pub fn quorum_policy(&self) -> topology::QuorumPolicy {
        topology::QuorumPolicy { min_workers: self.quorum, deadline_ms: self.round_deadline_ms }
    }
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 500,
            batch_per_worker: 32,
            base_lr: 1e-3,
            warmup_steps: 0,
            min_lr_frac: 0.0,
            eval_every: 100,
            seed: 42,
            check_replicas: false,
            topology: Topology::Star,
            chunk_size: 0,
            quorum: 0,
            round_deadline_ms: 0,
            replay_ring: crate::comm::tcp::DEFAULT_REPLAY_RING,
        }
    }
}

/// Run the synchronous training loop in-process (no threads).
pub fn run_sequential(
    task: &dyn GradTask,
    strategy: &dyn Strategy,
    nworkers: usize,
    cfg: &TrainConfig,
) -> RunResult {
    let d = task.dim();
    let mut engine = RoundEngine::new(strategy, nworkers, d, cfg.topology, cfg.chunk_size);
    let mut root = Rng::new(cfg.seed);
    let params0 = task.init_params(&mut root);
    let mut params: Vec<Vec<f32>> = vec![params0; nworkers];
    let mut worker_rngs: Vec<Rng> = (0..nworkers).map(|i| root.fork(i as u64)).collect();
    let mut workers: Vec<_> = (0..nworkers).map(|i| strategy.make_worker(i, nworkers, d)).collect();
    let mut grads: Vec<Vec<f32>> = vec![vec![0.0; d]; nworkers];
    let mut result = RunResult::new(task.name(), strategy.name(), nworkers);
    let t0 = std::time::Instant::now();
    for step in 0..cfg.steps {
        let lr = cosine_lr(step, cfg.steps, cfg.warmup_steps, cfg.base_lr, cfg.min_lr_frac) as f32;
        let mut train_loss = 0.0f64;
        for (w, ((g, p), r)) in
            grads.iter_mut().zip(&params).zip(worker_rngs.iter_mut()).enumerate()
        {
            train_loss +=
                task.minibatch_grad_worker(p, r, cfg.batch_per_worker, g, w, nworkers) as f64;
        }
        train_loss /= nworkers as f64;
        let sync = engine.is_sync_step(step);
        let hops = if sync {
            let uplinks = engine.encode_all(&mut workers, &grads, lr, step);
            let (downlink, hops) = engine.aggregate(&uplinks, lr, step);
            engine.apply_all(&mut workers, &mut params, &downlink, lr, step);
            // hand the round buffers back so the next sync step's
            // envelopes reuse their allocations
            engine.recycle_uplinks(uplinks);
            if cfg.check_replicas {
                for w in 1..nworkers {
                    assert_eq!(params[0], params[w], "replica divergence at sync step {step}");
                }
            }
            hops
        } else {
            // local phase: no bytes move; replicas explore independently
            for ((w, p), g) in workers.iter_mut().zip(params.iter_mut()).zip(&grads) {
                w.local_step(p, g, lr, step);
            }
            HopBytes::default()
        };
        let eval = if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            Some(task.evaluate(&params[0]))
        } else {
            None
        };
        result.push(StepRecord {
            step,
            lr: lr as f64,
            train_loss,
            eval,
            uplink_bytes: hops.uplink as u64,
            downlink_bytes: hops.downlink as u64,
            agg_uplink_bytes: hops.agg_uplink as u64,
            agg_downlink_bytes: hops.agg_downlink as u64,
            agg_uplink_msgs: hops.agg_uplink_msgs as u64,
            agg_downlink_msgs: hops.agg_downlink_msgs as u64,
            // lockstep: every sync round aggregates the full cluster
            quorum: if sync { nworkers as u64 } else { 0 },
        });
    }
    result.final_eval = Some(task.evaluate(&params[0]));
    result.wall_secs = t0.elapsed().as_secs_f64();
    result.final_params = Some(params.swap_remove(0));
    result
}

/// Run the same loop with one OS thread per worker over the in-process
/// byte-counted fabric. Returns the result plus the transport stats.
///
/// The worker-edge hops move over real channels (the fabric counts
/// them); the aggregator↔root hops of a hierarchical topology are
/// engine-simulated in the server thread and recorded on the same
/// [`CommStats`], so the per-hop accounting equals the sequential
/// driver's exactly.
pub fn run_threaded(
    task: Arc<dyn GradTask + Send + Sync>,
    strategy: &dyn Strategy,
    nworkers: usize,
    cfg: &TrainConfig,
) -> (RunResult, Arc<CommStats>) {
    let d = task.dim();
    let local_steps = strategy.local_steps().max(1);
    // the same deterministic plan the engine derives — workers and
    // engine can never disagree about the wire geometry
    let plan = strategy.plan(d, cfg.chunk_size);
    let stats = CommStats::new();
    let (mut server_tx, worker_txs) = inproc_fabric(nworkers, stats.clone());
    let mut root = Rng::new(cfg.seed);
    let params0 = task.init_params(&mut root);
    let worker_rngs: Vec<Rng> = (0..nworkers).map(|i| root.fork(i as u64)).collect();
    // metrics side-channels (not counted as training communication)
    let (loss_tx, loss_rx) = std::sync::mpsc::channel::<(usize, f64)>();
    let (eval_tx, eval_rx) = std::sync::mpsc::channel::<(usize, Eval)>();

    let handles: Vec<_> = worker_txs
        .into_iter()
        .zip(worker_rngs)
        .map(|(mut wt, mut rng)| {
            let task = task.clone();
            let mut logic = strategy.make_worker(wt.worker_id(), nworkers, d);
            let mut params = params0.clone();
            let cfg = cfg.clone();
            let loss_tx = loss_tx.clone();
            let eval_tx = eval_tx.clone();
            std::thread::spawn(move || -> std::io::Result<Vec<f32>> {
                let mut grad = vec![0.0f32; d];
                for step in 0..cfg.steps {
                    let lr = cosine_lr(
                        step,
                        cfg.steps,
                        cfg.warmup_steps,
                        cfg.base_lr,
                        cfg.min_lr_frac,
                    ) as f32;
                    let wid = wt.worker_id();
                    let loss = task.minibatch_grad_worker(
                        &params,
                        &mut rng,
                        cfg.batch_per_worker,
                        &mut grad,
                        wid,
                        nworkers,
                    );
                    let _ = loss_tx.send((step, loss as f64));
                    if (step + 1) % local_steps == 0 {
                        let uplink = logic.encode_planned(&grad, &plan, lr, step);
                        wt.send(uplink)?;
                        let downlink = wt.recv()?;
                        logic.apply_planned(&mut params, &downlink, &plan, lr, step);
                    } else {
                        logic.local_step(&mut params, &grad, lr, step);
                    }
                    // Periodic eval on worker 0's replica — the same
                    // post-step point the sequential driver evaluates,
                    // so the two modes' histories agree record-for-record.
                    if wid == 0 && cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
                        let _ = eval_tx.send((step, task.evaluate(&params)));
                    }
                }
                Ok(params)
            })
        })
        .collect();
    drop(loss_tx);
    drop(eval_tx);

    // Server loop on the current thread. Per-step worker-edge bytes are
    // CommStats deltas taken around the round: after `gather` returns,
    // every step-`s` uplink has been recorded and no step-`s+1` uplink
    // can exist (workers block on the downlink); after `broadcast`
    // returns, all step-`s` downlink bytes are recorded — so the deltas
    // are race-free and equal the sequential-mode accounting exactly.
    // Aggregator-hop bytes come straight from the engine (they never
    // race: the engine runs on this thread).
    let mut engine = RoundEngine::new(strategy, nworkers, d, cfg.topology, cfg.chunk_size);
    let mut step_bytes: Vec<(u64, u64, HopBytes)> = Vec::with_capacity(cfg.steps);
    let (mut prev_up, mut prev_down) = (0u64, 0u64);
    let t0 = std::time::Instant::now();
    for step in 0..cfg.steps {
        if !engine.is_sync_step(step) {
            step_bytes.push((0, 0, HopBytes::default()));
            continue;
        }
        let lr = cosine_lr(step, cfg.steps, cfg.warmup_steps, cfg.base_lr, cfg.min_lr_frac) as f32;
        let uplinks = server_tx.gather().expect("gather failed");
        let up_now = stats.uplink();
        let (downlink, hops) = engine.aggregate(&uplinks, lr, step);
        stats.record_agg_uplink(hops.agg_uplink, hops.agg_uplink_msgs);
        stats.record_agg_downlink(hops.agg_downlink, hops.agg_downlink_msgs);
        server_tx.broadcast(&downlink).expect("broadcast failed");
        let down_now = stats.downlink();
        step_bytes.push((up_now - prev_up, down_now - prev_down, hops));
        prev_up = up_now;
        prev_down = down_now;
    }

    let mut result = RunResult::new(task.name(), strategy.name(), nworkers);
    // collect losses per step (mean over workers)
    let mut per_step = vec![(0.0f64, 0usize); cfg.steps];
    for (step, loss) in loss_rx.iter() {
        per_step[step].0 += loss;
        per_step[step].1 += 1;
    }
    for (step, (sum, count)) in per_step.into_iter().enumerate() {
        let (uplink_bytes, downlink_bytes, hops) = step_bytes[step];
        // round through f32 exactly as the sequential recorder does, so
        // the two modes' histories stay comparable field-for-field
        let lr = cosine_lr(step, cfg.steps, cfg.warmup_steps, cfg.base_lr, cfg.min_lr_frac) as f32;
        result.push(StepRecord {
            step,
            lr: lr as f64,
            train_loss: sum / count.max(1) as f64,
            eval: None,
            uplink_bytes,
            downlink_bytes,
            agg_uplink_bytes: hops.agg_uplink as u64,
            agg_downlink_bytes: hops.agg_downlink as u64,
            agg_uplink_msgs: hops.agg_uplink_msgs as u64,
            agg_downlink_msgs: hops.agg_downlink_msgs as u64,
            quorum: if (step + 1) % local_steps == 0 { nworkers as u64 } else { 0 },
        });
    }
    // merge worker-0's periodic evals into the per-step history
    for (step, eval) in eval_rx.iter() {
        result.history[step].eval = Some(eval);
    }
    let mut final_params: Vec<Vec<f32>> = Vec::new();
    for h in handles {
        final_params.push(h.join().expect("worker panicked").expect("worker io error"));
    }
    // the replica invariant holds at sync points; the final join is one
    // only when the run ended on a sync boundary
    if cfg.check_replicas && cfg.steps % local_steps == 0 {
        for w in 1..nworkers {
            assert_eq!(final_params[0], final_params[w], "replica divergence (threaded)");
        }
    }
    result.final_eval = Some(task.evaluate(&final_params[0]));
    result.wall_secs = t0.elapsed().as_secs_f64();
    result.final_params = Some(final_params.swap_remove(0));
    (result, stats)
}

/// Convenience: final evaluation of a sequential run.
pub fn final_eval(
    task: &dyn GradTask,
    strategy: &dyn Strategy,
    nworkers: usize,
    cfg: &TrainConfig,
) -> Eval {
    run_sequential(task, strategy, nworkers, cfg).final_eval.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::dist::{by_name, StrategyHyper};
    use crate::tasks::quadratic::Quadratic;

    fn quick_cfg(steps: usize) -> TrainConfig {
        TrainConfig {
            steps,
            batch_per_worker: 8,
            base_lr: 0.01,
            eval_every: 0,
            seed: 7,
            check_replicas: true,
            ..Default::default()
        }
    }

    #[test]
    fn sequential_and_threaded_agree_bit_exactly() {
        // Same seed => same worker batches => identical trajectories for a
        // deterministic strategy (d-lion-mavo has no strategy-side rng).
        let task = Quadratic::new(64, 10.0, 0.5, 3);
        let hp = StrategyHyper::default();
        let strat = by_name("d-lion-mavo", &hp).unwrap();
        let cfg = quick_cfg(50);
        let seq = run_sequential(&task, strat.as_ref(), 4, &cfg);
        let task_arc: Arc<dyn GradTask + Send + Sync> = Arc::new(Quadratic::new(64, 10.0, 0.5, 3));
        let (thr, stats) = run_threaded(task_arc, strat.as_ref(), 4, &cfg);
        assert_eq!(seq.final_params, thr.final_params);
        // byte accounting: threaded CommStats must equal sequential sums
        let seq_up: u64 = seq.history.iter().map(|r| r.uplink_bytes).sum();
        let seq_down: u64 = seq.history.iter().map(|r| r.downlink_bytes).sum();
        assert_eq!(stats.uplink(), seq_up);
        assert_eq!(stats.downlink(), seq_down);
        // flat star: no aggregator hops on either driver
        assert_eq!(stats.agg_uplink(), 0);
        assert_eq!(stats.agg_downlink(), 0);
        assert_eq!(seq.total_agg_uplink(), 0);
        // ...and per-step histories must agree, not just the totals
        assert_eq!(seq.history.len(), thr.history.len());
        for (s, t) in seq.history.iter().zip(&thr.history) {
            assert_eq!(s.uplink_bytes, t.uplink_bytes, "step {} uplink", s.step);
            assert_eq!(s.downlink_bytes, t.downlink_bytes, "step {} downlink", s.step);
            assert_eq!(s.agg_uplink_bytes, t.agg_uplink_bytes, "step {} agg up", s.step);
            assert_eq!(s.agg_downlink_bytes, t.agg_downlink_bytes, "step {} agg down", s.step);
        }
    }

    #[test]
    fn threaded_periodic_eval_matches_sequential() {
        // The threaded driver must honor eval_every with the same cadence
        // and the same post-apply evaluation point as the sequential one;
        // identical trajectories => identical eval records.
        let cfg = TrainConfig { eval_every: 10, ..quick_cfg(35) };
        let task = Quadratic::new(48, 8.0, 0.4, 11);
        let strat = by_name("d-lion-mavo", &StrategyHyper::default()).unwrap();
        let seq = run_sequential(&task, strat.as_ref(), 3, &cfg);
        let task_arc: Arc<dyn GradTask + Send + Sync> = Arc::new(Quadratic::new(48, 8.0, 0.4, 11));
        let (thr, _) = run_threaded(task_arc, strat.as_ref(), 3, &cfg);
        let seq_evals: Vec<(usize, f64)> = seq
            .history
            .iter()
            .filter_map(|r| r.eval.as_ref().map(|e| (r.step, e.loss)))
            .collect();
        let thr_evals: Vec<(usize, f64)> = thr
            .history
            .iter()
            .filter_map(|r| r.eval.as_ref().map(|e| (r.step, e.loss)))
            .collect();
        assert_eq!(seq_evals.len(), 3, "steps 9, 19, 29");
        assert_eq!(seq_evals, thr_evals, "threaded eval cadence/values diverged");
    }

    #[test]
    fn chunked_sequential_and_threaded_agree_bit_exactly() {
        // chunk_size 7 → two 40-aligned chunks at d=64: both drivers
        // must stay bit-exact with each other *and* with the
        // whole-model run, and the payload accounting must not move.
        let task = Quadratic::new(64, 10.0, 0.5, 3);
        let hp = StrategyHyper::default();
        let strat = by_name("d-lion-mavo", &hp).unwrap();
        let mono = run_sequential(&task, strat.as_ref(), 4, &quick_cfg(40));
        let cfg = TrainConfig { chunk_size: 7, ..quick_cfg(40) };
        let seq = run_sequential(&task, strat.as_ref(), 4, &cfg);
        assert_eq!(seq.final_params, mono.final_params, "chunking changed the math");
        assert_eq!(seq.total_uplink(), mono.total_uplink());
        assert_eq!(seq.total_downlink(), mono.total_downlink());
        let task_arc: Arc<dyn GradTask + Send + Sync> = Arc::new(Quadratic::new(64, 10.0, 0.5, 3));
        let (thr, stats) = run_threaded(task_arc, strat.as_ref(), 4, &cfg);
        assert_eq!(seq.final_params, thr.final_params);
        assert_eq!(stats.uplink(), seq.total_uplink(), "transport counts payload bytes");
        assert_eq!(stats.downlink(), seq.total_downlink());
        for (s, t) in seq.history.iter().zip(&thr.history) {
            assert_eq!(s.uplink_bytes, t.uplink_bytes, "step {} uplink", s.step);
            assert_eq!(s.downlink_bytes, t.downlink_bytes, "step {} downlink", s.step);
        }
    }

    #[test]
    fn all_strategies_run_and_reduce_loss() {
        let task = Quadratic::new(32, 5.0, 0.3, 5);
        let hp = StrategyHyper { weight_decay: 0.001, ..Default::default() };
        for &name in crate::optim::dist::ALL_STRATEGIES
            .iter()
            .chain(crate::optim::dist::EXTENSION_STRATEGIES.iter())
        {
            let strat = by_name(name, &hp).unwrap();
            let lr = if name.starts_with("g-adamw") || name.starts_with("g-sgd") {
                0.05
            } else {
                0.02
            };
            // DGC warms up sparsity over its first 200 steps and clips
            // aggressively, so give every method the same longer horizon.
            let cfg = TrainConfig { base_lr: lr, ..quick_cfg(700) };
            let res = run_sequential(&task, strat.as_ref(), 4, &cfg);
            let init_loss = task.evaluate(&task.init_params(&mut Rng::new(cfg.seed))).loss;
            let fin = res.final_eval.unwrap().loss;
            assert!(
                fin < init_loss * 0.5,
                "{name}: final={fin} init={init_loss}"
            );
        }
    }

    #[test]
    fn hierarchical_topology_runs_every_strategy() {
        // The relay/vote/dense-sum partial paths must keep every
        // registry strategy training (and its replicas identical at
        // sync points) under a two-group tree.
        let task = Quadratic::new(24, 5.0, 0.3, 6);
        let hp = StrategyHyper { weight_decay: 0.001, ..Default::default() };
        for &name in crate::optim::dist::ALL_STRATEGIES
            .iter()
            .chain(crate::optim::dist::EXTENSION_STRATEGIES.iter())
        {
            let strat = by_name(name, &hp).unwrap();
            let cfg = TrainConfig {
                topology: Topology::Hierarchical { group_size: 2 },
                base_lr: 0.02,
                ..quick_cfg(40)
            };
            let res = run_sequential(&task, strat.as_ref(), 4, &cfg);
            assert!(res.total_agg_uplink() > 0, "{name}: no aggregator-hop bytes");
            assert!(res.total_agg_downlink() > 0, "{name}: no root-broadcast bytes");
        }
    }

    #[test]
    fn local_steps_move_zero_bytes_between_syncs() {
        let task = Quadratic::new(40, 5.0, 0.3, 8);
        let strat = by_name("d-lion-local(4)", &StrategyHyper::default()).unwrap();
        let cfg = quick_cfg(20);
        let res = run_sequential(&task, strat.as_ref(), 3, &cfg);
        for r in &res.history {
            if (r.step + 1) % 4 == 0 {
                assert!(r.uplink_bytes > 0 && r.downlink_bytes > 0, "sync step {}", r.step);
            } else {
                assert_eq!(r.uplink_bytes, 0, "local step {} moved bytes", r.step);
                assert_eq!(r.downlink_bytes, 0, "local step {} moved bytes", r.step);
            }
        }
        // amortized: exactly steps/4 sync rounds
        let sync_rounds = res.history.iter().filter(|r| r.uplink_bytes > 0).count();
        assert_eq!(sync_rounds, 5);
    }

    #[test]
    fn lr_schedule_is_logged() {
        let task = Quadratic::new(8, 1.0, 0.1, 1);
        let strat = by_name("d-lion-avg", &StrategyHyper::default()).unwrap();
        let cfg = TrainConfig {
            warmup_steps: 5,
            min_lr_frac: 0.1,
            ..quick_cfg(20)
        };
        let res = run_sequential(&task, strat.as_ref(), 2, &cfg);
        assert!(res.history[0].lr < res.history[5].lr);
        assert!(res.history[19].lr < res.history[5].lr);
    }
}
