//! Topology-aware round engine: *who* the sign frames flow through and
//! *when* they flow.
//!
//! The paper's Algorithm 1 hard-wires a flat star — every worker talks
//! to one server, every step. The two strongest follow-ups change the
//! routing and the cadence, not the frames: Lion Cub's hierarchical /
//! bandwidth-structured aggregation, and local-steps sign momentum
//! (ship one frame per H optimizer steps). This module factors both out
//! of the cluster drivers:
//!
//! * [`Topology`] — [`Topology::Star`] (the paper's layout) or
//!   [`Topology::Hierarchical`] with a group size: workers send to a
//!   group aggregator, aggregators send one *partial* frame to the
//!   root, and the broadcast retraces the tree downward. Partials come
//!   from [`ServerLogic::partial`]/[`ServerLogic::fold`]: the sign-vote
//!   family ships `intavg` vote sums (integer — **bit-exact vs the
//!   flat star for any grouping**), the dense family ships f32 partial
//!   sums (the same numbers regrouped; bit-exact for one group, and
//!   within f32 summation-order ulps of the flat star beyond that),
//!   and every other codec falls back to a relay frame (members
//!   forwarded verbatim — bit-exact for any grouping).
//! * [`RoundEngine`] — the shared choreography both
//!   [`crate::cluster::run_sequential`] and
//!   [`crate::cluster::run_threaded`] drive: it owns the group and root
//!   [`ServerLogic`] instances, knows the communication cadence
//!   ([`Strategy::local_steps`]), and returns per-hop byte accounting
//!   ([`HopBytes`]) so the Table-1 byte bookkeeping extends to every
//!   link of the tree.
//!
//! Invariants (tested in `tests/topology_parity.rs`):
//! * `Hierarchical { group_size ≥ nworkers }` is bit-identical to the
//!   flat star in parameters and worker-edge bytes (every family).
//! * For the sign-vote family and for relayed codecs, *any* grouping is
//!   trajectory-identical to the flat star; the dense family's
//!   multi-group fold regroups an f32 sum and may differ from the star
//!   in the last ulp (never between the two drivers).
//! * Sequential and threaded drivers agree bit-exactly on parameters
//!   and on the full per-hop byte history, for every topology.

use crate::error::{DlionError, Result};
use crate::optim::dist::{ServerLogic, Strategy};
use std::fmt;
use std::ops::Range;

/// Cluster communication layout.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Topology {
    /// Every worker uplinks straight to the single server (Algorithm 1).
    #[default]
    Star,
    /// Two-level tree: workers 0..g-1 share aggregator 0, workers
    /// g..2g-1 share aggregator 1, … (the last group may be smaller);
    /// aggregators fold their group and forward one partial to the root.
    Hierarchical {
        /// workers per group aggregator (≥ 1).
        group_size: usize,
    },
}

impl Topology {
    /// Parse the config syntax: `"star"` or `"hier:<group_size>"`.
    pub fn parse(s: &str) -> Result<Topology> {
        let s = s.trim();
        if s == "star" {
            return Ok(Topology::Star);
        }
        if let Some(gs) = s.strip_prefix("hier:") {
            let group_size: usize = gs.parse().map_err(|_| {
                DlionError::Config(format!(
                    "topology 'hier:<group_size>' needs an integer, got '{gs}'"
                ))
            })?;
            if group_size == 0 {
                return Err(DlionError::Config("topology group_size must be >= 1".into()));
            }
            return Ok(Topology::Hierarchical { group_size });
        }
        Err(DlionError::Config(format!(
            "unknown topology '{s}' (expected 'star' or 'hier:<group_size>')"
        )))
    }

    /// Contiguous worker ranges per group aggregator (one `0..n` range
    /// for the star, where the "aggregator" is the root itself).
    pub fn groups(&self, nworkers: usize) -> Vec<Range<usize>> {
        match *self {
            Topology::Star => vec![0..nworkers],
            Topology::Hierarchical { group_size } => {
                assert!(group_size >= 1, "group_size must be >= 1");
                let mut out = Vec::with_capacity(nworkers.div_ceil(group_size));
                let mut start = 0;
                while start < nworkers {
                    let end = (start + group_size).min(nworkers);
                    out.push(start..end);
                    start = end;
                }
                out
            }
        }
    }
}

impl fmt::Display for Topology {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Topology::Star => write!(f, "star"),
            Topology::Hierarchical { group_size } => write!(f, "hier:{group_size}"),
        }
    }
}

/// Per-hop byte accounting for one communication round. Worker-edge
/// hops (`uplink`/`downlink`) are what Table 1 counts; the aggregator
/// hops are zero for the flat star.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HopBytes {
    /// worker → aggregator (star: worker → server), summed over workers
    pub uplink: usize,
    /// aggregator → root, summed over groups (0 for the star)
    pub agg_uplink: usize,
    /// root → aggregator, broadcast × groups (0 for the star)
    pub agg_downlink: usize,
    /// aggregator → worker (star: server → worker), broadcast × workers
    pub downlink: usize,
}

/// The round choreography shared by the sequential and threaded cluster
/// drivers: routes the gathered worker uplinks through the configured
/// [`Topology`] and returns the broadcast downlink plus the per-hop
/// byte counts.
pub struct RoundEngine {
    groups: Vec<Range<usize>>,
    /// one `ServerLogic` per group aggregator (empty for the star)
    group_servers: Vec<Box<dyn ServerLogic>>,
    root: Box<dyn ServerLogic>,
    nworkers: usize,
    local_steps: usize,
}

impl RoundEngine {
    /// Build the engine for `strategy` over `nworkers` workers of a
    /// `dim`-parameter model. The communication cadence comes from the
    /// strategy itself ([`Strategy::local_steps`]), so the engine and
    /// the worker logic can never disagree about which steps sync.
    pub fn new(
        strategy: &dyn Strategy,
        nworkers: usize,
        dim: usize,
        topology: Topology,
    ) -> RoundEngine {
        let local_steps = strategy.local_steps().max(1);
        let (groups, group_servers) = match topology {
            Topology::Star => (topology.groups(nworkers), Vec::new()),
            Topology::Hierarchical { .. } => {
                let groups = topology.groups(nworkers);
                let servers: Vec<_> =
                    groups.iter().map(|g| strategy.make_server(g.len(), dim)).collect();
                (groups, servers)
            }
        };
        RoundEngine {
            groups,
            group_servers,
            root: strategy.make_server(nworkers, dim),
            nworkers,
            local_steps,
        }
    }

    /// Communication cadence: a frame crosses the wire every
    /// `local_steps`-th step (1 = every step, Algorithm 1).
    pub fn local_steps(&self) -> usize {
        self.local_steps
    }

    /// Is `step` a communication (sync) step? Sync steps are those with
    /// `(step + 1) % local_steps == 0`, matching the msync convention.
    pub fn is_sync_step(&self, step: usize) -> bool {
        (step + 1) % self.local_steps == 0
    }

    /// Route one round: fold the index-aligned worker uplinks through
    /// the topology into the broadcast downlink. Returns the downlink
    /// frame (identical for every worker — the replicated-parameter
    /// invariant rides on this) and the per-hop byte accounting.
    pub fn aggregate(&mut self, uplinks: &[Vec<u8>], lr: f32, step: usize) -> (Vec<u8>, HopBytes) {
        assert_eq!(uplinks.len(), self.nworkers, "uplink count mismatch");
        let uplink_bytes: usize = uplinks.iter().map(|m| m.len()).sum();
        if self.group_servers.is_empty() {
            // Flat star: the root aggregates all workers directly.
            let downlink = self.root.aggregate(uplinks, lr, step);
            let hops = HopBytes {
                uplink: uplink_bytes,
                agg_uplink: 0,
                agg_downlink: 0,
                downlink: downlink.len() * self.nworkers,
            };
            return (downlink, hops);
        }
        // Two-level: group partials up, root fold, broadcast retraces
        // the tree (root → G aggregators → nworkers workers).
        let partials: Vec<Vec<u8>> = self
            .group_servers
            .iter_mut()
            .zip(&self.groups)
            .map(|(gs, range)| gs.partial(&uplinks[range.clone()], lr, step))
            .collect();
        let agg_uplink: usize = partials.iter().map(|m| m.len()).sum();
        let downlink = self.root.fold(&partials, lr, step);
        let hops = HopBytes {
            uplink: uplink_bytes,
            agg_uplink,
            agg_downlink: downlink.len() * self.groups.len(),
            downlink: downlink.len() * self.nworkers,
        };
        (downlink, hops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::dist::{by_name, StrategyHyper};
    use crate::util::Rng;

    #[test]
    fn parse_and_display_round_trip() {
        assert_eq!(Topology::parse("star").unwrap(), Topology::Star);
        assert_eq!(
            Topology::parse("hier:4").unwrap(),
            Topology::Hierarchical { group_size: 4 }
        );
        for t in [Topology::Star, Topology::Hierarchical { group_size: 7 }] {
            assert_eq!(Topology::parse(&t.to_string()).unwrap(), t);
        }
        assert!(Topology::parse("hier:0").is_err());
        assert!(Topology::parse("hier:x").is_err());
        assert!(Topology::parse("ring").is_err());
    }

    #[test]
    fn groups_cover_workers_exactly() {
        let t = Topology::Hierarchical { group_size: 3 };
        assert_eq!(t.groups(7), vec![0..3, 3..6, 6..7]);
        assert_eq!(t.groups(3), vec![0..3]);
        assert_eq!(Topology::Star.groups(5), vec![0..5]);
        // group_size beyond nworkers degenerates to one group
        let t = Topology::Hierarchical { group_size: 99 };
        assert_eq!(t.groups(4), vec![0..4]);
    }

    #[test]
    fn engine_star_matches_run_round_accounting() {
        let (n, d) = (4, 129);
        let hp = StrategyHyper::default();
        let strat = by_name("d-lion-mavo", &hp).unwrap();
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut engine = RoundEngine::new(strat.as_ref(), n, d, Topology::Star);
        let mut rng = Rng::new(0x70);
        let ups: Vec<Vec<u8>> = workers
            .iter_mut()
            .map(|w| {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                w.encode(&g, 1e-3, 0)
            })
            .collect();
        let (down, hops) = engine.aggregate(&ups, 1e-3, 0);
        assert_eq!(hops.uplink, ups.iter().map(|m| m.len()).sum::<usize>());
        assert_eq!(hops.downlink, down.len() * n);
        assert_eq!(hops.agg_uplink, 0);
        assert_eq!(hops.agg_downlink, 0);
    }

    #[test]
    fn hierarchical_vote_partials_are_exact() {
        // Any grouping of the sign-vote family must produce the very
        // same downlink bytes as the flat star (integer sums regroup).
        let (n, d) = (6, 200);
        let hp = StrategyHyper::default();
        let mut rng = Rng::new(0x71);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                g
            })
            .collect();
        let frames = |topology: Topology| -> Vec<u8> {
            let strat = by_name("d-lion-mavo", &hp).unwrap();
            let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
            let mut engine = RoundEngine::new(strat.as_ref(), n, d, topology);
            let ups: Vec<Vec<u8>> = workers
                .iter_mut()
                .zip(&grads)
                .map(|(w, g)| w.encode(g, 1e-3, 0))
                .collect();
            engine.aggregate(&ups, 1e-3, 0).0
        };
        let flat = frames(Topology::Star);
        for gs in [1usize, 2, 3, 4, 6, 9] {
            assert_eq!(
                frames(Topology::Hierarchical { group_size: gs }),
                flat,
                "group_size={gs} changed the downlink"
            );
        }
    }

    #[test]
    fn hierarchical_agg_hop_is_cheaper_than_relaying_for_votes() {
        // The intavg vote partial must beat forwarding the member sign
        // frames verbatim once groups are large enough (log2(g+1) < g).
        let (n, d) = (8, 4096);
        let hp = StrategyHyper::default();
        let strat = by_name("d-lion-mavo", &hp).unwrap();
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut engine =
            RoundEngine::new(strat.as_ref(), n, d, Topology::Hierarchical { group_size: 4 });
        let mut rng = Rng::new(0x72);
        let ups: Vec<Vec<u8>> = workers
            .iter_mut()
            .map(|w| {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                w.encode(&g, 1e-3, 0)
            })
            .collect();
        let (_, hops) = engine.aggregate(&ups, 1e-3, 0);
        // 2 groups × (3-byte head + 3 bits/param) vs 8 × 1 bit/param
        assert!(hops.agg_uplink > 0);
        assert!(
            hops.agg_uplink < hops.uplink,
            "vote partials ({}) should be cheaper than the worker edge ({})",
            hops.agg_uplink,
            hops.uplink
        );
    }
}
