//! Topology-aware round engine: *who* the sign frames flow through and
//! *when* they flow.
//!
//! The paper's Algorithm 1 hard-wires a flat star — every worker talks
//! to one server, every step. The two strongest follow-ups change the
//! routing and the cadence, not the frames: Lion Cub's hierarchical /
//! bandwidth-structured aggregation, and local-steps sign momentum
//! (ship one frame per H optimizer steps). This module factors both out
//! of the cluster drivers:
//!
//! * [`Topology`] — [`Topology::Star`] (the paper's layout) or
//!   [`Topology::Hierarchical`] with a group size: workers send to a
//!   group aggregator, aggregators send one *partial* frame to the
//!   root, and the broadcast retraces the tree downward. Partials come
//!   from [`ServerLogic::partial`]/[`ServerLogic::fold`]: the sign-vote
//!   family ships `intavg` vote sums (integer — **bit-exact vs the
//!   flat star for any grouping**), the dense family ships f32 partial
//!   sums (the same numbers regrouped; bit-exact for one group, and
//!   within f32 summation-order ulps of the flat star beyond that),
//!   and every other codec falls back to a relay frame (members
//!   forwarded verbatim — bit-exact for any grouping).
//! * [`RoundEngine`] — the shared choreography both
//!   [`crate::cluster::run_sequential`] and
//!   [`crate::cluster::run_threaded`] drive: it owns the group and root
//!   [`ServerLogic`] instances, knows the communication cadence
//!   ([`Strategy::local_steps`]), and returns per-hop byte accounting
//!   ([`HopBytes`]) so the Table-1 byte bookkeeping extends to every
//!   link of the tree.
//!
//! Invariants (tested in `tests/topology_parity.rs`):
//! * `Hierarchical { group_size ≥ nworkers }` is bit-identical to the
//!   flat star in parameters and worker-edge bytes (every family).
//! * For the sign-vote family and for relayed codecs, *any* grouping is
//!   trajectory-identical to the flat star; the dense family's
//!   multi-group fold regroups an f32 sum and may differ from the star
//!   in the last ulp (never between the two drivers).
//! * Sequential and threaded drivers agree bit-exactly on parameters
//!   and on the full per-hop byte history, for every topology.

use crate::comm::chunked;
use crate::error::{DlionError, Result};
use crate::optim::dist::{
    sign_frame_lens, ChunkPlan, QuorumSupport, ServerLogic, SignKernel, Strategy, WorkerLogic,
    TAG_SIGN,
};
use crate::util::parallel;
use std::fmt;
use std::ops::Range;
use std::time::Duration;

/// Cluster communication layout.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Topology {
    /// Every worker uplinks straight to the single server (Algorithm 1).
    #[default]
    Star,
    /// Two-level tree: workers 0..g-1 share aggregator 0, workers
    /// g..2g-1 share aggregator 1, … (the last group may be smaller);
    /// aggregators fold their group and forward one partial to the root.
    Hierarchical {
        /// workers per group aggregator (≥ 1).
        group_size: usize,
    },
}

impl Topology {
    /// Parse the config syntax: `"star"` or `"hier:<group_size>"`.
    pub fn parse(s: &str) -> Result<Topology> {
        let s = s.trim();
        if s == "star" {
            return Ok(Topology::Star);
        }
        if let Some(gs) = s.strip_prefix("hier:") {
            let group_size: usize = gs.parse().map_err(|_| {
                DlionError::Config(format!(
                    "topology 'hier:<group_size>' needs an integer, got '{gs}'"
                ))
            })?;
            if group_size == 0 {
                return Err(DlionError::Config("topology group_size must be >= 1".into()));
            }
            return Ok(Topology::Hierarchical { group_size });
        }
        Err(DlionError::Config(format!(
            "unknown topology '{s}' (expected 'star' or 'hier:<group_size>')"
        )))
    }

    /// Contiguous worker ranges per group aggregator (one `0..n` range
    /// for the star, where the "aggregator" is the root itself).
    pub fn groups(&self, nworkers: usize) -> Vec<Range<usize>> {
        match *self {
            Topology::Star => vec![0..nworkers],
            Topology::Hierarchical { group_size } => {
                assert!(group_size >= 1, "group_size must be >= 1");
                let mut out = Vec::with_capacity(nworkers.div_ceil(group_size));
                let mut start = 0;
                while start < nworkers {
                    let end = (start + group_size).min(nworkers);
                    out.push(start..end);
                    start = end;
                }
                out
            }
        }
    }
}

impl fmt::Display for Topology {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Topology::Star => write!(f, "star"),
            Topology::Hierarchical { group_size } => write!(f, "hier:{group_size}"),
        }
    }
}

/// When an elastic round is allowed to close: wait for the deadline,
/// then aggregate whatever arrived — provided at least `min_workers`
/// uplinks made it. The zero value ([`QuorumPolicy::lockstep`]) is the
/// classic fixed-N round: wait forever, need everyone.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QuorumPolicy {
    /// Minimum arrived uplinks to close a round (0 = all workers).
    pub min_workers: usize,
    /// Per-round gather deadline in milliseconds (0 = block forever).
    pub deadline_ms: u64,
}

impl QuorumPolicy {
    /// The classic fixed-N round: block until every worker reports.
    pub fn lockstep() -> QuorumPolicy {
        QuorumPolicy::default()
    }

    /// Is this the classic wait-for-everyone policy?
    pub fn is_lockstep(&self) -> bool {
        *self == QuorumPolicy::default()
    }

    /// The gather deadline as a [`Duration`] (`None` = block forever).
    pub fn deadline(&self) -> Option<Duration> {
        (self.deadline_ms > 0).then(|| Duration::from_millis(self.deadline_ms))
    }

    /// Arrived-uplink floor for an `nworkers` cluster (0 resolves to
    /// "all of them").
    pub fn required(&self, nworkers: usize) -> usize {
        if self.min_workers == 0 {
            nworkers
        } else {
            self.min_workers.min(nworkers)
        }
    }
}

/// Per-hop byte and message accounting for one communication round.
/// Worker-edge hops (`uplink`/`downlink`) are what Table 1 counts; the
/// aggregator hops are zero for the flat star. Bytes are *payload*
/// bytes ([`crate::comm::chunked::payload_len`]): identical to physical
/// frame sizes for monolithic messages, chunking-invariant for chunked
/// ones.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HopBytes {
    /// worker → aggregator (star: worker → server), summed over workers
    pub uplink: usize,
    /// aggregator → root, summed over groups (0 for the star)
    pub agg_uplink: usize,
    /// root → aggregator, broadcast × groups (0 for the star)
    pub agg_downlink: usize,
    /// aggregator → worker (star: server → worker), broadcast × workers
    pub downlink: usize,
    /// aggregator → root messages this round (= groups; 0 for the star)
    pub agg_uplink_msgs: usize,
    /// root → aggregator messages this round (= groups; 0 for the star)
    pub agg_downlink_msgs: usize,
}

/// The round choreography shared by the sequential and threaded cluster
/// drivers: routes the gathered worker uplinks through the configured
/// [`Topology`], one [`crate::optim::dist::Chunk`] at a time, and
/// returns the broadcast downlink plus the per-hop accounting.
///
/// The engine owns one `ServerLogic` instance **per chunk** (and per
/// group aggregator under a hierarchical topology): each instance is
/// built for its chunk's dimension via
/// [`crate::optim::dist::Strategy::make_server_for_chunk`], so a
/// chunk's aggregate is exactly a whole-model aggregate over a smaller
/// model — which is what makes any chunking bit-exact — and a mixed
/// per-chunk assignment resolves to per-(group, chunk, arm) servers
/// with no engine-side special casing. On
/// multi-chunk plans over large models, encode, aggregate, and apply
/// all run chunk-/worker-parallel ([`crate::util::parallel`]); results
/// are collected in index order so parallelism never changes a byte.
pub struct RoundEngine {
    plan: ChunkPlan,
    groups: Vec<Range<usize>>,
    /// `[group][chunk]` aggregator servers (empty for the star)
    group_servers: Vec<Vec<Box<dyn ServerLogic>>>,
    /// `[chunk]` root servers
    root: Vec<Box<dyn ServerLogic>>,
    nworkers: usize,
    local_steps: usize,
    /// The strategy's partial-quorum semantics, captured at build time —
    /// the gate [`RoundEngine::aggregate_quorum`] checks before it lets
    /// a round close with missing uplinks.
    quorum_support: QuorumSupport,
    /// Recycled per-worker round buffers: `encode_all` lays each
    /// worker's tag-15 envelope out in one of these and chunk kernels
    /// write payloads in place, so steady-state rounds allocate nothing
    /// for uplinks. Returned to the pool via
    /// [`RoundEngine::recycle_uplinks`].
    uplink_bufs: Vec<Vec<u8>>,
}

impl RoundEngine {
    /// Build the engine for `strategy` over `nworkers` workers of a
    /// `dim`-parameter model. The communication cadence comes from the
    /// strategy itself ([`Strategy::local_steps`]), and the chunk plan
    /// from [`Strategy::plan`] — monolithic strategies collapse any
    /// `chunk_size` to a single chunk, so the engine and the worker
    /// logic can never disagree about geometry or cadence.
    pub fn new(
        strategy: &dyn Strategy,
        nworkers: usize,
        dim: usize,
        topology: Topology,
        chunk_size: usize,
    ) -> RoundEngine {
        let plan = strategy.plan(dim, chunk_size);
        let local_steps = strategy.local_steps().max(1);
        let groups = topology.groups(nworkers);
        // per-(group, chunk) — and, through make_server_for_chunk, per-
        // (group, chunk, arm): a mixed assignment routes each chunk to
        // its arm's native server, and deterministic per-link schedules
        // are seeded from the full cluster size so every instance
        // replays the workers' selection exactly.
        let group_servers = match topology {
            Topology::Star => Vec::new(),
            Topology::Hierarchical { .. } => groups
                .iter()
                .map(|g| {
                    plan.chunks()
                        .map(|c| strategy.make_server_for_chunk(g.len(), nworkers, c))
                        .collect()
                })
                .collect(),
        };
        let root =
            plan.chunks().map(|c| strategy.make_server_for_chunk(nworkers, nworkers, c)).collect();
        RoundEngine {
            plan,
            groups,
            group_servers,
            root,
            nworkers,
            local_steps,
            quorum_support: strategy.quorum(),
            uplink_bufs: Vec::new(),
        }
    }

    /// The strategy's partial-quorum semantics (see [`QuorumSupport`]).
    pub fn quorum_support(&self) -> QuorumSupport {
        self.quorum_support
    }

    /// The chunk plan every message of this engine follows.
    pub fn plan(&self) -> ChunkPlan {
        self.plan
    }

    /// Communication cadence: a frame crosses the wire every
    /// `local_steps`-th step (1 = every step, Algorithm 1).
    pub fn local_steps(&self) -> usize {
        self.local_steps
    }

    /// Is `step` a communication (sync) step? Sync steps are those with
    /// `(step + 1) % local_steps == 0`, matching the msync convention.
    pub fn is_sync_step(&self, step: usize) -> bool {
        (step + 1) % self.local_steps == 0
    }

    /// Encode every worker's uplink message under the engine's plan,
    /// parallel on large models (deterministic: every job writes a
    /// disjoint, index-addressed slice, so scheduling never changes a
    /// byte).
    ///
    /// When every worker exposes [`WorkerLogic::split_encode`] (the
    /// sign family) and the plan is chunked, the engine runs
    /// *(worker × chunk)*-parallel: each worker's momentum is carved
    /// into disjoint `split_at_mut` slices along the plan, its tag-15
    /// envelope is laid out at analytic offsets in a recycled round
    /// buffer ([`chunked::pack_into`]), and every chunk kernel writes
    /// its payload in place — closing the old "one worker's chunks
    /// encode serially because `encode_planned` borrows the whole
    /// worker" seam, with zero per-chunk allocation or splice copy.
    /// Other strategies keep the per-worker parallel path.
    pub fn encode_all(
        &mut self,
        workers: &mut [Box<dyn WorkerLogic>],
        grads: &[Vec<f32>],
        lr: f32,
        step: usize,
    ) -> Vec<Vec<u8>> {
        let plan = self.plan;
        let mut bufs = std::mem::take(&mut self.uplink_bufs);
        bufs.resize_with(workers.len(), Vec::new);
        let nthreads = parallel::auto_threads(plan.dim());
        if !plan.is_single() && workers.iter_mut().all(|w| w.split_encode().is_some()) {
            encode_all_split(&plan, workers, grads, &mut bufs, nthreads);
        } else {
            parallel::par_zip2_mut(workers, &mut bufs, nthreads, |w, buf, i| {
                *buf = w.encode_planned(&grads[i], &plan, lr, step);
            });
        }
        bufs
    }

    /// Return a round's uplink messages to the engine's buffer pool so
    /// the next [`RoundEngine::encode_all`] reuses their allocations.
    /// Optional — dropping the uplinks instead is always correct.
    pub fn recycle_uplinks(&mut self, uplinks: Vec<Vec<u8>>) {
        self.uplink_bufs = uplinks;
    }

    /// Apply the broadcast downlink on every worker's replica,
    /// worker-parallel on large models.
    pub fn apply_all(
        &self,
        workers: &mut [Box<dyn WorkerLogic>],
        params: &mut [Vec<f32>],
        downlink: &[u8],
        lr: f32,
        step: usize,
    ) {
        let plan = self.plan;
        let nthreads = parallel::auto_threads(plan.dim());
        parallel::par_zip2_mut(workers, params, nthreads, |w, p, _| {
            w.apply_planned(p, downlink, &plan, lr, step)
        });
    }

    /// Route one round: fold the index-aligned worker uplinks through
    /// the topology into the broadcast downlink. Returns the downlink
    /// message (identical for every worker — the replicated-parameter
    /// invariant rides on this) and the per-hop accounting.
    pub fn aggregate(&mut self, uplinks: &[Vec<u8>], lr: f32, step: usize) -> (Vec<u8>, HopBytes) {
        assert_eq!(uplinks.len(), self.nworkers, "uplink count mismatch");
        let uplink_bytes: usize = uplinks.iter().map(|m| chunked::payload_len(m)).sum();
        let ngroups = self.groups.len();
        if self.plan.is_single() {
            return self.aggregate_single(uplinks, lr, step, uplink_bytes);
        }
        // Chunked: split each worker's envelope into per-chunk frame
        // views, transpose to per-chunk worker lists, and aggregate the
        // chunks in parallel (each chunk has its own server state).
        let k = self.plan.num_chunks();
        let per_worker: Vec<Vec<&[u8]>> = uplinks
            .iter()
            .map(|m| {
                let frames = chunked::unpack(m).expect("malformed chunked uplink");
                assert_eq!(frames.len(), k, "uplink chunk count mismatch");
                frames
            })
            .collect();
        let plan = self.plan;
        let nthreads = parallel::auto_threads(plan.dim());
        if self.group_servers.is_empty() {
            // Flat star, chunked.
            let per_chunk: Vec<Vec<&[u8]>> = (0..k)
                .map(|c| per_worker.iter().map(|w| w[c]).collect())
                .collect();
            let downlinks = parallel::par_zip_map(
                &mut self.root,
                &per_chunk,
                nthreads,
                |srv, frames, c| srv.aggregate_chunk(frames, plan.chunk(c), lr, step),
            );
            let downlink = chunked::pack(&downlinks);
            let down = chunked::payload_len(&downlink);
            let hops = HopBytes {
                uplink: uplink_bytes,
                downlink: down * self.nworkers,
                ..HopBytes::default()
            };
            return (downlink, hops);
        }
        // Hierarchical, chunked: per-(group, chunk) partials up, per-
        // chunk fold at the root, broadcast retraces the tree.
        let mut partials: Vec<Vec<Vec<u8>>> = Vec::with_capacity(ngroups);
        for (gs, range) in self.group_servers.iter_mut().zip(&self.groups) {
            let group_frames: Vec<Vec<&[u8]>> = (0..k)
                .map(|c| per_worker[range.clone()].iter().map(|w| w[c]).collect())
                .collect();
            let p = parallel::par_zip_map(gs, &group_frames, nthreads, |srv, frames, c| {
                srv.partial_chunk(frames, plan.chunk(c), lr, step)
            });
            partials.push(p);
        }
        let agg_uplink: usize =
            partials.iter().map(|p| chunked::frames_payload_len(p)).sum();
        let per_chunk_partials: Vec<Vec<&[u8]>> = (0..k)
            .map(|c| partials.iter().map(|g| g[c].as_slice()).collect())
            .collect();
        let downlinks = parallel::par_zip_map(
            &mut self.root,
            &per_chunk_partials,
            nthreads,
            |srv, ps, c| srv.fold_chunk(ps, plan.chunk(c), lr, step),
        );
        let downlink = chunked::pack(&downlinks);
        let down = chunked::payload_len(&downlink);
        let hops = HopBytes {
            uplink: uplink_bytes,
            agg_uplink,
            agg_downlink: down * ngroups,
            downlink: down * self.nworkers,
            agg_uplink_msgs: ngroups,
            agg_downlink_msgs: ngroups,
        };
        (downlink, hops)
    }

    /// The single-chunk (whole-model) round — byte-for-byte the
    /// pre-chunking wire path: bare frames, no envelope.
    fn aggregate_single(
        &mut self,
        uplinks: &[Vec<u8>],
        lr: f32,
        step: usize,
        uplink_bytes: usize,
    ) -> (Vec<u8>, HopBytes) {
        if self.group_servers.is_empty() {
            let downlink = self.root[0].aggregate(uplinks, lr, step);
            let hops = HopBytes {
                uplink: uplink_bytes,
                downlink: downlink.len() * self.nworkers,
                ..HopBytes::default()
            };
            return (downlink, hops);
        }
        let partials: Vec<Vec<u8>> = self
            .group_servers
            .iter_mut()
            .zip(&self.groups)
            .map(|(gs, range)| gs[0].partial(&uplinks[range.clone()], lr, step))
            .collect();
        let agg_uplink: usize = partials.iter().map(|m| m.len()).sum();
        let downlink = self.root[0].fold(&partials, lr, step);
        let hops = HopBytes {
            uplink: uplink_bytes,
            agg_uplink,
            agg_downlink: downlink.len() * self.groups.len(),
            downlink: downlink.len() * self.nworkers,
            agg_uplink_msgs: self.groups.len(),
            agg_downlink_msgs: self.groups.len(),
        };
        (downlink, hops)
    }

    /// Route one **elastic** round: `uplinks[w]` is `Some` iff worker
    /// `w`'s frame arrived before the deadline, `None` for stragglers
    /// and crashed workers. Returns the broadcast downlink, the per-hop
    /// accounting (arrived frames only on the uplink edge), and the
    /// achieved quorum.
    ///
    /// Full arrival routes through [`RoundEngine::aggregate`] — the
    /// exact lockstep code path, so honest full-quorum rounds stay
    /// bit-identical to the fixed-N engine. A partial round needs the
    /// strategy to support it ([`Strategy::quorum`]): sign-vote
    /// families aggregate the quorum's ballots exactly (missing voters
    /// abstain), the dense family rescales its mean to the arrived
    /// count; anything else is a named [`DlionError::Cluster`], as is a
    /// round with zero arrivals. Under a hierarchical topology, groups
    /// with no arrivals ship no partial at all.
    ///
    /// On the local-steps cadence (`local_steps() == H > 1`) a round is
    /// one sync step and each frame is already the sign over an
    /// `H`-step vote window, so a missing slot abstains the *whole
    /// window* — the worker carries those votes into its next shipped
    /// frame ([`WorkerLogic::abstain_sync`]) and the ballot here stays
    /// exact: every arrived frame is a complete window, every missing
    /// one is deferred, never split.
    ///
    /// [`WorkerLogic::abstain_sync`]: crate::optim::dist::WorkerLogic::abstain_sync
    pub fn aggregate_quorum(
        &mut self,
        uplinks: Vec<Option<Vec<u8>>>,
        lr: f32,
        step: usize,
    ) -> Result<(Vec<u8>, HopBytes, usize)> {
        assert_eq!(uplinks.len(), self.nworkers, "uplink slot count mismatch");
        let arrived = uplinks.iter().filter(|u| u.is_some()).count();
        if arrived == self.nworkers {
            let ups: Vec<Vec<u8>> =
                uplinks.into_iter().map(|u| u.expect("counted as arrived")).collect();
            let (down, hops) = self.aggregate(&ups, lr, step);
            return Ok((down, hops, arrived));
        }
        if arrived == 0 {
            return Err(DlionError::Cluster(
                "elastic round closed with zero arrived uplinks".into(),
            ));
        }
        if self.quorum_support == QuorumSupport::Unsupported {
            return Err(DlionError::Cluster(format!(
                "strategy cannot close a partial round ({arrived}/{} uplinks arrived): \
                 only the sign-vote (exact abstention) and dense (rescaled mean) \
                 families support elastic quorums",
                self.nworkers
            )));
        }
        let uplink_bytes: usize =
            uplinks.iter().flatten().map(|m| chunked::payload_len(m)).sum();
        if self.plan.is_single() {
            return self.aggregate_quorum_single(&uplinks, lr, step, uplink_bytes, arrived);
        }
        // Chunked: same transpose as the lockstep path, minus the
        // missing workers' columns.
        let k = self.plan.num_chunks();
        let per_worker: Vec<Vec<&[u8]>> = uplinks
            .iter()
            .flatten()
            .map(|m| {
                let frames = chunked::unpack(m).expect("malformed chunked uplink");
                assert_eq!(frames.len(), k, "uplink chunk count mismatch");
                frames
            })
            .collect();
        let plan = self.plan;
        let nthreads = parallel::auto_threads(plan.dim());
        if self.group_servers.is_empty() {
            let per_chunk: Vec<Vec<&[u8]>> =
                (0..k).map(|c| per_worker.iter().map(|w| w[c]).collect()).collect();
            let downlinks =
                parallel::par_zip_map(&mut self.root, &per_chunk, nthreads, |srv, frames, _| {
                    srv.aggregate_quorum(frames, lr, step)
                });
            let downlink = chunked::pack(&downlinks);
            let down = chunked::payload_len(&downlink);
            let hops = HopBytes {
                uplink: uplink_bytes,
                downlink: down * self.nworkers,
                ..HopBytes::default()
            };
            return Ok((downlink, hops, arrived));
        }
        // Hierarchical, chunked: quorum partials from the groups that
        // have at least one arrival, quorum fold at the root.
        let arrived_in_group: Vec<Vec<usize>> = self
            .groups
            .iter()
            .map(|range| {
                uplinks[range.clone()]
                    .iter()
                    .enumerate()
                    .filter_map(|(i, u)| u.is_some().then_some(range.start + i))
                    .collect()
            })
            .collect();
        // index of each arrived worker within the flattened `per_worker`
        let dense_index: Vec<usize> = {
            let mut map = vec![usize::MAX; self.nworkers];
            let mut next = 0;
            for (w, u) in uplinks.iter().enumerate() {
                if u.is_some() {
                    map[w] = next;
                    next += 1;
                }
            }
            map
        };
        let mut partials: Vec<Vec<Vec<u8>>> = Vec::with_capacity(self.groups.len());
        for (gs, members) in self.group_servers.iter_mut().zip(&arrived_in_group) {
            if members.is_empty() {
                continue;
            }
            let group_frames: Vec<Vec<&[u8]>> = (0..k)
                .map(|c| members.iter().map(|&w| per_worker[dense_index[w]][c]).collect())
                .collect();
            let p = parallel::par_zip_map(gs, &group_frames, nthreads, |srv, frames, _| {
                srv.partial_quorum(frames, lr, step)
            });
            partials.push(p);
        }
        let agg_uplink: usize = partials.iter().map(|p| chunked::frames_payload_len(p)).sum();
        let per_chunk_partials: Vec<Vec<&[u8]>> =
            (0..k).map(|c| partials.iter().map(|g| g[c].as_slice()).collect()).collect();
        let downlinks = parallel::par_zip_map(
            &mut self.root,
            &per_chunk_partials,
            nthreads,
            |srv, ps, _| srv.fold_quorum(ps, lr, step),
        );
        let downlink = chunked::pack(&downlinks);
        let down = chunked::payload_len(&downlink);
        let hops = HopBytes {
            uplink: uplink_bytes,
            agg_uplink,
            agg_downlink: down * self.groups.len(),
            downlink: down * self.nworkers,
            agg_uplink_msgs: partials.len(),
            agg_downlink_msgs: self.groups.len(),
        };
        Ok((downlink, hops, arrived))
    }

    /// Single-chunk elastic round (bare frames, no envelope).
    fn aggregate_quorum_single(
        &mut self,
        uplinks: &[Option<Vec<u8>>],
        lr: f32,
        step: usize,
        uplink_bytes: usize,
        arrived: usize,
    ) -> Result<(Vec<u8>, HopBytes, usize)> {
        if self.group_servers.is_empty() {
            let frames: Vec<&[u8]> =
                uplinks.iter().flatten().map(|m| m.as_slice()).collect();
            let downlink = self.root[0].aggregate_quorum(&frames, lr, step);
            let hops = HopBytes {
                uplink: uplink_bytes,
                downlink: downlink.len() * self.nworkers,
                ..HopBytes::default()
            };
            return Ok((downlink, hops, arrived));
        }
        let mut partials: Vec<Vec<u8>> = Vec::new();
        for (gs, range) in self.group_servers.iter_mut().zip(&self.groups) {
            let frames: Vec<&[u8]> =
                uplinks[range.clone()].iter().flatten().map(|m| m.as_slice()).collect();
            if frames.is_empty() {
                continue;
            }
            partials.push(gs[0].partial_quorum(&frames, lr, step));
        }
        let agg_uplink: usize = partials.iter().map(|m| m.len()).sum();
        let prefs: Vec<&[u8]> = partials.iter().map(|m| m.as_slice()).collect();
        let downlink = self.root[0].fold_quorum(&prefs, lr, step);
        let hops = HopBytes {
            uplink: uplink_bytes,
            agg_uplink,
            agg_downlink: downlink.len() * self.groups.len(),
            downlink: downlink.len() * self.nworkers,
            agg_uplink_msgs: prefs.len(),
            agg_downlink_msgs: self.groups.len(),
        };
        Ok((downlink, hops, arrived))
    }
}

/// The (worker × chunk) encode fan-out behind
/// [`RoundEngine::encode_all`]: lay out every worker's envelope
/// skeleton in its recycled buffer, carve each worker's momentum and
/// envelope into disjoint per-chunk slices, then run all chunk kernels
/// as one flat parallel job list. Every job owns its slices, so any
/// schedule writes the same bytes as the sequential
/// `encode_planned` path (pinned in `tests/swar_kernels.rs`).
fn encode_all_split(
    plan: &ChunkPlan,
    workers: &mut [Box<dyn WorkerLogic>],
    grads: &[Vec<f32>],
    bufs: &mut [Vec<u8>],
    nthreads: usize,
) {
    struct Job<'a> {
        kernel: SignKernel,
        state: &'a mut [f32],
        grads: &'a [f32],
        payload: &'a mut [u8],
    }
    let lens = sign_frame_lens(plan);
    let mut jobs: Vec<Job<'_>> = Vec::with_capacity(workers.len() * plan.num_chunks());
    for ((w, buf), g) in workers.iter_mut().zip(bufs.iter_mut()).zip(grads) {
        let ranges = chunked::pack_into(buf, &lens);
        let se = w.split_encode().expect("encode_all checked every worker splits");
        debug_assert_eq!(se.state.len(), plan.dim(), "split state must cover the model");
        let mut rest = se.state;
        for (frame, c) in chunked::split_ranges_mut(buf, &ranges).into_iter().zip(plan.chunks()) {
            let (state, r) = std::mem::take(&mut rest).split_at_mut(c.len());
            rest = r;
            frame[0] = TAG_SIGN;
            let (_, payload) = frame.split_at_mut(1);
            jobs.push(Job { kernel: se.kernel, state, grads: &g[c.range()], payload });
        }
    }
    parallel::par_for_each_mut(&mut jobs, nthreads, |job, _| {
        job.kernel.encode(job.state, job.grads, job.payload);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::dist::{by_name, StrategyHyper};
    use crate::util::Rng;

    #[test]
    fn parse_and_display_round_trip() {
        assert_eq!(Topology::parse("star").unwrap(), Topology::Star);
        assert_eq!(
            Topology::parse("hier:4").unwrap(),
            Topology::Hierarchical { group_size: 4 }
        );
        for t in [Topology::Star, Topology::Hierarchical { group_size: 7 }] {
            assert_eq!(Topology::parse(&t.to_string()).unwrap(), t);
        }
        assert!(Topology::parse("hier:0").is_err());
        assert!(Topology::parse("hier:x").is_err());
        assert!(Topology::parse("ring").is_err());
    }

    #[test]
    fn groups_cover_workers_exactly() {
        let t = Topology::Hierarchical { group_size: 3 };
        assert_eq!(t.groups(7), vec![0..3, 3..6, 6..7]);
        assert_eq!(t.groups(3), vec![0..3]);
        assert_eq!(Topology::Star.groups(5), vec![0..5]);
        // group_size beyond nworkers degenerates to one group
        let t = Topology::Hierarchical { group_size: 99 };
        assert_eq!(t.groups(4), vec![0..4]);
    }

    #[test]
    fn engine_star_matches_run_round_accounting() {
        let (n, d) = (4, 129);
        let hp = StrategyHyper::default();
        let strat = by_name("d-lion-mavo", &hp).unwrap();
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut engine = RoundEngine::new(strat.as_ref(), n, d, Topology::Star, 0);
        let mut rng = Rng::new(0x70);
        let ups: Vec<Vec<u8>> = workers
            .iter_mut()
            .map(|w| {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                w.encode(&g, 1e-3, 0)
            })
            .collect();
        let (down, hops) = engine.aggregate(&ups, 1e-3, 0);
        assert_eq!(hops.uplink, ups.iter().map(|m| m.len()).sum::<usize>());
        assert_eq!(hops.downlink, down.len() * n);
        assert_eq!(hops.agg_uplink, 0);
        assert_eq!(hops.agg_downlink, 0);
    }

    #[test]
    fn hierarchical_vote_partials_are_exact() {
        // Any grouping of the sign-vote family must produce the very
        // same downlink bytes as the flat star (integer sums regroup).
        let (n, d) = (6, 200);
        let hp = StrategyHyper::default();
        let mut rng = Rng::new(0x71);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                g
            })
            .collect();
        let frames = |topology: Topology| -> Vec<u8> {
            let strat = by_name("d-lion-mavo", &hp).unwrap();
            let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
            let mut engine = RoundEngine::new(strat.as_ref(), n, d, topology, 0);
            let ups: Vec<Vec<u8>> = workers
                .iter_mut()
                .zip(&grads)
                .map(|(w, g)| w.encode(g, 1e-3, 0))
                .collect();
            engine.aggregate(&ups, 1e-3, 0).0
        };
        let flat = frames(Topology::Star);
        for gs in [1usize, 2, 3, 4, 6, 9] {
            assert_eq!(
                frames(Topology::Hierarchical { group_size: gs }),
                flat,
                "group_size={gs} changed the downlink"
            );
        }
    }

    #[test]
    fn chunked_engine_matches_monolithic_for_star_and_hier() {
        // One engine-level round: any chunk_size must yield the same
        // parameters and the same per-hop payload accounting as the
        // whole-model path, for both topologies.
        let (n, d) = (4usize, 200usize);
        let hp = StrategyHyper::default();
        let mut rng = Rng::new(0x74);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                g
            })
            .collect();
        for topology in [Topology::Star, Topology::Hierarchical { group_size: 2 }] {
            let round = |chunk_size: usize| {
                let strat = by_name("d-lion-mavo", &hp).unwrap();
                let mut workers: Vec<_> =
                    (0..n).map(|i| strat.make_worker(i, n, d)).collect();
                let mut engine = RoundEngine::new(strat.as_ref(), n, d, topology, chunk_size);
                let mut params: Vec<Vec<f32>> = vec![vec![0.3f32; d]; n];
                let ups = engine.encode_all(&mut workers, &grads, 1e-2, 0);
                let (down, hops) = engine.aggregate(&ups, 1e-2, 0);
                engine.apply_all(&mut workers, &mut params, &down, 1e-2, 0);
                (params, hops)
            };
            let (p_mono, h_mono) = round(0);
            for chunk_size in [1usize, 41, 199] {
                let (p, h) = round(chunk_size);
                assert_eq!(p, p_mono, "{topology}: chunk_size={chunk_size} changed params");
                assert_eq!(
                    (h.uplink, h.downlink),
                    (h_mono.uplink, h_mono.downlink),
                    "{topology}: chunk_size={chunk_size} changed worker-edge accounting"
                );
                assert_eq!(
                    (h.agg_uplink, h.agg_downlink),
                    (h_mono.agg_uplink, h_mono.agg_downlink),
                    "{topology}: chunk_size={chunk_size} changed aggregator accounting"
                );
            }
        }
    }

    #[test]
    fn hierarchical_agg_hop_is_cheaper_than_relaying_for_votes() {
        // The intavg vote partial must beat forwarding the member sign
        // frames verbatim once groups are large enough (log2(g+1) < g).
        let (n, d) = (8, 4096);
        let hp = StrategyHyper::default();
        let strat = by_name("d-lion-mavo", &hp).unwrap();
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut engine =
            RoundEngine::new(strat.as_ref(), n, d, Topology::Hierarchical { group_size: 4 }, 0);
        let mut rng = Rng::new(0x72);
        let ups: Vec<Vec<u8>> = workers
            .iter_mut()
            .map(|w| {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                w.encode(&g, 1e-3, 0)
            })
            .collect();
        let (_, hops) = engine.aggregate(&ups, 1e-3, 0);
        // 2 groups × (3-byte head + 3 bits/param) vs 8 × 1 bit/param
        assert!(hops.agg_uplink > 0);
        assert!(
            hops.agg_uplink < hops.uplink,
            "vote partials ({}) should be cheaper than the worker edge ({})",
            hops.agg_uplink,
            hops.uplink
        );
    }
}
