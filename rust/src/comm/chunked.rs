//! Chunked outer framing: a self-describing multi-frame envelope that
//! carries one codec frame per [`crate::optim::dist::ChunkPlan`] chunk.
//!
//! Layout: `[15][count: u16 LE][(len: u32 LE, frame bytes)*count]` —
//! tag 15 (`TAG_CHUNKED`) never collides with the per-strategy codec
//! tags (1–14), so a receiver can tell a chunked message from a
//! monolithic frame by its first byte. Each inner frame is a complete,
//! independently decodable `[tag][payload]` message for one contiguous
//! parameter range; the chunk geometry itself is *not* on the wire — it
//! is derived deterministically on both ends from `(dim, chunk_size)`,
//! exactly like the codec payload shapes.
//!
//! ## Payload accounting
//!
//! The repo's byte counters exist to validate the paper's Table-1
//! *communication volume* claims, so they count **codec payload
//! volume**: [`payload_len`] charges a chunked message as if its chunks
//! were spliced back into one monolithic frame — the outer envelope
//! (3-byte header + 4-byte length prefixes) and the per-chunk copies of
//! the frame head (tag + fixed fields, see [`head_len`]) are excluded.
//! Because native chunk plans are aligned to the codec's bit-packing
//! period (`Chunking::Native { align }`), the chunk payloads concatenate
//! bit-exactly into the monolithic payload and this accounting is
//! *chunking-invariant*: any `chunk_size` reports the same bytes as the
//! whole-model path. For a non-chunked message `payload_len` is simply
//! `msg.len()`, so all pre-existing accounting is unchanged.

/// First byte of a chunked multi-frame message.
pub const TAG_CHUNKED: u8 = 15;

/// Does this message carry the chunked outer framing?
#[inline]
pub fn is_chunked(msg: &[u8]) -> bool {
    !msg.is_empty() && msg[0] == TAG_CHUNKED
}

/// Pack per-chunk frames into one chunked message.
pub fn pack(frames: &[Vec<u8>]) -> Vec<u8> {
    assert!(frames.len() <= u16::MAX as usize, "too many chunks for the u16 count");
    let total: usize = frames.iter().map(|f| 4 + f.len()).sum();
    let mut msg = Vec::with_capacity(3 + total);
    msg.push(TAG_CHUNKED);
    msg.extend_from_slice(&(frames.len() as u16).to_le_bytes());
    for f in frames {
        msg.extend_from_slice(&(f.len() as u32).to_le_bytes());
        msg.extend_from_slice(f);
    }
    msg
}

/// Unpack a chunked message into per-chunk frame views (no copies).
/// Returns `None` if the message is not well-formed chunked framing.
pub fn unpack(msg: &[u8]) -> Option<Vec<&[u8]>> {
    if msg.len() < 3 || msg[0] != TAG_CHUNKED {
        return None;
    }
    let count = u16::from_le_bytes([msg[1], msg[2]]) as usize;
    let mut out = Vec::with_capacity(count);
    let mut off = 3usize;
    for _ in 0..count {
        if off + 4 > msg.len() {
            return None;
        }
        let len =
            u32::from_le_bytes([msg[off], msg[off + 1], msg[off + 2], msg[off + 3]]) as usize;
        off += 4;
        if off + len > msg.len() {
            return None;
        }
        out.push(&msg[off..off + len]);
        off += len;
    }
    if off != msg.len() {
        return None;
    }
    Some(out)
}

/// Fixed per-frame head bytes (tag + fixed-width fields that precede the
/// element payload) for each codec tag. This is what every chunk of a
/// chunked message repeats and what a monolithic frame carries once;
/// [`payload_len`] de-duplicates it. Tags are the
/// [`crate::optim::dist`] frame tags.
pub fn head_len(tag: u8) -> usize {
    match tag {
        // [tag] only: sign, tern, dense, msync frames
        1 | 2 | 4 | 11 | 12 => 1,
        // [tag][n: u16]: intavg / relay / dense-sum
        3 | 13 | 14 => 3,
        // [tag][scale: f32]: TernGrad / EF-SignSGD / QSGD uplinks
        6 | 8 | 9 => 5,
        // [tag][n: u16][scale: f32]: TernGrad downlink
        7 => 7,
        // [tag][d: u32][k: u32]: classic sparse
        5 => 9,
        // [tag][d: u32][k: u32][index_bytes: u32]: compact sparse
        10 => 13,
        // chunked envelope header itself
        TAG_CHUNKED => 3,
        _ => 1,
    }
}

/// Logical (payload-accounting) length of a set of per-chunk frames:
/// the length of the equivalent monolithic frame — one copy of the
/// frame head plus the concatenated chunk payloads. A single frame is
/// charged at face value.
pub fn frames_payload_len<B: AsRef<[u8]>>(frames: &[B]) -> usize {
    match frames {
        [] => 0,
        [only] => only.as_ref().len(),
        [first, ..] => {
            let first = first.as_ref();
            if first.is_empty() {
                return frames.iter().map(|f| f.as_ref().len()).sum();
            }
            let head = head_len(first[0]);
            head + frames
                .iter()
                .map(|f| {
                    let f = f.as_ref();
                    if f.is_empty() {
                        0
                    } else {
                        f.len().saturating_sub(head_len(f[0]))
                    }
                })
                .sum::<usize>()
        }
    }
}

/// Logical (payload-accounting) length of a wire message: `msg.len()`
/// for a monolithic frame; the de-duplicated monolithic-equivalent
/// length for a chunked message (see the module docs). Malformed
/// chunked framing falls back to the physical length.
pub fn payload_len(msg: &[u8]) -> usize {
    if !is_chunked(msg) {
        return msg.len();
    }
    match unpack(msg) {
        Some(frames) if !frames.is_empty() => frames_payload_len(&frames),
        _ => msg.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_round_trip() {
        let frames = vec![vec![1u8, 0xAB], vec![1u8, 0xCD, 0xEF], vec![1u8]];
        let msg = pack(&frames);
        assert!(is_chunked(&msg));
        let back = unpack(&msg).unwrap();
        assert_eq!(back.len(), 3);
        for (b, f) in back.iter().zip(&frames) {
            assert_eq!(b, &f.as_slice());
        }
    }

    #[test]
    fn unpack_rejects_malformed() {
        assert!(unpack(&[]).is_none());
        assert!(unpack(&[1, 2, 3]).is_none(), "wrong tag");
        // truncated length prefix
        assert!(unpack(&[TAG_CHUNKED, 1, 0, 5, 0]).is_none());
        // inner length overruns the buffer
        assert!(unpack(&[TAG_CHUNKED, 1, 0, 9, 0, 0, 0, 1]).is_none());
        // trailing garbage
        let mut msg = pack(&[vec![1u8, 2]]);
        msg.push(0);
        assert!(unpack(&msg).is_none());
    }

    #[test]
    fn payload_len_is_monolithic_equivalent() {
        // three sign chunks: heads de-duplicate to one tag byte
        let frames = vec![vec![1u8, 0x11, 0x22], vec![1u8, 0x33], vec![1u8, 0x44]];
        let msg = pack(&frames);
        assert_eq!(payload_len(&msg), 1 + 4);
        // monolithic messages are charged at face value
        assert_eq!(payload_len(&[4u8, 0, 0, 0, 0]), 5);
        // intavg chunks repeat a 3-byte head
        let frames = vec![vec![3u8, 4, 0, 0xAA], vec![3u8, 4, 0, 0xBB, 0xCC]];
        assert_eq!(payload_len(&pack(&frames)), 3 + 3);
    }

    #[test]
    fn payload_len_falls_back_on_malformed_chunked() {
        let bad = vec![TAG_CHUNKED, 9, 9, 1, 2, 3];
        assert_eq!(payload_len(&bad), bad.len());
    }
}
