//! Chunked outer framing: a self-describing multi-frame envelope that
//! carries one codec frame per [`crate::optim::dist::ChunkPlan`] chunk.
//!
//! Layout: `[15][count: u16 LE][(len: u32 LE, frame bytes)*count]` —
//! tag 15 (`TAG_CHUNKED`) never collides with the per-strategy codec
//! tags (1–14), so a receiver can tell a chunked message from a
//! monolithic frame by its first byte. Each inner frame is a complete,
//! independently decodable `[tag][payload]` message for one contiguous
//! parameter range; the chunk geometry itself is *not* on the wire — it
//! is derived deterministically on both ends from `(dim, chunk_size)`,
//! exactly like the codec payload shapes.
//!
//! Under a mixed per-chunk arm assignment
//! ([`crate::optim::dist::mixed`]) the inner frames of one envelope may
//! carry *different* codec tags — e.g. seven 1-bit sign chunks and one
//! dense f32 chunk. The decoder does not care (each frame is
//! self-describing); the payload accounting below does.
//!
//! ## Decode errors
//!
//! [`try_unpack`] names exactly what is malformed ([`ChunkedError`]):
//! truncated headers or length prefixes, inner lengths that overrun the
//! buffer, trailing bytes, empty inner frames, and inner tags outside
//! the codec range 1–14 (envelopes do not nest). It never panics on any
//! input. [`unpack`] is the `Option` convenience wrapper.
//!
//! ## Payload accounting
//!
//! The repo's byte counters exist to validate the paper's Table-1
//! *communication volume* claims, so they count **codec payload
//! volume**: [`payload_len`] charges a chunked message as if its chunks
//! were spliced back into monolithic frames — the outer envelope
//! (3-byte header + 4-byte length prefixes) and the per-chunk copies of
//! each frame head (tag + fixed fields, see [`head_len`]) are excluded;
//! one head is charged **per distinct inner tag**, because chunks that
//! share a codec splice into one monolithic frame while chunks of
//! different arms are separate frames however you cut them. Because
//! native chunk plans are aligned to the codec's bit-packing period
//! (`Chunking::Native { align }`), same-tag chunk payloads concatenate
//! bit-exactly into the monolithic payload and this accounting is
//! *chunking-invariant*: any `chunk_size` — and any per-chunk arm
//! assignment with the same per-arm coverage — reports the same bytes
//! as the whole-model path. For a non-chunked message `payload_len` is
//! simply `msg.len()`, so all pre-existing accounting is unchanged.

use std::fmt;

/// First byte of a chunked multi-frame message.
pub const TAG_CHUNKED: u8 = 15;

/// Why a buffer failed to parse as chunked framing ([`try_unpack`]).
/// Every variant names the offending chunk/byte so transport and test
/// layers can surface the exact failure instead of a silent `None`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkedError {
    /// The first byte is not [`TAG_CHUNKED`] (or the buffer is empty):
    /// this is a monolithic frame, not an envelope.
    NotChunked,
    /// The buffer ends inside the 3-byte `[tag][count: u16]` header.
    TruncatedHeader,
    /// The buffer ends inside chunk `chunk`'s 4-byte length prefix.
    TruncatedLength { chunk: usize },
    /// Chunk `chunk` declares `need` payload bytes but only `have`
    /// remain in the buffer.
    Truncated { chunk: usize, need: usize, have: usize },
    /// Chunk `chunk` is empty — every inner frame must carry a codec tag.
    EmptyFrame { chunk: usize },
    /// Chunk `chunk` leads with `tag`, which is not a codec frame tag
    /// (1..=14; envelopes do not nest, so 15 is also rejected).
    UnknownTag { chunk: usize, tag: u8 },
    /// All `count` chunks parsed but `extra` trailing bytes remain.
    TrailingBytes { extra: usize },
}

impl fmt::Display for ChunkedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            ChunkedError::NotChunked => write!(f, "not a chunked message (tag != 15)"),
            ChunkedError::TruncatedHeader => {
                write!(f, "chunked message truncated inside the [tag][count] header")
            }
            ChunkedError::TruncatedLength { chunk } => {
                write!(f, "chunked message truncated inside chunk {chunk}'s length prefix")
            }
            ChunkedError::Truncated { chunk, need, have } => write!(
                f,
                "chunk {chunk} declares {need} payload bytes but only {have} remain"
            ),
            ChunkedError::EmptyFrame { chunk } => {
                write!(f, "chunk {chunk} is empty (inner frames must carry a codec tag)")
            }
            ChunkedError::UnknownTag { chunk, tag } => write!(
                f,
                "chunk {chunk} leads with unknown inner tag {tag} (codec tags are 1..=14)"
            ),
            ChunkedError::TrailingBytes { extra } => {
                write!(f, "chunked message has {extra} trailing bytes after the last chunk")
            }
        }
    }
}

impl std::error::Error for ChunkedError {}

/// Does this message carry the chunked outer framing?
#[inline]
pub fn is_chunked(msg: &[u8]) -> bool {
    !msg.is_empty() && msg[0] == TAG_CHUNKED
}

/// Pack per-chunk frames into one chunked message.
pub fn pack(frames: &[Vec<u8>]) -> Vec<u8> {
    assert!(frames.len() <= u16::MAX as usize, "too many chunks for the u16 count");
    let total: usize = frames.iter().map(|f| 4 + f.len()).sum();
    let mut msg = Vec::with_capacity(3 + total);
    msg.push(TAG_CHUNKED);
    msg.extend_from_slice(&(frames.len() as u16).to_le_bytes());
    for f in frames {
        msg.extend_from_slice(&(f.len() as u32).to_le_bytes());
        msg.extend_from_slice(f);
    }
    msg
}

/// Lay out the envelope skeleton for frames of *known* lengths directly
/// into a reused buffer (§Perf optimization #4, the zero-copy frame
/// assembly path): writes the `[15][count]` header and every 4-byte
/// length prefix, zeroes the frame bodies, and returns each frame's
/// byte range within `buf`. Callers fill the frame bodies in place —
/// sign-family frame sizes are analytic (1 + ⌈len/8⌉), so the whole
/// uplink is assembled with zero per-chunk allocations and no splice
/// copy. `pack_into` followed by in-place frame fills is byte-identical
/// to [`pack`] of the same frames.
pub fn pack_into(buf: &mut Vec<u8>, frame_lens: &[usize]) -> Vec<std::ops::Range<usize>> {
    assert!(frame_lens.len() <= u16::MAX as usize, "too many chunks for the u16 count");
    let total = 3 + frame_lens.iter().map(|l| 4 + l).sum::<usize>();
    buf.clear();
    buf.resize(total, 0);
    buf[0] = TAG_CHUNKED;
    buf[1..3].copy_from_slice(&(frame_lens.len() as u16).to_le_bytes());
    let mut ranges = Vec::with_capacity(frame_lens.len());
    let mut off = 3usize;
    for &len in frame_lens {
        buf[off..off + 4].copy_from_slice(&(len as u32).to_le_bytes());
        off += 4;
        ranges.push(off..off + len);
        off += len;
    }
    ranges
}

/// Split disjoint ascending `ranges` of `buf` (as returned by
/// [`pack_into`]) into independent mutable frame views, so each chunk
/// encoder can write its frame from its own thread. Panics if the
/// ranges overlap, run backwards, or overrun `buf`.
pub fn split_ranges_mut<'a>(
    mut buf: &'a mut [u8],
    ranges: &[std::ops::Range<usize>],
) -> Vec<&'a mut [u8]> {
    let mut out = Vec::with_capacity(ranges.len());
    let mut consumed = 0usize;
    for r in ranges {
        assert!(r.start >= consumed && r.end >= r.start, "ranges must be disjoint ascending");
        let (_, rest) = buf.split_at_mut(r.start - consumed);
        let (frame, rest) = rest.split_at_mut(r.end - r.start);
        out.push(frame);
        buf = rest;
        consumed = r.end;
    }
    out
}

/// Unpack a chunked message into per-chunk frame views (no copies),
/// naming exactly what is malformed otherwise. Never panics.
pub fn try_unpack(msg: &[u8]) -> Result<Vec<&[u8]>, ChunkedError> {
    if msg.is_empty() || msg[0] != TAG_CHUNKED {
        return Err(ChunkedError::NotChunked);
    }
    if msg.len() < 3 {
        return Err(ChunkedError::TruncatedHeader);
    }
    let count = u16::from_le_bytes([msg[1], msg[2]]) as usize;
    let mut out = Vec::with_capacity(count);
    let mut off = 3usize;
    for chunk in 0..count {
        if off + 4 > msg.len() {
            return Err(ChunkedError::TruncatedLength { chunk });
        }
        let len =
            u32::from_le_bytes([msg[off], msg[off + 1], msg[off + 2], msg[off + 3]]) as usize;
        off += 4;
        if len > msg.len() - off {
            return Err(ChunkedError::Truncated { chunk, need: len, have: msg.len() - off });
        }
        let frame = &msg[off..off + len];
        match frame.first() {
            None => return Err(ChunkedError::EmptyFrame { chunk }),
            Some(&tag) if tag == 0 || tag >= TAG_CHUNKED => {
                return Err(ChunkedError::UnknownTag { chunk, tag })
            }
            Some(_) => {}
        }
        out.push(frame);
        off += len;
    }
    if off != msg.len() {
        return Err(ChunkedError::TrailingBytes { extra: msg.len() - off });
    }
    Ok(out)
}

/// Unpack a chunked message into per-chunk frame views (no copies).
/// Returns `None` if the message is not well-formed chunked framing;
/// [`try_unpack`] names the failure.
pub fn unpack(msg: &[u8]) -> Option<Vec<&[u8]>> {
    try_unpack(msg).ok()
}

/// Fixed per-frame head bytes (tag + fixed-width fields that precede the
/// element payload) for each codec tag. This is what every chunk of a
/// chunked message repeats and what a monolithic frame carries once;
/// [`payload_len`] de-duplicates it. Tags are the
/// [`crate::optim::dist`] frame tags.
pub fn head_len(tag: u8) -> usize {
    match tag {
        // [tag] only: sign, tern, dense, msync frames
        1 | 2 | 4 | 11 | 12 => 1,
        // [tag][n: u16]: intavg / relay / dense-sum
        3 | 13 | 14 => 3,
        // [tag][scale: f32]: TernGrad / EF-SignSGD / QSGD uplinks
        6 | 8 | 9 => 5,
        // [tag][n: u16][scale: f32]: TernGrad downlink
        7 => 7,
        // [tag][d: u32][k: u32]: classic sparse
        5 => 9,
        // [tag][d: u32][k: u32][index_bytes: u32]: compact sparse
        10 => 13,
        // chunked envelope header itself
        TAG_CHUNKED => 3,
        _ => 1,
    }
}

/// Logical (payload-accounting) length of a set of per-chunk frames:
/// the length of the equivalent monolithic frames — one copy of each
/// **distinct** frame head plus the concatenated chunk payloads. With a
/// single arm every chunk shares one tag and this is the pre-mixed
/// accounting (one head total); under a mixed per-chunk assignment each
/// arm's chunks splice into that arm's monolithic frame, so each arm
/// pays its head exactly once. A single frame is charged at face value;
/// empty frames (never produced by the encoders) charge nothing.
pub fn frames_payload_len<B: AsRef<[u8]>>(frames: &[B]) -> usize {
    if frames.len() <= 1 {
        return frames.first().map(|f| f.as_ref().len()).unwrap_or(0);
    }
    let mut seen = [false; 256];
    let mut total = 0usize;
    for f in frames {
        let f = f.as_ref();
        let Some(&tag) = f.first() else { continue };
        let head = head_len(tag);
        if !seen[tag as usize] {
            seen[tag as usize] = true;
            total += head.min(f.len());
        }
        total += f.len().saturating_sub(head);
    }
    total
}

/// Logical (payload-accounting) length of a wire message: `msg.len()`
/// for a monolithic frame; the de-duplicated monolithic-equivalent
/// length for a chunked message (see the module docs). Malformed
/// chunked framing falls back to the physical length.
pub fn payload_len(msg: &[u8]) -> usize {
    if !is_chunked(msg) {
        return msg.len();
    }
    match try_unpack(msg) {
        Ok(frames) if !frames.is_empty() => frames_payload_len(&frames),
        _ => msg.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_round_trip() {
        let frames = vec![vec![1u8, 0xAB], vec![1u8, 0xCD, 0xEF], vec![1u8]];
        let msg = pack(&frames);
        assert!(is_chunked(&msg));
        let back = unpack(&msg).unwrap();
        assert_eq!(back.len(), 3);
        for (b, f) in back.iter().zip(&frames) {
            assert_eq!(b, &f.as_slice());
        }
    }

    #[test]
    fn pack_into_plus_fills_is_byte_identical_to_pack() {
        let frames = vec![vec![1u8, 0xAB], vec![1u8, 0xCD, 0xEF], vec![1u8]];
        let lens: Vec<usize> = frames.iter().map(|f| f.len()).collect();
        let mut buf = vec![0x77u8; 3]; // stale reused buffer
        let ranges = pack_into(&mut buf, &lens);
        assert_eq!(ranges.len(), frames.len());
        let views = split_ranges_mut(&mut buf, &ranges);
        for (view, f) in views.into_iter().zip(&frames) {
            view.copy_from_slice(f);
        }
        assert_eq!(buf, pack(&frames));
        // reuse: second layout with different lengths starts clean
        let frames2 = vec![vec![2u8; 5], vec![2u8; 1]];
        let lens2: Vec<usize> = frames2.iter().map(|f| f.len()).collect();
        let ranges2 = pack_into(&mut buf, &lens2);
        for (view, f) in split_ranges_mut(&mut buf, &ranges2).into_iter().zip(&frames2) {
            view.copy_from_slice(f);
        }
        assert_eq!(buf, pack(&frames2));
    }

    #[test]
    fn split_ranges_mut_views_are_disjoint_and_aligned() {
        let mut buf: Vec<u8> = (0..20).collect();
        let ranges = vec![2..5, 5..5, 9..12];
        let views = split_ranges_mut(&mut buf, &ranges);
        assert_eq!(views[0], &[2, 3, 4]);
        assert!(views[1].is_empty());
        assert_eq!(views[2], &[9, 10, 11]);
    }

    #[test]
    fn unpack_rejects_malformed() {
        assert!(unpack(&[]).is_none());
        assert!(unpack(&[1, 2, 3]).is_none(), "wrong tag");
        // truncated length prefix
        assert!(unpack(&[TAG_CHUNKED, 1, 0, 5, 0]).is_none());
        // inner length overruns the buffer
        assert!(unpack(&[TAG_CHUNKED, 1, 0, 9, 0, 0, 0, 1]).is_none());
        // trailing garbage
        let mut msg = pack(&[vec![1u8, 2]]);
        msg.push(0);
        assert!(unpack(&msg).is_none());
    }

    #[test]
    fn try_unpack_names_every_failure() {
        assert_eq!(try_unpack(&[]), Err(ChunkedError::NotChunked));
        assert_eq!(try_unpack(&[4u8, 1, 2]), Err(ChunkedError::NotChunked));
        assert_eq!(try_unpack(&[TAG_CHUNKED]), Err(ChunkedError::TruncatedHeader));
        assert_eq!(
            try_unpack(&[TAG_CHUNKED, 1, 0, 5, 0]),
            Err(ChunkedError::TruncatedLength { chunk: 0 })
        );
        assert_eq!(
            try_unpack(&[TAG_CHUNKED, 1, 0, 9, 0, 0, 0, 1]),
            Err(ChunkedError::Truncated { chunk: 0, need: 9, have: 1 })
        );
        let mut msg = pack(&[vec![1u8, 2]]);
        msg.push(0);
        assert_eq!(try_unpack(&msg), Err(ChunkedError::TrailingBytes { extra: 1 }));
        // empty inner frame and non-codec inner tags are named too
        assert_eq!(try_unpack(&pack(&[vec![]])), Err(ChunkedError::EmptyFrame { chunk: 0 }));
        assert_eq!(
            try_unpack(&pack(&[vec![1u8, 2], vec![TAG_CHUNKED, 0, 0]])),
            Err(ChunkedError::UnknownTag { chunk: 1, tag: TAG_CHUNKED })
        );
        assert_eq!(
            try_unpack(&pack(&[vec![0u8]])),
            Err(ChunkedError::UnknownTag { chunk: 0, tag: 0 })
        );
        // the error text carries the specifics for the CLI/test layers
        let err = try_unpack(&pack(&[vec![200u8, 1]])).unwrap_err();
        assert!(err.to_string().contains("unknown inner tag 200"), "{err}");
    }

    #[test]
    fn payload_len_is_monolithic_equivalent() {
        // three sign chunks: heads de-duplicate to one tag byte
        let frames = vec![vec![1u8, 0x11, 0x22], vec![1u8, 0x33], vec![1u8, 0x44]];
        let msg = pack(&frames);
        assert_eq!(payload_len(&msg), 1 + 4);
        // monolithic messages are charged at face value
        assert_eq!(payload_len(&[4u8, 0, 0, 0, 0]), 5);
        // intavg chunks repeat a 3-byte head
        let frames = vec![vec![3u8, 4, 0, 0xAA], vec![3u8, 4, 0, 0xBB, 0xCC]];
        assert_eq!(payload_len(&pack(&frames)), 3 + 3);
    }

    #[test]
    fn payload_len_charges_one_head_per_distinct_tag() {
        // a mixed-assignment envelope: two sign chunks + one dense chunk
        // = the sign monolithic frame spliced (1 head + payloads) plus a
        // separate dense frame (1 head + payload)
        let frames = vec![
            vec![1u8, 0xAA, 0xBB],
            vec![4u8, 1, 2, 3, 4],
            vec![1u8, 0xCC],
        ];
        assert_eq!(payload_len(&pack(&frames)), (1 + 3) + (1 + 4));
        // interleaving does not change the charge (order-independent)
        let frames = vec![
            vec![4u8, 1, 2, 3, 4],
            vec![1u8, 0xAA, 0xBB],
            vec![1u8, 0xCC],
        ];
        assert_eq!(payload_len(&pack(&frames)), (1 + 3) + (1 + 4));
        // sign + intavg mixes charge each head once
        let frames = vec![vec![1u8, 0x11], vec![3u8, 4, 0, 0xAA], vec![3u8, 4, 0, 0xBB]];
        assert_eq!(payload_len(&pack(&frames)), (1 + 1) + (3 + 2));
    }

    #[test]
    fn payload_len_falls_back_on_malformed_chunked() {
        let bad = vec![TAG_CHUNKED, 9, 9, 1, 2, 3];
        assert_eq!(payload_len(&bad), bad.len());
    }
}
