//! Dense f32 codec — the 32d-bit baseline channel (Global Lion/AdamW).
//!
//! The public functions route through the vectorized kernels in
//! [`super::simd`] (LE memcpy pack/unpack, explicit-width accumulate);
//! the original per-element loops are kept as `*_scalar` parity oracles
//! (pinned bit-exact in `tests/simd_kernels.rs` and re-asserted by the
//! hotpath bench before timing).

use super::simd;

/// Payload bytes for `d` f32 values.
#[inline]
pub fn packed_len(d: usize) -> usize {
    4 * d
}

/// Encode f32 slice as little-endian bytes.
pub fn pack(values: &[f32]) -> Vec<u8> {
    let mut out = vec![0u8; packed_len(values.len())];
    simd::dense_pack_into(values, &mut out);
    out
}

/// Encode into a preallocated buffer at analytic offsets — the
/// zero-copy frame-assembly primitive: tag-14/15 envelopes lay dense
/// frames in place the way sign frames already are
/// (`chunked::pack_into` + per-range writes, no intermediate `Vec`).
pub fn pack_into(values: &[f32], out: &mut [u8]) {
    assert_eq!(out.len(), packed_len(values.len()), "dense output size mismatch");
    simd::dense_pack_into(values, out);
}

/// Scalar oracle for [`pack`] (§Perf parity baseline).
pub fn pack_scalar(values: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(packed_len(values.len()));
    for &v in values {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Decode all f32 values.
pub fn unpack(payload: &[u8]) -> Vec<f32> {
    assert!(payload.len() % 4 == 0, "dense payload not a multiple of 4");
    let mut out = vec![0.0f32; payload.len() / 4];
    simd::dense_unpack_into(payload, &mut out);
    out
}

/// Decode into a preallocated buffer.
pub fn unpack_into(payload: &[u8], out: &mut [f32]) {
    assert_eq!(payload.len(), 4 * out.len(), "dense payload size mismatch");
    simd::dense_unpack_into(payload, out);
}

/// Scalar oracle for [`unpack_into`].
pub fn unpack_into_scalar(payload: &[u8], out: &mut [f32]) {
    assert_eq!(payload.len(), 4 * out.len(), "dense payload size mismatch");
    for (o, c) in out.iter_mut().zip(payload.chunks_exact(4)) {
        *o = f32::from_le_bytes(c.try_into().unwrap());
    }
}

/// Accumulate decoded values into `acc` (server-side gradient averaging
/// hot path — no intermediate allocation). Bit-exact with
/// [`accumulate_scalar`] on every dispatch tier: the vector adds are
/// independent per-lane IEEE ops, never reassociated.
pub fn accumulate(payload: &[u8], acc: &mut [f32]) {
    assert_eq!(payload.len(), 4 * acc.len(), "dense payload size mismatch");
    simd::dense_accumulate(payload, acc);
}

/// Scalar oracle for [`accumulate`].
pub fn accumulate_scalar(payload: &[u8], acc: &mut [f32]) {
    assert_eq!(payload.len(), 4 * acc.len(), "dense payload size mismatch");
    for (a, c) in acc.iter_mut().zip(payload.chunks_exact(4)) {
        *a += f32::from_le_bytes(c.try_into().unwrap());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;

    #[test]
    fn roundtrip_bit_exact() {
        testing::forall(
            0x91,
            64,
            |r| testing::gen_vec_normal(r, 0, 300, 10.0),
            |v| unpack(&pack(v)) == *v,
        );
    }

    #[test]
    fn special_values() {
        let v = [f32::INFINITY, f32::NEG_INFINITY, 0.0, -0.0, f32::MIN_POSITIVE];
        let back = unpack(&pack(&v));
        assert_eq!(v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                   back.iter().map(|x| x.to_bits()).collect::<Vec<_>>());
    }

    #[test]
    fn accumulate_sums() {
        let a = pack(&[1.0, 2.0]);
        let b = pack(&[0.5, -1.0]);
        let mut acc = vec![0.0f32; 2];
        accumulate(&a, &mut acc);
        accumulate(&b, &mut acc);
        assert_eq!(acc, vec![1.5, 1.0]);
    }

    #[test]
    fn pack_matches_scalar_oracle() {
        testing::forall(
            0x92,
            64,
            |r| testing::gen_vec_normal(r, 0, 300, 10.0),
            |v| pack(v) == pack_scalar(v),
        );
    }

    #[test]
    fn pack_into_matches_pack() {
        let v: Vec<f32> = (0..37).map(|i| i as f32 * 0.25 - 4.0).collect();
        let mut out = vec![0u8; packed_len(v.len())];
        pack_into(&v, &mut out);
        assert_eq!(out, pack(&v));
    }

    #[test]
    #[should_panic(expected = "dense output size mismatch")]
    fn pack_into_rejects_wrong_size() {
        let mut out = vec![0u8; 7];
        pack_into(&[1.0, 2.0], &mut out);
    }

    #[test]
    fn size_is_32_bits_per_elem() {
        assert_eq!(packed_len(1_000_000), 4_000_000);
    }
}
