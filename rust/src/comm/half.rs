//! bf16 codec — the 16-bit half-precision middle ground between dense
//! f32 and the paper's 1-bit updates. Common production practice for
//! gradient all-reduce; included so Figure-4-style studies can place
//! D-Lion against the *de facto* baseline as well as the published ones.
//!
//! bf16 = the top 16 bits of IEEE f32 (8-bit exponent preserved), with
//! round-to-nearest-even on encode. The public pack/unpack/accumulate
//! route through [`super::simd`]'s branchless-rounding kernels (8 lanes
//! per AVX2 register); the per-element loops here remain as `*_scalar`
//! parity oracles.

use super::simd;

/// Payload bytes for `d` bf16 values.
#[inline]
pub fn packed_len(d: usize) -> usize {
    2 * d
}

/// f32 → bf16 with round-to-nearest-even.
#[inline]
pub fn to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040; // quiet NaN
    }
    // round-to-nearest-even on the truncated 16 bits: round up when the
    // dropped half exceeds a tie (round bit set + any sticky bit), or on
    // an exact tie when the kept mantissa is odd
    let round_bit = (bits >> 15) & 1;
    let sticky = bits & 0x7FFF;
    let mut hi = (bits >> 16) as u16;
    if round_bit == 1 && (sticky != 0 || hi & 1 == 1) {
        hi = hi.wrapping_add(1);
    }
    hi
}

/// bf16 → f32 (exact).
#[inline]
pub fn from_bf16_bits(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Encode an f32 slice as bf16 LE bytes (16 bits/param).
pub fn pack(values: &[f32]) -> Vec<u8> {
    let mut out = vec![0u8; packed_len(values.len())];
    simd::bf16_pack_into(values, &mut out);
    out
}

/// Encode into a preallocated buffer at analytic offsets.
pub fn pack_into(values: &[f32], out: &mut [u8]) {
    assert_eq!(out.len(), packed_len(values.len()), "bf16 output size mismatch");
    simd::bf16_pack_into(values, out);
}

/// Scalar oracle for [`pack`].
pub fn pack_scalar(values: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(packed_len(values.len()));
    for &v in values {
        out.extend_from_slice(&to_bf16_bits(v).to_le_bytes());
    }
    out
}

/// Decode into a preallocated f32 buffer.
pub fn unpack_into(payload: &[u8], out: &mut [f32]) {
    assert_eq!(payload.len(), 2 * out.len(), "bf16 payload size mismatch");
    simd::bf16_unpack_into(payload, out);
}

/// Scalar oracle for [`unpack_into`].
pub fn unpack_into_scalar(payload: &[u8], out: &mut [f32]) {
    assert_eq!(payload.len(), 2 * out.len(), "bf16 payload size mismatch");
    for (o, c) in out.iter_mut().zip(payload.chunks_exact(2)) {
        *o = from_bf16_bits(u16::from_le_bytes(c.try_into().unwrap()));
    }
}

/// Decode all values.
pub fn unpack(payload: &[u8]) -> Vec<f32> {
    assert!(payload.len() % 2 == 0, "bf16 payload not a multiple of 2");
    let mut out = vec![0.0f32; payload.len() / 2];
    unpack_into(payload, &mut out);
    out
}

/// Accumulate decoded values into `acc` (server averaging hot path).
/// Bit-exact with [`accumulate_scalar`] on every dispatch tier: the
/// vector adds are independent per-lane IEEE ops, never reassociated.
pub fn accumulate(payload: &[u8], acc: &mut [f32]) {
    assert_eq!(payload.len(), 2 * acc.len(), "bf16 payload size mismatch");
    simd::bf16_accumulate(payload, acc);
}

/// Scalar oracle for [`accumulate`].
pub fn accumulate_scalar(payload: &[u8], acc: &mut [f32]) {
    assert_eq!(payload.len(), 2 * acc.len(), "bf16 payload size mismatch");
    for (a, c) in acc.iter_mut().zip(payload.chunks_exact(2)) {
        *a += from_bf16_bits(u16::from_le_bytes(c.try_into().unwrap()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;

    #[test]
    fn exact_for_bf16_representable() {
        for v in [0.0f32, 1.0, -2.0, 0.5, 256.0, -1024.0] {
            assert_eq!(unpack(&pack(&[v])), vec![v]);
        }
    }

    #[test]
    fn relative_error_within_bf16_ulp() {
        testing::forall(
            0xC01,
            200,
            |r| r.normal_f32(0.0, 100.0),
            |&x| {
                let back = from_bf16_bits(to_bf16_bits(x));
                // bf16 has 8 significand bits -> rel err <= 2^-8
                x == 0.0 || ((back - x) / x).abs() <= 1.0 / 256.0
            },
        );
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-9 is exactly halfway between 1.0 and the next bf16;
        // ties-to-even keeps the even (1.0) mantissa.
        let halfway = f32::from_bits(0x3F80_8000);
        assert_eq!(to_bf16_bits(halfway), 0x3F80);
        // just above halfway rounds up
        let above = f32::from_bits(0x3F80_8001);
        assert_eq!(to_bf16_bits(above), 0x3F81);
    }

    #[test]
    fn specials() {
        assert!(from_bf16_bits(to_bf16_bits(f32::NAN)).is_nan());
        assert_eq!(from_bf16_bits(to_bf16_bits(f32::INFINITY)), f32::INFINITY);
        assert_eq!(from_bf16_bits(to_bf16_bits(-0.0)).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn size_is_16_bits_per_param() {
        assert_eq!(pack(&vec![1.0f32; 1000]).len(), 2000);
    }

    #[test]
    fn accumulate_sums() {
        let a = pack(&[1.0, 2.0]);
        let mut acc = vec![0.5f32; 2];
        accumulate(&a, &mut acc);
        assert_eq!(acc, vec![1.5, 2.5]);
    }

    #[test]
    fn pack_matches_scalar_oracle() {
        testing::forall(
            0xC02,
            64,
            |r| testing::gen_vec_normal(r, 0, 300, 50.0),
            |v| pack(v) == pack_scalar(v),
        );
    }

    #[test]
    fn pack_into_matches_pack() {
        let v: Vec<f32> = (0..41).map(|i| i as f32 * 0.3 - 6.0).collect();
        let mut out = vec![0u8; packed_len(v.len())];
        pack_into(&v, &mut out);
        assert_eq!(out, pack(&v));
    }

    #[test]
    #[should_panic(expected = "bf16 payload not a multiple of 2")]
    fn unpack_rejects_odd_payload() {
        unpack(&[0u8, 1, 2]);
    }
}
