//! log(N)-bit integer codec for the D-Lion (Avg) downlink.
//!
//! After the server sums N strictly-binary worker updates, each element
//! S[k] = Σ_i δ_i[k] lies in {−N, −N+2, …, N} — exactly N+1 values with
//! S ≡ N (mod 2). We encode the rank r = (S+N)/2 ∈ {0..N} using
//! b = ⌈log2(N+1)⌉ bits per element, bit-packed. This matches Table 1's
//! "log(n)·d" server→worker bandwidth for Distributed Lion-Avg.
//!
//! For b ≤ 8 (N ≤ 255 — every practical cluster) the public functions
//! route through [`super::simd`]'s 8-ranks-per-u64 kernels: eight b-bit
//! ranks always span exactly b whole bytes, so each group is one
//! combined word build + one store instead of a per-element flush loop.
//! The original shift-register loops are kept as `*_scalar` parity
//! oracles and as the b > 8 fallback.

use super::simd;
use crate::util::math::bits_for_count;

/// Bits per element for vote sums over `n` workers.
#[inline]
pub fn bits_per_elem(n: usize) -> u32 {
    bits_for_count(n) // ceil(log2(n+1))
}

/// Payload bytes for `d` elements over `n` workers.
#[inline]
pub fn packed_len(d: usize, n: usize) -> usize {
    ((d as u64 * bits_per_elem(n) as u64).div_ceil(8)) as usize
}

/// Pack vote sums S[k] ∈ {-n..n}, S[k] ≡ n (mod 2).
pub fn pack(sums: &[i32], n: usize) -> Vec<u8> {
    let b = bits_per_elem(n);
    #[cfg(debug_assertions)]
    for &s in sums {
        debug_assert!(
            s.unsigned_abs() as usize <= n && (s + n as i32) % 2 == 0,
            "vote sum {s} invalid for n={n}"
        );
    }
    if !(1..=8).contains(&b) {
        return pack_scalar(sums, n);
    }
    let mut out = vec![0u8; packed_len(sums.len(), n)];
    // rank = (s + n) / 2 = (s - lo) >> 1 with lo = -n
    simd::bitpack8_into(sums, -(n as i32), 1, b, &mut out);
    out
}

/// Scalar oracle for [`pack`], and the b > 8 fallback.
///
/// §Perf optimization #2: a 64-bit shift register replaces the per-bit
/// write loop — one bounds-checked store per *byte* instead of per bit.
pub fn pack_scalar(sums: &[i32], n: usize) -> Vec<u8> {
    let b = bits_per_elem(n);
    let mut out = Vec::with_capacity(packed_len(sums.len(), n));
    let mut acc: u64 = 0;
    let mut nbits: u32 = 0;
    for &s in sums {
        debug_assert!(
            s.unsigned_abs() as usize <= n && (s + n as i32) % 2 == 0,
            "vote sum {s} invalid for n={n}"
        );
        let rank = ((s + n as i32) / 2) as u64;
        acc |= rank << nbits;
        nbits += b;
        while nbits >= 8 {
            out.push(acc as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out.push(acc as u8);
    }
    debug_assert_eq!(out.len(), packed_len(sums.len(), n));
    out
}

/// Reference per-bit implementation (§Perf ablation oracle).
pub fn pack_naive(sums: &[i32], n: usize) -> Vec<u8> {
    let b = bits_per_elem(n);
    let mut out = vec![0u8; packed_len(sums.len(), n)];
    let mut bitpos = 0usize;
    for &s in sums {
        let rank = ((s + n as i32) / 2) as u32;
        let mut remaining = b;
        let mut val = rank;
        while remaining > 0 {
            let byte = bitpos >> 3;
            let off = bitpos & 7;
            let take = (8 - off).min(remaining as usize) as u32;
            out[byte] |= ((val & ((1 << take) - 1)) as u8) << off;
            val >>= take;
            remaining -= take;
            bitpos += take as usize;
        }
    }
    out
}

/// Unpack `d` vote sums.
pub fn unpack(packed: &[u8], d: usize, n: usize) -> Vec<i32> {
    let mut out = vec![0i32; d];
    unpack_into(packed, n, &mut out);
    out
}

/// Unpack into a preallocated buffer (8 ranks per u64 register for the
/// practical b ≤ 8 widths).
pub fn unpack_into(packed: &[u8], n: usize, out: &mut [i32]) {
    let b = bits_per_elem(n);
    if !(1..=8).contains(&b) {
        unpack_into_scalar(packed, n, out);
        return;
    }
    // s = rank * 2 - n = (rank << 1) + lo with lo = -n
    simd::bitunpack8_into(packed, -(n as i32), 1, b, out);
}

/// Scalar oracle for [`unpack_into`] (u64 shift register, one element
/// decoded per iteration), and the b > 8 fallback.
pub fn unpack_into_scalar(packed: &[u8], n: usize, out: &mut [i32]) {
    let b = bits_per_elem(n);
    let mask: u64 = (1u64 << b) - 1;
    let mut acc: u64 = 0;
    let mut nbits: u32 = 0;
    let mut pos = 0usize;
    for o in out.iter_mut() {
        while nbits < b {
            acc |= (packed[pos] as u64) << nbits;
            pos += 1;
            nbits += 8;
        }
        *o = (acc & mask) as i32 * 2 - n as i32;
        acc >>= b;
        nbits -= b;
    }
}

// ---------------------------------------------------------------------------
// General small-integer range packing (TernGrad downlink: S ∈ {−N..N},
// no parity constraint, ⌈log2(2N+1)⌉ bits/element).
// ---------------------------------------------------------------------------

/// Bits per element for integers in [lo, hi].
#[inline]
pub fn bits_for_range(lo: i32, hi: i32) -> u32 {
    debug_assert!(hi >= lo);
    bits_for_count((hi - lo) as usize)
}

/// Payload bytes for `d` integers in [lo, hi].
#[inline]
pub fn packed_len_range(d: usize, lo: i32, hi: i32) -> usize {
    ((d as u64 * bits_for_range(lo, hi) as u64).div_ceil(8)) as usize
}

/// Pack integers in [lo, hi] with the minimal fixed bit width.
pub fn pack_range(vals: &[i32], lo: i32, hi: i32) -> Vec<u8> {
    let b = bits_for_range(lo, hi);
    #[cfg(debug_assertions)]
    for &s in vals {
        debug_assert!((lo..=hi).contains(&s), "value {s} outside [{lo},{hi}]");
    }
    if !(1..=8).contains(&b) {
        return pack_range_scalar(vals, lo, hi);
    }
    let mut out = vec![0u8; packed_len_range(vals.len(), lo, hi)];
    simd::bitpack8_into(vals, lo, 0, b, &mut out);
    out
}

/// Scalar per-bit oracle for [`pack_range`], and the b > 8 fallback.
pub fn pack_range_scalar(vals: &[i32], lo: i32, hi: i32) -> Vec<u8> {
    let b = bits_for_range(lo, hi);
    let mut out = vec![0u8; packed_len_range(vals.len(), lo, hi)];
    let mut bitpos = 0usize;
    for &s in vals {
        debug_assert!((lo..=hi).contains(&s), "value {s} outside [{lo},{hi}]");
        let mut val = (s - lo) as u32;
        let mut remaining = b;
        while remaining > 0 {
            let byte = bitpos >> 3;
            let off = bitpos & 7;
            let take = (8 - off).min(remaining as usize) as u32;
            out[byte] |= ((val & ((1 << take) - 1)) as u8) << off;
            val >>= take;
            remaining -= take;
            bitpos += take as usize;
        }
    }
    out
}

/// Unpack `d` integers in [lo, hi].
pub fn unpack_range(packed: &[u8], d: usize, lo: i32, hi: i32) -> Vec<i32> {
    let b = bits_for_range(lo, hi);
    let mut out = vec![0i32; d];
    if !(1..=8).contains(&b) {
        unpack_range_scalar_into(packed, lo, hi, &mut out);
        return out;
    }
    simd::bitunpack8_into(packed, lo, 0, b, &mut out);
    out
}

/// Scalar per-bit oracle for [`unpack_range`], and the b > 8 fallback.
pub fn unpack_range_scalar_into(packed: &[u8], lo: i32, hi: i32, out: &mut [i32]) {
    let b = bits_for_range(lo, hi);
    let mut bitpos = 0usize;
    for o in out.iter_mut() {
        let mut rank = 0u32;
        let mut got = 0u32;
        while got < b {
            let byte = bitpos >> 3;
            let off = bitpos & 7;
            let take = (8 - off).min((b - got) as usize) as u32;
            let bits = (packed[byte] >> off) as u32 & ((1 << take) - 1);
            rank |= bits << got;
            got += take;
            bitpos += take as usize;
        }
        *o = rank as i32 + lo;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::Rng;

    fn gen_sums(rng: &mut Rng, d: usize, n: usize) -> Vec<i32> {
        (0..d)
            .map(|_| {
                // sum of n random ±1
                (0..n).map(|_| if rng.next_u64() & 1 == 0 { 1i32 } else { -1 }).sum()
            })
            .collect()
    }

    #[test]
    fn roundtrip_all_worker_counts() {
        for n in [1usize, 2, 3, 4, 7, 8, 16, 31, 32, 33] {
            testing::forall(
                0x60 + n as u64,
                32,
                |r| {
                    let d = r.below(150);
                    gen_sums(r, d, n)
                },
                |sums| unpack(&pack(sums, n), sums.len(), n) == *sums,
            );
        }
    }

    #[test]
    fn roundtrip_beyond_byte_wide_ranks() {
        // n = 300 → b = 9 > 8: the scalar fallback path must still
        // roundtrip (vote parity: sums share n's parity).
        let n = 300usize;
        let sums: Vec<i32> = (-150..=150).map(|s| s * 2).collect();
        assert_eq!(bits_per_elem(n), 9);
        assert_eq!(unpack(&pack(&sums, n), sums.len(), n), sums);
        assert_eq!(pack(&sums, n), pack_naive(&sums, n));
    }

    #[test]
    fn bits_per_elem_matches_table1() {
        // Table 1: server→worker log(n)·d bits for D-Lion Avg.
        assert_eq!(bits_per_elem(4), 3); // ceil(log2(5))
        assert_eq!(bits_per_elem(8), 4);
        assert_eq!(bits_per_elem(16), 5);
        assert_eq!(bits_per_elem(32), 6);
    }

    #[test]
    fn packed_size_exact() {
        // 100 elems, n=4 -> 3 bits each -> 300 bits -> 38 bytes
        assert_eq!(packed_len(100, 4), 38);
        // n=1 -> 1 bit each, same as sign codec
        assert_eq!(packed_len(64, 1), 8);
    }

    #[test]
    fn extremes_roundtrip() {
        for n in [1usize, 5, 32] {
            let sums = vec![n as i32, -(n as i32)];
            assert_eq!(unpack(&pack(&sums, n), 2, n), sums);
        }
    }

    #[test]
    fn fast_pack_matches_naive() {
        for n in [1usize, 2, 4, 7, 32, 64] {
            testing::forall(
                0x68 + n as u64,
                32,
                |r| {
                    let d = r.below(300);
                    gen_sums(r, d, n)
                },
                |sums| pack(sums, n) == pack_naive(sums, n) && pack(sums, n) == pack_scalar(sums, n),
            );
        }
    }

    #[test]
    fn range_roundtrip() {
        for (lo, hi) in [(-4i32, 4i32), (0, 1), (-32, 32), (-1, 1), (0, 255)] {
            testing::forall(
                0x65 + hi as u64,
                32,
                |r| {
                    let d = r.below(100);
                    (0..d)
                        .map(|_| lo + r.below((hi - lo + 1) as usize) as i32)
                        .collect::<Vec<i32>>()
                },
                |vals| unpack_range(&pack_range(vals, lo, hi), vals.len(), lo, hi) == *vals,
            );
        }
    }

    #[test]
    fn range_pack_matches_scalar_oracle() {
        for (lo, hi) in [(-4i32, 4i32), (-32, 32), (0, 255), (-1000, 1000)] {
            testing::forall(
                0x6A + hi as u64,
                32,
                |r| {
                    let d = r.below(120);
                    (0..d)
                        .map(|_| lo + r.below((hi - lo + 1) as usize) as i32)
                        .collect::<Vec<i32>>()
                },
                |vals| pack_range(vals, lo, hi) == pack_range_scalar(vals, lo, hi),
            );
        }
    }

    #[test]
    fn range_bits_match_terngrad_table1() {
        // TernGrad downlink: ceil(log2(2N+1)) bits per element.
        assert_eq!(bits_for_range(-4, 4), 4); // N=4: 9 values -> 4 bits
        assert_eq!(bits_for_range(-32, 32), 7); // N=32: 65 values -> 7 bits
    }
}
