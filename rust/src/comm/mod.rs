//! Communication layer: bit-exact codecs + byte-counted transports.
//!
//! Codec → Table 1 mapping (d parameters, N workers):
//!
//! | channel                         | codec          | bits/param        |
//! |---------------------------------|----------------|-------------------|
//! | D-Lion worker→server            | [`sign`]       | 1                 |
//! | D-Lion MaVo server→worker       | [`sign`]/[`tern`] | 1 (odd N) / 1.6 (even N, ties) |
//! | D-Lion Avg server→worker        | [`intavg`]     | ⌈log2(N+1)⌉       |
//! | TernGrad worker→server          | [`tern`]       | 1.6 (≈1.585 opt.) |
//! | TernGrad server→worker          | [`intavg`]-style sum | ⌈log2(2N+1)⌉ |
//! | GradDrop/DGC worker→server      | [`sparse`]     | 64·(1−η)          |
//! | Global (and DGC down) channels  | [`dense`]      | 32                |

pub mod chunked;
pub mod dense;
pub mod half;
pub mod intavg;
pub mod sign;
pub mod simd;
pub mod simnet;
pub mod sparse;
pub mod swar;
pub mod tcp;
pub mod tern;
pub mod transport;
pub mod varint;

pub use transport::{inproc_fabric, CommStats, Message, ServerTransport, WorkerTransport};
