//! 1-bit sign-vector codec (Distributed Lion uplink; MaVo downlink).
//!
//! Packs a strictly binary vector δ ∈ {−1,+1}^d into ⌈d/8⌉ bytes
//! (bit 1 ⇒ +1), i.e. exactly the `d` bits per parameter the paper's
//! Table 1 reports for the D-Lion worker→server channel.

/// Number of payload bytes for `d` elements.
#[inline]
pub fn packed_len(d: usize) -> usize {
    d.div_ceil(8)
}

/// Pack signs (as i8 in {-1,+1}) into bits. Panics on values outside {-1,+1}.
pub fn pack(signs: &[i8]) -> Vec<u8> {
    let mut out = vec![0u8; packed_len(signs.len())];
    for (i, &s) in signs.iter().enumerate() {
        debug_assert!(s == 1 || s == -1, "sign codec requires strictly binary input");
        if s > 0 {
            out[i >> 3] |= 1 << (i & 7);
        }
    }
    out
}

/// Pack from the sign bit of f32 values: v >= 0.0 ⇒ +1. This is the hot-path
/// variant used by the worker: it never materializes the i8 vector. Routed
/// through the SWAR word gather (§Perf optimization #4,
/// [`crate::comm::swar::pack_f32_into`]); [`pack_f32_scalar`] is the oracle.
pub fn pack_f32(values: &[f32]) -> Vec<u8> {
    let mut out = vec![0u8; packed_len(values.len())];
    super::swar::pack_f32_into(values, &mut out);
    out
}

/// Reference per-lane implementation of [`pack_f32`] (kept as the §Perf
/// ablation baseline and the property-test oracle for the SWAR gather).
pub fn pack_f32_scalar(values: &[f32]) -> Vec<u8> {
    let mut out = vec![0u8; packed_len(values.len())];
    // Process 8 at a time: build a byte from the IEEE sign bits.
    let chunks = values.chunks_exact(8);
    let rem = chunks.remainder();
    for (ci, chunk) in chunks.enumerate() {
        let mut byte = 0u8;
        for (j, &v) in chunk.iter().enumerate() {
            // sign bit 0 => v >= 0 (or +0.0) => +1
            byte |= (((v.to_bits() >> 31) ^ 1) as u8) << j;
        }
        out[ci] = byte;
    }
    let base = values.len() - rem.len();
    for (j, &v) in rem.iter().enumerate() {
        if v.to_bits() >> 31 == 0 {
            out[(base + j) >> 3] |= 1 << ((base + j) & 7);
        }
    }
    out
}

/// Unpack `d` signs into i8 {-1,+1}.
pub fn unpack(packed: &[u8], d: usize) -> Vec<i8> {
    assert!(packed.len() >= packed_len(d), "sign payload too short");
    let mut out = vec![0i8; d];
    unpack_into(packed, &mut out);
    out
}

/// Unpack into a preallocated buffer (hot path, no allocation): full
/// bytes expand through [`VOTE_LUT`] — one table row copy per 8 lanes
/// instead of 8 shift/mask selects — with a per-bit loop only for the
/// final partial byte.
pub fn unpack_into(packed: &[u8], out: &mut [i8]) {
    let full = out.len() / 8;
    let (head, tail) = out.split_at_mut(full * 8);
    for (ci, chunk) in head.chunks_exact_mut(8).enumerate() {
        chunk.copy_from_slice(&VOTE_LUT[packed[ci] as usize]);
    }
    for (j, o) in tail.iter_mut().enumerate() {
        let i = full * 8 + j;
        *o = if packed[i >> 3] >> (i & 7) & 1 == 1 { 1 } else { -1 };
    }
}

/// Byte → 8 signs lookup table (built at compile time): the server's
/// vote-accumulation inner loop reads one byte and adds 8 precomputed
/// ±1 values instead of doing 8 shift/mask ops (§Perf optimization #1,
/// ~3× over the per-bit loop — see `cargo bench --bench hotpath`).
static VOTE_LUT: [[i8; 8]; 256] = {
    let mut lut = [[0i8; 8]; 256];
    let mut byte = 0usize;
    while byte < 256 {
        let mut j = 0;
        while j < 8 {
            lut[byte][j] = if (byte >> j) & 1 == 1 { 1 } else { -1 };
            j += 1;
        }
        byte += 1;
    }
    lut
};

/// Accumulate unpacked signs into an i32 vote buffer: votes[i] += δ[i].
/// This is the server's majority-vote hot path: it never materializes
/// the i8 vector for each worker.
pub fn accumulate_votes(packed: &[u8], votes: &mut [i32]) {
    let chunks = votes.chunks_exact_mut(8);
    let len = chunks.len();
    for (ci, chunk) in chunks.enumerate() {
        let lut = &VOTE_LUT[packed[ci] as usize];
        for j in 0..8 {
            chunk[j] += lut[j] as i32;
        }
    }
    for i in len * 8..votes.len() {
        let bit = (packed[i >> 3] >> (i & 7)) & 1;
        votes[i] += (bit as i32) * 2 - 1;
    }
}

/// Reference per-bit implementation (kept for the §Perf ablation bench
/// and as the property-test oracle for [`accumulate_votes`]).
pub fn accumulate_votes_naive(packed: &[u8], votes: &mut [i32]) {
    for (i, v) in votes.iter_mut().enumerate() {
        let bit = (packed[i >> 3] >> (i & 7)) & 1;
        *v += (bit as i32) * 2 - 1; // 1 -> +1, 0 -> -1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::Rng;

    #[test]
    fn roundtrip_exact() {
        testing::forall(
            0x51,
            128,
            |r| testing::gen_vec_sign(r, 0, 300),
            |signs| unpack(&pack(signs), signs.len()) == *signs,
        );
    }

    #[test]
    fn packed_size_is_ceil_d_over_8() {
        for d in [0, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let signs = vec![1i8; d];
            assert_eq!(pack(&signs).len(), d.div_ceil(8));
        }
    }

    #[test]
    fn pack_f32_matches_pack_of_signs() {
        let mut rng = Rng::new(0x52);
        for _ in 0..64 {
            let v = testing::gen_vec_normal(&mut rng, 0, 200, 1.0);
            let signs: Vec<i8> = v.iter().map(|&x| if x >= 0.0 { 1 } else { -1 }).collect();
            assert_eq!(pack_f32(&v), pack(&signs));
        }
    }

    #[test]
    fn pack_f32_swar_matches_scalar_for_all_remainders() {
        let mut rng = Rng::new(0x56);
        for base in [0usize, 8, 64, 320] {
            for rem in 0..8usize {
                let d = base + rem;
                let mut v = vec![0.0f32; d];
                rng.fill_normal(&mut v, 1.0);
                if d > 0 {
                    v[rng.below(d)] = -0.0;
                    v[rng.below(d)] = 0.0;
                }
                assert_eq!(pack_f32(&v), pack_f32_scalar(&v), "d={d}");
            }
        }
    }

    #[test]
    fn unpack_roundtrips_all_remainder_lengths() {
        // every remainder 0..8 on top of whole-byte spans, so both the
        // LUT row copy and the partial-byte tail are exercised
        let mut rng = Rng::new(0x57);
        for base in [0usize, 8, 56, 128] {
            for rem in 0..8usize {
                let d = base + rem;
                let signs: Vec<i8> =
                    (0..d).map(|_| if rng.next_u64() & 1 == 0 { 1 } else { -1 }).collect();
                assert_eq!(unpack(&pack(&signs), d), signs, "d={d}");
            }
        }
    }

    #[test]
    fn pack_f32_zero_is_positive() {
        assert_eq!(unpack(&pack_f32(&[0.0]), 1), vec![1]);
        assert_eq!(unpack(&pack_f32(&[-0.0]), 1), vec![-1]); // IEEE -0 has sign bit set
    }

    #[test]
    fn lut_accumulate_matches_naive() {
        let mut rng = Rng::new(0x54);
        for _ in 0..64 {
            let d = rng.below(300) + 1;
            let signs = (0..d)
                .map(|_| if rng.next_u64() & 1 == 0 { 1i8 } else { -1 })
                .collect::<Vec<_>>();
            let packed = pack(&signs);
            let mut fast = vec![3i32; d];
            let mut slow = vec![3i32; d];
            accumulate_votes(&packed, &mut fast);
            accumulate_votes_naive(&packed, &mut slow);
            assert_eq!(fast, slow);
        }
    }

    #[test]
    fn accumulate_votes_equals_sum_of_unpacked() {
        let mut rng = Rng::new(0x53);
        for _ in 0..32 {
            let d = rng.below(200) + 1;
            let n = rng.below(9) + 1;
            let mut votes = vec![0i32; d];
            let mut expect = vec![0i32; d];
            for _ in 0..n {
                let signs = (0..d)
                    .map(|_| if rng.next_u64() & 1 == 0 { 1i8 } else { -1 })
                    .collect::<Vec<_>>();
                let packed = pack(&signs);
                accumulate_votes(&packed, &mut votes);
                for (e, &s) in expect.iter_mut().zip(&signs) {
                    *e += s as i32;
                }
            }
            assert_eq!(votes, expect);
        }
    }
}
