//! Explicit-width vectorized kernels for the non-sign codec families.
//!
//! [`swar`](super::swar) covers the 1-bit sign family with u64
//! SIMD-within-a-register tricks; this module covers everything else on
//! the per-step critical path — the dense f32 codec (g-lion/adamw/sgd
//! server sums and tag-14 partials), the bf16 codec, the intavg
//! log(N)-bit rank codec (the D-Lion-Avg downlink), and the base-3
//! ternary codec — with *explicit-width* vector paths and runtime
//! dispatch:
//!
//! * **AVX2** (`x86`): 8-lane `_mm256_*` kernels behind
//!   `is_x86_feature_detected!("avx2")`.
//! * **SSE2** (`x86`): 4-lane `_mm_*` kernels; SSE2 is architectural on
//!   x86-64, so these need no runtime check of their own.
//! * **Portable**: 8-lane *blocked* scalar loops written so LLVM's
//!   autovectorizer can lift them on any target — the universal
//!   fallback, and the only tier on non-x86 architectures.
//!
//! The tier is detected once (cached in an atomic) and can be clamped
//! down for testing with `DLION_SIMD=portable|sse2|avx2` — the oracle
//! parity suite (`tests/simd_kernels.rs`) exercises every compiled path
//! directly as well.
//!
//! **Oracle pattern** (mirroring `swar.rs`): the codec modules keep
//! their original scalar implementations as `*_scalar` parity oracles;
//! every kernel here must be *bit-exact* against them. That is a real
//! constraint, not an aspiration: dense/bf16 adds are independent
//! per-lane IEEE ops (no reassociation), intavg/tern are integer
//! bit-shuffles, and the bench asserts equality before timing.
//!
//! **Adding a kernel**: write the portable blocked loop first, pin it
//! against the scalar oracle in `tests/simd_kernels.rs` (lengths 0..65,
//! misaligned subranges, special values), then add explicit-width
//! paths under [`x86`] and a dispatch arm in the public wrapper.

/// Vector tier selected at runtime. Ordered so `min` clamps correctly:
/// `Portable < Sse2 < Avx2`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Lanes {
    /// Blocked scalar loops (autovectorizer-friendly) — any target.
    Portable,
    /// 4-lane `_mm_*` kernels — x86-64 baseline.
    Sse2,
    /// 8-lane `_mm256_*` kernels — requires runtime AVX2.
    Avx2,
}

impl Lanes {
    /// Stable lowercase name (lands in the bench JSON).
    pub fn name(self) -> &'static str {
        match self {
            Lanes::Portable => "portable",
            Lanes::Sse2 => "sse2",
            Lanes::Avx2 => "avx2",
        }
    }
}

use std::sync::atomic::{AtomicU8, Ordering};

/// 0 = undetected, else `Lanes` code + 1.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

/// The vector tier every public kernel in this module dispatches to.
/// Detected once per process; `DLION_SIMD=portable|sse2|avx2` clamps
/// the tier down (never above what the hardware supports).
pub fn active() -> Lanes {
    match ACTIVE.load(Ordering::Relaxed) {
        1 => Lanes::Portable,
        2 => Lanes::Sse2,
        3 => Lanes::Avx2,
        _ => {
            let l = detect();
            let code = match l {
                Lanes::Portable => 1,
                Lanes::Sse2 => 2,
                Lanes::Avx2 => 3,
            };
            ACTIVE.store(code, Ordering::Relaxed);
            l
        }
    }
}

fn detect() -> Lanes {
    let hw = hw_lanes();
    match std::env::var("DLION_SIMD").as_deref() {
        Ok("portable") => Lanes::Portable,
        Ok("sse2") => hw.min(Lanes::Sse2),
        _ => hw,
    }
}

#[cfg(target_arch = "x86_64")]
fn hw_lanes() -> Lanes {
    if is_x86_feature_detected!("avx2") {
        Lanes::Avx2
    } else {
        // SSE2 is part of the x86-64 baseline — always present.
        Lanes::Sse2
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn hw_lanes() -> Lanes {
    Lanes::Portable
}

// ---------------------------------------------------------------------------
// Dense f32 codec kernels.
//
// The packed form of a dense payload on a little-endian target IS the
// in-memory form of the `[f32]` slice, so pack/unpack are single
// `memcpy`s — the optimal "vectorization" (the platform memcpy moves
// cachelines at full width). Big-endian targets take the per-element
// scalar path; `accumulate` is the real vector kernel.
// ---------------------------------------------------------------------------

/// Encode `values` as little-endian f32 bytes into `out`
/// (`out.len() == 4 * values.len()`).
pub fn dense_pack_into(values: &[f32], out: &mut [u8]) {
    debug_assert_eq!(out.len(), 4 * values.len());
    if cfg!(target_endian = "little") {
        // SAFETY: f32 is 4 bytes with no padding; the byte view covers
        // exactly the slice, and u8 has alignment 1.
        let bytes = unsafe {
            std::slice::from_raw_parts(values.as_ptr().cast::<u8>(), values.len() * 4)
        };
        out.copy_from_slice(bytes);
    } else {
        for (o, &v) in out.chunks_exact_mut(4).zip(values) {
            o.copy_from_slice(&v.to_le_bytes());
        }
    }
}

/// Decode little-endian f32 bytes into `out`
/// (`payload.len() == 4 * out.len()`).
pub fn dense_unpack_into(payload: &[u8], out: &mut [f32]) {
    debug_assert_eq!(payload.len(), 4 * out.len());
    if cfg!(target_endian = "little") {
        // SAFETY: same layout argument as `dense_pack_into`; every bit
        // pattern is a valid f32.
        let bytes = unsafe {
            std::slice::from_raw_parts_mut(out.as_mut_ptr().cast::<u8>(), out.len() * 4)
        };
        bytes.copy_from_slice(payload);
    } else {
        for (o, c) in out.iter_mut().zip(payload.chunks_exact(4)) {
            *o = f32::from_le_bytes(c.try_into().unwrap());
        }
    }
}

/// `acc[i] += decode(payload[4i..4i+4])` — the server-sum hot loop.
/// Bit-exact with the scalar oracle on every tier: vector adds are
/// independent per-lane IEEE ops, never reassociated.
pub fn dense_accumulate(payload: &[u8], acc: &mut [f32]) {
    debug_assert_eq!(payload.len(), 4 * acc.len());
    match active() {
        #[cfg(target_arch = "x86_64")]
        Lanes::Avx2 => unsafe { x86::dense_accumulate_avx2(payload, acc) },
        #[cfg(target_arch = "x86_64")]
        Lanes::Sse2 => x86::dense_accumulate_sse2(payload, acc),
        _ => dense_accumulate_portable(payload, acc),
    }
}

/// 8-lane blocked portable accumulate (autovectorizer target).
pub fn dense_accumulate_portable(payload: &[u8], acc: &mut [f32]) {
    debug_assert_eq!(payload.len(), 4 * acc.len());
    let mut pc = payload.chunks_exact(32);
    let mut ac = acc.chunks_exact_mut(8);
    for (p, a) in (&mut pc).zip(&mut ac) {
        let mut v = [0.0f32; 8];
        for (x, c) in v.iter_mut().zip(p.chunks_exact(4)) {
            *x = f32::from_le_bytes(c.try_into().unwrap());
        }
        for (dst, x) in a.iter_mut().zip(v) {
            *dst += x;
        }
    }
    for (dst, c) in ac.into_remainder().iter_mut().zip(pc.remainder().chunks_exact(4)) {
        *dst += f32::from_le_bytes(c.try_into().unwrap());
    }
}

// ---------------------------------------------------------------------------
// bf16 codec kernels.
// ---------------------------------------------------------------------------

/// Branchless f32→bf16 round-to-nearest-even on the raw bits.
/// Bit-exact with [`crate::comm::half::to_bf16_bits`]: adding
/// `0x7FFF + lsb(hi)` carries into the kept 16 bits exactly when the
/// dropped half exceeds a tie, or ties with an odd kept mantissa; NaNs
/// select the quieted truncation instead.
#[inline]
pub fn bf16_round(bits: u32) -> u16 {
    let rounded = (bits.wrapping_add(0x7FFF + ((bits >> 16) & 1)) >> 16) as u16;
    let quiet = ((bits >> 16) as u16) | 0x0040;
    if (bits & 0x7FFF_FFFF) > 0x7F80_0000 {
        quiet
    } else {
        rounded
    }
}

/// Encode `values` as bf16 LE bytes into `out`
/// (`out.len() == 2 * values.len()`).
pub fn bf16_pack_into(values: &[f32], out: &mut [u8]) {
    debug_assert_eq!(out.len(), 2 * values.len());
    match active() {
        #[cfg(target_arch = "x86_64")]
        Lanes::Avx2 => unsafe { x86::bf16_pack_into_avx2(values, out) },
        _ => bf16_pack_into_portable(values, out),
    }
}

/// Portable bf16 encode: the branchless round compiles to a select, so
/// the loop stays a straight-line autovectorizer target.
pub fn bf16_pack_into_portable(values: &[f32], out: &mut [u8]) {
    debug_assert_eq!(out.len(), 2 * values.len());
    for (&v, o) in values.iter().zip(out.chunks_exact_mut(2)) {
        o.copy_from_slice(&bf16_round(v.to_bits()).to_le_bytes());
    }
}

/// Decode bf16 LE bytes into `out` (`payload.len() == 2 * out.len()`).
pub fn bf16_unpack_into(payload: &[u8], out: &mut [f32]) {
    debug_assert_eq!(payload.len(), 2 * out.len());
    match active() {
        #[cfg(target_arch = "x86_64")]
        Lanes::Avx2 => unsafe { x86::bf16_unpack_into_avx2(payload, out) },
        _ => bf16_unpack_into_portable(payload, out),
    }
}

/// Portable bf16 decode (a widening shift per element — trivially
/// vectorizable).
pub fn bf16_unpack_into_portable(payload: &[u8], out: &mut [f32]) {
    debug_assert_eq!(payload.len(), 2 * out.len());
    for (o, c) in out.iter_mut().zip(payload.chunks_exact(2)) {
        *o = f32::from_bits((u16::from_le_bytes([c[0], c[1]]) as u32) << 16);
    }
}

/// `acc[i] += decode(payload[2i..2i+2])` — bf16 server averaging.
pub fn bf16_accumulate(payload: &[u8], acc: &mut [f32]) {
    debug_assert_eq!(payload.len(), 2 * acc.len());
    match active() {
        #[cfg(target_arch = "x86_64")]
        Lanes::Avx2 => unsafe { x86::bf16_accumulate_avx2(payload, acc) },
        _ => bf16_accumulate_portable(payload, acc),
    }
}

/// Portable blocked bf16 accumulate.
pub fn bf16_accumulate_portable(payload: &[u8], acc: &mut [f32]) {
    debug_assert_eq!(payload.len(), 2 * acc.len());
    for (a, c) in acc.iter_mut().zip(payload.chunks_exact(2)) {
        *a += f32::from_bits((u16::from_le_bytes([c[0], c[1]]) as u32) << 16);
    }
}

// ---------------------------------------------------------------------------
// Fixed-width bit-packing kernels (intavg ranks, TernGrad range codes).
//
// The wire format is an LSB-first little-endian bit stream of b-bit
// ranks. For b ≤ 8, eight ranks always span exactly b whole bytes
// (8·b bits), so the kernel processes 8 elements per u64 register —
// one combined shift/or word build and one b-byte store per group,
// instead of the scalar path's per-element flush loop.
//
// Ranks are affine codes: `rank = (v - lo) >> shift`, decoded as
// `v = (rank << shift) + lo`. intavg uses `lo = -N, shift = 1`
// (vote sums have N's parity); range codes use `shift = 0`.
// ---------------------------------------------------------------------------

/// Pack `vals` as `b`-bit affine ranks into `out` (`1 <= b <= 8`,
/// `out.len()` = exact packed length `ceil(vals.len()*b/8)`).
pub fn bitpack8_into(vals: &[i32], lo: i32, shift: u32, b: u32, out: &mut [u8]) {
    debug_assert!((1..=8).contains(&b));
    let bb = b as usize;
    let chunks = vals.chunks_exact(8);
    let rem = chunks.remainder();
    let mut off = 0usize;
    for g in chunks {
        let mut word = 0u64;
        for (j, &v) in g.iter().enumerate() {
            let rank = (v.wrapping_sub(lo) as u32 >> shift) as u64;
            word |= rank << (j as u32 * b);
        }
        out[off..off + bb].copy_from_slice(&word.to_le_bytes()[..bb]);
        off += bb;
    }
    // Ragged tail (< 8 elements): scalar shift register, starting at
    // the byte boundary the full groups end on.
    let mut reg = 0u64;
    let mut nbits = 0u32;
    for &v in rem {
        let rank = (v.wrapping_sub(lo) as u32 >> shift) as u64;
        reg |= rank << nbits;
        nbits += b;
        while nbits >= 8 {
            out[off] = reg as u8;
            off += 1;
            reg >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out[off] = reg as u8;
        off += 1;
    }
    debug_assert_eq!(off, out.len());
}

/// Unpack `b`-bit affine ranks from `packed` into `out` (`1 <= b <= 8`).
pub fn bitunpack8_into(packed: &[u8], lo: i32, shift: u32, b: u32, out: &mut [i32]) {
    debug_assert!((1..=8).contains(&b));
    let bb = b as usize;
    let mask = (1u64 << b) - 1;
    let mut chunks = out.chunks_exact_mut(8);
    let mut off = 0usize;
    for g in &mut chunks {
        let mut buf = [0u8; 8];
        buf[..bb].copy_from_slice(&packed[off..off + bb]);
        off += bb;
        let word = u64::from_le_bytes(buf);
        for (j, o) in g.iter_mut().enumerate() {
            let rank = ((word >> (j as u32 * b)) & mask) as i32;
            *o = (rank << shift).wrapping_add(lo);
        }
    }
    let tail = chunks.into_remainder();
    let mut reg = 0u64;
    let mut nbits = 0u32;
    for o in tail.iter_mut() {
        while nbits < b {
            reg |= (packed[off] as u64) << nbits;
            off += 1;
            nbits += 8;
        }
        let rank = (reg & mask) as i32;
        *o = (rank << shift).wrapping_add(lo);
        reg >>= b;
        nbits -= b;
    }
}

// ---------------------------------------------------------------------------
// Ternary codec kernels (5 trits per byte, base 3).
// ---------------------------------------------------------------------------

/// Byte → its five decoded trits. Built with the same `%3` chain as the
/// scalar decoder for *all* 256 byte values (including the 13 encodings
/// ≥ 243 a well-formed packer never emits), so malformed payloads decode
/// identically on every path.
static TERN_LUT: [[i8; 5]; 256] = build_tern_lut();

const fn build_tern_lut() -> [[i8; 5]; 256] {
    let mut lut = [[0i8; 5]; 256];
    let mut byte = 0usize;
    while byte < 256 {
        let mut v = byte as u16;
        let mut j = 0;
        while j < 5 {
            lut[byte][j] = (v % 3) as i8 - 1;
            v /= 3;
            j += 1;
        }
        byte += 1;
    }
    lut
}

/// Pack trits in {-1,0,1} five-per-byte into `out`
/// (`out.len() == trits.len().div_ceil(5)`).
pub fn tern_pack_into(trits: &[i8], out: &mut [u8]) {
    debug_assert_eq!(out.len(), trits.len().div_ceil(5));
    let chunks = trits.chunks_exact(5);
    let rem = chunks.remainder();
    let mut ci = 0usize;
    for g in chunks {
        // Direct base-3 dot product — the same value the scalar
        // Horner loop computes, without the serial dependency chain.
        let byte = (g[0] + 1) as u16
            + 3 * (g[1] + 1) as u16
            + 9 * (g[2] + 1) as u16
            + 27 * (g[3] + 1) as u16
            + 81 * (g[4] + 1) as u16;
        out[ci] = byte as u8;
        ci += 1;
    }
    if !rem.is_empty() {
        let mut byte = 0u16;
        for &t in rem.iter().rev() {
            byte = byte * 3 + (t + 1) as u16;
        }
        out[ci] = byte as u8;
    }
}

/// Unpack trits five-per-byte into `out` — one 5-byte LUT row copy per
/// input byte instead of five `%3`/`/3` pairs (the `VOTE_LUT` trick).
pub fn tern_unpack_into(packed: &[u8], out: &mut [i8]) {
    let mut chunks = out.chunks_exact_mut(5);
    let mut ci = 0usize;
    for g in &mut chunks {
        g.copy_from_slice(&TERN_LUT[packed[ci] as usize]);
        ci += 1;
    }
    let tail = chunks.into_remainder();
    if !tail.is_empty() {
        let n = tail.len();
        tail.copy_from_slice(&TERN_LUT[packed[ci] as usize][..n]);
    }
}

// ---------------------------------------------------------------------------
// x86-64 explicit-width paths.
// ---------------------------------------------------------------------------

/// Explicit-width x86-64 kernels. The safe wrappers above dispatch here
/// after [`active`] confirms the tier; SSE2 functions are safe because
/// SSE2 is architectural on x86-64.
#[cfg(target_arch = "x86_64")]
pub mod x86 {
    use std::arch::x86_64::*;

    /// 8-lane AVX2 dense accumulate. Bit-exact with the scalar oracle:
    /// per-lane IEEE adds, no reassociation.
    ///
    /// # Safety
    /// The CPU must support AVX2 (`is_x86_feature_detected!("avx2")`)
    /// and `payload.len()` must equal `4 * acc.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dense_accumulate_avx2(payload: &[u8], acc: &mut [f32]) {
        debug_assert_eq!(payload.len(), 4 * acc.len());
        let n = acc.len();
        let words = n / 8;
        let p = payload.as_ptr();
        let a = acc.as_mut_ptr();
        for w in 0..words {
            let x = _mm256_loadu_ps(p.add(w * 32) as *const f32);
            let y = _mm256_loadu_ps(a.add(w * 8) as *const f32);
            _mm256_storeu_ps(a.add(w * 8), _mm256_add_ps(y, x));
        }
        for i in words * 8..n {
            let c: [u8; 4] = payload[4 * i..4 * i + 4].try_into().unwrap();
            *a.add(i) += f32::from_le_bytes(c);
        }
    }

    /// 4-lane SSE2 dense accumulate (x86-64 baseline — no runtime
    /// feature check needed).
    pub fn dense_accumulate_sse2(payload: &[u8], acc: &mut [f32]) {
        debug_assert_eq!(payload.len(), 4 * acc.len());
        let n = acc.len();
        let words = n / 4;
        // SAFETY: unaligned loads/stores on in-bounds addresses derived
        // from the slices; SSE2 is always available on x86-64.
        unsafe {
            let p = payload.as_ptr();
            let a = acc.as_mut_ptr();
            for w in 0..words {
                let x = _mm_loadu_ps(p.add(w * 16) as *const f32);
                let y = _mm_loadu_ps(a.add(w * 4) as *const f32);
                _mm_storeu_ps(a.add(w * 4), _mm_add_ps(y, x));
            }
        }
        for i in words * 4..n {
            let c: [u8; 4] = payload[4 * i..4 * i + 4].try_into().unwrap();
            acc[i] += f32::from_le_bytes(c);
        }
    }

    /// 8-lane AVX2 bf16 encode: branchless RNE in 32-bit lanes, then a
    /// saturating 32→16 pack (values are already ≤ 0xFFFF, so the
    /// saturation is exact) with the cross-lane qword fix-up.
    ///
    /// # Safety
    /// The CPU must support AVX2 and `out.len()` must equal
    /// `2 * values.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn bf16_pack_into_avx2(values: &[f32], out: &mut [u8]) {
        debug_assert_eq!(out.len(), 2 * values.len());
        let n = values.len();
        let words = n / 8;
        let v = values.as_ptr();
        let o = out.as_mut_ptr();
        let bias = _mm256_set1_epi32(0x7FFF);
        let one = _mm256_set1_epi32(1);
        let abs_mask = _mm256_set1_epi32(0x7FFF_FFFF);
        let inf = _mm256_set1_epi32(0x7F80_0000);
        let quiet_bit = _mm256_set1_epi32(0x0040);
        for w in 0..words {
            let x = _mm256_castps_si256(_mm256_loadu_ps(v.add(w * 8)));
            let hi = _mm256_srli_epi32::<16>(x);
            let lsb = _mm256_and_si256(hi, one);
            let rounded =
                _mm256_srli_epi32::<16>(_mm256_add_epi32(x, _mm256_add_epi32(lsb, bias)));
            let quiet = _mm256_or_si256(hi, quiet_bit);
            let is_nan = _mm256_cmpgt_epi32(_mm256_and_si256(x, abs_mask), inf);
            let h32 = _mm256_blendv_epi8(rounded, quiet, is_nan);
            // [r0..r3, 0×4 | r4..r7, 0×4] → qwords [0,2,1,3] → r0..r7
            let packed = _mm256_packus_epi32(h32, _mm256_setzero_si256());
            let lanes = _mm256_permute4x64_epi64::<0xD8>(packed);
            _mm_storeu_si128(o.add(w * 16) as *mut __m128i, _mm256_castsi256_si128(lanes));
        }
        for i in words * 8..n {
            let h = super::bf16_round((*v.add(i)).to_bits()).to_le_bytes();
            *o.add(2 * i) = h[0];
            *o.add(2 * i + 1) = h[1];
        }
    }

    /// 8-lane AVX2 bf16 decode (zero-extend + 16-bit left shift).
    ///
    /// # Safety
    /// The CPU must support AVX2 and `payload.len()` must equal
    /// `2 * out.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn bf16_unpack_into_avx2(payload: &[u8], out: &mut [f32]) {
        debug_assert_eq!(payload.len(), 2 * out.len());
        let n = out.len();
        let words = n / 8;
        let p = payload.as_ptr();
        let o = out.as_mut_ptr();
        for w in 0..words {
            let h = _mm_loadu_si128(p.add(w * 16) as *const __m128i);
            let wide = _mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h));
            _mm256_storeu_ps(o.add(w * 8), _mm256_castsi256_ps(wide));
        }
        for i in words * 8..n {
            let h = u16::from_le_bytes([*p.add(2 * i), *p.add(2 * i + 1)]);
            *o.add(i) = f32::from_bits((h as u32) << 16);
        }
    }

    /// 8-lane AVX2 bf16 accumulate (decode + per-lane IEEE add).
    ///
    /// # Safety
    /// The CPU must support AVX2 and `payload.len()` must equal
    /// `2 * acc.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn bf16_accumulate_avx2(payload: &[u8], acc: &mut [f32]) {
        debug_assert_eq!(payload.len(), 2 * acc.len());
        let n = acc.len();
        let words = n / 8;
        let p = payload.as_ptr();
        let a = acc.as_mut_ptr();
        for w in 0..words {
            let h = _mm_loadu_si128(p.add(w * 16) as *const __m128i);
            let wide = _mm256_castsi256_ps(_mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h)));
            let y = _mm256_loadu_ps(a.add(w * 8));
            _mm256_storeu_ps(a.add(w * 8), _mm256_add_ps(y, wide));
        }
        for i in words * 8..n {
            let h = u16::from_le_bytes([*p.add(2 * i), *p.add(2 * i + 1)]);
            *a.add(i) += f32::from_bits((h as u32) << 16);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::half;
    use crate::util::Rng;

    #[test]
    fn bf16_round_matches_scalar_oracle() {
        // Specials + tie/sticky boundaries + random bit patterns.
        let mut cases: Vec<u32> = vec![
            0x0000_0000, // +0.0
            0x8000_0000, // -0.0
            0x7F80_0000, // +inf
            0xFF80_0000, // -inf
            0x7FC0_0000, // qNaN
            0x7F80_0001, // sNaN
            0xFFFF_FFFF, // -NaN, all sticky
            0x3F80_8000, // 1.0 + exact tie (even keeps)
            0x3F80_8001, // just above the tie
            0x3F81_8000, // odd mantissa tie (rounds up)
            0x7F7F_FFFF, // f32::MAX (rounds to +inf)
            0xFF7F_FFFF, // f32::MIN (rounds to -inf)
            0x0000_0001, // smallest subnormal
            0x0000_8000, // subnormal tie
        ];
        let mut rng = Rng::new(0xB16);
        for _ in 0..200_000 {
            cases.push(rng.next_u64() as u32);
        }
        for bits in cases {
            assert_eq!(
                bf16_round(bits),
                half::to_bf16_bits(f32::from_bits(bits)),
                "bf16_round diverged on bits {bits:#010x}"
            );
        }
    }

    #[test]
    fn tern_lut_matches_div_chain_for_all_bytes() {
        for byte in 0u16..256 {
            let mut v = byte;
            for (j, &t) in TERN_LUT[byte as usize].iter().enumerate() {
                assert_eq!(t, (v % 3) as i8 - 1, "LUT byte {byte} trit {j}");
                v /= 3;
            }
        }
    }

    #[test]
    fn active_tier_is_cached_and_named() {
        let a = active();
        assert_eq!(a, active(), "tier must be stable across calls");
        assert!(["portable", "sse2", "avx2"].contains(&a.name()));
        #[cfg(target_arch = "x86_64")]
        assert!(a >= Lanes::Sse2, "x86-64 always has at least SSE2");
    }

    #[test]
    fn bitpack_groups_are_byte_aligned() {
        // 8 elements × b bits is always b whole bytes — the invariant
        // the 8-per-u64 kernel rests on.
        for b in 1u32..=8 {
            assert_eq!(8 * b % 8, 0);
            let vals: Vec<i32> = (0..16).map(|i| i % (1 << b)).collect();
            let mut out = vec![0u8; (vals.len() * b as usize).div_ceil(8)];
            bitpack8_into(&vals, 0, 0, b, &mut out);
            let mut back = vec![0i32; vals.len()];
            bitunpack8_into(&out, 0, 0, b, &mut back);
            assert_eq!(vals, back, "b={b}");
        }
    }
}
