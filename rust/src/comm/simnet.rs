//! Network wall-clock model: projects per-step communication time for
//! each strategy on parameterized links (the paper's testbed-bound
//! claim — "particularly advantageous for training large models" —
//! made quantitative). Pure analytics over the measured/analytic byte
//! counts; used by the `ext_netsim` bench and the `bandwidth_probe`
//! example.
//!
//! Model (parameter-server topology, full-duplex links):
//!   t_up   = latency + max_i(uplink_bytes_i) / server_bandwidth · N
//!            (server ingests N worker payloads through one NIC)
//!   t_down = latency + downlink_bytes · N / server_bandwidth
//!   t_comm = t_up + t_down
//! Worker NICs are assumed ≥ server NIC / N (the server is the
//! bottleneck, as in the paper's 4-node × 8-GPU setting).

use crate::optim::dist::Strategy;

/// A link configuration.
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// server NIC bandwidth, bytes/second
    pub bandwidth_bps: f64,
    /// one-way latency, seconds
    pub latency_s: f64,
}

impl Link {
    pub fn gbit(gbits: f64) -> Link {
        Link { bandwidth_bps: gbits * 1e9 / 8.0, latency_s: 50e-6 }
    }
}

/// Per-step communication time estimate for a strategy.
#[derive(Clone, Copy, Debug)]
pub struct CommTime {
    pub uplink_s: f64,
    pub downlink_s: f64,
}

impl CommTime {
    pub fn total(&self) -> f64 {
        self.uplink_s + self.downlink_s
    }
}

/// Estimate per-step communication time from the strategy's analytic
/// bits/param (Table 1) on a d-parameter model with n workers.
pub fn estimate(strategy: &dyn Strategy, d: usize, n: usize, link: Link) -> CommTime {
    let up_bytes_per_worker = strategy.uplink_bits_per_param(n) * d as f64 / 8.0;
    let down_bytes_per_worker = strategy.downlink_bits_per_param(n) * d as f64 / 8.0;
    CommTime {
        uplink_s: link.latency_s + up_bytes_per_worker * n as f64 / link.bandwidth_bps,
        downlink_s: link.latency_s + down_bytes_per_worker * n as f64 / link.bandwidth_bps,
    }
}

/// Projected step time = max(compute, comm) under compute/comm overlap,
/// or compute + comm without overlap.
pub fn step_time(compute_s: f64, comm: CommTime, overlap: bool) -> f64 {
    if overlap {
        compute_s.max(comm.total())
    } else {
        compute_s + comm.total()
    }
}

/// Per-step communication time when the round is split into `nchunks`
/// chunk messages and the two directions pipeline (chunk i's downlink
/// overlaps chunk i+1's uplink — what the chunked wire format enables):
///
/// ```text
/// t_up_c   = latency + up_bytes  / nchunks / bw · N
/// t_down_c = latency + down_bytes/ nchunks / bw · N
/// T        = t_up_c + (nchunks − 1)·max(t_up_c, t_down_c) + t_down_c
/// ```
///
/// `nchunks = 1` is exactly [`estimate`]`.total()` (serialized up then
/// down). More chunks hide the smaller direction under the larger one
/// but pay the per-message latency `nchunks` times — the sweet spot the
/// `ext_netsim` bench sweeps.
pub fn estimate_pipelined(
    strategy: &dyn Strategy,
    d: usize,
    n: usize,
    link: Link,
    nchunks: usize,
) -> f64 {
    let nchunks = nchunks.max(1);
    let full = estimate(strategy, d, n, link);
    let up_c = link.latency_s + (full.uplink_s - link.latency_s) / nchunks as f64;
    let down_c = link.latency_s + (full.downlink_s - link.latency_s) / nchunks as f64;
    up_c + (nchunks - 1) as f64 * up_c.max(down_c) + down_c
}

/// [`estimate_pipelined`] generalized to heterogeneous per-chunk costs
/// — the mixed-assignment projection. `chunks` holds each chunk's
/// (uplink_bytes, downlink_bytes) per worker
/// ([`crate::optim::dist::mixed::MixedStrategy::chunk_costs`] produces
/// it); chunk i's downlink overlaps chunk i+1's uplink, so a cheap
/// sign chunk hides under a dense neighbour's transfer:
///
/// ```text
/// T = t_up(0) + Σ_{i≥1} max(t_up(i), t_down(i−1)) + t_down(k−1)
/// t_dir(i) = latency + bytes_dir(i) · N / bw
/// ```
///
/// With uniform per-chunk costs this reduces exactly to
/// [`estimate_pipelined`]; a single chunk is the serial estimate.
pub fn estimate_pipelined_costs(chunks: &[(f64, f64)], n: usize, link: Link) -> f64 {
    if chunks.is_empty() {
        return 0.0;
    }
    let t = |bytes: f64| link.latency_s + bytes * n as f64 / link.bandwidth_bps;
    let mut total = t(chunks[0].0);
    for i in 1..chunks.len() {
        total += t(chunks[i].0).max(t(chunks[i - 1].1));
    }
    total + t(chunks[chunks.len() - 1].1)
}

/// Per-step communication time on a two-level hierarchy: workers reach
/// their group aggregator over `edge`, aggregators exchange partial /
/// broadcast frames with the root over `agg` (the ROADMAP's
/// "aggregator-hop latency model"). Groups run in parallel, so the edge
/// hop carries `group_size` frames and the agg hop `G = ⌈n/g⌉` partials
/// ([`Strategy::partial_bits_per_param`] — exact vote sums for the sign
/// family, f32 sums for the dense family, relayed members otherwise).
pub fn estimate_hier(
    strategy: &dyn Strategy,
    d: usize,
    n: usize,
    group_size: usize,
    edge: Link,
    agg: Link,
) -> CommTime {
    let g = group_size.clamp(1, n.max(1));
    let ngroups = n.div_ceil(g);
    let up_bytes = strategy.uplink_bits_per_param(n) * d as f64 / 8.0;
    let down_bytes = strategy.downlink_bits_per_param(n) * d as f64 / 8.0;
    let partial_bytes = strategy.partial_bits_per_param(g) * d as f64 / 8.0;
    CommTime {
        uplink_s: (edge.latency_s + up_bytes * g as f64 / edge.bandwidth_bps)
            + (agg.latency_s + partial_bytes * ngroups as f64 / agg.bandwidth_bps),
        downlink_s: (agg.latency_s + down_bytes * ngroups as f64 / agg.bandwidth_bps)
            + (edge.latency_s + down_bytes * g as f64 / edge.bandwidth_bps),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::dist::{by_name, StrategyHyper};

    #[test]
    fn dlion_is_30x_faster_on_the_wire_than_global() {
        let hp = StrategyHyper::default();
        let dlion = by_name("d-lion-mavo", &hp).unwrap();
        let glion = by_name("g-lion", &hp).unwrap();
        let link = Link::gbit(10.0);
        // 1B params, 33 workers (odd ⇒ MaVo downlink strictly 1 bit;
        // even N pays the 1.6-bit ternary tie frame and lands at ~25x)
        let (d, n) = (1_000_000_000, 33);
        let t_dlion = estimate(dlion.as_ref(), d, n, link).total();
        let t_glion = estimate(glion.as_ref(), d, n, link).total();
        let ratio = t_glion / t_dlion;
        assert!(
            (28.0..36.0).contains(&ratio),
            "expected ~32x wire-time ratio, got {ratio:.1}"
        );
    }

    #[test]
    fn latency_dominates_tiny_messages() {
        let hp = StrategyHyper::default();
        let s = by_name("d-lion-mavo", &hp).unwrap();
        let link = Link { bandwidth_bps: 1e12, latency_s: 1e-3 };
        let t = estimate(s.as_ref(), 1000, 4, link);
        assert!((t.total() - 2e-3).abs() < 1e-4);
    }

    #[test]
    fn overlap_hides_comm_under_compute() {
        let comm = CommTime { uplink_s: 0.1, downlink_s: 0.1 };
        assert_eq!(step_time(1.0, comm, true), 1.0);
        assert!((step_time(1.0, comm, false) - 1.2).abs() < 1e-12);
    }

    #[test]
    fn pipelined_one_chunk_is_the_serial_estimate() {
        let hp = StrategyHyper::default();
        let s = by_name("g-lion", &hp).unwrap();
        let link = Link::gbit(10.0);
        let serial = estimate(s.as_ref(), 10_000_000, 8, link).total();
        let one = estimate_pipelined(s.as_ref(), 10_000_000, 8, link, 1);
        assert!((serial - one).abs() < 1e-12);
    }

    #[test]
    fn pipelining_hides_the_smaller_direction() {
        // g-lion moves 32 bits each way: with k chunks the downlink of
        // chunk i overlaps the uplink of chunk i+1, approaching half
        // the serial time for bandwidth-dominated links.
        let hp = StrategyHyper::default();
        let s = by_name("g-lion", &hp).unwrap();
        let link = Link::gbit(10.0);
        let (d, n) = (1_000_000_000usize, 8);
        let serial = estimate_pipelined(s.as_ref(), d, n, link, 1);
        let k64 = estimate_pipelined(s.as_ref(), d, n, link, 64);
        assert!(k64 < serial * 0.6, "k=64 {k64:.3}s vs serial {serial:.3}s");
        // ...but latency eventually wins: absurd chunk counts regress
        let k = 5_000_000;
        assert!(estimate_pipelined(s.as_ref(), d, n, link, k) > k64);
    }

    #[test]
    fn pipelined_costs_generalize_the_uniform_estimate() {
        let hp = StrategyHyper::default();
        let s = by_name("g-lion", &hp).unwrap();
        let link = Link::gbit(10.0);
        let (d, n, k) = (10_000_000usize, 8, 16);
        // uniform per-chunk costs reduce exactly to estimate_pipelined
        let per_chunk = 32.0 * (d / k) as f64 / 8.0;
        let chunks = vec![(per_chunk, per_chunk); k];
        let uniform = estimate_pipelined_costs(&chunks, n, link);
        let reference = estimate_pipelined(s.as_ref(), d, n, link, k);
        assert!((uniform - reference).abs() < 1e-9, "{uniform} vs {reference}");
        // a mixed 7/8-sign + 1/8-dense assignment moves fewer bytes than
        // all-dense, so its pipelined projection must be strictly faster
        let mixed = crate::optim::dist::MixedStrategy::per_chunk(
            vec![by_name("d-lion-mavo", &hp).unwrap(), by_name("g-lion", &hp).unwrap()],
            vec![7, 1],
        )
        .unwrap();
        let costs = mixed.chunk_costs(d, d / 8, n);
        assert_eq!(costs.len(), 8);
        let t_mixed = estimate_pipelined_costs(&costs, n, link);
        assert!(t_mixed < reference, "{t_mixed} vs all-dense {reference}");
        // ...and slower than all-sign (the cheap floor)
        let sign_chunks = vec![(1.0 * (d / 8) as f64 / 8.0, 1.0 * (d / 8) as f64 / 8.0); 8];
        assert!(t_mixed > estimate_pipelined_costs(&sign_chunks, n, link));
        // degenerate: no chunks, no time
        assert_eq!(estimate_pipelined_costs(&[], n, link), 0.0);
    }

    #[test]
    fn hier_estimate_uses_the_partial_bits_model() {
        // With a narrow aggregator link, the sign family's log2(g+1)-bit
        // vote partials must beat g-lion's 32-bit dense sums on the agg
        // hop, and one full group over identical links degenerates to
        // roughly the flat estimate shape (same order of magnitude).
        let hp = StrategyHyper::default();
        let mavo = by_name("d-lion-mavo", &hp).unwrap();
        let glion = by_name("g-lion", &hp).unwrap();
        let edge = Link::gbit(100.0);
        let agg = Link::gbit(1.0);
        let (d, n, g) = (100_000_000usize, 32, 8);
        let t_mavo = estimate_hier(mavo.as_ref(), d, n, g, edge, agg).total();
        let t_glion = estimate_hier(glion.as_ref(), d, n, g, edge, agg).total();
        assert!(t_mavo * 4.0 < t_glion, "vote partials must dominate: {t_mavo} vs {t_glion}");
        // relay fallback (terngrad) pays g× its uplink on the agg hop
        let tern = by_name("terngrad", &hp).unwrap();
        assert!(tern.partial_bits_per_param(g) > tern.uplink_bits_per_param(g) * (g - 1) as f64);
    }

    #[test]
    fn avg_downlink_costs_more_than_mavo() {
        let hp = StrategyHyper::default();
        let mavo = by_name("d-lion-mavo", &hp).unwrap();
        let avg = by_name("d-lion-avg", &hp).unwrap();
        let link = Link::gbit(10.0);
        let n = 33; // odd: mavo downlink is strictly 1 bit
        let t_mavo = estimate(mavo.as_ref(), 1_000_000, n, link);
        let t_avg = estimate(avg.as_ref(), 1_000_000, n, link);
        assert!(t_avg.downlink_s > t_mavo.downlink_s);
        assert_eq!(t_avg.uplink_s, t_mavo.uplink_s);
    }
}
