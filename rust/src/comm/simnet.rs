//! Network wall-clock model: projects per-step communication time for
//! each strategy on parameterized links (the paper's testbed-bound
//! claim — "particularly advantageous for training large models" —
//! made quantitative). Pure analytics over the measured/analytic byte
//! counts; used by the `ext_netsim` bench and the `bandwidth_probe`
//! example.
//!
//! Model (parameter-server topology, full-duplex links):
//!   t_up   = latency + max_i(uplink_bytes_i) / server_bandwidth · N
//!            (server ingests N worker payloads through one NIC)
//!   t_down = latency + downlink_bytes · N / server_bandwidth
//!   t_comm = t_up + t_down
//! Worker NICs are assumed ≥ server NIC / N (the server is the
//! bottleneck, as in the paper's 4-node × 8-GPU setting).

use crate::optim::dist::Strategy;

/// A link configuration.
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// server NIC bandwidth, bytes/second
    pub bandwidth_bps: f64,
    /// one-way latency, seconds
    pub latency_s: f64,
}

impl Link {
    pub fn gbit(gbits: f64) -> Link {
        Link { bandwidth_bps: gbits * 1e9 / 8.0, latency_s: 50e-6 }
    }
}

/// Per-step communication time estimate for a strategy.
#[derive(Clone, Copy, Debug)]
pub struct CommTime {
    pub uplink_s: f64,
    pub downlink_s: f64,
}

impl CommTime {
    pub fn total(&self) -> f64 {
        self.uplink_s + self.downlink_s
    }
}

/// Estimate per-step communication time from the strategy's analytic
/// bits/param (Table 1) on a d-parameter model with n workers.
pub fn estimate(strategy: &dyn Strategy, d: usize, n: usize, link: Link) -> CommTime {
    let up_bytes_per_worker = strategy.uplink_bits_per_param(n) * d as f64 / 8.0;
    let down_bytes_per_worker = strategy.downlink_bits_per_param(n) * d as f64 / 8.0;
    CommTime {
        uplink_s: link.latency_s + up_bytes_per_worker * n as f64 / link.bandwidth_bps,
        downlink_s: link.latency_s + down_bytes_per_worker * n as f64 / link.bandwidth_bps,
    }
}

/// Projected step time = max(compute, comm) under compute/comm overlap,
/// or compute + comm without overlap.
pub fn step_time(compute_s: f64, comm: CommTime, overlap: bool) -> f64 {
    if overlap {
        compute_s.max(comm.total())
    } else {
        compute_s + comm.total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::dist::{by_name, StrategyHyper};

    #[test]
    fn dlion_is_30x_faster_on_the_wire_than_global() {
        let hp = StrategyHyper::default();
        let dlion = by_name("d-lion-mavo", &hp).unwrap();
        let glion = by_name("g-lion", &hp).unwrap();
        let link = Link::gbit(10.0);
        // 1B params, 33 workers (odd ⇒ MaVo downlink strictly 1 bit;
        // even N pays the 1.6-bit ternary tie frame and lands at ~25x)
        let (d, n) = (1_000_000_000, 33);
        let t_dlion = estimate(dlion.as_ref(), d, n, link).total();
        let t_glion = estimate(glion.as_ref(), d, n, link).total();
        let ratio = t_glion / t_dlion;
        assert!(
            (28.0..36.0).contains(&ratio),
            "expected ~32x wire-time ratio, got {ratio:.1}"
        );
    }

    #[test]
    fn latency_dominates_tiny_messages() {
        let hp = StrategyHyper::default();
        let s = by_name("d-lion-mavo", &hp).unwrap();
        let link = Link { bandwidth_bps: 1e12, latency_s: 1e-3 };
        let t = estimate(s.as_ref(), 1000, 4, link);
        assert!((t.total() - 2e-3).abs() < 1e-4);
    }

    #[test]
    fn overlap_hides_comm_under_compute() {
        let comm = CommTime { uplink_s: 0.1, downlink_s: 0.1 };
        assert_eq!(step_time(1.0, comm, true), 1.0);
        assert!((step_time(1.0, comm, false) - 1.2).abs() < 1e-12);
    }

    #[test]
    fn avg_downlink_costs_more_than_mavo() {
        let hp = StrategyHyper::default();
        let mavo = by_name("d-lion-mavo", &hp).unwrap();
        let avg = by_name("d-lion-avg", &hp).unwrap();
        let link = Link::gbit(10.0);
        let n = 33; // odd: mavo downlink is strictly 1 bit
        let t_mavo = estimate(mavo.as_ref(), 1_000_000, n, link);
        let t_avg = estimate(avg.as_ref(), 1_000_000, n, link);
        assert!(t_avg.downlink_s > t_mavo.downlink_s);
        assert_eq!(t_avg.uplink_s, t_mavo.uplink_s);
    }
}
