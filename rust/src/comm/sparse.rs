//! Sparse (index, value) codec for GradDrop / DGC uplinks.
//!
//! Encodes k non-zero entries of a d-dim vector as a little-endian
//! header (d: u32, k: u32) followed by k × (u32 index, f32 value).
//! Bandwidth: 64 + 64·k bits — with compression rate η (fraction
//! dropped), k = (1−η)·d and the uplink is (1−η)·64·d bits ≈ the
//! "(1−η)32d" of Table 1 up to the index overhead the paper elides
//! (DGC's reference implementation also ships 32-bit indices).

/// One sparse entry.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Entry {
    pub index: u32,
    pub value: f32,
}

/// Payload bytes for k entries.
#[inline]
pub fn packed_len(k: usize) -> usize {
    8 + 8 * k
}

/// Encode entries (must have index < d).
pub fn pack(d: usize, entries: &[Entry]) -> Vec<u8> {
    let mut out = Vec::with_capacity(packed_len(entries.len()));
    out.extend_from_slice(&(d as u32).to_le_bytes());
    out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    for e in entries {
        debug_assert!((e.index as usize) < d);
        out.extend_from_slice(&e.index.to_le_bytes());
        out.extend_from_slice(&e.value.to_le_bytes());
    }
    out
}

/// Decode into (d, entries).
pub fn unpack(payload: &[u8]) -> (usize, Vec<Entry>) {
    assert!(payload.len() >= 8, "sparse payload too short");
    let d = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
    let k = u32::from_le_bytes(payload[4..8].try_into().unwrap()) as usize;
    assert!(payload.len() >= packed_len(k), "sparse payload truncated");
    let mut entries = Vec::with_capacity(k);
    for i in 0..k {
        let off = 8 + 8 * i;
        entries.push(Entry {
            index: u32::from_le_bytes(payload[off..off + 4].try_into().unwrap()),
            value: f32::from_le_bytes(payload[off + 4..off + 8].try_into().unwrap()),
        });
    }
    (d, entries)
}

/// Scatter-add decoded entries into a dense accumulator.
pub fn scatter_add(payload: &[u8], acc: &mut [f32]) {
    let (d, entries) = unpack(payload);
    assert_eq!(d, acc.len(), "sparse dim mismatch");
    for e in entries {
        acc[e.index as usize] += e.value;
    }
}

/// Scatter-add a compact-format payload into a dense accumulator.
pub fn scatter_add_compact(payload: &[u8], acc: &mut [f32]) {
    let (d, entries) = unpack_compact(payload);
    assert_eq!(d, acc.len(), "sparse dim mismatch");
    for e in entries {
        acc[e.index as usize] += e.value;
    }
}

// ---------------------------------------------------------------------------
// Compact format: delta-varint indices + f32 values. ~40(1−η)·d bits
// instead of 64(1−η)·d for the paper's 4% keep rate (see comm::varint).
// Header: (d: u32, k: u32, index_bytes: u32) LE.
// ---------------------------------------------------------------------------

/// Encode entries with delta-varint index compression.
pub fn pack_compact(d: usize, entries: &[Entry]) -> Vec<u8> {
    let mut idx_buf = Vec::with_capacity(entries.len() * 2);
    let indices: Vec<u32> = entries.iter().map(|e| e.index).collect();
    super::varint::pack_sorted_indices(&indices, &mut idx_buf);
    let mut out = Vec::with_capacity(12 + idx_buf.len() + 4 * entries.len());
    out.extend_from_slice(&(d as u32).to_le_bytes());
    out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    out.extend_from_slice(&(idx_buf.len() as u32).to_le_bytes());
    out.extend_from_slice(&idx_buf);
    for e in entries {
        out.extend_from_slice(&e.value.to_le_bytes());
    }
    out
}

/// Decode the compact format into (d, entries).
pub fn unpack_compact(payload: &[u8]) -> (usize, Vec<Entry>) {
    assert!(payload.len() >= 12, "compact sparse payload too short");
    let d = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
    let k = u32::from_le_bytes(payload[4..8].try_into().unwrap()) as usize;
    let idx_len = u32::from_le_bytes(payload[8..12].try_into().unwrap()) as usize;
    let mut indices = Vec::with_capacity(k);
    let used = super::varint::unpack_sorted_indices(&payload[12..12 + idx_len], k, &mut indices)
        .expect("corrupt varint index stream");
    assert_eq!(used, idx_len, "index stream length mismatch");
    let vals = &payload[12 + idx_len..];
    assert!(vals.len() >= 4 * k, "compact sparse payload truncated");
    let entries = indices
        .into_iter()
        .enumerate()
        .map(|(i, index)| Entry {
            index,
            value: f32::from_le_bytes(vals[4 * i..4 * i + 4].try_into().unwrap()),
        })
        .collect();
    (d, entries)
}

/// Select the k largest-|value| entries of `dense` (top-k sparsification).
/// Returns entries sorted by index.
pub fn top_k(dense: &[f32], k: usize) -> Vec<Entry> {
    let k = k.min(dense.len());
    if k == 0 {
        return Vec::new();
    }
    // Threshold via select_nth on |value|.
    let mut mags: Vec<(usize, f32)> =
        dense.iter().enumerate().map(|(i, &v)| (i, v.abs())).collect();
    let nth = mags.len() - k;
    mags.select_nth_unstable_by(nth, |a, b| a.1.partial_cmp(&b.1).unwrap());
    let mut idx: Vec<usize> = mags[nth..].iter().map(|&(i, _)| i).collect();
    idx.sort_unstable();
    idx.into_iter()
        .map(|i| Entry { index: i as u32, value: dense[i] })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(0x81);
        for _ in 0..64 {
            let d = rng.below(500) + 1;
            let k = rng.below(d.min(64) + 1);
            let entries: Vec<Entry> = rng
                .sample_indices(d, k)
                .into_iter()
                .map(|i| Entry { index: i as u32, value: rng.normal_f32(0.0, 1.0) })
                .collect();
            let payload = pack(d, &entries);
            assert_eq!(payload.len(), packed_len(k));
            let (d2, back) = unpack(&payload);
            assert_eq!(d2, d);
            assert_eq!(back, entries);
        }
    }

    #[test]
    fn top_k_picks_largest_magnitudes() {
        let dense = [0.1, -5.0, 0.2, 3.0, -0.05, 4.0];
        let e = top_k(&dense, 3);
        let idx: Vec<u32> = e.iter().map(|x| x.index).collect();
        assert_eq!(idx, vec![1, 3, 5]);
        assert_eq!(e[0].value, -5.0);
    }

    #[test]
    fn top_k_edge_cases() {
        assert!(top_k(&[], 3).is_empty());
        assert!(top_k(&[1.0, 2.0], 0).is_empty());
        assert_eq!(top_k(&[1.0, 2.0], 5).len(), 2);
    }

    #[test]
    fn scatter_add_accumulates() {
        let payload = pack(
            4,
            &[Entry { index: 1, value: 2.0 }, Entry { index: 3, value: -1.0 }],
        );
        let mut acc = vec![1.0f32; 4];
        scatter_add(&payload, &mut acc);
        assert_eq!(acc, vec![1.0, 3.0, 1.0, 0.0]);
    }

    #[test]
    fn compact_roundtrip_and_is_smaller() {
        let mut rng = Rng::new(0x83);
        for _ in 0..64 {
            let d = rng.below(50_000) + 100;
            let k = (d / 25).max(1); // the paper's 4% keep rate
            let entries: Vec<Entry> = rng
                .sample_indices(d, k)
                .into_iter()
                .map(|i| Entry { index: i as u32, value: rng.normal_f32(0.0, 1.0) })
                .collect();
            let classic = pack(d, &entries);
            let compact = pack_compact(d, &entries);
            let (d2, back) = unpack_compact(&compact);
            assert_eq!(d2, d);
            assert_eq!(back, entries);
            if k > 20 {
                assert!(
                    compact.len() < classic.len() * 3 / 4,
                    "compact {} vs classic {} (k={k})",
                    compact.len(),
                    classic.len()
                );
            }
        }
    }

    #[test]
    fn top_k_then_roundtrip_property() {
        testing::forall(
            0x82,
            64,
            |r| testing::gen_vec_normal(r, 1, 200, 1.0),
            |dense| {
                let k = dense.len() / 10 + 1;
                let e = top_k(dense, k);
                let payload = pack(dense.len(), &e);
                let (_, back) = unpack(&payload);
                // kept entries preserve exact values
                back.iter().all(|en| dense[en.index as usize] == en.value)
                    && back.len() == k.min(dense.len())
            },
        );
    }
}
