//! SWAR (SIMD-within-a-register) kernels for the 1-bit sign wire
//! (§Perf optimization #4).
//!
//! Two hot loops dominate a Distributed-Lion round once the wire itself
//! is 1 bit/param: the worker-side sign gather (blend → packed payload)
//! and the server-side vote accumulate (N packed payloads → majority
//! plane). Both are bit-parallel problems, so plain u64 registers can
//! process 64 lanes per operation with no SIMD intrinsics (the offline
//! build targets stable scalar Rust):
//!
//! * **Sign gather** ([`sign_byte8`] / [`pack_f32_into`]): two f32 bit
//!   patterns are packed into one u64, whose bits 31 and 63 are the two
//!   IEEE sign bits. One shift + mask isolates both at once, so a byte
//!   of payload costs 4 word ops instead of 8 per-lane shift/or chains.
//! * **Bit-sliced majority vote** ([`VotePlanes`]): per 64-lane word the
//!   accumulator keeps B = ⌈log2(N+1)⌉ u64 *bit planes* — plane b holds
//!   bit b of every lane's vote counter. Adding one worker's packed
//!   payload is a carry-save ripple (`t = plane & carry; plane ^= carry;
//!   carry = t`), i.e. ≤ B word ops for 64 lanes, versus 64 separate i32
//!   adds in the scalar [`VOTE_LUT`] path. The majority plane ("count ≥
//!   threshold") falls out of one more bit-sliced add: adding the
//!   constant K = 2^B − T makes the per-lane carry-out exactly the
//!   predicate count ≥ T, and that carry word *is* the packed MaVo
//!   downlink payload.
//!
//! Bit-exactness: a lane's counter is the exact integer count of +1
//! votes, and integer addition is associative, so any grouping of
//! payloads (per-round, hierarchical partials, chunked splices) yields
//! the same planes — the kernel is pinned against the scalar
//! [`accumulate_votes`] oracle in unit + property tests.
//!
//! [`VOTE_LUT`]: super::sign::accumulate_votes
//! [`accumulate_votes`]: super::sign::accumulate_votes

use super::sign::packed_len;
use crate::util::math::bits_for_count;

/// Gather the IEEE sign bits of 8 lanes into one payload byte
/// (bit j = 1 ⇔ `v[j]` is non-negative, i.e. sign bit clear — the
/// [`super::sign`] codec convention, +0.0 ⇒ +1, −0.0 ⇒ −1).
#[inline]
pub fn sign_byte8(v: &[f32; 8]) -> u8 {
    let mut y = 0u64;
    for (i, pair) in v.chunks_exact(2).enumerate() {
        // bits 31 and 63 of w are the two IEEE sign bits
        let w = (pair[0].to_bits() as u64) | ((pair[1].to_bits() as u64) << 32);
        y |= ((w >> 31) & 0x0000_0001_0000_0001) << (2 * i);
    }
    // low half: even lanes at bits {0,2,4,6}; high half: odd lanes at
    // bits {32,34,36,38} — `y >> 31` drops them onto the odd bits.
    !(((y | (y >> 31)) & 0xff) as u8)
}

/// Build a partial payload byte from fewer than 8 trailing lanes
/// (unused high bits are 0, matching the codec's zero-fill).
#[inline]
pub fn sign_byte_partial(rem: &[f32]) -> u8 {
    debug_assert!(rem.len() < 8);
    let mut byte = 0u8;
    for (j, &v) in rem.iter().enumerate() {
        byte |= (((v.to_bits() >> 31) ^ 1) as u8) << j;
    }
    byte
}

/// SWAR sign gather into a preallocated payload (the zero-copy frame
/// assembly path): writes exactly `packed_len(values.len())` bytes of
/// `out`, overwriting every byte it touches so reused round buffers
/// never leak stale bits.
pub fn pack_f32_into(values: &[f32], out: &mut [u8]) {
    debug_assert!(out.len() >= packed_len(values.len()));
    let chunks = values.chunks_exact(8);
    let rem = chunks.remainder();
    for (ci, chunk) in chunks.enumerate() {
        out[ci] = sign_byte8(chunk.try_into().expect("chunks_exact(8) yields 8 lanes"));
    }
    if !rem.is_empty() {
        out[values.len() / 8] = sign_byte_partial(rem);
    }
}

/// Read 64 payload lanes as one little-endian word, zero-filling past
/// the end of the payload (payload bit i = word bit i for LE bytes).
#[inline]
fn read_word(packed: &[u8], wi: usize) -> u64 {
    let start = wi * 8;
    if start + 8 <= packed.len() {
        u64::from_le_bytes(packed[start..start + 8].try_into().expect("8-byte window"))
    } else {
        let mut buf = [0u8; 8];
        let rem = packed.len() - start;
        buf[..rem].copy_from_slice(&packed[start..]);
        u64::from_le_bytes(buf)
    }
}

/// Bit-sliced vote accumulator: per 64-lane word, B = ⌈log2(N+1)⌉ u64
/// bit planes hold every lane's count of +1 votes (see module docs).
///
/// The planes are stored interleaved (`planes[word * nbits + bit]`) so
/// one worker-add touches B contiguous words per input word — a single
/// forward stream over the buffer.
pub struct VotePlanes {
    planes: Vec<u64>,
    nbits: usize,
    dim: usize,
    added: usize,
}

impl VotePlanes {
    /// Accumulator for `dim` lanes and up to `nworkers` payloads per
    /// round (B = ⌈log2(nworkers+1)⌉ planes per word).
    pub fn new(dim: usize, nworkers: usize) -> Self {
        assert!(nworkers >= 1, "vote planes need at least one voter");
        let nbits = bits_for_count(nworkers) as usize;
        let words = dim.div_ceil(64);
        VotePlanes { planes: vec![0u64; words * nbits], nbits, dim, added: 0 }
    }

    /// Number of lanes.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Payloads added since the last [`VotePlanes::reset`].
    pub fn added(&self) -> usize {
        self.added
    }

    /// Clear all counters for the next round (keeps the allocation).
    pub fn reset(&mut self) {
        self.planes.fill(0);
        self.added = 0;
    }

    /// Carry-save add of one packed sign payload (payload bit 1 ⇒ that
    /// lane gains a +1 vote; bit 0 leaves its counter unchanged).
    pub fn add(&mut self, packed: &[u8]) {
        debug_assert_eq!(packed.len(), packed_len(self.dim), "payload/dim mismatch");
        debug_assert!(self.added + 1 < (1usize << self.nbits), "vote planes at capacity");
        let nbits = self.nbits;
        for (wi, word_planes) in self.planes.chunks_exact_mut(nbits).enumerate() {
            let mut carry = read_word(packed, wi);
            for p in word_planes.iter_mut() {
                if carry == 0 {
                    break;
                }
                let t = *p & carry;
                *p ^= carry;
                carry = t;
            }
            debug_assert_eq!(carry, 0, "vote plane counter overflow");
        }
        self.added += 1;
    }

    /// Emit the packed `[count ≥ threshold]` plane — for odd N and
    /// threshold T = (N+1)/2 this is exactly the MaVo downlink payload
    /// (`sign(Σδ) > 0`). Writes `packed_len(dim)` bytes of `out`; lanes
    /// past `dim` come out 0, matching the codec's zero-fill.
    ///
    /// Implementation: bit-sliced add of the constant K = 2^B − T; the
    /// per-lane carry-out of `count + K` is `count ≥ T`.
    pub fn threshold_into(&self, threshold: usize, out: &mut [u8]) {
        assert!(
            (1..=(1usize << self.nbits)).contains(&threshold),
            "threshold {threshold} out of range for {} planes",
            self.nbits
        );
        let plen = packed_len(self.dim);
        debug_assert!(out.len() >= plen);
        let k = (1u64 << self.nbits) - threshold as u64;
        let nbits = self.nbits;
        for (wi, word_planes) in self.planes.chunks_exact(nbits).enumerate() {
            let mut carry = 0u64;
            for (b, &p) in word_planes.iter().enumerate() {
                let kb = 0u64.wrapping_sub((k >> b) & 1); // broadcast bit b of K
                carry = (p & kb) | (p & carry) | (kb & carry);
            }
            let start = wi * 8;
            let n = (plen - start).min(8);
            out[start..start + n].copy_from_slice(&carry.to_le_bytes()[..n]);
        }
    }

    /// Extract per-lane +1-vote counts (test oracle / debugging; the
    /// hot path never materializes these).
    pub fn counts_into(&self, out: &mut [i32]) {
        debug_assert_eq!(out.len(), self.dim);
        let nbits = self.nbits;
        for (i, o) in out.iter_mut().enumerate() {
            let (wi, bit) = (i / 64, i % 64);
            let mut c = 0u64;
            for b in 0..nbits {
                c |= ((self.planes[wi * nbits + b] >> bit) & 1) << b;
            }
            *o = c as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::sign;
    use crate::util::Rng;

    fn random_signs(rng: &mut Rng, d: usize) -> Vec<i8> {
        (0..d).map(|_| if rng.next_u64() & 1 == 0 { 1i8 } else { -1 }).collect()
    }

    #[test]
    fn sign_byte8_matches_scalar_gather() {
        let mut rng = Rng::new(0x5A);
        for _ in 0..256 {
            let mut v = [0.0f32; 8];
            for x in v.iter_mut() {
                *x = rng.normal_f32(0.0, 1.0);
            }
            // inject signed zeros sometimes
            if rng.next_u64() & 3 == 0 {
                v[rng.below(8)] = if rng.next_u64() & 1 == 0 { 0.0 } else { -0.0 };
            }
            let mut expect = 0u8;
            for (j, &x) in v.iter().enumerate() {
                expect |= (((x.to_bits() >> 31) ^ 1) as u8) << j;
            }
            assert_eq!(sign_byte8(&v), expect, "{v:?}");
        }
    }

    #[test]
    fn pack_f32_into_matches_codec_for_all_remainders() {
        let mut rng = Rng::new(0x5B);
        for d in [0usize, 1, 2, 3, 4, 5, 6, 7, 8, 9, 15, 16, 17, 63, 64, 65, 200, 1003] {
            let mut v = vec![0.0f32; d];
            for x in v.iter_mut() {
                *x = rng.normal_f32(0.0, 1.0);
            }
            if d > 0 {
                v[rng.below(d)] = -0.0;
            }
            let mut out = vec![0xAAu8; sign::packed_len(d)]; // poisoned buffer
            pack_f32_into(&v, &mut out);
            assert_eq!(out, sign::pack_f32(&v), "d={d}");
        }
    }

    #[test]
    fn plane_counts_match_naive_vote_sums() {
        let mut rng = Rng::new(0x5C);
        for n in [1usize, 2, 3, 4, 5, 6, 7, 8, 9, 32] {
            for d in [0usize, 1, 7, 8, 63, 64, 65, 200] {
                let mut planes = VotePlanes::new(d, n);
                let mut votes = vec![0i32; d];
                for _ in 0..n {
                    let packed = sign::pack(&random_signs(&mut rng, d));
                    sign::accumulate_votes_naive(&packed, &mut votes);
                    planes.add(&packed);
                }
                let mut counts = vec![0i32; d];
                planes.counts_into(&mut counts);
                // votes = 2c − n  ⇔  c = (votes + n) / 2
                let expect: Vec<i32> = votes.iter().map(|&v| (v + n as i32) / 2).collect();
                assert_eq!(counts, expect, "n={n} d={d}");
            }
        }
    }

    #[test]
    fn threshold_plane_is_packed_majority_for_odd_n() {
        let mut rng = Rng::new(0x5D);
        for n in [1usize, 3, 5, 7, 9] {
            for d in [1usize, 7, 8, 63, 64, 65, 200] {
                let mut planes = VotePlanes::new(d, n);
                let mut votes = vec![0i32; d];
                for _ in 0..n {
                    let packed = sign::pack(&random_signs(&mut rng, d));
                    sign::accumulate_votes(&packed, &mut votes);
                    planes.add(&packed);
                }
                let majority: Vec<i8> =
                    votes.iter().map(|&v| if v > 0 { 1 } else { -1 }).collect();
                let expect = sign::pack(&majority);
                let mut got = vec![0xAAu8; sign::packed_len(d)];
                planes.threshold_into(n.div_ceil(2), &mut got);
                assert_eq!(got, expect, "n={n} d={d}");
            }
        }
    }

    #[test]
    fn reset_clears_and_reuses_allocation() {
        let mut rng = Rng::new(0x5E);
        let d = 130;
        let mut planes = VotePlanes::new(d, 5);
        for _ in 0..5 {
            planes.add(&sign::pack(&random_signs(&mut rng, d)));
        }
        planes.reset();
        assert_eq!(planes.added(), 0);
        let mut votes = vec![0i32; d];
        for _ in 0..3 {
            let packed = sign::pack(&random_signs(&mut rng, d));
            sign::accumulate_votes(&packed, &mut votes);
            planes.add(&packed);
        }
        let mut counts = vec![0i32; d];
        planes.counts_into(&mut counts);
        let expect: Vec<i32> = votes.iter().map(|&v| (v + 3) / 2).collect();
        assert_eq!(counts, expect);
    }
}
