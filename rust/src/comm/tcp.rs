//! Loopback-TCP transport: same [`ServerTransport`]/[`WorkerTransport`]
//! contract as the in-process fabric, but over real sockets with a
//! length-prefixed frame format. Proves the codecs' wire formats are
//! self-describing and lets the cluster span processes if desired.
//!
//! Frame: u32 LE payload length, then payload bytes.

use super::chunked;
use super::transport::{CommStats, Message, ServerTransport, SharedMessage, WorkerTransport};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> std::io::Result<()> {
    stream.write_all(&(payload.len() as u32).to_le_bytes())?;
    stream.write_all(payload)?;
    stream.flush()
}

fn read_frame(stream: &mut TcpStream) -> std::io::Result<Message> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    let mut payload = vec![0u8; len];
    stream.read_exact(&mut payload)?;
    Ok(payload)
}

pub struct TcpServer {
    conns: Vec<TcpStream>,
    stats: Arc<CommStats>,
}

pub struct TcpWorker {
    id: usize,
    conn: TcpStream,
    stats: Arc<CommStats>,
}

/// Bind an ephemeral loopback port and return (server-builder-port, listener).
pub fn bind_loopback() -> std::io::Result<(u16, TcpListener)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let port = listener.local_addr()?.port();
    Ok((port, listener))
}

impl TcpServer {
    /// Accept exactly `n` worker connections. Workers identify themselves
    /// with a 4-byte id frame so gather order is index-aligned.
    pub fn accept(listener: &TcpListener, n: usize, stats: Arc<CommStats>) -> std::io::Result<Self> {
        let mut conns: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (mut stream, _) = listener.accept()?;
            stream.set_nodelay(true)?;
            let mut id_buf = [0u8; 4];
            stream.read_exact(&mut id_buf)?;
            let id = u32::from_le_bytes(id_buf) as usize;
            if id >= n || conns[id].is_some() {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad worker id {id}"),
                ));
            }
            conns[id] = Some(stream);
        }
        Ok(TcpServer { conns: conns.into_iter().map(|c| c.unwrap()).collect(), stats })
    }
}

impl TcpWorker {
    pub fn connect(port: u16, id: usize, stats: Arc<CommStats>) -> std::io::Result<Self> {
        let mut conn = TcpStream::connect(("127.0.0.1", port))?;
        conn.set_nodelay(true)?;
        conn.write_all(&(id as u32).to_le_bytes())?;
        Ok(TcpWorker { id, conn, stats })
    }
}

impl ServerTransport for TcpServer {
    fn num_workers(&self) -> usize {
        self.conns.len()
    }

    fn gather(&mut self) -> std::io::Result<Vec<Message>> {
        let mut msgs = Vec::with_capacity(self.conns.len());
        for conn in &mut self.conns {
            msgs.push(read_frame(conn)?);
        }
        Ok(msgs)
    }

    fn broadcast(&mut self, msg: &[u8]) -> std::io::Result<()> {
        let logical = chunked::payload_len(msg);
        for conn in &mut self.conns {
            self.stats.record_downlink(logical);
            write_frame(conn, msg)?;
        }
        Ok(())
    }
}

impl WorkerTransport for TcpWorker {
    fn worker_id(&self) -> usize {
        self.id
    }

    fn send(&mut self, msg: Message) -> std::io::Result<()> {
        self.stats.record_uplink(chunked::payload_len(&msg));
        write_frame(&mut self.conn, &msg)
    }

    fn recv(&mut self) -> std::io::Result<SharedMessage> {
        read_frame(&mut self.conn).map(Arc::from)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn tcp_fabric_round() {
        let stats = CommStats::new();
        let (port, listener) = bind_loopback().unwrap();
        let n = 3;
        let worker_handles: Vec<_> = (0..n)
            .map(|id| {
                let stats = stats.clone();
                thread::spawn(move || {
                    let mut w = TcpWorker::connect(port, id, stats).unwrap();
                    w.send(vec![id as u8; 5]).unwrap();
                    let d = w.recv().unwrap();
                    assert_eq!(&d[..], [7u8; 3]);
                })
            })
            .collect();
        let mut server = TcpServer::accept(&listener, n, stats.clone()).unwrap();
        let msgs = server.gather().unwrap();
        for (i, m) in msgs.iter().enumerate() {
            assert_eq!(m, &vec![i as u8; 5]);
        }
        server.broadcast(&[7u8; 3]).unwrap();
        for h in worker_handles {
            h.join().unwrap();
        }
        assert_eq!(stats.uplink(), 15);
        assert_eq!(stats.downlink(), 9);
    }

    #[test]
    fn tcp_round_trips_multi_frame_chunked_messages() {
        // Satellite contract: a chunked multi-frame message survives a
        // real socket round trip byte-for-byte in both directions, and
        // the counters charge its monolithic-equivalent payload.
        let stats = CommStats::new();
        let (port, listener) = bind_loopback().unwrap();
        let up_msg = chunked::pack(&[vec![1u8, 0xDE, 0xAD], vec![1u8, 0xBE], vec![1u8, 0xEF]]);
        let down_msg = chunked::pack(&[vec![4u8, 1, 2, 3, 4], vec![4u8, 5, 6, 7, 8]]);
        let expect_down = down_msg.clone();
        let w_up = up_msg.clone();
        let worker = {
            let stats = stats.clone();
            thread::spawn(move || {
                let mut w = TcpWorker::connect(port, 0, stats).unwrap();
                w.send(w_up).unwrap();
                let d = w.recv().unwrap();
                assert_eq!(&d[..], &expect_down[..], "downlink envelope mangled");
                let frames = chunked::unpack(&d).unwrap();
                assert_eq!(frames.len(), 2, "self-describing chunk count");
            })
        };
        let mut server = TcpServer::accept(&listener, 1, stats.clone()).unwrap();
        let msgs = server.gather().unwrap();
        assert_eq!(msgs[0], up_msg, "uplink envelope mangled");
        let frames = chunked::unpack(&msgs[0]).unwrap();
        assert_eq!(frames.len(), 3);
        assert_eq!(frames[0], &up_msg[7..10]);
        server.broadcast(&down_msg).unwrap();
        worker.join().unwrap();
        // logical accounting: sign chunks 2+1+1 payload bytes + 1 tag;
        // dense chunks 4+4 payload bytes + 1 tag
        assert_eq!(stats.uplink(), 5);
        assert_eq!(stats.downlink(), 9);
    }
}
