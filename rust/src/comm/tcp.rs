//! Loopback-TCP transport: same [`ServerTransport`]/[`WorkerTransport`]
//! contract as the in-process fabric, but over real sockets with a
//! length-prefixed frame format. Proves the codecs' wire formats are
//! self-describing and lets the cluster span processes if desired.
//!
//! Frame: u32 LE payload length (clamped to [`MAX_FRAME_BYTES`] — a
//! corrupt peer cannot force an arbitrary allocation), then payload
//! bytes.
//!
//! Fault tolerance (the elastic/chaos layer rides on these):
//! * every read can run under a per-connection deadline
//!   ([`TcpServer::gather_quorum`]), so a stalled worker yields `None`
//!   for the round instead of hanging the server in `read_exact`;
//! * a worker that drops mid-frame surfaces a **named** error (which
//!   worker, what failed) and is marked dead — later rounds skip it;
//! * a dead worker can rejoin: the handshake is
//!   `[id: u32 LE][applied_rounds: u32 LE]`, and the server replays the
//!   broadcasts the worker missed from a small ring buffer
//!   ([`TcpServer::accept_reconnect`]), round-id checked, so the
//!   rejoining replica catches up to the cluster state exactly. The
//!   ring length is a config knob (`hyper.replay_ring`, threaded
//!   through [`TcpServer::accept`] / [`TcpWorker::reconnect`] from one
//!   source of truth) — a gap beyond it must catch up from a
//!   checkpoint first;
//! * both directions are backpressure-bounded: a worker caps its
//!   in-flight uplink frames ([`TcpWorker::set_max_in_flight`]) and the
//!   server can put broadcasts under a write deadline
//!   ([`TcpServer::set_write_deadline`]) so one stalled receiver with a
//!   full socket buffer cannot wedge the round loop.

use super::chunked;
use super::transport::{CommStats, Message, ServerTransport, SharedMessage, WorkerTransport};
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

/// Upper bound on a single frame's payload. Far above any real message
/// (a dense f32 frame at 16M params is 64 MB), far below what a
/// corrupt 4-byte prefix can claim (4 GB).
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Default broadcast rounds the server keeps for reconnect replay.
/// The live value is the `hyper.replay_ring` config knob
/// ([`crate::cluster::TrainConfig::replay_ring`]) — both ends of the
/// reconnect handshake are handed the same number, so the server's
/// ring length and the worker's hostile-count clamp cannot disagree.
pub const DEFAULT_REPLAY_RING: usize = 8;

/// Default cap on a worker's in-flight uplink frames (sent but not yet
/// answered by a downlink). The round protocol alternates send/recv so
/// a healthy worker never holds more than one; the cap turns an
/// unbounded queue-up against a wedged server into a named error.
pub const DEFAULT_MAX_IN_FLIGHT: usize = 32;

fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> std::io::Result<()> {
    stream.write_all(&(payload.len() as u32).to_le_bytes())?;
    stream.write_all(payload)?;
    stream.flush()
}

fn read_frame(stream: &mut TcpStream) -> std::io::Result<Message> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds MAX_FRAME_BYTES ({MAX_FRAME_BYTES})"),
        ));
    }
    let mut payload = vec![0u8; len];
    stream.read_exact(&mut payload)?;
    Ok(payload)
}

pub struct TcpServer {
    /// Index-aligned worker connections; `None` marks a dead worker
    /// (dropped mid-frame, missed deadline with a broken socket, …) —
    /// gather/broadcast skip it until [`TcpServer::accept_reconnect`]
    /// fills the slot again.
    conns: Vec<Option<TcpStream>>,
    stats: Arc<CommStats>,
    /// Broadcast rounds completed (the round id of the *next* broadcast).
    round: u32,
    /// Last `ring_cap` broadcasts, as `(round_id, frame)`.
    ring: VecDeque<(u32, Vec<u8>)>,
    /// Replay-ring capacity (the `hyper.replay_ring` knob).
    ring_cap: usize,
    /// Active read deadline, remembered so a connection installed later
    /// by [`TcpServer::accept_reconnect`] gets it too — without this a
    /// rejoined-then-stalling worker hangs the next blocking gather.
    read_deadline: Option<Duration>,
    /// Active write deadline (broadcast backpressure bound), applied to
    /// reconnect-installed connections the same way.
    write_deadline: Option<Duration>,
}

pub struct TcpWorker {
    id: usize,
    conn: TcpStream,
    stats: Arc<CommStats>,
    /// Downlink broadcasts received+applied (the `applied_rounds` this
    /// worker would present in a reconnect handshake).
    rounds: u32,
    /// Uplink frames sent but not yet answered by a downlink.
    in_flight: usize,
    /// Backpressure cap on `in_flight` (see [`DEFAULT_MAX_IN_FLIGHT`]).
    max_in_flight: usize,
}

/// Bind an ephemeral loopback port and return (server-builder-port, listener).
pub fn bind_loopback() -> std::io::Result<(u16, TcpListener)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let port = listener.local_addr()?.port();
    Ok((port, listener))
}

/// Read and validate the 8-byte `[id][applied_rounds]` handshake.
/// Truncated or garbage input is a named error, never a panic.
fn read_handshake(stream: &mut TcpStream, n: usize) -> std::io::Result<(usize, u32)> {
    let mut buf = [0u8; 8];
    stream.read_exact(&mut buf).map_err(|e| {
        std::io::Error::new(e.kind(), format!("truncated handshake (need 8 bytes): {e}"))
    })?;
    let id = u32::from_le_bytes(buf[0..4].try_into().expect("4-byte slice")) as usize;
    let applied = u32::from_le_bytes(buf[4..8].try_into().expect("4-byte slice"));
    if id >= n {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad worker id {id} (cluster size {n})"),
        ));
    }
    Ok((id, applied))
}

impl TcpServer {
    /// Accept exactly `n` worker connections. Workers identify
    /// themselves with the `[id][applied_rounds]` handshake (fresh
    /// connects present `applied_rounds = 0`) so gather order is
    /// index-aligned. `replay_ring` is the number of broadcasts kept
    /// for reconnect replay (the `hyper.replay_ring` knob — pass the
    /// same value to [`TcpWorker::reconnect`]).
    pub fn accept(
        listener: &TcpListener,
        n: usize,
        stats: Arc<CommStats>,
        replay_ring: usize,
    ) -> std::io::Result<Self> {
        let mut conns: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (mut stream, _) = listener.accept()?;
            stream.set_nodelay(true)?;
            let (id, _applied) = read_handshake(&mut stream, n)?;
            if conns[id].is_some() {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("duplicate worker id {id}"),
                ));
            }
            conns[id] = Some(stream);
        }
        Ok(TcpServer {
            conns,
            stats,
            round: 0,
            ring: VecDeque::new(),
            ring_cap: replay_ring,
            read_deadline: None,
            write_deadline: None,
        })
    }

    /// Number of currently connected (live) workers.
    pub fn live_workers(&self) -> usize {
        self.conns.iter().filter(|c| c.is_some()).count()
    }

    /// Is worker `id`'s connection currently live?
    pub fn is_live(&self, id: usize) -> bool {
        matches!(self.conns.get(id), Some(Some(_)))
    }

    /// Drop worker `id`'s connection (it will read EOF); subsequent
    /// gathers treat it as dead until it reconnects.
    pub fn disconnect(&mut self, id: usize) {
        if let Some(slot) = self.conns.get_mut(id) {
            *slot = None;
        }
    }

    /// Broadcast rounds completed so far.
    pub fn round(&self) -> u32 {
        self.round
    }

    /// Accept one **reconnecting** worker: validate the handshake (the
    /// id must name a currently-dead slot), replay every broadcast the
    /// worker missed from the ring — `[count: u32 LE]` frame, then
    /// `count` ordinary frames, oldest first — and install the
    /// connection. A worker that has been gone longer than the ring
    /// remembers gets a named error (it must rejoin from a checkpoint
    /// instead); so does an `applied_rounds` from the future.
    pub fn accept_reconnect(&mut self, listener: &TcpListener) -> std::io::Result<usize> {
        let (mut stream, _) = listener.accept()?;
        stream.set_nodelay(true)?;
        let n = self.conns.len();
        let (id, applied) = read_handshake(&mut stream, n)?;
        if self.conns[id].is_some() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("worker {id} reconnected while still live"),
            ));
        }
        if applied > self.round {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!(
                    "worker {id} claims {applied} applied rounds, server is at {}",
                    self.round
                ),
            ));
        }
        let missed = (self.round - applied) as usize;
        if missed > self.ring.len() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!(
                    "worker {id} missed {missed} rounds, replay ring holds {} \
                     (rejoin from a checkpoint)",
                    self.ring.len()
                ),
            ));
        }
        stream.write_all(&(missed as u32).to_le_bytes())?;
        let replay_from = self.ring.len() - missed;
        for (k, (round_id, frame)) in self.ring.iter().skip(replay_from).enumerate() {
            debug_assert_eq!(*round_id, applied + k as u32, "ring round ids");
            write_frame(&mut stream, frame)?;
            // Replay is real wire traffic but not a second logical
            // broadcast: those bytes were charged to `downlink` when the
            // round originally closed, so recovery traffic gets its own
            // counter and byte accounting stays per-hop-exact.
            self.stats.record_replay(chunked::payload_len(frame));
        }
        stream.flush()?;
        // The rejoined connection must honor the same deadlines as the
        // ones live when `set_read_deadline`/`set_write_deadline` ran,
        // or a stalling rejoiner hangs the next blocking gather.
        stream.set_read_timeout(self.read_deadline)?;
        stream.set_write_timeout(self.write_deadline)?;
        self.conns[id] = Some(stream);
        Ok(id)
    }

    /// Apply one read deadline to every live connection (`None` clears
    /// it — reads block forever again). The deadline is remembered and
    /// re-applied to connections [`TcpServer::accept_reconnect`]
    /// installs later.
    pub fn set_read_deadline(&mut self, deadline: Option<Duration>) -> std::io::Result<()> {
        self.read_deadline = deadline;
        for conn in self.conns.iter_mut().flatten() {
            conn.set_read_timeout(deadline)?;
        }
        Ok(())
    }

    /// Bound every broadcast write by `deadline` (backpressure: a
    /// receiver that stopped draining its socket eventually fills the
    /// kernel buffers, the blocked write times out, and the worker is
    /// marked dead instead of wedging the round loop). Remembered and
    /// re-applied on reconnect installs, like the read deadline.
    pub fn set_write_deadline(&mut self, deadline: Option<Duration>) -> std::io::Result<()> {
        self.write_deadline = deadline;
        for conn in self.conns.iter_mut().flatten() {
            conn.set_write_timeout(deadline)?;
        }
        Ok(())
    }
}

impl TcpWorker {
    pub fn connect(port: u16, id: usize, stats: Arc<CommStats>) -> std::io::Result<Self> {
        let mut conn = TcpStream::connect(("127.0.0.1", port))?;
        conn.set_nodelay(true)?;
        conn.write_all(&(id as u32).to_le_bytes())?;
        conn.write_all(&0u32.to_le_bytes())?; // fresh: 0 applied rounds
        Ok(TcpWorker {
            id,
            conn,
            stats,
            rounds: 0,
            in_flight: 0,
            max_in_flight: DEFAULT_MAX_IN_FLIGHT,
        })
    }

    /// Reconnect after a drop: present `[id][applied_rounds]`, then
    /// receive the broadcasts this worker missed (round-id checked
    /// server-side). Returns the worker plus the replayed downlinks,
    /// oldest first — the caller applies them in order before rejoining
    /// the round loop. `replay_ring` is the same `hyper.replay_ring`
    /// knob the server was built with: a hostile replay count beyond it
    /// is rejected without allocating.
    pub fn reconnect(
        port: u16,
        id: usize,
        applied_rounds: u32,
        stats: Arc<CommStats>,
        replay_ring: usize,
    ) -> std::io::Result<(Self, Vec<SharedMessage>)> {
        let mut conn = TcpStream::connect(("127.0.0.1", port))?;
        conn.set_nodelay(true)?;
        conn.write_all(&(id as u32).to_le_bytes())?;
        conn.write_all(&applied_rounds.to_le_bytes())?;
        let mut count_buf = [0u8; 4];
        conn.read_exact(&mut count_buf).map_err(|e| {
            std::io::Error::new(e.kind(), format!("reconnect replay header: {e}"))
        })?;
        let count = u32::from_le_bytes(count_buf) as usize;
        if count > replay_ring {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("server claims {count} replay frames (ring capacity {replay_ring})"),
            ));
        }
        let mut replayed = Vec::with_capacity(count);
        for _ in 0..count {
            replayed.push(SharedMessage::from(read_frame(&mut conn)?));
        }
        let rounds = applied_rounds + count as u32;
        Ok((
            TcpWorker {
                id,
                conn,
                stats,
                rounds,
                in_flight: 0,
                max_in_flight: DEFAULT_MAX_IN_FLIGHT,
            },
            replayed,
        ))
    }

    /// Downlink broadcasts received so far (the reconnect handshake's
    /// `applied_rounds`).
    pub fn rounds_received(&self) -> u32 {
        self.rounds
    }

    /// Override the in-flight uplink cap (backpressure bound enforced
    /// by [`WorkerTransport::send`]).
    pub fn set_max_in_flight(&mut self, cap: usize) {
        self.max_in_flight = cap;
    }
}

impl ServerTransport for TcpServer {
    fn num_workers(&self) -> usize {
        self.conns.len()
    }

    /// Lockstep gather: one frame from every worker, in index order. A
    /// dead or failing worker is a **named** error (`worker {i}: …`) —
    /// never a silent hang on a half-closed socket.
    fn gather(&mut self) -> std::io::Result<Vec<Message>> {
        let mut msgs = Vec::with_capacity(self.conns.len());
        for (i, conn) in self.conns.iter_mut().enumerate() {
            let conn = conn.as_mut().ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::NotConnected,
                    format!("worker {i}: disconnected"),
                )
            })?;
            let frame = read_frame(conn).map_err(|e| {
                std::io::Error::new(e.kind(), format!("worker {i}: {e}"))
            })?;
            msgs.push(frame);
        }
        Ok(msgs)
    }

    fn broadcast(&mut self, msg: &[u8]) -> std::io::Result<()> {
        let logical = chunked::payload_len(msg);
        for conn in self.conns.iter_mut() {
            let Some(stream) = conn.as_mut() else { continue };
            match write_frame(stream, msg) {
                Ok(()) => self.stats.record_downlink(logical),
                // a worker that died between gather and broadcast is
                // marked dead, not fatal — the elastic driver keeps the
                // survivors moving
                Err(_) => *conn = None,
            }
        }
        self.ring.push_back((self.round, msg.to_vec()));
        if self.ring.len() > self.ring_cap {
            self.ring.pop_front();
        }
        self.round += 1;
        Ok(())
    }

    /// Deadline gather: every live connection gets `deadline` to
    /// deliver its frame. A timeout yields `None` for the round (the
    /// connection stays live — the worker is merely late and, by the
    /// elastic protocol, skips the round rather than sending into the
    /// next one); EOF / reset / a malformed frame marks the worker dead
    /// and yields `None`. Dead slots yield `None` immediately.
    ///
    /// Note the deadline applies per connection and a partial frame
    /// followed by a timeout would leave the stream misaligned — the
    /// chaos protocol avoids this by making delayed workers skip the
    /// send entirely (frames are small; loopback delivers them whole).
    fn gather_quorum(
        &mut self,
        deadline: Option<Duration>,
    ) -> std::io::Result<Vec<Option<Message>>> {
        let mut msgs = Vec::with_capacity(self.conns.len());
        for conn in self.conns.iter_mut() {
            let Some(stream) = conn.as_mut() else {
                msgs.push(None);
                continue;
            };
            stream.set_read_timeout(deadline)?;
            match read_frame(stream) {
                Ok(frame) => msgs.push(Some(frame)),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    // straggler: no frame this round, connection kept
                    msgs.push(None);
                }
                Err(_) => {
                    // EOF, reset, oversized frame, …: the worker is gone
                    *conn = None;
                    msgs.push(None);
                }
            }
        }
        Ok(msgs)
    }
}

impl WorkerTransport for TcpWorker {
    fn worker_id(&self) -> usize {
        self.id
    }

    fn send(&mut self, msg: Message) -> std::io::Result<()> {
        if self.in_flight >= self.max_in_flight {
            return Err(std::io::Error::new(
                std::io::ErrorKind::WouldBlock,
                format!(
                    "worker {}: backpressure — {} uplink frames in flight (cap {}); \
                     apply a downlink before sending more",
                    self.id, self.in_flight, self.max_in_flight
                ),
            ));
        }
        self.stats.record_uplink(chunked::payload_len(&msg));
        write_frame(&mut self.conn, &msg)?;
        self.in_flight += 1;
        Ok(())
    }

    fn recv(&mut self) -> std::io::Result<SharedMessage> {
        let frame = read_frame(&mut self.conn)?;
        self.rounds += 1;
        self.in_flight = self.in_flight.saturating_sub(1);
        Ok(Arc::from(frame))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn tcp_fabric_round() {
        let stats = CommStats::new();
        let (port, listener) = bind_loopback().unwrap();
        let n = 3;
        let worker_handles: Vec<_> = (0..n)
            .map(|id| {
                let stats = stats.clone();
                thread::spawn(move || {
                    let mut w = TcpWorker::connect(port, id, stats).unwrap();
                    w.send(vec![id as u8; 5]).unwrap();
                    let d = w.recv().unwrap();
                    assert_eq!(&d[..], [7u8; 3]);
                    assert_eq!(w.rounds_received(), 1);
                })
            })
            .collect();
        let mut server =
            TcpServer::accept(&listener, n, stats.clone(), DEFAULT_REPLAY_RING).unwrap();
        let msgs = server.gather().unwrap();
        for (i, m) in msgs.iter().enumerate() {
            assert_eq!(m, &vec![i as u8; 5]);
        }
        server.broadcast(&[7u8; 3]).unwrap();
        assert_eq!(server.round(), 1);
        for h in worker_handles {
            h.join().unwrap();
        }
        assert_eq!(stats.uplink(), 15);
        assert_eq!(stats.downlink(), 9);
    }

    #[test]
    fn tcp_round_trips_multi_frame_chunked_messages() {
        // Satellite contract: a chunked multi-frame message survives a
        // real socket round trip byte-for-byte in both directions, and
        // the counters charge its monolithic-equivalent payload.
        let stats = CommStats::new();
        let (port, listener) = bind_loopback().unwrap();
        let up_msg = chunked::pack(&[vec![1u8, 0xDE, 0xAD], vec![1u8, 0xBE], vec![1u8, 0xEF]]);
        let down_msg = chunked::pack(&[vec![4u8, 1, 2, 3, 4], vec![4u8, 5, 6, 7, 8]]);
        let expect_down = down_msg.clone();
        let w_up = up_msg.clone();
        let worker = {
            let stats = stats.clone();
            thread::spawn(move || {
                let mut w = TcpWorker::connect(port, 0, stats).unwrap();
                w.send(w_up).unwrap();
                let d = w.recv().unwrap();
                assert_eq!(&d[..], &expect_down[..], "downlink envelope mangled");
                let frames = chunked::unpack(&d).unwrap();
                assert_eq!(frames.len(), 2, "self-describing chunk count");
            })
        };
        let mut server =
            TcpServer::accept(&listener, 1, stats.clone(), DEFAULT_REPLAY_RING).unwrap();
        let msgs = server.gather().unwrap();
        assert_eq!(msgs[0], up_msg, "uplink envelope mangled");
        let frames = chunked::unpack(&msgs[0]).unwrap();
        assert_eq!(frames.len(), 3);
        assert_eq!(frames[0], &up_msg[7..10]);
        server.broadcast(&down_msg).unwrap();
        worker.join().unwrap();
        // logical accounting: sign chunks 2+1+1 payload bytes + 1 tag;
        // dense chunks 4+4 payload bytes + 1 tag
        assert_eq!(stats.uplink(), 5);
        assert_eq!(stats.downlink(), 9);
    }

    #[test]
    fn oversized_length_prefix_is_a_named_error_not_an_allocation() {
        // Satellite regression: a corrupt 4-byte prefix claiming 4 GB
        // must produce InvalidData naming the budget, not vec![0; 4GB].
        let (port, listener) = bind_loopback().unwrap();
        let stats = CommStats::new();
        let attacker = thread::spawn(move || {
            let mut s = TcpStream::connect(("127.0.0.1", port)).unwrap();
            s.write_all(&0u32.to_le_bytes()).unwrap(); // id 0
            s.write_all(&0u32.to_le_bytes()).unwrap(); // applied 0
            s.write_all(&u32::MAX.to_le_bytes()).unwrap(); // "4 GB frame"
            s
        });
        let mut server = TcpServer::accept(&listener, 1, stats, DEFAULT_REPLAY_RING).unwrap();
        let err = server.gather().unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        let msg = err.to_string();
        assert!(msg.contains("MAX_FRAME_BYTES"), "unnamed error: {msg}");
        assert!(msg.contains("worker 0"), "error must name the worker: {msg}");
        drop(attacker.join().unwrap());
    }
}
