//! Ternary codec: values in {−1, 0, +1} packed 5 per byte (base-3).
//!
//! 3^5 = 243 ≤ 256, so five trits fit one byte: 1.6 bits/element, matching
//! TernGrad's ~1.5d-bit worker→server channel (Table 1; the theoretical
//! optimum is log2(3) ≈ 1.585 bits). Also used for the D-Lion MaVo
//! downlink when N is even (vote ties produce genuine zeros; with odd N
//! the downlink is strictly binary and the 1-bit sign codec applies).
//!
//! The public pack/unpack route through [`super::simd`]: encode as a
//! direct base-3 dot product (no serial Horner chain between the five
//! multiplies) and decode via a 256×5 lookup table. The loops here stay
//! as `*_scalar` parity oracles — including for malformed bytes ≥ 243,
//! which the LUT reproduces digit-for-digit.

use super::simd;

/// Payload bytes for `d` ternary values.
#[inline]
pub fn packed_len(d: usize) -> usize {
    d.div_ceil(5)
}

/// Pack trits in {-1,0,1} (stored as t+1 in {0,1,2}).
pub fn pack(trits: &[i8]) -> Vec<u8> {
    let mut out = vec![0u8; packed_len(trits.len())];
    simd::tern_pack_into(trits, &mut out);
    out
}

/// Scalar oracle for [`pack`] (serial Horner per byte).
pub fn pack_scalar(trits: &[i8]) -> Vec<u8> {
    let mut out = vec![0u8; packed_len(trits.len())];
    for (ci, chunk) in trits.chunks(5).enumerate() {
        let mut byte = 0u16;
        // Horner, last trit is highest power so decode pops in order.
        for &t in chunk.iter().rev() {
            debug_assert!((-1..=1).contains(&t), "ternary codec requires {{-1,0,1}}");
            byte = byte * 3 + (t + 1) as u16;
        }
        out[ci] = byte as u8;
    }
    out
}

/// Unpack `d` trits.
pub fn unpack(packed: &[u8], d: usize) -> Vec<i8> {
    let mut out = vec![0i8; d];
    unpack_into(packed, &mut out);
    out
}

/// Unpack into a preallocated buffer.
pub fn unpack_into(packed: &[u8], out: &mut [i8]) {
    simd::tern_unpack_into(packed, out);
}

/// Scalar oracle for [`unpack_into`] (serial %3 chain per byte).
pub fn unpack_into_scalar(packed: &[u8], out: &mut [i8]) {
    for (ci, chunk) in out.chunks_mut(5).enumerate() {
        let mut v = packed[ci] as u16;
        for o in chunk.iter_mut() {
            *o = (v % 3) as i8 - 1;
            v /= 3;
        }
    }
}

/// Effective bits per element of this encoding (8/5 = 1.6).
pub const BITS_PER_ELEM: f64 = 8.0 / 5.0;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;

    #[test]
    fn roundtrip() {
        testing::forall(
            0x71,
            128,
            |r| testing::gen_vec_tern(r, 0, 300, 0.4),
            |t| unpack(&pack(t), t.len()) == *t,
        );
    }

    #[test]
    fn size_is_1_6_bits_per_elem() {
        assert_eq!(packed_len(5), 1);
        assert_eq!(packed_len(6), 2);
        assert_eq!(packed_len(1_000_000), 200_000); // 1.6e6 bits
    }

    #[test]
    fn all_27_three_trit_combos() {
        for a in -1..=1i8 {
            for b in -1..=1i8 {
                for c in -1..=1i8 {
                    let t = [a, b, c];
                    assert_eq!(unpack(&pack(&t), 3), t);
                }
            }
        }
    }

    #[test]
    fn pack_matches_scalar_oracle() {
        testing::forall(
            0x72,
            128,
            |r| testing::gen_vec_tern(r, 0, 300, 0.4),
            |t| pack(t) == pack_scalar(t),
        );
    }

    #[test]
    fn unpack_matches_scalar_oracle_on_all_bytes() {
        // Every byte value, including malformed ≥ 243, must decode
        // identically to the scalar %3 chain.
        let packed: Vec<u8> = (0..=255u8).collect();
        let mut fast = vec![0i8; 256 * 5];
        let mut slow = vec![0i8; 256 * 5];
        unpack_into(&packed, &mut fast);
        unpack_into_scalar(&packed, &mut slow);
        assert_eq!(fast, slow);
    }
}
