//! Transport abstraction with per-direction byte accounting.
//!
//! The paper's claims are about *communication volume*; every byte that
//! crosses a worker↔server boundary in this repo goes through a
//! [`ServerTransport`]/[`WorkerTransport`] pair, whose counters feed the
//! bandwidth columns of Table 1 / Figure 4 benches. Two implementations:
//!
//! * [`InProcServer`]/[`InProcWorker`] — `std::sync::mpsc` channels
//!   between threads (the default cluster fabric).
//! * `comm::tcp::TcpTransport` — real loopback TCP sockets, proving the
//!   wire format is self-describing.

use super::chunked;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

/// Byte and message counters shared by all endpoints of one cluster.
/// The worker-edge pair (`uplink`/`downlink`) is recorded by the
/// transports themselves; the aggregator pair covers the group↔root
/// hops of a hierarchical topology ([`crate::cluster::topology`]),
/// recorded by the round engine (in-process aggregators are co-located
/// with the root, so that hop is simulated — its byte accounting is
/// exact, its latency is not).
///
/// Bytes are *codec payload* bytes ([`chunked::payload_len`]): for the
/// monolithic frames every pre-chunking path moves they equal the
/// physical message size; for chunked multi-frame messages the envelope
/// overhead is excluded so the Table-1 accounting is chunking-invariant.
#[derive(Default, Debug)]
pub struct CommStats {
    /// bytes moved worker → server/aggregator (sum over workers)
    pub uplink_bytes: AtomicU64,
    /// bytes moved server/aggregator → worker (sum over workers)
    pub downlink_bytes: AtomicU64,
    /// bytes moved aggregator → root (sum over groups; 0 on a flat star)
    pub agg_uplink_bytes: AtomicU64,
    /// bytes moved root → aggregator (broadcast × groups; 0 on a flat star)
    pub agg_downlink_bytes: AtomicU64,
    /// number of uplink messages
    pub uplink_msgs: AtomicU64,
    /// number of downlink messages
    pub downlink_msgs: AtomicU64,
    /// number of aggregator → root messages (hierarchical only)
    pub agg_uplink_msgs: AtomicU64,
    /// number of root → aggregator messages (hierarchical only)
    pub agg_downlink_msgs: AtomicU64,
    /// communication rounds closed (elastic driver only)
    pub rounds: AtomicU64,
    /// rounds that closed with fewer uplinks than workers
    pub partial_rounds: AtomicU64,
    /// sum of achieved quorums over all closed rounds
    pub quorum_sum: AtomicU64,
    /// bytes re-sent from the broadcast replay ring to rejoining
    /// workers — real wire traffic, but *not* a second logical
    /// broadcast: the same payload was already charged to
    /// `downlink_bytes` when its round closed, so recovery traffic is
    /// kept out of the round-accounting columns
    pub replay_bytes: AtomicU64,
    /// number of replayed frames (reconnect catch-up)
    pub replay_msgs: AtomicU64,
}

impl CommStats {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }
    pub fn record_uplink(&self, bytes: usize) {
        self.uplink_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.uplink_msgs.fetch_add(1, Ordering::Relaxed);
    }
    pub fn record_downlink(&self, bytes: usize) {
        self.downlink_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.downlink_msgs.fetch_add(1, Ordering::Relaxed);
    }
    /// Record one round's aggregator→root traffic (all groups).
    pub fn record_agg_uplink(&self, bytes: usize, msgs: usize) {
        self.agg_uplink_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.agg_uplink_msgs.fetch_add(msgs as u64, Ordering::Relaxed);
    }
    /// Record one round's root→aggregator traffic (broadcast × groups).
    pub fn record_agg_downlink(&self, bytes: usize, msgs: usize) {
        self.agg_downlink_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.agg_downlink_msgs.fetch_add(msgs as u64, Ordering::Relaxed);
    }
    /// Record one frame replayed to a rejoining worker (reconnect
    /// catch-up traffic — charged separately from `downlink`, which
    /// already counted these payload bytes at the original broadcast).
    pub fn record_replay(&self, bytes: usize) {
        self.replay_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.replay_msgs.fetch_add(1, Ordering::Relaxed);
    }
    /// Record one elastic round closing with `arrived` of `nworkers`
    /// uplinks (the achieved quorum).
    pub fn record_round_quorum(&self, arrived: usize, nworkers: usize) {
        self.rounds.fetch_add(1, Ordering::Relaxed);
        self.quorum_sum.fetch_add(arrived as u64, Ordering::Relaxed);
        if arrived < nworkers {
            self.partial_rounds.fetch_add(1, Ordering::Relaxed);
        }
    }
    pub fn uplink(&self) -> u64 {
        self.uplink_bytes.load(Ordering::Relaxed)
    }
    pub fn downlink(&self) -> u64 {
        self.downlink_bytes.load(Ordering::Relaxed)
    }
    pub fn agg_uplink(&self) -> u64 {
        self.agg_uplink_bytes.load(Ordering::Relaxed)
    }
    pub fn agg_downlink(&self) -> u64 {
        self.agg_downlink_bytes.load(Ordering::Relaxed)
    }
    /// Aggregator→root message count (hierarchical message-count
    /// observability; 0 on the flat star).
    pub fn agg_uplink_msg_count(&self) -> u64 {
        self.agg_uplink_msgs.load(Ordering::Relaxed)
    }
    /// Root→aggregator message count.
    pub fn agg_downlink_msg_count(&self) -> u64 {
        self.agg_downlink_msgs.load(Ordering::Relaxed)
    }
    /// Elastic rounds closed so far.
    pub fn round_count(&self) -> u64 {
        self.rounds.load(Ordering::Relaxed)
    }
    /// Elastic rounds that closed below full quorum.
    pub fn partial_round_count(&self) -> u64 {
        self.partial_rounds.load(Ordering::Relaxed)
    }
    /// Sum of achieved quorums (mean quorum = this / [`Self::round_count`]).
    pub fn quorum_total(&self) -> u64 {
        self.quorum_sum.load(Ordering::Relaxed)
    }
    /// Bytes replayed to rejoining workers (recovery traffic).
    pub fn replay(&self) -> u64 {
        self.replay_bytes.load(Ordering::Relaxed)
    }
    /// Frames replayed to rejoining workers.
    pub fn replay_msg_count(&self) -> u64 {
        self.replay_msgs.load(Ordering::Relaxed)
    }
    /// All bytes that crossed any link (worker edge + aggregator hops).
    pub fn total(&self) -> u64 {
        self.uplink() + self.downlink() + self.agg_uplink() + self.agg_downlink()
    }
    pub fn reset(&self) {
        self.uplink_bytes.store(0, Ordering::Relaxed);
        self.downlink_bytes.store(0, Ordering::Relaxed);
        self.agg_uplink_bytes.store(0, Ordering::Relaxed);
        self.agg_downlink_bytes.store(0, Ordering::Relaxed);
        self.uplink_msgs.store(0, Ordering::Relaxed);
        self.downlink_msgs.store(0, Ordering::Relaxed);
        self.agg_uplink_msgs.store(0, Ordering::Relaxed);
        self.agg_downlink_msgs.store(0, Ordering::Relaxed);
        self.rounds.store(0, Ordering::Relaxed);
        self.partial_rounds.store(0, Ordering::Relaxed);
        self.quorum_sum.store(0, Ordering::Relaxed);
        self.replay_bytes.store(0, Ordering::Relaxed);
        self.replay_msgs.store(0, Ordering::Relaxed);
    }
}

/// A message on the fabric.
pub type Message = Vec<u8>;

/// A broadcast downlink message: one shared allocation handed to every
/// worker (the server clones the `Arc`, not the bytes, so an N-worker
/// broadcast is O(d), not O(N·d)).
pub type SharedMessage = Arc<[u8]>;

/// Server side of a transport: receive one uplink from each worker,
/// broadcast one downlink to all.
pub trait ServerTransport: Send {
    fn num_workers(&self) -> usize;
    /// Gather one message from every worker (index-aligned).
    fn gather(&mut self) -> std::io::Result<Vec<Message>>;
    /// Broadcast one message to every worker.
    fn broadcast(&mut self, msg: &[u8]) -> std::io::Result<()>;
    /// Elastic gather: wait up to `deadline` per worker (`None` =
    /// forever) and return `Some(frame)` for each uplink that arrived,
    /// `None` for stragglers and disconnected workers — the transport
    /// never fails the whole round because one worker went quiet. The
    /// default is the lockstep gather (every slot `Some`), so
    /// transports without deadline support still serve
    /// lockstep-policy elastic drivers.
    fn gather_quorum(
        &mut self,
        deadline: Option<std::time::Duration>,
    ) -> std::io::Result<Vec<Option<Message>>> {
        let _ = deadline;
        Ok(self.gather()?.into_iter().map(Some).collect())
    }
}

/// Worker side of a transport.
pub trait WorkerTransport: Send {
    fn worker_id(&self) -> usize;
    /// Send an uplink message to the server.
    fn send(&mut self, msg: Message) -> std::io::Result<()>;
    /// Block for the next downlink broadcast. The broadcast frame is
    /// shared — workers only read it ([`SharedMessage`] derefs to
    /// `&[u8]`), which is what lets the in-process fabric ship one
    /// allocation to all N workers.
    fn recv(&mut self) -> std::io::Result<SharedMessage>;
}

// ---------------------------------------------------------------------------
// In-process channel fabric
// ---------------------------------------------------------------------------

pub struct InProcServer {
    uplinks: Vec<Receiver<Message>>,
    downlinks: Vec<Sender<SharedMessage>>,
    stats: Arc<CommStats>,
}

pub struct InProcWorker {
    id: usize,
    uplink: Sender<Message>,
    downlink: Receiver<SharedMessage>,
    stats: Arc<CommStats>,
}

/// Build an in-process fabric for `n` workers. Returns (server, workers).
pub fn inproc_fabric(n: usize, stats: Arc<CommStats>) -> (InProcServer, Vec<InProcWorker>) {
    let mut up_rx = Vec::with_capacity(n);
    let mut down_tx = Vec::with_capacity(n);
    let mut workers = Vec::with_capacity(n);
    for id in 0..n {
        let (utx, urx) = std::sync::mpsc::channel();
        let (dtx, drx) = std::sync::mpsc::channel();
        up_rx.push(urx);
        down_tx.push(dtx);
        workers.push(InProcWorker {
            id,
            uplink: utx,
            downlink: drx,
            stats: stats.clone(),
        });
    }
    (InProcServer { uplinks: up_rx, downlinks: down_tx, stats }, workers)
}

impl ServerTransport for InProcServer {
    fn num_workers(&self) -> usize {
        self.uplinks.len()
    }

    fn gather(&mut self) -> std::io::Result<Vec<Message>> {
        let mut msgs = Vec::with_capacity(self.uplinks.len());
        for rx in &self.uplinks {
            let m = rx.recv().map_err(|e| {
                std::io::Error::new(std::io::ErrorKind::BrokenPipe, format!("gather: {e}"))
            })?;
            msgs.push(m);
        }
        Ok(msgs)
    }

    fn broadcast(&mut self, msg: &[u8]) -> std::io::Result<()> {
        // One shared copy of the frame; every send clones the Arc (a
        // refcount bump), so the broadcast is O(d) + O(N), not O(N·d).
        let shared: SharedMessage = Arc::from(msg);
        let logical = chunked::payload_len(msg);
        for tx in &self.downlinks {
            // A hung-up worker (dead receiver) is skipped, not fatal:
            // the elastic driver keeps broadcasting to the survivors.
            if tx.send(shared.clone()).is_ok() {
                self.stats.record_downlink(logical);
            }
        }
        Ok(())
    }

    /// Per-worker `recv_timeout` gather: a worker that missed the
    /// deadline or hung up contributes `None` this round; its frame (if
    /// merely late) stays queued in the channel for the next round's
    /// gather — which is why the elastic driver must pair this with
    /// workers that *skip* sending on delayed rounds, keeping the
    /// frame↔round alignment deterministic.
    fn gather_quorum(
        &mut self,
        deadline: Option<std::time::Duration>,
    ) -> std::io::Result<Vec<Option<Message>>> {
        let mut msgs = Vec::with_capacity(self.uplinks.len());
        for rx in &self.uplinks {
            let got = match deadline {
                None => rx.recv().ok(),
                Some(d) => rx.recv_timeout(d).ok(),
            };
            msgs.push(got);
        }
        Ok(msgs)
    }
}

impl WorkerTransport for InProcWorker {
    fn worker_id(&self) -> usize {
        self.id
    }

    fn send(&mut self, msg: Message) -> std::io::Result<()> {
        self.stats.record_uplink(chunked::payload_len(&msg));
        self.uplink.send(msg).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::BrokenPipe, format!("send: {e}"))
        })
    }

    fn recv(&mut self) -> std::io::Result<SharedMessage> {
        self.downlink.recv().map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::BrokenPipe, format!("recv: {e}"))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fabric_moves_messages_and_counts_bytes() {
        let stats = CommStats::new();
        let (mut server, workers) = inproc_fabric(3, stats.clone());
        let handles: Vec<_> = workers
            .into_iter()
            .map(|mut w| {
                thread::spawn(move || {
                    w.send(vec![w.worker_id() as u8; 10]).unwrap();
                    let d = w.recv().unwrap();
                    assert_eq!(&d[..], [9u8; 4]);
                })
            })
            .collect();
        let msgs = server.gather().unwrap();
        assert_eq!(msgs.len(), 3);
        for (i, m) in msgs.iter().enumerate() {
            assert_eq!(m, &vec![i as u8; 10]);
        }
        server.broadcast(&[9u8; 4]).unwrap();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(stats.uplink(), 30);
        assert_eq!(stats.downlink(), 12);
        assert_eq!(stats.uplink_msgs.load(std::sync::atomic::Ordering::Relaxed), 3);
    }

    #[test]
    fn stats_reset() {
        let stats = CommStats::new();
        stats.record_uplink(100);
        stats.record_downlink(50);
        assert_eq!(stats.total(), 150);
        stats.record_agg_uplink(30, 2);
        stats.record_agg_downlink(20, 2);
        assert_eq!(stats.agg_uplink(), 30);
        assert_eq!(stats.agg_downlink(), 20);
        assert_eq!(stats.agg_uplink_msg_count(), 2);
        assert_eq!(stats.agg_downlink_msg_count(), 2);
        assert_eq!(stats.total(), 200, "total covers every hop");
        stats.record_replay(16);
        assert_eq!(stats.replay(), 16);
        assert_eq!(stats.replay_msg_count(), 1);
        assert_eq!(stats.total(), 200, "replay traffic stays out of round accounting");
        stats.reset();
        assert_eq!(stats.total(), 0);
        assert_eq!(stats.agg_uplink_msg_count(), 0);
        assert_eq!(stats.replay(), 0);
        assert_eq!(stats.replay_msg_count(), 0);
    }

    #[test]
    fn broadcast_shares_one_allocation_across_workers() {
        // Satellite contract: the downlink broadcast must not clone the
        // frame per worker — every receiver sees the very same buffer.
        let stats = CommStats::new();
        let (mut server, mut workers) = inproc_fabric(3, stats.clone());
        server.broadcast(&[42u8; 8]).unwrap();
        let received: Vec<_> = workers.iter_mut().map(|w| w.recv().unwrap()).collect();
        for r in &received {
            assert_eq!(&r[..], [42u8; 8]);
            assert!(Arc::ptr_eq(r, &received[0]), "broadcast must share one Arc");
        }
        assert_eq!(stats.downlink(), 24, "accounting still counts per-worker bytes");
    }

    #[test]
    fn transport_counts_payload_bytes_for_chunked_messages() {
        // Two sign chunk frames: physical envelope = 3 + 2·(4 + 2) = 15
        // bytes, logical payload = 1 tag + 2 payload bytes = 3.
        let stats = CommStats::new();
        let (mut server, mut workers) = inproc_fabric(1, stats.clone());
        let msg = crate::comm::chunked::pack(&[vec![1u8, 0xAA], vec![1u8, 0xBB]]);
        workers[0].send(msg.clone()).unwrap();
        let got = server.gather().unwrap();
        assert_eq!(got[0], msg, "the physical message moves verbatim");
        assert_eq!(stats.uplink(), 3, "counters see the monolithic-equivalent bytes");
        server.broadcast(&msg).unwrap();
        assert_eq!(stats.downlink(), 3);
    }
}
