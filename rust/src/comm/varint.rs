//! LEB128 varints + delta coding — compact sparse-index encoding.
//!
//! DGC's reference implementation ships 32-bit indices; for top-k
//! selections the *gaps* between sorted indices are geometrically
//! distributed with mean 1/keep_frac (≈25 for the paper's 4%), so
//! delta + LEB128 stores most gaps in one byte: ~8–16 bits/index
//! instead of 32. Used by [`crate::comm::sparse`]'s compact format.

/// Append `v` as LEB128.
pub fn write_u32(v: u32, out: &mut Vec<u8>) {
    let mut v = v;
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Read one LEB128 u32; returns (value, bytes consumed).
pub fn read_u32(data: &[u8]) -> Option<(u32, usize)> {
    let mut v: u32 = 0;
    for (i, &byte) in data.iter().enumerate().take(5) {
        v |= ((byte & 0x7F) as u32) << (7 * i);
        if byte & 0x80 == 0 {
            return Some((v, i + 1));
        }
    }
    None
}

/// Encode sorted indices as delta varints.
pub fn pack_sorted_indices(indices: &[u32], out: &mut Vec<u8>) {
    let mut prev = 0u32;
    for (i, &idx) in indices.iter().enumerate() {
        debug_assert!(i == 0 || idx > prev, "indices must be strictly increasing");
        let gap = if i == 0 { idx } else { idx - prev - 1 };
        write_u32(gap, out);
        prev = idx;
    }
}

/// Decode `k` delta-varint indices; returns bytes consumed.
pub fn unpack_sorted_indices(data: &[u8], k: usize, out: &mut Vec<u32>) -> Option<usize> {
    let mut pos = 0usize;
    let mut prev = 0u32;
    for i in 0..k {
        let (gap, used) = read_u32(&data[pos..])?;
        pos += used;
        let idx = if i == 0 { gap } else { prev + 1 + gap };
        out.push(idx);
        prev = idx;
    }
    Some(pos)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::Rng;

    #[test]
    fn varint_roundtrip_all_widths() {
        for v in [0u32, 1, 127, 128, 16383, 16384, u32::MAX / 2, u32::MAX] {
            let mut buf = Vec::new();
            write_u32(v, &mut buf);
            let (back, used) = read_u32(&buf).unwrap();
            assert_eq!(back, v);
            assert_eq!(used, buf.len());
        }
    }

    #[test]
    fn varint_rejects_truncation() {
        let mut buf = Vec::new();
        write_u32(1_000_000, &mut buf);
        assert!(read_u32(&buf[..buf.len() - 1]).is_none());
    }

    #[test]
    fn indices_roundtrip() {
        testing::forall(
            0xB01,
            100,
            |r| {
                let d = 1 + r.below(100_000);
                let k = 1 + r.below(d.min(500));
                r.sample_indices(d, k).into_iter().map(|i| i as u32).collect::<Vec<u32>>()
            },
            |idx| {
                let mut buf = Vec::new();
                pack_sorted_indices(idx, &mut buf);
                let mut back = Vec::new();
                let used = unpack_sorted_indices(&buf, idx.len(), &mut back).unwrap();
                used == buf.len() && back == *idx
            },
        );
    }

    #[test]
    fn dense_gaps_cost_about_one_byte_each() {
        // 4% keep over 100k coords: mean gap 25 -> 1 byte per index.
        let mut rng = Rng::new(0xB02);
        let idx: Vec<u32> =
            rng.sample_indices(100_000, 4_000).into_iter().map(|i| i as u32).collect();
        let mut buf = Vec::new();
        pack_sorted_indices(&idx, &mut buf);
        let bits_per_index = buf.len() as f64 * 8.0 / idx.len() as f64;
        assert!(bits_per_index < 12.0, "bits/index = {bits_per_index}");
    }
}
