//! Experiment configuration: TOML-subset files (see `configs/*.toml`)
//! mapped onto typed structs, with CLI `key=value` overrides.

pub mod toml;

use crate::cluster::TrainConfig;
use crate::error::{DlionError, Result};
use crate::optim::dist::StrategyHyper;
use std::path::Path;

/// A full experiment: which task, which strategies, how many workers,
/// training hyper-parameters, seeds.
#[derive(Clone, Debug)]
pub struct Experiment {
    pub name: String,
    pub task: String,
    pub strategies: Vec<String>,
    pub workers: Vec<usize>,
    pub seeds: Vec<usize>,
    pub train: TrainConfig,
    pub hyper: StrategyHyper,
    /// task-specific knobs
    pub task_dim: usize,
    pub task_hidden: usize,
    pub task_train_n: usize,
    pub task_test_n: usize,
    pub task_noise: f64,
    pub out_dir: String,
}

impl Default for Experiment {
    fn default() -> Self {
        Experiment {
            name: "default".into(),
            task: "mlp-vision".into(),
            strategies: vec!["d-lion-mavo".into()],
            workers: vec![4],
            seeds: vec![42, 52, 62], // the paper's three seeds
            train: TrainConfig::default(),
            hyper: StrategyHyper::default(),
            task_dim: 64,
            task_hidden: 32,
            task_train_n: 4096,
            task_test_n: 1024,
            task_noise: 0.3,
            out_dir: "results".into(),
        }
    }
}

impl Experiment {
    /// Load from a TOML-subset file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(&path)?;
        Self::parse(&text)
    }

    /// Parse from a string.
    pub fn parse(text: &str) -> Result<Self> {
        let doc = toml::parse(text).map_err(|e| DlionError::Config(e.to_string()))?;
        let mut exp = Experiment::default();
        let top = toml::section(&doc, "");
        exp.name = top.str_or("name", &exp.name);
        exp.task = top.str_or("task", &exp.task);
        exp.out_dir = top.str_or("out_dir", &exp.out_dir);
        exp.strategies = top.str_list_or(
            "strategies",
            &exp.strategies.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        exp.workers = top.usize_list_or("workers", &exp.workers);
        exp.seeds = top.usize_list_or("seeds", &exp.seeds);
        let topo = top.str_or("topology", &exp.train.topology.to_string());
        exp.train.topology = crate::cluster::topology::Topology::parse(&topo)?;

        let t = toml::section(&doc, "train");
        // `topology` is accepted both at top level and under [train]
        // (it is a TrainConfig field); the [train] spelling wins.
        let topo = t.str_or("topology", &exp.train.topology.to_string());
        exp.train.topology = crate::cluster::topology::Topology::parse(&topo)?;
        exp.train.steps = t.usize_or("steps", exp.train.steps);
        exp.train.batch_per_worker = t.usize_or("batch_per_worker", exp.train.batch_per_worker);
        exp.train.base_lr = t.f64_or("lr", exp.train.base_lr);
        exp.train.warmup_steps = t.usize_or("warmup_steps", exp.train.warmup_steps);
        exp.train.min_lr_frac = t.f64_or("min_lr_frac", exp.train.min_lr_frac);
        exp.train.eval_every = t.usize_or("eval_every", exp.train.eval_every);
        exp.train.check_replicas = t.bool_or("check_replicas", exp.train.check_replicas);
        exp.train.chunk_size = t.usize_or("chunk_size", exp.train.chunk_size);

        let h = toml::section(&doc, "hyper");
        // `chunk_size` is a wire-format knob shared by the strategy and
        // cluster layers; it is accepted under [hyper] (the canonical
        // spelling) and [train], with the [hyper] value winning. The
        // elastic-round knobs follow the same convention.
        exp.train.chunk_size = h.usize_or("chunk_size", exp.train.chunk_size);
        exp.train.quorum = h.usize_or("quorum", exp.train.quorum);
        exp.train.round_deadline_ms =
            h.usize_or("round_deadline_ms", exp.train.round_deadline_ms as usize) as u64;
        exp.train.replay_ring = h.usize_or("replay_ring", exp.train.replay_ring);
        exp.hyper.beta1 = h.f64_or("beta1", exp.hyper.beta1 as f64) as f32;
        exp.hyper.beta2 = h.f64_or("beta2", exp.hyper.beta2 as f64) as f32;
        exp.hyper.weight_decay = h.f64_or("weight_decay", exp.hyper.weight_decay as f64) as f32;
        exp.hyper.signum_beta = h.f64_or("signum_beta", exp.hyper.signum_beta as f64) as f32;
        exp.hyper.sgd_momentum = h.f64_or("sgd_momentum", exp.hyper.sgd_momentum as f64) as f32;
        exp.hyper.keep_frac = h.f64_or("keep_frac", exp.hyper.keep_frac as f64) as f32;
        exp.hyper.dgc_clip_norm = h.f64_or("dgc_clip_norm", exp.hyper.dgc_clip_norm as f64) as f32;
        exp.hyper.dgc_warmup_steps = h.usize_or("dgc_warmup_steps", exp.hyper.dgc_warmup_steps);
        exp.hyper.msync_every = h.usize_or("msync_every", exp.hyper.msync_every);
        exp.hyper.compact_sparse = h.bool_or("compact_sparse", exp.hyper.compact_sparse);
        exp.hyper.link_budget = h.f64_or("link_budget", exp.hyper.link_budget as f64) as f32;
        exp.hyper.local_steps = h.usize_or("local_steps", exp.hyper.local_steps);

        let tk = toml::section(&doc, "task");
        exp.task_dim = tk.usize_or("dim", exp.task_dim);
        exp.task_hidden = tk.usize_or("hidden", exp.task_hidden);
        exp.task_train_n = tk.usize_or("train_n", exp.task_train_n);
        exp.task_test_n = tk.usize_or("test_n", exp.task_test_n);
        exp.task_noise = tk.f64_or("noise", exp.task_noise);
        Ok(exp)
    }

    /// Apply `key=value` CLI overrides (dotted paths: `train.steps=100`).
    pub fn apply_override(&mut self, kv: &str) -> Result<()> {
        let (key, val) = kv
            .split_once('=')
            .ok_or_else(|| DlionError::Config(format!("override '{kv}' is not key=value")))?;
        let bad = |k: &str| DlionError::Config(format!("unknown override key '{k}'"));
        let parse_usize =
            |v: &str| v.parse::<usize>().map_err(|e| DlionError::Config(e.to_string()));
        let parse_f64 =
            |v: &str| v.parse::<f64>().map_err(|e| DlionError::Config(e.to_string()));
        match key {
            "name" => self.name = val.into(),
            "task" => self.task = val.into(),
            "out_dir" => self.out_dir = val.into(),
            "strategies" => self.strategies = val.split(',').map(String::from).collect(),
            "workers" => {
                self.workers = val
                    .split(',')
                    .map(|s| s.parse::<usize>())
                    .collect::<std::result::Result<_, _>>()
                    .map_err(|e| DlionError::Config(e.to_string()))?
            }
            "seeds" => {
                self.seeds = val
                    .split(',')
                    .map(|s| s.parse::<usize>())
                    .collect::<std::result::Result<_, _>>()
                    .map_err(|e| DlionError::Config(e.to_string()))?
            }
            "topology" | "train.topology" => {
                self.train.topology = crate::cluster::topology::Topology::parse(val)?
            }
            "hyper.chunk_size" | "train.chunk_size" => {
                self.train.chunk_size = parse_usize(val)?
            }
            "hyper.quorum" | "train.quorum" => self.train.quorum = parse_usize(val)?,
            "hyper.round_deadline_ms" | "train.round_deadline_ms" => {
                self.train.round_deadline_ms = parse_usize(val)? as u64
            }
            "hyper.replay_ring" | "train.replay_ring" => {
                self.train.replay_ring = parse_usize(val)?
            }
            "train.steps" => self.train.steps = parse_usize(val)?,
            "train.batch_per_worker" => self.train.batch_per_worker = parse_usize(val)?,
            "train.lr" => self.train.base_lr = parse_f64(val)?,
            "train.warmup_steps" => self.train.warmup_steps = parse_usize(val)?,
            "train.eval_every" => self.train.eval_every = parse_usize(val)?,
            "hyper.beta1" => self.hyper.beta1 = parse_f64(val)? as f32,
            "hyper.beta2" => self.hyper.beta2 = parse_f64(val)? as f32,
            "hyper.weight_decay" => self.hyper.weight_decay = parse_f64(val)? as f32,
            "hyper.keep_frac" => self.hyper.keep_frac = parse_f64(val)? as f32,
            "hyper.msync_every" => self.hyper.msync_every = parse_usize(val)?,
            "hyper.compact_sparse" => {
                self.hyper.compact_sparse = match val {
                    "true" | "1" => true,
                    "false" | "0" => false,
                    other => {
                        return Err(DlionError::Config(format!(
                            "hyper.compact_sparse expects true/false, got '{other}'"
                        )))
                    }
                }
            }
            "hyper.link_budget" => self.hyper.link_budget = parse_f64(val)? as f32,
            "hyper.local_steps" => self.hyper.local_steps = parse_usize(val)?,
            "task.dim" => self.task_dim = parse_usize(val)?,
            "task.hidden" => self.task_hidden = parse_usize(val)?,
            "task.train_n" => self.task_train_n = parse_usize(val)?,
            "task.test_n" => self.task_test_n = parse_usize(val)?,
            _ => return Err(bad(key)),
        }
        Ok(())
    }

    /// Instantiate the task named by `self.task`.
    pub fn build_task(&self, seed: u64) -> Result<Box<dyn crate::tasks::GradTask + Send + Sync>> {
        use crate::tasks::{data::VisionData, linreg::LinReg, mlp::MlpVision, quadratic::Quadratic};
        use std::sync::Arc;
        Ok(match self.task.as_str() {
            "quadratic" => Box::new(Quadratic::new(
                self.task_dim,
                10.0,
                self.task_noise as f32,
                seed,
            )),
            "linreg" => Box::new(LinReg::new(
                self.task_dim,
                self.task_train_n,
                self.task_noise as f32,
                seed,
            )),
            "mlp-vision" => {
                let data = Arc::new(VisionData::generate(
                    self.task_train_n,
                    self.task_test_n,
                    self.task_noise as f32,
                    seed,
                ));
                Box::new(MlpVision::new(data, self.task_hidden))
            }
            // "lm" (default tiny) or "lm:<model>" from the native model
            // registry — trains through the in-memory native backend,
            // no artifacts directory needed.
            name if name == "lm" || name.starts_with("lm:") => {
                let model = name.strip_prefix("lm:").unwrap_or("tiny");
                Box::new(crate::lm::LmTask::native(
                    model,
                    120_000,
                    crate::lm::corpus::Grammar::default(),
                    seed,
                )?)
            }
            other => return Err(DlionError::Config(format!("unknown task '{other}'"))),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_override() {
        let mut exp = Experiment::parse(
            r#"
name = "t"
task = "quadratic"
strategies = ["d-lion-mavo", "terngrad"]
workers = [4, 8]
topology = "hier:4"

[train]
steps = 50
lr = 0.02

[hyper]
weight_decay = 0.01
msync_every = 8
compact_sparse = true
link_budget = 6.0
local_steps = 8
chunk_size = 4096
quorum = 3
round_deadline_ms = 250
replay_ring = 16

[task]
dim = 128
"#,
        )
        .unwrap();
        assert_eq!(exp.name, "t");
        assert_eq!(exp.strategies.len(), 2);
        assert_eq!(exp.workers, vec![4, 8]);
        assert_eq!(exp.train.steps, 50);
        assert_eq!(
            exp.train.topology,
            crate::cluster::topology::Topology::Hierarchical { group_size: 4 }
        );
        assert!((exp.hyper.weight_decay - 0.01).abs() < 1e-7);
        assert_eq!(exp.hyper.msync_every, 8);
        assert!(exp.hyper.compact_sparse);
        assert!((exp.hyper.link_budget - 6.0).abs() < 1e-7);
        assert_eq!(exp.hyper.local_steps, 8);
        assert_eq!(exp.train.chunk_size, 4096);
        assert_eq!(exp.task_dim, 128);
        assert_eq!(exp.train.quorum, 3);
        assert_eq!(exp.train.round_deadline_ms, 250);
        let policy = exp.train.quorum_policy();
        assert_eq!(policy.min_workers, 3);
        assert_eq!(policy.deadline_ms, 250);
        exp.apply_override("hyper.chunk_size=128").unwrap();
        assert_eq!(exp.train.chunk_size, 128);
        exp.apply_override("hyper.quorum=5").unwrap();
        assert_eq!(exp.train.quorum, 5);
        exp.apply_override("hyper.round_deadline_ms=1000").unwrap();
        assert_eq!(exp.train.round_deadline_ms, 1000);
        assert_eq!(exp.train.replay_ring, 16, "hyper.replay_ring from the file");
        exp.apply_override("hyper.replay_ring=4").unwrap();
        assert_eq!(exp.train.replay_ring, 4);
        assert!(exp.apply_override("hyper.replay_ring=x").is_err());
        assert!(exp.apply_override("hyper.quorum=x").is_err());
        exp.apply_override("train.chunk_size=0").unwrap();
        assert_eq!(exp.train.chunk_size, 0);
        assert!(exp.apply_override("hyper.chunk_size=x").is_err());
        exp.apply_override("train.steps=99").unwrap();
        assert_eq!(exp.train.steps, 99);
        exp.apply_override("workers=2,4").unwrap();
        assert_eq!(exp.workers, vec![2, 4]);
        exp.apply_override("hyper.msync_every=16").unwrap();
        assert_eq!(exp.hyper.msync_every, 16);
        exp.apply_override("hyper.compact_sparse=true").unwrap();
        assert!(exp.hyper.compact_sparse);
        assert!(exp.apply_override("hyper.compact_sparse=maybe").is_err());
        exp.apply_override("hyper.link_budget=8.5").unwrap();
        assert!((exp.hyper.link_budget - 8.5).abs() < 1e-6);
        exp.apply_override("hyper.local_steps=2").unwrap();
        assert_eq!(exp.hyper.local_steps, 2);
        exp.apply_override("topology=star").unwrap();
        assert_eq!(exp.train.topology, crate::cluster::topology::Topology::Star);
        exp.apply_override("train.topology=hier:2").unwrap();
        assert_eq!(
            exp.train.topology,
            crate::cluster::topology::Topology::Hierarchical { group_size: 2 }
        );
        assert!(exp.apply_override("topology=ring").is_err());
        assert!(exp.apply_override("topology=hier:0").is_err());
        assert!(exp.apply_override("garbage").is_err());
        assert!(exp.apply_override("no.such.key=1").is_err());
    }

    #[test]
    fn bad_topology_in_file_is_a_parse_error() {
        let err = Experiment::parse("topology = \"mesh\"\n").err().expect("must fail");
        assert!(err.to_string().contains("unknown topology"));
        let err = Experiment::parse("[train]\ntopology = \"mesh\"\n").err().expect("must fail");
        assert!(err.to_string().contains("unknown topology"));
    }

    #[test]
    fn topology_under_train_section_is_honored() {
        let exp = Experiment::parse("[train]\ntopology = \"hier:3\"\n").unwrap();
        assert_eq!(
            exp.train.topology,
            crate::cluster::topology::Topology::Hierarchical { group_size: 3 }
        );
    }

    #[test]
    fn shipped_configs_parse_and_strategies_resolve() {
        // keep configs/*.toml honest: every listed strategy must resolve
        // (including the composite bandwidth-aware name, which exercises
        // the quote-aware TOML array splitting)
        for path in [
            "../configs/fig2.toml",
            "../configs/lioncub.toml",
            "../configs/topology.toml",
            "../configs/mixed.toml",
        ] {
            let exp = Experiment::load(path).unwrap_or_else(|e| panic!("{path}: {e}"));
            assert!(!exp.strategies.is_empty(), "{path}: empty strategies");
            for s in &exp.strategies {
                assert!(
                    crate::optim::dist::by_name(s, &exp.hyper).is_ok(),
                    "{path}: strategy '{s}' does not resolve"
                );
            }
        }
    }

    #[test]
    fn builds_all_tasks() {
        let mut exp = Experiment::default();
        exp.task_train_n = 64;
        exp.task_test_n = 16;
        for t in ["quadratic", "linreg", "mlp-vision"] {
            exp.task = t.into();
            let task = exp.build_task(1).unwrap();
            assert!(task.dim() > 0);
        }
        exp.task = "bogus".into();
        assert!(exp.build_task(1).is_err());
    }

    #[test]
    fn builds_lm_task_natively() {
        let mut exp = Experiment::default();
        exp.task = "lm".into();
        let task = exp.build_task(1).unwrap();
        assert_eq!(task.dim(), 143_680); // tiny
        assert!(exp.build_task(1).is_ok(), "rebuild is deterministic");
        exp.task = "lm:nonexistent-model".into();
        assert!(exp.build_task(1).is_err());
    }
}
