//! TOML-subset parser (no `toml`/`serde` crates offline — see DESIGN.md).
//!
//! Supported: `[section]` tables, `key = value` with string, integer,
//! float, boolean, and flat arrays of those; `#` comments; blank lines.
//! Nested tables/dotted keys are out of scope (our configs don't need
//! them).

use std::collections::BTreeMap;
use std::fmt;

/// A TOML-subset value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// Parsed document: section → key → value. Top-level keys live in "".
pub type Doc = BTreeMap<String, BTreeMap<String, Value>>;

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.message)
    }
}
impl std::error::Error for TomlError {}

fn parse_scalar(s: &str, line: usize) -> Result<Value, TomlError> {
    let s = s.trim();
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(TomlError { line, message: format!("cannot parse value '{s}'") })
}

/// Split an array body on commas that are not inside a quoted string
/// (strategy names like `"bandwidth-aware(d-lion-mavo,g-lion)"` carry
/// commas of their own).
fn split_array_items(inner: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in inner.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&inner[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&inner[start..]);
    parts
}

fn parse_value(s: &str, line: usize) -> Result<Value, TomlError> {
    let s = s.trim();
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err(TomlError { line, message: "unterminated array".into() });
        }
        let inner = &s[1..s.len() - 1];
        if inner.trim().is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        // split at top level (no nested arrays supported)
        let items: Result<Vec<Value>, TomlError> =
            split_array_items(inner).into_iter().map(|p| parse_scalar(p, line)).collect();
        return Ok(Value::Arr(items?));
    }
    parse_scalar(s, line)
}

/// Strip a trailing comment that is not inside a string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse a document.
pub fn parse(input: &str) -> Result<Doc, TomlError> {
    let mut doc: Doc = BTreeMap::new();
    doc.insert(String::new(), BTreeMap::new());
    let mut section = String::new();
    for (ln, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(TomlError { line: ln + 1, message: "bad section header".into() });
            }
            section = line[1..line.len() - 1].trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let eq = line.find('=').ok_or(TomlError {
            line: ln + 1,
            message: "expected 'key = value'".into(),
        })?;
        let key = line[..eq].trim().to_string();
        if key.is_empty() {
            return Err(TomlError { line: ln + 1, message: "empty key".into() });
        }
        let val = parse_value(&line[eq + 1..], ln + 1)?;
        doc.entry(section.clone()).or_default().insert(key, val);
    }
    Ok(doc)
}

/// Typed accessors with good error messages.
pub struct Section<'a> {
    pub name: &'a str,
    pub map: &'a BTreeMap<String, Value>,
}

impl<'a> Section<'a> {
    pub fn get(&self, key: &str) -> Option<&'a Value> {
        self.map.get(key)
    }
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_i64()).map(|i| i as usize).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Vec<usize> {
        self.get(key)
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|v| v.as_i64()).map(|i| i as usize).collect())
            .unwrap_or_else(|| default.to_vec())
    }
    pub fn str_list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        self.get(key)
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|v| v.as_str()).map(String::from).collect())
            .unwrap_or_else(|| default.iter().map(|s| s.to_string()).collect())
    }
}

/// Get a section view (empty map if absent).
pub fn section<'a>(doc: &'a Doc, name: &'a str) -> Section<'a> {
    // no `once_cell` offline: std's OnceLock provides the lazy empty map
    static EMPTY: std::sync::OnceLock<BTreeMap<String, Value>> = std::sync::OnceLock::new();
    Section { name, map: doc.get(name).unwrap_or_else(|| EMPTY.get_or_init(BTreeMap::new)) }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "fig2"        # inline comment
seed = 42

[train]
steps = 1000
lr = 0.0005
workers = [4, 8, 16, 32]
strategies = ["d-lion-mavo", "g-lion"]
check = true
"#;

    #[test]
    fn parses_sample() {
        let doc = parse(SAMPLE).unwrap();
        let top = section(&doc, "");
        assert_eq!(top.str_or("name", "?"), "fig2");
        assert_eq!(top.usize_or("seed", 0), 42);
        let train = section(&doc, "train");
        assert_eq!(train.usize_or("steps", 0), 1000);
        assert!((train.f64_or("lr", 0.0) - 0.0005).abs() < 1e-12);
        assert_eq!(train.usize_list_or("workers", &[]), vec![4, 8, 16, 32]);
        assert_eq!(
            train.str_list_or("strategies", &[]),
            vec!["d-lion-mavo".to_string(), "g-lion".to_string()]
        );
        assert!(train.bool_or("check", false));
    }

    #[test]
    fn defaults_for_missing() {
        let doc = parse("").unwrap();
        let s = section(&doc, "nope");
        assert_eq!(s.usize_or("x", 7), 7);
        assert_eq!(s.str_or("y", "dflt"), "dflt");
    }

    #[test]
    fn errors_have_line_numbers() {
        let err = parse("a = 1\nbad line\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err = parse("[unterminated\n").unwrap_err();
        assert_eq!(err.line, 1);
        let err = parse("k = [1, 2\n").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = parse("k = \"a#b\"").unwrap();
        assert_eq!(section(&doc, "").str_or("k", ""), "a#b");
    }

    #[test]
    fn comma_inside_string_does_not_split_array_items() {
        // composite strategy names carry commas of their own
        let doc =
            parse("s = [\"bandwidth-aware(d-lion-mavo,g-lion)\", \"d-lion-ef\"]").unwrap();
        assert_eq!(
            section(&doc, "").str_list_or("s", &[]),
            vec!["bandwidth-aware(d-lion-mavo,g-lion)".to_string(), "d-lion-ef".to_string()]
        );
    }
}
