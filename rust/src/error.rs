//! Crate-wide error type (hand-rolled: the offline vendored crate set
//! has no `thiserror` — see DESIGN.md "Environment-forced substitutions").

use std::fmt;

/// Errors surfaced by the Distributed Lion library.
#[derive(Debug)]
pub enum DlionError {
    Config(String),
    Codec(String),
    Cluster(String),
    Runtime(String),
    Artifact(String),
    Io(std::io::Error),
    Json(crate::util::json::JsonError),
    Xla(String),
}

impl fmt::Display for DlionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DlionError::Config(m) => write!(f, "config error: {m}"),
            DlionError::Codec(m) => write!(f, "codec error: {m}"),
            DlionError::Cluster(m) => write!(f, "cluster error: {m}"),
            DlionError::Runtime(m) => write!(f, "runtime error: {m}"),
            DlionError::Artifact(m) => write!(f, "artifact error: {m}"),
            DlionError::Io(e) => write!(f, "io error: {e}"),
            DlionError::Json(e) => write!(f, "json error: {e}"),
            DlionError::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for DlionError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DlionError::Io(e) => Some(e),
            DlionError::Json(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for DlionError {
    fn from(e: std::io::Error) -> Self {
        DlionError::Io(e)
    }
}

impl From<crate::util::json::JsonError> for DlionError {
    fn from(e: crate::util::json::JsonError) -> Self {
        DlionError::Json(e)
    }
}

impl From<xla::Error> for DlionError {
    fn from(e: xla::Error) -> Self {
        DlionError::Xla(format!("{e:?}"))
    }
}

pub type Result<T> = std::result::Result<T, DlionError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_previous_thiserror_format() {
        assert_eq!(
            DlionError::Config("bad key".into()).to_string(),
            "config error: bad key"
        );
        let io: DlionError =
            std::io::Error::new(std::io::ErrorKind::NotFound, "nope").into();
        assert!(io.to_string().starts_with("io error: "));
    }

    #[test]
    fn source_chains_io() {
        use std::error::Error;
        let e: DlionError =
            std::io::Error::new(std::io::ErrorKind::Other, "inner").into();
        assert!(e.source().is_some());
        assert!(DlionError::Codec("x".into()).source().is_none());
    }
}
