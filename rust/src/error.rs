//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by the Distributed Lion library.
#[derive(Error, Debug)]
pub enum DlionError {
    #[error("config error: {0}")]
    Config(String),

    #[error("codec error: {0}")]
    Codec(String),

    #[error("cluster error: {0}")]
    Cluster(String),

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("json error: {0}")]
    Json(#[from] crate::util::json::JsonError),

    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for DlionError {
    fn from(e: xla::Error) -> Self {
        DlionError::Xla(format!("{e:?}"))
    }
}

pub type Result<T> = std::result::Result<T, DlionError>;
