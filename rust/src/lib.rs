//! # Distributed Lion
//!
//! A production-style reproduction of *Communication Efficient
//! Distributed Training with Distributed Lion* (NeurIPS 2024) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the distributed-training coordinator: comm
//!   codecs with exact Table-1 bandwidths, every optimizer/strategy from
//!   the paper's evaluation, a threaded leader/worker cluster with byte
//!   accounting, and theory diagnostics for Section 4.
//! * **L2/L1 (`python/compile`)** — the GPT2++-style transformer
//!   (fwd/bwd) and the fused Pallas `lion_step` / `majority_vote`
//!   kernels, AOT-lowered to HLO text at build time.
//! * **runtime** — loads the AOT artifacts through PJRT and serves them
//!   to the coordinator's hot path; python never runs at train time.
//!
//! Quickstart: see `examples/quickstart.rs`, or
//! `cargo run --release --example cifar_sim`.
//!
//! ## Strategy registry
//!
//! [`optim::dist::by_name`] resolves every row of the paper's evaluation
//! matrix plus the extension strategies; channels name the codec each
//! direction rides on ([`comm`]) and the resulting Table-1 bits/param.
//! Prose documentation of every entry (wire format, frame layout,
//! formulas, which paper table/figure it reproduces) lives in
//! `docs/STRATEGIES.md`.
//!
//! | name            | paper §        | uplink (codec, bits)     | downlink (codec, bits)        |
//! |-----------------|----------------|--------------------------|-------------------------------|
//! | `d-lion-mavo`   | Alg. 1, §5.1   | `sign`, 1                | `sign` 1 (odd N) / `tern` 1.6 |
//! | `d-lion-avg`    | Alg. 1, §5.1   | `sign`, 1                | `intavg`, ⌈log2(N+1)⌉         |
//! | `d-signum-mavo` | §5.1 (Fig. 4)  | `sign`, 1                | as d-lion-mavo                |
//! | `d-signum-avg`  | §5.1 (Fig. 4)  | `sign`, 1                | as d-lion-avg                 |
//! | `g-lion`        | §5.1 baseline  | `dense`, 32              | `dense`, 32                   |
//! | `g-adamw`       | §5.1 baseline  | `dense`, 32              | `dense`, 32                   |
//! | `g-sgd`         | §5.1 baseline  | `dense`, 32              | `dense`, 32                   |
//! | `terngrad`      | §5.1 baseline  | `tern`+scale, 1.6        | `intavg` range, ⌈log2(2N+1)⌉  |
//! | `graddrop`      | §5.1 baseline  | `sparse`, 64·keep¹       | `dense`, 32                   |
//! | `dgc`           | §5.1 baseline  | `sparse`, 64·keep¹ (warmup) | `dense`, 32                |
//! | `qsgd`          | extension      | 8-bit quant + scale      | `dense`, 32                   |
//! | `ef-signsgd`    | extension      | `sign`+scale, 1          | `dense`, 32                   |
//! | `d-lion-ef`     | ext. (Lion Cub) | `sign`, 1               | as d-lion-mavo                |
//! | `d-lion-msync`  | ext. (Lion Cub) | `sign`+bf16, 1 + 16/k   | as d-lion-mavo + 16/k         |
//! | `d-lion-local(H)` | ext. (local steps) | `sign`, 1/H        | as d-lion-mavo ÷ H            |
//! | `bandwidth-aware(a,b)` | ext. (Lion Cub) | wrapped frames    | budget-weighted mix           |
//! | `mixed(a*w,b,…)` | ext. (mixed wires) | arms' frames per chunk | chunk-share weighted mix  |
//! | `mixed(a@cheap,b@rich)` | ext. (mixed wires) | arm per round/link | per-hop budget mix     |
//!
//! ¹ with `StrategyHyper::compact_sparse`, the sparse uplinks switch to
//! delta-varint indices at ≈40·keep bits/param.
//!
//! Rounds route through a configurable [`cluster::topology::Topology`]
//! (flat star or a two-level worker → group-aggregator → root tree with
//! exact partial aggregation) at the strategy's communication cadence —
//! see `docs/STRATEGIES.md` § "Topologies".

pub mod bench_utils;
pub mod cli;
pub mod cluster;
pub mod comm;
pub mod config;
pub mod error;
pub mod lm;
pub mod optim;
pub mod runtime;
pub mod tasks;
pub mod testing;
pub mod theory;
pub mod util;

pub use error::{DlionError, Result};
