//! # Distributed Lion
//!
//! A production-style reproduction of *Communication Efficient
//! Distributed Training with Distributed Lion* (NeurIPS 2024) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the distributed-training coordinator: comm
//!   codecs with exact Table-1 bandwidths, every optimizer/strategy from
//!   the paper's evaluation, a threaded leader/worker cluster with byte
//!   accounting, and theory diagnostics for Section 4.
//! * **L2/L1 (`python/compile`)** — the GPT2++-style transformer
//!   (fwd/bwd) and the fused Pallas `lion_step` / `majority_vote`
//!   kernels, AOT-lowered to HLO text at build time.
//! * **runtime** — loads the AOT artifacts through PJRT and serves them
//!   to the coordinator's hot path; python never runs at train time.
//!
//! Quickstart: see `examples/quickstart.rs`, or
//! `cargo run --release --example cifar_sim`.

pub mod bench_utils;
pub mod cli;
pub mod cluster;
pub mod comm;
pub mod config;
pub mod error;
pub mod lm;
pub mod optim;
pub mod runtime;
pub mod tasks;
pub mod testing;
pub mod theory;
pub mod util;

pub use error::{DlionError, Result};
