//! Checkpointing: save/restore flat parameters (+ optimizer momenta)
//! with integrity checks against the artifact manifest, so long LM runs
//! can resume and the finetuning benches can branch from a shared
//! pretrained state.
//!
//! Format (little-endian):
//! ```text
//! magic   "DLCK"            4 B
//! version u32               4 B
//! step    u64               8 B
//! dim     u64               8 B
//! model   u32 len + bytes   (manifest model name; must match on load)
//! params  dim × f32
//! nmom    u32               number of momentum buffers (0 or N)
//! moms    nmom × dim × f32
//! crc     u32               crc32 of everything above
//! ```

use crate::error::{DlionError, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"DLCK";
const VERSION: u32 = 1;

/// A training checkpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub model: String,
    pub params: Vec<f32>,
    /// per-worker optimizer momenta (empty if not saved)
    pub momenta: Vec<Vec<f32>>,
}

/// crc32 (IEEE, bitwise — checkpoints are MB-scale, this is not hot).
fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

impl Checkpoint {
    pub fn new(step: u64, model: impl Into<String>, params: Vec<f32>) -> Self {
        Checkpoint { step, model: model.into(), params, momenta: Vec::new() }
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let dim = self.params.len();
        let mut out = Vec::with_capacity(64 + 4 * dim * (1 + self.momenta.len()));
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&(dim as u64).to_le_bytes());
        out.extend_from_slice(&(self.model.len() as u32).to_le_bytes());
        out.extend_from_slice(self.model.as_bytes());
        for &p in &self.params {
            out.extend_from_slice(&p.to_le_bytes());
        }
        out.extend_from_slice(&(self.momenta.len() as u32).to_le_bytes());
        for m in &self.momenta {
            assert_eq!(m.len(), dim, "momentum dim mismatch");
            for &v in m {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse from bytes with full validation.
    pub fn from_bytes(data: &[u8]) -> Result<Self> {
        let err = |m: &str| DlionError::Artifact(format!("checkpoint: {m}"));
        if data.len() < 32 {
            return Err(err("truncated header"));
        }
        let (body, crc_bytes) = data.split_at(data.len() - 4);
        let stored = u32::from_le_bytes(crc_bytes.try_into().unwrap());
        if crc32(body) != stored {
            return Err(err("crc mismatch (corrupt file)"));
        }
        let mut r = body;
        let mut take = |n: usize| -> Result<&[u8]> {
            if r.len() < n {
                return Err(DlionError::Artifact("checkpoint: truncated".into()));
            }
            let (head, tail) = r.split_at(n);
            r = tail;
            Ok(head)
        };
        if take(4)? != MAGIC {
            return Err(err("bad magic"));
        }
        let version = u32::from_le_bytes(take(4)?.try_into().unwrap());
        if version != VERSION {
            return Err(err(&format!("unsupported version {version}")));
        }
        let step = u64::from_le_bytes(take(8)?.try_into().unwrap());
        let dim = u64::from_le_bytes(take(8)?.try_into().unwrap()) as usize;
        let name_len = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
        let model = String::from_utf8(take(name_len)?.to_vec())
            .map_err(|_| err("bad model name"))?;
        let mut params = vec![0.0f32; dim];
        let pbytes = take(4 * dim)?;
        for (p, c) in params.iter_mut().zip(pbytes.chunks_exact(4)) {
            *p = f32::from_le_bytes(c.try_into().unwrap());
        }
        let nmom = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
        let mut momenta = Vec::with_capacity(nmom);
        for _ in 0..nmom {
            let mbytes = take(4 * dim)?;
            let mut m = vec![0.0f32; dim];
            for (v, c) in m.iter_mut().zip(mbytes.chunks_exact(4)) {
                *v = f32::from_le_bytes(c.try_into().unwrap());
            }
            momenta.push(m);
        }
        Ok(Checkpoint { step, model, params, momenta })
    }

    /// Write to a file (atomic: tmp + rename).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let tmp = path.with_extension("tmp");
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&self.to_bytes())?;
        f.sync_all()?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load from a file, checking the model name against `expect_model`
    /// (pass "" to skip) and the dimension against `expect_dim`
    /// (pass 0 to skip).
    pub fn load(path: impl AsRef<Path>, expect_model: &str, expect_dim: usize) -> Result<Self> {
        let mut data = Vec::new();
        std::fs::File::open(path.as_ref())?.read_to_end(&mut data)?;
        let ck = Self::from_bytes(&data)?;
        if !expect_model.is_empty() && ck.model != expect_model {
            return Err(DlionError::Artifact(format!(
                "checkpoint is for model '{}', expected '{expect_model}'",
                ck.model
            )));
        }
        if expect_dim != 0 && ck.params.len() != expect_dim {
            return Err(DlionError::Artifact(format!(
                "checkpoint dim {} != expected {expect_dim}",
                ck.params.len()
            )));
        }
        Ok(ck)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample() -> Checkpoint {
        let mut rng = Rng::new(1);
        let mut params = vec![0.0f32; 1000];
        rng.fill_normal(&mut params, 1.0);
        let mut m = vec![0.0f32; 1000];
        rng.fill_normal(&mut m, 0.1);
        let mut ck = Checkpoint::new(1234, "tiny", params);
        ck.momenta.push(m);
        ck
    }

    #[test]
    fn roundtrip_bytes() {
        let ck = sample();
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(ck, back);
    }

    #[test]
    fn roundtrip_file() {
        let ck = sample();
        let path = std::env::temp_dir().join(format!("dlion_ck_{}.bin", std::process::id()));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path, "tiny", 1000).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn detects_corruption() {
        let ck = sample();
        let mut bytes = ck.to_bytes();
        bytes[100] ^= 0xFF;
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn rejects_wrong_model_or_dim() {
        let ck = sample();
        let path = std::env::temp_dir().join(format!("dlion_ck2_{}.bin", std::process::id()));
        ck.save(&path).unwrap();
        assert!(Checkpoint::load(&path, "other-model", 0).is_err());
        assert!(Checkpoint::load(&path, "", 999).is_err());
        assert!(Checkpoint::load(&path, "", 0).is_ok());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_truncation() {
        let ck = sample();
        let bytes = ck.to_bytes();
        for cut in [3usize, 20, bytes.len() / 2, bytes.len() - 5] {
            assert!(Checkpoint::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    /// Rebuild the trailing crc over an edited body, so parsing gets
    /// past the integrity check and exercises the structural errors.
    fn with_fresh_crc(mut bytes: Vec<u8>) -> Vec<u8> {
        let n = bytes.len() - 4;
        let crc = crc32(&bytes[..n]);
        bytes[n..].copy_from_slice(&crc.to_le_bytes());
        bytes
    }

    /// Every corruption mode fails by *name* — the chaos rejoin path
    /// surfaces these verbatim, so a mid-run catch-up from a damaged
    /// checkpoint is a diagnosable error, not a hang or a garbage
    /// replica.
    #[test]
    fn corruption_errors_are_named() {
        let ck = sample();
        let bytes = ck.to_bytes();

        // header shorter than the fixed fields
        let err = Checkpoint::from_bytes(&bytes[..10]).unwrap_err().to_string();
        assert!(err.contains("truncated header"), "{err}");

        // one flipped body byte: the crc catches it before any parsing
        let mut corrupt = bytes.clone();
        corrupt[100] ^= 0xFF;
        let err = Checkpoint::from_bytes(&corrupt).unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "{err}");

        // wrong magic behind a valid crc
        let mut magic = bytes.clone();
        magic[..4].copy_from_slice(b"NOPE");
        let err = Checkpoint::from_bytes(&with_fresh_crc(magic)).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");

        // future version behind a valid crc
        let mut vers = bytes.clone();
        vers[4..8].copy_from_slice(&99u32.to_le_bytes());
        let err = Checkpoint::from_bytes(&with_fresh_crc(vers)).unwrap_err().to_string();
        assert!(err.contains("unsupported version 99"), "{err}");

        // interior truncation behind a valid crc: the field reader fires
        let cut = bytes.len() - 40;
        let err = Checkpoint::from_bytes(&with_fresh_crc(bytes[..cut].to_vec()))
            .unwrap_err()
            .to_string();
        assert!(err.contains("truncated"), "{err}");

        // a truncated *file* fails by name through the load path too
        let path = std::env::temp_dir().join(format!("dlion_ck3_{}.bin", std::process::id()));
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let err = Checkpoint::load(&path, "tiny", 1000).unwrap_err().to_string();
        assert!(err.contains("crc mismatch") || err.contains("truncated"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
