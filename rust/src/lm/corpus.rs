//! Synthetic byte-level corpus — the OpenWebText stand-in (DESIGN.md
//! "Environment-forced substitutions"). Sentences are drawn from a
//! stochastic template grammar over a fixed word bank, so the stream has
//! real structure at several scales (characters → words → syntax) for a
//! byte-level LM to learn, and perplexity differences between optimizers
//! are meaningful.

use crate::util::Rng;

const SUBJECTS: &[&str] = &[
    "the lion", "a worker", "the server", "the model", "a gradient", "the optimizer",
    "the scheduler", "a tensor", "the network", "the dataset",
];
const VERBS: &[&str] = &[
    "updates", "signs", "aggregates", "broadcasts", "compresses", "trains",
    "averages", "reduces", "sends", "receives",
];
const OBJECTS: &[&str] = &[
    "the momentum", "a binary vector", "the parameters", "the votes", "the batch",
    "the learning rate", "the weights", "a sparse update", "the loss", "the bandwidth",
];
const ADVERBS: &[&str] = &[
    "quickly", "efficiently", "silently", "in parallel", "every step", "without delay",
];

/// Grammar weights let us shift the distribution for the finetuning
/// experiments (Table 4 analogue): each "domain" reweights clause types.
#[derive(Clone, Copy, Debug)]
pub struct Grammar {
    /// probability a sentence carries an adverb
    pub p_adverb: f64,
    /// probability of a compound sentence ("... and ...")
    pub p_compound: f64,
    /// bias toward the first half of each word bank (domain vocabulary)
    pub vocab_skew: f64,
}

impl Default for Grammar {
    fn default() -> Self {
        Grammar { p_adverb: 0.3, p_compound: 0.2, vocab_skew: 0.0 }
    }
}

impl Grammar {
    /// The 7 downstream "domains" used by the Table-4 analogue bench.
    pub fn domain(i: usize) -> Grammar {
        let t = i as f64 / 7.0;
        Grammar {
            p_adverb: 0.1 + 0.8 * t,
            p_compound: 0.05 + 0.5 * (1.0 - t),
            vocab_skew: -0.8 + 1.6 * t,
        }
    }

    fn pick<'a>(&self, bank: &[&'a str], rng: &mut Rng) -> &'a str {
        let n = bank.len();
        let u = rng.uniform();
        // skew < 0 biases early entries, > 0 late entries
        let shaped = if self.vocab_skew >= 0.0 {
            u.powf(1.0 / (1.0 + self.vocab_skew))
        } else {
            1.0 - (1.0 - u).powf(1.0 / (1.0 - self.vocab_skew))
        };
        bank[((shaped * n as f64) as usize).min(n - 1)]
    }

    fn clause(&self, rng: &mut Rng, out: &mut String) {
        out.push_str(self.pick(SUBJECTS, rng));
        out.push(' ');
        out.push_str(self.pick(VERBS, rng));
        out.push(' ');
        out.push_str(self.pick(OBJECTS, rng));
        if rng.uniform() < self.p_adverb {
            out.push(' ');
            out.push_str(self.pick(ADVERBS, rng));
        }
    }

    /// One sentence ending in ". ".
    pub fn sentence(&self, rng: &mut Rng, out: &mut String) {
        self.clause(rng, out);
        if rng.uniform() < self.p_compound {
            out.push_str(" and ");
            self.clause(rng, out);
        }
        out.push_str(". ");
    }
}

/// A generated corpus of bytes with a train/valid split.
pub struct Corpus {
    pub train: Vec<u8>,
    pub valid: Vec<u8>,
}

impl Corpus {
    /// Generate `total_bytes` of text (deterministic in seed), 95/5 split.
    pub fn generate(total_bytes: usize, grammar: Grammar, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut text = String::with_capacity(total_bytes + 128);
        while text.len() < total_bytes {
            grammar.sentence(&mut rng, &mut text);
        }
        let bytes = text.into_bytes();
        let split = bytes.len() * 95 / 100;
        Corpus { train: bytes[..split].to_vec(), valid: bytes[split..].to_vec() }
    }

    /// Sample a [batch, seq+1] window matrix of token ids (bytes) from a
    /// split, using the caller's rng (the worker's private data stream).
    pub fn sample_tokens(data: &[u8], rng: &mut Rng, batch: usize, seq_plus1: usize) -> Vec<i32> {
        assert!(data.len() > seq_plus1, "corpus too small for seq len");
        let mut out = Vec::with_capacity(batch * seq_plus1);
        for _ in 0..batch {
            let start = rng.below(data.len() - seq_plus1);
            out.extend(data[start..start + seq_plus1].iter().map(|&b| b as i32));
        }
        out
    }

    /// Deterministic eval batches covering the validation split.
    pub fn eval_batches(&self, batch: usize, seq_plus1: usize, max_batches: usize) -> Vec<Vec<i32>> {
        let mut batches = Vec::new();
        let mut pos = 0usize;
        'outer: for _ in 0..max_batches {
            let mut b = Vec::with_capacity(batch * seq_plus1);
            for _ in 0..batch {
                if pos + seq_plus1 > self.valid.len() {
                    break 'outer;
                }
                b.extend(self.valid[pos..pos + seq_plus1].iter().map(|&x| x as i32));
                pos += seq_plus1;
            }
            batches.push(b);
        }
        batches
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = Corpus::generate(5000, Grammar::default(), 1);
        let b = Corpus::generate(5000, Grammar::default(), 1);
        assert_eq!(a.train, b.train);
        let c = Corpus::generate(5000, Grammar::default(), 2);
        assert_ne!(a.train, c.train);
    }

    #[test]
    fn text_is_ascii_sentences() {
        let c = Corpus::generate(2000, Grammar::default(), 3);
        let s = String::from_utf8(c.train.clone()).unwrap();
        assert!(s.is_ascii());
        assert!(s.contains(". "));
        assert!(s.contains("the "));
    }

    #[test]
    fn domains_differ() {
        let a = Corpus::generate(4000, Grammar::domain(0), 5);
        let b = Corpus::generate(4000, Grammar::domain(6), 5);
        assert_ne!(a.train, b.train);
        // domain 6 has high adverb rate -> "quickly" style words more common
        let count = |data: &[u8], w: &str| {
            String::from_utf8_lossy(data).matches(w).count()
        };
        let adverbs_b: usize = ADVERBS.iter().map(|w| count(&b.train, w)).sum();
        let adverbs_a: usize = ADVERBS.iter().map(|w| count(&a.train, w)).sum();
        assert!(adverbs_b > adverbs_a, "b={adverbs_b} a={adverbs_a}");
    }

    #[test]
    fn sampling_shapes() {
        let c = Corpus::generate(3000, Grammar::default(), 7);
        let mut rng = Rng::new(9);
        let toks = Corpus::sample_tokens(&c.train, &mut rng, 4, 33);
        assert_eq!(toks.len(), 4 * 33);
        assert!(toks.iter().all(|&t| (0..256).contains(&t)));
        let evals = c.eval_batches(2, 33, 3);
        assert!(!evals.is_empty());
        assert!(evals.iter().all(|b| b.len() == 2 * 33));
    }
}
