//! Language-model harness: glues the transformer artifacts into the
//! cluster as a [`GradTask`], so every distributed strategy (D-Lion,
//! G-AdamW, TernGrad, …) trains the *same* model through the *same*
//! coordinator code path. This is the Table 3/4 substrate. The
//! [`crate::runtime::Runtime`] underneath is backend-agnostic: with a
//! compiled artifact set it runs PJRT, and with none at all it falls
//! back to the in-memory native backend — so the LM path works on a
//! fresh checkout with zero Python/JAX in the loop.

pub mod checkpoint;
pub mod corpus;

use crate::error::Result;
use crate::runtime::trainstep::EvalStepExec;
use crate::runtime::{Runtime, TrainStepExec};
use crate::tasks::{Eval, GradTask};
use crate::util::Rng;
use corpus::{Corpus, Grammar};
use std::sync::Arc;

/// A byte-level transformer LM training task backed by AOT artifacts.
pub struct LmTask {
    pub rt: Arc<Runtime>,
    pub corpus: Arc<Corpus>,
    pub batch: usize,
    pub seq_plus1: usize,
    init: Vec<f32>,
    eval_batches: Vec<Vec<i32>>,
}

impl LmTask {
    /// Build from an artifacts dir; generates a deterministic corpus.
    /// Falls back to the in-memory native backend (model `tiny`, or
    /// `DLION_MODEL`) when the directory has no manifest.
    pub fn new(artifacts_dir: &str, corpus_bytes: usize, grammar: Grammar, seed: u64) -> Result<Self> {
        let rt = Arc::new(Runtime::open(artifacts_dir)?);
        Self::with_runtime(rt, corpus_bytes, grammar, seed)
    }

    /// A fully in-memory native LM task for a registered model config —
    /// no artifacts directory required.
    pub fn native(model: &str, corpus_bytes: usize, grammar: Grammar, seed: u64) -> Result<Self> {
        let rt = Arc::new(Runtime::native(model, 0)?);
        Self::with_runtime(rt, corpus_bytes, grammar, seed)
    }

    pub fn with_runtime(
        rt: Arc<Runtime>,
        corpus_bytes: usize,
        grammar: Grammar,
        seed: u64,
    ) -> Result<Self> {
        let ts = TrainStepExec::new(&rt)?;
        let (batch, seq_plus1) = (ts.batch, ts.seq_plus1);
        drop(ts);
        let corpus = Arc::new(Corpus::generate(corpus_bytes, grammar, seed));
        let init = rt.init_params()?;
        let eval_batches = corpus.eval_batches(batch, seq_plus1, 8);
        Ok(LmTask { rt, corpus, batch, seq_plus1, init, eval_batches })
    }

    /// Replace the corpus (finetuning: new domain, same weights).
    pub fn with_corpus(&self, corpus_bytes: usize, grammar: Grammar, seed: u64) -> LmTask {
        let corpus = Arc::new(Corpus::generate(corpus_bytes, grammar, seed));
        let eval_batches = corpus.eval_batches(self.batch, self.seq_plus1, 8);
        LmTask {
            rt: self.rt.clone(),
            corpus,
            batch: self.batch,
            seq_plus1: self.seq_plus1,
            init: self.init.clone(),
            eval_batches,
        }
    }

    /// Start finetuning from pretrained parameters instead of the AOT init.
    pub fn set_init(&mut self, params: Vec<f32>) {
        assert_eq!(params.len(), self.init.len());
        self.init = params;
    }

    /// Mean eval loss over the held-out batches (perplexity = exp(loss)).
    pub fn eval_loss(&self, params: &[f32]) -> Result<f64> {
        let es = EvalStepExec::new(&self.rt)?;
        let mut total = 0.0f64;
        for b in &self.eval_batches {
            total += es.run(params, b)? as f64;
        }
        Ok(total / self.eval_batches.len().max(1) as f64)
    }
}

impl GradTask for LmTask {
    fn name(&self) -> String {
        format!("lm-{}", self.rt.manifest.model_name)
    }

    fn dim(&self) -> usize {
        self.rt.manifest.flat_dim
    }

    fn init_params(&self, _rng: &mut Rng) -> Vec<f32> {
        // deterministic init from the AOT pipeline; worker data streams
        // provide the stochasticity
        self.init.clone()
    }

    fn minibatch_grad(
        &self,
        params: &[f32],
        rng: &mut Rng,
        _batch: usize, // batch size is baked into the artifact shape
        grad: &mut [f32],
    ) -> f32 {
        let ts = TrainStepExec::new(&self.rt).expect("train_step artifact");
        let tokens = Corpus::sample_tokens(&self.corpus.train, rng, self.batch, self.seq_plus1);
        ts.run(params, &tokens, grad).expect("train_step execution")
    }

    fn evaluate(&self, params: &[f32]) -> Eval {
        let loss = self.eval_loss(params).expect("eval_step execution");
        Eval { loss, accuracy: None }
    }
}
