//! AdamW (Loshchilov & Hutter 2017) — the paper's main accuracy baseline.

use super::{AdamWParams, Optimizer};

/// AdamW with decoupled weight decay and bias correction.
pub struct AdamW {
    pub hp: AdamWParams,
    pub m: Vec<f32>, // first moment
    pub v: Vec<f32>, // second moment
    pub t: u64,      // step counter for bias correction
}

impl AdamW {
    pub fn new(dim: usize, hp: AdamWParams) -> Self {
        AdamW { hp, m: vec![0.0; dim], v: vec![0.0; dim], t: 0 }
    }
}

impl Optimizer for AdamW {
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        debug_assert_eq!(params.len(), self.m.len());
        self.begin_step();
        self.step_range(params, grads, lr, 0);
    }

    /// Bias correction advances per logical step, not per chunk — the
    /// chunked caller announces the step boundary (its first owned
    /// chunk, which under a mixed assignment may not sit at offset 0).
    fn begin_step(&mut self) {
        self.t += 1;
    }

    fn step_range(&mut self, params: &mut [f32], grads: &[f32], lr: f32, offset: usize) {
        debug_assert_eq!(params.len(), grads.len());
        let AdamWParams { beta1, beta2, eps, weight_decay } = self.hp;
        let bc1 = 1.0 - beta1.powi(self.t as i32);
        let bc2 = 1.0 - beta2.powi(self.t as i32);
        let end = offset + grads.len();
        for ((p, (m, v)), &g) in params
            .iter_mut()
            .zip(self.m[offset..end].iter_mut().zip(self.v[offset..end].iter_mut()))
            .zip(grads)
        {
            *m = beta1 * *m + (1.0 - beta1) * g;
            *v = beta2 * *v + (1.0 - beta2) * g * g;
            let mhat = *m / bc1;
            let vhat = *v / bc2;
            *p -= lr * (mhat / (vhat.sqrt() + eps) + weight_decay * *p);
        }
    }

    fn name(&self) -> &'static str {
        "adamw"
    }

    fn state_bytes(&self) -> usize {
        8 * self.m.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;

    #[test]
    fn first_step_is_signlike() {
        // With bias correction, the very first AdamW step ≈ lr·sign(g).
        let mut opt = AdamW::new(3, AdamWParams { weight_decay: 0.0, ..Default::default() });
        let mut p = vec![0.0f32; 3];
        opt.step(&mut p, &[10.0, -0.001, 3.0], 0.1);
        testing::assert_allclose(&p, &[-0.1, 0.1, -0.1], 1e-3, 1e-3, "adamw first step");
    }

    #[test]
    fn decoupled_decay_shrinks_params_with_zero_grad() {
        let mut opt = AdamW::new(1, AdamWParams { weight_decay: 0.1, ..Default::default() });
        let mut p = vec![2.0f32];
        for _ in 0..10 {
            opt.step(&mut p, &[0.0], 0.1);
        }
        // p *= (1 - lr*wd)^10
        let expect = 2.0 * (1.0f32 - 0.01).powi(10);
        testing::assert_allclose(&p, &[expect], 1e-4, 1e-4, "adamw decay");
    }

    #[test]
    fn second_moment_damps_large_gradient_axis() {
        let mut opt = AdamW::new(2, AdamWParams { weight_decay: 0.0, ..Default::default() });
        let mut p = vec![0.0f32, 0.0];
        // axis 0 gets consistently huge grads, axis 1 small: per-axis
        // normalized steps should be comparable (Adam's preconditioning).
        for _ in 0..100 {
            opt.step(&mut p, &[100.0, 0.01], 0.01);
        }
        let ratio = p[0] / p[1];
        assert!((0.5..2.0).contains(&ratio), "ratio={ratio}");
    }
}
