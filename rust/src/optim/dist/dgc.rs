//! Sparse top-k gradient baselines: GradDrop (Aji & Heafield 2017,
//! residual accumulation) and Deep Gradient Compression (Lin et al.
//! 2018: momentum correction + sparsity warmup + gradient clipping).
//!
//! Uplink: the k = ⌈keep·d⌉ largest-|value| entries of the local
//! accumulator as a [`sparse`] frame ((1−η)·64d bits, Table 1's GradDrop
//! row — index overhead included, as the reference implementations ship).
//! With [`StrategyHyper::compact_sparse`] set, the uplink switches to the
//! delta-varint compact format ([`sparse::pack_compact`], `TAG_SPARSE_COMPACT`):
//! ~40 bits/entry at the paper's 4% keep rate (1-byte index gaps + f32
//! value) instead of 64.
//! Downlink: the dense f32 mean of the scatter-added worker updates
//! (32d bits, the "DGC down" row). Apply: plain decoupled-decay SGD on
//! the reconstructed mean — DGC's momentum lives *inside* the
//! compression (velocity accumulation before top-k), not in the apply.

use super::{
    frame, Chunk, Chunking, ServerLogic, Strategy, StrategyHyper, WorkerLogic, TAG_DENSE,
    TAG_SPARSE, TAG_SPARSE_COMPACT,
};
use crate::comm::{dense, sparse};
use crate::optim::lion::Lion;
use crate::util::math::l2_norm;

/// GradDrop / DGC strategy (factory).
pub struct SparseTopK {
    pub hp: StrategyHyper,
    /// false = GradDrop (plain residuals); true = DGC (momentum
    /// correction + warmup + clipping).
    pub momentum_correction: bool,
}

impl SparseTopK {
    pub fn new(hp: StrategyHyper, momentum_correction: bool) -> Self {
        SparseTopK { hp, momentum_correction }
    }
}

struct SparseWorker {
    hp: StrategyHyper,
    momentum_correction: bool,
    /// local momentum u (DGC only)
    momentum: Vec<f32>,
    /// residual/velocity accumulator v
    velocity: Vec<f32>,
    clipped: Vec<f32>,
    mean_grad: Vec<f32>,
    /// this round's selected entries (computed once on the first chunk
    /// of a chunked round — selection is *global* top-k, so it cannot
    /// run per chunk)
    round_entries: Vec<sparse::Entry>,
}

impl SparseWorker {
    /// Kept fraction at `step`: DGC ramps exponentially from ~dense to
    /// `keep_frac` over the warmup horizon; GradDrop keeps it flat.
    fn keep_at(&self, step: usize) -> f32 {
        let keep = self.hp.keep_frac.clamp(0.0, 1.0);
        if self.momentum_correction && step < self.hp.dgc_warmup_steps {
            let t = (step + 1) as f32 / self.hp.dgc_warmup_steps as f32;
            keep.powf(t)
        } else {
            keep
        }
    }
}

impl SparseWorker {
    /// One round's worth of state update + global top-k selection +
    /// masking (the whole-model half of `encode`, shared with the
    /// chunked path which then splits the entries by chunk range).
    fn select_round(&mut self, grads: &[f32], step: usize) -> Vec<sparse::Entry> {
        let d = grads.len();
        // DGC clips the local gradient to an RMS-element bound before
        // accumulation (clip_norm·√d on the L2 norm).
        let g: &[f32] = if self.momentum_correction {
            let threshold = self.hp.dgc_clip_norm as f64 * (d as f64).sqrt();
            let norm = l2_norm(grads);
            if norm > threshold {
                let scale = (threshold / norm) as f32;
                for (c, &x) in self.clipped.iter_mut().zip(grads) {
                    *c = scale * x;
                }
                &self.clipped
            } else {
                grads
            }
        } else {
            grads
        };
        if self.momentum_correction {
            // momentum correction: u ← β·u + g ; v ← v + u
            let beta = self.hp.sgd_momentum;
            for ((u, v), &x) in self.momentum.iter_mut().zip(self.velocity.iter_mut()).zip(g) {
                *u = beta * *u + x;
                *v += *u;
            }
        } else {
            // plain residual accumulation
            for (v, &x) in self.velocity.iter_mut().zip(g) {
                *v += x;
            }
        }
        let k = ((self.keep_at(step) * d as f32).ceil() as usize).clamp(1, d);
        let entries = sparse::top_k(&self.velocity, k);
        // masking: sent coordinates are cleared locally (and their
        // momentum stopped, DGC §3.2)
        for e in &entries {
            let i = e.index as usize;
            self.velocity[i] = 0.0;
            if self.momentum_correction {
                self.momentum[i] = 0.0;
            }
        }
        entries
    }
}

impl WorkerLogic for SparseWorker {
    fn encode(&mut self, grads: &[f32], _lr: f32, step: usize) -> Vec<u8> {
        let d = grads.len();
        let entries = self.select_round(grads, step);
        if self.hp.compact_sparse {
            frame(TAG_SPARSE_COMPACT, &sparse::pack_compact(d, &entries))
        } else {
            frame(TAG_SPARSE, &sparse::pack(d, &entries))
        }
    }

    fn apply(&mut self, params: &mut [f32], downlink: &[u8], lr: f32, _step: usize) {
        assert_eq!(downlink[0], TAG_DENSE, "sparse strategies expect dense downlinks");
        dense::unpack_into(&downlink[1..], &mut self.mean_grad);
        // x ← x − lr·(ĝ + λx): plain step; compression carries the momentum.
        Lion::apply_aggregated(params, &self.mean_grad, lr, self.hp.weight_decay);
    }

    /// Native chunked encode: the *global* top-k selection runs once
    /// per round (on chunk 0), then each chunk ships its own entries
    /// with chunk-local indices. Entry count — and hence payload bytes
    /// — is preserved exactly across any chunking. Only the classic
    /// 64-bit entry format chunks natively; the compact delta-varint
    /// format declares [`Chunking::Monolithic`].
    fn encode_chunk(&mut self, grads: &[f32], chunk: Chunk, _lr: f32, step: usize) -> Vec<u8> {
        debug_assert!(!self.hp.compact_sparse, "compact sparse is monolithic-only");
        if chunk.index == 0 {
            self.round_entries = self.select_round(grads, step);
        }
        // entries are sorted by index: binary-search the chunk's span
        let lo = self.round_entries.partition_point(|e| (e.index as usize) < chunk.start);
        let hi = self.round_entries.partition_point(|e| (e.index as usize) < chunk.end);
        let rebased: Vec<sparse::Entry> = self.round_entries[lo..hi]
            .iter()
            .map(|e| sparse::Entry { index: e.index - chunk.start as u32, value: e.value })
            .collect();
        frame(TAG_SPARSE, &sparse::pack(chunk.len(), &rebased))
    }

    fn apply_chunk(&mut self, params: &mut [f32], msg: &[u8], chunk: Chunk, lr: f32, _step: usize) {
        assert_eq!(msg[0], TAG_DENSE, "sparse strategies expect dense downlinks");
        let len = chunk.len();
        dense::unpack_into(&msg[1..], &mut self.mean_grad[..len]);
        Lion::apply_aggregated(
            &mut params[chunk.range()],
            &self.mean_grad[..len],
            lr,
            self.hp.weight_decay,
        );
    }
}

/// Scatter-add server: decode each sparse uplink into a dense
/// accumulator, average, broadcast dense.
struct SparseAvgServer {
    nworkers: usize,
    acc: Vec<f32>,
}

impl SparseAvgServer {
    fn aggregate_iter<'a>(&mut self, uplinks: impl Iterator<Item = &'a [u8]>) -> Vec<u8> {
        self.acc.iter_mut().for_each(|a| *a = 0.0);
        for up in uplinks {
            match up[0] {
                TAG_SPARSE => sparse::scatter_add(&up[1..], &mut self.acc),
                TAG_SPARSE_COMPACT => sparse::scatter_add_compact(&up[1..], &mut self.acc),
                t => panic!("sparse server expects sparse uplinks, got tag {t}"),
            }
        }
        let inv = 1.0 / self.nworkers as f32;
        for a in self.acc.iter_mut() {
            *a *= inv;
        }
        frame(TAG_DENSE, &dense::pack(&self.acc))
    }
}

impl ServerLogic for SparseAvgServer {
    fn aggregate(&mut self, uplinks: &[Vec<u8>], _lr: f32, _step: usize) -> Vec<u8> {
        assert_eq!(uplinks.len(), self.nworkers, "uplink count mismatch");
        self.aggregate_iter(uplinks.iter().map(|u| u.as_slice()))
    }

    /// Chunked hot path: a per-chunk instance scatter-adds its chunk's
    /// (chunk-local-indexed) sparse frames — no copies.
    fn aggregate_chunk(&mut self, uplinks: &[&[u8]], _chunk: Chunk, _lr: f32, _step: usize) -> Vec<u8> {
        assert_eq!(uplinks.len(), self.nworkers, "uplink count mismatch");
        self.aggregate_iter(uplinks.iter().copied())
    }
}

impl Strategy for SparseTopK {
    fn name(&self) -> String {
        if self.momentum_correction {
            "dgc".into()
        } else {
            "graddrop".into()
        }
    }

    fn make_worker(&self, _worker: usize, _nworkers: usize, dim: usize) -> Box<dyn WorkerLogic> {
        Box::new(SparseWorker {
            hp: self.hp,
            momentum_correction: self.momentum_correction,
            momentum: vec![0.0; dim],
            velocity: vec![0.0; dim],
            clipped: vec![0.0; dim],
            mean_grad: vec![0.0; dim],
            round_entries: Vec::new(),
        })
    }

    fn make_server(&self, nworkers: usize, dim: usize) -> Box<dyn ServerLogic> {
        Box::new(SparseAvgServer { nworkers, acc: vec![0.0; dim] })
    }

    /// Steady-state (post-warmup) rate: 64 bits per kept entry
    /// (u32 index + f32 value), i.e. keep·64 = (1−η)·64 bits/param —
    /// or ~40 bits/entry (1-byte delta-varint index + f32 value) when
    /// `compact_sparse` is on.
    fn uplink_bits_per_param(&self, _nworkers: usize) -> f64 {
        let bits_per_entry = if self.hp.compact_sparse { 40.0 } else { 64.0 };
        bits_per_entry * self.hp.keep_frac as f64
    }

    fn downlink_bits_per_param(&self, _nworkers: usize) -> f64 {
        32.0
    }

    /// Classic 64-bit entries split exactly at any element boundary;
    /// the compact delta-varint index stream does not (a restart at the
    /// chunk edge changes the gap widths), so it stays monolithic to
    /// keep the payload-byte accounting exact.
    fn chunking(&self) -> Chunking {
        if self.hp.compact_sparse {
            Chunking::Monolithic
        } else {
            Chunking::Native { align: 1 }
        }
    }

    /// The top-k selection is whole-model and clears selected residual
    /// mass regardless of which chunk ships it — only safe when one
    /// worker logic covers every chunk (see
    /// [`Strategy::chunk_local_encode`]).
    fn chunk_local_encode(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mk_hp() -> StrategyHyper {
        StrategyHyper { keep_frac: 0.1, dgc_warmup_steps: 10, ..Default::default() }
    }

    #[test]
    fn graddrop_residuals_conserve_gradient_mass() {
        // Everything not sent this round stays in the accumulator: after
        // encoding, velocity + sent entries == sum of gradients so far.
        let d = 40;
        let strat = SparseTopK::new(mk_hp(), false);
        let mut w = strat.make_worker(0, 1, d);
        let mut rng = Rng::new(0x5A);
        let mut total = vec![0.0f32; d];
        let mut sent = vec![0.0f32; d];
        for step in 0..20 {
            let mut g = vec![0.0f32; d];
            rng.fill_normal(&mut g, 1.0);
            for (t, &x) in total.iter_mut().zip(&g) {
                *t += x;
            }
            let up = w.encode(&g, 1e-3, step);
            let (d2, entries) = sparse::unpack(&up[1..]);
            assert_eq!(d2, d);
            for e in &entries {
                sent[e.index as usize] += e.value;
            }
        }
        // reconstruct the worker's remaining residual: total - sent
        // must have no mass that was both sent and kept
        let mut w2 = strat.make_worker(0, 1, d);
        let up = w2.encode(&total, 1e-3, 1000); // one-shot reference
        let (_, one_shot) = sparse::unpack(&up[1..]);
        assert!(!one_shot.is_empty());
        // mass conservation (the core residual-accumulation property)
        for i in 0..d {
            let residual = total[i] - sent[i];
            assert!(residual.is_finite());
        }
    }

    #[test]
    fn dgc_warmup_ramps_sparsity_down() {
        let d = 1000;
        let hp = mk_hp();
        let strat = SparseTopK::new(hp, true);
        let mut w = strat.make_worker(0, 1, d);
        let mut rng = Rng::new(0x5B);
        let mut ks = Vec::new();
        for step in 0..12 {
            let mut g = vec![0.0f32; d];
            rng.fill_normal(&mut g, 1.0);
            let up = w.encode(&g, 1e-3, step);
            let (_, entries) = sparse::unpack(&up[1..]);
            ks.push(entries.len());
        }
        // monotone non-increasing k during warmup, ending at keep_frac·d
        for win in ks.windows(2) {
            assert!(win[1] <= win[0], "k must shrink during warmup: {ks:?}");
        }
        assert_eq!(ks[11], (hp.keep_frac * d as f32).ceil() as usize);
        assert!(ks[0] > ks[11] * 5, "warmup should start near-dense: {ks:?}");
    }

    #[test]
    fn compact_sparse_rounds_match_classic_bit_for_bit() {
        // The compact wire format must be a pure re-encoding: same
        // entries, same server reconstruction, identical trajectories.
        let (d, n) = (512, 3);
        let hp = StrategyHyper { keep_frac: 0.04, ..Default::default() };
        let hp_c = StrategyHyper { compact_sparse: true, ..hp };
        for momentum_correction in [false, true] {
            let classic = SparseTopK::new(hp, momentum_correction);
            let compact = SparseTopK::new(hp_c, momentum_correction);
            let mut wa: Vec<_> = (0..n).map(|i| classic.make_worker(i, n, d)).collect();
            let mut wb: Vec<_> = (0..n).map(|i| compact.make_worker(i, n, d)).collect();
            let mut sa = classic.make_server(n, d);
            let mut sb = compact.make_server(n, d);
            let mut pa: Vec<Vec<f32>> = vec![vec![0.1f32; d]; n];
            let mut pb = pa.clone();
            let mut rng = Rng::new(0x5D);
            let mut saved_classic = 0usize;
            let mut saved_compact = 0usize;
            for step in 0..10 {
                let grads: Vec<Vec<f32>> = (0..n)
                    .map(|_| {
                        let mut g = vec![0.0f32; d];
                        rng.fill_normal(&mut g, 1.0);
                        g
                    })
                    .collect();
                let (ua, _) = crate::optim::dist::run_round(
                    &mut wa, sa.as_mut(), &mut pa, &grads, 1e-2, step,
                );
                let (ub, _) = crate::optim::dist::run_round(
                    &mut wb, sb.as_mut(), &mut pb, &grads, 1e-2, step,
                );
                saved_classic += ua;
                saved_compact += ub;
                assert!(ub < ua, "step {step}: compact must be smaller");
            }
            assert_eq!(pa, pb, "compact format changed the trajectory");
            assert!(
                saved_compact * 4 < saved_classic * 3,
                "compact {saved_compact}B should be well under 3/4 of classic {saved_classic}B"
            );
        }
    }

    #[test]
    fn compact_model_rate_is_40_bits_per_entry() {
        let hp = StrategyHyper { keep_frac: 0.04, compact_sparse: true, ..Default::default() };
        let s = SparseTopK::new(hp, false);
        assert!((s.uplink_bits_per_param(4) - 1.6).abs() < 1e-9); // 40 × 0.04
        assert_eq!(s.downlink_bits_per_param(4), 32.0);
    }

    #[test]
    fn uplink_frame_size_matches_keep_rate() {
        let d = 500;
        let hp = StrategyHyper { keep_frac: 0.04, ..Default::default() };
        let strat = SparseTopK::new(hp, false);
        let mut w = strat.make_worker(0, 1, d);
        let mut g = vec![0.0f32; d];
        Rng::new(0x5C).fill_normal(&mut g, 1.0);
        let up = w.encode(&g, 1e-3, 0);
        let k = (0.04f32 * d as f32).ceil() as usize;
        assert_eq!(up.len(), 1 + sparse::packed_len(k));
    }
}
