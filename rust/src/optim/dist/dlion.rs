//! Distributed Lion (paper Algorithm 1) and the D-SIGNUM ablation.
//!
//! Worker: keep a private Lion momentum; each round send the *binary*
//! update δ_i = sign(β1·m + (1−β1)·g) as a 1-bit frame, then advance the
//! momentum (the fused [`Lion::encode_fused`] hot path does both in one
//! pass). Server: accumulate the votes S = Σ_i δ_i and broadcast either
//! sign(S) (majority vote) or S itself log(N)-bit-packed (average).
//! Worker apply: x ← x − lr·(Δ + λx) with Δ the decoded aggregate —
//! exactly [`Lion::apply_aggregated`], so a 1-worker D-Lion reproduces
//! single-node Lion bit-for-bit.
//!
//! D-SIGNUM is the same round with Signum's single-β momentum
//! (Bernstein et al. 2018), the paper's Figure-4 ablation.

use super::{
    frame, sign_family_downlink_bits, Chunk, Chunking, ServerLogic, SignKernel, SignVoteServer,
    SplitEncode, Strategy, UpdateDecoder, WorkerLogic, SIGN_FAMILY_ALIGN, TAG_SIGN,
};
use crate::comm::sign;
use crate::optim::lion::Lion;
use crate::optim::signum::{signum_encode_slice, Signum};
use crate::optim::LionParams;
use crate::util::math::bits_for_count;

/// Server-side aggregation rule for 1-bit worker updates (Table 1's two
/// Distributed-Lion rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Aggregation {
    /// Δ = sign(Σ δ_i): 1 bit/param downlink (odd N; 1.6 with even-N ties).
    MajorityVote,
    /// Δ = (Σ δ_i)/N: ⌈log2(N+1)⌉ bits/param downlink.
    Average,
}

/// Distributed Lion strategy (factory).
pub struct DLion {
    pub hp: LionParams,
    pub agg: Aggregation,
}

impl DLion {
    pub fn new(hp: LionParams, agg: Aggregation) -> Self {
        DLion { hp, agg }
    }
}

struct DLionWorker {
    lion: Lion,
    weight_decay: f32,
    decoder: UpdateDecoder,
}

impl WorkerLogic for DLionWorker {
    fn encode(&mut self, grads: &[f32], _lr: f32, _step: usize) -> Vec<u8> {
        // One fused pass: blend-sign bits packed + momentum advanced.
        frame(TAG_SIGN, &self.lion.encode_fused(grads))
    }

    fn apply(&mut self, params: &mut [f32], downlink: &[u8], lr: f32, _step: usize) {
        let update = self.decoder.decode(downlink);
        Lion::apply_aggregated(params, update, lr, self.weight_decay);
    }

    /// Native chunked encode: the fused pass over just `chunk.range()`.
    fn encode_chunk(&mut self, grads: &[f32], chunk: Chunk, _lr: f32, _step: usize) -> Vec<u8> {
        frame(TAG_SIGN, &self.lion.encode_fused_range(grads, chunk.range()))
    }

    fn apply_chunk(&mut self, params: &mut [f32], msg: &[u8], chunk: Chunk, lr: f32, _step: usize) {
        let update = self.decoder.decode_len(msg, chunk.len());
        Lion::apply_aggregated(&mut params[chunk.range()], update, lr, self.weight_decay);
    }

    /// The fused Lion encode is a pure slice kernel over the momentum,
    /// so the round engine may encode this worker's chunks in parallel.
    fn split_encode(&mut self) -> Option<SplitEncode<'_>> {
        let LionParams { beta1, beta2, .. } = self.lion.hp;
        Some(SplitEncode {
            state: &mut self.lion.momentum,
            kernel: SignKernel::LionFused { beta1, beta2 },
        })
    }

    fn momentum(&self) -> Option<&[f32]> {
        Some(&self.lion.momentum)
    }
}

impl Strategy for DLion {
    fn name(&self) -> String {
        match self.agg {
            Aggregation::MajorityVote => "d-lion-mavo".into(),
            Aggregation::Average => "d-lion-avg".into(),
        }
    }

    fn make_worker(&self, _worker: usize, _nworkers: usize, dim: usize) -> Box<dyn WorkerLogic> {
        Box::new(DLionWorker {
            lion: Lion::new(dim, self.hp),
            weight_decay: self.hp.weight_decay,
            decoder: UpdateDecoder::new(dim),
        })
    }

    fn make_server(&self, nworkers: usize, dim: usize) -> Box<dyn ServerLogic> {
        Box::new(SignVoteServer::new(nworkers, dim, self.agg))
    }

    fn uplink_bits_per_param(&self, _nworkers: usize) -> f64 {
        1.0
    }

    fn downlink_bits_per_param(&self, nworkers: usize) -> f64 {
        sign_family_downlink_bits(self.agg, nworkers)
    }

    /// Sign/tern/intavg payloads all hit byte boundaries every 40
    /// elements, so 40-aligned chunks splice bit-exactly.
    fn chunking(&self) -> Chunking {
        Chunking::Native { align: SIGN_FAMILY_ALIGN }
    }

    /// Aggregator→root hop ships exact integer vote sums:
    /// ⌈log₂(g+1)⌉ bits/param per group.
    fn partial_bits_per_param(&self, group_size: usize) -> f64 {
        bits_for_count(group_size) as f64
    }

    /// A missing voter abstains exactly — the vote over the quorum is
    /// the ground-truth aggregate over the quorum.
    fn quorum(&self) -> super::QuorumSupport {
        super::QuorumSupport::Exact
    }
}

/// D-SIGNUM: Signum workers behind the same vote/average servers.
pub struct DSignum {
    pub beta: f32,
    pub weight_decay: f32,
    pub agg: Aggregation,
}

impl DSignum {
    pub fn new(beta: f32, weight_decay: f32, agg: Aggregation) -> Self {
        DSignum { beta, weight_decay, agg }
    }
}

struct DSignumWorker {
    signum: Signum,
    weight_decay: f32,
    decoder: UpdateDecoder,
}

impl DSignumWorker {
    /// Fused advance-and-pack over one momentum range (Signum signs the
    /// freshly-advanced momentum) — single pass, no blend scratch.
    fn encode_range(&mut self, grads: &[f32], range: std::ops::Range<usize>) -> Vec<u8> {
        let gs = &grads[range.clone()];
        let ms = &mut self.signum.momentum[range];
        let mut msg = vec![0u8; 1 + sign::packed_len(gs.len())];
        msg[0] = TAG_SIGN;
        signum_encode_slice(self.signum.beta, ms, gs, &mut msg[1..]);
        msg
    }
}

impl WorkerLogic for DSignumWorker {
    fn encode(&mut self, grads: &[f32], _lr: f32, _step: usize) -> Vec<u8> {
        self.encode_range(grads, 0..grads.len())
    }

    fn apply(&mut self, params: &mut [f32], downlink: &[u8], lr: f32, _step: usize) {
        let update = self.decoder.decode(downlink);
        Lion::apply_aggregated(params, update, lr, self.weight_decay);
    }

    fn encode_chunk(&mut self, grads: &[f32], chunk: Chunk, _lr: f32, _step: usize) -> Vec<u8> {
        self.encode_range(grads, chunk.range())
    }

    fn apply_chunk(&mut self, params: &mut [f32], msg: &[u8], chunk: Chunk, lr: f32, _step: usize) {
        let update = self.decoder.decode_len(msg, chunk.len());
        Lion::apply_aggregated(&mut params[chunk.range()], update, lr, self.weight_decay);
    }

    /// Signum's fused encode is a pure slice kernel over the momentum.
    fn split_encode(&mut self) -> Option<SplitEncode<'_>> {
        Some(SplitEncode {
            state: &mut self.signum.momentum,
            kernel: SignKernel::Signum { beta: self.signum.beta },
        })
    }

    fn momentum(&self) -> Option<&[f32]> {
        Some(&self.signum.momentum)
    }
}

impl Strategy for DSignum {
    fn name(&self) -> String {
        match self.agg {
            Aggregation::MajorityVote => "d-signum-mavo".into(),
            Aggregation::Average => "d-signum-avg".into(),
        }
    }

    fn make_worker(&self, _worker: usize, _nworkers: usize, dim: usize) -> Box<dyn WorkerLogic> {
        Box::new(DSignumWorker {
            signum: Signum::new(dim, self.beta, self.weight_decay),
            weight_decay: self.weight_decay,
            decoder: UpdateDecoder::new(dim),
        })
    }

    fn make_server(&self, nworkers: usize, dim: usize) -> Box<dyn ServerLogic> {
        Box::new(SignVoteServer::new(nworkers, dim, self.agg))
    }

    fn uplink_bits_per_param(&self, _nworkers: usize) -> f64 {
        1.0
    }

    fn downlink_bits_per_param(&self, nworkers: usize) -> f64 {
        sign_family_downlink_bits(self.agg, nworkers)
    }

    fn chunking(&self) -> Chunking {
        Chunking::Native { align: SIGN_FAMILY_ALIGN }
    }

    fn partial_bits_per_param(&self, group_size: usize) -> f64 {
        bits_for_count(group_size) as f64
    }

    /// Sign votes tolerate any voter count (abstention-exact).
    fn quorum(&self) -> super::QuorumSupport {
        super::QuorumSupport::Exact
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Optimizer;
    use crate::util::Rng;

    #[test]
    fn one_worker_dlion_equals_single_node_lion() {
        // With N = 1 the vote is the worker's own update, so the round
        // must reproduce Optimizer::step bit-for-bit.
        let hp = LionParams { beta1: 0.9, beta2: 0.99, weight_decay: 0.01 };
        let d = 67;
        for agg in [Aggregation::MajorityVote, Aggregation::Average] {
            let strat = DLion::new(hp, agg);
            let mut worker = strat.make_worker(0, 1, d);
            let mut server = strat.make_server(1, d);
            let mut lion = Lion::new(d, hp);
            let mut pa = vec![0.3f32; d];
            let mut pb = pa.clone();
            let mut rng = Rng::new(0xD1);
            for step in 0..40 {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                let up = worker.encode(&g, 0.01, step);
                let down = server.aggregate(&[up], 0.01, step);
                worker.apply(&mut pa, &down, 0.01, step);
                lion.step(&mut pb, &g, 0.01);
            }
            assert_eq!(pa, pb, "agg {agg:?} diverged from single-node Lion");
        }
    }

    #[test]
    fn mavo_downlink_is_binary_for_odd_n_ternary_for_even() {
        let hp = LionParams::default();
        let d = 50;
        let strat = DLion::new(hp, Aggregation::MajorityVote);
        let mut rng = Rng::new(0xD2);
        for n in [1usize, 2, 3, 4, 5] {
            let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
            let mut server = strat.make_server(n, d);
            let ups: Vec<_> = workers
                .iter_mut()
                .map(|w| {
                    let mut g = vec![0.0f32; d];
                    rng.fill_normal(&mut g, 1.0);
                    w.encode(&g, 1e-3, 0)
                })
                .collect();
            let down = server.aggregate(&ups, 1e-3, 0);
            let expect = if n % 2 == 1 { super::super::TAG_SIGN } else { super::super::TAG_TERN };
            assert_eq!(down[0], expect, "n={n}");
            assert_eq!(down.len(), 1 + if n % 2 == 1 { d.div_ceil(8) } else { d.div_ceil(5) });
        }
    }

    #[test]
    fn avg_downlink_carries_exact_vote_sums() {
        let hp = LionParams::default();
        let d = 33;
        let n = 4;
        let strat = DLion::new(hp, Aggregation::Average);
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut server = strat.make_server(n, d);
        let mut rng = Rng::new(0xD3);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                g
            })
            .collect();
        let ups: Vec<_> = workers
            .iter_mut()
            .zip(&grads)
            .map(|(w, g)| w.encode(g, 1e-3, 0))
            .collect();
        // reference votes from the individual 1-bit frames
        let mut votes = vec![0i32; d];
        for up in &ups {
            crate::comm::sign::accumulate_votes(&up[1..], &mut votes);
        }
        let down = server.aggregate(&ups, 1e-3, 0);
        assert_eq!(down[0], super::super::TAG_INTAVG);
        let got = crate::comm::intavg::unpack(&down[3..], d, n);
        assert_eq!(got, votes);
    }

    #[test]
    fn signum_collapses_to_lion_with_equal_betas() {
        // D-SIGNUM(β) must equal D-Lion(β1=β2=β) trajectory-for-trajectory.
        let beta = 0.95f32;
        let d = 29;
        let n = 3;
        let lion_hp = LionParams { beta1: beta, beta2: beta, weight_decay: 0.005 };
        let dl = DLion::new(lion_hp, Aggregation::MajorityVote);
        let ds = DSignum::new(beta, 0.005, Aggregation::MajorityVote);
        let mut wa: Vec<_> = (0..n).map(|i| dl.make_worker(i, n, d)).collect();
        let mut wb: Vec<_> = (0..n).map(|i| ds.make_worker(i, n, d)).collect();
        let mut sa = dl.make_server(n, d);
        let mut sb = ds.make_server(n, d);
        let mut pa: Vec<Vec<f32>> = vec![vec![0.2f32; d]; n];
        let mut pb = pa.clone();
        let mut rng = Rng::new(0xD4);
        for step in 0..30 {
            let grads: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut g = vec![0.0f32; d];
                    rng.fill_normal(&mut g, 1.0);
                    g
                })
                .collect();
            super::super::run_round(&mut wa, sa.as_mut(), &mut pa, &grads, 0.01, step);
            super::super::run_round(&mut wb, sb.as_mut(), &mut pb, &grads, 0.01, step);
        }
        assert_eq!(pa, pb);
    }
}
