//! Error-feedback Distributed Lion (Lion Cub's EF variant, Ishikawa et
//! al. 2024; error-feedback framework of Karimireddy et al. 2019).
//!
//! Plain D-Lion discards everything the 1-bit uplink cannot carry: the
//! worker ships sign(c_t) for the blend c_t = β1·m_t + (1−β1)·g_t and the
//! magnitude information is gone. The EF variant keeps a per-worker
//! residual e_t of exactly that compression error and folds it into the
//! next round's pre-compression signal:
//!
//! ```text
//! c_t = β1·m_t + (1−β1)·g_t          // Lion blend (unchanged)
//! p_t = c_t + e_t                    // fold in last round's residual
//! send sign(p_t)                     // 1-bit frame, same wire as D-Lion
//! γ_t = ‖p_t‖₁ / d                   // compression scale (ℓ1 mean)
//! e_{t+1} = p_t − γ_t·sign(p_t)      // the residual IS the comp. error
//! m_{t+1} = β2·m_t + (1−β2)·g_t      // momentum (unchanged)
//! ```
//!
//! The wire format is bit-identical to `d-lion-mavo`: 1-bit sign uplink
//! into the shared `SignVoteServer`, majority-vote downlink, worker
//! apply `x ← x − lr·(Δ + λx)`. Error feedback is purely worker-local —
//! the scale γ_t is never transmitted, it only calibrates how much of
//! the signal the residual re-injects next round.

use super::{
    frame, sign_family_downlink_bits, ServerLogic, SignVoteServer, Strategy, UpdateDecoder,
    WorkerLogic, TAG_SIGN,
};
use crate::comm::sign;
use crate::optim::lion::{bsign, Lion};
use crate::optim::LionParams;
use crate::util::math::l1_norm;

/// Error-feedback D-Lion strategy (factory). Registry name `d-lion-ef`.
pub struct DLionEf {
    pub hp: LionParams,
    pub agg: super::Aggregation,
}

impl DLionEf {
    pub fn new(hp: LionParams, agg: super::Aggregation) -> Self {
        DLionEf { hp, agg }
    }
}

/// Worker state: Lion momentum + the EF residual. `pub(crate)` so the
/// in-module tests can assert the residual recursion exactly.
pub(crate) struct EfWorker {
    lion: Lion,
    weight_decay: f32,
    /// e_t — what the previous 1-bit frame could not carry.
    pub(crate) error: Vec<f32>,
    /// scratch: p_t = c_t + e_t
    pub(crate) corrected: Vec<f32>,
    decoder: UpdateDecoder,
}

impl WorkerLogic for EfWorker {
    fn encode(&mut self, grads: &[f32], _lr: f32, _step: usize) -> Vec<u8> {
        let d = grads.len();
        // p = β1·m + (1−β1)·g + e  (blend computed against the *current*
        // momentum, before the β2 advance — same ordering as Lion::step).
        let b1 = self.lion.hp.beta1;
        for (((p, &m), &g), &e) in self
            .corrected
            .iter_mut()
            .zip(&self.lion.momentum)
            .zip(grads)
            .zip(&self.error)
        {
            *p = b1 * m + (1.0 - b1) * g + e;
        }
        let scale = (l1_norm(&self.corrected) / d as f64) as f32;
        // e ← p − γ·sign(p): exactly the compression error of this frame.
        for (e, &p) in self.error.iter_mut().zip(&self.corrected) {
            *e = p - scale * bsign(p);
        }
        self.lion.advance_momentum(grads);
        frame(TAG_SIGN, &sign::pack_f32(&self.corrected))
    }

    fn apply(&mut self, params: &mut [f32], downlink: &[u8], lr: f32, _step: usize) {
        let update = self.decoder.decode(downlink);
        Lion::apply_aggregated(params, update, lr, self.weight_decay);
    }

    fn momentum(&self) -> Option<&[f32]> {
        Some(&self.lion.momentum)
    }
}

impl Strategy for DLionEf {
    fn name(&self) -> String {
        "d-lion-ef".into()
    }

    fn make_worker(&self, _worker: usize, _nworkers: usize, dim: usize) -> Box<dyn WorkerLogic> {
        Box::new(EfWorker {
            lion: Lion::new(dim, self.hp),
            weight_decay: self.hp.weight_decay,
            error: vec![0.0; dim],
            corrected: vec![0.0; dim],
            decoder: UpdateDecoder::new(dim),
        })
    }

    fn make_server(&self, nworkers: usize, dim: usize) -> Box<dyn ServerLogic> {
        Box::new(SignVoteServer::new(nworkers, dim, self.agg))
    }

    fn uplink_bits_per_param(&self, _nworkers: usize) -> f64 {
        1.0
    }

    fn downlink_bits_per_param(&self, nworkers: usize) -> f64 {
        sign_family_downlink_bits(self.agg, nworkers)
    }

    /// Sign votes tolerate any voter count, and the EF residual folds a
    /// straggler's unsent mass into its next frame automatically.
    fn quorum(&self) -> super::QuorumSupport {
        super::QuorumSupport::Exact
    }
}

#[cfg(test)]
mod tests {
    use super::super::Aggregation;
    use super::*;
    use crate::util::Rng;

    fn mk() -> DLionEf {
        DLionEf::new(
            LionParams { beta1: 0.9, beta2: 0.99, weight_decay: 0.01 },
            Aggregation::MajorityVote,
        )
    }

    // NB: the exact residual-recursion invariant (e == p − γ·sign(p)
    // after every encode, replayed externally frame-for-frame) lives in
    // tests/property_invariants.rs as a randomized property — keep the
    // unit tests here to smoke-level checks so the recursion has one
    // canonical spec.

    #[test]
    fn zero_residual_start_matches_plain_dlion_first_frame() {
        // With e_0 = 0 the first EF frame equals plain D-Lion's frame.
        let d = 64;
        let ef = mk();
        let dl = super::super::DLion::new(ef.hp, Aggregation::MajorityVote);
        let mut we = ef.make_worker(0, 1, d);
        let mut wd = dl.make_worker(0, 1, d);
        let mut g = vec![0.0f32; d];
        Rng::new(0xE1).fill_normal(&mut g, 1.0);
        assert_eq!(we.encode(&g, 1e-3, 0), wd.encode(&g, 1e-3, 0));
    }

    #[test]
    fn ef_signal_mean_converges_to_true_gradient_direction() {
        // Constant gradient: the time-average of γ-scaled transmitted
        // signs must track the blend direction (EF's defining property) —
        // coordinates with tiny |g| flip, large ones saturate.
        let d = 16;
        let strat = mk();
        let mut w = EfWorker {
            lion: Lion::new(d, strat.hp),
            weight_decay: 0.0,
            error: vec![0.0; d],
            corrected: vec![0.0; d],
            decoder: UpdateDecoder::new(d),
        };
        let g: Vec<f32> = (0..d).map(|i| (i as f32 - 7.5) / 8.0).collect();
        // start at the momentum fixed point (m = g) so the EMA warmup
        // ramp does not bias the time-average we measure
        w.lion.momentum.copy_from_slice(&g);
        let reps = 600;
        let mut mean = vec![0.0f64; d];
        for step in 0..reps {
            // replicate scale before encode mutates the state
            let b1 = w.lion.hp.beta1;
            let p: Vec<f32> = w
                .lion
                .momentum
                .iter()
                .zip(&g)
                .zip(&w.error)
                .map(|((&m, &gg), &e)| b1 * m + (1.0 - b1) * gg + e)
                .collect();
            let scale = (l1_norm(&p) / d as f64) as f32;
            let up = w.encode(&g, 1e-3, step);
            let signs = sign::unpack(&up[1..], d);
            for (acc, &s) in mean.iter_mut().zip(&signs) {
                *acc += scale as f64 * s as f64 / reps as f64;
            }
        }
        for (m, &gg) in mean.iter().zip(&g) {
            assert!(
                (m - gg as f64).abs() < 0.08,
                "EF mean {m:.4} vs blend target {gg:.4}"
            );
        }
    }

    #[test]
    fn replicas_stay_identical() {
        let d = 40;
        let n = 3;
        let strat = mk();
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut server = strat.make_server(n, d);
        let mut params: Vec<Vec<f32>> = vec![vec![0.1f32; d]; n];
        let mut rng = Rng::new(0xE2);
        for step in 0..25 {
            let grads: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut g = vec![0.0f32; d];
                    rng.fill_normal(&mut g, 1.0);
                    g
                })
                .collect();
            super::super::run_round(&mut workers, server.as_mut(), &mut params, &grads, 0.01, step);
            for w in 1..n {
                assert_eq!(params[0], params[w], "step {step}");
            }
        }
    }
}
