//! Byzantine fault injection (the `ext_byzantine` bench).
//!
//! The paper inherits SignSGD-with-majority-vote's robustness story
//! (Bernstein et al. 2018c, cited in footnote 4): a 1-bit vote bounds a
//! corrupt worker's per-coordinate influence to ±1 vote, while f32
//! averaging is unbounded. [`FaultyWorker`] wraps an honest
//! [`WorkerLogic`] and corrupts its uplink *payload* while preserving
//! the frame tag and length, so the server still decodes a well-formed
//! message — an adversary that keeps the protocol but lies about the
//! content, the strongest attack the aggregation rule itself can see.

use super::{Chunk, WorkerLogic};
use crate::util::Rng;

/// Corruption model applied to each uplink frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Replace every payload byte with uniform random bytes.
    RandomBytes,
    /// Bitwise-invert the payload (flips every vote / sign bit).
    BitFlip,
    /// No corruption (control arm).
    Honest,
}

/// A worker whose uplinks are corrupted after honest encoding. The
/// inner logic still advances its own state and applies downlinks
/// honestly, so the attack is purely on the communicated update.
pub struct FaultyWorker {
    inner: Box<dyn WorkerLogic>,
    fault: Fault,
    rng: Rng,
    /// First step the corruption fires on (0 = from the start) — lets
    /// the chaos harness run honest warmup rounds, then turn Byzantine
    /// mid-run at a planned round.
    from_step: usize,
}

impl FaultyWorker {
    pub fn new(inner: Box<dyn WorkerLogic>, fault: Fault, seed: u64) -> Self {
        Self::from_step(inner, fault, seed, 0)
    }

    /// Like [`FaultyWorker::new`] but honest until `step >= from_step`.
    pub fn from_step(
        inner: Box<dyn WorkerLogic>,
        fault: Fault,
        seed: u64,
        from_step: usize,
    ) -> Self {
        FaultyWorker { inner, fault, rng: Rng::new(seed), from_step }
    }

    /// Corrupt the payload of one already-encoded frame in place,
    /// preserving byte 0 (the frame tag) and the length.
    fn corrupt(&mut self, msg: &mut [u8], step: usize) {
        if step < self.from_step {
            return;
        }
        match self.fault {
            Fault::RandomBytes => {
                for b in msg.iter_mut().skip(1) {
                    *b = (self.rng.next_u64() & 0xFF) as u8;
                }
            }
            Fault::BitFlip => {
                for b in msg.iter_mut().skip(1) {
                    *b = !*b;
                }
            }
            Fault::Honest => {}
        }
    }
}

impl WorkerLogic for FaultyWorker {
    fn encode(&mut self, grads: &[f32], lr: f32, step: usize) -> Vec<u8> {
        let mut msg = self.inner.encode(grads, lr, step);
        self.corrupt(&mut msg, step);
        msg
    }

    fn apply(&mut self, params: &mut [f32], downlink: &[u8], lr: f32, step: usize) {
        self.inner.apply(params, downlink, lr, step);
    }

    // Chunked wire: corrupt each per-chunk frame the same way (tag and
    // length preserved per chunk), apply honestly — without these
    // overrides the defaults would route through whole-model
    // encode/apply and double-corrupt or break multi-chunk plans.
    fn encode_chunk(&mut self, grads: &[f32], chunk: Chunk, lr: f32, step: usize) -> Vec<u8> {
        let mut msg = self.inner.encode_chunk(grads, chunk, lr, step);
        self.corrupt(&mut msg, step);
        msg
    }

    fn apply_chunk(&mut self, params: &mut [f32], msg: &[u8], chunk: Chunk, lr: f32, step: usize) {
        self.inner.apply_chunk(params, msg, chunk, lr, step);
    }

    // Local steps and the momentum probe are worker-local (nothing on
    // the wire to corrupt): delegate so wrapping a local-steps strategy
    // keeps its cadence and the drift benches keep their probe.
    fn local_step(&mut self, params: &mut [f32], grads: &[f32], lr: f32, step: usize) {
        self.inner.local_step(params, grads, lr, step);
    }

    fn momentum(&self) -> Option<&[f32]> {
        self.inner.momentum()
    }

    // An abstained sync window ships nothing — there is no frame to
    // corrupt. Delegate so the inner strategy keeps its abstention
    // semantics (e.g. the local-steps vote carry) instead of the
    // default encode-and-drop, which would discard carried votes.
    fn abstain_sync(&mut self, grads: &[f32], lr: f32, step: usize) {
        self.inner.abstain_sync(grads, lr, step);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::dist::{by_name, run_round, StrategyHyper};
    use crate::util::Rng;

    #[test]
    fn faulty_frames_keep_tag_and_length() {
        let hp = StrategyHyper::default();
        let d = 123;
        for name in ["d-lion-mavo", "g-lion", "terngrad"] {
            let strat = by_name(name, &hp).unwrap();
            let mut honest = strat.make_worker(0, 1, d);
            let mut faulty =
                FaultyWorker::new(strat.make_worker(0, 1, d), Fault::RandomBytes, 99);
            let mut g = vec![0.0f32; d];
            Rng::new(1).fill_normal(&mut g, 1.0);
            let a = honest.encode(&g, 1e-3, 0);
            let b = faulty.encode(&g, 1e-3, 0);
            assert_eq!(a.len(), b.len(), "{name}: length must be preserved");
            assert_eq!(a[0], b[0], "{name}: tag must be preserved");
            assert_ne!(a[1..], b[1..], "{name}: payload must actually be corrupted");
        }
    }

    #[test]
    fn vote_bounds_byzantine_influence_on_replicas() {
        // One corrupt worker among an odd majority: the round still
        // completes and honest replicas stay bit-identical.
        let hp = StrategyHyper::default();
        let (d, n) = (64, 5);
        let strat = by_name("d-lion-mavo", &hp).unwrap();
        let mut workers: Vec<Box<dyn WorkerLogic>> =
            (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let honest = std::mem::replace(&mut workers[0], strat.make_worker(0, n, d));
        workers[0] = Box::new(FaultyWorker::new(honest, Fault::RandomBytes, 7));
        let mut server = strat.make_server(n, d);
        let mut params: Vec<Vec<f32>> = vec![vec![0.1f32; d]; n];
        let mut rng = Rng::new(2);
        for step in 0..10 {
            let grads: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut g = vec![0.0f32; d];
                    rng.fill_normal(&mut g, 1.0);
                    g
                })
                .collect();
            run_round(&mut workers, server.as_mut(), &mut params, &grads, 1e-2, step);
        }
        for w in 2..n {
            assert_eq!(params[1], params[w], "honest replicas diverged");
        }
        assert!(params[1].iter().all(|p| p.is_finite()));
    }
}
