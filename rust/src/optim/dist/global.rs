//! Global baselines (G-Lion / G-AdamW / G-SGD): dense f32 gradients up,
//! dense f32 mean down — the paper's 32d/32d accuracy references.
//!
//! The server is a stateless averager; every worker runs an identical
//! replica of the single-node [`Optimizer`] on the broadcast mean, which
//! keeps parameters bit-identical across workers (the same replicated-
//! parameter invariant the 1-bit strategies satisfy) while reusing the
//! [`crate::optim`] implementations unchanged.

use super::{
    read_u16, Chunk, ChunkPlan, Chunking, ServerLogic, Strategy, StrategyHyper, WorkerLogic,
    TAG_DENSE, TAG_DENSE_SUM,
};
use crate::comm::{chunked, dense};

/// Single-allocation dense frame: `[TAG_DENSE][f32 payload]` laid in
/// place with the vectorized `dense::pack_into` — no intermediate
/// payload `Vec` + copy like the generic `frame()` helper.
fn dense_frame(values: &[f32]) -> Vec<u8> {
    let mut msg = vec![0u8; 1 + dense::packed_len(values.len())];
    msg[0] = TAG_DENSE;
    dense::pack_into(values, &mut msg[1..]);
    msg
}
use crate::optim::adamw::AdamW;
use crate::optim::lion::Lion;
use crate::optim::sgd::SgdMomentum;
use crate::optim::{AdamWParams, LionParams, Optimizer};

/// Which single-node optimizer the workers replicate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GlobalOpt {
    Lion,
    AdamW,
    Sgd,
}

/// Global dense-gradient strategy (factory).
pub struct Global {
    pub opt: GlobalOpt,
    pub hp: StrategyHyper,
}

impl Global {
    pub fn new(opt: GlobalOpt, hp: StrategyHyper) -> Self {
        Global { opt, hp }
    }

    fn build_optimizer(&self, dim: usize) -> Box<dyn Optimizer> {
        match self.opt {
            GlobalOpt::Lion => Box::new(Lion::new(
                dim,
                LionParams {
                    beta1: self.hp.beta1,
                    beta2: self.hp.beta2,
                    weight_decay: self.hp.weight_decay,
                },
            )),
            GlobalOpt::AdamW => Box::new(AdamW::new(
                dim,
                AdamWParams {
                    weight_decay: self.hp.weight_decay,
                    ..Default::default()
                },
            )),
            GlobalOpt::Sgd => Box::new(SgdMomentum::new(
                dim,
                self.hp.sgd_momentum,
                self.hp.weight_decay,
            )),
        }
    }
}

struct GlobalWorker {
    opt: Box<dyn Optimizer>,
    mean_grad: Vec<f32>,
}

impl WorkerLogic for GlobalWorker {
    fn encode(&mut self, grads: &[f32], _lr: f32, _step: usize) -> Vec<u8> {
        dense_frame(grads)
    }

    fn apply(&mut self, params: &mut [f32], downlink: &[u8], lr: f32, _step: usize) {
        assert_eq!(downlink[0], TAG_DENSE, "global strategies expect dense downlinks");
        dense::unpack_into(&downlink[1..], &mut self.mean_grad);
        self.opt.step(params, &self.mean_grad, lr);
    }

    fn encode_chunk(&mut self, grads: &[f32], chunk: Chunk, _lr: f32, _step: usize) -> Vec<u8> {
        dense_frame(&grads[chunk.range()])
    }

    /// Zero-copy chunked assembly: lay every chunk's dense frame
    /// directly into the tag-15 envelope (`chunked::pack_into` skeleton
    /// + analytic-offset `dense::pack_into` per range), so chunked and
    /// mixed `RoundEngine` rounds hit the vector pack kernel with one
    /// allocation per round instead of one `Vec` per chunk plus an
    /// envelope copy. Byte-identical to the collect-then-pack default.
    fn encode_planned(&mut self, grads: &[f32], plan: &ChunkPlan, lr: f32, step: usize) -> Vec<u8> {
        if plan.is_single() {
            return self.encode(grads, lr, step);
        }
        let lens: Vec<usize> = plan.chunks().map(|c| 1 + dense::packed_len(c.len())).collect();
        let mut buf = Vec::new();
        let ranges = chunked::pack_into(&mut buf, &lens);
        let views = chunked::split_ranges_mut(&mut buf, &ranges);
        for (view, c) in views.into_iter().zip(plan.chunks()) {
            view[0] = TAG_DENSE;
            dense::pack_into(&grads[c.range()], &mut view[1..]);
        }
        buf
    }

    /// Ranged apply: decode the chunk's dense mean and advance the
    /// replicated optimizer over just that slice. Per-step scalar state
    /// (AdamW's bias-correction counter) advances on the first chunk
    /// *this worker logic* serves each round — `chunk.index == 0` is
    /// arm-local under a mixed per-chunk assignment, so a dense arm
    /// that owns no range starting at offset 0 still counts its steps.
    fn apply_chunk(&mut self, params: &mut [f32], msg: &[u8], chunk: Chunk, lr: f32, _step: usize) {
        assert_eq!(msg[0], TAG_DENSE, "global strategies expect dense downlinks");
        if chunk.index == 0 {
            self.opt.begin_step();
        }
        let len = chunk.len();
        dense::unpack_into(&msg[1..], &mut self.mean_grad[..len]);
        self.opt.step_range(&mut params[chunk.range()], &self.mean_grad[..len], lr, chunk.start);
    }
}

/// Stateless dense averager over dense f32 uplinks.
pub(crate) struct DenseAvgServer {
    nworkers: usize,
    acc: Vec<f32>,
}

impl DenseAvgServer {
    pub(crate) fn new(nworkers: usize, dim: usize) -> Self {
        DenseAvgServer { nworkers, acc: vec![0.0; dim] }
    }

    /// Zero the accumulator and sum the dense uplinks into it (worker
    /// order — the f32 accumulation order every path shares).
    fn accumulate_uplinks<'a>(&mut self, uplinks: impl Iterator<Item = &'a [u8]>) {
        self.acc.iter_mut().for_each(|a| *a = 0.0);
        for up in uplinks {
            assert_eq!(up[0], TAG_DENSE, "dense server expects dense uplinks");
            dense::accumulate(&up[1..], &mut self.acc);
        }
    }

    /// Scale the accumulated sum to the mean over `voters` contributors
    /// and frame it. Elastic rounds pass the arrived count — the mean
    /// rescales to the quorum; lockstep passes `nworkers`.
    fn finish_mean(&mut self, voters: usize) -> Vec<u8> {
        let inv = 1.0 / voters as f32;
        for a in self.acc.iter_mut() {
            *a *= inv;
        }
        dense_frame(&self.acc)
    }

    /// Frame the accumulated sum as a tag-14 partial covering `voters`
    /// (single allocation, payload laid in place at offset 3).
    fn sum_partial(&self, voters: usize) -> Vec<u8> {
        let mut msg = vec![0u8; 3 + dense::packed_len(self.acc.len())];
        msg[0] = TAG_DENSE_SUM;
        msg[1..3].copy_from_slice(&(voters as u16).to_le_bytes());
        dense::pack_into(&self.acc, &mut msg[3..]);
        msg
    }

    /// Sum tag-14 group partials into the accumulator; returns the
    /// total contributor count the partials self-describe.
    fn sum_partials<'a>(&mut self, partials: impl Iterator<Item = &'a [u8]>) -> usize {
        self.acc.iter_mut().for_each(|a| *a = 0.0);
        let mut total = 0usize;
        for p in partials {
            assert_eq!(p[0], TAG_DENSE_SUM, "dense fold expects dense-sum partials");
            total += read_u16(p, 1) as usize;
            dense::accumulate(&p[3..], &mut self.acc);
        }
        total
    }

    /// Sum tag-14 group partials into the accumulator and finish
    /// (lockstep: partials must cover every worker).
    fn fold_partials<'a>(&mut self, partials: impl Iterator<Item = &'a [u8]>) -> Vec<u8> {
        let total = self.sum_partials(partials);
        assert_eq!(total, self.nworkers, "group partials must cover all workers");
        self.finish_mean(total)
    }
}

impl ServerLogic for DenseAvgServer {
    fn aggregate(&mut self, uplinks: &[Vec<u8>], _lr: f32, _step: usize) -> Vec<u8> {
        assert_eq!(uplinks.len(), self.nworkers, "uplink count mismatch");
        self.accumulate_uplinks(uplinks.iter().map(|u| u.as_slice()));
        self.finish_mean(self.nworkers)
    }

    /// Chunked hot path: per-chunk instances average their chunk's
    /// dense frames straight from the envelope views.
    fn aggregate_chunk(&mut self, uplinks: &[&[u8]], _chunk: Chunk, _lr: f32, _step: usize) -> Vec<u8> {
        assert_eq!(uplinks.len(), self.nworkers, "uplink count mismatch");
        self.accumulate_uplinks(uplinks.iter().copied());
        self.finish_mean(self.nworkers)
    }

    /// Group hop: ship the group's f32 partial gradient sum (tag 14) —
    /// 32 bits/param per *group* instead of per worker, which is where
    /// hierarchical aggregation pays off for the dense family.
    /// Layout: `[TAG_DENSE_SUM][g: u16 LE][dense f32 payload]`.
    fn partial(&mut self, uplinks: &[Vec<u8>], _lr: f32, _step: usize) -> Vec<u8> {
        assert_eq!(uplinks.len(), self.nworkers, "group uplink count mismatch");
        self.accumulate_uplinks(uplinks.iter().map(|u| u.as_slice()));
        self.sum_partial(self.nworkers)
    }

    fn partial_chunk(&mut self, uplinks: &[&[u8]], _chunk: Chunk, _lr: f32, _step: usize) -> Vec<u8> {
        assert_eq!(uplinks.len(), self.nworkers, "group uplink count mismatch");
        self.accumulate_uplinks(uplinks.iter().copied());
        self.sum_partial(self.nworkers)
    }

    /// Root hop: add the group sums (left-to-right, the same f32
    /// accumulation order the flat server uses within a group) and
    /// broadcast the mean over the full worker count.
    fn fold(&mut self, partials: &[Vec<u8>], _lr: f32, _step: usize) -> Vec<u8> {
        self.fold_partials(partials.iter().map(|p| p.as_slice()))
    }

    fn fold_chunk(&mut self, partials: &[&[u8]], _chunk: Chunk, _lr: f32, _step: usize) -> Vec<u8> {
        self.fold_partials(partials.iter().copied())
    }

    /// Elastic rounds: the mean rescales to the arrived count — sum
    /// over Q, divide by Q. At Q == nworkers this is byte-identical to
    /// the lockstep aggregate.
    fn aggregate_quorum(&mut self, uplinks: &[&[u8]], _lr: f32, _step: usize) -> Vec<u8> {
        let q = uplinks.len();
        assert!(q >= 1 && q <= self.nworkers, "quorum {q} out of range 1..={}", self.nworkers);
        self.accumulate_uplinks(uplinks.iter().copied());
        self.finish_mean(q)
    }

    fn partial_quorum(&mut self, uplinks: &[&[u8]], _lr: f32, _step: usize) -> Vec<u8> {
        let q = uplinks.len();
        assert!(q >= 1 && q <= self.nworkers, "quorum {q} out of range 1..={}", self.nworkers);
        self.accumulate_uplinks(uplinks.iter().copied());
        self.sum_partial(q)
    }

    fn fold_quorum(&mut self, partials: &[&[u8]], _lr: f32, _step: usize) -> Vec<u8> {
        let total = self.sum_partials(partials.iter().copied());
        assert!(
            total >= 1 && total <= self.nworkers,
            "folded quorum {total} out of range 1..={}",
            self.nworkers
        );
        self.finish_mean(total)
    }
}

impl Strategy for Global {
    fn name(&self) -> String {
        match self.opt {
            GlobalOpt::Lion => "g-lion".into(),
            GlobalOpt::AdamW => "g-adamw".into(),
            GlobalOpt::Sgd => "g-sgd".into(),
        }
    }

    fn make_worker(&self, _worker: usize, _nworkers: usize, dim: usize) -> Box<dyn WorkerLogic> {
        Box::new(GlobalWorker {
            opt: self.build_optimizer(dim),
            mean_grad: vec![0.0; dim],
        })
    }

    fn make_server(&self, nworkers: usize, dim: usize) -> Box<dyn ServerLogic> {
        Box::new(DenseAvgServer::new(nworkers, dim))
    }

    fn uplink_bits_per_param(&self, _nworkers: usize) -> f64 {
        32.0
    }

    fn downlink_bits_per_param(&self, _nworkers: usize) -> f64 {
        32.0
    }

    /// Dense f32 payloads split at any element boundary.
    fn chunking(&self) -> Chunking {
        Chunking::Native { align: 1 }
    }

    /// Aggregator→root hop ships one f32 partial sum per group.
    fn partial_bits_per_param(&self, _group_size: usize) -> f64 {
        32.0
    }

    /// The dense mean rescales to whatever quorum arrived.
    fn quorum(&self) -> super::QuorumSupport {
        super::QuorumSupport::Rescaled
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::chunked;
    use crate::optim::dist::frame;
    use crate::util::Rng;

    #[test]
    fn encode_planned_matches_collect_then_pack() {
        // The zero-copy envelope assembly must be byte-identical to the
        // default path: encode each chunk, then chunked::pack.
        let hp = StrategyHyper::default();
        let strat = Global::new(GlobalOpt::Lion, hp);
        let d = 103;
        let mut rng = Rng::new(0x63);
        let mut g = vec![0.0f32; d];
        rng.fill_normal(&mut g, 1.0);
        let plan = ChunkPlan::new(d, 17, 1);
        assert!(!plan.is_single());
        let mut w = strat.make_worker(0, 2, d);
        let fast = w.encode_planned(&g, &plan, 1e-3, 0);
        let frames: Vec<Vec<u8>> =
            plan.chunks().map(|c| w.encode_chunk(&g, c, 1e-3, 0)).collect();
        assert_eq!(fast, chunked::pack(&frames));
        // single-chunk plans stay a bare tag-1 frame
        let whole = ChunkPlan::single(d);
        assert_eq!(w.encode_planned(&g, &whole, 1e-3, 0), w.encode(&g, 1e-3, 0));
    }

    #[test]
    fn one_worker_global_equals_single_node_optimizer() {
        let hp = StrategyHyper { weight_decay: 0.01, ..Default::default() };
        let d = 31;
        for opt in [GlobalOpt::Lion, GlobalOpt::AdamW, GlobalOpt::Sgd] {
            let strat = Global::new(opt, hp);
            let mut worker = strat.make_worker(0, 1, d);
            let mut server = strat.make_server(1, d);
            let mut reference = strat.build_optimizer(d);
            let mut pa = vec![0.4f32; d];
            let mut pb = pa.clone();
            let mut rng = Rng::new(0x61);
            for step in 0..25 {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                let up = worker.encode(&g, 0.02, step);
                let down = server.aggregate(&[up], 0.02, step);
                worker.apply(&mut pa, &down, 0.02, step);
                reference.step(&mut pb, &g, 0.02);
            }
            assert_eq!(pa, pb, "{opt:?} diverged from its single-node optimizer");
        }
    }

    #[test]
    fn one_group_dense_fold_is_bitwise_flat() {
        // partial over the single full group + fold must reproduce the
        // flat aggregate byte-for-byte (same f32 accumulation order;
        // the root adds the partial into a zeroed accumulator, which is
        // exact because a left-to-right f32 sum is never -0.0).
        let (n, d) = (4, 57);
        let mut rng = Rng::new(0x62);
        let ups: Vec<Vec<u8>> = (0..n)
            .map(|_| {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                frame(TAG_DENSE, &dense::pack(&g))
            })
            .collect();
        let mut flat = DenseAvgServer::new(n, d);
        let mut group = DenseAvgServer::new(n, d);
        let mut root = DenseAvgServer::new(n, d);
        let reference = flat.aggregate(&ups, 1e-3, 0);
        let partial = group.partial(&ups, 1e-3, 0);
        assert_eq!(partial[0], TAG_DENSE_SUM);
        assert_eq!(root.fold(&[partial], 1e-3, 0), reference);
    }

    #[test]
    fn server_broadcasts_exact_mean() {
        let d = 10;
        let mut server = DenseAvgServer::new(2, d);
        let a: Vec<f32> = (0..d).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..d).map(|i| -(i as f32) + 1.0).collect();
        let ups = vec![
            frame(TAG_DENSE, &dense::pack(&a)),
            frame(TAG_DENSE, &dense::pack(&b)),
        ];
        let down = server.aggregate(&ups, 1e-3, 0);
        let mean = dense::unpack(&down[1..]);
        for (m, (x, y)) in mean.iter().zip(a.iter().zip(&b)) {
            assert_eq!(*m, (x + y) / 2.0);
        }
    }
}
