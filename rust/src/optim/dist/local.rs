//! Local-steps Distributed Lion (`d-lion-local(H)`) — the "Distributed
//! Sign Momentum with Local Steps" direction (Yu et al. 2024): take H
//! local Lion steps between communication rounds and ship the **sign of
//! the accumulated update**, amortizing the 1-bit frame to 1/H
//! bits/param per optimizer step.
//!
//! One H-step window on worker i (base x̄ = the replicated parameters at
//! the last sync point, bitwise equal across workers):
//!
//! ```text
//! for t in window:                  # H steps, the last one syncs
//!     u_t = sign(β1·m_t + (1−β1)·g_t)    # the usual Lion update
//!     a  += u_t                          # accumulate the binary votes
//!     m  ← β2·m_t + (1−β2)·g_t           # momentum (every step)
//!     if t is not the sync step:
//!         x ← x − ε_t·(u_t + λx)         # LOCAL exploration step
//! send sign(a)                           # 1-bit frame, Λ = Σ_window ε_t
//! recv Δ = MajorityVote_i(sign(a_i))     # the flat d-lion-mavo server
//! x ← x̄ − Λ·(Δ + λ·x̄);  x̄ ← x           # reconcile: replicas re-equal
//! ```
//!
//! The local steps explore (they move the points at which gradients are
//! sampled and feed the momentum) but the *global* trajectory advances
//! only by the aggregated sign step with the window's summed learning
//! rate — so replicas are bit-identical at every sync point, which is
//! where the cluster drivers assert the replica invariant. With H = 1
//! there are no local steps, `a = u_t`, and the strategy is bit-exact
//! `d-lion-mavo` (tested below and in `tests/topology_parity.rs`).
//!
//! Wire format: identical to `d-lion-mavo` (tag-1 uplink into the
//! shared sign-vote server, majority-vote downlink) — it is the
//! *cadence* that changes, which is why the analytic Table-1 model
//! divides by H. The server also inherits the exact hierarchical vote
//! partials, so `d-lion-local(H)` composes with
//! [`crate::cluster::topology::Topology::Hierarchical`] for free.

use super::{
    frame, sign_family_downlink_bits, Aggregation, ServerLogic, SignVoteServer, Strategy,
    UpdateDecoder, WorkerLogic, TAG_SIGN,
};
use crate::comm::sign;
use crate::optim::lion::{bsign, Lion};
use crate::optim::LionParams;

/// Local-steps Distributed Lion strategy (factory). Registry names
/// `d-lion-local(<H>)` and the bare `d-lion-local` alias (H from
/// `StrategyHyper::local_steps`).
pub struct DLionLocal {
    pub hp: LionParams,
    /// window length H ≥ 1: one wire round every H optimizer steps.
    pub h: usize,
}

impl DLionLocal {
    pub fn new(hp: LionParams, h: usize) -> Self {
        assert!(h >= 1, "d-lion-local needs H >= 1");
        DLionLocal { hp, h }
    }
}

struct LocalWorker {
    lion: Lion,
    weight_decay: f32,
    /// accumulated binary votes over the current window, each ∈ [−H, H]
    acc: Vec<i32>,
    /// replicated parameters at the last sync point (the window base)
    base: Vec<f32>,
    /// Σ of the window's learning rates (including the sync step's)
    lr_sum: f32,
    /// local steps taken this window (0 ⇒ base not yet captured)
    local_taken: usize,
    /// scratch for the packed sign(acc) frame
    signs: Vec<i8>,
    /// the window that just closed was *abstained* (its uplink never
    /// reached the wire): keep `acc` across the reconciling `apply` so
    /// the votes fold, whole, into the next shipped frame — the exact
    /// vote-level analogue of the chaos driver's `StragglerFold`
    carried: bool,
    decoder: UpdateDecoder,
}

impl WorkerLogic for LocalWorker {
    fn local_step(&mut self, params: &mut [f32], grads: &[f32], lr: f32, _step: usize) {
        if self.local_taken == 0 {
            // window start: params are the replicated sync-point state
            self.base.copy_from_slice(params);
        }
        self.local_taken += 1;
        self.lr_sum += lr;
        let b1 = self.lion.hp.beta1;
        let b2 = self.lion.hp.beta2;
        let wd = self.weight_decay;
        // fused: vote accumulation + local Lion step + momentum advance
        for (((p, m), &g), a) in params
            .iter_mut()
            .zip(self.lion.momentum.iter_mut())
            .zip(grads)
            .zip(self.acc.iter_mut())
        {
            let u = bsign(b1 * *m + (1.0 - b1) * g);
            *a += u as i32;
            *p -= lr * (u + wd * *p);
            *m = b2 * *m + (1.0 - b2) * g;
        }
    }

    fn encode(&mut self, grads: &[f32], lr: f32, _step: usize) -> Vec<u8> {
        // The sync step contributes its vote and momentum advance but no
        // local parameter step — its update ships inside the aggregate.
        self.lr_sum += lr;
        let b1 = self.lion.hp.beta1;
        let b2 = self.lion.hp.beta2;
        for (((m, &g), a), s) in self
            .lion
            .momentum
            .iter_mut()
            .zip(grads)
            .zip(self.acc.iter_mut())
            .zip(self.signs.iter_mut())
        {
            let u = bsign(b1 * *m + (1.0 - b1) * g);
            *a += u as i32;
            // binarized like bsign: a zero vote sum ships +1, keeping
            // the uplink strictly 1-bit
            *s = if *a >= 0 { 1 } else { -1 };
            *m = b2 * *m + (1.0 - b2) * g;
        }
        self.carried = false;
        frame(TAG_SIGN, &sign::pack(&self.signs))
    }

    fn abstain_sync(&mut self, grads: &[f32], lr: f32, _step: usize) {
        // Exactly `encode`'s state bookkeeping — the sync step's vote,
        // momentum advance, and Λ contribution — minus the frame. The
        // window's votes stay in `acc` (carried) so the next shipped
        // uplink is sign(votes of every window since the last send):
        // the window folds whole instead of being dropped.
        self.lr_sum += lr;
        let b1 = self.lion.hp.beta1;
        let b2 = self.lion.hp.beta2;
        for ((m, &g), a) in
            self.lion.momentum.iter_mut().zip(grads).zip(self.acc.iter_mut())
        {
            let u = bsign(b1 * *m + (1.0 - b1) * g);
            *a += u as i32;
            *m = b2 * *m + (1.0 - b2) * g;
        }
        self.carried = true;
    }

    fn apply(&mut self, params: &mut [f32], downlink: &[u8], _lr: f32, _step: usize) {
        if self.local_taken == 0 {
            // H = 1 (or a degenerate 1-step window): no local step ran,
            // so the current params *are* the window base.
            self.base.copy_from_slice(params);
        }
        let update = self.decoder.decode(downlink);
        // rewind the local exploration, apply the aggregate once with
        // the window's summed learning rate
        params.copy_from_slice(&self.base);
        Lion::apply_aggregated(params, update, self.lr_sum, self.weight_decay);
        self.local_taken = 0;
        self.lr_sum = 0.0;
        if self.carried {
            // abstained window: the votes survive into the next shipped
            // uplink; only the window Λ and local-step count reset (all
            // replicas applied the same aggregate with the same Λ, so
            // the replica invariant is untouched).
            return;
        }
        self.acc.iter_mut().for_each(|a| *a = 0);
    }

    fn momentum(&self) -> Option<&[f32]> {
        Some(&self.lion.momentum)
    }
}

impl Strategy for DLionLocal {
    fn name(&self) -> String {
        format!("d-lion-local({})", self.h)
    }

    fn make_worker(&self, _worker: usize, _nworkers: usize, dim: usize) -> Box<dyn WorkerLogic> {
        Box::new(LocalWorker {
            lion: Lion::new(dim, self.hp),
            weight_decay: self.hp.weight_decay,
            acc: vec![0; dim],
            base: vec![0.0; dim],
            lr_sum: 0.0,
            local_taken: 0,
            signs: vec![0; dim],
            carried: false,
            decoder: UpdateDecoder::new(dim),
        })
    }

    fn make_server(&self, nworkers: usize, dim: usize) -> Box<dyn ServerLogic> {
        Box::new(SignVoteServer::new(nworkers, dim, Aggregation::MajorityVote))
    }

    /// Amortized over the window: one 1-bit frame per H steps.
    fn uplink_bits_per_param(&self, _nworkers: usize) -> f64 {
        1.0 / self.h as f64
    }

    fn downlink_bits_per_param(&self, nworkers: usize) -> f64 {
        sign_family_downlink_bits(Aggregation::MajorityVote, nworkers) / self.h as f64
    }

    fn local_steps(&self) -> usize {
        self.h
    }

    /// Sign votes tolerate any voter count (abstention-exact).
    fn quorum(&self) -> super::QuorumSupport {
        super::QuorumSupport::Exact
    }
}

#[cfg(test)]
mod tests {
    use super::super::{by_name, run_round, DLion, StrategyHyper};
    use super::*;
    use crate::util::Rng;

    fn rand_grads(rng: &mut Rng, n: usize, d: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                g
            })
            .collect()
    }

    #[test]
    fn h1_is_bitwise_dlion_mavo() {
        // With H = 1 every step syncs, the vote accumulator holds one
        // vote, and the trajectory must equal d-lion-mavo bit-for-bit
        // (frames AND parameters).
        let hp = LionParams { beta1: 0.9, beta2: 0.99, weight_decay: 0.01 };
        let (d, n) = (67, 3);
        let local = DLionLocal::new(hp, 1);
        let mavo = DLion::new(hp, Aggregation::MajorityVote);
        let mut wa: Vec<_> = (0..n).map(|i| local.make_worker(i, n, d)).collect();
        let mut wb: Vec<_> = (0..n).map(|i| mavo.make_worker(i, n, d)).collect();
        let mut sa = local.make_server(n, d);
        let mut sb = mavo.make_server(n, d);
        let mut pa: Vec<Vec<f32>> = vec![vec![0.3f32; d]; n];
        let mut pb = pa.clone();
        let mut rng = Rng::new(0x10C);
        for step in 0..40 {
            let grads = rand_grads(&mut rng, n, d);
            let ups_a: Vec<Vec<u8>> =
                wa.iter_mut().zip(&grads).map(|(w, g)| w.encode(g, 0.01, step)).collect();
            let ups_b: Vec<Vec<u8>> =
                wb.iter_mut().zip(&grads).map(|(w, g)| w.encode(g, 0.01, step)).collect();
            assert_eq!(ups_a, ups_b, "step {step}: H=1 frames must equal d-lion-mavo");
            let down_a = sa.aggregate(&ups_a, 0.01, step);
            let down_b = sb.aggregate(&ups_b, 0.01, step);
            assert_eq!(down_a, down_b);
            for (w, p) in wa.iter_mut().zip(pa.iter_mut()) {
                w.apply(p, &down_a, 0.01, step);
            }
            for (w, p) in wb.iter_mut().zip(pb.iter_mut()) {
                w.apply(p, &down_b, 0.01, step);
            }
            assert_eq!(pa, pb, "step {step}");
        }
    }

    #[test]
    fn replicas_diverge_locally_and_reconcile_at_sync() {
        let hp = LionParams { beta1: 0.9, beta2: 0.99, weight_decay: 0.005 };
        let (d, n, h) = (50, 3, 4);
        let strat = DLionLocal::new(hp, h);
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut server = strat.make_server(n, d);
        let mut params: Vec<Vec<f32>> = vec![vec![0.2f32; d]; n];
        let mut rng = Rng::new(0x10D);
        for step in 0..16 {
            let grads = rand_grads(&mut rng, n, d);
            if (step + 1) % h == 0 {
                run_round(&mut workers, server.as_mut(), &mut params, &grads, 0.01, step);
                for w in 1..n {
                    assert_eq!(params[0], params[w], "sync step {step}: replicas must agree");
                }
            } else {
                for ((w, p), g) in workers.iter_mut().zip(params.iter_mut()).zip(&grads) {
                    w.local_step(p, g, 0.01, step);
                }
                // per-worker gradients drive the local replicas apart
                assert!(
                    (1..n).any(|w| params[w] != params[0]),
                    "local step {step}: replicas should explore independently"
                );
            }
        }
    }

    #[test]
    fn window_applies_summed_learning_rate_from_the_base() {
        // One window with H = 2 and a single worker: the final state
        // must be x̄ − Λ·(Δ + λ·x̄) with Λ = lr0 + lr1 and Δ the worker's
        // own accumulated-sign vote (N = 1 majority vote).
        let hp = LionParams { beta1: 0.9, beta2: 0.99, weight_decay: 0.1 };
        let d = 33;
        let strat = DLionLocal::new(hp, 2);
        let mut worker = strat.make_worker(0, 1, d);
        let mut server = strat.make_server(1, d);
        let mut rng = Rng::new(0x10E);
        let g0 = rand_grads(&mut rng, 1, d).pop().unwrap();
        let g1 = rand_grads(&mut rng, 1, d).pop().unwrap();
        let base: Vec<f32> = (0..d).map(|i| 0.1 * (i as f32 - 16.0)).collect();
        let mut params = base.clone();
        let (lr0, lr1) = (0.02f32, 0.01f32);
        worker.local_step(&mut params, &g0, lr0, 0);
        let up = worker.encode(&g1, lr1, 1);
        let down = server.aggregate(&[up.clone()], lr1, 1);
        worker.apply(&mut params, &down, lr1, 1);
        // reference: replay the vote from the frame
        let votes = sign::unpack(&up[1..], d);
        let lam = lr0 + lr1;
        for ((&p, &b), &v) in params.iter().zip(&base).zip(&votes) {
            let expect = b - lam * (v as f32 + hp.weight_decay * b);
            assert_eq!(p, expect);
        }
    }

    #[test]
    fn abstained_window_votes_carry_into_the_next_shipped_frame() {
        // Two workers, H = 2, four steps (two windows). Worker 1
        // abstains on the first sync step (its frame never ships; the
        // round closes over worker 0 alone) — its next shipped frame
        // must be sign(votes of BOTH windows), checked against an i32
        // oracle replaying the vote/momentum recursion, and the
        // replicas must still agree at every sync point.
        let hp = LionParams { beta1: 0.9, beta2: 0.99, weight_decay: 0.01 };
        let (d, n, h) = (41, 2, 2);
        let strat = DLionLocal::new(hp, h);
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut server = strat.make_server(n, d);
        let mut params: Vec<Vec<f32>> = vec![vec![0.15f32; d]; n];
        let mut rng = Rng::new(0x10F);
        let grads: Vec<Vec<Vec<f32>>> = (0..4).map(|_| rand_grads(&mut rng, n, d)).collect();

        // oracle for worker 1: replay momentum + vote accumulation
        let mut m_ref = vec![0.0f32; d];
        let mut acc_ref = vec![0i32; d];
        let mut vote = |g: &[f32]| {
            for ((m, &gi), a) in m_ref.iter_mut().zip(g).zip(acc_ref.iter_mut()) {
                let u = bsign(hp.beta1 * *m + (1.0 - hp.beta1) * gi);
                *a += u as i32;
                *m = hp.beta2 * *m + (1.0 - hp.beta2) * gi;
            }
        };

        // window 1: local step, then worker 1 abstains at the sync step
        for (i, (w, p)) in workers.iter_mut().zip(params.iter_mut()).enumerate() {
            w.local_step(p, &grads[0][i], 0.01, 0);
        }
        vote(&grads[0][1]);
        vote(&grads[1][1]);
        let up0 = workers[0].encode(&grads[1][0], 0.01, 1);
        workers[1].abstain_sync(&grads[1][1], 0.01, 1);
        let down = server.aggregate_quorum(&[up0.as_slice()], 0.01, 1);
        for (w, p) in workers.iter_mut().zip(params.iter_mut()) {
            w.apply(p, &down, 0.01, 1);
        }
        assert_eq!(params[0], params[1], "abstaining replica must still reconcile");

        // window 2: both ship; worker 1's frame covers both windows
        for (i, (w, p)) in workers.iter_mut().zip(params.iter_mut()).enumerate() {
            w.local_step(p, &grads[2][i], 0.01, 2);
        }
        vote(&grads[2][1]);
        vote(&grads[3][1]);
        let _up0 = workers[0].encode(&grads[3][0], 0.01, 3);
        let up1 = workers[1].encode(&grads[3][1], 0.01, 3);
        let shipped = sign::unpack(&up1[1..], d);
        for (i, (&s, &a)) in shipped.iter().zip(&acc_ref).enumerate() {
            let expect = if a >= 0 { 1i8 } else { -1 };
            assert_eq!(s, expect, "lane {i}: carried vote sum {a} must drive the sign");
        }
        // and the carry is consumed: votes from before the ship are gone
        let down2 = server.aggregate_quorum(&[up1.as_slice()], 0.01, 3);
        for (w, p) in workers.iter_mut().zip(params.iter_mut()) {
            w.apply(p, &down2, 0.01, 3);
        }
        let up1_fresh = workers[1].encode(&grads[0][1], 0.01, 5);
        let mut m_solo = m_ref.clone();
        let fresh: Vec<i8> = grads[0][1]
            .iter()
            .zip(m_solo.iter_mut())
            .map(|(&gi, m)| {
                let u = bsign(hp.beta1 * *m + (1.0 - hp.beta1) * gi);
                *m = hp.beta2 * *m + (1.0 - hp.beta2) * gi;
                u
            })
            .collect();
        assert_eq!(
            sign::unpack(&up1_fresh[1..], d),
            fresh,
            "after a shipped window the accumulator must restart from zero"
        );
    }

    #[test]
    fn amortized_bits_model_divides_by_h() {
        let hp = StrategyHyper::default();
        for h in [1usize, 2, 4, 8] {
            let s = by_name(&format!("d-lion-local({h})"), &hp).unwrap();
            assert_eq!(s.local_steps(), h);
            assert_eq!(s.uplink_bits_per_param(3), 1.0 / h as f64);
            assert_eq!(s.downlink_bits_per_param(3), 1.0 / h as f64);
            assert_eq!(s.downlink_bits_per_param(4), 1.6 / h as f64);
        }
    }

    #[test]
    fn name_round_trips_through_registry() {
        let hp = StrategyHyper::default();
        let s = by_name("d-lion-local(6)", &hp).unwrap();
        assert_eq!(s.name(), "d-lion-local(6)");
        let again = by_name(&s.name(), &hp).unwrap();
        assert_eq!(again.local_steps(), 6);
    }
}
