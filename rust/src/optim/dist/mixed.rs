//! Mixed-wire strategy: a different registry arm per chunk and per
//! link, over the tag-15 chunked wire surface.
//!
//! The paper's core claim is a performance-vs-bandwidth trade-off:
//! binary D-Lion votes where bits are scarce, richer frames where they
//! are not. The chunked wire API already lets every native family
//! encode, aggregate, and apply one contiguous parameter range at a
//! time — so heterogeneous wires need no format surgery, only a
//! *selector* that assigns arms. [`MixedStrategy`] is that selector, in
//! two modes sharing one registry syntax:
//!
//! * **Per-chunk (static)** — `mixed(<arm>[*<weight>], ...)`: the
//!   [`super::ChunkPlan`] chunks are dealt to the arms in a weighted
//!   cycle (weights `7,1` ⇒ chunks `0..7 → arm0`, chunk `7 → arm1`,
//!   repeating). Chunk *i* is served by its arm on **every** hop: the
//!   worker edge ships the arm's native frame, and under a hierarchical
//!   topology the aggregator→root hop ships the arm's partial — so one
//!   round's agg hop can carry `intavg` vote partials for seven chunks
//!   and a dense f32 sum for the eighth.
//! * **Per-link (dynamic)** — `mixed(<cheap>@cheap,<rich>@rich)`: the
//!   token bucket of [`super::select`] decides per round whether the
//!   rich arm serves, but with one bucket **per hop**, each accounting
//!   its own traffic against [`super::StrategyHyper::link_budget`]: the
//!   worker-edge bucket pays `uplink + downlink` bits/param per worker,
//!   the aggregator bucket pays `partial + broadcast` bits/param per
//!   group. A rich round fires only when *both* hops afford it, so
//!   neither hop's long-run spend ever exceeds the budget (when the
//!   budget affords that hop's cheap cost at all). Workers and every
//!   server instance replay the identical schedule — a pure function of
//!   the budget, the arms' analytic models, and the cluster size — so
//!   no selection bit crosses the wire.
//!
//! Arms must communicate every step (`local_steps() == 1`) and have a
//! native chunked wire format ([`super::Chunking::Native`]); the shared
//! plan aligns to the lcm of the arms' codec alignments, so every arm's
//! chunk payloads still splice bit-exactly into its monolithic frames
//! and the payload-byte accounting stays chunking-invariant
//! ([`crate::comm::chunked::frames_payload_len`] charges one frame head
//! per distinct inner tag).
//!
//! ## Arm-local chunk views
//!
//! Each arm's [`super::WorkerLogic`] holds whole-model state but only
//! ever sees the chunks it owns. The worker wrapper re-indexes each
//! chunk to the arm's local ordinal (`index`/`count` become "k-th of my
//! m chunks"; `start..end` stay global so state and frames keep real
//! parameter coordinates). That is what makes round-start hooks fire
//! per arm — a sparse arm runs its *global* top-k selection on its
//! first owned chunk of the round, a dense arm advances AdamW's
//! bias-correction counter there — and it is why `mixed(a,a)` is
//! bit-exact and payload-byte-identical to plain `a`: with one arm the
//! re-indexing is the identity. Classic-sparse arms (whole-model top-k
//! whose selection clears residual mass wherever it lands —
//! [`Strategy::chunk_local_encode`] is false) are only accepted when
//! **all** arms are identical: `mixed(dgc,dgc)` ships every selected
//! coordinate through some arm and stays exact, while a heterogeneous
//! mix would silently destroy the mass selected in other arms' ranges,
//! so the parser rejects it by name.
//!
//! ## Invariants (pinned in `tests/`)
//!
//! * `mixed(a,a)` ≡ plain `a`: parameters and per-hop payload bytes,
//!   for any chunk size, topology, and driver (`topology_parity.rs`).
//! * Measured bits/param on every hop match the weighted analytic
//!   model when the cycle divides the chunk count
//!   (`table1_regression.rs`).
//! * The per-link selector never exceeds either hop's budget over a
//!   long run, and worker/server schedule replicas stay bitwise in
//!   sync (`property_invariants.rs`).

use super::select::{BucketSchedule, AMORTIZE_HORIZON};
use super::{Chunk, ChunkPlan, Chunking, ServerLogic, Strategy, StrategyHyper, WorkerLogic};
use crate::error::{DlionError, Result};

// ---------------------------------------------------------------------------
// Chunk → arm assignment (static mode)
// ---------------------------------------------------------------------------

/// Deterministic weighted-cyclic map from chunk index to arm index:
/// with weights `w_0..w_{k-1}` (cycle length `W = Σ w_j`), cycle
/// position `p` belongs to the arm whose weight block contains `p` —
/// e.g. weights `[7, 1]` give the "7/8 chunks cheap, 1/8 rich" split.
/// Both ends of the wire derive it from the registry name alone.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Assignment {
    /// arm index per cycle position (length = Σ weights)
    cycle: Vec<usize>,
}

impl Assignment {
    /// Build from per-arm weights (all ≥ 1).
    pub fn new(weights: &[usize]) -> Assignment {
        debug_assert!(!weights.is_empty() && weights.iter().all(|&w| w >= 1));
        let mut cycle = Vec::with_capacity(weights.iter().sum());
        for (arm, &w) in weights.iter().enumerate() {
            for _ in 0..w {
                cycle.push(arm);
            }
        }
        Assignment { cycle }
    }

    /// Cycle length `W = Σ weights`.
    pub fn cycle_len(&self) -> usize {
        self.cycle.len()
    }

    /// The arm serving chunk `chunk_index`.
    pub fn arm(&self, chunk_index: usize) -> usize {
        self.cycle[chunk_index % self.cycle.len()]
    }

    /// 0-based ordinal of `chunk_index` among the chunks its arm owns.
    pub fn local_index(&self, chunk_index: usize) -> usize {
        let w = self.cycle.len();
        let arm = self.arm(chunk_index);
        let per_cycle = self.cycle.iter().filter(|&&a| a == arm).count();
        (chunk_index / w) * per_cycle
            + self.cycle[..chunk_index % w].iter().filter(|&&a| a == arm).count()
    }

    /// Number of chunks `arm` owns in a `total_chunks`-chunk plan.
    pub fn owned(&self, arm: usize, total_chunks: usize) -> usize {
        let w = self.cycle.len();
        let per_cycle = self.cycle.iter().filter(|&&a| a == arm).count();
        (total_chunks / w) * per_cycle
            + self.cycle[..total_chunks % w].iter().filter(|&&a| a == arm).count()
    }

    /// Model-level share of parameters `arm` serves (exact whenever the
    /// cycle length divides the number of equal-size chunks; the
    /// analytic bits/param formulas weight by this).
    pub fn fraction(&self, arm: usize) -> f64 {
        self.cycle.iter().filter(|&&a| a == arm).count() as f64 / self.cycle.len() as f64
    }

    /// Re-index `chunk` to its arm's local view: same global parameter
    /// range, arm-local ordinal and count (so arms see their owned
    /// chunks as a dense 0..m sequence and fire their per-round hooks
    /// on local index 0).
    fn rebase(&self, chunk: Chunk) -> Chunk {
        Chunk {
            index: self.local_index(chunk.index),
            count: self.owned(self.arm(chunk.index), chunk.count).max(1),
            ..chunk
        }
    }
}

// ---------------------------------------------------------------------------
// Per-link dual token bucket (dynamic mode)
// ---------------------------------------------------------------------------

/// Worker-edge round cost of an arm: uplink + downlink bits/param per
/// worker (the same accounting [`super::select::BandwidthAware`] uses).
fn edge_cost(s: &dyn Strategy, nworkers: usize) -> f64 {
    s.uplink_bits_per_param(nworkers) + s.downlink_bits_per_param(nworkers)
}

/// Aggregator-hop round cost of an arm: one partial up + one broadcast
/// down, bits/param per group. `partial_bits_per_param(nworkers)` is the
/// full-cluster partial — an upper bound on any group's partial for the
/// mixable families (⌈log₂(g+1)⌉ and 32-bit sums are monotone in g), so
/// the bucket can be replayed from the cluster size alone and never
/// under-prices the hop.
fn agg_cost(s: &dyn Strategy, nworkers: usize) -> f64 {
    s.partial_bits_per_param(nworkers) + s.downlink_bits_per_param(nworkers)
}

/// Two [`BucketSchedule`]s — one per hop — that fire the rich arm only
/// when *both* hops afford it. Each hop accrues `budget − cheap_cost`
/// net credit per round against its own `rich − cheap` surcharge, so
/// the true-cap argument of [`super::select`] holds per hop: every rich
/// surcharge is fully funded from that hop's banked credit.
#[derive(Clone, Copy, Debug)]
pub struct DualBucket {
    edge: BucketSchedule,
    agg: BucketSchedule,
}

impl DualBucket {
    /// Build the schedule both ends replay: a pure function of the
    /// budget, the two arms' analytic models, and the cluster size.
    pub fn new(budget: f64, cheap: &dyn Strategy, rich: &dyn Strategy, nworkers: usize) -> Self {
        DualBucket {
            edge: BucketSchedule::new(budget, edge_cost(cheap, nworkers), edge_cost(rich, nworkers)),
            agg: BucketSchedule::new(budget, agg_cost(cheap, nworkers), agg_cost(rich, nworkers)),
        }
    }

    /// Advance one round; true when the rich arm serves it.
    pub fn next(&mut self) -> bool {
        self.edge.accrue();
        self.agg.accrue();
        let rich = self.edge.affords() && self.agg.affords();
        self.edge.settle(rich);
        self.agg.settle(rich);
        rich
    }
}

// ---------------------------------------------------------------------------
// The strategy
// ---------------------------------------------------------------------------

enum Mode {
    /// chunk `i` → `arms[assign.arm(i)]`, fixed for the whole run
    PerChunk { weights: Vec<usize>, assign: Assignment },
    /// `arms[cheap]` / `arms[1 - cheap]` selected per round by the
    /// per-hop dual bucket under `budget` bits/param/round
    PerLink { cheap: usize, budget: f64 },
}

/// Mixed-wire meta-strategy (factory). Registry syntax:
/// `mixed(<arm>[*<weight>], ...)` (per-chunk) or
/// `mixed(<cheap>@cheap,<rich>@rich)` (per-link, budget-driven).
pub struct MixedStrategy {
    arms: Vec<Box<dyn Strategy>>,
    mode: Mode,
}

/// An arm must be mixable: every-step cadence and a native chunked
/// codec (monolithic wire formats cannot be assigned per chunk).
fn validate_arm(s: &dyn Strategy) -> Result<()> {
    if s.local_steps() != 1 {
        return Err(DlionError::Config(format!(
            "mixed arm '{}' must communicate every step: \
             local-steps strategies cannot be mixed",
            s.name()
        )));
    }
    if !matches!(s.chunking(), Chunking::Native { .. }) {
        return Err(DlionError::Config(format!(
            "mixed arm '{}' has no native chunked wire format: \
             monolithic strategies cannot be assigned per chunk",
            s.name()
        )));
    }
    Ok(())
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

fn lcm(a: usize, b: usize) -> usize {
    a / gcd(a, b) * b
}

impl MixedStrategy {
    /// Static per-chunk assignment: `weights[j]` cycle slots per arm.
    pub fn per_chunk(arms: Vec<Box<dyn Strategy>>, weights: Vec<usize>) -> Result<MixedStrategy> {
        if arms.is_empty() {
            return Err(DlionError::Config(
                "mixed strategy has an empty arm list: name at least one registered arm".into(),
            ));
        }
        if weights.len() != arms.len() || weights.iter().any(|&w| w == 0) {
            return Err(DlionError::Config(
                "mixed strategy needs one positive weight per arm".into(),
            ));
        }
        for a in &arms {
            validate_arm(a.as_ref())?;
        }
        // whole-model encoders (classic sparse top-k) destroy residual
        // mass in ranges they do not ship; with identical arms every
        // range ships through *some* arm (mixed(dgc,dgc) is bit-exact
        // to plain dgc), but a heterogeneous assignment would leak it
        let homogeneous = arms.windows(2).all(|w| w[0].name() == w[1].name());
        if !homogeneous {
            if let Some(a) = arms.iter().find(|a| !a.chunk_local_encode()) {
                return Err(DlionError::Config(format!(
                    "mixed arm '{}' selects whole-model (non-chunk-local) state and \
                     can only be mixed with identical arms",
                    a.name()
                )));
            }
        }
        let assign = Assignment::new(&weights);
        Ok(MixedStrategy { arms, mode: Mode::PerChunk { weights, assign } })
    }

    /// Dynamic per-link selection under `budget` bits/param/round per
    /// hop. `arms` keep the caller's order; `cheap` indexes into it.
    pub fn per_link(
        arms: Vec<Box<dyn Strategy>>,
        cheap: usize,
        budget: f64,
    ) -> Result<MixedStrategy> {
        if arms.len() != 2 || cheap > 1 {
            return Err(DlionError::Config(
                "per-link mixed needs exactly two arms (one @cheap, one @rich)".into(),
            ));
        }
        for a in &arms {
            validate_arm(a.as_ref())?;
        }
        Ok(MixedStrategy { arms, mode: Mode::PerLink { cheap, budget } })
    }

    fn cheap_rich(&self, cheap: usize) -> (&dyn Strategy, &dyn Strategy) {
        (self.arms[cheap].as_ref(), self.arms[1 - cheap].as_ref())
    }

    /// The rich-round fraction the dual bucket settles into (what the
    /// analytic bits/param model amortizes over).
    fn rich_fraction(&self, nworkers: usize) -> f64 {
        match self.mode {
            Mode::PerChunk { .. } => 0.0,
            Mode::PerLink { cheap, budget } => {
                let (c, r) = self.cheap_rich(cheap);
                let mut sched = DualBucket::new(budget, c, r, nworkers);
                let rich = (0..AMORTIZE_HORIZON).filter(|_| sched.next()).count();
                rich as f64 / AMORTIZE_HORIZON as f64
            }
        }
    }

    /// Blend a per-arm analytic rate into the mixed rate: weighted by
    /// chunk share (static) or by the amortized rich fraction at
    /// `nworkers` (dynamic).
    fn blend(&self, nworkers: usize, rate: impl Fn(&dyn Strategy) -> f64) -> f64 {
        match &self.mode {
            Mode::PerChunk { assign, .. } => self
                .arms
                .iter()
                .enumerate()
                .map(|(j, a)| assign.fraction(j) * rate(a.as_ref()))
                .sum(),
            Mode::PerLink { cheap, .. } => {
                let f = self.rich_fraction(nworkers);
                let (c, r) = self.cheap_rich(*cheap);
                f * rate(r) + (1.0 - f) * rate(c)
            }
        }
    }

    /// Per-chunk (uplink, downlink) payload bytes per worker per round
    /// under this strategy's plan for `(dim, chunk_size)` — the
    /// heterogeneous cost vector [`crate::comm::simnet`]'s pipelined
    /// estimate consumes. Static assignments price each chunk at its
    /// arm's rate; the per-link mode prices every chunk at the
    /// amortized mix.
    pub fn chunk_costs(&self, dim: usize, chunk_size: usize, nworkers: usize) -> Vec<(f64, f64)> {
        let plan = self.plan(dim, chunk_size);
        // the per-link mix is chunk-independent: amortize the schedule
        // once, not once per chunk (it replays 10⁴ bucket rounds)
        let link_mix = match &self.mode {
            Mode::PerChunk { .. } => None,
            Mode::PerLink { .. } => Some((
                self.uplink_bits_per_param(nworkers),
                self.downlink_bits_per_param(nworkers),
            )),
        };
        plan.chunks()
            .map(|c| {
                let (up, down) = match &self.mode {
                    Mode::PerChunk { assign, .. } => {
                        let a = self.arms[assign.arm(c.index)].as_ref();
                        (a.uplink_bits_per_param(nworkers), a.downlink_bits_per_param(nworkers))
                    }
                    Mode::PerLink { .. } => link_mix.expect("computed above"),
                };
                (up * c.len() as f64 / 8.0, down * c.len() as f64 / 8.0)
            })
            .collect()
    }
}

impl Strategy for MixedStrategy {
    fn name(&self) -> String {
        let arms: Vec<String> = match &self.mode {
            Mode::PerChunk { weights, .. } => self
                .arms
                .iter()
                .zip(weights)
                .map(|(a, &w)| if w == 1 { a.name() } else { format!("{}*{w}", a.name()) })
                .collect(),
            Mode::PerLink { cheap, .. } => self
                .arms
                .iter()
                .enumerate()
                .map(|(j, a)| {
                    format!("{}@{}", a.name(), if j == *cheap { "cheap" } else { "rich" })
                })
                .collect(),
        };
        format!("mixed({})", arms.join(","))
    }

    fn make_worker(&self, worker: usize, nworkers: usize, dim: usize) -> Box<dyn WorkerLogic> {
        match &self.mode {
            Mode::PerChunk { assign, .. } => Box::new(MixedChunkWorker {
                arms: self.arms.iter().map(|a| a.make_worker(worker, nworkers, dim)).collect(),
                assign: assign.clone(),
            }),
            Mode::PerLink { cheap, budget } => {
                let (c, r) = self.cheap_rich(*cheap);
                Box::new(MixedLinkWorker {
                    cheap: c.make_worker(worker, nworkers, dim),
                    rich: r.make_worker(worker, nworkers, dim),
                    sched: DualBucket::new(*budget, c, r, nworkers),
                    rich_now: false,
                })
            }
        }
    }

    fn make_server(&self, nworkers: usize, dim: usize) -> Box<dyn ServerLogic> {
        self.make_server_for_chunk(nworkers, nworkers, Chunk::whole(dim))
    }

    /// The per-(chunk, arm) routing point: each chunk's server is its
    /// arm's native server, built for the chunk's dimension — so the
    /// round engine's per-(group, chunk) instances become
    /// per-(group, chunk, arm) with no engine-side special casing. The
    /// per-link mode wraps both arms' servers behind the replayed
    /// schedule, seeded from `cluster_workers` (a group aggregator
    /// folds `nworkers < cluster_workers` uplinks but must pick the
    /// same arm as every worker and the root).
    fn make_server_for_chunk(
        &self,
        nworkers: usize,
        cluster_workers: usize,
        chunk: Chunk,
    ) -> Box<dyn ServerLogic> {
        match &self.mode {
            Mode::PerChunk { assign, .. } => {
                self.arms[assign.arm(chunk.index)].make_server(nworkers, chunk.len())
            }
            Mode::PerLink { cheap, budget } => {
                let (c, r) = self.cheap_rich(*cheap);
                Box::new(MixedLinkServer {
                    cheap: c.make_server(nworkers, chunk.len()),
                    rich: r.make_server(nworkers, chunk.len()),
                    sched: DualBucket::new(*budget, c, r, cluster_workers),
                })
            }
        }
    }

    fn uplink_bits_per_param(&self, nworkers: usize) -> f64 {
        self.blend(nworkers, |a| a.uplink_bits_per_param(nworkers))
    }

    fn downlink_bits_per_param(&self, nworkers: usize) -> f64 {
        self.blend(nworkers, |a| a.downlink_bits_per_param(nworkers))
    }

    /// Aggregator→root hop: each chunk ships its arm's partial, so the
    /// hop rate is the same blend over the arms' partial models.
    ///
    /// Caveat (per-link mode only): the trait signature exposes the
    /// group size but not the cluster size, so the rich-round fraction
    /// here is amortized at `group_size` while the *runtime* schedule
    /// is seeded from the cluster size — the two can differ when the
    /// arms' cost models differ between those worker counts (e.g. the
    /// even-/odd-N majority-vote downlink). Treat the per-link partial
    /// model as an approximation; the static blend is exact.
    fn partial_bits_per_param(&self, group_size: usize) -> f64 {
        self.blend(group_size, |a| a.partial_bits_per_param(group_size))
    }

    /// The whole-model default is re-pointed for multi-arm static
    /// assignments: `chunk_size == 0` partitions the model into exactly
    /// one weight cycle (`Σ weights` chunks) instead of collapsing to a
    /// single chunk — a single-chunk plan would silently route the
    /// entire model to arm 0 while the analytic models still reported
    /// the weighted blend. Explicit chunk sizes (and the per-link mode,
    /// whose arms serve whole rounds anyway) keep the standard
    /// [`ChunkPlan::new`] behavior; a model smaller than one aligned
    /// chunk still degenerates honestly.
    fn plan(&self, dim: usize, chunk_size: usize) -> ChunkPlan {
        let align = match self.chunking() {
            Chunking::Native { align } => align,
            Chunking::Monolithic => return ChunkPlan::single(dim),
        };
        let chunk_size = match &self.mode {
            Mode::PerChunk { assign, .. } if chunk_size == 0 && assign.cycle_len() > 1 => {
                // round the per-slot size DOWN to the alignment: rounding
                // up could shrink the chunk count below the cycle length
                // and starve the tail arms. Whenever dim ≥ cycle · align,
                // every cycle slot (hence every arm) serves at least one
                // chunk; below that the leading slots win — the honest
                // degenerate for models smaller than one aligned cycle.
                (dim / assign.cycle_len() / align * align).max(align)
            }
            _ => chunk_size,
        };
        ChunkPlan::new(dim, chunk_size, align)
    }

    /// The shared plan aligns to the lcm of the arms' codec alignments,
    /// so every arm's chunks splice bit-exactly into its own monolithic
    /// payload.
    fn chunking(&self) -> Chunking {
        let mut align = 1usize;
        for a in &self.arms {
            match a.chunking() {
                Chunking::Native { align: x } => align = lcm(align, x),
                // unreachable after constructor validation; collapsing
                // to a single-chunk plan is the safe fallback
                Chunking::Monolithic => return Chunking::Monolithic,
            }
        }
        Chunking::Native { align }
    }
}

// ---------------------------------------------------------------------------
// Worker / server wrappers
// ---------------------------------------------------------------------------

/// Static mode: route each chunk to its arm's worker logic, re-indexed
/// to the arm-local view.
struct MixedChunkWorker {
    arms: Vec<Box<dyn WorkerLogic>>,
    assign: Assignment,
}

impl WorkerLogic for MixedChunkWorker {
    fn encode(&mut self, grads: &[f32], lr: f32, step: usize) -> Vec<u8> {
        // single-chunk plan: the whole model is chunk 0's arm
        self.arms[self.assign.arm(0)].encode(grads, lr, step)
    }

    fn apply(&mut self, params: &mut [f32], downlink: &[u8], lr: f32, step: usize) {
        self.arms[self.assign.arm(0)].apply(params, downlink, lr, step);
    }

    fn encode_chunk(&mut self, grads: &[f32], chunk: Chunk, lr: f32, step: usize) -> Vec<u8> {
        let arm = self.assign.arm(chunk.index);
        let local = self.assign.rebase(chunk);
        self.arms[arm].encode_chunk(grads, local, lr, step)
    }

    fn apply_chunk(&mut self, params: &mut [f32], frame: &[u8], chunk: Chunk, lr: f32, step: usize) {
        let arm = self.assign.arm(chunk.index);
        let local = self.assign.rebase(chunk);
        self.arms[arm].apply_chunk(params, frame, local, lr, step);
    }
}

/// Dynamic mode: advance the dual bucket once per round (on the first
/// chunk of the encode half) and hand the whole round to the chosen arm.
struct MixedLinkWorker {
    cheap: Box<dyn WorkerLogic>,
    rich: Box<dyn WorkerLogic>,
    sched: DualBucket,
    rich_now: bool,
}

impl MixedLinkWorker {
    fn current(&mut self) -> &mut dyn WorkerLogic {
        if self.rich_now {
            self.rich.as_mut()
        } else {
            self.cheap.as_mut()
        }
    }
}

impl WorkerLogic for MixedLinkWorker {
    fn encode(&mut self, grads: &[f32], lr: f32, step: usize) -> Vec<u8> {
        self.rich_now = self.sched.next();
        self.current().encode(grads, lr, step)
    }

    fn apply(&mut self, params: &mut [f32], downlink: &[u8], lr: f32, step: usize) {
        self.current().apply(params, downlink, lr, step);
    }

    fn encode_chunk(&mut self, grads: &[f32], chunk: Chunk, lr: f32, step: usize) -> Vec<u8> {
        if chunk.index == 0 {
            self.rich_now = self.sched.next();
        }
        self.current().encode_chunk(grads, chunk, lr, step)
    }

    fn apply_chunk(&mut self, params: &mut [f32], frame: &[u8], chunk: Chunk, lr: f32, step: usize) {
        self.current().apply_chunk(params, frame, chunk, lr, step);
    }
}

/// Dynamic mode, server side: every engine instance (root or group
/// aggregator, per chunk) holds both arms' servers plus its own replica
/// of the schedule, advanced exactly once per round — each instance
/// receives exactly one aggregate/partial/fold(-chunk) call per wire
/// round, so all replicas stay in lockstep with the workers.
struct MixedLinkServer {
    cheap: Box<dyn ServerLogic>,
    rich: Box<dyn ServerLogic>,
    sched: DualBucket,
}

impl MixedLinkServer {
    fn pick(&mut self) -> &mut dyn ServerLogic {
        if self.sched.next() {
            self.rich.as_mut()
        } else {
            self.cheap.as_mut()
        }
    }
}

impl ServerLogic for MixedLinkServer {
    fn aggregate(&mut self, uplinks: &[Vec<u8>], lr: f32, step: usize) -> Vec<u8> {
        self.pick().aggregate(uplinks, lr, step)
    }

    fn partial(&mut self, uplinks: &[Vec<u8>], lr: f32, step: usize) -> Vec<u8> {
        self.pick().partial(uplinks, lr, step)
    }

    fn fold(&mut self, partials: &[Vec<u8>], lr: f32, step: usize) -> Vec<u8> {
        self.pick().fold(partials, lr, step)
    }

    fn aggregate_chunk(&mut self, uplinks: &[&[u8]], chunk: Chunk, lr: f32, step: usize) -> Vec<u8> {
        self.pick().aggregate_chunk(uplinks, chunk, lr, step)
    }

    fn partial_chunk(&mut self, uplinks: &[&[u8]], chunk: Chunk, lr: f32, step: usize) -> Vec<u8> {
        self.pick().partial_chunk(uplinks, chunk, lr, step)
    }

    fn fold_chunk(&mut self, partials: &[&[u8]], chunk: Chunk, lr: f32, step: usize) -> Vec<u8> {
        self.pick().fold_chunk(partials, chunk, lr, step)
    }
}

// ---------------------------------------------------------------------------
// Registry parsing
// ---------------------------------------------------------------------------

/// Parse the `mixed(...)` registry syntax. `name` is the full composite
/// name (for error messages); `rest` is everything after the `mixed`
/// prefix. Every failure names exactly what is malformed.
pub(crate) fn parse(name: &str, rest: &str, hp: &StrategyHyper) -> Result<Box<dyn Strategy>> {
    let malformed = || {
        DlionError::Config(format!(
            "malformed mixed strategy '{name}': expected \
             mixed(<arm>[*<weight>], ...) or mixed(<cheap>@cheap,<rich>@rich)"
        ))
    };
    let inner = rest
        .strip_prefix('(')
        .and_then(|r| r.strip_suffix(')'))
        .ok_or_else(malformed)?;
    if inner.trim().is_empty() {
        return Err(DlionError::Config(format!(
            "mixed strategy '{name}' has an empty arm list: \
             name at least one registered arm"
        )));
    }
    // split on top-level commas only, so an arm like d-lion-local(2) —
    // or a (rejected) nested composite — reaches its own named error
    // instead of being mangled mid-parens
    let mut tokens: Vec<&str> = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, c) in inner.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                tokens.push(&inner[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    tokens.push(&inner[start..]);

    enum Role {
        Cheap,
        Rich,
    }
    let mut arms: Vec<Box<dyn Strategy>> = Vec::new();
    let mut weights: Vec<usize> = Vec::new();
    let mut roles: Vec<Option<Role>> = Vec::new();
    for tok in tokens {
        let tok = tok.trim();
        if tok.is_empty() {
            return Err(DlionError::Config(format!(
                "mixed strategy '{name}' has an empty arm \
                 (trailing or doubled comma)"
            )));
        }
        let (tok, role) = if let Some(t) = tok.strip_suffix("@cheap") {
            (t.trim(), Some(Role::Cheap))
        } else if let Some(t) = tok.strip_suffix("@rich") {
            (t.trim(), Some(Role::Rich))
        } else {
            (tok, None)
        };
        let (arm_name, weight) = match tok.rsplit_once('*') {
            Some((a, w)) => {
                let w: usize = w.trim().parse().map_err(|_| {
                    DlionError::Config(format!(
                        "arm weight in '{name}' must be a positive integer, got '{w}'"
                    ))
                })?;
                if w == 0 {
                    return Err(DlionError::Config(format!(
                        "arm weight in '{name}' must be a positive integer, got '0'"
                    )));
                }
                (a.trim(), w)
            }
            None => (tok, 1),
        };
        // one level of composition only: nested selectors' names carry
        // their own commas and could never round-trip through this parser
        if arm_name.starts_with("mixed") || arm_name.starts_with("bandwidth-aware") {
            return Err(DlionError::Config(format!(
                "mixed arms cannot be composite in '{name}': \
                 selectors nest one level only"
            )));
        }
        arms.push(super::by_name(arm_name, hp)?);
        weights.push(weight);
        roles.push(role);
    }

    let tagged = roles.iter().filter(|r| r.is_some()).count();
    if tagged == 0 {
        return Ok(Box::new(MixedStrategy::per_chunk(arms, weights)?));
    }
    // per-link mode: exactly one @cheap and one @rich, weights default
    if arms.len() != 2 || tagged != 2 {
        return Err(DlionError::Config(format!(
            "per-link mixed strategy '{name}' needs exactly two role-tagged arms: \
             one @cheap and one @rich"
        )));
    }
    if weights.iter().any(|&w| w != 1) {
        return Err(DlionError::Config(format!(
            "role-tagged arms cannot carry weights in '{name}': \
             the link budget, not a chunk ratio, drives per-link selection"
        )));
    }
    let cheap = match (&roles[0], &roles[1]) {
        (Some(Role::Cheap), Some(Role::Rich)) => 0,
        (Some(Role::Rich), Some(Role::Cheap)) => 1,
        _ => {
            return Err(DlionError::Config(format!(
                "per-link mixed strategy '{name}' needs exactly two role-tagged arms: \
                 one @cheap and one @rich"
            )))
        }
    };
    Ok(Box::new(MixedStrategy::per_link(arms, cheap, hp.link_budget as f64)?))
}

#[cfg(test)]
mod tests {
    use super::super::{by_name, StrategyHyper};
    use super::*;

    #[test]
    fn assignment_geometry() {
        // weights [7, 1]: cycle 0..7 → arm0, 7 → arm1
        let a = Assignment::new(&[7, 1]);
        assert_eq!(a.cycle_len(), 8);
        assert_eq!(a.arm(0), 0);
        assert_eq!(a.arm(6), 0);
        assert_eq!(a.arm(7), 1);
        assert_eq!(a.arm(15), 1);
        assert_eq!(a.local_index(7), 0);
        assert_eq!(a.local_index(15), 1);
        assert_eq!(a.local_index(8), 7, "second cycle resumes arm0's ordinals");
        assert_eq!(a.owned(0, 16), 14);
        assert_eq!(a.owned(1, 16), 2);
        assert_eq!(a.owned(1, 7), 0, "short plans may starve late arms");
        assert!((a.fraction(0) - 0.875).abs() < 1e-12);
        // rebase: global range kept, ordinal/count arm-local
        let c = Chunk { index: 7, count: 16, start: 280, end: 320 };
        let r = a.rebase(c);
        assert_eq!((r.index, r.count, r.start, r.end), (0, 2, 280, 320));
        // one-arm assignment: rebase is the identity (the mixed(a,a)
        // parity contract rides on this)
        let id = Assignment::new(&[1]);
        for i in 0..5 {
            let c = Chunk { index: i, count: 5, start: 10 * i, end: 10 * (i + 1) };
            assert_eq!(id.rebase(c), c);
        }
        // interleaved [1, 1]: arm0 evens, arm1 odds
        let ab = Assignment::new(&[1, 1]);
        assert_eq!(ab.local_index(4), 2);
        assert_eq!(ab.local_index(5), 2);
    }

    #[test]
    fn parse_round_trips_names() {
        let hp = StrategyHyper::default();
        for name in [
            "mixed(d-lion-mavo,g-lion)",
            "mixed(d-lion-mavo*7,g-lion)",
            "mixed(g-lion,d-signum-mavo,d-lion-avg)",
            "mixed(dgc,dgc)",
            "mixed(d-lion-mavo@cheap,g-lion@rich)",
            "mixed(g-lion@rich,d-lion-mavo@cheap)",
        ] {
            let s = by_name(name, &hp).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(s.name(), name, "name must round-trip");
            let again = by_name(&s.name(), &hp).unwrap();
            assert_eq!(again.name(), name);
        }
    }

    #[test]
    fn weighted_model_is_the_chunk_share_blend() {
        let hp = StrategyHyper::default();
        let s = by_name("mixed(d-lion-mavo*7,g-lion)", &hp).unwrap();
        let n = 3; // odd: mavo downlink 1 bit
        assert!((s.uplink_bits_per_param(n) - (7.0 + 32.0) / 8.0).abs() < 1e-12);
        assert!((s.downlink_bits_per_param(n) - (7.0 + 32.0) / 8.0).abs() < 1e-12);
        // agg hop: 7/8 vote partials (⌈log2(g+1)⌉) + 1/8 dense sums
        let g = 2;
        assert!((s.partial_bits_per_param(g) - (7.0 * 2.0 + 32.0) / 8.0).abs() < 1e-12);
    }

    #[test]
    fn mixed_alignment_is_the_arm_lcm() {
        let hp = StrategyHyper::default();
        let s = by_name("mixed(d-lion-mavo,g-lion)", &hp).unwrap();
        assert_eq!(s.chunking(), Chunking::Native { align: 40 });
        let s = by_name("mixed(dgc,dgc)", &hp).unwrap();
        assert_eq!(s.chunking(), Chunking::Native { align: 1 });
        // the shared plan rounds chunk sizes to the mixed alignment
        let s = by_name("mixed(d-lion-mavo,g-lion)", &hp).unwrap();
        let plan = s.plan(96, 7);
        assert_eq!(plan.num_chunks(), 3);
        assert_eq!(plan.chunk(0).range(), 0..40);
    }

    #[test]
    fn default_chunk_size_partitions_one_weight_cycle() {
        // chunk_size 0 (the config default) on a multi-arm static
        // assignment must not collapse to a single chunk — that would
        // silently route the whole model to arm 0 while the analytic
        // models still reported the weighted blend. One weight cycle is
        // the smallest plan on which the named mix is exact.
        let hp = StrategyHyper::default();
        let s = by_name("mixed(d-lion-mavo*7,g-lion)", &hp).unwrap();
        let plan = s.plan(3200, 0);
        assert_eq!(plan.num_chunks(), 8, "one chunk per cycle slot");
        assert_eq!(plan.chunk(0).len(), 400);
        // explicit chunk sizes are untouched
        assert_eq!(s.plan(3200, 400).num_chunks(), 8);
        // the per-slot size rounds DOWN to the alignment, so every arm
        // still serves whenever the model fits one full aligned cycle
        // (rounding up would drop the chunk count below the cycle and
        // starve the tail arms — dim 400 must not become 40-chunk-less)
        let plan = s.plan(400, 0);
        assert_eq!(plan.num_chunks(), 10);
        assert_eq!(plan.chunk(7).len(), 40, "the g-lion slot serves");
        // below one aligned cycle (dim < 8·40) the leading slots win
        assert_eq!(s.plan(240, 0).num_chunks(), 6, "honest degenerate");
        // a model smaller than one aligned chunk still degenerates
        assert!(s.plan(30, 0).is_single());
        // the per-link mode keeps the monolithic default (its arms
        // serve whole rounds regardless of chunking)
        let s = by_name("mixed(d-lion-mavo@cheap,g-lion@rich)", &hp).unwrap();
        assert!(s.plan(3200, 0).is_single());
        // same-arm mixes split too — harmless by chunking invariance
        let s = by_name("mixed(g-lion,g-lion)", &hp).unwrap();
        assert_eq!(s.plan(100, 0).num_chunks(), 2);
    }

    #[test]
    fn dual_bucket_fires_only_when_both_hops_afford() {
        let hp = StrategyHyper::default();
        let cheap = by_name("d-lion-mavo", &hp).unwrap();
        let rich = by_name("g-lion", &hp).unwrap();
        let n = 3; // edge cheap 2, rich 64; agg cheap 2+1=3, rich 64
        // a budget that affords the edge alternation (33 = (2+64)/2)
        // but sits below the agg-hop average ((3+64)/2 = 33.5) fires
        // strictly less often than the edge bucket alone would
        let mut dual = DualBucket::new(33.0, cheap.as_ref(), rich.as_ref(), n);
        let mut edge_only = BucketSchedule::new(33.0, 2.0, 64.0);
        let rounds = 1000;
        let dual_fired = (0..rounds).filter(|_| dual.next()).count();
        let edge_fired = (0..rounds).filter(|_| edge_only.next()).count();
        assert!(dual_fired < edge_fired, "{dual_fired} vs {edge_fired}");
        assert!(dual_fired > 0, "a feasible budget must fire sometimes");
        // generous budget: both hops afford every round
        let mut dual = DualBucket::new(128.0, cheap.as_ref(), rich.as_ref(), n);
        assert!((0..32).all(|_| dual.next()));
        // infeasible budget: never
        let mut dual = DualBucket::new(1.0, cheap.as_ref(), rich.as_ref(), n);
        assert!((0..128).all(|_| !dual.next()));
    }

    #[test]
    fn per_link_model_respects_the_budget() {
        let n = 3;
        for budget in [3.0f32, 10.0, 33.0, 50.0, 100.0] {
            let hp = StrategyHyper { link_budget: budget, ..Default::default() };
            let s = by_name("mixed(d-lion-mavo@cheap,g-lion@rich)", &hp).unwrap();
            let edge = s.uplink_bits_per_param(n) + s.downlink_bits_per_param(n);
            let cap = (budget as f64).max(2.0); // cheap edge floor
            assert!(edge <= cap + 1e-9, "budget {budget}: edge model {edge:.3}");
            assert!(edge >= 2.0 - 1e-9);
        }
        // at/above the rich cost the model is pure rich
        let hp = StrategyHyper { link_budget: 128.0, ..Default::default() };
        let s = by_name("mixed(d-lion-mavo@cheap,g-lion@rich)", &hp).unwrap();
        assert_eq!(s.uplink_bits_per_param(n), 32.0);
    }

    #[test]
    fn chunk_costs_price_each_chunk_at_its_arm() {
        let hp = StrategyHyper::default();
        let arms = vec![by_name("d-lion-mavo", &hp).unwrap(), by_name("g-lion", &hp).unwrap()];
        let s = MixedStrategy::per_chunk(arms, vec![7, 1]).unwrap();
        let costs = s.chunk_costs(320, 40, 3);
        assert_eq!(costs.len(), 8);
        for c in &costs[..7] {
            assert!((c.0 - 40.0 / 8.0).abs() < 1e-9, "sign chunks are 1 bit/param");
        }
        assert!((costs[7].0 - 40.0 * 4.0).abs() < 1e-9, "dense chunk is 32 bits/param");
    }

    #[test]
    fn parse_failures_are_named() {
        let hp = StrategyHyper::default();
        let msg = |name: &str| by_name(name, &hp).err().expect(name).to_string();
        assert!(msg("mixed").contains("mixed(<arm>"), "bare name: {}", msg("mixed"));
        assert!(msg("mixed(d-lion-mavo").contains("mixed(<arm>"));
        assert!(msg("mixed()").contains("empty arm list"));
        assert!(msg("mixed( )").contains("empty arm list"));
        assert!(msg("mixed(d-lion-mavo,)").contains("empty arm"));
        assert!(msg("mixed(d-lion-mavo,,g-lion)").contains("empty arm"));
        assert!(msg("mixed(mixed(d-lion-mavo,g-lion),dgc)").contains("one level only"));
        assert!(msg("mixed(bandwidth-aware(d-lion-mavo,g-lion),dgc)").contains("one level only"));
        assert!(msg("mixed(d-lion-local(2),g-lion)").contains("every step"));
        assert!(msg("mixed(terngrad,g-lion)").contains("native chunked"));
        // classic sparse selects whole-model top-k: heterogeneous mixes
        // would destroy residual mass in other arms' ranges
        assert!(msg("mixed(dgc,g-lion)").contains("identical arms"));
        assert!(msg("mixed(graddrop,d-lion-mavo)").contains("identical arms"));
        assert!(by_name("mixed(dgc,dgc)", &hp).is_ok(), "homogeneous sparse is exact");
        assert!(msg("mixed(nope,g-lion)").contains("unknown strategy"));
        assert!(msg("mixed(d-lion-mavo*0,g-lion)").contains("positive integer"));
        assert!(msg("mixed(d-lion-mavo*x,g-lion)").contains("positive integer"));
        assert!(msg("mixed(d-lion-mavo@cheap,g-lion)").contains("@rich"));
        assert!(msg("mixed(d-lion-mavo@cheap,g-lion@cheap)").contains("@rich"));
        assert!(msg("mixed(d-lion-mavo@cheap,g-lion@rich,dgc)").contains("exactly two"));
        assert!(msg("mixed(d-lion-mavo*2@cheap,g-lion@rich)").contains("cannot carry weights"));
        // compact sparse flips dgc to a monolithic wire format: not mixable
        let hp_c = StrategyHyper { compact_sparse: true, ..hp };
        let err = by_name("mixed(dgc,g-lion)", &hp_c).err().expect("compact dgc");
        assert!(err.to_string().contains("native chunked"), "{err}");
    }

    #[test]
    fn heterogeneous_static_round_is_consistent() {
        // One full multi-chunk round by hand (what the engine does):
        // sign and dense frames in the same envelope, replicas identical.
        use crate::comm::chunked;
        use crate::util::Rng;
        let hp = StrategyHyper::default();
        let strat = by_name("mixed(d-lion-mavo,g-lion)", &hp).unwrap();
        let (n, d) = (3usize, 120usize);
        let plan = strat.plan(d, 40);
        assert_eq!(plan.num_chunks(), 3);
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut servers: Vec<_> =
            plan.chunks().map(|c| strat.make_server_for_chunk(n, n, c)).collect();
        let mut params: Vec<Vec<f32>> = vec![vec![0.2f32; d]; n];
        let mut rng = Rng::new(0x1A17);
        for step in 0..5 {
            let grads: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut g = vec![0.0f32; d];
                    rng.fill_normal(&mut g, 1.0);
                    g
                })
                .collect();
            let ups: Vec<Vec<u8>> = workers
                .iter_mut()
                .zip(&grads)
                .map(|(w, g)| w.encode_planned(g, &plan, 1e-2, step))
                .collect();
            // chunks 0, 2 are 1-bit sign frames; chunk 1 is dense f32
            let frames = chunked::unpack(&ups[0]).unwrap();
            assert_eq!(frames[0][0], super::super::TAG_SIGN);
            assert_eq!(frames[1][0], super::super::TAG_DENSE);
            assert_eq!(frames[2][0], super::super::TAG_SIGN);
            let downs: Vec<Vec<u8>> = plan
                .chunks()
                .map(|c| {
                    let per_chunk: Vec<&[u8]> =
                        ups.iter().map(|m| chunked::unpack(m).unwrap()[c.index]).collect();
                    servers[c.index].aggregate_chunk(&per_chunk, c, 1e-2, step)
                })
                .collect();
            let down = chunked::pack(&downs);
            for (w, p) in workers.iter_mut().zip(params.iter_mut()) {
                w.apply_planned(p, &down, &plan, 1e-2, step);
            }
            for w in 1..n {
                assert_eq!(params[0], params[w], "step {step}: replica divergence");
            }
        }
    }
}
