//! Distributed strategies: Algorithm 1's worker-encode / server-aggregate /
//! worker-apply round, for Distributed Lion and every baseline of the
//! paper's Section 5.1 evaluation (plus the extension baselines used by
//! the projection benches).
//!
//! Layering: a [`Strategy`] is a stateless factory + analytic bandwidth
//! model; it builds per-worker [`WorkerLogic`] state machines and one
//! [`ServerLogic`]. The cluster layer ([`crate::cluster`]) drives them
//! either in-process ([`run_round`]) or over a byte-counted transport
//! fabric — both paths move the *same* frames, so the transport counters
//! and the sequential byte accounting agree bit-exactly.
//!
//! ## Wire frames
//!
//! Every message starts with a one-byte codec tag; payloads are the
//! bit-exact [`crate::comm`] codecs (Table 1 byte accounting):
//!
//! | tag | layout                                   | codec             |
//! |-----|------------------------------------------|-------------------|
//! | 1   | `[1][sign payload]`                      | [`sign`], 1 b/p   |
//! | 2   | `[2][tern payload]`                      | [`tern`], 1.6 b/p |
//! | 3   | `[3][n: u16 LE][intavg payload]`         | [`intavg`], ⌈log2(n+1)⌉ |
//! | 4   | `[4][dense f32 payload]`                 | [`dense`](crate::comm::dense), 32 b/p |
//! | 5   | `[5][sparse payload]`                    | [`sparse`](crate::comm::sparse), 64·keep |
//! | 6   | `[6][scale: f32 LE][tern payload]`       | TernGrad uplink   |
//! | 7   | `[7][n: u16 LE][scale: f32 LE][range payload]` | TernGrad downlink, ⌈log2(2n+1)⌉ |
//! | 8   | `[8][scale: f32 LE][sign payload]`       | EF-SignSGD uplink |
//! | 9   | `[9][scale: f32 LE][u8 levels]`          | QSGD uplink, 8 b/p |
//! | 10  | `[10][compact sparse payload]`           | [`sparse`](crate::comm::sparse) compact, ≈40·keep |
//! | 11  | `[11][sign payload][bf16 momentum]`      | msync uplink, 1 + 16 b/p |
//! | 12  | `[12][vote frame][bf16 mean momentum]`   | msync downlink    |
//! | 13  | `[13][count: u16 LE][(len: u32 LE, frame)*]` | relay partial (aggregator→root fallback) |
//! | 14  | `[14][count: u16 LE][dense f32 payload]` | dense-sum partial (global family) |
//! | 15  | `[15][count: u16 LE][(len: u32 LE, frame)*]` | chunked envelope ([`crate::comm::chunked`]) |
//!
//! The bandwidth-aware selector ([`select`]) and the mixed-wire
//! selector ([`mixed`]) add no framing of their own: their rounds are
//! the wrapped arms' frames verbatim (per round for the former, per
//! chunk and per link for the latter — a mixed envelope simply carries
//! different inner tags per chunk). Tags 13/14 and the tag-3 vote
//! partial only ever cross the aggregator→root hop of a hierarchical
//! topology ([`crate::cluster::topology`]); workers never see them.
//!
//! ## Chunked wire surface
//!
//! The round API is chunk-oriented: a [`ChunkPlan`] deterministically
//! partitions the `dim`-parameter model into fixed-size contiguous
//! [`Chunk`]s, and the per-chunk halves of the round are
//! [`WorkerLogic::encode_chunk`] / [`WorkerLogic::apply_chunk`] and
//! [`ServerLogic::aggregate_chunk`] / [`ServerLogic::partial_chunk`] /
//! [`ServerLogic::fold_chunk`]. Multi-chunk messages ride the tag-15
//! envelope; a single-chunk plan moves exactly the pre-chunking
//! monolithic frames (no envelope), which is how the whole-model
//! methods remain the degenerate case rather than a separate code path.
//!
//! A strategy opts in via [`Strategy::chunking`]: the sign-vote family
//! (D-Lion, D-SIGNUM — sign/tern/intavg codecs, alignment
//! [`SIGN_FAMILY_ALIGN`]), the dense family (g-lion/g-adamw/g-sgd), and
//! the classic sparse top-k family (graddrop/dgc) encode, aggregate,
//! and apply natively per chunk — bit-exact against the monolithic path
//! for *any* `chunk_size`, with identical worker-edge payload-byte
//! accounting ([`crate::comm::chunked::payload_len`]; aggregator-hop
//! invariance additionally holds for the mergeable-partial families,
//! while relay-fallback partials repeat their tag-13 framing per chunk
//! and are priced honestly). Every other strategy keeps
//! the default [`Chunking::Monolithic`] and collapses to a single-chunk
//! plan, so the full registry works unchanged under any configured
//! `chunk_size`. The cluster layer's round engine iterates the plan and
//! runs encode/aggregate/apply chunk-parallel on large models
//! ([`crate::util::parallel`]).

pub mod dgc;
pub mod dlion;
pub mod ef;
pub mod faulty;
pub mod global;
pub mod local;
pub mod mixed;
pub mod msync;
pub mod select;
pub mod terngrad;

use crate::comm::{chunked, intavg, sign, swar, tern};
use crate::error::{DlionError, Result};
use crate::optim::LionParams;
use crate::util::math::bits_for_count;
use std::ops::Range;

pub use self::dgc::SparseTopK;
pub use self::dlion::{Aggregation, DLion, DSignum};
pub use self::ef::DLionEf;
pub use self::faulty::{Fault, FaultyWorker};
pub use self::global::{Global, GlobalOpt};
pub use self::local::DLionLocal;
pub use self::mixed::MixedStrategy;
pub use self::msync::DLionMsync;
pub use self::select::BandwidthAware;
pub use self::terngrad::{EfSignSgd, Qsgd, TernGrad};

/// Frame tags (first byte of every uplink/downlink message).
pub const TAG_SIGN: u8 = 1;
pub const TAG_TERN: u8 = 2;
pub const TAG_INTAVG: u8 = 3;
pub const TAG_DENSE: u8 = 4;
pub const TAG_SPARSE: u8 = 5;
pub const TAG_TERN_SCALED: u8 = 6;
pub const TAG_SUM_SCALED: u8 = 7;
pub const TAG_SIGN_SCALED: u8 = 8;
pub const TAG_QUANT: u8 = 9;
pub const TAG_SPARSE_COMPACT: u8 = 10;
pub const TAG_SIGN_MOM: u8 = 11;
pub const TAG_MSYNC_DOWN: u8 = 12;
pub const TAG_RELAY: u8 = 13;
pub const TAG_DENSE_SUM: u8 = 14;
/// Chunked multi-frame envelope (re-export of [`crate::comm::chunked::TAG_CHUNKED`]).
pub const TAG_CHUNKED: u8 = chunked::TAG_CHUNKED;

/// Chunk alignment for the sign-vote family: the lcm of the sign codec's
/// 8-elements-per-byte, the ternary codec's 5-per-byte, and the intavg
/// codec's byte period — any multiple-of-40 chunk boundary falls on a
/// byte boundary in all three payloads, so chunk payloads concatenate
/// bit-exactly into the monolithic payload.
pub const SIGN_FAMILY_ALIGN: usize = 40;

/// One contiguous parameter range of a [`ChunkPlan`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Chunk {
    /// position in the plan (0-based)
    pub index: usize,
    /// total chunks in the plan
    pub count: usize,
    /// first parameter index (inclusive)
    pub start: usize,
    /// one past the last parameter index
    pub end: usize,
}

impl Chunk {
    /// The single chunk of a whole-model (monolithic) plan.
    pub fn whole(dim: usize) -> Chunk {
        Chunk { index: 0, count: 1, start: 0, end: dim }
    }

    /// Number of parameters in this chunk.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    /// The parameter index range this chunk covers.
    pub fn range(&self) -> Range<usize> {
        self.start..self.end
    }

    /// Is this the only chunk of its plan?
    pub fn is_whole(&self) -> bool {
        self.count == 1
    }
}

/// Deterministic fixed-size partition of a `dim`-parameter model —
/// the geometry both ends of the wire derive from `(dim, chunk_size)`
/// without any on-wire negotiation. All chunks have the same element
/// count (rounded up to the strategy's codec alignment) except the
/// last, which takes the remainder.
///
/// # Examples
///
/// ```
/// use dlion::optim::dist::ChunkPlan;
///
/// let plan = ChunkPlan::new(100, 30, 8); // 30 rounds up to 32
/// assert_eq!(plan.num_chunks(), 4);
/// assert_eq!(plan.chunk(0).range(), 0..32);
/// assert_eq!(plan.chunk(3).range(), 96..100);
/// // chunk_size 0 (or >= dim) degenerates to the whole-model plan
/// assert!(ChunkPlan::new(100, 0, 8).is_single());
/// assert!(ChunkPlan::new(100, 100, 8).is_single());
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkPlan {
    dim: usize,
    chunk: usize,
}

impl ChunkPlan {
    /// The whole-model plan: one chunk covering `0..dim`.
    pub fn single(dim: usize) -> ChunkPlan {
        ChunkPlan { dim, chunk: dim.max(1) }
    }

    /// Build a plan with `chunk_size` elements per chunk, rounded up to
    /// `align` (the codec's bit-packing period). `chunk_size == 0` or
    /// `chunk_size >= dim` yields the whole-model plan. The tag-15
    /// envelope carries a u16 chunk count, so the chunk size is also
    /// raised as needed to keep `num_chunks() <= u16::MAX` — a tiny
    /// configured chunk_size on a huge model coarsens instead of
    /// panicking mid-round.
    pub fn new(dim: usize, chunk_size: usize, align: usize) -> ChunkPlan {
        let align = align.max(1);
        if chunk_size == 0 || chunk_size >= dim {
            return ChunkPlan::single(dim);
        }
        let chunk = chunk_size.div_ceil(align) * align;
        let min_chunk = dim.div_ceil(u16::MAX as usize).div_ceil(align) * align;
        let chunk = chunk.max(min_chunk);
        if chunk >= dim {
            ChunkPlan::single(dim)
        } else {
            ChunkPlan { dim, chunk }
        }
    }

    /// Model dimension this plan partitions.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Elements per chunk (after alignment; the last chunk may be smaller).
    pub fn chunk_elems(&self) -> usize {
        self.chunk
    }

    pub fn num_chunks(&self) -> usize {
        if self.dim == 0 {
            1
        } else {
            self.dim.div_ceil(self.chunk)
        }
    }

    /// Whole-model plan (the monolithic wire format, no envelope)?
    pub fn is_single(&self) -> bool {
        self.num_chunks() == 1
    }

    /// The `index`-th chunk's geometry.
    pub fn chunk(&self, index: usize) -> Chunk {
        let count = self.num_chunks();
        debug_assert!(index < count, "chunk index out of range");
        let start = index * self.chunk;
        Chunk { index, count, start, end: (start + self.chunk).min(self.dim) }
    }

    /// Iterate the chunks in index order.
    pub fn chunks(&self) -> impl Iterator<Item = Chunk> + '_ {
        (0..self.num_chunks()).map(|i| self.chunk(i))
    }
}

/// How a strategy's aggregation behaves when a round closes with fewer
/// uplinks than the cluster size ([`Strategy::quorum`]) — the contract
/// the elastic round engine ([`crate::cluster::topology::RoundEngine`])
/// checks before it accepts a partial quorum.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QuorumSupport {
    /// Missing voters abstain *exactly*: aggregating the arrived
    /// uplinks is, by definition, the aggregate over that subset. The
    /// sign-vote family is here — a vote sum over Q ⊆ N binary frames
    /// is the Q-worker vote sum, and the tag-3 intavg partials already
    /// carry their voter count on the wire.
    Exact,
    /// The aggregate is a mean that rescales by the arrived count
    /// (dense f32 family: sum over Q, divide by Q).
    Rescaled,
    /// No partial-quorum semantics (sparse top-k selections, momentum
    /// sync frames, per-round selector schedules): rounds must be full,
    /// and the engine rejects a partial round with a named error. The
    /// default.
    #[default]
    Unsupported,
}

/// How a strategy's wire format partitions ([`Strategy::chunking`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Chunking {
    /// No native chunked codec: any configured `chunk_size` collapses to
    /// the single-chunk (whole-model) plan. The default.
    Monolithic,
    /// Native per-chunk encode/aggregate/apply; chunk sizes are rounded
    /// up to `align` so chunk payloads splice bit-exactly into the
    /// monolithic payload (payload-byte accounting is chunking-invariant).
    Native {
        /// element alignment (the codec's bit-packing period)
        align: usize,
    },
}

/// Pure per-chunk encode kernel for the sign-family split-borrow path:
/// a `Copy` recipe that turns a disjoint momentum slice + gradient slice
/// into a 1-bit `TAG_SIGN` payload, advancing the momentum in the same
/// pass. Because it borrows nothing, the round engine can run one
/// worker's chunks on different threads (see
/// [`WorkerLogic::split_encode`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SignKernel {
    /// Fused D-Lion worker encode: pack bsign(β1·m + (1−β1)·g), then
    /// m ← β2·m + (1−β2)·g ([`crate::optim::lion::fused_encode_slice`]).
    LionFused {
        /// Lion blend coefficient β1.
        beta1: f32,
        /// Lion momentum coefficient β2.
        beta2: f32,
    },
    /// Fused Signum worker encode: m ← β·m + (1−β)·g, then pack
    /// bsign(m) ([`crate::optim::signum::signum_encode_slice`]).
    Signum {
        /// Signum momentum coefficient β.
        beta: f32,
    },
}

impl SignKernel {
    /// Encode one chunk: `state` and `grads` are the chunk's disjoint
    /// slices, `out` is the chunk frame's payload (bit 0 = slice lane 0,
    /// `sign::packed_len(len)` bytes, every byte overwritten).
    pub fn encode(self, state: &mut [f32], grads: &[f32], out: &mut [u8]) {
        match self {
            SignKernel::LionFused { beta1, beta2 } => {
                crate::optim::lion::fused_encode_slice(beta1, beta2, state, grads, out)
            }
            SignKernel::Signum { beta } => {
                crate::optim::signum::signum_encode_slice(beta, state, grads, out)
            }
        }
    }
}

/// Split-borrow view of a worker's encode state (returned by
/// [`WorkerLogic::split_encode`]): the whole-model mutable state slice
/// plus the kernel that encodes any sub-range of it. The caller carves
/// `state` into disjoint `split_at_mut` slices along the `ChunkPlan` and
/// may run the kernel on each from a different thread.
pub struct SplitEncode<'a> {
    /// The worker's full mutable per-parameter state (Lion/Signum
    /// momentum), index-aligned with the model parameters.
    pub state: &'a mut [f32],
    /// The pure per-chunk encode recipe.
    pub kernel: SignKernel,
}

/// Worker-side half of one synchronous round (Algorithm 1 lines 4–6, 9).
///
/// `encode` consumes the local stochastic gradient and produces the
/// uplink frame, advancing any worker-local optimizer state (momentum,
/// error feedback, residuals). `apply` consumes the server broadcast and
/// updates the replicated parameters; every worker applies the identical
/// downlink, which is what keeps replicas bit-identical.
///
/// # Examples
///
/// ```
/// use dlion::optim::dist::{by_name, StrategyHyper};
///
/// let strat = by_name("d-lion-mavo", &StrategyHyper::default()).unwrap();
/// let mut worker = strat.make_worker(0, 1, 8); // worker 0 of 1, dim 8
/// let uplink = worker.encode(&[1.0; 8], 1e-3, 0);
/// assert_eq!(uplink[0], dlion::optim::dist::TAG_SIGN); // 1-bit frame
/// assert_eq!(uplink.len(), 1 + 1); // tag + 8 sign bits
/// ```
pub trait WorkerLogic: Send {
    fn encode(&mut self, grads: &[f32], lr: f32, step: usize) -> Vec<u8>;
    fn apply(&mut self, params: &mut [f32], downlink: &[u8], lr: f32, step: usize);

    /// Encode one chunk's uplink frame. `grads` is the full gradient
    /// slice; the frame covers `chunk.range()`. Called in ascending
    /// chunk order within a round. Strategies without a native chunked
    /// codec ([`Chunking::Monolithic`]) only ever see the whole-model
    /// chunk and fall through to [`WorkerLogic::encode`].
    fn encode_chunk(&mut self, grads: &[f32], chunk: Chunk, lr: f32, step: usize) -> Vec<u8> {
        assert!(
            chunk.is_whole(),
            "strategy has no native chunked encode; the plan must be single-chunk"
        );
        self.encode(grads, lr, step)
    }

    /// Apply one chunk's downlink frame to `params[chunk.range()]`.
    fn apply_chunk(&mut self, params: &mut [f32], frame: &[u8], chunk: Chunk, lr: f32, step: usize) {
        assert!(
            chunk.is_whole(),
            "strategy has no native chunked apply; the plan must be single-chunk"
        );
        self.apply(params, frame, lr, step);
    }

    /// Split-borrowable encode surface for chunk-parallel rounds.
    /// Returning `Some` promises that, for **any** `ChunkPlan`, encoding
    /// each chunk via [`SignKernel::encode`] on the corresponding
    /// disjoint `state` slice produces exactly the bytes of
    /// [`WorkerLogic::encode_chunk`] (a `TAG_SIGN` frame of analytic
    /// size `1 + sign::packed_len(len)`), independent of chunk order.
    /// The default `None` keeps strategies whose uplink cannot be built
    /// from disjoint per-round state slices (monolithic codecs,
    /// data-dependent frame sizes, step-dependent frames like momentum
    /// sync) on the per-worker sequential path.
    fn split_encode(&mut self) -> Option<SplitEncode<'_>> {
        None
    }

    /// Encode the full uplink message under `plan`: the bare monolithic
    /// frame for a single-chunk plan, a tag-15 chunked envelope
    /// otherwise. This is what the cluster drivers call.
    ///
    /// Workers exposing [`WorkerLogic::split_encode`] assemble the
    /// envelope zero-copy: one exact-size buffer laid out up front
    /// ([`chunked::pack_into`], sign-family frame sizes are analytic)
    /// with each chunk kernel writing its payload in place — no
    /// per-chunk `Vec` churn or splice copy. Other strategies collect
    /// per-chunk frames and splice.
    fn encode_planned(&mut self, grads: &[f32], plan: &ChunkPlan, lr: f32, step: usize) -> Vec<u8> {
        if plan.is_single() {
            return self.encode(grads, lr, step);
        }
        if let Some(se) = self.split_encode() {
            let mut buf = Vec::new();
            encode_split_into(se, grads, plan, &mut buf);
            return buf;
        }
        let frames: Vec<Vec<u8>> =
            plan.chunks().map(|c| self.encode_chunk(grads, c, lr, step)).collect();
        chunked::pack(&frames)
    }

    /// Apply the full downlink message under `plan` (counterpart of
    /// [`WorkerLogic::encode_planned`]).
    fn apply_planned(
        &mut self,
        params: &mut [f32],
        downlink: &[u8],
        plan: &ChunkPlan,
        lr: f32,
        step: usize,
    ) {
        if plan.is_single() {
            self.apply(params, downlink, lr, step);
            return;
        }
        let frames = chunked::unpack(downlink).expect("malformed chunked downlink");
        assert_eq!(frames.len(), plan.num_chunks(), "downlink chunk count mismatch");
        for (frame, c) in frames.iter().zip(plan.chunks()) {
            self.apply_chunk(params, frame, c, lr, step);
        }
    }

    /// Take one purely local optimizer step (no communication). Called
    /// by the cluster drivers on the non-sync steps of a local-steps
    /// strategy ([`Strategy::local_steps`] > 1); replicas may diverge
    /// between sync points and are reconciled by the next `apply`.
    ///
    /// Strategies that communicate every step (`local_steps() == 1`,
    /// the default) never receive this call.
    fn local_step(&mut self, _params: &mut [f32], _grads: &[f32], _lr: f32, _step: usize) {
        panic!(
            "local_step called on a strategy with local_steps == 1; \
             only local-steps strategies (d-lion-local) support it"
        );
    }

    /// Observe a sync step whose uplink never leaves the worker — the
    /// elastic driver's abstention hook for the local-steps cadence.
    /// The worker must perform exactly the *state* bookkeeping of
    /// [`WorkerLogic::encode`] (vote accumulation, momentum advance,
    /// window learning-rate sums) without a frame reaching the wire, so
    /// that the abstained window folds, whole, into the next uplink the
    /// worker does ship (the vote-level analogue of the chaos driver's
    /// gradient-level `StragglerFold`). The following
    /// [`WorkerLogic::apply`] still runs: the downlink aggregated from
    /// the *other* workers' votes reconciles this replica too.
    ///
    /// The default encodes and drops the frame — correct for any
    /// strategy whose `encode` is its only sync-step state mutation.
    /// Strategies that must distinguish a shipped window from an
    /// abstained one (e.g. `d-lion-local(H)` carrying its vote window)
    /// override this. Per-step strategies (`local_steps() == 1`) never
    /// receive this call — their abstention path is the gradient-level
    /// fold.
    fn abstain_sync(&mut self, grads: &[f32], lr: f32, step: usize) {
        let _ = self.encode(grads, lr, step);
    }

    /// Introspection hook: the worker's optimizer momentum, when it has
    /// one. Benches use this to measure momentum drift across workers
    /// under non-iid shards; never used on the training path.
    fn momentum(&self) -> Option<&[f32]> {
        None
    }
}

/// Analytic frame lengths of a sign-family chunked uplink: each chunk is
/// a `[TAG_SIGN]` frame over `chunk.len()` 1-bit lanes.
pub fn sign_frame_lens(plan: &ChunkPlan) -> Vec<usize> {
    plan.chunks().map(|c| 1 + sign::packed_len(c.len())).collect()
}

/// Assemble a sign-family chunked uplink into `buf` with zero per-chunk
/// allocations: lay out the tag-15 envelope at its analytic offsets,
/// then run the worker's [`SignKernel`] over each chunk's disjoint
/// state/grad slices, writing payload bytes in place. Byte-identical to
/// the collect-and-[`chunked::pack`] path. Sequential counterpart of
/// the round engine's chunk-parallel encode; reuses `buf`'s capacity.
pub fn encode_split_into(se: SplitEncode<'_>, grads: &[f32], plan: &ChunkPlan, buf: &mut Vec<u8>) {
    debug_assert_eq!(se.state.len(), plan.dim(), "split state must cover the model");
    debug_assert_eq!(grads.len(), plan.dim());
    let lens = sign_frame_lens(plan);
    let ranges = chunked::pack_into(buf, &lens);
    let kernel = se.kernel;
    let mut rest = se.state;
    for (frame, c) in chunked::split_ranges_mut(buf, &ranges).into_iter().zip(plan.chunks()) {
        let (state, r) = std::mem::take(&mut rest).split_at_mut(c.len());
        rest = r;
        frame[0] = TAG_SIGN;
        kernel.encode(state, &grads[c.range()], &mut frame[1..]);
    }
}

/// Server-side half: fold the index-aligned worker uplinks into one
/// downlink frame (Algorithm 1 lines 7–8).
///
/// # Examples
///
/// ```
/// use dlion::optim::dist::{by_name, StrategyHyper, TAG_SIGN};
///
/// let strat = by_name("d-lion-mavo", &StrategyHyper::default()).unwrap();
/// let (n, d) = (3, 8);
/// let mut workers: Vec<_> = (0..n).map(|w| strat.make_worker(w, n, d)).collect();
/// let mut server = strat.make_server(n, d);
/// let ups: Vec<_> = workers.iter_mut().map(|w| w.encode(&[1.0; 8], 1e-3, 0)).collect();
/// let down = server.aggregate(&ups, 1e-3, 0);
/// assert_eq!(down[0], TAG_SIGN); // odd N: strictly binary majority vote
/// ```
pub trait ServerLogic: Send {
    fn aggregate(&mut self, uplinks: &[Vec<u8>], lr: f32, step: usize) -> Vec<u8>;

    /// Group-aggregator hop of a hierarchical topology: fold this
    /// group's uplinks into one *partial* frame for the root.
    ///
    /// The default is a relay frame (tag 13) carrying the member
    /// uplinks verbatim — always exact, but it compresses nothing.
    /// Strategies with a mergeable aggregate override it: the sign-vote
    /// family ships its integer vote sums as a tag-3 `intavg` frame
    /// (⌈log₂(g+1)⌉ bits/param for a g-worker group), the dense family
    /// ships f32 partial sums (tag 14). A `ServerLogic` built for a
    /// group (via `make_server(group_size, dim)`) only ever sees
    /// `partial`; root instances only see `aggregate`/`fold`.
    fn partial(&mut self, uplinks: &[Vec<u8>], _lr: f32, _step: usize) -> Vec<u8> {
        relay_pack(uplinks)
    }

    /// Root hop of a hierarchical topology: fold the group partials
    /// into the final downlink frame. Must pair with `partial`: the
    /// default unwraps relay frames back into the flat uplink list and
    /// aggregates it, which reproduces the flat-star downlink
    /// bit-for-bit for any grouping.
    fn fold(&mut self, partials: &[Vec<u8>], lr: f32, step: usize) -> Vec<u8> {
        let mut flat: Vec<Vec<u8>> = Vec::new();
        for p in partials {
            relay_unpack(p, &mut flat);
        }
        self.aggregate(&flat, lr, step)
    }

    /// Per-chunk [`ServerLogic::aggregate`]: fold the workers' frames
    /// for one chunk into that chunk's downlink frame. The round engine
    /// builds one `ServerLogic` instance per chunk (via
    /// `make_server(nworkers, chunk.len())`), so the default — delegate
    /// to the whole-model `aggregate` — is already correct; native
    /// servers override it to skip the defensive copy.
    fn aggregate_chunk(&mut self, uplinks: &[&[u8]], _chunk: Chunk, lr: f32, step: usize) -> Vec<u8> {
        let owned: Vec<Vec<u8>> = uplinks.iter().map(|m| m.to_vec()).collect();
        self.aggregate(&owned, lr, step)
    }

    /// Per-chunk [`ServerLogic::partial`] (group-aggregator hop).
    fn partial_chunk(&mut self, uplinks: &[&[u8]], _chunk: Chunk, lr: f32, step: usize) -> Vec<u8> {
        let owned: Vec<Vec<u8>> = uplinks.iter().map(|m| m.to_vec()).collect();
        self.partial(&owned, lr, step)
    }

    /// Per-chunk [`ServerLogic::fold`] (root hop).
    fn fold_chunk(&mut self, partials: &[&[u8]], _chunk: Chunk, lr: f32, step: usize) -> Vec<u8> {
        let owned: Vec<Vec<u8>> = partials.iter().map(|m| m.to_vec()).collect();
        self.fold(&owned, lr, step)
    }

    /// Aggregate a **partial quorum**: `uplinks` holds only the frames
    /// that arrived by the round deadline (1 ≤ Q ≤ nworkers of them).
    /// Only meaningful when the owning strategy reports
    /// [`QuorumSupport::Exact`] or [`QuorumSupport::Rescaled`]; at
    /// Q = nworkers the downlink must be byte-identical to
    /// [`ServerLogic::aggregate`] (the elastic engine's full-quorum
    /// rounds stay bit-exact with the lockstep engine). The default
    /// panics — the round engine gates on [`Strategy::quorum`] before
    /// routing a partial round here.
    fn aggregate_quorum(&mut self, uplinks: &[&[u8]], _lr: f32, _step: usize) -> Vec<u8> {
        let _ = uplinks;
        panic!("strategy has no partial-quorum aggregation (QuorumSupport::Unsupported)");
    }

    /// Quorum counterpart of [`ServerLogic::partial`]: fold the group's
    /// *arrived* uplinks (1 ≤ Q ≤ group size) into one partial frame
    /// whose on-wire count is Q, so the root's fold rescales exactly.
    fn partial_quorum(&mut self, uplinks: &[&[u8]], _lr: f32, _step: usize) -> Vec<u8> {
        let _ = uplinks;
        panic!("strategy has no partial-quorum partials (QuorumSupport::Unsupported)");
    }

    /// Quorum counterpart of [`ServerLogic::fold`]: sum group partials
    /// whose counts may cover fewer than nworkers voters (groups with
    /// no arrivals ship nothing) and finish over the achieved total.
    fn fold_quorum(&mut self, partials: &[&[u8]], _lr: f32, _step: usize) -> Vec<u8> {
        let _ = partials;
        panic!("strategy has no partial-quorum fold (QuorumSupport::Unsupported)");
    }
}

/// A distributed training strategy: a factory for worker/server logic
/// plus the analytic Table-1 bandwidth model.
///
/// # Examples
///
/// Drive one synchronous round by hand (what [`run_round`] does):
///
/// ```
/// use dlion::optim::dist::{by_name, run_round, StrategyHyper};
///
/// let strat = by_name("d-lion-mavo", &StrategyHyper::default()).unwrap();
/// let (n, d) = (3, 16);
/// let mut workers: Vec<_> = (0..n).map(|w| strat.make_worker(w, n, d)).collect();
/// let mut server = strat.make_server(n, d);
/// let mut params = vec![vec![0.5f32; d]; n];
/// let grads = vec![vec![1.0f32; d]; n];
/// let (up, down) = run_round(&mut workers, server.as_mut(), &mut params, &grads, 1e-3, 0);
/// assert!(up > 0 && down > 0);
/// assert_eq!(params[0], params[1]); // replicas stay bit-identical
/// ```
pub trait Strategy: Send + Sync {
    /// Registry name (e.g. "d-lion-mavo").
    fn name(&self) -> String;

    /// Build worker `worker`'s logic for a `dim`-parameter model in an
    /// `nworkers`-worker cluster (the count lets bandwidth-aware logic
    /// replay the server's selection schedule).
    fn make_worker(&self, worker: usize, nworkers: usize, dim: usize) -> Box<dyn WorkerLogic>;

    /// Build the server logic for `nworkers` workers.
    fn make_server(&self, nworkers: usize, dim: usize) -> Box<dyn ServerLogic>;

    /// Build the server logic for one chunk of the plan — the round
    /// engine's per-(group, chunk) instantiation point. `nworkers` is
    /// the number of uplinks this instance folds (the group size when
    /// it serves a group-aggregator hop); `cluster_workers` is the full
    /// cluster size, which deterministic schedules that must replay
    /// identically on every node (the mixed per-link selector) derive
    /// from — never from the local fold width. The default ignores the
    /// chunk geometry beyond its length, which is correct for every
    /// single-arm strategy; [`mixed::MixedStrategy`] overrides it to
    /// route each chunk to its assigned arm's server, turning the
    /// engine's instances into per-(group, chunk, arm) servers with no
    /// engine-side special casing.
    fn make_server_for_chunk(
        &self,
        nworkers: usize,
        cluster_workers: usize,
        chunk: Chunk,
    ) -> Box<dyn ServerLogic> {
        let _ = cluster_workers;
        self.make_server(nworkers, chunk.len())
    }

    /// Analytic worker→server payload bits per parameter (Table 1).
    fn uplink_bits_per_param(&self, nworkers: usize) -> f64;

    /// Analytic server→worker payload bits per parameter (Table 1).
    fn downlink_bits_per_param(&self, nworkers: usize) -> f64;

    /// Communication cadence: the cluster drivers run one wire round
    /// every `local_steps()`-th step and call
    /// [`WorkerLogic::local_step`] on the steps in between. 1 (the
    /// default) is Algorithm 1's every-step round.
    fn local_steps(&self) -> usize {
        1
    }

    /// How this strategy's wire format partitions. The default —
    /// [`Chunking::Monolithic`] — collapses any configured `chunk_size`
    /// to the whole-model plan, so strategies without native chunked
    /// codecs keep working unchanged.
    fn chunking(&self) -> Chunking {
        Chunking::Monolithic
    }

    /// Does this strategy's chunked encode touch strictly chunk-local
    /// state? The sign-vote and dense families do (their per-chunk
    /// frames are pure functions of the chunk's range). Classic sparse
    /// top-k does **not**: its per-round selection is whole-model, and
    /// selected coordinates are cleared from the residual whether or
    /// not their chunk ships — correct when one logic instance covers
    /// every chunk (plain runs, `mixed(dgc,dgc)`), but a heterogeneous
    /// mixed assignment would silently destroy the residual mass that
    /// lands in other arms' ranges. [`mixed::MixedStrategy`] therefore
    /// only accepts non-chunk-local arms when all arms are identical.
    fn chunk_local_encode(&self) -> bool {
        true
    }

    /// The chunk plan this strategy uses for a `dim`-parameter model
    /// under the configured `chunk_size` (0 = whole-model).
    fn plan(&self, dim: usize, chunk_size: usize) -> ChunkPlan {
        match self.chunking() {
            Chunking::Monolithic => ChunkPlan::single(dim),
            Chunking::Native { align } => ChunkPlan::new(dim, chunk_size, align),
        }
    }

    /// Analytic aggregator→root partial-frame bits per parameter for a
    /// `group_size`-worker group (the hierarchical topology's middle
    /// hop, used by [`crate::comm::simnet`]'s latency model). The
    /// default is the relay fallback — member uplinks forwarded
    /// verbatim; strategies with a mergeable partial override it.
    fn partial_bits_per_param(&self, group_size: usize) -> f64 {
        group_size as f64 * self.uplink_bits_per_param(group_size)
    }

    /// Partial-quorum semantics of this strategy's aggregation (see
    /// [`QuorumSupport`]). The elastic round engine refuses to close a
    /// round early unless this returns something other than
    /// [`QuorumSupport::Unsupported`].
    fn quorum(&self) -> QuorumSupport {
        QuorumSupport::Unsupported
    }
}

/// Hyper-parameters shared by the whole strategy registry (a superset:
/// each strategy reads the fields it needs; Table 2 defaults).
#[derive(Clone, Copy, Debug)]
pub struct StrategyHyper {
    /// Lion update interpolation β1.
    pub beta1: f32,
    /// Lion momentum β2.
    pub beta2: f32,
    /// Decoupled weight decay λ (all strategies).
    pub weight_decay: f32,
    /// Signum momentum β (D-SIGNUM ablations).
    pub signum_beta: f32,
    /// Heavy-ball momentum for g-sgd / TernGrad / QSGD / EF-SignSGD.
    pub sgd_momentum: f32,
    /// Kept fraction 1−η for the sparse uplinks (GradDrop/DGC; paper 4%).
    pub keep_frac: f32,
    /// DGC gradient-clip threshold, in units of √d (RMS-element bound).
    pub dgc_clip_norm: f32,
    /// DGC sparsity warmup horizon (steps of exponential ramp to keep_frac).
    pub dgc_warmup_steps: usize,
    /// Momentum-sync cadence for `d-lion-msync` (rounds between bf16
    /// momentum frames; 0 disables sync).
    pub msync_every: usize,
    /// Ship GradDrop/DGC uplinks in the delta-varint compact sparse
    /// format (~40 bits/entry) instead of the classic 64-bit entries.
    pub compact_sparse: bool,
    /// Link budget for the `bandwidth-aware` selector, in bits/param per
    /// round (uplink + downlink combined, analytic Table-1 accounting).
    pub link_budget: f32,
    /// Local-step window H for `d-lion-local` (one wire round every H
    /// optimizer steps; the explicit `d-lion-local(<H>)` name overrides
    /// this). Must be ≥ 1; 1 degenerates to `d-lion-mavo`.
    pub local_steps: usize,
}

impl Default for StrategyHyper {
    fn default() -> Self {
        StrategyHyper {
            beta1: 0.9,
            beta2: 0.99,
            weight_decay: 0.0,
            signum_beta: 0.9,
            sgd_momentum: 0.9,
            keep_frac: 0.04,
            dgc_clip_norm: 1.0,
            dgc_warmup_steps: 200,
            msync_every: 32,
            compact_sparse: false,
            link_budget: 4.0,
            local_steps: 4,
        }
    }
}

/// The registered Section-5.1 strategy matrix (what sweeps iterate).
pub const ALL_STRATEGIES: [&str; 10] = [
    "d-lion-mavo",
    "d-lion-avg",
    "d-signum-mavo",
    "d-signum-avg",
    "g-lion",
    "g-adamw",
    "g-sgd",
    "terngrad",
    "graddrop",
    "dgc",
];

/// Extension strategies `by_name` resolves beyond the Section-5.1 matrix:
/// the network-projection baselines plus the Lion Cub-style variants
/// (error feedback, momentum sync, bandwidth-aware selection), the
/// local-steps D-Lion family, and the mixed-wire selector ([`mixed`]).
pub const EXTENSION_STRATEGIES: [&str; 7] = [
    "qsgd",
    "ef-signsgd",
    "d-lion-ef",
    "d-lion-msync",
    "d-lion-local(4)",
    "bandwidth-aware(d-lion-mavo,g-lion)",
    "mixed(d-lion-mavo,g-lion)",
];

/// Look up a strategy by registry name.
///
/// Resolves every entry of [`ALL_STRATEGIES`] and
/// [`EXTENSION_STRATEGIES`]. The bandwidth-aware selector also accepts
/// the composite form `bandwidth-aware(<cheap>,<rich>)` for any two
/// registered (non-composite) names, and the bare alias
/// `bandwidth-aware` for the default `(d-lion-mavo,g-lion)` pair. The
/// local-steps family accepts `d-lion-local(<H>)` for any H ≥ 1, and
/// the bare alias `d-lion-local` for `StrategyHyper::local_steps`. The
/// mixed-wire selector accepts `mixed(<arm>[*<weight>], ...)` (static
/// per-chunk assignment) and `mixed(<cheap>@cheap,<rich>@rich)`
/// (per-link selection under `StrategyHyper::link_budget`) over any
/// natively-chunkable, every-step arms — see [`mixed`].
///
/// Unknown or malformed names return a [`DlionError::Config`] whose
/// message says exactly what failed to parse (the CLI surfaces it
/// verbatim), never a silent absence.
///
/// # Examples
///
/// ```
/// use dlion::optim::dist::{by_name, StrategyHyper};
///
/// let hp = StrategyHyper::default();
/// let dlion = by_name("d-lion-mavo", &hp).expect("registered");
/// assert_eq!(dlion.name(), "d-lion-mavo");
/// assert_eq!(dlion.uplink_bits_per_param(8), 1.0);
///
/// // amortized momentum-sync accounting: 1 + 16/msync_every bits up
/// let hp2 = StrategyHyper { msync_every: 8, ..hp };
/// let msync = by_name("d-lion-msync", &hp2).unwrap();
/// assert_eq!(msync.uplink_bits_per_param(3), 3.0);
///
/// // composite selector names resolve recursively
/// assert!(by_name("bandwidth-aware(d-lion-mavo,g-lion)", &hp).is_ok());
/// assert!(by_name("mixed(d-lion-mavo*7,g-lion)", &hp).is_ok());
/// assert!(by_name("mixed(d-lion-mavo@cheap,g-lion@rich)", &hp).is_ok());
///
/// // local-steps D-Lion: amortized 1/H-bit uplink
/// let local = by_name("d-lion-local(8)", &hp).unwrap();
/// assert_eq!(local.local_steps(), 8);
/// assert_eq!(local.uplink_bits_per_param(3), 0.125);
///
/// // failures carry the reason, not a silent None
/// let err = by_name("no-such-strategy", &hp).err().expect("must fail");
/// assert!(err.to_string().contains("unknown strategy"));
/// let err = by_name("bandwidth-aware(d-lion-mavo", &hp).err().expect("must fail");
/// assert!(err.to_string().contains("bandwidth-aware(<cheap>,<rich>)"));
/// ```
pub fn by_name(name: &str, hp: &StrategyHyper) -> Result<Box<dyn Strategy>> {
    let lion = LionParams {
        beta1: hp.beta1,
        beta2: hp.beta2,
        weight_decay: hp.weight_decay,
    };
    if let Some(rest) = name.strip_prefix("bandwidth-aware") {
        let malformed = || {
            DlionError::Config(format!(
                "malformed composite strategy '{name}': expected \
                 bandwidth-aware(<cheap>,<rich>) with two registered names"
            ))
        };
        let (cheap_name, rich_name) = if rest.is_empty() {
            ("d-lion-mavo", "g-lion")
        } else {
            rest.strip_prefix('(')
                .and_then(|r| r.strip_suffix(')'))
                .and_then(|r| r.split_once(','))
                .ok_or_else(malformed)?
        };
        let (cheap_name, rich_name) = (cheap_name.trim(), rich_name.trim());
        // one level of composition only: a nested selector's name would
        // carry its own comma and could never round-trip through this
        // parser, so reject selector arms outright
        if [cheap_name, rich_name]
            .iter()
            .any(|a| a.starts_with("bandwidth-aware") || a.starts_with("mixed"))
        {
            return Err(DlionError::Config(format!(
                "selector arms cannot be composite in '{name}': \
                 bandwidth-aware nests one level only"
            )));
        }
        let cheap = by_name(cheap_name, hp)?;
        let rich = by_name(rich_name, hp)?;
        // the selector replays one schedule per wire round; an arm that
        // skips rounds would desynchronize worker and server schedules
        if cheap.local_steps() != 1 || rich.local_steps() != 1 {
            return Err(DlionError::Config(format!(
                "selector arms must communicate every step in '{name}': \
                 local-steps strategies cannot be wrapped"
            )));
        }
        return Ok(Box::new(BandwidthAware::new(cheap, rich, hp.link_budget as f64)));
    }
    if let Some(rest) = name.strip_prefix("mixed") {
        return mixed::parse(name, rest, hp);
    }
    if let Some(rest) = name.strip_prefix("d-lion-local") {
        let h = if rest.is_empty() {
            hp.local_steps
        } else {
            rest.strip_prefix('(')
                .and_then(|r| r.strip_suffix(')'))
                .and_then(|r| r.trim().parse::<usize>().ok())
                .ok_or_else(|| {
                    DlionError::Config(format!(
                        "malformed local-steps strategy '{name}': expected \
                         d-lion-local(<H>) with an integer H >= 1"
                    ))
                })?
        };
        if h == 0 {
            return Err(DlionError::Config(format!(
                "local-steps strategy '{name}' needs H >= 1 (H = 1 \
                 degenerates to d-lion-mavo)"
            )));
        }
        return Ok(Box::new(DLionLocal::new(lion, h)));
    }
    Ok(match name {
        "d-lion-mavo" => Box::new(DLion::new(lion, Aggregation::MajorityVote)),
        "d-lion-avg" => Box::new(DLion::new(lion, Aggregation::Average)),
        "d-lion-ef" => Box::new(DLionEf::new(lion, Aggregation::MajorityVote)),
        "d-lion-msync" => {
            Box::new(DLionMsync::new(lion, Aggregation::MajorityVote, hp.msync_every))
        }
        "d-signum-mavo" => {
            Box::new(DSignum::new(hp.signum_beta, hp.weight_decay, Aggregation::MajorityVote))
        }
        "d-signum-avg" => {
            Box::new(DSignum::new(hp.signum_beta, hp.weight_decay, Aggregation::Average))
        }
        "g-lion" => Box::new(Global::new(GlobalOpt::Lion, *hp)),
        "g-adamw" => Box::new(Global::new(GlobalOpt::AdamW, *hp)),
        "g-sgd" => Box::new(Global::new(GlobalOpt::Sgd, *hp)),
        "terngrad" => Box::new(TernGrad::new(*hp)),
        "graddrop" => Box::new(SparseTopK::new(*hp, false)),
        "dgc" => Box::new(SparseTopK::new(*hp, true)),
        "qsgd" => Box::new(Qsgd::new(*hp)),
        "ef-signsgd" => Box::new(EfSignSgd::new(*hp)),
        _ => {
            return Err(DlionError::Config(format!(
                "unknown strategy '{name}' (run `dlion strategies` for the registry)"
            )))
        }
    })
}

/// One synchronous round over in-process workers (the sequential-mode
/// inner loop). Returns (uplink_bytes, downlink_bytes) with the same
/// accounting the transport fabric records in threaded mode: uplink is
/// the sum of worker frames, downlink is the broadcast frame × workers.
pub fn run_round(
    workers: &mut [Box<dyn WorkerLogic>],
    server: &mut dyn ServerLogic,
    params: &mut [Vec<f32>],
    grads: &[Vec<f32>],
    lr: f32,
    step: usize,
) -> (usize, usize) {
    debug_assert_eq!(workers.len(), params.len());
    debug_assert_eq!(workers.len(), grads.len());
    let uplinks: Vec<Vec<u8>> = workers
        .iter_mut()
        .zip(grads)
        .map(|(w, g)| w.encode(g, lr, step))
        .collect();
    let up_bytes: usize = uplinks.iter().map(|m| m.len()).sum();
    let downlink = server.aggregate(&uplinks, lr, step);
    let down_bytes = downlink.len() * workers.len();
    for (w, p) in workers.iter_mut().zip(params.iter_mut()) {
        w.apply(p, &downlink, lr, step);
    }
    (up_bytes, down_bytes)
}

// ---------------------------------------------------------------------------
// Shared frame helpers
// ---------------------------------------------------------------------------

/// Build a `[tag][payload]` frame.
pub(crate) fn frame(tag: u8, payload: &[u8]) -> Vec<u8> {
    let mut msg = Vec::with_capacity(1 + payload.len());
    msg.push(tag);
    msg.extend_from_slice(payload);
    msg
}

/// Pack member frames into a relay partial (tag 13): the universal —
/// exact but uncompressed — aggregator→root fallback for codecs with
/// no mergeable partial aggregate.
/// Layout: `[13][count: u16 LE][(len: u32 LE, frame bytes)*count]`.
pub(crate) fn relay_pack(uplinks: &[Vec<u8>]) -> Vec<u8> {
    let total: usize = uplinks.iter().map(|m| 4 + m.len()).sum();
    let mut msg = Vec::with_capacity(3 + total);
    msg.push(TAG_RELAY);
    msg.extend_from_slice(&(uplinks.len() as u16).to_le_bytes());
    for up in uplinks {
        msg.extend_from_slice(&(up.len() as u32).to_le_bytes());
        msg.extend_from_slice(up);
    }
    msg
}

/// Unpack a relay partial, appending the member frames to `out` in
/// worker order. Panics on any other tag (mixed partial kinds cannot
/// occur: one `ServerLogic` type produces both sides).
pub(crate) fn relay_unpack(msg: &[u8], out: &mut Vec<Vec<u8>>) {
    assert_eq!(msg[0], TAG_RELAY, "relay fold expects tag-13 partials, got {}", msg[0]);
    let count = read_u16(msg, 1) as usize;
    let mut off = 3usize;
    for _ in 0..count {
        let len = u32::from_le_bytes([msg[off], msg[off + 1], msg[off + 2], msg[off + 3]]) as usize;
        off += 4;
        out.push(msg[off..off + len].to_vec());
        off += len;
    }
    assert_eq!(off, msg.len(), "relay partial has trailing bytes");
}

pub(crate) fn read_u16(msg: &[u8], off: usize) -> u16 {
    u16::from_le_bytes([msg[off], msg[off + 1]])
}

pub(crate) fn read_f32(msg: &[u8], off: usize) -> f32 {
    f32::from_le_bytes([msg[off], msg[off + 1], msg[off + 2], msg[off + 3]])
}

/// Reusable decoder for the sign-family downlinks (TAG_SIGN / TAG_TERN /
/// TAG_INTAVG) into a dense f32 update vector — allocation-free after
/// the first round.
pub(crate) struct UpdateDecoder {
    trits: Vec<i8>,
    votes: Vec<i32>,
    update: Vec<f32>,
}

impl UpdateDecoder {
    pub(crate) fn new(dim: usize) -> Self {
        UpdateDecoder {
            trits: vec![0; dim],
            votes: vec![0; dim],
            update: vec![0.0; dim],
        }
    }

    /// Decode a downlink frame into the aggregated update Δ ∈ [−1, 1]^d.
    pub(crate) fn decode(&mut self, msg: &[u8]) -> &[f32] {
        let d = self.update.len();
        self.decode_len(msg, d)
    }

    /// Decode a frame covering the first `len` elements (a chunk's
    /// worth) — the chunked apply path; `decode` is the `len == dim`
    /// special case.
    pub(crate) fn decode_len(&mut self, msg: &[u8], len: usize) -> &[f32] {
        match msg[0] {
            TAG_SIGN => {
                sign::unpack_into(&msg[1..], &mut self.trits[..len]);
                for (u, &t) in self.update[..len].iter_mut().zip(&self.trits[..len]) {
                    *u = t as f32;
                }
            }
            TAG_TERN => {
                tern::unpack_into(&msg[1..], &mut self.trits[..len]);
                for (u, &t) in self.update[..len].iter_mut().zip(&self.trits[..len]) {
                    *u = t as f32;
                }
            }
            TAG_INTAVG => {
                let n = read_u16(msg, 1) as usize;
                intavg::unpack_into(&msg[3..], n, &mut self.votes[..len]);
                let inv = 1.0 / n as f32;
                for (u, &s) in self.update[..len].iter_mut().zip(&self.votes[..len]) {
                    *u = s as f32 * inv;
                }
            }
            t => panic!("unexpected downlink tag {t}"),
        }
        &self.update[..len]
    }
}

/// Shared server for the 1-bit sign-update family (D-Lion, D-SIGNUM):
/// accumulate worker votes, then either majority-vote or integer-average
/// the result (the two downlink columns of Table 1).
///
/// Partially aggregates exactly: a group instance ships its integer
/// vote sums as a tag-3 `intavg` partial, and the root instance sums
/// the partials — the total votes (and hence the downlink bytes) are
/// identical to the flat star for any grouping.
pub(crate) struct SignVoteServer {
    nworkers: usize,
    agg: Aggregation,
    votes: Vec<i32>,
    /// scratch for decoding one group partial during `fold`
    scratch: Vec<i32>,
    /// §Perf optimization #4 — bit-sliced accumulator for the pure-vote
    /// downlink (odd-N MajorityVote only; `None` keeps the i32 oracle
    /// path for averages, even-N ternary ties, and partials).
    planes: Option<swar::VotePlanes>,
}

impl SignVoteServer {
    pub(crate) fn new(nworkers: usize, dim: usize, agg: Aggregation) -> Self {
        // Odd-N majority vote never needs the integer sums — only the
        // [count ≥ (N+1)/2] plane — so it runs on the SWAR accumulator.
        let planes = (agg == Aggregation::MajorityVote && nworkers % 2 == 1)
            .then(|| swar::VotePlanes::new(dim, nworkers));
        SignVoteServer { nworkers, agg, votes: vec![0; dim], scratch: Vec::new(), planes }
    }

    /// Zero the vote buffer and accumulate the 1-bit uplinks into it.
    fn accumulate_uplinks<'a>(&mut self, uplinks: impl Iterator<Item = &'a [u8]>) {
        self.votes.iter_mut().for_each(|v| *v = 0);
        for up in uplinks {
            assert_eq!(up[0], TAG_SIGN, "sign-vote server expects 1-bit uplinks");
            sign::accumulate_votes(&up[1..], &mut self.votes);
        }
    }

    /// Bit-sliced fast path for the full aggregate (`None` when this
    /// server's downlink is not a pure odd-N majority plane): carry-save
    /// accumulate the payload words, then emit the packed
    /// [count ≥ (N+1)/2] plane straight into the downlink frame — the
    /// per-lane i32 votes are never materialized. Bit-exact with
    /// [`SignVoteServer::finish`]'s odd-N arm (`vote sum > 0 ⇔ count ≥
    /// (N+1)/2`); partials stay on the integer path since plane counters
    /// sum associatively either way.
    fn aggregate_swar<'a>(&mut self, uplinks: impl Iterator<Item = &'a [u8]>) -> Option<Vec<u8>> {
        let planes = self.planes.as_mut()?;
        planes.reset();
        for up in uplinks {
            assert_eq!(up[0], TAG_SIGN, "sign-vote server expects 1-bit uplinks");
            planes.add(&up[1..]);
        }
        debug_assert_eq!(planes.added(), self.nworkers);
        let mut msg = vec![0u8; 1 + sign::packed_len(planes.dim())];
        msg[0] = TAG_SIGN;
        planes.threshold_into(self.nworkers.div_ceil(2), &mut msg[1..]);
        Some(msg)
    }

    /// Encode the accumulated votes as a tag-3 intavg partial frame
    /// covering `voters` ballots (the full `nworkers` in lockstep
    /// rounds; the arrived quorum in elastic rounds).
    fn votes_partial(&self, voters: usize) -> Vec<u8> {
        let payload = intavg::pack(&self.votes, voters);
        let mut msg = Vec::with_capacity(3 + payload.len());
        msg.push(TAG_INTAVG);
        msg.extend_from_slice(&(voters as u16).to_le_bytes());
        msg.extend_from_slice(&payload);
        msg
    }

    /// Sum intavg vote partials into the vote buffer; returns the total
    /// voter count covered (each partial self-describes its count, so
    /// partial quorums sum exactly).
    fn sum_partials<'a>(&mut self, partials: impl Iterator<Item = &'a [u8]>) -> usize {
        let d = self.votes.len();
        self.votes.iter_mut().for_each(|v| *v = 0);
        self.scratch.resize(d, 0);
        let mut total = 0usize;
        for p in partials {
            assert_eq!(p[0], TAG_INTAVG, "sign-vote fold expects intavg partials");
            let group_n = read_u16(p, 1) as usize;
            intavg::unpack_into(&p[3..], group_n, &mut self.scratch);
            for (v, &s) in self.votes.iter_mut().zip(&self.scratch) {
                *v += s;
            }
            total += group_n;
        }
        total
    }

    /// Sum intavg vote partials into the vote buffer, then finish
    /// (lockstep: partials must cover every worker).
    fn fold_partials<'a>(&mut self, partials: impl Iterator<Item = &'a [u8]>) -> Vec<u8> {
        let total = self.sum_partials(partials);
        assert_eq!(total, self.nworkers, "group partials must cover all workers");
        self.finish(total)
    }

    /// Encode the accumulated votes as the downlink frame (the shared
    /// tail of `aggregate` and `fold`), over `voters` ballots. A
    /// missing voter abstains *exactly*: the vote sum over the quorum
    /// IS the aggregate over the quorum, so the odd/even wire-format
    /// branch follows the achieved count, not the cluster size.
    fn finish(&mut self, voters: usize) -> Vec<u8> {
        match self.agg {
            Aggregation::MajorityVote => {
                if voters % 2 == 1 {
                    // Odd count: the vote sum is never zero, the downlink
                    // is strictly binary — 1 bit/param (Table 1's d·d row).
                    let signs: Vec<i8> =
                        self.votes.iter().map(|&v| if v > 0 { 1 } else { -1 }).collect();
                    frame(TAG_SIGN, &sign::pack(&signs))
                } else {
                    // Even count: ties produce genuine zeros; pay the
                    // 1.6-bit ternary frame.
                    let trits: Vec<i8> =
                        self.votes.iter().map(|&v| crate::util::math::isign(v)).collect();
                    frame(TAG_TERN, &tern::pack(&trits))
                }
            }
            Aggregation::Average => {
                let payload = intavg::pack(&self.votes, voters);
                let mut msg = Vec::with_capacity(3 + payload.len());
                msg.push(TAG_INTAVG);
                msg.extend_from_slice(&(voters as u16).to_le_bytes());
                msg.extend_from_slice(&payload);
                msg
            }
        }
    }

    /// Quorum aggregate shared by the whole-model and chunk paths:
    /// `q = uplinks.len()` ballots arrived, the rest abstain. Odd-q
    /// pure majority votes ride the SWAR planes with the threshold
    /// lowered to ⌈q/2⌉ (the planes are sized for `nworkers`, which
    /// bounds any quorum count); everything else takes the i32 path
    /// with the achieved count. At q == nworkers this is byte-identical
    /// to the lockstep aggregate.
    fn aggregate_quorum_frames(&mut self, uplinks: &[&[u8]]) -> Vec<u8> {
        let q = uplinks.len();
        assert!(q >= 1 && q <= self.nworkers, "quorum {q} out of range 1..={}", self.nworkers);
        if self.agg == Aggregation::MajorityVote && q % 2 == 1 {
            if let Some(planes) = self.planes.as_mut() {
                planes.reset();
                for up in uplinks {
                    assert_eq!(up[0], TAG_SIGN, "sign-vote server expects 1-bit uplinks");
                    planes.add(&up[1..]);
                }
                debug_assert_eq!(planes.added(), q);
                let mut msg = vec![0u8; 1 + sign::packed_len(planes.dim())];
                msg[0] = TAG_SIGN;
                planes.threshold_into(q.div_ceil(2), &mut msg[1..]);
                return msg;
            }
        }
        self.accumulate_uplinks(uplinks.iter().copied());
        self.finish(q)
    }
}

impl ServerLogic for SignVoteServer {
    fn aggregate(&mut self, uplinks: &[Vec<u8>], _lr: f32, _step: usize) -> Vec<u8> {
        assert_eq!(uplinks.len(), self.nworkers, "uplink count mismatch");
        if let Some(msg) = self.aggregate_swar(uplinks.iter().map(|u| u.as_slice())) {
            return msg;
        }
        self.accumulate_uplinks(uplinks.iter().map(|u| u.as_slice()));
        self.finish(self.nworkers)
    }

    /// Group hop: ship the group's exact vote sums, log₂(g+1)-bit
    /// packed — `[TAG_INTAVG][g: u16 LE][intavg payload]` (votes over g
    /// binary uplinks satisfy the codec's parity invariant).
    fn partial(&mut self, uplinks: &[Vec<u8>], _lr: f32, _step: usize) -> Vec<u8> {
        assert_eq!(uplinks.len(), self.nworkers, "group uplink count mismatch");
        self.accumulate_uplinks(uplinks.iter().map(|u| u.as_slice()));
        self.votes_partial(self.nworkers)
    }

    /// Root hop: sum the group vote sums — integer addition regroups
    /// exactly, so the downlink equals the flat star's bit-for-bit.
    fn fold(&mut self, partials: &[Vec<u8>], _lr: f32, _step: usize) -> Vec<u8> {
        self.fold_partials(partials.iter().map(|p| p.as_slice()))
    }

    /// Chunked hot path: a per-chunk instance accumulates its chunk's
    /// sign frames directly from the envelope views — no copies, and
    /// integer votes make every chunking bit-exact vs the flat frame.
    fn aggregate_chunk(&mut self, uplinks: &[&[u8]], _chunk: Chunk, _lr: f32, _step: usize) -> Vec<u8> {
        assert_eq!(uplinks.len(), self.nworkers, "uplink count mismatch");
        if let Some(msg) = self.aggregate_swar(uplinks.iter().copied()) {
            return msg;
        }
        self.accumulate_uplinks(uplinks.iter().copied());
        self.finish(self.nworkers)
    }

    fn partial_chunk(&mut self, uplinks: &[&[u8]], _chunk: Chunk, _lr: f32, _step: usize) -> Vec<u8> {
        assert_eq!(uplinks.len(), self.nworkers, "group uplink count mismatch");
        self.accumulate_uplinks(uplinks.iter().copied());
        self.votes_partial(self.nworkers)
    }

    fn fold_chunk(&mut self, partials: &[&[u8]], _chunk: Chunk, _lr: f32, _step: usize) -> Vec<u8> {
        self.fold_partials(partials.iter().copied())
    }

    /// Elastic rounds: missing voters abstain exactly — the aggregate
    /// over the arrived ballots is the ground truth over the quorum.
    fn aggregate_quorum(&mut self, uplinks: &[&[u8]], _lr: f32, _step: usize) -> Vec<u8> {
        self.aggregate_quorum_frames(uplinks)
    }

    /// Elastic group hop: the partial's on-wire count is the group's
    /// *arrived* count, so the root's fold sums achieved quorums.
    fn partial_quorum(&mut self, uplinks: &[&[u8]], _lr: f32, _step: usize) -> Vec<u8> {
        let q = uplinks.len();
        assert!(q >= 1 && q <= self.nworkers, "quorum {q} out of range 1..={}", self.nworkers);
        self.accumulate_uplinks(uplinks.iter().copied());
        self.votes_partial(q)
    }

    /// Elastic root hop: finish over however many voters the partials
    /// cover (groups with no arrivals shipped nothing).
    fn fold_quorum(&mut self, partials: &[&[u8]], _lr: f32, _step: usize) -> Vec<u8> {
        let total = self.sum_partials(partials.iter().copied());
        assert!(
            total >= 1 && total <= self.nworkers,
            "folded quorum {total} out of range 1..={}",
            self.nworkers
        );
        self.finish(total)
    }
}

/// Downlink bits/param for the sign-update family.
pub(crate) fn sign_family_downlink_bits(agg: Aggregation, nworkers: usize) -> f64 {
    match agg {
        Aggregation::MajorityVote => {
            if nworkers % 2 == 1 {
                1.0
            } else {
                tern::BITS_PER_ELEM
            }
        }
        Aggregation::Average => bits_for_count(nworkers) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn registry_resolves_all_names() {
        let hp = StrategyHyper::default();
        for &name in ALL_STRATEGIES.iter().chain(EXTENSION_STRATEGIES.iter()) {
            let s = by_name(name, &hp).unwrap_or_else(|e| panic!("unregistered: {name}: {e}"));
            assert_eq!(s.name(), name, "name round-trip");
        }
        // the bare aliases resolve through the hyper-parameters
        let ba = by_name("bandwidth-aware", &hp).unwrap();
        assert_eq!(ba.name(), "bandwidth-aware(d-lion-mavo,g-lion)");
        let local = by_name("d-lion-local", &hp).unwrap();
        assert_eq!(local.name(), format!("d-lion-local({})", hp.local_steps));
        assert!(by_name("no-such-strategy", &hp).is_err());
        assert!(by_name("bandwidth-aware(nope,g-lion)", &hp).is_err());
        assert!(by_name("bandwidth-aware(", &hp).is_err());
        // nested selectors are rejected (their names cannot round-trip)
        assert!(by_name("bandwidth-aware(bandwidth-aware,g-lion)", &hp).is_err());
        assert!(by_name("bandwidth-aware(d-lion-mavo,bandwidth-aware)", &hp).is_err());
    }

    #[test]
    fn parse_failures_name_the_problem() {
        // Satellite contract: malformed names produce a message the CLI
        // can surface verbatim, never a silent absence.
        let hp = StrategyHyper::default();
        let msg = |name: &str| by_name(name, &hp).err().expect(name).to_string();
        assert!(msg("frobnicate").contains("unknown strategy 'frobnicate'"));
        assert!(msg("bandwidth-aware(d-lion-mavo)").contains("bandwidth-aware(<cheap>,<rich>)"));
        assert!(msg("bandwidth-aware(a,b,c)").contains("unknown strategy"), "inner arm error");
        assert!(msg("bandwidth-aware(bandwidth-aware,g-lion)").contains("one level only"));
        assert!(msg("d-lion-local(x)").contains("d-lion-local(<H>)"));
        assert!(msg("d-lion-local(0)").contains("H >= 1"));
        // local-steps strategies cannot ride inside the selector
        assert!(msg("bandwidth-aware(d-lion-local(2),g-lion)").contains("every step"));
        // mixed composites fail with the same named-error contract
        // (the full matrix lives in mixed::tests::parse_failures_are_named)
        assert!(msg("mixed()").contains("empty arm list"));
        assert!(msg("mixed(d-lion-mavo,)").contains("empty arm"));
        assert!(msg("mixed(d-lion-local(2),g-lion)").contains("every step"));
        assert!(msg("mixed(terngrad,g-lion)").contains("native chunked"));
        assert!(msg("bandwidth-aware(mixed(d-lion-mavo,g-lion),g-lion)").contains("one level"));
    }

    #[test]
    fn relay_partials_round_trip_and_fold_matches_flat() {
        // The default partial/fold path (relay) must reproduce the flat
        // aggregate bit-for-bit for a codec with no mergeable partial.
        let hp = StrategyHyper::default();
        let (d, n) = (97, 4);
        let strat = by_name("terngrad", &hp).unwrap();
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut rng = Rng::new(0xD17);
        let ups: Vec<Vec<u8>> = workers
            .iter_mut()
            .map(|w| {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                w.encode(&g, 1e-3, 0)
            })
            .collect();
        // relay codec round-trip
        let packed = relay_pack(&ups[..2]);
        assert_eq!(packed[0], TAG_RELAY);
        let mut back = Vec::new();
        relay_unpack(&packed, &mut back);
        assert_eq!(back, &ups[..2]);
        // grouped fold == flat aggregate (TernGrad's server is
        // deterministic given the uplinks, so frames must match)
        let mut flat_server = strat.make_server(n, d);
        let flat = flat_server.aggregate(&ups, 1e-3, 0);
        let mut g0 = strat.make_server(2, d);
        let mut g1 = strat.make_server(2, d);
        let partials =
            vec![g0.partial(&ups[..2], 1e-3, 0), g1.partial(&ups[2..], 1e-3, 0)];
        let mut root = strat.make_server(n, d);
        assert_eq!(root.fold(&partials, 1e-3, 0), flat);
    }

    #[test]
    fn round_byte_accounting_matches_frame_sizes() {
        let hp = StrategyHyper::default();
        let (d, n) = (257, 4);
        let mut rng = Rng::new(0xD15);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                g
            })
            .collect();
        for &name in ALL_STRATEGIES.iter().chain(EXTENSION_STRATEGIES.iter()) {
            let strat = by_name(name, &hp).unwrap();
            let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
            let mut server = strat.make_server(n, d);
            let mut params: Vec<Vec<f32>> = vec![vec![0.5f32; d]; n];
            let (up, down) =
                run_round(&mut workers, server.as_mut(), &mut params, &grads, 1e-3, 0);
            assert!(up > 0 && down > 0, "{name}: no bytes moved");
            assert_eq!(down % n, 0, "{name}: downlink must be broadcast × n");
            // replicas identical after one round
            for w in 1..n {
                assert_eq!(params[0], params[w], "{name}: replica divergence");
            }
        }
    }

    #[test]
    fn update_decoder_roundtrips_all_tags() {
        let d = 41;
        let mut dec = UpdateDecoder::new(d);
        let signs: Vec<i8> = (0..d).map(|i| if i % 3 == 0 { 1 } else { -1 }).collect();
        let msg = frame(TAG_SIGN, &sign::pack(&signs));
        let upd = dec.decode(&msg);
        assert!(upd.iter().zip(&signs).all(|(&u, &s)| u == s as f32));

        let trits: Vec<i8> = (0..d).map(|i| (i % 3) as i8 - 1).collect();
        let msg = frame(TAG_TERN, &tern::pack(&trits));
        let upd = dec.decode(&msg);
        assert!(upd.iter().zip(&trits).all(|(&u, &t)| u == t as f32));

        let n = 5usize;
        let sums: Vec<i32> = (0..d).map(|i| (i as i32 % (n as i32 + 1)) * 2 - n as i32).collect();
        let mut msg = vec![TAG_INTAVG];
        msg.extend_from_slice(&(n as u16).to_le_bytes());
        msg.extend_from_slice(&intavg::pack(&sums, n));
        let upd = dec.decode(&msg);
        assert!(upd
            .iter()
            .zip(&sums)
            .all(|(&u, &s)| (u - s as f32 / n as f32).abs() < 1e-7));
    }

    #[test]
    fn chunk_plan_geometry() {
        let p = ChunkPlan::new(96, 1, 40); // 1 rounds up to the 40-elem alignment
        assert_eq!(p.num_chunks(), 3);
        assert_eq!(p.chunk(0).range(), 0..40);
        assert_eq!(p.chunk(2).range(), 80..96);
        assert_eq!(p.chunk(2).count, 3);
        assert!(!p.chunk(1).is_whole());
        let chunks: Vec<Chunk> = p.chunks().collect();
        assert!(chunks.windows(2).all(|w| w[0].end == w[1].start), "chunks must tile");
        assert_eq!(chunks.last().unwrap().end, 96);
        // degenerate plans collapse to the whole model
        assert!(ChunkPlan::new(96, 0, 40).is_single());
        assert!(ChunkPlan::new(96, 96, 40).is_single());
        assert!(ChunkPlan::new(96, 99, 40).is_single());
        assert!(ChunkPlan::new(40, 39, 40).is_single(), "aligned size reaches dim");
        assert_eq!(ChunkPlan::single(7).chunk(0), Chunk::whole(7));
        // the u16 chunk count of the tag-15 envelope is never exceeded:
        // a tiny chunk_size on a huge model coarsens instead of panicking
        let big = ChunkPlan::new(10_000_000, 100, 1);
        assert!(big.num_chunks() <= u16::MAX as usize, "{}", big.num_chunks());
        let big = ChunkPlan::new(100_000_000, 1, 40);
        assert!(big.num_chunks() <= u16::MAX as usize);
        assert_eq!(big.chunk_elems() % 40, 0, "clamp keeps the alignment");
    }

    #[test]
    fn registry_chunking_declarations() {
        let hp = StrategyHyper::default();
        for name in ["d-lion-mavo", "d-lion-avg", "d-signum-mavo", "d-signum-avg"] {
            let s = by_name(name, &hp).unwrap();
            assert_eq!(s.chunking(), Chunking::Native { align: SIGN_FAMILY_ALIGN }, "{name}");
        }
        for name in ["g-lion", "g-adamw", "g-sgd", "graddrop", "dgc"] {
            let s = by_name(name, &hp).unwrap();
            assert_eq!(s.chunking(), Chunking::Native { align: 1 }, "{name}");
        }
        // compact sparse has delta-coded indices that cannot splice: it
        // must stay monolithic so the byte accounting stays exact
        let hp_c = StrategyHyper { compact_sparse: true, ..hp };
        assert_eq!(by_name("dgc", &hp_c).unwrap().chunking(), Chunking::Monolithic);
        // mixed plans align to the lcm of the arms' alignments
        let s = by_name("mixed(d-lion-mavo,g-lion)", &hp).unwrap();
        assert_eq!(s.chunking(), Chunking::Native { align: SIGN_FAMILY_ALIGN });
        let s = by_name("mixed(dgc,dgc)", &hp).unwrap();
        assert_eq!(s.chunking(), Chunking::Native { align: 1 });
        // everything else defaults to monolithic and must still plan
        for name in ["terngrad", "qsgd", "ef-signsgd", "d-lion-ef", "d-lion-msync"] {
            let s = by_name(name, &hp).unwrap();
            assert!(s.plan(1000, 64).is_single(), "{name} must collapse to one chunk");
        }
    }

    #[test]
    fn chunked_envelope_splices_to_the_monolithic_frame() {
        let hp = StrategyHyper::default();
        let (d, n) = (96, 3);
        let strat = by_name("d-lion-mavo", &hp).unwrap();
        let plan = strat.plan(d, 7); // rounds up to 40-elem chunks
        assert_eq!(plan.num_chunks(), 3);
        let mut rng = Rng::new(0xC4);
        let mut g = vec![0.0f32; d];
        rng.fill_normal(&mut g, 1.0);
        let mut wa = strat.make_worker(0, n, d);
        let mut wb = strat.make_worker(0, n, d);
        let mono = wa.encode(&g, 1e-3, 0);
        let msg = wb.encode_planned(&g, &plan, 1e-3, 0);
        assert_eq!(msg[0], TAG_CHUNKED);
        // payload accounting is chunking-invariant...
        assert_eq!(chunked::payload_len(&msg), mono.len());
        // ...because the aligned chunk payloads splice bit-exactly
        let frames = chunked::unpack(&msg).unwrap();
        let spliced: Vec<u8> = std::iter::once(TAG_SIGN)
            .chain(frames.iter().flat_map(|f| f[1..].iter().copied()))
            .collect();
        assert_eq!(spliced, mono);
    }

    #[test]
    fn per_chunk_servers_reproduce_the_monolithic_round() {
        // The full chunked round (encode_planned → per-chunk
        // aggregate_chunk → apply_planned) must match run_round
        // bit-for-bit in params and payload bytes for every native
        // family, across steps (stateful workers included).
        let hp = StrategyHyper::default();
        let (d, n) = (96, 4);
        let mut rng = Rng::new(0xC5);
        let all_grads: Vec<Vec<Vec<f32>>> = (0..3)
            .map(|_| {
                (0..n)
                    .map(|_| {
                        let mut g = vec![0.0f32; d];
                        rng.fill_normal(&mut g, 1.0);
                        g
                    })
                    .collect()
            })
            .collect();
        for name in ["d-lion-mavo", "d-lion-avg", "d-signum-mavo", "g-lion", "g-adamw", "dgc"] {
            let strat = by_name(name, &hp).unwrap();
            let plan = strat.plan(d, 8);
            assert!(!plan.is_single(), "{name}: expected a multi-chunk plan");
            let mut wa: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
            let mut sa = strat.make_server(n, d);
            let mut pa: Vec<Vec<f32>> = vec![vec![0.2f32; d]; n];
            let mut wb: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
            let mut sb: Vec<_> = plan.chunks().map(|c| strat.make_server(n, c.len())).collect();
            let mut pb = pa.clone();
            for (step, grads) in all_grads.iter().enumerate() {
                let (mono_up, mono_down) =
                    run_round(&mut wa, sa.as_mut(), &mut pa, grads, 1e-2, step);
                let ups: Vec<Vec<u8>> = wb
                    .iter_mut()
                    .zip(grads)
                    .map(|(w, g)| w.encode_planned(g, &plan, 1e-2, step))
                    .collect();
                let up_bytes: usize = ups.iter().map(|m| chunked::payload_len(m)).sum();
                assert_eq!(up_bytes, mono_up, "{name} step {step}: uplink payload bytes");
                let per_worker: Vec<Vec<&[u8]>> =
                    ups.iter().map(|m| chunked::unpack(m).unwrap()).collect();
                let downs: Vec<Vec<u8>> = plan
                    .chunks()
                    .map(|c| {
                        let frames: Vec<&[u8]> =
                            per_worker.iter().map(|w| w[c.index]).collect();
                        sb[c.index].aggregate_chunk(&frames, c, 1e-2, step)
                    })
                    .collect();
                let down = chunked::pack(&downs);
                assert_eq!(
                    chunked::payload_len(&down) * n,
                    mono_down,
                    "{name} step {step}: downlink payload bytes"
                );
                for (w, p) in wb.iter_mut().zip(pb.iter_mut()) {
                    w.apply_planned(p, &down, &plan, 1e-2, step);
                }
                assert_eq!(pa, pb, "{name} step {step}: chunked params diverged");
            }
        }
    }

    #[test]
    fn analytic_bits_match_comm_mod_formulas() {
        let hp = StrategyHyper::default();
        for n in [1usize, 2, 3, 4, 8, 16, 32, 33] {
            let mavo = by_name("d-lion-mavo", &hp).unwrap();
            assert_eq!(mavo.uplink_bits_per_param(n), 1.0);
            assert_eq!(
                mavo.downlink_bits_per_param(n),
                if n % 2 == 1 { 1.0 } else { 1.6 }
            );
            let avg = by_name("d-lion-avg", &hp).unwrap();
            assert_eq!(avg.downlink_bits_per_param(n), bits_for_count(n) as f64);
            let tg = by_name("terngrad", &hp).unwrap();
            assert_eq!(tg.uplink_bits_per_param(n), 1.6);
            assert_eq!(
                tg.downlink_bits_per_param(n),
                intavg::bits_for_range(-(n as i32), n as i32) as f64
            );
            let g = by_name("g-lion", &hp).unwrap();
            assert_eq!(g.uplink_bits_per_param(n), 32.0);
            assert_eq!(g.downlink_bits_per_param(n), 32.0);
        }
    }
}
