//! Distributed strategies: Algorithm 1's worker-encode / server-aggregate /
//! worker-apply round, for Distributed Lion and every baseline of the
//! paper's Section 5.1 evaluation (plus the extension baselines used by
//! the projection benches).
//!
//! Layering: a [`Strategy`] is a stateless factory + analytic bandwidth
//! model; it builds per-worker [`WorkerLogic`] state machines and one
//! [`ServerLogic`]. The cluster layer ([`crate::cluster`]) drives them
//! either in-process ([`run_round`]) or over a byte-counted transport
//! fabric — both paths move the *same* frames, so the transport counters
//! and the sequential byte accounting agree bit-exactly.
//!
//! ## Wire frames
//!
//! Every message starts with a one-byte codec tag; payloads are the
//! bit-exact [`crate::comm`] codecs (Table 1 byte accounting):
//!
//! | tag | layout                                   | codec             |
//! |-----|------------------------------------------|-------------------|
//! | 1   | `[1][sign payload]`                      | [`sign`], 1 b/p   |
//! | 2   | `[2][tern payload]`                      | [`tern`], 1.6 b/p |
//! | 3   | `[3][n: u16 LE][intavg payload]`         | [`intavg`], ⌈log2(n+1)⌉ |
//! | 4   | `[4][dense f32 payload]`                 | [`dense`](crate::comm::dense), 32 b/p |
//! | 5   | `[5][sparse payload]`                    | [`sparse`](crate::comm::sparse), 64·keep |
//! | 6   | `[6][scale: f32 LE][tern payload]`       | TernGrad uplink   |
//! | 7   | `[7][n: u16 LE][scale: f32 LE][range payload]` | TernGrad downlink, ⌈log2(2n+1)⌉ |
//! | 8   | `[8][scale: f32 LE][sign payload]`       | EF-SignSGD uplink |
//! | 9   | `[9][scale: f32 LE][u8 levels]`          | QSGD uplink, 8 b/p |
//! | 10  | `[10][compact sparse payload]`           | [`sparse`](crate::comm::sparse) compact, ≈40·keep |
//! | 11  | `[11][sign payload][bf16 momentum]`      | msync uplink, 1 + 16 b/p |
//! | 12  | `[12][vote frame][bf16 mean momentum]`   | msync downlink    |
//! | 13  | `[13][count: u16 LE][(len: u32 LE, frame)*]` | relay partial (aggregator→root fallback) |
//! | 14  | `[14][count: u16 LE][dense f32 payload]` | dense-sum partial (global family) |
//!
//! The bandwidth-aware selector ([`select`]) adds no framing of its own:
//! its rounds are the wrapped strategies' frames verbatim. Tags 13/14
//! and the tag-3 vote partial only ever cross the aggregator→root hop
//! of a hierarchical topology ([`crate::cluster::topology`]); workers
//! never see them.

pub mod dgc;
pub mod dlion;
pub mod ef;
pub mod faulty;
pub mod global;
pub mod local;
pub mod msync;
pub mod select;
pub mod terngrad;

use crate::comm::{intavg, sign, tern};
use crate::error::{DlionError, Result};
use crate::optim::LionParams;
use crate::util::math::bits_for_count;

pub use self::dgc::SparseTopK;
pub use self::dlion::{Aggregation, DLion, DSignum};
pub use self::ef::DLionEf;
pub use self::faulty::{Fault, FaultyWorker};
pub use self::global::{Global, GlobalOpt};
pub use self::local::DLionLocal;
pub use self::msync::DLionMsync;
pub use self::select::BandwidthAware;
pub use self::terngrad::{EfSignSgd, Qsgd, TernGrad};

/// Frame tags (first byte of every uplink/downlink message).
pub const TAG_SIGN: u8 = 1;
pub const TAG_TERN: u8 = 2;
pub const TAG_INTAVG: u8 = 3;
pub const TAG_DENSE: u8 = 4;
pub const TAG_SPARSE: u8 = 5;
pub const TAG_TERN_SCALED: u8 = 6;
pub const TAG_SUM_SCALED: u8 = 7;
pub const TAG_SIGN_SCALED: u8 = 8;
pub const TAG_QUANT: u8 = 9;
pub const TAG_SPARSE_COMPACT: u8 = 10;
pub const TAG_SIGN_MOM: u8 = 11;
pub const TAG_MSYNC_DOWN: u8 = 12;
pub const TAG_RELAY: u8 = 13;
pub const TAG_DENSE_SUM: u8 = 14;

/// Worker-side half of one synchronous round (Algorithm 1 lines 4–6, 9).
///
/// `encode` consumes the local stochastic gradient and produces the
/// uplink frame, advancing any worker-local optimizer state (momentum,
/// error feedback, residuals). `apply` consumes the server broadcast and
/// updates the replicated parameters; every worker applies the identical
/// downlink, which is what keeps replicas bit-identical.
///
/// # Examples
///
/// ```
/// use dlion::optim::dist::{by_name, StrategyHyper};
///
/// let strat = by_name("d-lion-mavo", &StrategyHyper::default()).unwrap();
/// let mut worker = strat.make_worker(0, 1, 8); // worker 0 of 1, dim 8
/// let uplink = worker.encode(&[1.0; 8], 1e-3, 0);
/// assert_eq!(uplink[0], dlion::optim::dist::TAG_SIGN); // 1-bit frame
/// assert_eq!(uplink.len(), 1 + 1); // tag + 8 sign bits
/// ```
pub trait WorkerLogic: Send {
    fn encode(&mut self, grads: &[f32], lr: f32, step: usize) -> Vec<u8>;
    fn apply(&mut self, params: &mut [f32], downlink: &[u8], lr: f32, step: usize);

    /// Take one purely local optimizer step (no communication). Called
    /// by the cluster drivers on the non-sync steps of a local-steps
    /// strategy ([`Strategy::local_steps`] > 1); replicas may diverge
    /// between sync points and are reconciled by the next `apply`.
    ///
    /// Strategies that communicate every step (`local_steps() == 1`,
    /// the default) never receive this call.
    fn local_step(&mut self, _params: &mut [f32], _grads: &[f32], _lr: f32, _step: usize) {
        panic!(
            "local_step called on a strategy with local_steps == 1; \
             only local-steps strategies (d-lion-local) support it"
        );
    }

    /// Introspection hook: the worker's optimizer momentum, when it has
    /// one. Benches use this to measure momentum drift across workers
    /// under non-iid shards; never used on the training path.
    fn momentum(&self) -> Option<&[f32]> {
        None
    }
}

/// Server-side half: fold the index-aligned worker uplinks into one
/// downlink frame (Algorithm 1 lines 7–8).
///
/// # Examples
///
/// ```
/// use dlion::optim::dist::{by_name, StrategyHyper, TAG_SIGN};
///
/// let strat = by_name("d-lion-mavo", &StrategyHyper::default()).unwrap();
/// let (n, d) = (3, 8);
/// let mut workers: Vec<_> = (0..n).map(|w| strat.make_worker(w, n, d)).collect();
/// let mut server = strat.make_server(n, d);
/// let ups: Vec<_> = workers.iter_mut().map(|w| w.encode(&[1.0; 8], 1e-3, 0)).collect();
/// let down = server.aggregate(&ups, 1e-3, 0);
/// assert_eq!(down[0], TAG_SIGN); // odd N: strictly binary majority vote
/// ```
pub trait ServerLogic: Send {
    fn aggregate(&mut self, uplinks: &[Vec<u8>], lr: f32, step: usize) -> Vec<u8>;

    /// Group-aggregator hop of a hierarchical topology: fold this
    /// group's uplinks into one *partial* frame for the root.
    ///
    /// The default is a relay frame (tag 13) carrying the member
    /// uplinks verbatim — always exact, but it compresses nothing.
    /// Strategies with a mergeable aggregate override it: the sign-vote
    /// family ships its integer vote sums as a tag-3 `intavg` frame
    /// (⌈log₂(g+1)⌉ bits/param for a g-worker group), the dense family
    /// ships f32 partial sums (tag 14). A `ServerLogic` built for a
    /// group (via `make_server(group_size, dim)`) only ever sees
    /// `partial`; root instances only see `aggregate`/`fold`.
    fn partial(&mut self, uplinks: &[Vec<u8>], _lr: f32, _step: usize) -> Vec<u8> {
        relay_pack(uplinks)
    }

    /// Root hop of a hierarchical topology: fold the group partials
    /// into the final downlink frame. Must pair with `partial`: the
    /// default unwraps relay frames back into the flat uplink list and
    /// aggregates it, which reproduces the flat-star downlink
    /// bit-for-bit for any grouping.
    fn fold(&mut self, partials: &[Vec<u8>], lr: f32, step: usize) -> Vec<u8> {
        let mut flat: Vec<Vec<u8>> = Vec::new();
        for p in partials {
            relay_unpack(p, &mut flat);
        }
        self.aggregate(&flat, lr, step)
    }
}

/// A distributed training strategy: a factory for worker/server logic
/// plus the analytic Table-1 bandwidth model.
///
/// # Examples
///
/// Drive one synchronous round by hand (what [`run_round`] does):
///
/// ```
/// use dlion::optim::dist::{by_name, run_round, StrategyHyper};
///
/// let strat = by_name("d-lion-mavo", &StrategyHyper::default()).unwrap();
/// let (n, d) = (3, 16);
/// let mut workers: Vec<_> = (0..n).map(|w| strat.make_worker(w, n, d)).collect();
/// let mut server = strat.make_server(n, d);
/// let mut params = vec![vec![0.5f32; d]; n];
/// let grads = vec![vec![1.0f32; d]; n];
/// let (up, down) = run_round(&mut workers, server.as_mut(), &mut params, &grads, 1e-3, 0);
/// assert!(up > 0 && down > 0);
/// assert_eq!(params[0], params[1]); // replicas stay bit-identical
/// ```
pub trait Strategy: Send + Sync {
    /// Registry name (e.g. "d-lion-mavo").
    fn name(&self) -> String;

    /// Build worker `worker`'s logic for a `dim`-parameter model in an
    /// `nworkers`-worker cluster (the count lets bandwidth-aware logic
    /// replay the server's selection schedule).
    fn make_worker(&self, worker: usize, nworkers: usize, dim: usize) -> Box<dyn WorkerLogic>;

    /// Build the server logic for `nworkers` workers.
    fn make_server(&self, nworkers: usize, dim: usize) -> Box<dyn ServerLogic>;

    /// Analytic worker→server payload bits per parameter (Table 1).
    fn uplink_bits_per_param(&self, nworkers: usize) -> f64;

    /// Analytic server→worker payload bits per parameter (Table 1).
    fn downlink_bits_per_param(&self, nworkers: usize) -> f64;

    /// Communication cadence: the cluster drivers run one wire round
    /// every `local_steps()`-th step and call
    /// [`WorkerLogic::local_step`] on the steps in between. 1 (the
    /// default) is Algorithm 1's every-step round.
    fn local_steps(&self) -> usize {
        1
    }
}

/// Hyper-parameters shared by the whole strategy registry (a superset:
/// each strategy reads the fields it needs; Table 2 defaults).
#[derive(Clone, Copy, Debug)]
pub struct StrategyHyper {
    /// Lion update interpolation β1.
    pub beta1: f32,
    /// Lion momentum β2.
    pub beta2: f32,
    /// Decoupled weight decay λ (all strategies).
    pub weight_decay: f32,
    /// Signum momentum β (D-SIGNUM ablations).
    pub signum_beta: f32,
    /// Heavy-ball momentum for g-sgd / TernGrad / QSGD / EF-SignSGD.
    pub sgd_momentum: f32,
    /// Kept fraction 1−η for the sparse uplinks (GradDrop/DGC; paper 4%).
    pub keep_frac: f32,
    /// DGC gradient-clip threshold, in units of √d (RMS-element bound).
    pub dgc_clip_norm: f32,
    /// DGC sparsity warmup horizon (steps of exponential ramp to keep_frac).
    pub dgc_warmup_steps: usize,
    /// Momentum-sync cadence for `d-lion-msync` (rounds between bf16
    /// momentum frames; 0 disables sync).
    pub msync_every: usize,
    /// Ship GradDrop/DGC uplinks in the delta-varint compact sparse
    /// format (~40 bits/entry) instead of the classic 64-bit entries.
    pub compact_sparse: bool,
    /// Link budget for the `bandwidth-aware` selector, in bits/param per
    /// round (uplink + downlink combined, analytic Table-1 accounting).
    pub link_budget: f32,
    /// Local-step window H for `d-lion-local` (one wire round every H
    /// optimizer steps; the explicit `d-lion-local(<H>)` name overrides
    /// this). Must be ≥ 1; 1 degenerates to `d-lion-mavo`.
    pub local_steps: usize,
}

impl Default for StrategyHyper {
    fn default() -> Self {
        StrategyHyper {
            beta1: 0.9,
            beta2: 0.99,
            weight_decay: 0.0,
            signum_beta: 0.9,
            sgd_momentum: 0.9,
            keep_frac: 0.04,
            dgc_clip_norm: 1.0,
            dgc_warmup_steps: 200,
            msync_every: 32,
            compact_sparse: false,
            link_budget: 4.0,
            local_steps: 4,
        }
    }
}

/// The registered Section-5.1 strategy matrix (what sweeps iterate).
pub const ALL_STRATEGIES: [&str; 10] = [
    "d-lion-mavo",
    "d-lion-avg",
    "d-signum-mavo",
    "d-signum-avg",
    "g-lion",
    "g-adamw",
    "g-sgd",
    "terngrad",
    "graddrop",
    "dgc",
];

/// Extension strategies `by_name` resolves beyond the Section-5.1 matrix:
/// the network-projection baselines plus the Lion Cub-style variants
/// (error feedback, momentum sync, bandwidth-aware selection) and the
/// local-steps D-Lion family.
pub const EXTENSION_STRATEGIES: [&str; 6] = [
    "qsgd",
    "ef-signsgd",
    "d-lion-ef",
    "d-lion-msync",
    "d-lion-local(4)",
    "bandwidth-aware(d-lion-mavo,g-lion)",
];

/// Look up a strategy by registry name.
///
/// Resolves every entry of [`ALL_STRATEGIES`] and
/// [`EXTENSION_STRATEGIES`]. The bandwidth-aware selector also accepts
/// the composite form `bandwidth-aware(<cheap>,<rich>)` for any two
/// registered (non-composite) names, and the bare alias
/// `bandwidth-aware` for the default `(d-lion-mavo,g-lion)` pair. The
/// local-steps family accepts `d-lion-local(<H>)` for any H ≥ 1, and
/// the bare alias `d-lion-local` for `StrategyHyper::local_steps`.
///
/// Unknown or malformed names return a [`DlionError::Config`] whose
/// message says exactly what failed to parse (the CLI surfaces it
/// verbatim), never a silent absence.
///
/// # Examples
///
/// ```
/// use dlion::optim::dist::{by_name, StrategyHyper};
///
/// let hp = StrategyHyper::default();
/// let dlion = by_name("d-lion-mavo", &hp).expect("registered");
/// assert_eq!(dlion.name(), "d-lion-mavo");
/// assert_eq!(dlion.uplink_bits_per_param(8), 1.0);
///
/// // amortized momentum-sync accounting: 1 + 16/msync_every bits up
/// let hp2 = StrategyHyper { msync_every: 8, ..hp };
/// let msync = by_name("d-lion-msync", &hp2).unwrap();
/// assert_eq!(msync.uplink_bits_per_param(3), 3.0);
///
/// // composite selector names resolve recursively
/// assert!(by_name("bandwidth-aware(d-lion-mavo,g-lion)", &hp).is_ok());
///
/// // local-steps D-Lion: amortized 1/H-bit uplink
/// let local = by_name("d-lion-local(8)", &hp).unwrap();
/// assert_eq!(local.local_steps(), 8);
/// assert_eq!(local.uplink_bits_per_param(3), 0.125);
///
/// // failures carry the reason, not a silent None
/// let err = by_name("no-such-strategy", &hp).err().expect("must fail");
/// assert!(err.to_string().contains("unknown strategy"));
/// let err = by_name("bandwidth-aware(d-lion-mavo", &hp).err().expect("must fail");
/// assert!(err.to_string().contains("bandwidth-aware(<cheap>,<rich>)"));
/// ```
pub fn by_name(name: &str, hp: &StrategyHyper) -> Result<Box<dyn Strategy>> {
    let lion = LionParams {
        beta1: hp.beta1,
        beta2: hp.beta2,
        weight_decay: hp.weight_decay,
    };
    if let Some(rest) = name.strip_prefix("bandwidth-aware") {
        let malformed = || {
            DlionError::Config(format!(
                "malformed composite strategy '{name}': expected \
                 bandwidth-aware(<cheap>,<rich>) with two registered names"
            ))
        };
        let (cheap_name, rich_name) = if rest.is_empty() {
            ("d-lion-mavo", "g-lion")
        } else {
            rest.strip_prefix('(')
                .and_then(|r| r.strip_suffix(')'))
                .and_then(|r| r.split_once(','))
                .ok_or_else(malformed)?
        };
        let (cheap_name, rich_name) = (cheap_name.trim(), rich_name.trim());
        // one level of composition only: a nested selector's name would
        // carry its own comma and could never round-trip through this
        // parser, so reject selector arms outright
        if cheap_name.starts_with("bandwidth-aware") || rich_name.starts_with("bandwidth-aware") {
            return Err(DlionError::Config(format!(
                "selector arms cannot be composite in '{name}': \
                 bandwidth-aware nests one level only"
            )));
        }
        let cheap = by_name(cheap_name, hp)?;
        let rich = by_name(rich_name, hp)?;
        // the selector replays one schedule per wire round; an arm that
        // skips rounds would desynchronize worker and server schedules
        if cheap.local_steps() != 1 || rich.local_steps() != 1 {
            return Err(DlionError::Config(format!(
                "selector arms must communicate every step in '{name}': \
                 local-steps strategies cannot be wrapped"
            )));
        }
        return Ok(Box::new(BandwidthAware::new(cheap, rich, hp.link_budget as f64)));
    }
    if let Some(rest) = name.strip_prefix("d-lion-local") {
        let h = if rest.is_empty() {
            hp.local_steps
        } else {
            rest.strip_prefix('(')
                .and_then(|r| r.strip_suffix(')'))
                .and_then(|r| r.trim().parse::<usize>().ok())
                .ok_or_else(|| {
                    DlionError::Config(format!(
                        "malformed local-steps strategy '{name}': expected \
                         d-lion-local(<H>) with an integer H >= 1"
                    ))
                })?
        };
        if h == 0 {
            return Err(DlionError::Config(format!(
                "local-steps strategy '{name}' needs H >= 1 (H = 1 \
                 degenerates to d-lion-mavo)"
            )));
        }
        return Ok(Box::new(DLionLocal::new(lion, h)));
    }
    Ok(match name {
        "d-lion-mavo" => Box::new(DLion::new(lion, Aggregation::MajorityVote)),
        "d-lion-avg" => Box::new(DLion::new(lion, Aggregation::Average)),
        "d-lion-ef" => Box::new(DLionEf::new(lion, Aggregation::MajorityVote)),
        "d-lion-msync" => {
            Box::new(DLionMsync::new(lion, Aggregation::MajorityVote, hp.msync_every))
        }
        "d-signum-mavo" => {
            Box::new(DSignum::new(hp.signum_beta, hp.weight_decay, Aggregation::MajorityVote))
        }
        "d-signum-avg" => {
            Box::new(DSignum::new(hp.signum_beta, hp.weight_decay, Aggregation::Average))
        }
        "g-lion" => Box::new(Global::new(GlobalOpt::Lion, *hp)),
        "g-adamw" => Box::new(Global::new(GlobalOpt::AdamW, *hp)),
        "g-sgd" => Box::new(Global::new(GlobalOpt::Sgd, *hp)),
        "terngrad" => Box::new(TernGrad::new(*hp)),
        "graddrop" => Box::new(SparseTopK::new(*hp, false)),
        "dgc" => Box::new(SparseTopK::new(*hp, true)),
        "qsgd" => Box::new(Qsgd::new(*hp)),
        "ef-signsgd" => Box::new(EfSignSgd::new(*hp)),
        _ => {
            return Err(DlionError::Config(format!(
                "unknown strategy '{name}' (run `dlion strategies` for the registry)"
            )))
        }
    })
}

/// One synchronous round over in-process workers (the sequential-mode
/// inner loop). Returns (uplink_bytes, downlink_bytes) with the same
/// accounting the transport fabric records in threaded mode: uplink is
/// the sum of worker frames, downlink is the broadcast frame × workers.
pub fn run_round(
    workers: &mut [Box<dyn WorkerLogic>],
    server: &mut dyn ServerLogic,
    params: &mut [Vec<f32>],
    grads: &[Vec<f32>],
    lr: f32,
    step: usize,
) -> (usize, usize) {
    debug_assert_eq!(workers.len(), params.len());
    debug_assert_eq!(workers.len(), grads.len());
    let uplinks: Vec<Vec<u8>> = workers
        .iter_mut()
        .zip(grads)
        .map(|(w, g)| w.encode(g, lr, step))
        .collect();
    let up_bytes: usize = uplinks.iter().map(|m| m.len()).sum();
    let downlink = server.aggregate(&uplinks, lr, step);
    let down_bytes = downlink.len() * workers.len();
    for (w, p) in workers.iter_mut().zip(params.iter_mut()) {
        w.apply(p, &downlink, lr, step);
    }
    (up_bytes, down_bytes)
}

// ---------------------------------------------------------------------------
// Shared frame helpers
// ---------------------------------------------------------------------------

/// Build a `[tag][payload]` frame.
pub(crate) fn frame(tag: u8, payload: &[u8]) -> Vec<u8> {
    let mut msg = Vec::with_capacity(1 + payload.len());
    msg.push(tag);
    msg.extend_from_slice(payload);
    msg
}

/// Pack member frames into a relay partial (tag 13): the universal —
/// exact but uncompressed — aggregator→root fallback for codecs with
/// no mergeable partial aggregate.
/// Layout: `[13][count: u16 LE][(len: u32 LE, frame bytes)*count]`.
pub(crate) fn relay_pack(uplinks: &[Vec<u8>]) -> Vec<u8> {
    let total: usize = uplinks.iter().map(|m| 4 + m.len()).sum();
    let mut msg = Vec::with_capacity(3 + total);
    msg.push(TAG_RELAY);
    msg.extend_from_slice(&(uplinks.len() as u16).to_le_bytes());
    for up in uplinks {
        msg.extend_from_slice(&(up.len() as u32).to_le_bytes());
        msg.extend_from_slice(up);
    }
    msg
}

/// Unpack a relay partial, appending the member frames to `out` in
/// worker order. Panics on any other tag (mixed partial kinds cannot
/// occur: one `ServerLogic` type produces both sides).
pub(crate) fn relay_unpack(msg: &[u8], out: &mut Vec<Vec<u8>>) {
    assert_eq!(msg[0], TAG_RELAY, "relay fold expects tag-13 partials, got {}", msg[0]);
    let count = read_u16(msg, 1) as usize;
    let mut off = 3usize;
    for _ in 0..count {
        let len = u32::from_le_bytes([msg[off], msg[off + 1], msg[off + 2], msg[off + 3]]) as usize;
        off += 4;
        out.push(msg[off..off + len].to_vec());
        off += len;
    }
    assert_eq!(off, msg.len(), "relay partial has trailing bytes");
}

pub(crate) fn read_u16(msg: &[u8], off: usize) -> u16 {
    u16::from_le_bytes([msg[off], msg[off + 1]])
}

pub(crate) fn read_f32(msg: &[u8], off: usize) -> f32 {
    f32::from_le_bytes([msg[off], msg[off + 1], msg[off + 2], msg[off + 3]])
}

/// Reusable decoder for the sign-family downlinks (TAG_SIGN / TAG_TERN /
/// TAG_INTAVG) into a dense f32 update vector — allocation-free after
/// the first round.
pub(crate) struct UpdateDecoder {
    trits: Vec<i8>,
    votes: Vec<i32>,
    update: Vec<f32>,
}

impl UpdateDecoder {
    pub(crate) fn new(dim: usize) -> Self {
        UpdateDecoder {
            trits: vec![0; dim],
            votes: vec![0; dim],
            update: vec![0.0; dim],
        }
    }

    /// Decode a downlink frame into the aggregated update Δ ∈ [−1, 1]^d.
    pub(crate) fn decode(&mut self, msg: &[u8]) -> &[f32] {
        match msg[0] {
            TAG_SIGN => {
                sign::unpack_into(&msg[1..], &mut self.trits);
                for (u, &t) in self.update.iter_mut().zip(&self.trits) {
                    *u = t as f32;
                }
            }
            TAG_TERN => {
                tern::unpack_into(&msg[1..], &mut self.trits);
                for (u, &t) in self.update.iter_mut().zip(&self.trits) {
                    *u = t as f32;
                }
            }
            TAG_INTAVG => {
                let n = read_u16(msg, 1) as usize;
                intavg::unpack_into(&msg[3..], n, &mut self.votes);
                let inv = 1.0 / n as f32;
                for (u, &s) in self.update.iter_mut().zip(&self.votes) {
                    *u = s as f32 * inv;
                }
            }
            t => panic!("unexpected downlink tag {t}"),
        }
        &self.update
    }
}

/// Shared server for the 1-bit sign-update family (D-Lion, D-SIGNUM):
/// accumulate worker votes, then either majority-vote or integer-average
/// the result (the two downlink columns of Table 1).
///
/// Partially aggregates exactly: a group instance ships its integer
/// vote sums as a tag-3 `intavg` partial, and the root instance sums
/// the partials — the total votes (and hence the downlink bytes) are
/// identical to the flat star for any grouping.
pub(crate) struct SignVoteServer {
    nworkers: usize,
    agg: Aggregation,
    votes: Vec<i32>,
    /// scratch for decoding one group partial during `fold`
    scratch: Vec<i32>,
}

impl SignVoteServer {
    pub(crate) fn new(nworkers: usize, dim: usize, agg: Aggregation) -> Self {
        SignVoteServer { nworkers, agg, votes: vec![0; dim], scratch: Vec::new() }
    }

    /// Zero the vote buffer and accumulate the 1-bit uplinks into it.
    fn accumulate_uplinks(&mut self, uplinks: &[Vec<u8>]) {
        self.votes.iter_mut().for_each(|v| *v = 0);
        for up in uplinks {
            assert_eq!(up[0], TAG_SIGN, "sign-vote server expects 1-bit uplinks");
            sign::accumulate_votes(&up[1..], &mut self.votes);
        }
    }

    /// Encode the accumulated votes as the downlink frame (the shared
    /// tail of `aggregate` and `fold`).
    fn finish(&mut self) -> Vec<u8> {
        match self.agg {
            Aggregation::MajorityVote => {
                if self.nworkers % 2 == 1 {
                    // Odd N: the vote sum is never zero, the downlink is
                    // strictly binary — 1 bit/param (Table 1's d·d row).
                    let signs: Vec<i8> =
                        self.votes.iter().map(|&v| if v > 0 { 1 } else { -1 }).collect();
                    frame(TAG_SIGN, &sign::pack(&signs))
                } else {
                    // Even N: ties produce genuine zeros; pay the 1.6-bit
                    // ternary frame.
                    let trits: Vec<i8> =
                        self.votes.iter().map(|&v| crate::util::math::isign(v)).collect();
                    frame(TAG_TERN, &tern::pack(&trits))
                }
            }
            Aggregation::Average => {
                let payload = intavg::pack(&self.votes, self.nworkers);
                let mut msg = Vec::with_capacity(3 + payload.len());
                msg.push(TAG_INTAVG);
                msg.extend_from_slice(&(self.nworkers as u16).to_le_bytes());
                msg.extend_from_slice(&payload);
                msg
            }
        }
    }
}

impl ServerLogic for SignVoteServer {
    fn aggregate(&mut self, uplinks: &[Vec<u8>], _lr: f32, _step: usize) -> Vec<u8> {
        assert_eq!(uplinks.len(), self.nworkers, "uplink count mismatch");
        self.accumulate_uplinks(uplinks);
        self.finish()
    }

    /// Group hop: ship the group's exact vote sums, log₂(g+1)-bit
    /// packed — `[TAG_INTAVG][g: u16 LE][intavg payload]` (votes over g
    /// binary uplinks satisfy the codec's parity invariant).
    fn partial(&mut self, uplinks: &[Vec<u8>], _lr: f32, _step: usize) -> Vec<u8> {
        assert_eq!(uplinks.len(), self.nworkers, "group uplink count mismatch");
        self.accumulate_uplinks(uplinks);
        let payload = intavg::pack(&self.votes, self.nworkers);
        let mut msg = Vec::with_capacity(3 + payload.len());
        msg.push(TAG_INTAVG);
        msg.extend_from_slice(&(self.nworkers as u16).to_le_bytes());
        msg.extend_from_slice(&payload);
        msg
    }

    /// Root hop: sum the group vote sums — integer addition regroups
    /// exactly, so the downlink equals the flat star's bit-for-bit.
    fn fold(&mut self, partials: &[Vec<u8>], _lr: f32, _step: usize) -> Vec<u8> {
        let d = self.votes.len();
        self.votes.iter_mut().for_each(|v| *v = 0);
        self.scratch.resize(d, 0);
        let mut total = 0usize;
        for p in partials {
            assert_eq!(p[0], TAG_INTAVG, "sign-vote fold expects intavg partials");
            let group_n = read_u16(p, 1) as usize;
            intavg::unpack_into(&p[3..], group_n, &mut self.scratch);
            for (v, &s) in self.votes.iter_mut().zip(&self.scratch) {
                *v += s;
            }
            total += group_n;
        }
        assert_eq!(total, self.nworkers, "group partials must cover all workers");
        self.finish()
    }
}

/// Downlink bits/param for the sign-update family.
pub(crate) fn sign_family_downlink_bits(agg: Aggregation, nworkers: usize) -> f64 {
    match agg {
        Aggregation::MajorityVote => {
            if nworkers % 2 == 1 {
                1.0
            } else {
                tern::BITS_PER_ELEM
            }
        }
        Aggregation::Average => bits_for_count(nworkers) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn registry_resolves_all_names() {
        let hp = StrategyHyper::default();
        for &name in ALL_STRATEGIES.iter().chain(EXTENSION_STRATEGIES.iter()) {
            let s = by_name(name, &hp).unwrap_or_else(|e| panic!("unregistered: {name}: {e}"));
            assert_eq!(s.name(), name, "name round-trip");
        }
        // the bare aliases resolve through the hyper-parameters
        let ba = by_name("bandwidth-aware", &hp).unwrap();
        assert_eq!(ba.name(), "bandwidth-aware(d-lion-mavo,g-lion)");
        let local = by_name("d-lion-local", &hp).unwrap();
        assert_eq!(local.name(), format!("d-lion-local({})", hp.local_steps));
        assert!(by_name("no-such-strategy", &hp).is_err());
        assert!(by_name("bandwidth-aware(nope,g-lion)", &hp).is_err());
        assert!(by_name("bandwidth-aware(", &hp).is_err());
        // nested selectors are rejected (their names cannot round-trip)
        assert!(by_name("bandwidth-aware(bandwidth-aware,g-lion)", &hp).is_err());
        assert!(by_name("bandwidth-aware(d-lion-mavo,bandwidth-aware)", &hp).is_err());
    }

    #[test]
    fn parse_failures_name_the_problem() {
        // Satellite contract: malformed names produce a message the CLI
        // can surface verbatim, never a silent absence.
        let hp = StrategyHyper::default();
        let msg = |name: &str| by_name(name, &hp).err().expect(name).to_string();
        assert!(msg("frobnicate").contains("unknown strategy 'frobnicate'"));
        assert!(msg("bandwidth-aware(d-lion-mavo)").contains("bandwidth-aware(<cheap>,<rich>)"));
        assert!(msg("bandwidth-aware(a,b,c)").contains("unknown strategy"), "inner arm error");
        assert!(msg("bandwidth-aware(bandwidth-aware,g-lion)").contains("one level only"));
        assert!(msg("d-lion-local(x)").contains("d-lion-local(<H>)"));
        assert!(msg("d-lion-local(0)").contains("H >= 1"));
        // local-steps strategies cannot ride inside the selector
        assert!(msg("bandwidth-aware(d-lion-local(2),g-lion)").contains("every step"));
    }

    #[test]
    fn relay_partials_round_trip_and_fold_matches_flat() {
        // The default partial/fold path (relay) must reproduce the flat
        // aggregate bit-for-bit for a codec with no mergeable partial.
        let hp = StrategyHyper::default();
        let (d, n) = (97, 4);
        let strat = by_name("terngrad", &hp).unwrap();
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut rng = Rng::new(0xD17);
        let ups: Vec<Vec<u8>> = workers
            .iter_mut()
            .map(|w| {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                w.encode(&g, 1e-3, 0)
            })
            .collect();
        // relay codec round-trip
        let packed = relay_pack(&ups[..2]);
        assert_eq!(packed[0], TAG_RELAY);
        let mut back = Vec::new();
        relay_unpack(&packed, &mut back);
        assert_eq!(back, &ups[..2]);
        // grouped fold == flat aggregate (TernGrad's server is
        // deterministic given the uplinks, so frames must match)
        let mut flat_server = strat.make_server(n, d);
        let flat = flat_server.aggregate(&ups, 1e-3, 0);
        let mut g0 = strat.make_server(2, d);
        let mut g1 = strat.make_server(2, d);
        let partials =
            vec![g0.partial(&ups[..2], 1e-3, 0), g1.partial(&ups[2..], 1e-3, 0)];
        let mut root = strat.make_server(n, d);
        assert_eq!(root.fold(&partials, 1e-3, 0), flat);
    }

    #[test]
    fn round_byte_accounting_matches_frame_sizes() {
        let hp = StrategyHyper::default();
        let (d, n) = (257, 4);
        let mut rng = Rng::new(0xD15);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                g
            })
            .collect();
        for &name in ALL_STRATEGIES.iter().chain(EXTENSION_STRATEGIES.iter()) {
            let strat = by_name(name, &hp).unwrap();
            let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
            let mut server = strat.make_server(n, d);
            let mut params: Vec<Vec<f32>> = vec![vec![0.5f32; d]; n];
            let (up, down) =
                run_round(&mut workers, server.as_mut(), &mut params, &grads, 1e-3, 0);
            assert!(up > 0 && down > 0, "{name}: no bytes moved");
            assert_eq!(down % n, 0, "{name}: downlink must be broadcast × n");
            // replicas identical after one round
            for w in 1..n {
                assert_eq!(params[0], params[w], "{name}: replica divergence");
            }
        }
    }

    #[test]
    fn update_decoder_roundtrips_all_tags() {
        let d = 41;
        let mut dec = UpdateDecoder::new(d);
        let signs: Vec<i8> = (0..d).map(|i| if i % 3 == 0 { 1 } else { -1 }).collect();
        let msg = frame(TAG_SIGN, &sign::pack(&signs));
        let upd = dec.decode(&msg);
        assert!(upd.iter().zip(&signs).all(|(&u, &s)| u == s as f32));

        let trits: Vec<i8> = (0..d).map(|i| (i % 3) as i8 - 1).collect();
        let msg = frame(TAG_TERN, &tern::pack(&trits));
        let upd = dec.decode(&msg);
        assert!(upd.iter().zip(&trits).all(|(&u, &t)| u == t as f32));

        let n = 5usize;
        let sums: Vec<i32> = (0..d).map(|i| (i as i32 % (n as i32 + 1)) * 2 - n as i32).collect();
        let mut msg = vec![TAG_INTAVG];
        msg.extend_from_slice(&(n as u16).to_le_bytes());
        msg.extend_from_slice(&intavg::pack(&sums, n));
        let upd = dec.decode(&msg);
        assert!(upd
            .iter()
            .zip(&sums)
            .all(|(&u, &s)| (u - s as f32 / n as f32).abs() < 1e-7));
    }

    #[test]
    fn analytic_bits_match_comm_mod_formulas() {
        let hp = StrategyHyper::default();
        for n in [1usize, 2, 3, 4, 8, 16, 32, 33] {
            let mavo = by_name("d-lion-mavo", &hp).unwrap();
            assert_eq!(mavo.uplink_bits_per_param(n), 1.0);
            assert_eq!(
                mavo.downlink_bits_per_param(n),
                if n % 2 == 1 { 1.0 } else { 1.6 }
            );
            let avg = by_name("d-lion-avg", &hp).unwrap();
            assert_eq!(avg.downlink_bits_per_param(n), bits_for_count(n) as f64);
            let tg = by_name("terngrad", &hp).unwrap();
            assert_eq!(tg.uplink_bits_per_param(n), 1.6);
            assert_eq!(
                tg.downlink_bits_per_param(n),
                intavg::bits_for_range(-(n as i32), n as i32) as f64
            );
            let g = by_name("g-lion", &hp).unwrap();
            assert_eq!(g.uplink_bits_per_param(n), 32.0);
            assert_eq!(g.downlink_bits_per_param(n), 32.0);
        }
    }
}
