//! Momentum-synchronized Distributed Lion (Lion Cub's momentum-sync
//! direction, Ishikawa et al. 2024; Distributed Sign Momentum, Yu et al.
//! 2024).
//!
//! Plain D-Lion keeps each worker's Lion momentum private forever; under
//! heterogeneous (non-iid) shards the momenta slowly drift apart and the
//! majority vote degrades. This variant re-synchronizes them every
//! `msync_every` rounds by shipping a quantized momentum frame alongside
//! the usual 1-bit update:
//!
//! * **Ordinary round** — bit-identical to `d-lion-mavo`: `[TAG_SIGN]`
//!   uplink into the shared `SignVoteServer`, majority-vote downlink.
//! * **Sync round** (every `msync_every`-th, i.e. when
//!   `(step+1) % msync_every == 0`) — the worker appends its
//!   just-advanced momentum as a bf16 payload ([`crate::comm::half`]):
//!   `[TAG_SIGN_MOM][sign payload][bf16 momentum]`. The server feeds the
//!   sign part through the normal vote, averages the decoded momenta in
//!   f32, and broadcasts `[TAG_MSYNC_DOWN][vote frame][bf16 mean]`.
//!   Every worker overwrites its momentum with the decoded bf16 mean, so
//!   worker momenta are **bitwise equal** after every sync round (they
//!   all decode the same broadcast bytes).
//!
//! Amortized bandwidth (Table-1 accounting): the bf16 frame adds
//! 16/msync_every bits/param to each direction on top of D-Lion MaVo's
//! 1-bit uplink and 1/1.6-bit downlink.

use super::{
    frame, sign_family_downlink_bits, Aggregation, ServerLogic, SignVoteServer, Strategy,
    UpdateDecoder, WorkerLogic, TAG_INTAVG, TAG_MSYNC_DOWN, TAG_SIGN, TAG_SIGN_MOM, TAG_TERN,
};
use crate::comm::{half, intavg, sign, tern};
use crate::optim::lion::Lion;
use crate::optim::LionParams;

/// Is `step` a momentum-sync round for the given cadence?
#[inline]
pub fn is_sync_round(step: usize, msync_every: usize) -> bool {
    msync_every > 0 && (step + 1) % msync_every == 0
}

/// Byte length of the inner vote frame at the head of a
/// `TAG_MSYNC_DOWN` downlink (`d`-parameter model; reads the intavg
/// worker count from the frame itself).
fn inner_frame_len(inner: &[u8], d: usize) -> usize {
    match inner[0] {
        TAG_SIGN => 1 + sign::packed_len(d),
        TAG_TERN => 1 + tern::packed_len(d),
        TAG_INTAVG => {
            let n = super::read_u16(inner, 1) as usize;
            3 + intavg::packed_len(d, n)
        }
        t => panic!("unexpected inner msync tag {t}"),
    }
}

/// Momentum-synchronized D-Lion strategy (factory). Registry name
/// `d-lion-msync`.
pub struct DLionMsync {
    pub hp: LionParams,
    pub agg: Aggregation,
    /// sync cadence in rounds (0 disables sync — degenerates to D-Lion).
    pub msync_every: usize,
}

impl DLionMsync {
    pub fn new(hp: LionParams, agg: Aggregation, msync_every: usize) -> Self {
        DLionMsync { hp, agg, msync_every }
    }
}

struct MsyncWorker {
    lion: Lion,
    weight_decay: f32,
    msync_every: usize,
    decoder: UpdateDecoder,
}

impl WorkerLogic for MsyncWorker {
    fn encode(&mut self, grads: &[f32], _lr: f32, step: usize) -> Vec<u8> {
        if is_sync_round(step, self.msync_every) {
            let packed = self.lion.encode_fused(grads);
            let mut msg =
                Vec::with_capacity(1 + packed.len() + half::packed_len(self.lion.momentum.len()));
            msg.push(TAG_SIGN_MOM);
            msg.extend_from_slice(&packed);
            msg.extend_from_slice(&half::pack(&self.lion.momentum));
            msg
        } else {
            frame(TAG_SIGN, &self.lion.encode_fused(grads))
        }
    }

    fn apply(&mut self, params: &mut [f32], downlink: &[u8], lr: f32, step: usize) {
        if is_sync_round(step, self.msync_every) {
            assert_eq!(downlink[0], TAG_MSYNC_DOWN, "msync expects a sync downlink");
            let d = params.len();
            let inner = &downlink[1..];
            let ilen = inner_frame_len(inner, d);
            let update = self.decoder.decode(&inner[..ilen]);
            Lion::apply_aggregated(params, update, lr, self.weight_decay);
            // Overwrite the local momentum with the broadcast mean: every
            // worker decodes the same bytes, so momenta become bitwise
            // equal here.
            half::unpack_into(&inner[ilen..], &mut self.lion.momentum);
        } else {
            let update = self.decoder.decode(downlink);
            Lion::apply_aggregated(params, update, lr, self.weight_decay);
        }
    }

    fn momentum(&self) -> Option<&[f32]> {
        Some(&self.lion.momentum)
    }
}

struct MsyncServer {
    vote: SignVoteServer,
    nworkers: usize,
    msync_every: usize,
    mom_acc: Vec<f32>,
}

impl ServerLogic for MsyncServer {
    fn aggregate(&mut self, uplinks: &[Vec<u8>], lr: f32, step: usize) -> Vec<u8> {
        if !is_sync_round(step, self.msync_every) {
            return self.vote.aggregate(uplinks, lr, step);
        }
        assert_eq!(uplinks.len(), self.nworkers, "uplink count mismatch");
        let d = self.mom_acc.len();
        let sign_len = sign::packed_len(d);
        self.mom_acc.iter_mut().for_each(|a| *a = 0.0);
        let mut sign_frames: Vec<Vec<u8>> = Vec::with_capacity(self.nworkers);
        for up in uplinks {
            assert_eq!(up[0], TAG_SIGN_MOM, "msync server expects sign+momentum uplinks");
            sign_frames.push(frame(TAG_SIGN, &up[1..1 + sign_len]));
            half::accumulate(&up[1 + sign_len..], &mut self.mom_acc);
        }
        let inv = 1.0 / self.nworkers as f32;
        for a in self.mom_acc.iter_mut() {
            *a *= inv;
        }
        let inner = self.vote.aggregate(&sign_frames, lr, step);
        let mut msg = Vec::with_capacity(1 + inner.len() + half::packed_len(d));
        msg.push(TAG_MSYNC_DOWN);
        msg.extend_from_slice(&inner);
        msg.extend_from_slice(&half::pack(&self.mom_acc));
        msg
    }
}

impl Strategy for DLionMsync {
    fn name(&self) -> String {
        "d-lion-msync".into()
    }

    fn make_worker(&self, _worker: usize, _nworkers: usize, dim: usize) -> Box<dyn WorkerLogic> {
        Box::new(MsyncWorker {
            lion: Lion::new(dim, self.hp),
            weight_decay: self.hp.weight_decay,
            msync_every: self.msync_every,
            decoder: UpdateDecoder::new(dim),
        })
    }

    fn make_server(&self, nworkers: usize, dim: usize) -> Box<dyn ServerLogic> {
        Box::new(MsyncServer {
            vote: SignVoteServer::new(nworkers, dim, self.agg),
            nworkers,
            msync_every: self.msync_every,
            mom_acc: vec![0.0; dim],
        })
    }

    /// Amortized over the cadence: 1-bit sign + a 16-bit bf16 momentum
    /// frame every `msync_every` rounds.
    fn uplink_bits_per_param(&self, _nworkers: usize) -> f64 {
        let sync = if self.msync_every > 0 { 16.0 / self.msync_every as f64 } else { 0.0 };
        1.0 + sync
    }

    fn downlink_bits_per_param(&self, nworkers: usize) -> f64 {
        let sync = if self.msync_every > 0 { 16.0 / self.msync_every as f64 } else { 0.0 };
        sign_family_downlink_bits(self.agg, nworkers) + sync
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mk(every: usize) -> DLionMsync {
        DLionMsync::new(
            LionParams { beta1: 0.9, beta2: 0.99, weight_decay: 0.01 },
            Aggregation::MajorityVote,
            every,
        )
    }

    fn rand_grads(rng: &mut Rng, n: usize, d: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                g
            })
            .collect()
    }

    #[test]
    fn momenta_bitwise_equal_after_sync_round() {
        // Diverge momenta with per-worker gradients, then check through
        // the wire: the sync round after a resync, fed *identical*
        // gradients, must produce bitwise-identical bf16 momentum
        // payloads from every worker (possible only if the resynced
        // momenta were bitwise equal).
        let (d, n, every) = (67, 3, 2);
        let strat = mk(every);
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut server = strat.make_server(n, d);
        let mut params: Vec<Vec<f32>> = vec![vec![0.2f32; d]; n];
        let mut rng = Rng::new(0x515);
        // steps 0..=1: per-worker grads, momenta diverge; step 1 syncs.
        for step in 0..2 {
            let grads = rand_grads(&mut rng, n, d);
            super::super::run_round(&mut workers, server.as_mut(), &mut params, &grads, 0.01, step);
        }
        // step 2 (ordinary), step 3 (sync): identical gradient everywhere.
        let mut shared = vec![0.0f32; d];
        rng.fill_normal(&mut shared, 1.0);
        let grads = vec![shared; n];
        super::super::run_round(&mut workers, server.as_mut(), &mut params, &grads, 0.01, 2);
        let ups: Vec<Vec<u8>> =
            workers.iter_mut().zip(&grads).map(|(w, g)| w.encode(g, 0.01, 3)).collect();
        let sign_len = sign::packed_len(d);
        for up in &ups {
            assert_eq!(up[0], TAG_SIGN_MOM);
            assert_eq!(
                up[1 + sign_len..],
                ups[0][1 + sign_len..],
                "momentum payloads differ after resync"
            );
        }
        // Sanity: before any sync, divergent grads yield divergent momenta.
        let strat2 = mk(1); // sync every round => first round already ships momenta
        let mut w2: Vec<_> = (0..n).map(|i| strat2.make_worker(i, n, d)).collect();
        let grads = rand_grads(&mut rng, n, d);
        let ups2: Vec<Vec<u8>> =
            w2.iter_mut().zip(&grads).map(|(w, g)| w.encode(g, 0.01, 0)).collect();
        assert!(
            (1..n).any(|w| ups2[w][1 + sign_len..] != ups2[0][1 + sign_len..]),
            "divergent grads should give divergent momentum frames"
        );
    }

    #[test]
    fn ordinary_rounds_are_bitwise_dlion() {
        // With the sync cadence never firing inside the horizon, msync
        // must reproduce plain d-lion-mavo trajectories bit-for-bit.
        let (d, n) = (41, 3);
        let ms = mk(1000);
        let dl = super::super::DLion::new(ms.hp, Aggregation::MajorityVote);
        let mut wa: Vec<_> = (0..n).map(|i| ms.make_worker(i, n, d)).collect();
        let mut wb: Vec<_> = (0..n).map(|i| dl.make_worker(i, n, d)).collect();
        let mut sa = ms.make_server(n, d);
        let mut sb = dl.make_server(n, d);
        let mut pa: Vec<Vec<f32>> = vec![vec![0.3f32; d]; n];
        let mut pb = pa.clone();
        let mut rng = Rng::new(0x516);
        for step in 0..30 {
            let grads = rand_grads(&mut rng, n, d);
            super::super::run_round(&mut wa, sa.as_mut(), &mut pa, &grads, 0.01, step);
            super::super::run_round(&mut wb, sb.as_mut(), &mut pb, &grads, 0.01, step);
        }
        assert_eq!(pa, pb);
    }

    #[test]
    fn sync_round_frames_carry_the_bf16_momentum() {
        let (d, n, every) = (30, 2, 3);
        let strat = mk(every);
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut server = strat.make_server(n, d);
        let mut params: Vec<Vec<f32>> = vec![vec![0.0f32; d]; n];
        let mut rng = Rng::new(0x517);
        for step in 0..6 {
            let grads = rand_grads(&mut rng, n, d);
            let ups: Vec<Vec<u8>> =
                workers.iter_mut().zip(&grads).map(|(w, g)| w.encode(g, 0.01, step)).collect();
            let expect_sync = is_sync_round(step, every);
            for up in &ups {
                if expect_sync {
                    assert_eq!(up[0], TAG_SIGN_MOM, "step {step}");
                    assert_eq!(up.len(), 1 + sign::packed_len(d) + half::packed_len(d));
                } else {
                    assert_eq!(up[0], TAG_SIGN, "step {step}");
                    assert_eq!(up.len(), 1 + sign::packed_len(d));
                }
            }
            let down = server.aggregate(&ups, 0.01, step);
            if expect_sync {
                assert_eq!(down[0], TAG_MSYNC_DOWN);
            }
            for (w, p) in workers.iter_mut().zip(params.iter_mut()) {
                w.apply(p, &down, 0.01, step);
            }
            for w in 1..n {
                assert_eq!(params[0], params[w], "replica divergence at step {step}");
            }
        }
    }

    #[test]
    fn amortized_bits_model() {
        let s = mk(8);
        assert_eq!(s.uplink_bits_per_param(3), 1.0 + 2.0);
        assert_eq!(s.downlink_bits_per_param(3), 1.0 + 2.0);
        assert_eq!(s.downlink_bits_per_param(4), 1.6 + 2.0);
        let never = mk(0);
        assert_eq!(never.uplink_bits_per_param(3), 1.0);
    }
}
