//! Bandwidth-aware strategy selection — the Lion Cub observation that
//! the best compressor depends on the link, made operational: wrap two
//! registered strategies (a *cheap* one and a *rich* one) and pick
//! per-round whichever the link budget affords.
//!
//! The selector is a deterministic token bucket over the strategies'
//! analytic Table-1 models ([`Strategy::uplink_bits_per_param`] +
//! [`Strategy::downlink_bits_per_param`]). Every round must spend at
//! least the cheap arm's cost, so the bucket accrues the *net* credit
//! `link_budget − cheap` per round (clamped to `[0, rich − cheap]`);
//! when the credit covers the rich arm's surcharge `rich − cheap`, the
//! rich round runs and the surcharge is deducted. Worker and server
//! replay the identical schedule (it is a pure function of the budget
//! and the two cost models), so no selection bit ever crosses the wire
//! — the frames are the wrapped strategies' frames, unchanged.
//!
//! This makes `link_budget` a true cap: long-run spend is
//! `min(max(budget, cheap), rich)` bits/param/round — feasible budgets
//! are met exactly (header slack aside), budgets below the cheap cost
//! degenerate to always-cheap (the bucket never accrues), and budgets
//! at or above the rich cost run rich every round.
//!
//! Each round's gradient flows through the **chosen arm only** — the
//! idle arm's `encode` is never called, so strategies whose encode
//! assumes its frame ships (residual accumulators like DGC/GradDrop or
//! the EF variants: they clear sent mass, or bank exactly the
//! compression error) keep their invariants intact. The trade-off is
//! that each arm's optimizer state tracks only the subsequence of
//! rounds it served, which is the honest semantics of per-round
//! selection.

use super::{ServerLogic, Strategy, WorkerLogic};

/// Deterministic token-bucket schedule shared by workers, the server,
/// and the analytic bandwidth model.
#[derive(Clone, Copy, Debug)]
pub struct BucketSchedule {
    /// net credit accrued per round: budget − cheap cost (bits/param).
    gain: f64,
    /// rich arm's surcharge over the cheap arm: rich − cheap cost.
    surcharge: f64,
    credit: f64,
}

impl BucketSchedule {
    pub fn new(budget: f64, cheap_cost: f64, rich_cost: f64) -> Self {
        BucketSchedule {
            gain: budget - cheap_cost,
            surcharge: rich_cost - cheap_cost,
            credit: 0.0,
        }
    }

    /// Advance one round; returns true when the rich strategy runs.
    /// Order matters: accrue, fire, deduct, and only then clamp the
    /// leftover to `[0, surcharge]` — clamping before the fire check
    /// would destroy earned credit and systematically underspend
    /// budgets whose net gain does not divide the surcharge. The final
    /// clamp keeps an infeasible budget (below the cheap cost) from
    /// accruing and bounds any banked burst to one rich round. A
    /// non-positive surcharge (the "rich" arm is no costlier than the
    /// cheap one) always runs rich.
    pub fn next(&mut self) -> bool {
        self.accrue();
        let rich = self.affords();
        self.settle(rich);
        rich
    }

    /// Accrue one round's net credit (the first phase of
    /// [`BucketSchedule::next`], split out so composite schedules — the
    /// mixed per-link selector runs one bucket per hop — can gate the
    /// fire decision on several buckets at once).
    pub fn accrue(&mut self) {
        self.credit += self.gain;
    }

    /// Does the banked credit cover the rich surcharge right now?
    pub fn affords(&self) -> bool {
        self.credit >= self.surcharge
    }

    /// Deduct the surcharge if the rich round `fired`, then clamp the
    /// leftover (the closing phase of [`BucketSchedule::next`]).
    pub fn settle(&mut self, fired: bool) {
        if fired {
            self.credit -= self.surcharge;
        }
        self.credit = self.credit.clamp(0.0, self.surcharge.max(0.0));
    }
}

/// Bandwidth-aware meta-strategy (factory). Registry names:
/// `bandwidth-aware` (defaults to wrapping `d-lion-mavo` and `g-lion`)
/// or `bandwidth-aware(<cheap>,<rich>)` for any two registered names.
pub struct BandwidthAware {
    pub cheap: Box<dyn Strategy>,
    pub rich: Box<dyn Strategy>,
    /// link budget in bits/param/round, uplink + downlink combined.
    pub link_budget: f64,
}

impl BandwidthAware {
    pub fn new(cheap: Box<dyn Strategy>, rich: Box<dyn Strategy>, link_budget: f64) -> Self {
        BandwidthAware { cheap, rich, link_budget }
    }

    /// Round cost of a strategy under the selector's accounting.
    fn cost(s: &dyn Strategy, nworkers: usize) -> f64 {
        s.uplink_bits_per_param(nworkers) + s.downlink_bits_per_param(nworkers)
    }

    fn schedule(&self, nworkers: usize) -> BucketSchedule {
        BucketSchedule::new(
            self.link_budget,
            Self::cost(self.cheap.as_ref(), nworkers),
            Self::cost(self.rich.as_ref(), nworkers),
        )
    }

    /// The rich-round fraction over `horizon` rounds (what the analytic
    /// bits/param model amortizes over).
    fn rich_fraction(&self, nworkers: usize, horizon: usize) -> f64 {
        let mut sched = self.schedule(nworkers);
        let rich = (0..horizon).filter(|_| sched.next()).count();
        rich as f64 / horizon as f64
    }
}

struct SelectWorker {
    cheap: Box<dyn WorkerLogic>,
    rich: Box<dyn WorkerLogic>,
    sched: BucketSchedule,
    rich_now: bool,
}

impl WorkerLogic for SelectWorker {
    fn encode(&mut self, grads: &[f32], lr: f32, step: usize) -> Vec<u8> {
        self.rich_now = self.sched.next();
        // Only the chosen arm sees this round's gradient: encoding the
        // idle arm would break residual accumulators (their encode
        // assumes the frame ships) and would waste a dense encode per
        // cheap round for strategies like g-lion.
        if self.rich_now {
            self.rich.encode(grads, lr, step)
        } else {
            self.cheap.encode(grads, lr, step)
        }
    }

    fn apply(&mut self, params: &mut [f32], downlink: &[u8], lr: f32, step: usize) {
        if self.rich_now {
            self.rich.apply(params, downlink, lr, step);
        } else {
            self.cheap.apply(params, downlink, lr, step);
        }
    }
}

struct SelectServer {
    cheap: Box<dyn ServerLogic>,
    rich: Box<dyn ServerLogic>,
    sched: BucketSchedule,
}

impl ServerLogic for SelectServer {
    fn aggregate(&mut self, uplinks: &[Vec<u8>], lr: f32, step: usize) -> Vec<u8> {
        if self.sched.next() {
            self.rich.aggregate(uplinks, lr, step)
        } else {
            self.cheap.aggregate(uplinks, lr, step)
        }
    }
}

impl Strategy for BandwidthAware {
    fn name(&self) -> String {
        format!("bandwidth-aware({},{})", self.cheap.name(), self.rich.name())
    }

    fn make_worker(&self, worker: usize, nworkers: usize, dim: usize) -> Box<dyn WorkerLogic> {
        Box::new(SelectWorker {
            cheap: self.cheap.make_worker(worker, nworkers, dim),
            rich: self.rich.make_worker(worker, nworkers, dim),
            sched: self.schedule(nworkers),
            rich_now: false,
        })
    }

    fn make_server(&self, nworkers: usize, dim: usize) -> Box<dyn ServerLogic> {
        Box::new(SelectServer {
            cheap: self.cheap.make_server(nworkers, dim),
            rich: self.rich.make_server(nworkers, dim),
            sched: self.schedule(nworkers),
        })
    }

    fn uplink_bits_per_param(&self, nworkers: usize) -> f64 {
        let f = self.rich_fraction(nworkers, AMORTIZE_HORIZON);
        f * self.rich.uplink_bits_per_param(nworkers)
            + (1.0 - f) * self.cheap.uplink_bits_per_param(nworkers)
    }

    fn downlink_bits_per_param(&self, nworkers: usize) -> f64 {
        let f = self.rich_fraction(nworkers, AMORTIZE_HORIZON);
        f * self.rich.downlink_bits_per_param(nworkers)
            + (1.0 - f) * self.cheap.downlink_bits_per_param(nworkers)
    }
}

/// Horizon the analytic model amortizes the schedule over. The bucket
/// schedule is eventually periodic with a short period, so this is far
/// past mixing for any realistic budget. Shared with the mixed per-link
/// selector ([`super::mixed`]), which amortizes its dual-bucket
/// schedule the same way.
pub(crate) const AMORTIZE_HORIZON: usize = 10_000;

#[cfg(test)]
mod tests {
    use super::super::{by_name, run_round, StrategyHyper};
    use super::*;
    use crate::util::Rng;

    fn mk(budget: f32) -> Box<dyn Strategy> {
        let hp = StrategyHyper { link_budget: budget, ..Default::default() };
        by_name("bandwidth-aware(d-lion-mavo,g-lion)", &hp).unwrap()
    }

    #[test]
    fn bucket_alternates_at_half_rich_budget() {
        // cheap = d-lion-mavo odd N (1+1=2), rich = g-lion (64). Budget 33
        // nets 31 credit/round against a 62 surcharge: rich every other
        // round exactly, average spend (2+64)/2 = 33 = the budget.
        let mut s = BucketSchedule::new(33.0, 2.0, 64.0);
        let pattern: Vec<bool> = (0..8).map(|_| s.next()).collect();
        assert_eq!(pattern, vec![false, true, false, true, false, true, false, true]);
    }

    #[test]
    fn degenerate_budgets() {
        // Budget equal to the cheap cost: zero net gain, never rich.
        let mut s = BucketSchedule::new(2.0, 2.0, 64.0);
        assert!((0..320).all(|_| !s.next()));
        // Budget below the cheap cost: infeasible, still never rich.
        let mut s = BucketSchedule::new(1.0, 2.0, 64.0);
        assert!((0..64).all(|_| !s.next()));
        // Budget at/above the rich cost: always rich.
        let mut s = BucketSchedule::new(64.0, 2.0, 64.0);
        assert!((0..16).all(|_| s.next()));
        // Slightly feasible: gain 2 vs surcharge 62 → rich every 31st.
        let mut s = BucketSchedule::new(4.0, 2.0, 64.0);
        let fired = (0..124).filter(|_| s.next()).count();
        assert_eq!(fired, 4, "4 rich rounds in 124 at 2 net bits/round");
    }

    #[test]
    fn non_divisible_budget_is_met_not_underspent() {
        // gain 40 vs surcharge 62 does not divide evenly; leftover
        // credit after a fire must carry over (not be clamped away) so
        // the long-run spend converges to the budget, not below it.
        let (budget, cheap, rich) = (42.0, 2.0, 64.0);
        let mut s = BucketSchedule::new(budget, cheap, rich);
        let rounds = 10_000;
        let fired = (0..rounds).filter(|_| s.next()).count() as f64;
        let spend = (cheap * (rounds as f64 - fired) + rich * fired) / rounds as f64;
        assert!(
            (spend - budget).abs() < 0.1,
            "long-run spend {spend:.3} should meet the {budget} budget"
        );
    }

    #[test]
    fn worker_and_server_schedules_agree_and_replicas_hold() {
        let (d, n) = (48, 3);
        let strat = mk(33.0);
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut server = strat.make_server(n, d);
        let mut params: Vec<Vec<f32>> = vec![vec![0.1f32; d]; n];
        let mut rng = Rng::new(0xBA);
        for step in 0..20 {
            let grads: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut g = vec![0.0f32; d];
                    rng.fill_normal(&mut g, 1.0);
                    g
                })
                .collect();
            let (up, _) =
                run_round(&mut workers, server.as_mut(), &mut params, &grads, 0.01, step);
            // alternating schedule: odd steps rich (dense), even cheap (sign)
            let per_worker = up / n;
            if step % 2 == 1 {
                assert_eq!(per_worker, 1 + 4 * d, "step {step}: expected dense frames");
            } else {
                assert_eq!(per_worker, 1 + d.div_ceil(8), "step {step}: expected sign frames");
            }
            for w in 1..n {
                assert_eq!(params[0], params[w], "step {step}");
            }
        }
    }

    #[test]
    fn amortized_model_is_budget_shaped() {
        let n = 3;
        // alternating: (2 + 64)/2 = 33 total; up = (1+32)/2, down likewise
        let s = mk(33.0);
        assert!((s.uplink_bits_per_param(n) - 16.5).abs() < 0.05);
        assert!((s.downlink_bits_per_param(n) - 16.5).abs() < 0.05);
        // generous budget: pure rich
        let s = mk(128.0);
        assert_eq!(s.uplink_bits_per_param(n), 32.0);
        // budget exactly the cheap cost: pure cheap, spend == budget
        let s = mk(2.0);
        assert_eq!(s.uplink_bits_per_param(n), 1.0);
        assert_eq!(s.downlink_bits_per_param(n), 1.0);
    }

    #[test]
    fn name_round_trips_through_registry() {
        let s = mk(4.0);
        assert_eq!(s.name(), "bandwidth-aware(d-lion-mavo,g-lion)");
        let again = by_name(&s.name(), &StrategyHyper::default()).unwrap();
        assert_eq!(again.name(), s.name());
    }

    /// Drive `rounds` rounds of a named selector pair and assert the
    /// replicated-parameter invariant plus schedule agreement.
    fn run_pair(name: &str, hp: &StrategyHyper, rounds: usize) -> (f64, f64) {
        let (d, n) = (96, 3);
        let strat = by_name(name, hp).unwrap();
        assert_eq!(strat.name(), name, "composite name must round-trip");
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut server = strat.make_server(n, d);
        let mut params: Vec<Vec<f32>> = vec![vec![0.1f32; d]; n];
        let mut rng = Rng::new(0xBB);
        let mut total_bits = 0.0f64;
        for step in 0..rounds {
            let grads: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut g = vec![0.0f32; d];
                    rng.fill_normal(&mut g, 1.0);
                    g
                })
                .collect();
            let (up, down) =
                run_round(&mut workers, server.as_mut(), &mut params, &grads, 0.01, step);
            total_bits += (up + down) as f64 * 8.0 / n as f64;
            for w in 1..n {
                assert_eq!(params[0], params[w], "{name}: replica divergence at step {step}");
            }
        }
        let spent = total_bits / (rounds as f64 * d as f64);
        let model = strat.uplink_bits_per_param(n) + strat.downlink_bits_per_param(n);
        (spent, model)
    }

    #[test]
    fn msync_rich_arm_pair_respects_budget_and_replicas() {
        // (d-lion-mavo, d-lion-msync): the rich arm ships bf16 momentum
        // frames. With msync_every = 1 every rich round is a sync round,
        // so the rich arm's amortized model (1+16 bits each way) equals
        // its wire cost exactly and the bucket's budget is tight. (With
        // a sparser msync cadence the arm's cost is step-indexed and
        // can misalign with the selection schedule — the model then
        // describes the cadence average, not each served round.)
        let hp = StrategyHyper { link_budget: 10.0, msync_every: 1, ..Default::default() };
        // cheap (mavo, odd n) = 2; rich (msync, every=1) = 34
        let (spent, model) = run_pair("bandwidth-aware(d-lion-mavo,d-lion-msync)", &hp, 40);
        assert!(spent <= 10.0 + 0.5, "spent {spent:.2} vs budget 10");
        assert!(model <= 10.0 + 1e-9, "model {model:.2} must respect the budget");
        assert!(model > 2.0, "some rich rounds must fire");
    }

    #[test]
    fn dgc_cheap_arm_pair_respects_budget_and_replicas() {
        // (dgc, g-lion): a sparse residual-accumulating cheap arm under
        // a dense rich arm. Warmup is disabled so DGC's wire cost sits
        // at its steady-state analytic model and the measured spend is
        // directly comparable to the budget (with warmup on, early
        // rounds ship near-dense frames the model does not budget for —
        // the bucket caps the *model*, not a warmup transient).
        let hp = StrategyHyper {
            link_budget: 40.0,
            keep_frac: 0.04,
            dgc_warmup_steps: 0,
            ..Default::default()
        };
        // cheap (dgc) = 64·0.04 + 32 = 34.56; rich (g-lion) = 64
        let (spent, model) = run_pair("bandwidth-aware(dgc,g-lion)", &hp, 40);
        assert!(model <= 40.0 + 1e-9, "model {model:.2} must respect the budget");
        assert!(model > 34.56, "some rich rounds must fire");
        // headers (sparse frame head, tags) ride on top of the payload
        // model; a full extra bit/param of slack covers them
        assert!(spent <= 40.0 + 1.0, "spent {spent:.2} vs budget 40");
    }
}
