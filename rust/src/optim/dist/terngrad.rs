//! Quantized-gradient baselines.
//!
//! * [`TernGrad`] (Wen et al. 2017): stochastic ternarization
//!   t ∈ {−1,0,+1} with per-worker scale s = ‖g‖∞, 1.6d-bit uplink
//!   ([`tern`] codec; the paper's Table 1 quotes the 1.5d entropy bound).
//!   The server sums the integer trits (S ∈ {−N..N}, ⌈log2(2N+1)⌉-bit
//!   downlink via [`intavg::pack_range`]) and ships the mean scale, so
//!   workers reconstruct ĝ = s̄·S/N — the scale-sharing variant of the
//!   reference implementation.
//! * [`Qsgd`] (Alistarh et al. 2017): 8-bit stochastic fixed-point
//!   quantization with an f32 scale; dense f32 mean downlink.
//! * [`EfSignSgd`] (Karimireddy et al. 2019): 1-bit sign compression
//!   with error feedback and an ℓ1 scale; dense f32 mean downlink.
//!
//! All three apply momentum-SGD on the reconstructed mean gradient
//! (their reference training recipes), reusing [`SgdMomentum`].

use super::{
    frame, read_f32, read_u16, ServerLogic, Strategy, StrategyHyper, WorkerLogic, TAG_DENSE,
    TAG_QUANT, TAG_SIGN_SCALED, TAG_SUM_SCALED, TAG_TERN_SCALED,
};
use crate::comm::{dense, intavg, sign, tern};
use crate::optim::lion::bsign;
use crate::optim::sgd::SgdMomentum;
use crate::util::math::{l1_norm, linf_norm};
use crate::util::Rng;

/// Seed domain for the per-worker ternarization/quantization streams —
/// a fixed constant so identical runs produce identical bytes (the
/// determinism invariant) while workers stay decorrelated.
const QUANT_SEED: u64 = 0x7E26_0000;

// ---------------------------------------------------------------------------
// TernGrad
// ---------------------------------------------------------------------------

/// TernGrad strategy (factory).
pub struct TernGrad {
    pub hp: StrategyHyper,
}

impl TernGrad {
    pub fn new(hp: StrategyHyper) -> Self {
        TernGrad { hp }
    }
}

struct TernGradWorker {
    rng: Rng,
    sgd: SgdMomentum,
    trits: Vec<i8>,
    mean_grad: Vec<f32>,
}

impl WorkerLogic for TernGradWorker {
    fn encode(&mut self, grads: &[f32], _lr: f32, _step: usize) -> Vec<u8> {
        let s = linf_norm(grads) as f32;
        let inv = if s > 0.0 { 1.0 / s } else { 0.0 };
        for (t, &g) in self.trits.iter_mut().zip(grads) {
            // P[t = sign(g)] = |g|/s  (unbiased: s·E[t] = g)
            let p = (g.abs() * inv) as f64;
            *t = if self.rng.uniform() < p {
                if g >= 0.0 {
                    1
                } else {
                    -1
                }
            } else {
                0
            };
        }
        let mut msg = Vec::with_capacity(5 + tern::packed_len(self.trits.len()));
        msg.push(TAG_TERN_SCALED);
        msg.extend_from_slice(&s.to_le_bytes());
        msg.extend_from_slice(&tern::pack(&self.trits));
        msg
    }

    fn apply(&mut self, params: &mut [f32], downlink: &[u8], lr: f32, _step: usize) {
        assert_eq!(downlink[0], TAG_SUM_SCALED, "terngrad expects a scaled-sum downlink");
        let n = read_u16(downlink, 1) as usize;
        let mean_scale = read_f32(downlink, 3);
        let d = params.len();
        let sums = intavg::unpack_range(&downlink[7..], d, -(n as i32), n as i32);
        let scale = mean_scale / n as f32;
        for (o, &v) in self.mean_grad.iter_mut().zip(&sums) {
            *o = scale * v as f32;
        }
        self.sgd.apply_gradient(params, &self.mean_grad, lr);
    }
}

struct TernGradServer {
    nworkers: usize,
    trits: Vec<i8>,
    sums: Vec<i32>,
}

impl ServerLogic for TernGradServer {
    fn aggregate(&mut self, uplinks: &[Vec<u8>], _lr: f32, _step: usize) -> Vec<u8> {
        assert_eq!(uplinks.len(), self.nworkers, "uplink count mismatch");
        self.sums.iter_mut().for_each(|s| *s = 0);
        let mut scale_sum = 0.0f32;
        for up in uplinks {
            assert_eq!(up[0], TAG_TERN_SCALED, "terngrad server expects ternary uplinks");
            scale_sum += read_f32(up, 1);
            tern::unpack_into(&up[5..], &mut self.trits);
            for (s, &t) in self.sums.iter_mut().zip(&self.trits) {
                *s += t as i32;
            }
        }
        let mean_scale = scale_sum / self.nworkers as f32;
        let n = self.nworkers as i32;
        let payload = intavg::pack_range(&self.sums, -n, n);
        let mut msg = Vec::with_capacity(7 + payload.len());
        msg.push(TAG_SUM_SCALED);
        msg.extend_from_slice(&(self.nworkers as u16).to_le_bytes());
        msg.extend_from_slice(&mean_scale.to_le_bytes());
        msg.extend_from_slice(&payload);
        msg
    }
}

impl Strategy for TernGrad {
    fn name(&self) -> String {
        "terngrad".into()
    }

    fn make_worker(&self, worker: usize, _nworkers: usize, dim: usize) -> Box<dyn WorkerLogic> {
        Box::new(TernGradWorker {
            rng: Rng::new(QUANT_SEED ^ worker as u64),
            sgd: SgdMomentum::new(dim, self.hp.sgd_momentum, self.hp.weight_decay),
            trits: vec![0; dim],
            mean_grad: vec![0.0; dim],
        })
    }

    fn make_server(&self, nworkers: usize, dim: usize) -> Box<dyn ServerLogic> {
        Box::new(TernGradServer {
            nworkers,
            trits: vec![0; dim],
            sums: vec![0; dim],
        })
    }

    fn uplink_bits_per_param(&self, _nworkers: usize) -> f64 {
        tern::BITS_PER_ELEM // 1.6 (vs the 1.585-bit entropy optimum)
    }

    fn downlink_bits_per_param(&self, nworkers: usize) -> f64 {
        intavg::bits_for_range(-(nworkers as i32), nworkers as i32) as f64
    }
}

// ---------------------------------------------------------------------------
// QSGD (8-bit stochastic fixed-point)
// ---------------------------------------------------------------------------

/// QSGD strategy (factory), at the byte quantization level (s = 127).
pub struct Qsgd {
    pub hp: StrategyHyper,
}

impl Qsgd {
    pub fn new(hp: StrategyHyper) -> Self {
        Qsgd { hp }
    }
}

struct QsgdWorker {
    rng: Rng,
    sgd: SgdMomentum,
    levels: Vec<u8>,
    mean_grad: Vec<f32>,
}

impl WorkerLogic for QsgdWorker {
    fn encode(&mut self, grads: &[f32], _lr: f32, _step: usize) -> Vec<u8> {
        let s = linf_norm(grads) as f32;
        let inv = if s > 0.0 { 127.0 / s } else { 0.0 };
        for (l, &g) in self.levels.iter_mut().zip(grads) {
            let x = g.abs() * inv; // in [0, 127]
            let lo = x.floor();
            let level = lo as i32 + (self.rng.uniform() < (x - lo) as f64) as i32;
            let signed = if g >= 0.0 { level } else { -level };
            *l = (signed.clamp(-127, 127) as i8) as u8;
        }
        let mut msg = Vec::with_capacity(5 + self.levels.len());
        msg.push(TAG_QUANT);
        msg.extend_from_slice(&s.to_le_bytes());
        msg.extend_from_slice(&self.levels);
        msg
    }

    fn apply(&mut self, params: &mut [f32], downlink: &[u8], lr: f32, _step: usize) {
        assert_eq!(downlink[0], TAG_DENSE, "qsgd expects dense downlinks");
        dense::unpack_into(&downlink[1..], &mut self.mean_grad);
        self.sgd.apply_gradient(params, &self.mean_grad, lr);
    }
}

struct ScaledLevelsServer {
    nworkers: usize,
    acc: Vec<f32>,
}

impl ServerLogic for ScaledLevelsServer {
    fn aggregate(&mut self, uplinks: &[Vec<u8>], _lr: f32, _step: usize) -> Vec<u8> {
        assert_eq!(uplinks.len(), self.nworkers, "uplink count mismatch");
        self.acc.iter_mut().for_each(|a| *a = 0.0);
        for up in uplinks {
            assert_eq!(up[0], TAG_QUANT, "qsgd server expects quantized uplinks");
            let s = read_f32(up, 1);
            let unit = s / 127.0;
            for (a, &b) in self.acc.iter_mut().zip(&up[5..]) {
                *a += unit * (b as i8) as f32;
            }
        }
        let inv = 1.0 / self.nworkers as f32;
        for a in self.acc.iter_mut() {
            *a *= inv;
        }
        frame(TAG_DENSE, &dense::pack(&self.acc))
    }
}

impl Strategy for Qsgd {
    fn name(&self) -> String {
        "qsgd".into()
    }

    fn make_worker(&self, worker: usize, _nworkers: usize, dim: usize) -> Box<dyn WorkerLogic> {
        Box::new(QsgdWorker {
            rng: Rng::new(QUANT_SEED ^ 0x0515_0000 ^ worker as u64),
            sgd: SgdMomentum::new(dim, self.hp.sgd_momentum, self.hp.weight_decay),
            levels: vec![0; dim],
            mean_grad: vec![0.0; dim],
        })
    }

    fn make_server(&self, nworkers: usize, dim: usize) -> Box<dyn ServerLogic> {
        Box::new(ScaledLevelsServer { nworkers, acc: vec![0.0; dim] })
    }

    fn uplink_bits_per_param(&self, _nworkers: usize) -> f64 {
        8.0
    }

    fn downlink_bits_per_param(&self, _nworkers: usize) -> f64 {
        32.0
    }
}

// ---------------------------------------------------------------------------
// EF-SignSGD (1-bit with error feedback)
// ---------------------------------------------------------------------------

/// EF-SignSGD strategy (factory).
pub struct EfSignSgd {
    pub hp: StrategyHyper,
}

impl EfSignSgd {
    pub fn new(hp: StrategyHyper) -> Self {
        EfSignSgd { hp }
    }
}

struct EfSignSgdWorker {
    sgd: SgdMomentum,
    error: Vec<f32>,
    corrected: Vec<f32>,
    mean_grad: Vec<f32>,
}

impl WorkerLogic for EfSignSgdWorker {
    fn encode(&mut self, grads: &[f32], _lr: f32, _step: usize) -> Vec<u8> {
        let d = grads.len();
        for ((c, e), &g) in self.corrected.iter_mut().zip(&self.error).zip(grads) {
            *c = g + e;
        }
        let scale = (l1_norm(&self.corrected) / d as f64) as f32;
        // e ← p − scale·sign(p): what the 1-bit frame cannot carry
        for (e, &p) in self.error.iter_mut().zip(&self.corrected) {
            *e = p - scale * bsign(p);
        }
        let mut msg = Vec::with_capacity(5 + sign::packed_len(d));
        msg.push(TAG_SIGN_SCALED);
        msg.extend_from_slice(&scale.to_le_bytes());
        msg.extend_from_slice(&sign::pack_f32(&self.corrected));
        msg
    }

    fn apply(&mut self, params: &mut [f32], downlink: &[u8], lr: f32, _step: usize) {
        assert_eq!(downlink[0], TAG_DENSE, "ef-signsgd expects dense downlinks");
        dense::unpack_into(&downlink[1..], &mut self.mean_grad);
        self.sgd.apply_gradient(params, &self.mean_grad, lr);
    }
}

struct ScaledSignServer {
    nworkers: usize,
    trits: Vec<i8>,
    acc: Vec<f32>,
}

impl ServerLogic for ScaledSignServer {
    fn aggregate(&mut self, uplinks: &[Vec<u8>], _lr: f32, _step: usize) -> Vec<u8> {
        assert_eq!(uplinks.len(), self.nworkers, "uplink count mismatch");
        self.acc.iter_mut().for_each(|a| *a = 0.0);
        for up in uplinks {
            assert_eq!(up[0], TAG_SIGN_SCALED, "ef-signsgd server expects scaled signs");
            let scale = read_f32(up, 1);
            sign::unpack_into(&up[5..], &mut self.trits);
            for (a, &t) in self.acc.iter_mut().zip(&self.trits) {
                *a += scale * t as f32;
            }
        }
        let inv = 1.0 / self.nworkers as f32;
        for a in self.acc.iter_mut() {
            *a *= inv;
        }
        frame(TAG_DENSE, &dense::pack(&self.acc))
    }
}

impl Strategy for EfSignSgd {
    fn name(&self) -> String {
        "ef-signsgd".into()
    }

    fn make_worker(&self, _worker: usize, _nworkers: usize, dim: usize) -> Box<dyn WorkerLogic> {
        Box::new(EfSignSgdWorker {
            sgd: SgdMomentum::new(dim, self.hp.sgd_momentum, self.hp.weight_decay),
            error: vec![0.0; dim],
            corrected: vec![0.0; dim],
            mean_grad: vec![0.0; dim],
        })
    }

    fn make_server(&self, nworkers: usize, dim: usize) -> Box<dyn ServerLogic> {
        Box::new(ScaledSignServer {
            nworkers,
            trits: vec![0; dim],
            acc: vec![0.0; dim],
        })
    }

    fn uplink_bits_per_param(&self, _nworkers: usize) -> f64 {
        1.0
    }

    fn downlink_bits_per_param(&self, _nworkers: usize) -> f64 {
        32.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn terngrad_is_unbiased_in_expectation() {
        let d = 8;
        let hp = StrategyHyper::default();
        let strat = TernGrad::new(hp);
        let mut w = strat.make_worker(0, 1, d);
        let grads: Vec<f32> = vec![2.0, -1.0, 0.5, 0.0, -2.0, 1.5, -0.25, 1.0];
        let reps = 4000;
        let mut mean = vec![0.0f64; d];
        for step in 0..reps {
            let up = w.encode(&grads, 1e-3, step);
            assert_eq!(up[0], TAG_TERN_SCALED);
            let s = read_f32(&up, 1);
            assert_eq!(s, 2.0);
            let trits = tern::unpack(&up[5..], d);
            for (m, &t) in mean.iter_mut().zip(&trits) {
                *m += s as f64 * t as f64 / reps as f64;
            }
        }
        for (m, &g) in mean.iter().zip(&grads) {
            assert!((m - g as f64).abs() < 0.12, "E[s·t]={m} vs g={g}");
        }
    }

    #[test]
    fn terngrad_roundtrip_reconstructs_scaled_sum() {
        let d = 100;
        let n = 4;
        let hp = StrategyHyper::default();
        let strat = TernGrad::new(hp);
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut server = strat.make_server(n, d);
        let mut rng = Rng::new(0x7E);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                g
            })
            .collect();
        let ups: Vec<_> = workers
            .iter_mut()
            .zip(&grads)
            .map(|(w, g)| w.encode(g, 1e-3, 0))
            .collect();
        let down = server.aggregate(&ups, 1e-3, 0);
        assert_eq!(down[0], TAG_SUM_SCALED);
        assert_eq!(read_u16(&down, 1) as usize, n);
        let sums = intavg::unpack_range(&down[7..], d, -(n as i32), n as i32);
        // every sum must be reachable from n trits
        assert!(sums.iter().all(|s| s.unsigned_abs() as usize <= n));
    }

    #[test]
    fn qsgd_quantization_error_bounded_by_one_level() {
        let d = 64;
        let hp = StrategyHyper::default();
        let strat = Qsgd::new(hp);
        let mut w = strat.make_worker(0, 1, d);
        let mut server = strat.make_server(1, d);
        let mut g = vec![0.0f32; d];
        Rng::new(0x05).fill_normal(&mut g, 3.0);
        let up = w.encode(&g, 1e-3, 0);
        let down = server.aggregate(&[up], 1e-3, 0);
        let recon = dense::unpack(&down[1..]);
        let s = linf_norm(&g) as f32;
        let unit = s / 127.0;
        for (r, &x) in recon.iter().zip(&g) {
            assert!((r - x).abs() <= unit + 1e-6, "recon {r} vs {x} (unit {unit})");
        }
    }

    #[test]
    fn ef_signsgd_error_feedback_preserves_signal() {
        // With a constant gradient the error-compensated 1-bit stream's
        // running mean must converge to the true gradient.
        let d = 16;
        let hp = StrategyHyper::default();
        let strat = EfSignSgd::new(hp);
        let mut w = strat.make_worker(0, 1, d);
        let mut server = strat.make_server(1, d);
        let g: Vec<f32> = (0..d).map(|i| (i as f32 - 7.5) / 4.0).collect();
        let reps = 400;
        let mut mean = vec![0.0f64; d];
        for step in 0..reps {
            let up = w.encode(&g, 1e-3, step);
            let down = server.aggregate(&[up], 1e-3, step);
            for (m, &r) in mean.iter_mut().zip(&dense::unpack(&down[1..])) {
                *m += r as f64 / reps as f64;
            }
        }
        for (m, &x) in mean.iter().zip(&g) {
            assert!((m - x as f64).abs() < 0.05, "mean {m} vs g {x}");
        }
    }
}
