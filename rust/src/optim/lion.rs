//! Lion (EvoLved Sign Momentum) — Chen et al. 2023b, paper eq. (1).
//!
//! ```text
//! u_t     = sign(β1·m_t + (1−β1)·g_t)        // double-β interpolation
//! x_{t+1} = x_t − ε·(u_t + λ·x_t)            // update + decoupled decay
//! m_{t+1} = β2·m_t + (1−β2)·g_t              // momentum
//! ```
//!
//! `sign` here is the *binarized* sign (0 ⇒ +1) so the update is strictly
//! binary — required for the 1-bit D-Lion codec and numerically identical
//! for continuous gradients (P[blend = 0] = 0). The Pallas `lion_step`
//! kernel uses the same convention and the runtime integration test
//! checks bit-exact agreement.

use super::{LionParams, Optimizer};

/// Binarized sign: x ≥ 0 ⇒ +1 else −1.
#[inline(always)]
pub fn bsign(x: f32) -> f32 {
    // branch-free: flip on IEEE sign bit
    f32::from_bits(0x3F80_0000 | (x.to_bits() & 0x8000_0000))
}

/// Free-function form of the fused D-Lion worker encode over an
/// arbitrary *state slice*: blend-sign-pack the payload bits of
/// `momentum`/`grads` (bit 0 of `out` = lane 0 of the slice) and advance
/// the momentum, in one pass. Taking disjoint `&mut [f32]` slices
/// (rather than `&mut Lion`) is what lets `RoundEngine` split one
/// worker's momentum along the `ChunkPlan` via `split_at_mut` and encode
/// its chunks in parallel (§Perf optimization #4: the byte assembly is
/// the SWAR gather, and every output byte is stored whole so reused
/// round buffers never leak stale bits).
///
/// `momentum` and `grads` must be the same length; `out` must hold at
/// least `packed_len(grads.len())` bytes. Bit-exact with
/// [`Lion::encode_fused_range`] (which delegates here).
pub fn fused_encode_slice(
    beta1: f32,
    beta2: f32,
    momentum: &mut [f32],
    grads: &[f32],
    out: &mut [u8],
) {
    debug_assert_eq!(momentum.len(), grads.len());
    debug_assert!(out.len() >= crate::comm::sign::packed_len(grads.len()));
    let d = grads.len();
    let full = d / 8;
    let (m_head, m_tail) = momentum.split_at_mut(full * 8);
    let (g_head, g_tail) = grads.split_at(full * 8);
    let mut blend = [0.0f32; 8];
    for (ci, (mc, gc)) in m_head.chunks_exact_mut(8).zip(g_head.chunks_exact(8)).enumerate() {
        for ((b, m), &g) in blend.iter_mut().zip(mc.iter_mut()).zip(gc) {
            let m0 = *m;
            *b = beta1 * m0 + (1.0 - beta1) * g;
            *m = beta2 * m0 + (1.0 - beta2) * g;
        }
        out[ci] = crate::comm::swar::sign_byte8(&blend);
    }
    if !m_tail.is_empty() {
        let mut byte = 0u8;
        for (j, (m, &g)) in m_tail.iter_mut().zip(g_tail).enumerate() {
            let m0 = *m;
            let bl = beta1 * m0 + (1.0 - beta1) * g;
            byte |= (((bl.to_bits() >> 31) ^ 1) as u8) << j;
            *m = beta2 * m0 + (1.0 - beta2) * g;
        }
        out[full] = byte;
    }
}

/// Single-node Lion optimizer.
pub struct Lion {
    pub hp: LionParams,
    pub momentum: Vec<f32>,
}

impl Lion {
    pub fn new(dim: usize, hp: LionParams) -> Self {
        Lion { hp, momentum: vec![0.0; dim] }
    }

    /// Compute the binary update δ = bsign(β1·m + (1−β1)·g) *without*
    /// touching params or momentum (worker-side D-Lion uses this).
    pub fn peek_update(&self, grads: &[f32], out: &mut [f32]) {
        let b1 = self.hp.beta1;
        for ((o, &m), &g) in out.iter_mut().zip(&self.momentum).zip(grads) {
            *o = bsign(b1 * m + (1.0 - b1) * g);
        }
    }

    /// Advance only the momentum: m ← β2·m + (1−β2)·g.
    pub fn advance_momentum(&mut self, grads: &[f32]) {
        let b2 = self.hp.beta2;
        for (m, &g) in self.momentum.iter_mut().zip(grads) {
            *m = b2 * *m + (1.0 - b2) * g;
        }
    }

    /// Apply an externally-aggregated update Δ (D-Lion worker-side apply):
    /// x ← x − lr·(Δ + λ·x).
    pub fn apply_aggregated(params: &mut [f32], delta: &[f32], lr: f32, wd: f32) {
        for (p, &d) in params.iter_mut().zip(delta) {
            *p -= lr * (d + wd * *p);
        }
    }

    /// §Perf optimization #3 — the fused D-Lion worker hot path: compute
    /// the blend sign bits AND advance the momentum in a single pass over
    /// (m, g), writing the packed 1-bit payload directly. Replaces
    /// peek_update (blend store) + pack_f32 (blend re-read) +
    /// advance_momentum (second m/g pass): 3 passes → 1, and the d×4-byte
    /// scratch store disappears. Bit-exact with the decomposed path
    /// (tested below).
    pub fn encode_fused(&mut self, grads: &[f32]) -> Vec<u8> {
        debug_assert_eq!(grads.len(), self.momentum.len());
        self.encode_fused_range(grads, 0..grads.len())
    }

    /// Ranged variant of [`Lion::encode_fused`] for the chunked wire
    /// path: pack the blend signs of `range` (bits start at the chunk's
    /// own bit 0) and advance only `momentum[range]`. `grads` is the
    /// full gradient slice. The whole-range call is `encode_fused`
    /// itself, and disjoint ranges compose to it bit-exactly.
    pub fn encode_fused_range(&mut self, grads: &[f32], range: std::ops::Range<usize>) -> Vec<u8> {
        let (b1, b2) = (self.hp.beta1, self.hp.beta2);
        let gs = &grads[range.clone()];
        let ms = &mut self.momentum[range];
        let mut out = vec![0u8; crate::comm::sign::packed_len(gs.len())];
        fused_encode_slice(b1, b2, ms, gs, &mut out);
        out
    }
}

impl Optimizer for Lion {
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        debug_assert_eq!(params.len(), self.momentum.len());
        self.step_range(params, grads, lr, 0);
    }

    fn step_range(&mut self, params: &mut [f32], grads: &[f32], lr: f32, offset: usize) {
        debug_assert_eq!(params.len(), grads.len());
        let LionParams { beta1, beta2, weight_decay } = self.hp;
        let m = &mut self.momentum[offset..offset + grads.len()];
        for ((p, m), &g) in params.iter_mut().zip(m).zip(grads) {
            let u = bsign(beta1 * *m + (1.0 - beta1) * g);
            *p -= lr * (u + weight_decay * *p);
            *m = beta2 * *m + (1.0 - beta2) * g;
        }
    }

    fn name(&self) -> &'static str {
        "lion"
    }

    fn state_bytes(&self) -> usize {
        4 * self.momentum.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;

    #[test]
    fn bsign_convention() {
        assert_eq!(bsign(3.0), 1.0);
        assert_eq!(bsign(-3.0), -1.0);
        assert_eq!(bsign(0.0), 1.0); // binarized: zero maps to +1
        assert_eq!(bsign(-0.0), -1.0); // IEEE sign bit
        assert_eq!(bsign(f32::MIN_POSITIVE), 1.0);
    }

    #[test]
    fn bsign_matches_naive() {
        testing::forall(
            0xA1,
            256,
            |r| r.normal_f32(0.0, 10.0),
            |&x| bsign(x) == if x.is_sign_positive() { 1.0 } else { -1.0 },
        );
    }

    #[test]
    fn step_matches_manual_unroll() {
        let hp = LionParams { beta1: 0.9, beta2: 0.99, weight_decay: 0.1 };
        let mut lion = Lion::new(2, hp);
        let mut p = vec![1.0f32, -2.0];
        let g = vec![0.5f32, -0.25];
        let lr = 0.1;
        // manual: m=0 so u = sign((1-b1) g) = sign(g)
        let expect_p = [
            1.0 - lr * (1.0 + 0.1 * 1.0),
            -2.0 - lr * (-1.0 + 0.1 * -2.0),
        ];
        lion.step(&mut p, &g, lr);
        testing::assert_allclose(&p, &expect_p, 1e-7, 1e-6, "lion step");
        // momentum advanced: m = (1-b2) g
        testing::assert_allclose(
            &lion.momentum,
            &[0.01 * 0.5, 0.01 * -0.25],
            1e-8,
            1e-6,
            "lion momentum",
        );
    }

    #[test]
    fn peek_plus_apply_plus_advance_equals_step() {
        // The decomposed worker-side path (peek_update / apply_aggregated /
        // advance_momentum with N=1) must reproduce Optimizer::step exactly.
        let hp = LionParams { beta1: 0.9, beta2: 0.99, weight_decay: 0.01 };
        let mut rng = crate::util::Rng::new(0xA2);
        let d = 64;
        let mut a = Lion::new(d, hp);
        let mut b = Lion::new(d, hp);
        let mut pa = vec![0.0f32; d];
        rng.fill_normal(&mut pa, 1.0);
        let mut pb = pa.clone();
        let mut delta = vec![0.0f32; d];
        for step in 0..50 {
            let mut g = vec![0.0f32; d];
            let mut r2 = crate::util::Rng::new(1000 + step);
            r2.fill_normal(&mut g, 1.0);
            a.step(&mut pa, &g, 0.01);
            b.peek_update(&g, &mut delta);
            Lion::apply_aggregated(&mut pb, &delta, 0.01, hp.weight_decay);
            b.advance_momentum(&g);
        }
        assert_eq!(pa, pb, "decomposed path must be bit-exact");
        assert_eq!(a.momentum, b.momentum);
    }

    #[test]
    fn encode_fused_is_bit_exact_with_decomposed_path() {
        let hp = LionParams::default();
        let mut rng = crate::util::Rng::new(0xA3);
        for d in [1usize, 7, 8, 9, 64, 1000, 1003] {
            let mut a = Lion::new(d, hp);
            let mut b = Lion::new(d, hp);
            rng.fill_normal(&mut a.momentum, 0.3);
            b.momentum.copy_from_slice(&a.momentum);
            for _ in 0..5 {
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 1.0);
                let fused = a.encode_fused(&g);
                let blend: Vec<f32> = b
                    .momentum
                    .iter()
                    .zip(&g)
                    .map(|(&m, &gg)| hp.beta1 * m + (1.0 - hp.beta1) * gg)
                    .collect();
                let decomposed = crate::comm::sign::pack_f32(&blend);
                b.advance_momentum(&g);
                assert_eq!(fused, decomposed, "d={d}");
                assert_eq!(a.momentum, b.momentum, "d={d}");
            }
        }
    }

    #[test]
    fn encode_fused_range_composes_to_encode_fused() {
        // Disjoint ranged calls must update the same momentum and emit
        // payloads that splice into the whole-model payload when range
        // starts are byte-aligned (multiples of 8).
        let hp = LionParams::default();
        let mut rng = crate::util::Rng::new(0xA4);
        for d in [96usize, 101, 1003] {
            let mut a = Lion::new(d, hp);
            let mut b = Lion::new(d, hp);
            rng.fill_normal(&mut a.momentum, 0.3);
            b.momentum.copy_from_slice(&a.momentum);
            let mut g = vec![0.0f32; d];
            rng.fill_normal(&mut g, 1.0);
            let whole = a.encode_fused(&g);
            let mut spliced = Vec::new();
            let chunk = 40; // multiple of 8: chunk payloads are byte-aligned
            let mut start = 0;
            while start < d {
                let end = (start + chunk).min(d);
                spliced.extend_from_slice(&b.encode_fused_range(&g, start..end));
                start = end;
            }
            assert_eq!(spliced, whole, "d={d}");
            assert_eq!(a.momentum, b.momentum, "d={d}");
        }
    }

    #[test]
    fn step_range_composes_to_step() {
        let hp = LionParams { beta1: 0.9, beta2: 0.99, weight_decay: 0.01 };
        let d = 70;
        let mut a = Lion::new(d, hp);
        let mut b = Lion::new(d, hp);
        let mut pa = vec![0.4f32; d];
        let mut pb = pa.clone();
        let mut rng = crate::util::Rng::new(0xA5);
        for _ in 0..20 {
            let mut g = vec![0.0f32; d];
            rng.fill_normal(&mut g, 1.0);
            a.step(&mut pa, &g, 0.01);
            for start in (0..d).step_by(32) {
                let end = (start + 32).min(d);
                b.step_range(&mut pb[start..end], &g[start..end], 0.01, start);
            }
        }
        assert_eq!(pa, pb);
        assert_eq!(a.momentum, b.momentum);
    }

    #[test]
    fn weight_decay_pulls_toward_feasible_box() {
        // With zero gradient signal the iterates converge into
        // F = {x : |λ x|_inf <= 1} (Phase I, Thm 4.4).
        let hp = LionParams { beta1: 0.9, beta2: 0.99, weight_decay: 0.5 };
        let mut lion = Lion::new(1, hp);
        let mut p = vec![100.0f32];
        for _ in 0..2000 {
            lion.step(&mut p, &[0.0], 0.01);
        }
        assert!((hp.weight_decay * p[0]).abs() <= 1.0 + 1e-3, "p={}", p[0]);
    }
}
