//! Optimizers and distributed strategies.
//!
//! Two layers:
//!
//! * [`Optimizer`] — classical single-node optimizers operating on a flat
//!   f32 parameter buffer: [`lion::Lion`], [`adamw::AdamW`],
//!   [`sgd::SgdMomentum`], [`signum::Signum`]. These are the paper's
//!   eq. (1) plus the comparison baselines.
//! * [`dist`] — synchronous distributed strategies that split each step
//!   into worker-encode / server-aggregate / worker-apply message phases
//!   (Algorithm 1 in the paper and every baseline of Section 5.1).

pub mod adamw;
pub mod dist;
pub mod lion;
pub mod sgd;
pub mod signum;

/// A single-node optimizer over a flat parameter vector.
pub trait Optimizer: Send {
    /// One update: params ← params − lr·(update(grads) + decoupled wd term).
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32);

    /// Advance per-step scalar state (e.g. AdamW's bias-correction
    /// counter) once at the start of a logical step. [`Optimizer::step`]
    /// implementations call it themselves; chunked callers invoke it
    /// once before their first [`Optimizer::step_range`] call of each
    /// step. That first chunk need not start at global offset 0: under
    /// a mixed per-chunk arm assignment an optimizer may own only a
    /// subset of the parameter ranges, so the trigger is "first chunk I
    /// serve this step", not "offset == 0". Stateless-per-step
    /// optimizers keep the no-op default.
    fn begin_step(&mut self) {}

    /// Ranged update for the chunked wire path: apply one step's update
    /// to the parameter slice that starts at global index `offset`
    /// (`params`/`grads` are the chunk's views; optimizer state is
    /// indexed at `offset..offset + grads.len()`).
    ///
    /// Contract: within one logical step the caller covers each of its
    /// ranges exactly once, in ascending order, and calls
    /// [`Optimizer::begin_step`] before the first of them. The default
    /// is only valid for whole-vector calls and exists so optimizers
    /// never used through the chunked path need no override.
    fn step_range(&mut self, params: &mut [f32], grads: &[f32], lr: f32, offset: usize) {
        assert_eq!(offset, 0, "{}: no ranged step support", self.name());
        self.step(params, grads, lr);
    }

    /// Human-readable name for logs/benches.
    fn name(&self) -> &'static str;

    /// Bytes of optimizer state (paper §1: Lion halves Adam's state).
    fn state_bytes(&self) -> usize;
}

/// Hyper-parameters shared by the Lion family (Table 2 CIFAR defaults).
#[derive(Clone, Copy, Debug)]
pub struct LionParams {
    pub beta1: f32,
    pub beta2: f32,
    pub weight_decay: f32,
}

impl Default for LionParams {
    fn default() -> Self {
        // Chen et al. 2023b defaults, used throughout the paper.
        LionParams { beta1: 0.9, beta2: 0.99, weight_decay: 0.005 }
    }
}

/// Hyper-parameters for AdamW (paper Table 2 CIFAR defaults).
#[derive(Clone, Copy, Debug)]
pub struct AdamWParams {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamWParams {
    fn default() -> Self {
        AdamWParams { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0005 }
    }
}

#[cfg(test)]
mod tests {
    use super::adamw::AdamW;
    use super::lion::Lion;
    use super::sgd::SgdMomentum;
    use super::signum::Signum;
    use super::*;

    fn quad_grad(params: &[f32], out: &mut [f32]) {
        // f(x) = 0.5 * ||x - 1||^2, grad = x - 1
        for (g, &p) in out.iter_mut().zip(params) {
            *g = p - 1.0;
        }
    }

    fn converges<O: Optimizer>(mut opt: O, lr: f32, steps: usize) -> f32 {
        let d = 16;
        let mut params = vec![5.0f32; d];
        let mut grads = vec![0.0f32; d];
        for _ in 0..steps {
            quad_grad(&params, &mut grads);
            opt.step(&mut params, &grads, lr);
        }
        params.iter().map(|&p| (p - 1.0).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn all_optimizers_reduce_quadratic() {
        assert!(converges(Lion::new(16, LionParams { weight_decay: 0.0, ..Default::default() }), 0.01, 2000) < 0.1);
        assert!(converges(AdamW::new(16, AdamWParams { weight_decay: 0.0, ..Default::default() }), 0.05, 2000) < 0.1);
        assert!(converges(SgdMomentum::new(16, 0.9, 0.0), 0.1, 2000) < 0.1);
        assert!(converges(Signum::new(16, 0.9, 0.0), 0.01, 2000) < 0.1);
    }

    #[test]
    fn state_sizes_match_paper_claim() {
        // Lion stores one momentum; AdamW stores two (memory advantage, §1).
        let d = 1000;
        let lion = Lion::new(d, LionParams::default());
        let adam = AdamW::new(d, AdamWParams::default());
        assert_eq!(lion.state_bytes(), 4 * d);
        assert_eq!(adam.state_bytes(), 8 * d);
    }
}
