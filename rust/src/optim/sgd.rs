//! SGD with (heavy-ball) momentum — base optimizer for the TernGrad,
//! GradDrop, and DGC baselines (their reference implementations apply
//! plain momentum-SGD on the decompressed aggregate gradient).

use super::Optimizer;

/// SGD with momentum and decoupled weight decay.
pub struct SgdMomentum {
    pub momentum: f32,
    pub weight_decay: f32,
    pub velocity: Vec<f32>,
}

impl SgdMomentum {
    pub fn new(dim: usize, momentum: f32, weight_decay: f32) -> Self {
        SgdMomentum { momentum, weight_decay, velocity: vec![0.0; dim] }
    }

    /// Apply a raw (already aggregated) gradient with this optimizer's
    /// state — used worker-side by the compression baselines.
    pub fn apply_gradient(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        for ((p, v), &g) in params.iter_mut().zip(self.velocity.iter_mut()).zip(grad) {
            *v = self.momentum * *v + g;
            *p -= lr * (*v + self.weight_decay * *p);
        }
    }
}

impl Optimizer for SgdMomentum {
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        self.apply_gradient(params, grads, lr);
    }

    fn step_range(&mut self, params: &mut [f32], grads: &[f32], lr: f32, offset: usize) {
        debug_assert_eq!(params.len(), grads.len());
        let v = &mut self.velocity[offset..offset + grads.len()];
        for ((p, v), &g) in params.iter_mut().zip(v).zip(grads) {
            *v = self.momentum * *v + g;
            *p -= lr * (*v + self.weight_decay * *p);
        }
    }

    fn name(&self) -> &'static str {
        "sgd-momentum"
    }

    fn state_bytes(&self) -> usize {
        4 * self.velocity.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_momentum_is_plain_sgd() {
        let mut opt = SgdMomentum::new(2, 0.0, 0.0);
        let mut p = vec![1.0f32, 2.0];
        opt.step(&mut p, &[0.5, -0.5], 0.1);
        assert_eq!(p, vec![0.95, 2.05]);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut opt = SgdMomentum::new(1, 0.9, 0.0);
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[1.0], 1.0); // v=1, p=-1
        opt.step(&mut p, &[1.0], 1.0); // v=1.9, p=-2.9
        assert!((p[0] + 2.9).abs() < 1e-6, "p={}", p[0]);
    }
}
