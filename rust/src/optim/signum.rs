//! Signum (SignSGD with momentum, Bernstein et al. 2018) — the paper's
//! Figure-4 ablation baseline (D-SIGNUM). Lion generalizes Signum: with
//! β1 = β2 = β Lion's double-β blend collapses to Signum's single
//! momentum sign.

use super::lion::bsign;
use super::Optimizer;

/// Free-function form of the fused Signum worker encode over an
/// arbitrary *state slice*: advance `momentum` (m ← β·m + (1−β)·g) and
/// pack the signs of the fresh momentum in the same pass (bit 0 of
/// `out` = lane 0 of the slice). The split-borrow counterpart of
/// [`crate::optim::lion::fused_encode_slice`] — `RoundEngine` hands it
/// disjoint momentum slices along the `ChunkPlan` for intra-worker
/// chunk-parallel encode. Bit-exact with
/// [`Signum::update_and_peek_range`] + `sign::pack_f32` of the result
/// (bsign preserves the IEEE sign bit).
pub fn signum_encode_slice(beta: f32, momentum: &mut [f32], grads: &[f32], out: &mut [u8]) {
    debug_assert_eq!(momentum.len(), grads.len());
    debug_assert!(out.len() >= crate::comm::sign::packed_len(grads.len()));
    let d = grads.len();
    let full = d / 8;
    let (m_head, m_tail) = momentum.split_at_mut(full * 8);
    let (g_head, g_tail) = grads.split_at(full * 8);
    let mut fresh = [0.0f32; 8];
    for (ci, (mc, gc)) in m_head.chunks_exact_mut(8).zip(g_head.chunks_exact(8)).enumerate() {
        for ((f, m), &g) in fresh.iter_mut().zip(mc.iter_mut()).zip(gc) {
            *m = beta * *m + (1.0 - beta) * g;
            *f = *m;
        }
        out[ci] = crate::comm::swar::sign_byte8(&fresh);
    }
    if !m_tail.is_empty() {
        let mut byte = 0u8;
        for (j, (m, &g)) in m_tail.iter_mut().zip(g_tail).enumerate() {
            *m = beta * *m + (1.0 - beta) * g;
            byte |= (((m.to_bits() >> 31) ^ 1) as u8) << j;
        }
        out[full] = byte;
    }
}

/// Signum: m ← β·m + (1−β)·g ; x ← x − lr·(sign(m) + λx).
pub struct Signum {
    pub beta: f32,
    pub weight_decay: f32,
    pub momentum: Vec<f32>,
}

impl Signum {
    pub fn new(dim: usize, beta: f32, weight_decay: f32) -> Self {
        Signum { beta, weight_decay, momentum: vec![0.0; dim] }
    }

    /// Worker-side: compute binary update into `out` *after* advancing
    /// momentum (Signum signs the freshly-updated momentum).
    pub fn update_and_peek(&mut self, grads: &[f32], out: &mut [f32]) {
        self.update_and_peek_range(grads, 0..grads.len(), out);
    }

    /// Ranged variant for the chunked wire path: advance and sign only
    /// `momentum[range]`; `grads` is the full slice, `out` holds
    /// `range.len()` elements.
    pub fn update_and_peek_range(
        &mut self,
        grads: &[f32],
        range: std::ops::Range<usize>,
        out: &mut [f32],
    ) {
        let beta = self.beta;
        let gs = &grads[range.clone()];
        for ((m, &g), o) in self.momentum[range].iter_mut().zip(gs).zip(out.iter_mut()) {
            *m = beta * *m + (1.0 - beta) * g;
            *o = bsign(*m);
        }
    }
}

impl Optimizer for Signum {
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        let beta = self.beta;
        let wd = self.weight_decay;
        for ((p, m), &g) in params.iter_mut().zip(self.momentum.iter_mut()).zip(grads) {
            *m = beta * *m + (1.0 - beta) * g;
            *p -= lr * (bsign(*m) + wd * *p);
        }
    }

    fn name(&self) -> &'static str {
        "signum"
    }

    fn state_bytes(&self) -> usize {
        4 * self.momentum.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::lion::Lion;
    use crate::optim::LionParams;
    use crate::util::Rng;

    #[test]
    fn signum_is_lion_with_equal_betas() {
        // Lion with β1 = β2 = β signs (β·m + (1−β)g) which equals the
        // *new* Signum momentum — trajectories must agree bit-exactly.
        let beta = 0.95;
        let d = 32;
        let mut lion = Lion::new(d, LionParams { beta1: beta, beta2: beta, weight_decay: 0.0 });
        let mut signum = Signum::new(d, beta, 0.0);
        let mut pa = vec![0.5f32; d];
        let mut pb = pa.clone();
        let mut rng = Rng::new(0xB1);
        for _ in 0..100 {
            let mut g = vec![0.0f32; d];
            rng.fill_normal(&mut g, 1.0);
            lion.step(&mut pa, &g, 0.01);
            signum.step(&mut pb, &g, 0.01);
        }
        assert_eq!(pa, pb);
    }

    #[test]
    fn updates_are_binary() {
        let mut s = Signum::new(4, 0.99, 0.0);
        let mut out = vec![0.0f32; 4];
        s.update_and_peek(&[1.0, -1.0, 0.5, -0.0], &mut out);
        assert!(out.iter().all(|&u| u == 1.0 || u == -1.0));
    }
}
