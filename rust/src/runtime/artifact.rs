//! Artifact manifest: the contract between `python/compile/aot.py`
//! (which writes `artifacts/manifest.json`) and the rust runtime
//! (which loads and executes the HLO artifacts it describes).

use crate::error::{DlionError, Result};
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One named tensor (parameter or artifact I/O).
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
    /// offset into the flat f32 parameter vector
    pub offset: usize,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled executable.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<ParamSpec>,
    pub outputs: Vec<ParamSpec>,
}

/// The full manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model_name: String,
    pub config: BTreeMap<String, f64>,
    pub params: Vec<ParamSpec>,
    pub flat_dim: usize,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Declared execution backend (`"native"`, `"pjrt"`); empty for
    /// legacy (aot.py v1) manifests — see
    /// [`crate::runtime::backend::select_backend_name`].
    pub backend: String,
    /// Generation-input hash: an unchanged `source_hash` means
    /// `gen-artifacts` may no-op (the recompilation cache key).
    pub source_hash: String,
    /// FNV-1a 64 hex digests of payload files in `dir`, keyed by file
    /// name; verified by [`Manifest::verify_checksums`] before anything
    /// executes.
    pub checksums: BTreeMap<String, String>,
}

fn parse_tensor(j: &Json, with_offset: bool) -> Result<ParamSpec> {
    let name = j
        .get("name")
        .and_then(|v| v.as_str())
        .ok_or_else(|| DlionError::Artifact("tensor missing name".into()))?
        .to_string();
    let shape = j
        .get("shape")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| DlionError::Artifact(format!("tensor {name} missing shape")))?
        .iter()
        .map(|v| v.as_usize().unwrap_or(0))
        .collect();
    let dtype = j
        .get("dtype")
        .and_then(|v| v.as_str())
        .unwrap_or("f32")
        .to_string();
    let offset = if with_offset {
        j.get("offset")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| DlionError::Artifact(format!("param {name} missing offset")))?
    } else {
        0
    };
    Ok(ParamSpec { name, shape, dtype, offset })
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let j = json::parse(text)?;
        let model_name = j
            .get("model")
            .and_then(|v| v.as_str())
            .unwrap_or("unknown")
            .to_string();
        let mut config = BTreeMap::new();
        if let Some(cfg) = j.get("config").and_then(|v| v.as_obj()) {
            for (k, v) in cfg {
                if let Some(x) = v.as_f64() {
                    config.insert(k.clone(), x);
                }
            }
        }
        let params: Vec<ParamSpec> = j
            .get("params")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().map(|p| parse_tensor(p, true)).collect::<Result<Vec<_>>>())
            .transpose()?
            .unwrap_or_default();
        let flat_dim = j.get("flat_dim").and_then(|v| v.as_usize()).unwrap_or(0);
        // validate contiguous layout
        let mut expect = 0usize;
        for p in &params {
            if p.offset != expect {
                return Err(DlionError::Artifact(format!(
                    "param {} offset {} != expected {expect}",
                    p.name, p.offset
                )));
            }
            expect += p.numel();
        }
        if flat_dim != expect {
            return Err(DlionError::Artifact(format!(
                "flat_dim {flat_dim} != sum of param sizes {expect}"
            )));
        }
        let mut artifacts = BTreeMap::new();
        if let Some(arts) = j.get("artifacts").and_then(|v| v.as_obj()) {
            for (name, a) in arts {
                let file = a
                    .get("file")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| DlionError::Artifact(format!("artifact {name} missing file")))?
                    .to_string();
                let inputs = a
                    .get("inputs")
                    .and_then(|v| v.as_arr())
                    .map(|ar| ar.iter().map(|t| parse_tensor(t, false)).collect::<Result<Vec<_>>>())
                    .transpose()?
                    .unwrap_or_default();
                let outputs = a
                    .get("outputs")
                    .and_then(|v| v.as_arr())
                    .map(|ar| ar.iter().map(|t| parse_tensor(t, false)).collect::<Result<Vec<_>>>())
                    .transpose()?
                    .unwrap_or_default();
                artifacts.insert(
                    name.clone(),
                    ArtifactSpec { name: name.clone(), file, inputs, outputs },
                );
            }
        }
        let backend = j.get("backend").and_then(|v| v.as_str()).unwrap_or("").to_string();
        let source_hash =
            j.get("source_hash").and_then(|v| v.as_str()).unwrap_or("").to_string();
        let mut checksums = BTreeMap::new();
        if let Some(cs) = j.get("checksums").and_then(|v| v.as_obj()) {
            for (file, digest) in cs {
                let digest = digest.as_str().ok_or_else(|| {
                    DlionError::Artifact(format!("checksum for '{file}' is not a string"))
                })?;
                checksums.insert(file.clone(), digest.to_string());
            }
        }
        Ok(Manifest {
            dir,
            model_name,
            config,
            params,
            flat_dim,
            artifacts,
            backend,
            source_hash,
            checksums,
        })
    }

    /// Verify every payload checksum recorded in the manifest against
    /// the bytes on disk. Errors name the offending file and both
    /// hashes — a stale or truncated artifact must never execute
    /// silently.
    pub fn verify_checksums(&self) -> Result<()> {
        for (file, want) in &self.checksums {
            let path = self.dir.join(file);
            let bytes = std::fs::read(&path).map_err(|e| {
                DlionError::Artifact(format!(
                    "artifact payload '{file}' unreadable at {}: {e}",
                    path.display()
                ))
            })?;
            let got = crate::util::hash::fnv64_hex(&bytes);
            if &got != want {
                return Err(DlionError::Artifact(format!(
                    "checksum mismatch for artifact payload '{file}': expected {want}, actual {got}"
                )));
            }
        }
        Ok(())
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| DlionError::Artifact(format!("no artifact '{name}' in manifest")))
    }

    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Slice a flat parameter buffer into per-tensor views. A length
    /// disagreement names the first parameter whose declared span falls
    /// outside the buffer (manifests can be constructed directly, so
    /// this re-checks what `parse` validated).
    pub fn split_flat<'a>(&self, flat: &'a [f32]) -> Result<Vec<&'a [f32]>> {
        if flat.len() != self.flat_dim {
            let culprit = self
                .params
                .iter()
                .find(|p| p.offset + p.numel() > flat.len())
                .map(|p| {
                    format!(
                        " (param '{}' spans {}..{})",
                        p.name,
                        p.offset,
                        p.offset + p.numel()
                    )
                })
                .unwrap_or_default();
            return Err(DlionError::Artifact(format!(
                "flat buffer len {} != flat_dim {}{culprit}",
                flat.len(),
                self.flat_dim
            )));
        }
        self.params
            .iter()
            .map(|p| {
                if p.offset + p.numel() > flat.len() {
                    return Err(DlionError::Artifact(format!(
                        "param '{}' numel {} at offset {} overruns flat buffer of {}",
                        p.name,
                        p.numel(),
                        p.offset,
                        flat.len()
                    )));
                }
                Ok(&flat[p.offset..p.offset + p.numel()])
            })
            .collect()
    }

    pub fn config_usize(&self, key: &str) -> Option<usize> {
        self.config.get(key).map(|&x| x as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": "tiny",
      "config": {"vocab": 256, "dim": 32, "layers": 2, "seq_len": 64, "batch": 4},
      "flat_dim": 20,
      "params": [
        {"name": "embed", "shape": [4, 4], "dtype": "f32", "offset": 0},
        {"name": "head",  "shape": [4],   "dtype": "f32", "offset": 16}
      ],
      "artifacts": {
        "train_step": {
          "file": "train_step_tiny.hlo.txt",
          "inputs": [{"name": "tokens", "shape": [4, 65], "dtype": "i32"}],
          "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}]
        }
      }
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.model_name, "tiny");
        assert_eq!(m.flat_dim, 20);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[1].offset, 16);
        assert_eq!(m.config_usize("vocab"), Some(256));
        let a = m.artifact("train_step").unwrap();
        assert_eq!(a.inputs[0].shape, vec![4, 65]);
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn split_flat_views() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let flat: Vec<f32> = (0..20).map(|i| i as f32).collect();
        let views = m.split_flat(&flat).unwrap();
        assert_eq!(views.len(), 2);
        assert_eq!(views[0].len(), 16);
        assert_eq!(views[1][0], 16.0);
        assert!(m.split_flat(&flat[..10]).is_err());
    }

    #[test]
    fn rejects_gap_in_layout() {
        let bad = SAMPLE.replace("\"offset\": 16", "\"offset\": 17");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn split_flat_names_offending_param() {
        let mut m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        // a manifest whose specs disagree with the buffer: shrink the
        // buffer so 'head' (offset 16, numel 4) falls outside it
        let flat: Vec<f32> = (0..18).map(|i| i as f32).collect();
        let err = m.split_flat(&flat).unwrap_err().to_string();
        assert!(err.contains("head"), "error should name the param: {err}");
        // direct-construction drift: flat_dim says 18 but specs need 20
        m.flat_dim = 18;
        let err = m.split_flat(&flat).unwrap_err().to_string();
        assert!(err.contains("head"), "error should name the param: {err}");
    }

    #[test]
    fn legacy_manifest_has_empty_backend_fields() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert!(m.backend.is_empty());
        assert!(m.source_hash.is_empty());
        assert!(m.checksums.is_empty());
        m.verify_checksums().unwrap(); // vacuously true
    }

    #[test]
    fn checksum_verification_names_file_and_hashes() {
        let dir = std::env::temp_dir().join(format!("dlion-cksum-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let payload = dir.join("params_init.bin");
        std::fs::write(&payload, b"good bytes").unwrap();
        let good = crate::util::hash::fnv64_hex(b"good bytes");

        let mut m = Manifest::parse(SAMPLE, dir.clone()).unwrap();
        m.checksums.insert("params_init.bin".into(), good.clone());
        m.verify_checksums().unwrap();

        // corruption → named mismatch with expected/actual hashes
        std::fs::write(&payload, b"evil bytes").unwrap();
        let err = m.verify_checksums().unwrap_err().to_string();
        assert!(err.contains("params_init.bin"), "{err}");
        assert!(err.contains(&good), "expected hash in error: {err}");
        assert!(err.contains(&crate::util::hash::fnv64_hex(b"evil bytes")), "actual hash: {err}");

        // missing payload → named unreadable error
        std::fs::remove_file(&payload).unwrap();
        let err = m.verify_checksums().unwrap_err().to_string();
        assert!(err.contains("params_init.bin") && err.contains("unreadable"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
