//! The execution-backend seam: every artifact in a [`Manifest`] runs
//! through `trait Backend`, so the coordinator's hot path is identical
//! whether the kernels execute as AOT-compiled HLO under PJRT
//! ([`crate::runtime::client::PjrtBackend`]) or as the pure-Rust
//! executors in [`crate::runtime::native`].
//!
//! Interchange is [`HostTensor`] — a host-side shape + typed buffer,
//! the lowest common denominator both backends marshal natively (PJRT
//! literals are the same bytes; the native backend reads the buffers
//! in place). Backend selection (`select_backend_name`) is:
//!
//! 1. `DLION_BACKEND=native|pjrt` environment override, then
//! 2. the manifest's own `"backend"` field, then
//! 3. legacy inference: a manifest whose artifacts carry `.hlo` payload
//!    files is a PJRT artifact set; anything else defaults to native.
//!
//! See `docs/BACKENDS.md` for the add-a-backend procedure.

use crate::error::{DlionError, Result};
use crate::runtime::artifact::Manifest;

/// Element payload of a [`HostTensor`].
#[derive(Clone, Debug, PartialEq)]
pub enum HostData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    I8(Vec<i8>),
}

/// A host-side tensor: row-major data plus shape (scalars use `[]`).
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: HostData,
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Self {
        HostTensor { shape: shape.to_vec(), data: HostData::F32(data) }
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Self {
        HostTensor { shape: shape.to_vec(), data: HostData::I32(data) }
    }

    pub fn i8(data: Vec<i8>, shape: &[usize]) -> Self {
        HostTensor { shape: shape.to_vec(), data: HostData::I8(data) }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor { shape: Vec::new(), data: HostData::F32(vec![v]) }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Validate that the buffer length matches the shape.
    pub fn check(&self, ctx: &str) -> Result<()> {
        let len = match &self.data {
            HostData::F32(v) => v.len(),
            HostData::I32(v) => v.len(),
            HostData::I8(v) => v.len(),
        };
        if len != self.numel() {
            return Err(DlionError::Runtime(format!(
                "{ctx}: tensor shape {:?} needs {} elems, got {len}",
                self.shape,
                self.numel()
            )));
        }
        Ok(())
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            HostData::F32(v) => Ok(v),
            other => Err(DlionError::Runtime(format!("expected f32 tensor, got {other:?}"))),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            HostData::I32(v) => Ok(v),
            other => Err(DlionError::Runtime(format!("expected i32 tensor, got {other:?}"))),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match &self.data {
            HostData::I8(v) => Ok(v),
            other => Err(DlionError::Runtime(format!("expected i8 tensor, got {other:?}"))),
        }
    }

    /// Scalar f32 read-back (`loss` outputs).
    pub fn scalar(&self) -> Result<f32> {
        let v = self.as_f32()?;
        v.first().copied().ok_or_else(|| DlionError::Runtime("empty scalar tensor".into()))
    }
}

/// An execution backend for one manifest's artifact set.
///
/// Implementations must be deterministic: the same `(artifact, inputs)`
/// pair returns the same outputs, so the cluster drivers' replicated-
/// parameter invariant holds across backends.
pub trait Backend: Send + Sync {
    /// Registry name (`"native"`, `"pjrt"`).
    fn name(&self) -> &'static str;

    /// One-time validation/warm-up hook, called by `Runtime` after
    /// construction: backends check the manifest contract they will be
    /// asked to execute (payload files exist, layout matches) so a bad
    /// artifact set fails at load, not mid-train.
    fn load(&self, manifest: &Manifest) -> Result<()>;

    /// Execute the named artifact. Inputs/outputs follow the manifest's
    /// `ArtifactSpec` order.
    fn run(&self, manifest: &Manifest, artifact: &str, inputs: &[HostTensor])
        -> Result<Vec<HostTensor>>;
}

/// Resolve which backend a manifest should execute on (see module docs
/// for the precedence). Returns the backend *name*; construction lives
/// in [`crate::runtime::client::Runtime`] so this stays unit-testable
/// without a PJRT toolchain.
pub fn select_backend_name(manifest: &Manifest) -> Result<String> {
    if let Ok(env) = std::env::var("DLION_BACKEND") {
        let env = env.trim().to_ascii_lowercase();
        return match env.as_str() {
            "native" | "pjrt" => Ok(env),
            other => Err(DlionError::Runtime(format!(
                "DLION_BACKEND='{other}' is not a known backend (native, pjrt)"
            ))),
        };
    }
    if !manifest.backend.is_empty() {
        return Ok(manifest.backend.clone());
    }
    // Legacy manifests (aot.py, pre-`backend` field): PJRT iff the
    // artifact payloads are HLO files on disk.
    let has_hlo = manifest.artifacts.values().any(|a| a.file.ends_with(".hlo.txt"));
    Ok(if has_hlo { "pjrt".into() } else { "native".into() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest(backend: &str, file: &str) -> Manifest {
        let text = format!(
            r#"{{
              "model": "tiny", "backend": "{backend}", "flat_dim": 4,
              "params": [{{"name": "w", "shape": [4], "dtype": "f32", "offset": 0}}],
              "artifacts": {{"lion_update": {{"file": "{file}", "inputs": [], "outputs": []}}}}
            }}"#
        );
        Manifest::parse(&text, PathBuf::from("/tmp")).unwrap()
    }

    #[test]
    fn host_tensor_accessors() {
        let t = HostTensor::f32(vec![1.0, 2.0], &[2]);
        assert_eq!(t.numel(), 2);
        t.check("test").unwrap();
        assert_eq!(t.as_f32().unwrap(), &[1.0, 2.0]);
        assert!(t.as_i32().is_err());
        assert!(t.as_i8().is_err());
        let bad = HostTensor::f32(vec![1.0], &[3]);
        assert!(bad.check("test").is_err());
        assert_eq!(HostTensor::scalar_f32(7.5).scalar().unwrap(), 7.5);
    }

    #[test]
    fn selection_precedence() {
        // NB: relies on DLION_BACKEND being unset in the test env; the
        // explicit-field and legacy-inference arms are env-independent.
        if std::env::var("DLION_BACKEND").is_ok() {
            return;
        }
        assert_eq!(select_backend_name(&manifest("native", "")).unwrap(), "native");
        assert_eq!(select_backend_name(&manifest("pjrt", "x.hlo.txt")).unwrap(), "pjrt");
        // legacy manifest without a backend field: infer from payloads
        assert_eq!(select_backend_name(&manifest("", "train_step.hlo.txt")).unwrap(), "pjrt");
        assert_eq!(select_backend_name(&manifest("", "")).unwrap(), "native");
    }
}
