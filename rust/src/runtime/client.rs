//! PJRT client wrapper: one CPU client, a compile cache of loaded
//! executables keyed by artifact name, literal marshalling helpers.

use crate::error::{DlionError, Result};
use crate::runtime::artifact::Manifest;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

/// The runtime: a PJRT CPU client plus compiled executables for the
/// artifacts in one manifest. Thread-safe (`compile` is internally
/// locked; execution goes through &self).
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: Mutex<BTreeMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create from an artifacts directory (must contain manifest.json).
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, executables: Mutex::new(BTreeMap::new()) })
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        {
            let cache = self.executables.lock().unwrap();
            if let Some(exe) = cache.get(name) {
                return Ok(exe.clone());
            }
        }
        let path = self.manifest.artifact_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.executables.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with literal inputs; returns the flattened
    /// tuple outputs (aot.py lowers with return_tuple=True).
    pub fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(inputs)?;
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| DlionError::Runtime(format!("artifact {name}: empty result")))?
            .to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// f32 tensor literal from a slice (row-major).
    pub fn literal_f32(&self, data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            return Err(DlionError::Runtime(format!(
                "literal shape {shape:?} needs {numel} elems, got {}",
                data.len()
            )));
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&dims)?)
    }

    /// i32 tensor literal from a slice.
    pub fn literal_i32(&self, data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            return Err(DlionError::Runtime(format!(
                "literal shape {shape:?} needs {numel} elems, got {}",
                data.len()
            )));
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&dims)?)
    }

    /// i8 tensor literal (sign vectors) from raw bytes.
    pub fn literal_i8(&self, data: &[i8], shape: &[usize]) -> Result<xla::Literal> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            return Err(DlionError::Runtime(format!(
                "literal shape {shape:?} needs {numel} elems, got {}",
                data.len()
            )));
        }
        // i8 -> u8 reinterpret is a plain byte view
        let bytes: &[u8] =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S8,
            shape,
            bytes,
        )?)
    }

    /// Read back an f32 literal into a Vec.
    pub fn to_vec_f32(&self, lit: &xla::Literal) -> Result<Vec<f32>> {
        Ok(lit.to_vec::<f32>()?)
    }
}
