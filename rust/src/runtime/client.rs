//! The backend-agnostic [`Runtime`]: one manifest plus the [`Backend`]
//! that executes its artifacts, selected per
//! [`crate::runtime::backend::select_backend_name`]. Also home of
//! [`PjrtBackend`], the original PJRT/XLA execution path moved behind
//! the trait (one CPU client, a compile cache of loaded executables
//! keyed by artifact name, literal marshalling).

use crate::error::{DlionError, Result};
use crate::runtime::artifact::Manifest;
use crate::runtime::backend::{select_backend_name, Backend, HostData, HostTensor};
use crate::runtime::native::{self, NativeBackend};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// The runtime: a manifest and its execution backend. `Send + Sync` —
/// the native backend is stateless and the PJRT compile cache is
/// internally locked — so LM tasks can ride the threaded cluster
/// drivers.
pub struct Runtime {
    pub manifest: Manifest,
    backend: Box<dyn Backend>,
}

impl Runtime {
    /// Load from an artifacts directory (must contain `manifest.json`).
    /// Payload checksums are verified *before* backend construction: a
    /// stale or truncated artifact set fails here, by name.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        manifest.verify_checksums()?;
        Self::from_manifest(manifest)
    }

    /// Build the backend a manifest asks for.
    pub fn from_manifest(manifest: Manifest) -> Result<Self> {
        let name = select_backend_name(&manifest)?;
        let backend: Box<dyn Backend> = match name.as_str() {
            "native" => Box::new(NativeBackend::from_manifest(&manifest)?),
            "pjrt" => Box::new(PjrtBackend::new()?),
            other => {
                return Err(DlionError::Runtime(format!(
                    "no backend named '{other}' (native, pjrt)"
                )))
            }
        };
        backend.load(&manifest)?;
        Ok(Runtime { manifest, backend })
    }

    /// A fully in-memory native runtime for a registered model config —
    /// no artifacts directory, no files. This is the default LM path on
    /// a fresh checkout: the manifest is synthesized and the initial
    /// parameters are drawn deterministically from `seed`.
    pub fn native(model: &str, seed: u64) -> Result<Self> {
        let cfg = native::ModelCfg::by_name(model)?;
        let src = native::gen::source_hash(&cfg, seed, native::DEFAULT_VOTE_WORKERS);
        let text = native::gen::manifest_json(
            &cfg,
            seed,
            native::DEFAULT_VOTE_WORKERS,
            &src,
            &BTreeMap::new(),
        );
        let manifest = Manifest::parse(&text, PathBuf::new())?;
        let backend = NativeBackend::from_manifest(&manifest)?;
        Ok(Runtime { manifest, backend: Box::new(backend) })
    }

    /// Open `artifacts_dir` if it holds a manifest, else fall back to
    /// the in-memory native runtime for `fallback_model` (seed 0). This
    /// is why `cargo test` / `dlion lm` work with no `artifacts/`
    /// directory present.
    pub fn open_model(artifacts_dir: impl AsRef<Path>, fallback_model: &str) -> Result<Self> {
        if artifacts_dir.as_ref().join("manifest.json").exists() {
            Self::load(artifacts_dir)
        } else {
            Self::native(fallback_model, 0)
        }
    }

    /// [`Runtime::open_model`] with the default fallback model
    /// (`DLION_MODEL` env var, else `tiny`).
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let model = std::env::var("DLION_MODEL").unwrap_or_else(|_| "tiny".into());
        Self::open_model(artifacts_dir, &model)
    }

    /// Which backend executes this runtime's artifacts.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Initial flat parameters: `params_init.bin` when the artifact set
    /// ships one (always true for aot.py sets), else the deterministic
    /// native init from the manifest's `init_seed`.
    pub fn init_params(&self) -> Result<Vec<f32>> {
        let path = self.manifest.dir.join("params_init.bin");
        if path.is_file() {
            let bytes = std::fs::read(&path)?;
            if bytes.len() != 4 * self.manifest.flat_dim {
                return Err(DlionError::Artifact(format!(
                    "params_init.bin has {} bytes, expected {}",
                    bytes.len(),
                    4 * self.manifest.flat_dim
                )));
            }
            return Ok(bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect());
        }
        let seed = self.manifest.config_usize("init_seed").unwrap_or(0) as u64;
        let cfg = NativeBackend::model_cfg(&self.manifest)?;
        Ok(cfg.init_params(seed))
    }

    /// Execute the named artifact.
    pub fn run(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.manifest.artifact(name)?; // named error before dispatch
        self.backend.run(&self.manifest, name, inputs)
    }
}

/// The PJRT/XLA execution path: compiles `*.hlo.txt` payloads on first
/// use and caches the loaded executables.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    executables: Mutex<BTreeMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtBackend {
    pub fn new() -> Result<Self> {
        Ok(PjrtBackend { client: xla::PjRtClient::cpu()?, executables: Mutex::new(BTreeMap::new()) })
    }

    /// Compile (or fetch from cache) the named artifact.
    fn executable(
        &self,
        manifest: &Manifest,
        name: &str,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        {
            let cache = self.executables.lock().unwrap();
            if let Some(exe) = cache.get(name) {
                return Ok(exe.clone());
            }
        }
        let path = manifest.artifact_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.executables.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    fn literal(&self, t: &HostTensor) -> Result<xla::Literal> {
        t.check("pjrt input")?;
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        Ok(match &t.data {
            HostData::F32(v) => {
                if dims.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
            HostData::I32(v) => {
                if dims.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
            HostData::I8(v) => {
                // i8 -> u8 reinterpret is a plain byte view
                let bytes: &[u8] =
                    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len()) };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S8,
                    &t.shape,
                    bytes,
                )?
            }
        })
    }

    fn host_tensor(lit: &xla::Literal, dtype: &str, shape: &[usize]) -> Result<HostTensor> {
        Ok(match dtype {
            "i8" => HostTensor::i8(lit.to_vec::<i8>()?, shape),
            "i32" => HostTensor::i32(lit.to_vec::<i32>()?, shape),
            _ => HostTensor::f32(lit.to_vec::<f32>()?, shape),
        })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn load(&self, manifest: &Manifest) -> Result<()> {
        // payloads must exist before we promise to execute them
        for (name, spec) in &manifest.artifacts {
            let path = manifest.dir.join(&spec.file);
            if spec.file.is_empty() || !path.is_file() {
                return Err(DlionError::Artifact(format!(
                    "artifact '{name}' payload '{}' missing under {}",
                    spec.file,
                    manifest.dir.display()
                )));
            }
        }
        Ok(())
    }

    fn run(
        &self,
        manifest: &Manifest,
        artifact: &str,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let spec = manifest.artifact(artifact)?.clone();
        let exe = self.executable(manifest, artifact)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| self.literal(t)).collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| DlionError::Runtime(format!("artifact {artifact}: empty result")))?
            .to_literal_sync()?;
        let tuple = lit.to_tuple()?;
        if !spec.outputs.is_empty() && tuple.len() != spec.outputs.len() {
            return Err(DlionError::Runtime(format!(
                "artifact {artifact} returned {} outputs, manifest declares {}",
                tuple.len(),
                spec.outputs.len()
            )));
        }
        tuple
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let (dtype, shape) = spec
                    .outputs
                    .get(i)
                    .map(|o| (o.dtype.as_str(), o.shape.as_slice()))
                    .unwrap_or(("f32", &[]));
                Self::host_tensor(l, dtype, shape)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::GradTask;

    #[test]
    fn runtime_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Runtime>();
    }

    #[test]
    fn in_memory_native_runtime_runs_artifacts() {
        let rt = Runtime::native("tiny", 0).unwrap();
        assert_eq!(rt.backend_name(), "native");
        assert_eq!(rt.manifest.flat_dim, 143_680);
        let init = rt.init_params().unwrap();
        assert_eq!(init.len(), rt.manifest.flat_dim);
        // deterministic across constructions
        let rt2 = Runtime::native("tiny", 0).unwrap();
        assert_eq!(init, rt2.init_params().unwrap());
        assert_ne!(init, Runtime::native("tiny", 1).unwrap().init_params().unwrap());

        let d = 9usize;
        let out = rt
            .run(
                "apply_update",
                &[
                    HostTensor::f32(vec![1.0; d], &[d]),
                    HostTensor::f32(vec![-1.0; d], &[d]),
                    HostTensor::scalar_f32(0.5),
                    HostTensor::scalar_f32(0.0),
                ],
            )
            .unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &vec![1.5f32; d][..]);
        assert!(rt.run("nonexistent", &[]).is_err());
    }

    #[test]
    fn open_model_falls_back_to_native() {
        let missing = std::env::temp_dir().join("dlion-no-such-artifacts-dir");
        let rt = Runtime::open_model(&missing, "tiny").unwrap();
        assert_eq!(rt.backend_name(), "native");
        assert_eq!(rt.manifest.model_name, "tiny");
    }

    // keeps this test file honest about the GradTask trait-object story:
    // Box<dyn GradTask + Send + Sync> must stay constructible
    #[allow(dead_code)]
    fn gradtask_object(t: Box<dyn GradTask + Send + Sync>) -> usize {
        t.dim()
    }
}
