//! Artifact runtime: loads a manifest (`artifacts/manifest.json`) and
//! executes its five artifacts (`train_step`, `eval_step`,
//! `lion_update`, `majority_vote`, `apply_update`) through a pluggable
//! [`Backend`]:
//!
//! * [`native`] — pure-Rust executors (transformer fwd/bwd + Lion/vote
//!   kernels), the default; works fully in-memory with no artifacts
//!   directory at all (`Runtime::native`).
//! * pjrt ([`client::PjrtBackend`]) — the AOT path: HLO text produced
//!   by `make artifacts` (`python/compile/aot.py`), compiled and run
//!   under PJRT. Interchange is HLO *text*: jax ≥ 0.5 serializes protos
//!   with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//!   text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Selection precedence: `DLION_BACKEND` env var → the manifest's
//! `backend` field → legacy inference from payload file names. See
//! `docs/BACKENDS.md`.

pub mod artifact;
pub mod backend;
pub mod client;
pub mod native;
pub mod trainstep;

pub use artifact::{ArtifactSpec, Manifest, ParamSpec};
pub use backend::{select_backend_name, Backend, HostData, HostTensor};
pub use client::{PjrtBackend, Runtime};
pub use native::{ModelCfg, NativeBackend};
pub use trainstep::{EvalStepExec, LionUpdateExec, TrainStepExec};
