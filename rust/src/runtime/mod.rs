//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py` → `artifacts/*.hlo.txt` + `manifest.json`)
//! and executes them from the rust hot path. Python never runs here.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

pub mod artifact;
pub mod client;
pub mod trainstep;

pub use artifact::{ArtifactSpec, Manifest, ParamSpec};
pub use client::Runtime;
pub use trainstep::{LionUpdateExec, TrainStepExec};
