//! Native artifact generation: `dlion gen-artifacts` writes the same
//! `manifest.json` + `params_init.bin` contract as `python/compile/aot.py`
//! — minus the HLO payloads, because the native backend executes the
//! artifact set in-process. Regeneration is cached on `source_hash`
//! (model config + init seed + vote width + format version, FNV-1a):
//! an unchanged hash with intact checksums is a no-op, the
//! casettek/raster recompilation-cache design.

use crate::error::Result;
use crate::runtime::artifact::Manifest;
use crate::runtime::native::model::ModelCfg;
use crate::util::hash::{fnv64_hex, Fnv64};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Native manifest format version (aot.py writes version 1; version 2
/// adds `backend`, `source_hash`, `checksums`, and the Lion betas +
/// init seed in `config`).
pub const MANIFEST_VERSION: usize = 2;

/// Server-side aggregation width of the `majority_vote` artifact
/// (mirrors `aot.py::DEFAULT_VOTE_WORKERS`).
pub const DEFAULT_VOTE_WORKERS: usize = 4;

/// Default Lion betas baked into `lion_update` (ref.py / Algorithm 1).
pub const BETA1: f32 = 0.9;
pub const BETA2: f32 = 0.99;

/// What [`generate`] did.
pub struct GenReport {
    pub manifest: Manifest,
    pub dir: PathBuf,
    /// false ⇒ the existing artifact set already matched `source_hash`
    /// (and its checksums verified), so nothing was rewritten.
    pub fresh: bool,
    pub source_hash: String,
}

/// The recompilation-cache key: every input that changes the generated
/// artifact set must feed this hash.
pub fn source_hash(cfg: &ModelCfg, seed: u64, vote_workers: usize) -> String {
    let mut h = Fnv64::new();
    h.update(format!("native-artifacts-v{MANIFEST_VERSION}").as_bytes());
    h.update(
        format!(
            "|{} v{} d{} l{} h{} t{} b{}|seed={seed}|vote={vote_workers}",
            cfg.name, cfg.vocab, cfg.dim, cfg.layers, cfg.heads, cfg.seq_len, cfg.batch
        )
        .as_bytes(),
    );
    h.update(format!("|b1={BETA1}|b2={BETA2}").as_bytes());
    h.hex()
}

fn tensor_json(name: &str, shape: &[usize], dtype: &str, offset: Option<usize>) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".into(), Json::Str(name.into()));
    o.insert(
        "shape".into(),
        Json::Arr(shape.iter().map(|&s| Json::Num(s as f64)).collect()),
    );
    o.insert("dtype".into(), Json::Str(dtype.into()));
    if let Some(off) = offset {
        o.insert("offset".into(), Json::Num(off as f64));
    }
    Json::Obj(o)
}

fn artifact_json(file: &str, inputs: Vec<Json>, outputs: Vec<Json>) -> Json {
    let mut o = BTreeMap::new();
    o.insert("file".into(), Json::Str(file.into()));
    o.insert("inputs".into(), Json::Arr(inputs));
    o.insert("outputs".into(), Json::Arr(outputs));
    Json::Obj(o)
}

/// Build the native `manifest.json` text for one model config. The
/// artifact I/O specs are shape-identical to `aot.py`'s (same names,
/// same order), so `TrainStepExec` & co. cannot tell the backends
/// apart; artifact `file` entries are empty — native payloads execute
/// in-process.
pub fn manifest_json(
    cfg: &ModelCfg,
    seed: u64,
    vote_workers: usize,
    src_hash: &str,
    checksums: &BTreeMap<String, String>,
) -> String {
    let specs = cfg.param_specs();
    let flat_dim = cfg.flat_dim();

    let mut params = Vec::with_capacity(specs.len());
    let mut off = 0usize;
    for (name, shape) in &specs {
        params.push(tensor_json(name, shape, "f32", Some(off)));
        off += shape.iter().product::<usize>();
    }

    let tok = || tensor_json("tokens", &[cfg.batch, cfg.seq_len + 1], "i32", None);
    let param_io: Vec<Json> =
        specs.iter().map(|(n, s)| tensor_json(n, s, "f32", None)).collect();
    let grad_io: Vec<Json> = specs
        .iter()
        .map(|(n, s)| tensor_json(&format!("d_{n}"), s, "f32", None))
        .collect();

    let mut artifacts = BTreeMap::new();
    let mut ts_in = vec![tok()];
    ts_in.extend(param_io.clone());
    let mut ts_out = vec![tensor_json("loss", &[], "f32", None)];
    ts_out.extend(grad_io);
    artifacts.insert("train_step".to_string(), artifact_json("", ts_in, ts_out));

    let mut es_in = vec![tok()];
    es_in.extend(param_io);
    artifacts.insert(
        "eval_step".to_string(),
        artifact_json("", es_in, vec![tensor_json("loss", &[], "f32", None)]),
    );
    artifacts.insert(
        "lion_update".to_string(),
        artifact_json(
            "",
            vec![
                tensor_json("m", &[flat_dim], "f32", None),
                tensor_json("g", &[flat_dim], "f32", None),
            ],
            vec![
                tensor_json("delta", &[flat_dim], "i8", None),
                tensor_json("m_new", &[flat_dim], "f32", None),
            ],
        ),
    );
    artifacts.insert(
        "majority_vote".to_string(),
        artifact_json(
            "",
            vec![tensor_json("deltas", &[vote_workers, flat_dim], "i8", None)],
            vec![tensor_json("agg", &[flat_dim], "i8", None)],
        ),
    );
    artifacts.insert(
        "apply_update".to_string(),
        artifact_json(
            "",
            vec![
                tensor_json("x", &[flat_dim], "f32", None),
                tensor_json("delta", &[flat_dim], "f32", None),
                tensor_json("lr", &[], "f32", None),
                tensor_json("wd", &[], "f32", None),
            ],
            vec![tensor_json("x_new", &[flat_dim], "f32", None)],
        ),
    );

    let mut config = BTreeMap::new();
    config.insert("vocab".into(), Json::Num(cfg.vocab as f64));
    config.insert("dim".into(), Json::Num(cfg.dim as f64));
    config.insert("layers".into(), Json::Num(cfg.layers as f64));
    config.insert("heads".into(), Json::Num(cfg.heads as f64));
    config.insert("seq_len".into(), Json::Num(cfg.seq_len as f64));
    config.insert("batch".into(), Json::Num(cfg.batch as f64));
    config.insert("vote_workers".into(), Json::Num(vote_workers as f64));
    config.insert("beta1".into(), Json::Num(BETA1 as f64));
    config.insert("beta2".into(), Json::Num(BETA2 as f64));
    config.insert("init_seed".into(), Json::Num(seed as f64));

    let mut root = BTreeMap::new();
    root.insert("version".into(), Json::Num(MANIFEST_VERSION as f64));
    root.insert("model".into(), Json::Str(cfg.name.clone()));
    root.insert("backend".into(), Json::Str("native".into()));
    root.insert("source_hash".into(), Json::Str(src_hash.into()));
    root.insert("config".into(), Json::Obj(config));
    root.insert("flat_dim".into(), Json::Num(flat_dim as f64));
    root.insert("params".into(), Json::Arr(params));
    root.insert(
        "artifacts".into(),
        Json::Obj(artifacts.into_iter().collect()),
    );
    root.insert(
        "checksums".into(),
        Json::Obj(checksums.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect()),
    );
    crate::util::json::emit(&Json::Obj(root))
}

/// Generate (or no-op revalidate) a native artifact set in `out_dir`.
pub fn generate(
    model: &str,
    out_dir: impl AsRef<Path>,
    seed: u64,
    vote_workers: usize,
    force: bool,
) -> Result<GenReport> {
    let out_dir = out_dir.as_ref().to_path_buf();
    let cfg = ModelCfg::by_name(model)?;
    let src_hash = source_hash(&cfg, seed, vote_workers);

    if !force {
        if let Ok(existing) = Manifest::load(&out_dir) {
            if existing.source_hash == src_hash && existing.verify_checksums().is_ok() {
                return Ok(GenReport {
                    manifest: existing,
                    dir: out_dir,
                    fresh: false,
                    source_hash: src_hash,
                });
            }
        }
    }

    std::fs::create_dir_all(&out_dir)?;
    let init = cfg.init_params(seed);
    let mut bytes = Vec::with_capacity(init.len() * 4);
    for v in &init {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(out_dir.join("params_init.bin"), &bytes)?;

    let mut checksums = BTreeMap::new();
    checksums.insert("params_init.bin".to_string(), fnv64_hex(&bytes));

    let text = manifest_json(&cfg, seed, vote_workers, &src_hash, &checksums);
    std::fs::write(out_dir.join("manifest.json"), &text)?;

    let manifest = Manifest::parse(&text, out_dir.clone())?;
    manifest.verify_checksums()?;
    Ok(GenReport { manifest, dir: out_dir, fresh: true, source_hash: src_hash })
}
