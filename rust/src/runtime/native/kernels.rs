//! Native executors for the three Lion/vote artifacts. These are the
//! `ref.py` contracts (`lion_update_ref`, `majority_vote_ref`,
//! `apply_update_ref`) expressed through the repo's own oracles —
//! [`crate::optim::lion::bsign`] and [`crate::optim::lion::Lion`] — so
//! the native backend is pinned to exactly the arithmetic the 1-bit
//! codec and `SignVoteServer` already use (the tests below check
//! bit-exactness, including the ±0.0 / NaN corners where a naive
//! `x >= 0` branch would diverge from the IEEE sign-bit convention).

use crate::optim::lion::{bsign, Lion};

/// Fused Lion worker update (paper eq. 4):
/// `delta = bsign(β1·m + (1−β1)·g)` in {−1,+1} as i8,
/// `m_new = β2·m + (1−β2)·g`.
pub fn lion_update(m: &[f32], g: &[f32], beta1: f32, beta2: f32) -> (Vec<i8>, Vec<f32>) {
    debug_assert_eq!(m.len(), g.len());
    let mut delta = Vec::with_capacity(m.len());
    let mut m_new = Vec::with_capacity(m.len());
    for (&mv, &gv) in m.iter().zip(g) {
        delta.push(bsign(beta1 * mv + (1.0 - beta1) * gv) as i8);
        m_new.push(beta2 * mv + (1.0 - beta2) * gv);
    }
    (delta, m_new)
}

/// Server majority vote (paper eq. 5): `sign(Σᵢ deltas[i])` in
/// {−1, 0, +1} (zero only on even-N ties). `deltas` is row-major
/// `[n, d]`.
pub fn majority_vote(deltas: &[i8], n: usize, d: usize) -> Vec<i8> {
    debug_assert_eq!(deltas.len(), n * d);
    let mut votes = vec![0i32; d];
    for row in deltas.chunks_exact(d) {
        for (v, &s) in votes.iter_mut().zip(row) {
            *v += s as i32;
        }
    }
    votes.into_iter().map(crate::util::math::isign).collect()
}

/// Worker-side apply (paper eq. 6): `x − lr·(Δ + wd·x)`, delegating to
/// the coordinator's own [`Lion::apply_aggregated`] arithmetic.
pub fn apply_update(x: &[f32], delta: &[f32], lr: f32, wd: f32) -> Vec<f32> {
    debug_assert_eq!(x.len(), delta.len());
    let mut out = x.to_vec();
    Lion::apply_aggregated(&mut out, delta, lr, wd);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{sign, tern};
    use crate::optim::dist::{Aggregation, ServerLogic, SignVoteServer, TAG_SIGN, TAG_TERN};
    use crate::optim::LionParams;
    use crate::testing::gen_vec_normal;
    use crate::util::Rng;

    const B1: f32 = 0.9;
    const B2: f32 = 0.99;

    /// Native `lion_update` is bit-exact with the fused SWAR encode path
    /// (`Lion::encode_fused`: sign bits + momentum advance in one pass).
    #[test]
    fn lion_update_matches_fused_encoder_bit_exact() {
        let mut rng = Rng::new(0x11_07);
        for _ in 0..crate::testing::default_cases() / 4 {
            let m0 = gen_vec_normal(&mut rng, 1, 300, 1.0);
            let g = gen_vec_normal(&mut rng, m0.len(), m0.len(), 1.0);
            let (delta, m_new) = lion_update(&m0, &g, B1, B2);

            let mut lion =
                Lion::new(m0.len(), LionParams { beta1: B1, beta2: B2, ..LionParams::default() });
            lion.momentum.copy_from_slice(&m0);
            let packed = lion.encode_fused(&g);
            let fused_delta = sign::unpack(&packed, m0.len());

            assert_eq!(delta, fused_delta, "delta vs fused 1-bit encode");
            // momentum advance must match the fused path bit-for-bit
            assert!(m_new.iter().zip(&lion.momentum).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    /// ±0.0 resolves through the IEEE sign bit (the `optim::lion::bsign`
    /// convention the codec pins): +0.0 → +1, −0.0 → −1. A NaN momentum
    /// blend keeps its sign bit rather than poisoning the sign wire.
    #[test]
    fn lion_update_signed_zero_and_nan_edges() {
        // β1·m + (1−β1)·g: crafted so the blend is exactly ±0.0 / NaN
        let m = [0.0f32, -0.0, f32::NAN, -1.0, 1.0];
        let g = [0.0f32, -0.0, 0.0, f32::NAN, f32::NAN];
        let (delta, m_new) = lion_update(&m, &g, B1, B2);
        assert_eq!(delta[0], 1, "+0.0 blend votes +1");
        assert_eq!(delta[1], -1, "-0.0 blend votes -1");
        // blends 2..5 are NaN; bsign reads the (unspecified but
        // deterministic) sign bit — only require a valid binary vote,
        // same as the fused encoder would emit
        for (i, &d) in delta.iter().enumerate() {
            assert!(d == 1 || d == -1, "delta[{i}] = {d} must stay binary");
        }
        // and exactly what the fused packer emits for the same inputs
        let mut lion =
            Lion::new(m.len(), LionParams { beta1: B1, beta2: B2, ..LionParams::default() });
        lion.momentum.copy_from_slice(&m);
        assert_eq!(delta, sign::unpack(&lion.encode_fused(&g), m.len()));
        // momentum propagates NaN (no silent masking)
        assert!(m_new[2].is_nan() && m_new[3].is_nan() && m_new[4].is_nan());
    }

    /// Native `majority_vote` is bit-exact with `SignVoteServer` for odd
    /// worker counts (strictly binary downlink) and even counts (ternary
    /// downlink with genuine tie zeros).
    #[test]
    fn majority_vote_matches_sign_vote_server_bit_exact() {
        let mut rng = Rng::new(0x707E);
        for &n in &[1usize, 2, 3, 4, 5, 8] {
            for _ in 0..20 {
                let d = 1 + rng.below(200);
                let deltas: Vec<i8> =
                    (0..n * d).map(|_| if rng.uniform() < 0.5 { 1 } else { -1 }).collect();
                let native = majority_vote(&deltas, n, d);

                let uplinks: Vec<Vec<u8>> = deltas
                    .chunks_exact(d)
                    .map(|row| {
                        let mut msg = vec![TAG_SIGN];
                        msg.extend_from_slice(&sign::pack(row));
                        msg
                    })
                    .collect();
                let mut server = SignVoteServer::new(n, d, Aggregation::MajorityVote);
                let downlink = server.aggregate(&uplinks, 0.1, 0);
                let server_agg = match downlink[0] {
                    TAG_SIGN => sign::unpack(&downlink[1..], d),
                    TAG_TERN => tern::unpack(&downlink[1..], d),
                    tag => panic!("unexpected downlink tag {tag}"),
                };
                assert_eq!(native, server_agg, "n={n} d={d}");
                if n % 2 == 1 {
                    assert!(native.iter().all(|&s| s != 0), "odd-N vote must be binary");
                }
            }
        }
    }

    #[test]
    fn majority_vote_even_tie_is_zero() {
        // two workers, opposite votes → exact tie → 0
        let deltas = [1i8, -1, -1, 1];
        assert_eq!(majority_vote(&deltas, 2, 2), vec![0, 0]);
    }

    /// `apply_update` is literally `Lion::apply_aggregated` — same
    /// float op order, so bit-exact by construction; pin it anyway.
    #[test]
    fn apply_update_matches_lion_apply_bit_exact() {
        let mut rng = Rng::new(0xA991);
        let x = gen_vec_normal(&mut rng, 50, 200, 1.0);
        let delta: Vec<f32> = (0..x.len()).map(|_| if rng.uniform() < 0.5 { 1.0 } else { -1.0 }).collect();
        let out = apply_update(&x, &delta, 3e-3, 0.1);
        let mut oracle = x.clone();
        Lion::apply_aggregated(&mut oracle, &delta, 3e-3, 0.1);
        assert!(out.iter().zip(&oracle).all(|(a, b)| a.to_bits() == b.to_bits()));
        // ref.py identity: x − lr·(Δ + wd·x)
        for i in 0..x.len() {
            let want = x[i] - 3e-3 * (delta[i] + 0.1 * x[i]);
            assert_eq!(out[i], want);
        }
    }
}
