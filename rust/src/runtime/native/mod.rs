//! The native execution backend: a pure-Rust executor for the five-artifact
//! set (`train_step`, `eval_step`, `lion_update`, `majority_vote`,
//! `apply_update`) that makes the LM path run with zero Python/JAX/PJRT
//! in the loop. The transformer math lives in [`model`] (a port of
//! `python/compile/model.py` with hand-written backward passes), the
//! Lion/vote kernels in [`kernels`] (pinned bit-exact to
//! `optim::lion::bsign` and `SignVoteServer`), and artifact generation
//! in [`gen`].

pub mod gen;
pub mod kernels;
pub mod model;
pub mod tensor;

pub use gen::{generate, GenReport, DEFAULT_VOTE_WORKERS};
pub use model::ModelCfg;

use crate::error::{DlionError, Result};
use crate::runtime::artifact::Manifest;
use crate::runtime::backend::{Backend, HostTensor};

/// Pure-Rust backend for one model config. Stateless across calls —
/// every `run` is a function of its inputs, which is what lets
/// `Runtime` be `Send + Sync` and the LM task join the threaded
/// cluster drivers.
pub struct NativeBackend {
    cfg: ModelCfg,
    beta1: f32,
    beta2: f32,
}

impl NativeBackend {
    /// Extract the [`ModelCfg`] a manifest describes; errors name the
    /// missing config key.
    pub fn model_cfg(m: &Manifest) -> Result<ModelCfg> {
        let need = |k: &str| {
            m.config_usize(k).ok_or_else(|| {
                DlionError::Artifact(format!(
                    "manifest config missing '{k}' (required by the native backend)"
                ))
            })
        };
        Ok(ModelCfg {
            name: m.model_name.clone(),
            vocab: need("vocab")?,
            dim: need("dim")?,
            layers: need("layers")?,
            heads: need("heads")?,
            seq_len: need("seq_len")?,
            batch: need("batch")?,
        })
    }

    /// Build from a manifest, validating that the manifest's parameter
    /// layout is exactly this model's spec order (the flat-buffer
    /// contract) — a layout mismatch is named, not silently reinterpreted.
    pub fn from_manifest(m: &Manifest) -> Result<Self> {
        let cfg = Self::model_cfg(m)?;
        let specs = cfg.param_specs();
        if m.params.len() != specs.len() {
            return Err(DlionError::Artifact(format!(
                "manifest lists {} param tensors, model {} defines {}",
                m.params.len(),
                cfg.name,
                specs.len()
            )));
        }
        for (got, (name, shape)) in m.params.iter().zip(&specs) {
            if &got.name != name || &got.shape != shape {
                return Err(DlionError::Artifact(format!(
                    "manifest param '{}' {:?} disagrees with model spec '{name}' {shape:?}",
                    got.name, got.shape
                )));
            }
        }
        if m.flat_dim != cfg.flat_dim() {
            return Err(DlionError::Artifact(format!(
                "manifest flat_dim {} != model {} flat_dim {}",
                m.flat_dim,
                cfg.name,
                cfg.flat_dim()
            )));
        }
        let beta1 = m.config.get("beta1").map(|&x| x as f32).unwrap_or(gen::BETA1);
        let beta2 = m.config.get("beta2").map(|&x| x as f32).unwrap_or(gen::BETA2);
        Ok(NativeBackend { cfg, beta1, beta2 })
    }

    /// Concatenate per-tensor param inputs back into the flat buffer
    /// (manifest order), naming any tensor whose size disagrees.
    fn flatten_params(&self, m: &Manifest, inputs: &[HostTensor]) -> Result<Vec<f32>> {
        if inputs.len() != m.params.len() {
            return Err(DlionError::Runtime(format!(
                "expected {} param tensors, got {}",
                m.params.len(),
                inputs.len()
            )));
        }
        let mut flat = vec![0.0f32; m.flat_dim];
        for (inp, spec) in inputs.iter().zip(&m.params) {
            let v = inp.as_f32()?;
            if v.len() != spec.numel() {
                return Err(DlionError::Runtime(format!(
                    "param '{}' input has {} elems, spec {:?} needs {}",
                    spec.name,
                    v.len(),
                    spec.shape,
                    spec.numel()
                )));
            }
            flat[spec.offset..spec.offset + spec.numel()].copy_from_slice(v);
        }
        Ok(flat)
    }

    /// Split a flat gradient buffer into per-tensor outputs (manifest
    /// order), matching `train_step`'s tuple contract.
    fn split_grads(&self, m: &Manifest, flat: &[f32]) -> Vec<HostTensor> {
        m.params
            .iter()
            .map(|spec| {
                HostTensor::f32(flat[spec.offset..spec.offset + spec.numel()].to_vec(), &spec.shape)
            })
            .collect()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn load(&self, manifest: &Manifest) -> Result<()> {
        // no payloads to compile; re-validate the layout contract so a
        // hand-edited manifest fails at load, not mid-train
        Self::from_manifest(manifest).map(|_| ())
    }

    fn run(
        &self,
        manifest: &Manifest,
        artifact: &str,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        for (i, t) in inputs.iter().enumerate() {
            t.check(&format!("native {artifact} input {i}"))?;
        }
        match artifact {
            "train_step" | "eval_step" => {
                let tokens = inputs
                    .first()
                    .ok_or_else(|| DlionError::Runtime(format!("{artifact}: no token input")))?
                    .as_i32()?;
                let flat = self.flatten_params(manifest, &inputs[1..])?;
                if artifact == "eval_step" {
                    let loss = model::eval_step(&self.cfg, &flat, tokens)?;
                    Ok(vec![HostTensor::scalar_f32(loss)])
                } else {
                    let (loss, grads) = model::train_step(&self.cfg, &flat, tokens)?;
                    let mut out = Vec::with_capacity(1 + manifest.params.len());
                    out.push(HostTensor::scalar_f32(loss));
                    out.extend(self.split_grads(manifest, &grads));
                    Ok(out)
                }
            }
            "lion_update" => {
                let (m, g) = match inputs {
                    [m, g] => (m.as_f32()?, g.as_f32()?),
                    _ => {
                        return Err(DlionError::Runtime(format!(
                            "lion_update takes (m, g), got {} inputs",
                            inputs.len()
                        )))
                    }
                };
                if m.len() != g.len() {
                    return Err(DlionError::Runtime(format!(
                        "lion_update: m has {} elems, g has {}",
                        m.len(),
                        g.len()
                    )));
                }
                let (delta, m_new) = kernels::lion_update(m, g, self.beta1, self.beta2);
                let d = m.len();
                Ok(vec![HostTensor::i8(delta, &[d]), HostTensor::f32(m_new, &[d])])
            }
            "majority_vote" => {
                let t = inputs.first().ok_or_else(|| {
                    DlionError::Runtime("majority_vote: no deltas input".into())
                })?;
                if t.shape.len() != 2 {
                    return Err(DlionError::Runtime(format!(
                        "majority_vote deltas must be [N, d], got shape {:?}",
                        t.shape
                    )));
                }
                let (n, d) = (t.shape[0], t.shape[1]);
                let agg = kernels::majority_vote(t.as_i8()?, n, d);
                Ok(vec![HostTensor::i8(agg, &[d])])
            }
            "apply_update" => {
                let (x, delta, lr, wd) = match inputs {
                    [x, delta, lr, wd] => (x.as_f32()?, delta.as_f32()?, lr.scalar()?, wd.scalar()?),
                    _ => {
                        return Err(DlionError::Runtime(format!(
                            "apply_update takes (x, delta, lr, wd), got {} inputs",
                            inputs.len()
                        )))
                    }
                };
                if x.len() != delta.len() {
                    return Err(DlionError::Runtime(format!(
                        "apply_update: x has {} elems, delta has {}",
                        x.len(),
                        delta.len()
                    )));
                }
                let d = x.len();
                Ok(vec![HostTensor::f32(kernels::apply_update(x, delta, lr, wd), &[d])])
            }
            other => Err(DlionError::Runtime(format!(
                "native backend has no executor for artifact '{other}'"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    fn micro_manifest() -> Manifest {
        // tiny is the smallest registered config; synthesize in-memory
        let cfg = ModelCfg::by_name("tiny").unwrap();
        let sh = gen::source_hash(&cfg, 0, 3);
        let text = gen::manifest_json(&cfg, 0, 3, &sh, &BTreeMap::new());
        Manifest::parse(&text, PathBuf::new()).unwrap()
    }

    #[test]
    fn synthesized_manifest_round_trips() {
        let m = micro_manifest();
        assert_eq!(m.model_name, "tiny");
        assert_eq!(m.backend, "native");
        assert_eq!(m.flat_dim, 143_680);
        assert!(!m.source_hash.is_empty());
        assert_eq!(m.params.len(), 2 + 2 * 9 + 2);
        for name in ["train_step", "eval_step", "lion_update", "majority_vote", "apply_update"] {
            assert!(m.artifact(name).is_ok(), "missing artifact {name}");
        }
        assert_eq!(m.artifact("majority_vote").unwrap().inputs[0].shape, vec![3, 143_680]);
        assert_eq!(m.config_usize("init_seed"), Some(0));
        NativeBackend::from_manifest(&m).unwrap();
    }

    #[test]
    fn layout_mismatch_is_named() {
        let mut m = micro_manifest();
        m.params[3].name = "layer0.wq_typo".into();
        let err = NativeBackend::from_manifest(&m).unwrap_err().to_string();
        assert!(err.contains("wq_typo"), "{err}");
    }

    #[test]
    fn kernels_run_through_backend_dispatch() {
        let m = micro_manifest();
        let be = NativeBackend::from_manifest(&m).unwrap();
        let d = 11usize;
        let mv = HostTensor::f32(vec![0.5; d], &[d]);
        let gv = HostTensor::f32(vec![-1.0; d], &[d]);
        let out = be.run(&m, "lion_update", &[mv, gv]).unwrap();
        assert_eq!(out[0].as_i8().unwrap(), &vec![1i8; d][..]); // 0.9·0.5 − 0.1 > 0
        let deltas = HostTensor::i8(vec![1, 1, -1, -1, -1, 1], &[3, 2]);
        let out = be.run(&m, "majority_vote", &[deltas]).unwrap();
        assert_eq!(out[0].as_i8().unwrap(), &[-1, 1]);
        let x = HostTensor::f32(vec![1.0, 2.0], &[2]);
        let delta = HostTensor::f32(vec![1.0, -1.0], &[2]);
        let out = be
            .run(&m, "apply_update", &[x, delta, HostTensor::scalar_f32(0.1), HostTensor::scalar_f32(0.0)])
            .unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[0.9, 2.1]);
        let err = be.run(&m, "warp_drive", &[]).unwrap_err().to_string();
        assert!(err.contains("warp_drive"), "{err}");
    }
}
