//! Pure-Rust GPT2++ (paper §5.2): the GPT-2 block with RMSNorm and a
//! SwiGLU MLP, causal attention, learned positional embeddings, byte
//! vocab. This is a line-for-line port of `python/compile/model.py` —
//! same config registry, same ordered parameter layout, same fused
//! `train_step = (tokens, params…) → (loss, grads…)` contract — executed
//! host-side with hand-written backward passes instead of JAX autodiff.
//!
//! The flat layout is the manifest contract: `param_specs` must list
//! tensors in exactly the order `model.py::param_specs` does, or PJRT
//! and native artifacts would disagree about what the coordinator's
//! flat buffer means.

use crate::error::{DlionError, Result};
use crate::runtime::native::tensor::{log_sum_exp, matmul, matmul_at_acc, matmul_bt_acc, sigmoid};
use crate::util::Rng;

/// RMSNorm epsilon (`model.py::rms_norm`).
const RMS_EPS: f32 = 1e-5;

/// Model hyperparameters; mirrors `model.py::ModelConfig`.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub dim: usize,
    pub layers: usize,
    pub heads: usize,
    pub seq_len: usize,
    pub batch: usize,
}

impl ModelCfg {
    /// The registered model sizes (`model.py::CONFIGS`).
    pub fn by_name(name: &str) -> Result<ModelCfg> {
        let (dim, layers, heads, seq_len, batch) = match name {
            "tiny" => (64, 2, 2, 64, 4),
            "small" => (256, 4, 4, 128, 8),
            "lm10m" => (320, 8, 8, 256, 8),
            "lm25m" => (512, 8, 8, 256, 8),
            "lm100m" => (768, 14, 12, 256, 8),
            other => {
                return Err(DlionError::Config(format!(
                    "unknown model config '{other}' (tiny, small, lm10m, lm25m, lm100m)"
                )))
            }
        };
        Ok(ModelCfg { name: name.to_string(), vocab: 256, dim, layers, heads, seq_len, batch })
    }

    pub fn names() -> &'static [&'static str] {
        &["tiny", "small", "lm10m", "lm25m", "lm100m"]
    }

    pub fn head_dim(&self) -> usize {
        debug_assert_eq!(self.dim % self.heads, 0);
        self.dim / self.heads
    }

    /// SwiGLU hidden width: `dim · 8/3` rounded up to a multiple of 32
    /// (`dim·8` is exact, so integer division matches Python's `int()`).
    pub fn mlp_hidden(&self) -> usize {
        (self.dim * 8 / 3).div_ceil(32) * 32
    }

    /// Ordered `(name, shape)` list — the flat-layout contract
    /// (`model.py::param_specs`).
    pub fn param_specs(&self) -> Vec<(String, Vec<usize>)> {
        let d = self.dim;
        let f = self.mlp_hidden();
        let mut specs = vec![
            ("embed".to_string(), vec![self.vocab, d]),
            ("pos".to_string(), vec![self.seq_len, d]),
        ];
        for i in 0..self.layers {
            let p = format!("layer{i}.");
            specs.push((format!("{p}ln1"), vec![d]));
            specs.push((format!("{p}wq"), vec![d, d]));
            specs.push((format!("{p}wk"), vec![d, d]));
            specs.push((format!("{p}wv"), vec![d, d]));
            specs.push((format!("{p}wo"), vec![d, d]));
            specs.push((format!("{p}ln2"), vec![d]));
            specs.push((format!("{p}w_gate"), vec![d, f]));
            specs.push((format!("{p}w_up"), vec![d, f]));
            specs.push((format!("{p}w_down"), vec![f, d]));
        }
        specs.push(("ln_f".to_string(), vec![d]));
        specs.push(("head".to_string(), vec![d, self.vocab]));
        specs
    }

    pub fn flat_dim(&self) -> usize {
        self.param_specs().iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    /// Deterministic initialization from `seed` (GPT-2-style scaled
    /// normal, norms at 1, `model.py::init_params` scales). The RNG is
    /// this repo's xoshiro stream, so native init is reproducible
    /// without JAX; PJRT artifact sets ship their own `params_init.bin`.
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut out = vec![0.0f32; self.flat_dim()];
        let mut rng = Rng::new(seed ^ 0xD110_4A11_CE_u64);
        let mut off = 0usize;
        let res_scale = 1.0 / (2.0 * self.layers as f32).sqrt();
        for (name, shape) in self.param_specs() {
            let n: usize = shape.iter().product();
            let dst = &mut out[off..off + n];
            off += n;
            if name.ends_with("ln1") || name.ends_with("ln2") || name.ends_with("ln_f") {
                dst.fill(1.0);
            } else if name == "pos" {
                rng.fill_normal(dst, 0.01);
            } else if name == "embed" {
                rng.fill_normal(dst, 0.02);
            } else {
                let mut scale = 1.0 / (shape[0] as f32).sqrt();
                if name.ends_with("wo") || name.ends_with("w_down") {
                    scale *= res_scale;
                }
                rng.fill_normal(dst, scale);
            }
        }
        out
    }
}

/// Immutable per-tensor views over one flat buffer, in spec order.
fn split<'a>(cfg: &ModelCfg, flat: &'a [f32]) -> Result<Vec<&'a [f32]>> {
    let specs = cfg.param_specs();
    let want: usize = specs.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
    if flat.len() != want {
        return Err(DlionError::Runtime(format!(
            "model {}: flat buffer has {} params, config needs {want}",
            cfg.name,
            flat.len()
        )));
    }
    let mut views = Vec::with_capacity(specs.len());
    let mut rest = flat;
    for (_, shape) in &specs {
        let (head, tail) = rest.split_at(shape.iter().product());
        views.push(head);
        rest = tail;
    }
    Ok(views)
}

/// Mutable per-tensor views (gradient output buffer), in spec order.
fn split_mut<'a>(cfg: &ModelCfg, flat: &'a mut [f32]) -> Vec<&'a mut [f32]> {
    let specs = cfg.param_specs();
    let mut views = Vec::with_capacity(specs.len());
    let mut rest = flat;
    for (_, shape) in &specs {
        let (head, tail) = rest.split_at_mut(shape.iter().product());
        views.push(head);
        rest = tail;
    }
    views
}

// Positions of named tensors in the spec-order view list.
const IDX_EMBED: usize = 0;
const IDX_POS: usize = 1;
const PER_LAYER: usize = 9;
#[derive(Clone, Copy)]
enum L {
    Ln1 = 0,
    Wq = 1,
    Wk = 2,
    Wv = 3,
    Wo = 4,
    Ln2 = 5,
    WGate = 6,
    WUp = 7,
    WDown = 8,
}
fn li(layer: usize, which: L) -> usize {
    2 + layer * PER_LAYER + which as usize
}
fn idx_lnf(cfg: &ModelCfg) -> usize {
    2 + cfg.layers * PER_LAYER
}
fn idx_head(cfg: &ModelCfg) -> usize {
    3 + cfg.layers * PER_LAYER
}

/// Per-layer forward activations retained for the backward pass.
struct LayerCache {
    xa: Vec<f32>,    // residual input to the attention block [BT,D]
    h1: Vec<f32>,    // rms_norm(xa, ln1)
    r1: Vec<f32>,    // rsqrt(mean(xa²)+eps) per row [BT]
    q: Vec<f32>,     // h1 @ wq [BT,D]
    k: Vec<f32>,
    v: Vec<f32>,
    probs: Vec<f32>, // softmax scores [B,H,T,T]
    ctx: Vec<f32>,   // attention context before wo [BT,D]
    xb: Vec<f32>,    // residual input to the MLP block [BT,D]
    h2: Vec<f32>,    // rms_norm(xb, ln2)
    r2: Vec<f32>,
    gate: Vec<f32>,  // h2 @ w_gate [BT,F]
    up: Vec<f32>,    // h2 @ w_up [BT,F]
    su: Vec<f32>,    // silu(gate) * up [BT,F]
}

struct FwdCache {
    layers: Vec<LayerCache>,
    xf: Vec<f32>, // final residual stream [BT,D]
    rf: Vec<f32>, // final-norm rsqrt [BT]
    hf: Vec<f32>, // rms_norm(xf, ln_f)
}

/// `y = rms_norm(x, scale)` row-wise; records the rsqrt factor per row.
fn rms_norm_fwd(x: &[f32], scale: &[f32], d: usize, y: &mut [f32], r: &mut [f32]) {
    for (row, (yrow, rr)) in
        x.chunks_exact(d).zip(y.chunks_exact_mut(d).zip(r.iter_mut()))
    {
        let ms: f32 = row.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let rs = 1.0 / (ms + RMS_EPS).sqrt();
        *rr = rs;
        for ((yo, &xv), &sc) in yrow.iter_mut().zip(row).zip(scale) {
            *yo = xv * rs * sc;
        }
    }
}

/// Backward of `rms_norm`: accumulates `+=` into `dx` (residual chain)
/// and `dscale`.
fn rms_norm_bwd(
    x: &[f32],
    scale: &[f32],
    r: &[f32],
    dy: &[f32],
    d: usize,
    dx: &mut [f32],
    dscale: &mut [f32],
) {
    let inv_d = 1.0 / d as f32;
    for (((row, dyrow), dxrow), &rs) in x
        .chunks_exact(d)
        .zip(dy.chunks_exact(d))
        .zip(dx.chunks_exact_mut(d))
        .zip(r.iter())
    {
        // g = dy ⊙ scale; dx += r·g − x·r³·(g·x)/D; dscale += dy ⊙ x·r
        let mut dot = 0.0f32;
        for ((&dyv, &sc), &xv) in dyrow.iter().zip(scale).zip(row) {
            dot += dyv * sc * xv;
        }
        let coef = rs * rs * rs * dot * inv_d;
        for (((dxo, &dyv), &sc), &xv) in dxrow.iter_mut().zip(dyrow).zip(scale).zip(row) {
            *dxo += rs * dyv * sc - xv * coef;
        }
        for ((ds, &dyv), &xv) in dscale.iter_mut().zip(dyrow).zip(row) {
            *ds += dyv * xv * rs;
        }
    }
}

fn validate_tokens(cfg: &ModelCfg, tokens: &[i32]) -> Result<()> {
    let want = cfg.batch * (cfg.seq_len + 1);
    if tokens.len() != want {
        return Err(DlionError::Runtime(format!(
            "model {}: tokens len {} != batch·(seq_len+1) = {want}",
            cfg.name,
            tokens.len()
        )));
    }
    if let Some(&bad) = tokens.iter().find(|&&t| t < 0 || t as usize >= cfg.vocab) {
        return Err(DlionError::Runtime(format!(
            "model {}: token {bad} outside vocab 0..{}",
            cfg.name, cfg.vocab
        )));
    }
    Ok(())
}

/// Forward pass over `inputs` (i32[B,T], already the `tokens[:, :-1]`
/// slice). Returns the activation cache and the logits [BT,V].
fn forward(cfg: &ModelCfg, p: &[&[f32]], inputs: &[i32]) -> (FwdCache, Vec<f32>) {
    let (b, t, d) = (cfg.batch, cfg.seq_len, cfg.dim);
    let (h, hd, f, v) = (cfg.heads, cfg.head_dim(), cfg.mlp_hidden(), cfg.vocab);
    let bt = b * t;
    let inv_sqrt_hd = 1.0 / (hd as f32).sqrt();

    // x = embed[tokens] + pos
    let mut x = vec![0.0f32; bt * d];
    let (embed, pos) = (p[IDX_EMBED], p[IDX_POS]);
    for (i, row) in x.chunks_exact_mut(d).enumerate() {
        let tok = inputs[i] as usize;
        let ti = i % t;
        for ((o, &e), &pp) in row.iter_mut().zip(&embed[tok * d..(tok + 1) * d]).zip(&pos[ti * d..(ti + 1) * d]) {
            *o = e + pp;
        }
    }

    let mut layers = Vec::with_capacity(cfg.layers);
    for l in 0..cfg.layers {
        let xa = x.clone();
        let mut h1 = vec![0.0f32; bt * d];
        let mut r1 = vec![0.0f32; bt];
        rms_norm_fwd(&xa, p[li(l, L::Ln1)], d, &mut h1, &mut r1);

        let mut q = vec![0.0f32; bt * d];
        let mut k = vec![0.0f32; bt * d];
        let mut vv = vec![0.0f32; bt * d];
        matmul(&mut q, &h1, p[li(l, L::Wq)], bt, d, d);
        matmul(&mut k, &h1, p[li(l, L::Wk)], bt, d, d);
        matmul(&mut vv, &h1, p[li(l, L::Wv)], bt, d, d);

        // causal attention per (batch, head)
        let mut probs = vec![0.0f32; b * h * t * t];
        let mut ctx = vec![0.0f32; bt * d];
        let mut scores = vec![0.0f32; t];
        for bi in 0..b {
            for hi in 0..h {
                let hoff = hi * hd;
                let prow = &mut probs[(bi * h + hi) * t * t..(bi * h + hi + 1) * t * t];
                for ti in 0..t {
                    let qrow = &q[(bi * t + ti) * d + hoff..(bi * t + ti) * d + hoff + hd];
                    let mut mx = f32::NEG_INFINITY;
                    for (si, sc) in scores[..=ti].iter_mut().enumerate() {
                        let krow = &k[(bi * t + si) * d + hoff..(bi * t + si) * d + hoff + hd];
                        let mut acc = 0.0f32;
                        for (&qv, &kv) in qrow.iter().zip(krow) {
                            acc += qv * kv;
                        }
                        *sc = acc * inv_sqrt_hd;
                        mx = mx.max(*sc);
                    }
                    let mut denom = 0.0f32;
                    for sc in scores[..=ti].iter_mut() {
                        *sc = (*sc - mx).exp();
                        denom += *sc;
                    }
                    let inv = 1.0 / denom;
                    let crow =
                        &mut ctx[(bi * t + ti) * d + hoff..(bi * t + ti) * d + hoff + hd];
                    for (si, &e) in scores[..=ti].iter().enumerate() {
                        let pr = e * inv;
                        prow[ti * t + si] = pr;
                        let vrow = &vv[(bi * t + si) * d + hoff..(bi * t + si) * d + hoff + hd];
                        for (c, &vval) in crow.iter_mut().zip(vrow) {
                            *c += pr * vval;
                        }
                    }
                }
            }
        }

        // x ← xa + ctx @ wo
        let mut att_out = vec![0.0f32; bt * d];
        matmul(&mut att_out, &ctx, p[li(l, L::Wo)], bt, d, d);
        for ((xo, &a), &ao) in x.iter_mut().zip(&xa).zip(&att_out) {
            *xo = a + ao;
        }
        let xb = x.clone();

        let mut h2 = vec![0.0f32; bt * d];
        let mut r2 = vec![0.0f32; bt];
        rms_norm_fwd(&xb, p[li(l, L::Ln2)], d, &mut h2, &mut r2);
        let mut gate = vec![0.0f32; bt * f];
        let mut up = vec![0.0f32; bt * f];
        matmul(&mut gate, &h2, p[li(l, L::WGate)], bt, d, f);
        matmul(&mut up, &h2, p[li(l, L::WUp)], bt, d, f);
        let mut su = vec![0.0f32; bt * f];
        for ((s, &g), &u) in su.iter_mut().zip(&gate).zip(&up) {
            *s = g * sigmoid(g) * u;
        }
        // x ← xb + su @ w_down
        let mut mlp_out = vec![0.0f32; bt * d];
        matmul(&mut mlp_out, &su, p[li(l, L::WDown)], bt, f, d);
        for ((xo, &a), &mo) in x.iter_mut().zip(&xb).zip(&mlp_out) {
            *xo = a + mo;
        }

        layers.push(LayerCache { xa, h1, r1, q, k, v: vv, probs, ctx, xb, h2, r2, gate, up, su });
    }

    let xf = x;
    let mut hf = vec![0.0f32; bt * d];
    let mut rf = vec![0.0f32; bt];
    rms_norm_fwd(&xf, p[idx_lnf(cfg)], d, &mut hf, &mut rf);
    let mut logits = vec![0.0f32; bt * v];
    matmul(&mut logits, &hf, p[idx_head(cfg)], bt, d, v);
    (FwdCache { layers, xf, rf, hf }, logits)
}

/// Mean next-byte cross-entropy; optionally writes `(softmax − onehot)/BT`
/// into `dlogits`.
fn loss_from_logits(
    logits: &[f32],
    targets: &[i32],
    v: usize,
    mut dlogits: Option<&mut [f32]>,
) -> f32 {
    let bt = targets.len();
    let inv_bt = 1.0 / bt as f32;
    let mut loss = 0.0f32;
    for (i, row) in logits.chunks_exact(v).enumerate() {
        let tgt = targets[i] as usize;
        let lse = log_sum_exp(row);
        loss += lse - row[tgt];
        if let Some(dl) = dlogits.as_deref_mut() {
            let drow = &mut dl[i * v..(i + 1) * v];
            for (o, &lv) in drow.iter_mut().zip(row) {
                *o = (lv - lse).exp() * inv_bt;
            }
            drow[tgt] -= inv_bt;
        }
    }
    loss * inv_bt
}

/// Loss-only evaluation (`eval_step` artifact). `tokens` is i32[B,T+1].
pub fn eval_step(cfg: &ModelCfg, flat_params: &[f32], tokens: &[i32]) -> Result<f32> {
    validate_tokens(cfg, tokens)?;
    let p = split(cfg, flat_params)?;
    let (inputs, targets) = split_tokens(cfg, tokens);
    let (_, logits) = forward(cfg, &p, &inputs);
    Ok(loss_from_logits(&logits, &targets, cfg.vocab, None))
}

/// Split `tokens[B,T+1]` into next-byte (inputs, targets), each [B,T].
fn split_tokens(cfg: &ModelCfg, tokens: &[i32]) -> (Vec<i32>, Vec<i32>) {
    let (b, t) = (cfg.batch, cfg.seq_len);
    let mut inputs = Vec::with_capacity(b * t);
    let mut targets = Vec::with_capacity(b * t);
    for row in tokens.chunks_exact(t + 1) {
        inputs.extend_from_slice(&row[..t]);
        targets.extend_from_slice(&row[1..]);
    }
    (inputs, targets)
}

/// Fused forward + backward (`train_step` artifact): returns the scalar
/// loss and the flat gradient buffer in manifest param order.
pub fn train_step(cfg: &ModelCfg, flat_params: &[f32], tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
    validate_tokens(cfg, tokens)?;
    let p = split(cfg, flat_params)?;
    let (inputs, targets) = split_tokens(cfg, tokens);
    let (b, t, d) = (cfg.batch, cfg.seq_len, cfg.dim);
    let (h, hd, f, v) = (cfg.heads, cfg.head_dim(), cfg.mlp_hidden(), cfg.vocab);
    let bt = b * t;
    let inv_sqrt_hd = 1.0 / (hd as f32).sqrt();

    let (cache, logits) = forward(cfg, &p, &inputs);

    let mut flat_grads = vec![0.0f32; flat_params.len()];
    let mut g = split_mut(cfg, &mut flat_grads);

    let mut dlogits = vec![0.0f32; bt * v];
    let loss = loss_from_logits(&logits, &targets, v, Some(&mut dlogits));

    // head + final norm
    matmul_at_acc(&mut *g[idx_head(cfg)], &cache.hf, &dlogits, bt, d, v);
    let mut dhf = vec![0.0f32; bt * d];
    matmul_bt_acc(&mut dhf, &dlogits, p[idx_head(cfg)], bt, v, d);
    let mut dx = vec![0.0f32; bt * d];
    rms_norm_bwd(&cache.xf, p[idx_lnf(cfg)], &cache.rf, &dhf, d, &mut dx, &mut *g[idx_lnf(cfg)]);

    for l in (0..cfg.layers).rev() {
        let lc = &cache.layers[l];

        // ---- MLP block: x_out = xb + (silu(h2@w_gate) ⊙ (h2@w_up)) @ w_down
        // dx currently holds ∂loss/∂x_out, which is also ∂/∂(mlp_out).
        let mut d_su = vec![0.0f32; bt * f];
        matmul_bt_acc(&mut d_su, &dx, p[li(l, L::WDown)], bt, d, f);
        matmul_at_acc(&mut *g[li(l, L::WDown)], &lc.su, &dx, bt, f, d);
        let mut d_gate = vec![0.0f32; bt * f];
        let mut d_up = vec![0.0f32; bt * f];
        for i in 0..bt * f {
            let (ds, ga, u) = (d_su[i], lc.gate[i], lc.up[i]);
            let sg = sigmoid(ga);
            d_up[i] = ds * ga * sg; // silu(gate)
            // silu'(a) = σ(a)·(1 + a·(1 − σ(a)))
            d_gate[i] = ds * u * sg * (1.0 + ga * (1.0 - sg));
        }
        matmul_at_acc(&mut *g[li(l, L::WGate)], &lc.h2, &d_gate, bt, d, f);
        matmul_at_acc(&mut *g[li(l, L::WUp)], &lc.h2, &d_up, bt, d, f);
        let mut dh2 = vec![0.0f32; bt * d];
        matmul_bt_acc(&mut dh2, &d_gate, p[li(l, L::WGate)], bt, f, d);
        matmul_bt_acc(&mut dh2, &d_up, p[li(l, L::WUp)], bt, f, d);
        // residual: dx becomes ∂/∂xb = ∂/∂x_out + norm-chain term
        rms_norm_bwd(&lc.xb, p[li(l, L::Ln2)], &lc.r2, &dh2, d, &mut dx, &mut *g[li(l, L::Ln2)]);

        // ---- attention block: xb = xa + (attn(h1)) @ wo
        matmul_at_acc(&mut *g[li(l, L::Wo)], &lc.ctx, &dx, bt, d, d);
        let mut d_ctx = vec![0.0f32; bt * d];
        matmul_bt_acc(&mut d_ctx, &dx, p[li(l, L::Wo)], bt, d, d);

        let mut dq = vec![0.0f32; bt * d];
        let mut dk = vec![0.0f32; bt * d];
        let mut dv = vec![0.0f32; bt * d];
        let mut dp = vec![0.0f32; t];
        for bi in 0..b {
            for hi in 0..h {
                let hoff = hi * hd;
                let prow = &lc.probs[(bi * h + hi) * t * t..(bi * h + hi + 1) * t * t];
                for ti in 0..t {
                    let row = bi * t + ti;
                    let dctx_row = &d_ctx[row * d + hoff..row * d + hoff + hd];
                    // d_probs[ti,si] = dctx · v[si]; softmax-row dot
                    let mut pdot = 0.0f32;
                    for (si, dpv) in dp[..=ti].iter_mut().enumerate() {
                        let vrow = &lc.v[(bi * t + si) * d + hoff..(bi * t + si) * d + hoff + hd];
                        let mut acc = 0.0f32;
                        for (&dc, &vv) in dctx_row.iter().zip(vrow) {
                            acc += dc * vv;
                        }
                        *dpv = acc;
                        pdot += prow[ti * t + si] * acc;
                    }
                    let qrow = lc.q[row * d + hoff..row * d + hoff + hd].to_vec();
                    for si in 0..=ti {
                        let pr = prow[ti * t + si];
                        // dv[si] += p·dctx ; dscores = p·(dp − Σp·dp)·scale
                        let dsc = pr * (dp[si] - pdot) * inv_sqrt_hd;
                        let src = bi * t + si;
                        let krow = &lc.k[src * d + hoff..src * d + hoff + hd];
                        let dqrow = &mut dq[row * d + hoff..row * d + hoff + hd];
                        for (o, &kv) in dqrow.iter_mut().zip(krow) {
                            *o += dsc * kv;
                        }
                        let dkrow = &mut dk[src * d + hoff..src * d + hoff + hd];
                        for (o, &qv) in dkrow.iter_mut().zip(&qrow) {
                            *o += dsc * qv;
                        }
                        let dvrow = &mut dv[src * d + hoff..src * d + hoff + hd];
                        for (o, &dc) in dvrow.iter_mut().zip(dctx_row) {
                            *o += pr * dc;
                        }
                    }
                }
            }
        }

        matmul_at_acc(&mut *g[li(l, L::Wq)], &lc.h1, &dq, bt, d, d);
        matmul_at_acc(&mut *g[li(l, L::Wk)], &lc.h1, &dk, bt, d, d);
        matmul_at_acc(&mut *g[li(l, L::Wv)], &lc.h1, &dv, bt, d, d);
        let mut dh1 = vec![0.0f32; bt * d];
        matmul_bt_acc(&mut dh1, &dq, p[li(l, L::Wq)], bt, d, d);
        matmul_bt_acc(&mut dh1, &dk, p[li(l, L::Wk)], bt, d, d);
        matmul_bt_acc(&mut dh1, &dv, p[li(l, L::Wv)], bt, d, d);
        // residual: dx becomes ∂/∂xa
        rms_norm_bwd(&lc.xa, p[li(l, L::Ln1)], &lc.r1, &dh1, d, &mut dx, &mut *g[li(l, L::Ln1)]);
    }

    // embedding + positional (scatter-add over token / position rows)
    let (g_head, g_tail) = g.split_at_mut(IDX_POS);
    let g_embed = &mut *g_head[IDX_EMBED];
    let g_pos = &mut *g_tail[0];
    for (i, row) in dx.chunks_exact(d).enumerate() {
        let tok = inputs[i] as usize;
        let ti = i % t;
        for ((e, pg), &dxv) in g_embed[tok * d..(tok + 1) * d]
            .iter_mut()
            .zip(&mut g_pos[ti * d..(ti + 1) * d])
            .zip(row)
        {
            *e += dxv;
            *pg += dxv;
        }
    }

    Ok((loss, flat_grads))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn micro() -> ModelCfg {
        ModelCfg {
            name: "micro".into(),
            vocab: 13,
            dim: 8,
            layers: 1,
            heads: 2,
            seq_len: 6,
            batch: 2,
        }
    }

    fn micro_tokens(cfg: &ModelCfg, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..cfg.batch * (cfg.seq_len + 1)).map(|_| rng.below(cfg.vocab) as i32).collect()
    }

    #[test]
    fn registry_matches_python_configs() {
        let tiny = ModelCfg::by_name("tiny").unwrap();
        assert_eq!((tiny.dim, tiny.layers, tiny.heads, tiny.seq_len, tiny.batch), (64, 2, 2, 64, 4));
        assert_eq!(tiny.mlp_hidden(), 192);
        assert_eq!(tiny.flat_dim(), 143_680);
        assert_eq!(ModelCfg::by_name("lm100m").unwrap().mlp_hidden(), 2048);
        assert!(ModelCfg::by_name("gpt5").is_err());
        // spec order is the manifest contract
        let names: Vec<String> = tiny.param_specs().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names[0], "embed");
        assert_eq!(names[1], "pos");
        assert_eq!(names[2], "layer0.ln1");
        assert_eq!(names[10], "layer0.w_down");
        assert_eq!(names[names.len() - 2], "ln_f");
        assert_eq!(names[names.len() - 1], "head");
    }

    #[test]
    fn init_is_deterministic_and_scaled() {
        let cfg = ModelCfg::by_name("tiny").unwrap();
        let a = cfg.init_params(7);
        let b = cfg.init_params(7);
        assert_eq!(a, b);
        let c = cfg.init_params(8);
        assert_ne!(a, c);
        // ln params sit at exactly 1.0
        let specs = cfg.param_specs();
        let mut off = 0;
        for (name, shape) in &specs {
            let n: usize = shape.iter().product();
            if name.ends_with("ln1") || name.ends_with("ln_f") {
                assert!(a[off..off + n].iter().all(|&x| x == 1.0), "{name}");
            }
            off += n;
        }
    }

    #[test]
    fn loss_at_init_is_near_uniform() {
        let cfg = micro();
        let params = cfg.init_params(3);
        let tokens = micro_tokens(&cfg, 11);
        let loss = eval_step(&cfg, &params, &tokens).unwrap();
        let uniform = (cfg.vocab as f32).ln();
        assert!(
            (loss - uniform).abs() < 1.0,
            "init loss {loss} should be near ln(V) = {uniform}"
        );
    }

    #[test]
    fn train_and_eval_agree_on_loss() {
        let cfg = micro();
        let params = cfg.init_params(3);
        let tokens = micro_tokens(&cfg, 11);
        let (loss, grads) = train_step(&cfg, &params, &tokens).unwrap();
        let eval = eval_step(&cfg, &params, &tokens).unwrap();
        assert_eq!(loss, eval);
        assert_eq!(grads.len(), cfg.flat_dim());
        assert!(grads.iter().all(|g| g.is_finite()));
        assert!(grads.iter().any(|&g| g != 0.0));
    }

    #[test]
    fn rejects_bad_tokens() {
        let cfg = micro();
        let params = cfg.init_params(3);
        let mut tokens = micro_tokens(&cfg, 11);
        assert!(eval_step(&cfg, &params, &tokens[1..]).is_err());
        tokens[0] = cfg.vocab as i32;
        let err = eval_step(&cfg, &params, &tokens).unwrap_err().to_string();
        assert!(err.contains("vocab"), "{err}");
    }

    /// Central-difference gradient check of the full fused backward: the
    /// native `train_step` against numeric ∂loss/∂θ on sampled coords of
    /// every parameter tensor.
    #[test]
    fn gradients_match_finite_differences() {
        let cfg = micro();
        let params = cfg.init_params(5);
        let tokens = micro_tokens(&cfg, 17);
        let (_, grads) = train_step(&cfg, &params, &tokens).unwrap();

        let specs = cfg.param_specs();
        let mut probe_rng = Rng::new(99);
        let eps = 2e-3f32;
        let mut off = 0usize;
        for (name, shape) in &specs {
            let n: usize = shape.iter().product();
            for _ in 0..4 {
                let idx = off + probe_rng.below(n);
                let mut pp = params.clone();
                pp[idx] += eps;
                let lp = eval_step(&cfg, &pp, &tokens).unwrap();
                pp[idx] = params[idx] - eps;
                let lm = eval_step(&cfg, &pp, &tokens).unwrap();
                let fd = (lp - lm) / (2.0 * eps);
                let an = grads[idx];
                assert!(
                    (fd - an).abs() <= 2e-2 * (1.0 + fd.abs().max(an.abs())),
                    "grad check failed for {name}[{}]: analytic={an} fd={fd}",
                    idx - off
                );
            }
            off += n;
        }
    }
}
