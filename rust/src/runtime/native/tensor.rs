//! Dense tensor primitives for the native backend: row-major f32
//! matmuls in the four orientations the transformer forward/backward
//! needs, with deterministic row-parallelism.
//!
//! Parallel splits are over *output rows* (disjoint `&mut` blocks), so
//! every product is bit-identical to the sequential loop regardless of
//! thread count — the same determinism contract as
//! [`crate::util::parallel`]. The inner loops are written in `(i, k, j)`
//! order (broadcast `a[i,k]`, stream `b` rows) so the compiler
//! auto-vectorizes the j-loop.

use crate::util::parallel::auto_threads;

/// Run `f(row_index, row)` over the rows of `out`, splitting across
/// threads when `total_flops` is large enough to amortize spawn/join.
/// `f` must be pure per row.
fn par_rows<F>(out: &mut [f32], row_len: usize, total_flops: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len() % row_len.max(1), 0);
    let rows = if row_len == 0 { 0 } else { out.len() / row_len };
    let nthreads = auto_threads(total_flops).min(rows.max(1));
    if nthreads <= 1 {
        for (i, row) in out.chunks_mut(row_len).enumerate() {
            f(i, row);
        }
        return;
    }
    let block = rows.div_ceil(nthreads);
    std::thread::scope(|s| {
        for (bi, chunk) in out.chunks_mut(block * row_len).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (j, row) in chunk.chunks_mut(row_len).enumerate() {
                    f(bi * block + j, row);
                }
            });
        }
    });
}

/// `out[m,n] = a[m,k] @ b[k,n]` (overwrite).
pub fn matmul(out: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    par_rows(out, n, m * k * n, |i, row| {
        row.fill(0.0);
        let arow = &a[i * k..(i + 1) * k];
        for (kk, &aik) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in row.iter_mut().zip(brow) {
                *o += aik * bv;
            }
        }
    });
}

/// `out[m,k] += a[m,n] @ b[k,n]ᵀ` — the `dy @ Wᵀ` backward orientation.
/// Each output element is a row·row dot, so both operands stream.
pub fn matmul_bt_acc(out: &mut [f32], a: &[f32], b: &[f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * k);
    par_rows(out, k, m * k * n, |i, row| {
        let arow = &a[i * n..(i + 1) * n];
        for (j, o) in row.iter_mut().enumerate() {
            let brow = &b[j * n..(j + 1) * n];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *o += acc;
        }
    });
}

/// `out[m,n] += a[r,m]ᵀ @ b[r,n]` — the `xᵀ @ dy` weight-gradient
/// orientation. Output row `i` accumulates `a[r,i] * b[r,·]` over all
/// shared rows `r`.
pub fn matmul_at_acc(out: &mut [f32], a: &[f32], b: &[f32], r: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), r * m);
    debug_assert_eq!(b.len(), r * n);
    debug_assert_eq!(out.len(), m * n);
    par_rows(out, n, r * m * n, |i, row| {
        for rr in 0..r {
            let aik = a[rr * m + i];
            if aik == 0.0 {
                continue;
            }
            let brow = &b[rr * n..(rr + 1) * n];
            for (o, &bv) in row.iter_mut().zip(brow) {
                *o += aik * bv;
            }
        }
    });
}

/// Numerically-stable log-sum-exp of one logit row.
pub fn log_sum_exp(row: &[f32]) -> f32 {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let s: f32 = row.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// `sigmoid(x)`.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    #[test]
    fn orientations_agree_with_naive() {
        let mut rng = Rng::new(0xBEEF);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 7), (8, 16, 4), (17, 9, 33)] {
            let mut a = vec![0.0f32; m * k];
            let mut b = vec![0.0f32; k * n];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            let want = naive_matmul(&a, &b, m, k, n);
            let mut got = vec![0.0f32; m * n];
            matmul(&mut got, &a, &b, m, k, n);
            assert_eq!(got, want, "matmul {m}x{k}x{n}");

            // a@b == (a) @ (bᵀ)ᵀ: check bt against a naive transpose
            let mut bt = vec![0.0f32; n * k];
            for kk in 0..k {
                for j in 0..n {
                    bt[j * k + kk] = b[kk * n + j];
                }
            }
            let mut got_bt = vec![0.0f32; m * n];
            matmul_bt_acc(&mut got_bt, &a, &bt, m, k, n);
            crate::testing::assert_allclose(&got_bt, &want, 1e-5, 1e-5, "matmul_bt_acc");

            // aᵀ@b via at_acc on a pre-transposed a
            let mut at = vec![0.0f32; k * m];
            for i in 0..m {
                for kk in 0..k {
                    at[kk * m + i] = a[i * k + kk];
                }
            }
            let mut got_at = vec![0.0f32; m * n];
            matmul_at_acc(&mut got_at, &at, &b, k, m, n);
            crate::testing::assert_allclose(&got_at, &want, 1e-5, 1e-5, "matmul_at_acc");
        }
    }

    #[test]
    fn acc_variants_accumulate() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 4.0];
        // 1x2 @ (1x2)ᵀ = [[11]]
        let mut out = vec![100.0f32];
        matmul_bt_acc(&mut out, &a, &b, 1, 2, 1);
        assert_eq!(out, vec![111.0]);
    }

    #[test]
    fn log_sum_exp_stable() {
        let row = [1000.0f32, 1000.0];
        let lse = log_sum_exp(&row);
        assert!((lse - (1000.0 + 2.0f32.ln())).abs() < 1e-3);
        assert!(log_sum_exp(&[0.0, 0.0, 0.0, 0.0]).abs() - 4.0f32.ln().abs() < 1e-6);
    }
}
