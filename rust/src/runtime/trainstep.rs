//! Typed wrappers over the artifact set, marshalled through
//! [`HostTensor`] so they are backend-agnostic:
//!
//! * [`TrainStepExec`] — the L2 transformer `train_step`:
//!   (tokens i32[B,T+1], params…) → (loss f32[], grads…), one fused
//!   executable for forward + backward.
//! * [`LionUpdateExec`] — the L1 fused Lion kernel:
//!   (m f32[d], g f32[d]) → (delta i8[d] ∈ {−1,+1}, m_new f32[d]).
//! * [`EvalStepExec`] — loss-only evaluation.

use crate::error::{DlionError, Result};
use crate::runtime::backend::HostTensor;
use crate::runtime::Runtime;

fn token_shape(rt: &Runtime, artifact: &str) -> Result<(usize, usize)> {
    let spec = rt.manifest.artifact(artifact)?;
    let tok = spec
        .inputs
        .first()
        .ok_or_else(|| DlionError::Artifact(format!("{artifact} has no inputs")))?;
    if tok.shape.len() != 2 {
        return Err(DlionError::Artifact(format!(
            "{artifact} token input must be [B, T+1], got {:?}",
            tok.shape
        )));
    }
    Ok((tok.shape[0], tok.shape[1]))
}

/// tokens + per-tensor param views, in manifest order.
fn step_inputs(
    rt: &Runtime,
    flat_params: &[f32],
    tokens: &[i32],
    batch: usize,
    seq_plus1: usize,
) -> Result<Vec<HostTensor>> {
    let m = &rt.manifest;
    let views = m.split_flat(flat_params)?;
    let mut inputs = Vec::with_capacity(1 + views.len());
    inputs.push(HostTensor::i32(tokens.to_vec(), &[batch, seq_plus1]));
    for (view, spec) in views.iter().zip(&m.params) {
        inputs.push(HostTensor::f32(view.to_vec(), &spec.shape));
    }
    Ok(inputs)
}

/// Fused forward+backward over the transformer.
pub struct TrainStepExec<'rt> {
    rt: &'rt Runtime,
    pub batch: usize,
    pub seq_plus1: usize,
}

impl<'rt> TrainStepExec<'rt> {
    pub fn new(rt: &'rt Runtime) -> Result<Self> {
        let (batch, seq_plus1) = token_shape(rt, "train_step")?;
        Ok(TrainStepExec { rt, batch, seq_plus1 })
    }

    /// Run fwd+bwd: `flat_params` is the coordinator's flat buffer,
    /// `tokens` is row-major [B, T+1]. Writes flat gradients into
    /// `grad_out` and returns the scalar loss.
    pub fn run(&self, flat_params: &[f32], tokens: &[i32], grad_out: &mut [f32]) -> Result<f32> {
        let m = &self.rt.manifest;
        if grad_out.len() != m.flat_dim {
            return Err(DlionError::Runtime("grad_out size mismatch".into()));
        }
        let inputs = step_inputs(self.rt, flat_params, tokens, self.batch, self.seq_plus1)?;
        let outputs = self.rt.run("train_step", &inputs)?;
        if outputs.len() != 1 + m.params.len() {
            return Err(DlionError::Runtime(format!(
                "train_step returned {} outputs, expected {}",
                outputs.len(),
                1 + m.params.len()
            )));
        }
        let loss = outputs[0].scalar()?;
        for (out, spec) in outputs[1..].iter().zip(&m.params) {
            let src = out.as_f32()?;
            if src.len() != spec.numel() {
                return Err(DlionError::Runtime(format!(
                    "train_step grad '{}' has {} elems, expected {}",
                    spec.name,
                    src.len(),
                    spec.numel()
                )));
            }
            grad_out[spec.offset..spec.offset + spec.numel()].copy_from_slice(src);
        }
        Ok(loss)
    }
}

/// Loss-only eval step.
pub struct EvalStepExec<'rt> {
    rt: &'rt Runtime,
    pub batch: usize,
    pub seq_plus1: usize,
}

impl<'rt> EvalStepExec<'rt> {
    pub fn new(rt: &'rt Runtime) -> Result<Self> {
        let (batch, seq_plus1) = token_shape(rt, "eval_step")?;
        Ok(EvalStepExec { rt, batch, seq_plus1 })
    }

    pub fn run(&self, flat_params: &[f32], tokens: &[i32]) -> Result<f32> {
        let inputs = step_inputs(self.rt, flat_params, tokens, self.batch, self.seq_plus1)?;
        let outputs = self.rt.run("eval_step", &inputs)?;
        outputs
            .first()
            .ok_or_else(|| DlionError::Runtime("eval_step returned no outputs".into()))?
            .scalar()
    }
}

/// The fused Lion kernel (L1): one pass producing the binary update and
/// the new momentum.
pub struct LionUpdateExec<'rt> {
    rt: &'rt Runtime,
    pub dim: usize,
}

impl<'rt> LionUpdateExec<'rt> {
    pub fn new(rt: &'rt Runtime) -> Result<Self> {
        let spec = rt.manifest.artifact("lion_update")?;
        let dim = spec
            .inputs
            .first()
            .map(|t| t.numel())
            .ok_or_else(|| DlionError::Artifact("lion_update has no inputs".into()))?;
        Ok(LionUpdateExec { rt, dim })
    }

    /// (m, g) → (delta ∈ {−1,+1} as i8, m_new).
    pub fn run(&self, m: &[f32], g: &[f32]) -> Result<(Vec<i8>, Vec<f32>)> {
        if m.len() != self.dim || g.len() != self.dim {
            return Err(DlionError::Runtime(format!(
                "lion_update dim mismatch: kernel d={}, got m={} g={}",
                self.dim,
                m.len(),
                g.len()
            )));
        }
        let inputs = [
            HostTensor::f32(m.to_vec(), &[self.dim]),
            HostTensor::f32(g.to_vec(), &[self.dim]),
        ];
        let outputs = self.rt.run("lion_update", &inputs)?;
        if outputs.len() != 2 {
            return Err(DlionError::Runtime(format!(
                "lion_update returned {} outputs, expected 2",
                outputs.len()
            )));
        }
        Ok((outputs[0].as_i8()?.to_vec(), outputs[1].as_f32()?.to_vec()))
    }
}
