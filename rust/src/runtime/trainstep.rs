//! Typed wrappers over the AOT artifacts:
//!
//! * [`TrainStepExec`] — the L2 transformer `train_step`:
//!   (tokens i32[B,T+1], params…) → (loss f32[], grads…), one fused
//!   executable for forward + backward.
//! * [`LionUpdateExec`] — the L1 Pallas fused Lion kernel:
//!   (m f32[d], g f32[d]) → (delta i8[d] ∈ {−1,+1}, m_new f32[d]).
//! * [`EvalStepExec`] — loss-only evaluation.

use crate::error::{DlionError, Result};
use crate::runtime::Runtime;

/// Fused forward+backward over the transformer.
pub struct TrainStepExec<'rt> {
    rt: &'rt Runtime,
    pub batch: usize,
    pub seq_plus1: usize,
}

impl<'rt> TrainStepExec<'rt> {
    pub fn new(rt: &'rt Runtime) -> Result<Self> {
        let spec = rt.manifest.artifact("train_step")?;
        let tok = spec
            .inputs
            .first()
            .ok_or_else(|| DlionError::Artifact("train_step has no inputs".into()))?;
        if tok.shape.len() != 2 {
            return Err(DlionError::Artifact(format!(
                "train_step token input must be [B, T+1], got {:?}",
                tok.shape
            )));
        }
        // warm the compile cache
        rt.executable("train_step")?;
        Ok(TrainStepExec { rt, batch: tok.shape[0], seq_plus1: tok.shape[1] })
    }

    /// Run fwd+bwd: `flat_params` is the coordinator's flat buffer,
    /// `tokens` is row-major [B, T+1]. Writes flat gradients into
    /// `grad_out` and returns the scalar loss.
    pub fn run(&self, flat_params: &[f32], tokens: &[i32], grad_out: &mut [f32]) -> Result<f32> {
        let m = &self.rt.manifest;
        if grad_out.len() != m.flat_dim {
            return Err(DlionError::Runtime("grad_out size mismatch".into()));
        }
        let views = m.split_flat(flat_params)?;
        let mut inputs = Vec::with_capacity(1 + views.len());
        inputs.push(self.rt.literal_i32(tokens, &[self.batch, self.seq_plus1])?);
        for (view, spec) in views.iter().zip(&m.params) {
            inputs.push(self.rt.literal_f32(view, &spec.shape)?);
        }
        let outputs = self.rt.run("train_step", &inputs)?;
        if outputs.len() != 1 + m.params.len() {
            return Err(DlionError::Runtime(format!(
                "train_step returned {} outputs, expected {}",
                outputs.len(),
                1 + m.params.len()
            )));
        }
        let loss = outputs[0].to_vec::<f32>()?[0];
        for (out, spec) in outputs[1..].iter().zip(&m.params) {
            let dst = &mut grad_out[spec.offset..spec.offset + spec.numel()];
            out.copy_raw_to(dst)?;
        }
        Ok(loss)
    }
}

/// Loss-only eval step.
pub struct EvalStepExec<'rt> {
    rt: &'rt Runtime,
    pub batch: usize,
    pub seq_plus1: usize,
}

impl<'rt> EvalStepExec<'rt> {
    pub fn new(rt: &'rt Runtime) -> Result<Self> {
        let spec = rt.manifest.artifact("eval_step")?;
        let tok = spec
            .inputs
            .first()
            .ok_or_else(|| DlionError::Artifact("eval_step has no inputs".into()))?;
        rt.executable("eval_step")?;
        Ok(EvalStepExec { rt, batch: tok.shape[0], seq_plus1: tok.shape[1] })
    }

    pub fn run(&self, flat_params: &[f32], tokens: &[i32]) -> Result<f32> {
        let m = &self.rt.manifest;
        let views = m.split_flat(flat_params)?;
        let mut inputs = Vec::with_capacity(1 + views.len());
        inputs.push(self.rt.literal_i32(tokens, &[self.batch, self.seq_plus1])?);
        for (view, spec) in views.iter().zip(&m.params) {
            inputs.push(self.rt.literal_f32(view, &spec.shape)?);
        }
        let outputs = self.rt.run("eval_step", &inputs)?;
        Ok(outputs[0].to_vec::<f32>()?[0])
    }
}

/// The fused Pallas Lion kernel (L1): one pass producing the binary
/// update and the new momentum.
pub struct LionUpdateExec<'rt> {
    rt: &'rt Runtime,
    pub dim: usize,
}

impl<'rt> LionUpdateExec<'rt> {
    pub fn new(rt: &'rt Runtime) -> Result<Self> {
        let spec = rt.manifest.artifact("lion_update")?;
        let dim = spec
            .inputs
            .first()
            .map(|t| t.numel())
            .ok_or_else(|| DlionError::Artifact("lion_update has no inputs".into()))?;
        rt.executable("lion_update")?;
        Ok(LionUpdateExec { rt, dim })
    }

    /// (m, g) → (delta ∈ {−1,+1} as i8, m_new).
    pub fn run(&self, m: &[f32], g: &[f32]) -> Result<(Vec<i8>, Vec<f32>)> {
        if m.len() != self.dim || g.len() != self.dim {
            return Err(DlionError::Runtime(format!(
                "lion_update dim mismatch: kernel d={}, got m={} g={}",
                self.dim,
                m.len(),
                g.len()
            )));
        }
        let inputs = [
            self.rt.literal_f32(m, &[self.dim])?,
            self.rt.literal_f32(g, &[self.dim])?,
        ];
        let outputs = self.rt.run("lion_update", &inputs)?;
        let delta = outputs[0].to_vec::<i8>()?;
        let m_new = outputs[1].to_vec::<f32>()?;
        Ok((delta, m_new))
    }
}
