//! Synthetic vision dataset — the CIFAR-10 stand-in for the Figure 2–4
//! sweeps (CIFAR itself is not redistributable inside this sandbox; see
//! DESIGN.md). Ten classes of 16×16 grayscale images built from
//! per-class frequency-grating templates plus per-sample deformation
//! and additive noise, so the task is learnable but not trivially
//! linearly separable; class difficulty varies with template overlap.

use crate::util::Rng;

pub const IMG_SIDE: usize = 16;
pub const IMG_DIM: usize = IMG_SIDE * IMG_SIDE;
pub const NUM_CLASSES: usize = 10;

/// A fixed synthetic classification dataset.
pub struct VisionData {
    pub train_x: Vec<f32>, // n_train × IMG_DIM
    pub train_y: Vec<u8>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<u8>,
    pub n_train: usize,
    pub n_test: usize,
}

fn template(class: usize, rng: &mut Rng) -> Vec<f32> {
    // Each class: sum of 2 oriented gratings + a class-specific blob.
    let fx1 = 1.0 + rng.uniform() as f32 * 3.0;
    let fy1 = 1.0 + rng.uniform() as f32 * 3.0;
    let fx2 = 1.0 + rng.uniform() as f32 * 5.0;
    let fy2 = 1.0 + rng.uniform() as f32 * 5.0;
    let ph1 = rng.uniform() as f32 * std::f32::consts::TAU;
    let ph2 = rng.uniform() as f32 * std::f32::consts::TAU;
    let cx = rng.uniform() as f32 * IMG_SIDE as f32;
    let cy = rng.uniform() as f32 * IMG_SIDE as f32;
    let mut t = vec![0.0f32; IMG_DIM];
    for y in 0..IMG_SIDE {
        for x in 0..IMG_SIDE {
            let xf = x as f32 / IMG_SIDE as f32 * std::f32::consts::TAU;
            let yf = y as f32 / IMG_SIDE as f32 * std::f32::consts::TAU;
            let g1 = (fx1 * xf + fy1 * yf + ph1).sin();
            let g2 = (fx2 * xf + fy2 * yf + ph2).cos();
            let dx = x as f32 - cx;
            let dy = y as f32 - cy;
            let blob = (-(dx * dx + dy * dy) / 18.0).exp();
            t[y * IMG_SIDE + x] = 0.6 * g1 + 0.4 * g2 + 1.2 * blob;
        }
    }
    // class parity flips contrast to add template diversity
    if class % 2 == 1 {
        for v in t.iter_mut() {
            *v = -*v;
        }
    }
    t
}

impl VisionData {
    /// Generate deterministically from `seed`.
    pub fn generate(n_train: usize, n_test: usize, noise: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let templates: Vec<Vec<f32>> = (0..NUM_CLASSES).map(|c| template(c, &mut rng)).collect();
        let make = |n: usize, rng: &mut Rng| {
            let mut xs = vec![0.0f32; n * IMG_DIM];
            let mut ys = vec![0u8; n];
            for i in 0..n {
                let c = rng.below(NUM_CLASSES);
                ys[i] = c as u8;
                let shift_x = rng.below(3) as isize - 1; // small translation jitter
                let shift_y = rng.below(3) as isize - 1;
                let amp = 0.8 + 0.4 * rng.uniform() as f32;
                let row = &mut xs[i * IMG_DIM..(i + 1) * IMG_DIM];
                for y in 0..IMG_SIDE {
                    for x in 0..IMG_SIDE {
                        let sx = (x as isize + shift_x).rem_euclid(IMG_SIDE as isize) as usize;
                        let sy = (y as isize + shift_y).rem_euclid(IMG_SIDE as isize) as usize;
                        row[y * IMG_SIDE + x] =
                            amp * templates[c][sy * IMG_SIDE + sx] + rng.normal_f32(0.0, noise);
                    }
                }
            }
            (xs, ys)
        };
        let (train_x, train_y) = make(n_train, &mut rng);
        let (test_x, test_y) = make(n_test, &mut rng);
        VisionData { train_x, train_y, test_x, test_y, n_train, n_test }
    }

    pub fn train_row(&self, i: usize) -> (&[f32], usize) {
        (&self.train_x[i * IMG_DIM..(i + 1) * IMG_DIM], self.train_y[i] as usize)
    }

    pub fn test_row(&self, i: usize) -> (&[f32], usize) {
        (&self.test_x[i * IMG_DIM..(i + 1) * IMG_DIM], self.test_y[i] as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = VisionData::generate(50, 10, 0.3, 42);
        let b = VisionData::generate(50, 10, 0.3, 42);
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.train_y, b.train_y);
        let c = VisionData::generate(50, 10, 0.3, 43);
        assert_ne!(a.train_x, c.train_x);
    }

    #[test]
    fn labels_cover_all_classes() {
        let d = VisionData::generate(500, 100, 0.3, 1);
        let mut seen = [false; NUM_CLASSES];
        for &y in &d.train_y {
            seen[y as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn nearest_template_is_informative() {
        // Sanity: a nearest-class-mean classifier on clean data should beat
        // chance by a wide margin, i.e. the dataset is actually learnable.
        let d = VisionData::generate(2000, 400, 0.3, 7);
        let mut means = vec![vec![0.0f64; IMG_DIM]; NUM_CLASSES];
        let mut counts = vec![0usize; NUM_CLASSES];
        for i in 0..d.n_train {
            let (x, y) = d.train_row(i);
            counts[y] += 1;
            for (m, &v) in means[y].iter_mut().zip(x) {
                *m += v as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..d.n_test {
            let (x, y) = d.test_row(i);
            let best = (0..NUM_CLASSES)
                .min_by(|&a, &b| {
                    let da: f64 = x
                        .iter()
                        .zip(&means[a])
                        .map(|(&v, &m)| (v as f64 - m).powi(2))
                        .sum();
                    let db: f64 = x
                        .iter()
                        .zip(&means[b])
                        .map(|(&v, &m)| (v as f64 - m).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == y {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.n_test as f64;
        assert!(acc > 0.5, "nearest-mean acc={acc}, dataset too hard");
        assert!(acc < 1.0, "dataset trivially separable");
    }
}
