//! Linear-regression task on a fixed synthetic design matrix:
//! f(w) = 1/(2n) Σ (xᵢᵀw − yᵢ)², minibatched by row sampling.
//! A convex task with *data* (not additive-noise) stochasticity — the
//! regime Assumption 4.1 actually describes.

use super::{Eval, GradTask};
use crate::util::Rng;

pub struct LinReg {
    pub dim: usize,
    rows: Vec<f32>, // n × dim, row-major
    targets: Vec<f32>,
    n: usize,
    pub truth: Vec<f32>,
}

impl LinReg {
    pub fn new(dim: usize, n: usize, noise: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut truth = vec![0.0f32; dim];
        rng.fill_normal(&mut truth, 1.0);
        let mut rows = vec![0.0f32; n * dim];
        rng.fill_normal(&mut rows, 1.0);
        let targets: Vec<f32> = (0..n)
            .map(|i| {
                let x = &rows[i * dim..(i + 1) * dim];
                crate::util::math::dot(x, &truth) as f32 + rng.normal_f32(0.0, noise)
            })
            .collect();
        LinReg { dim, rows, targets, n, truth }
    }

    fn row(&self, i: usize) -> &[f32] {
        &self.rows[i * self.dim..(i + 1) * self.dim]
    }
}

impl GradTask for LinReg {
    fn name(&self) -> String {
        format!("linreg-d{}-n{}", self.dim, self.n)
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        let mut p = vec![0.0f32; self.dim];
        rng.fill_normal(&mut p, 0.1);
        p
    }

    fn minibatch_grad(
        &self,
        params: &[f32],
        rng: &mut Rng,
        batch: usize,
        grad: &mut [f32],
    ) -> f32 {
        grad.iter_mut().for_each(|g| *g = 0.0);
        let b = batch.max(1);
        let mut loss = 0.0f64;
        for _ in 0..b {
            let i = rng.below(self.n);
            let x = self.row(i);
            let err = crate::util::math::dot(x, params) as f32 - self.targets[i];
            loss += 0.5 * (err as f64) * (err as f64);
            crate::util::math::axpy(err / b as f32, x, grad);
        }
        (loss / b as f64) as f32
    }

    fn evaluate(&self, params: &[f32]) -> Eval {
        let mut loss = 0.0f64;
        for i in 0..self.n {
            let err = crate::util::math::dot(self.row(i), params) as f32 - self.targets[i];
            loss += 0.5 * (err as f64) * (err as f64);
        }
        Eval { loss: loss / self.n as f64, accuracy: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn truth_is_near_optimal() {
        let t = LinReg::new(8, 200, 0.01, 5);
        let at_truth = t.evaluate(&t.truth).loss;
        let mut rng = Rng::new(6);
        let random = t.evaluate(&t.init_params(&mut rng)).loss;
        assert!(at_truth < random / 10.0, "truth={at_truth} random={random}");
    }

    #[test]
    fn finite_diff() {
        let t = LinReg::new(10, 100, 0.1, 7);
        super::super::finite_diff_check(&t, 11, 8, 8, 2e-2);
    }

    #[test]
    fn full_batch_gradient_descent_converges() {
        let t = LinReg::new(6, 100, 0.0, 8);
        let mut rng = Rng::new(9);
        let mut p = t.init_params(&mut rng);
        let mut g = vec![0.0f32; 6];
        for _ in 0..500 {
            t.minibatch_grad(&p, &mut Rng::new(1), 100, &mut g);
            crate::util::math::axpy(-0.05, &g.clone(), &mut p);
        }
        assert!(t.evaluate(&p).loss < 1e-2);
    }
}
