//! Two-layer MLP classifier with hand-written backprop over the
//! synthetic vision dataset — the rust-native model behind the
//! Figure 2–4 sweeps (the paper's ViT-on-CIFAR role; the attention
//! transformer itself lives in the JAX/PJRT path, `crate::lm`).
//!
//! Architecture: x → W1·x + b1 → ReLU → W2·h + b2 → softmax CE.
//! Flat parameter layout: [W1 (h×in), b1 (h), W2 (c×h), b2 (c)].

use super::data::{VisionData, IMG_DIM, NUM_CLASSES};
use super::{Eval, GradTask};
use crate::util::math::softmax;
use crate::util::Rng;
use std::sync::Arc;

/// How training data is partitioned across workers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sharding {
    /// every worker samples the full training set (the paper's main
    /// setting, footnote 3)
    Iid,
    /// class-skewed: with probability `alpha` a worker samples only from
    /// classes c with c ≡ worker (mod nworkers); with probability
    /// 1−alpha it samples uniformly. alpha=0 ⇒ Iid, alpha=1 ⇒ fully
    /// partitioned (the hardest non-i.i.d. regime).
    ByClass { alpha: f64 },
}

pub struct MlpVision {
    pub data: Arc<VisionData>,
    pub hidden: usize,
    pub input: usize,
    pub classes: usize,
    pub sharding: Sharding,
    /// train-row indices grouped by label (for ByClass sampling)
    by_class: Vec<Vec<usize>>,
}

impl MlpVision {
    pub fn new(data: Arc<VisionData>, hidden: usize) -> Self {
        Self::with_sharding(data, hidden, Sharding::Iid)
    }

    pub fn with_sharding(data: Arc<VisionData>, hidden: usize, sharding: Sharding) -> Self {
        let mut by_class = vec![Vec::new(); NUM_CLASSES];
        for i in 0..data.n_train {
            by_class[data.train_y[i] as usize].push(i);
        }
        MlpVision { data, hidden, input: IMG_DIM, classes: NUM_CLASSES, sharding, by_class }
    }

    /// Draw one training-row index respecting the sharding policy.
    fn draw_index(&self, rng: &mut Rng, worker: usize, nworkers: usize) -> usize {
        match self.sharding {
            Sharding::Iid => rng.below(self.data.n_train),
            Sharding::ByClass { alpha } => {
                if rng.uniform() < alpha && nworkers > 0 {
                    // sample among this worker's resident classes
                    let mine: Vec<usize> = (0..self.classes)
                        .filter(|c| c % nworkers == worker % nworkers)
                        .collect();
                    let c = mine[rng.below(mine.len())];
                    let rows = &self.by_class[c];
                    rows[rng.below(rows.len())]
                } else {
                    rng.below(self.data.n_train)
                }
            }
        }
    }

    #[inline]
    fn w1_len(&self) -> usize {
        self.hidden * self.input
    }
    #[inline]
    fn w2_off(&self) -> usize {
        self.w1_len() + self.hidden
    }
    #[inline]
    fn b2_off(&self) -> usize {
        self.w2_off() + self.classes * self.hidden
    }

    /// Forward pass for one sample; fills hidden activations and logits.
    fn forward(&self, params: &[f32], x: &[f32], h: &mut [f32], logits: &mut [f32]) {
        let (w1, rest) = params.split_at(self.w1_len());
        let (b1, rest) = rest.split_at(self.hidden);
        let (w2, b2) = rest.split_at(self.classes * self.hidden);
        for j in 0..self.hidden {
            let row = &w1[j * self.input..(j + 1) * self.input];
            let z = crate::util::math::dot(row, x) as f32 + b1[j];
            h[j] = z.max(0.0); // ReLU
        }
        for c in 0..self.classes {
            let row = &w2[c * self.hidden..(c + 1) * self.hidden];
            logits[c] = crate::util::math::dot(row, h) as f32 + b2[c];
        }
    }

    /// Loss + gradient for one (x, y); accumulates into `grad`.
    fn backward(
        &self,
        params: &[f32],
        x: &[f32],
        y: usize,
        scale: f32,
        grad: &mut [f32],
        h: &mut [f32],
        logits: &mut [f32],
        probs: &mut [f32],
    ) -> f32 {
        self.forward(params, x, h, logits);
        softmax(logits, probs);
        let loss = -(probs[y].max(1e-12)).ln();
        // dL/dlogit = p - onehot(y)
        let w2 = &params[self.w2_off()..self.b2_off()];
        let (gw2_all, gb2_zone) = {
            let (head, tail) = grad.split_at_mut(self.b2_off());
            (head, tail)
        };
        let (gw1_zone, g_rest) = gw2_all.split_at_mut(self.w1_len());
        let (gb1_zone, gw2_zone) = g_rest.split_at_mut(self.hidden);
        // backprop to hidden
        let mut dh = vec![0.0f32; self.hidden];
        for c in 0..self.classes {
            let dlogit = (probs[c] - if c == y { 1.0 } else { 0.0 }) * scale;
            gb2_zone[c] += dlogit;
            let w2row = &w2[c * self.hidden..(c + 1) * self.hidden];
            let gw2row = &mut gw2_zone[c * self.hidden..(c + 1) * self.hidden];
            for j in 0..self.hidden {
                gw2row[j] += dlogit * h[j];
                dh[j] += dlogit * w2row[j];
            }
        }
        // through ReLU into layer 1
        for j in 0..self.hidden {
            if h[j] > 0.0 {
                let dz = dh[j];
                gb1_zone[j] += dz;
                let gw1row = &mut gw1_zone[j * self.input..(j + 1) * self.input];
                crate::util::math::axpy(dz, x, gw1row);
            }
        }
        loss
    }
}

impl GradTask for MlpVision {
    fn name(&self) -> String {
        format!("mlp-vision-h{}", self.hidden)
    }

    fn dim(&self) -> usize {
        self.b2_off() + self.classes
    }

    fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        let mut p = vec![0.0f32; self.dim()];
        // He init for W1, Xavier-ish for W2, zero biases.
        let s1 = (2.0 / self.input as f32).sqrt();
        let s2 = (1.0 / self.hidden as f32).sqrt();
        let w1_len = self.w1_len();
        let w2_off = self.w2_off();
        let b2_off = self.b2_off();
        rng.fill_normal(&mut p[..w1_len], s1);
        let (_, tail) = p.split_at_mut(w2_off);
        rng.fill_normal(&mut tail[..b2_off - w2_off], s2);
        p
    }

    fn minibatch_grad(
        &self,
        params: &[f32],
        rng: &mut Rng,
        batch: usize,
        grad: &mut [f32],
    ) -> f32 {
        self.minibatch_grad_worker(params, rng, batch, grad, 0, 0)
    }

    fn minibatch_grad_worker(
        &self,
        params: &[f32],
        rng: &mut Rng,
        batch: usize,
        grad: &mut [f32],
        worker: usize,
        nworkers: usize,
    ) -> f32 {
        grad.iter_mut().for_each(|g| *g = 0.0);
        let b = batch.max(1);
        let scale = 1.0 / b as f32;
        let mut h = vec![0.0f32; self.hidden];
        let mut logits = vec![0.0f32; self.classes];
        let mut probs = vec![0.0f32; self.classes];
        let mut loss = 0.0f64;
        for _ in 0..b {
            let i = self.draw_index(rng, worker, nworkers);
            let (x, y) = self.data.train_row(i);
            loss += self
                .backward(params, x, y, scale, grad, &mut h, &mut logits, &mut probs)
                as f64;
        }
        (loss / b as f64) as f32
    }

    fn evaluate(&self, params: &[f32]) -> Eval {
        let mut h = vec![0.0f32; self.hidden];
        let mut logits = vec![0.0f32; self.classes];
        let mut probs = vec![0.0f32; self.classes];
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for i in 0..self.data.n_test {
            let (x, y) = self.data.test_row(i);
            self.forward(params, x, &mut h, &mut logits);
            softmax(&logits, &mut probs);
            loss += -(probs[y].max(1e-12) as f64).ln();
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == y {
                correct += 1;
            }
        }
        Eval {
            loss: loss / self.data.n_test as f64,
            accuracy: Some(correct as f64 / self.data.n_test as f64),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::lion::Lion;
    use crate::optim::{LionParams, Optimizer};

    fn small_task() -> MlpVision {
        let data = Arc::new(VisionData::generate(400, 100, 0.3, 11));
        MlpVision::new(data, 16)
    }

    #[test]
    fn dim_matches_layout() {
        let t = small_task();
        assert_eq!(t.dim(), 16 * 256 + 16 + 10 * 16 + 10);
    }

    #[test]
    fn finite_diff() {
        let t = small_task();
        super::super::finite_diff_check(&t, 21, 4, 10, 5e-2);
    }

    #[test]
    fn byclass_sharding_skews_labels() {
        let data = Arc::new(VisionData::generate(1000, 100, 0.3, 13));
        let t = MlpVision::with_sharding(data, 8, Sharding::ByClass { alpha: 1.0 });
        let mut rng = Rng::new(17);
        let nworkers = 5;
        // worker 0 with alpha=1 must only see classes ≡ 0 (mod 5)
        for _ in 0..200 {
            let i = t.draw_index(&mut rng, 0, nworkers);
            let (_, y) = t.data.train_row(i);
            assert_eq!(y % nworkers, 0, "worker 0 saw class {y}");
        }
        // alpha=0 is i.i.d. — all classes appear
        let t = MlpVision::with_sharding(t.data.clone(), 8, Sharding::ByClass { alpha: 0.0 });
        let mut seen = [false; 10];
        for _ in 0..2000 {
            let i = t.draw_index(&mut rng, 0, nworkers);
            seen[t.data.train_row(i).1] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn lion_training_beats_chance() {
        let t = small_task();
        let mut rng = Rng::new(31);
        let mut p = t.init_params(&mut rng);
        let mut lion = Lion::new(t.dim(), LionParams { weight_decay: 0.001, ..Default::default() });
        let mut g = vec![0.0f32; t.dim()];
        for _ in 0..300 {
            t.minibatch_grad(&p, &mut rng, 32, &mut g);
            lion.step(&mut p, &g, 1e-3);
        }
        let acc = t.evaluate(&p).accuracy.unwrap();
        assert!(acc > 0.5, "acc={acc} (chance=0.1)");
    }
}
